"""XRT1 tensor-container IO — the Python half of `rust/src/util/io.rs`.

A deliberately trivial tagged binary so the Rust runtime needs no
zip/npz parsing:

    magic  b"XRT1"
    u32    n_tensors
    repeat n_tensors:
      u32 name_len, name (utf-8)
      u32 ndim, u32 dims[ndim]
      f32 data[prod(dims)]   (little-endian)
"""

from __future__ import annotations

import struct
from pathlib import Path

import numpy as np

MAGIC = b"XRT1"


def save_tensors(path: str | Path, tensors: dict[str, np.ndarray]) -> None:
    """Write a name→array map (arrays are cast to f32)."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors.items():
            arr = np.asarray(arr, dtype=np.float32)
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.astype("<f4").tobytes())


def load_tensors(path: str | Path) -> dict[str, np.ndarray]:
    """Read a container back."""
    out: dict[str, np.ndarray] = {}
    with open(path, "rb") as f:
        if f.read(4) != MAGIC:
            raise ValueError(f"bad magic in {path}")
        (n,) = struct.unpack("<I", f.read(4))
        for _ in range(n):
            (nl,) = struct.unpack("<I", f.read(4))
            name = f.read(nl).decode("utf-8")
            (ndim,) = struct.unpack("<I", f.read(4))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            total = int(np.prod(dims)) if ndim else 1
            data = np.frombuffer(f.read(4 * total), dtype="<f4")
            out[name] = data.reshape(dims).copy()
    return out
