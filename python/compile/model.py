"""L2 — the three XR perception models in JAX, with the QAT
quantization hooks (fake-quant weights/activations + PACT).

Layer names and weight layouts match the Rust executor exactly
(`rust/src/models/exec.rs`): conv weights are HWIO ``[k, k, in, out]``,
fc weights ``[in, out]``, PACT thresholds ``<act>.alpha``. The forward
functions are written against a flat ``params: dict[str, Array]`` so the
same dict round-trips through the XRT1 container to Rust.

``fmts`` — one format string per *compute* layer (see
``quantlib.ALL_FORMATS``) or ``None`` for the FP32 reference. When set,
both the layer's weights and its *output activations* are fake-quantized
to that format (the paper: "activations are retained with particular
precision across all layers, while computations remain in
FP-arithmetic").

The compute hot-spot (quantized GEMM) also exists as a Pallas kernel —
``kernels.mpmatmul`` — used by :func:`fc_pallas` so the exported HLO
exercises the L1 path; the pure-jnp forward here is its oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import quantlib as ql

# --------------------------------------------------------------------------
# primitives
# --------------------------------------------------------------------------


def conv2d(params, name, x, stride=1, pad=1, fmt=None):
    """NCHW conv with HWIO weights + bias. Both operands are quantized
    (the hardware input stage encodes activations and weights alike)."""
    w = params[f"{name}.w"]
    b = params[f"{name}.b"]
    if fmt is not None:
        x = ql.fake_quant(x, fmt)
        w = ql.fake_quant(w, fmt)
        b = ql.fake_quant(b, fmt)
    y = jax.lax.conv_general_dilated(
        x, w,
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "HWIO", "NCHW"),
    )
    y = y + b[None, :, None, None]
    if fmt is not None:
        y = ql.fake_quant(y, fmt)
    return y


def fc(params, name, x, fmt=None):
    w = params[f"{name}.w"]
    b = params[f"{name}.b"]
    if fmt is not None:
        x = ql.fake_quant(x, fmt)
        w = ql.fake_quant(w, fmt)
        b = ql.fake_quant(b, fmt)
    y = x @ w + b
    if fmt is not None:
        y = ql.fake_quant(y, fmt)
    return y


def pact_act(params, name, x, n_bits=8):
    """PACT activation (eqs. 6-7); α is trained."""
    alpha = jnp.maximum(params[f"{name}.alpha"], 1e-3)
    return ql.pact_quantize(x, alpha, n_bits)


def maxpool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 1, 2, 2), (1, 1, 2, 2), "VALID"
    )


def _he(rng, shape, fan_in):
    return (jax.random.normal(rng, shape) * jnp.sqrt(2.0 / fan_in)).astype(jnp.float32)


# --------------------------------------------------------------------------
# EffNet-XR (classification, 5 compute layers)
# --------------------------------------------------------------------------

EFFNET_COMPUTE = ["conv1", "conv2", "conv3", "fc1", "fc2"]


def effnet_params(key):
    ks = jax.random.split(key, 5)
    return {
        "conv1.w": _he(ks[0], (3, 3, 1, 8), 9),
        "conv1.b": jnp.zeros(8),
        "conv2.w": _he(ks[1], (3, 3, 8, 16), 72),
        "conv2.b": jnp.zeros(16),
        "conv3.w": _he(ks[2], (3, 3, 16, 32), 144),
        "conv3.b": jnp.zeros(32),
        "fc1.w": _he(ks[3], (128, 64), 128),
        "fc1.b": jnp.zeros(64),
        "fc2.w": _he(ks[4], (64, 10), 64),
        "fc2.b": jnp.zeros(10),
        "act1.alpha": jnp.array([4.0]),
        "act2.alpha": jnp.array([4.0]),
        "act3.alpha": jnp.array([4.0]),
        "act4.alpha": jnp.array([4.0]),
    }


def effnet_forward(params, x, fmts=None):
    """x: [n, 1, 16, 16] -> logits [n, 10]."""
    f = (lambda i: fmts[i]) if fmts is not None else (lambda i: None)
    x = conv2d(params, "conv1", x, fmt=f(0))
    x = maxpool2(pact_act(params, "act1", x))
    x = conv2d(params, "conv2", x, fmt=f(1))
    x = maxpool2(pact_act(params, "act2", x))
    x = conv2d(params, "conv3", x, fmt=f(2))
    x = maxpool2(pact_act(params, "act3", x))
    x = x.reshape(x.shape[0], -1)
    x = pact_act(params, "act4", fc(params, "fc1", x, fmt=f(3)))
    return fc(params, "fc2", x, fmt=f(4))


# --------------------------------------------------------------------------
# GazeNet (regression, 3 compute layers)
# --------------------------------------------------------------------------

GAZE_COMPUTE = ["fc1", "fc2", "fc3"]


def gaze_params(key):
    ks = jax.random.split(key, 3)
    return {
        "fc1.w": _he(ks[0], (16, 64), 16),
        "fc1.b": jnp.zeros(64),
        "fc2.w": _he(ks[1], (64, 64), 64),
        "fc2.b": jnp.zeros(64),
        "fc3.w": _he(ks[2], (64, 2), 64),
        "fc3.b": jnp.zeros(2),
        "act1.alpha": jnp.array([4.0]),
        "act2.alpha": jnp.array([4.0]),
    }


def gaze_forward(params, x, fmts=None):
    """x: [n, 16] -> gaze [n, 2] (radians)."""
    f = (lambda i: fmts[i]) if fmts is not None else (lambda i: None)
    x = pact_act(params, "act1", fc(params, "fc1", x, fmt=f(0)))
    x = pact_act(params, "act2", fc(params, "fc2", x, fmt=f(1)))
    return fc(params, "fc3", x, fmt=f(2))


# --------------------------------------------------------------------------
# UL-VIO-lite (odometry, 4 compute layers)
# --------------------------------------------------------------------------

ULVIO_COMPUTE = ["conv1", "conv2", "fc1", "fc2"]


def ulvio_params(key):
    ks = jax.random.split(key, 4)
    return {
        "conv1.w": _he(ks[0], (3, 3, 2, 8), 18),
        "conv1.b": jnp.zeros(8),
        "conv2.w": _he(ks[1], (3, 3, 8, 16), 72),
        "conv2.b": jnp.zeros(16),
        "fc1.w": _he(ks[2], (262, 64), 262),
        "fc1.b": jnp.zeros(64),
        "fc2.w": _he(ks[3], (64, 6), 64),
        "fc2.b": jnp.zeros(6),
        "act1.alpha": jnp.array([4.0]),
        "act2.alpha": jnp.array([4.0]),
        "act3.alpha": jnp.array([4.0]),
    }


def ulvio_forward(params, img, imu, fmts=None):
    """img: [n, 2, 16, 16], imu: [n, 6] -> rel pose [n, 6]."""
    f = (lambda i: fmts[i]) if fmts is not None else (lambda i: None)
    x = conv2d(params, "conv1", img, stride=2, fmt=f(0))
    x = pact_act(params, "act1", x)
    x = conv2d(params, "conv2", x, stride=2, fmt=f(1))
    x = pact_act(params, "act2", x)
    x = x.reshape(x.shape[0], -1)
    x = jnp.concatenate([x, imu], axis=1)
    x = pact_act(params, "act3", fc(params, "fc1", x, fmt=f(2)))
    return fc(params, "fc2", x, fmt=f(3))


# --------------------------------------------------------------------------
# Pallas-kerneled FC (the L1 integration point; see kernels/mpmatmul.py)
# --------------------------------------------------------------------------


def fc_pallas(params, name, x, fmt):
    """Same contract as :func:`fc` with the quantized matmul running in
    the Pallas kernel (interpret mode on CPU)."""
    from .kernels import mpmatmul

    w = params[f"{name}.w"]
    b = params[f"{name}.b"]
    if fmt != "fp32":
        b = ql.fake_quant(b, fmt)
    y = mpmatmul.mpmatmul(x, w, fmt)
    y = y + b
    if fmt == "fp32":
        return y
    return ql.scaled_quantize_jnp(y, fmt, ql.dyn_scale(y, fmt))


def gaze_forward_pallas(params, x, fmts):
    """GazeNet with every FC running through the Pallas kernel — the
    variant exported to HLO as `gaze_mxp_pallas`."""
    x = pact_act(params, "act1", fc_pallas(params, "fc1", x, fmts[0]))
    x = pact_act(params, "act2", fc_pallas(params, "fc2", x, fmts[1]))
    return fc_pallas(params, "fc3", x, fmts[2])


# --------------------------------------------------------------------------
# MLP-XR (the Table-IV-style MLP workload: flattened shapes-10)
# --------------------------------------------------------------------------

MLP_COMPUTE = ["fc1", "fc2", "fc3"]


def mlp_params(key):
    ks = jax.random.split(key, 3)
    return {
        "fc1.w": _he(ks[0], (256, 128), 256),
        "fc1.b": jnp.zeros(128),
        "fc2.w": _he(ks[1], (128, 64), 128),
        "fc2.b": jnp.zeros(64),
        "fc3.w": _he(ks[2], (64, 10), 64),
        "fc3.b": jnp.zeros(10),
        "act1.alpha": jnp.array([4.0]),
        "act2.alpha": jnp.array([4.0]),
    }


def mlp_forward(params, x, fmts=None):
    """x: [n, 256] (flattened 16x16) -> logits [n, 10]."""
    f = (lambda i: fmts[i]) if fmts is not None else (lambda i: None)
    x = pact_act(params, "act1", fc(params, "fc1", x, fmt=f(0)))
    x = pact_act(params, "act2", fc(params, "fc2", x, fmt=f(1)))
    return fc(params, "fc3", x, fmt=f(2))


MODELS = {
    "effnet": dict(params=effnet_params, forward=effnet_forward, compute=EFFNET_COMPUTE),
    "gaze": dict(params=gaze_params, forward=gaze_forward, compute=GAZE_COMPUTE),
    "ulvio": dict(params=ulvio_params, forward=ulvio_forward, compute=ULVIO_COMPUTE),
    "mlp": dict(params=mlp_params, forward=mlp_forward, compute=MLP_COMPUTE),
}
