"""QAT training for the three XR workload models (build-time only).

Hand-rolled Adam (optax is not available in the offline image). Flow per
model, mirroring the paper's §III protocol:

1. train FP32 to convergence on the synthetic workload;
2. for each hardware format, fine-tune with fake-quant in the loop
   (QAT) — "the retraining process maintains minimal accuracy loss";
3. capture per-layer loss gradients (for the sensitivity metric /
   planner) and the trained PACT α's.

Everything returns plain numpy dicts ready for the XRT1 container.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import datasets, model as M, quantlib as ql

HW_FMTS = ["fp4", "posit4", "posit8", "posit16"]


# --------------------------------------------------------------------------
# Adam
# --------------------------------------------------------------------------


def adam_init(params):
    z = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": z, "v": {k: jnp.zeros_like(v) for k, v in params.items()}, "t": 0}


def adam_step(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = {k: b1 * state["m"][k] + (1 - b1) * grads[k] for k in params}
    v = {k: b2 * state["v"][k] + (1 - b2) * grads[k] ** 2 for k in params}
    new = {}
    for k in params:
        mh = m[k] / (1 - b1**t)
        vh = v[k] / (1 - b2**t)
        new[k] = params[k] - lr * mh / (jnp.sqrt(vh) + eps)
    return new, {"m": m, "v": v, "t": t}


# --------------------------------------------------------------------------
# losses + training loops
# --------------------------------------------------------------------------


def xent(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(logp[jnp.arange(labels.shape[0]), labels])


def _train(loss_fn, params, data, steps, batch, lr, seed):
    """Generic mini-batch Adam loop. `data` is a tuple of arrays with
    equal leading dim; `loss_fn(params, *batch_arrays)`."""
    n = data[0].shape[0]
    state = adam_init(params)
    rng = np.random.default_rng(seed)

    @jax.jit
    def step(params, state, *batch_arrays):
        loss, grads = jax.value_and_grad(loss_fn)(params, *batch_arrays)
        params, state = adam_step(params, grads, state, lr=lr)
        return params, state, loss

    losses = []
    for _ in range(steps):
        idx = rng.integers(0, n, batch)
        batch_arrays = tuple(jnp.asarray(d[idx]) for d in data)
        params, state, loss = step(params, state, *batch_arrays)
        losses.append(float(loss))
    return params, losses


def _grads_of(loss_fn, params, data, batch=256, seed=0):
    """One full-batch gradient for the sensitivity export (`.w` layers)."""
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, data[0].shape[0], batch)
    batch_arrays = tuple(jnp.asarray(d[idx]) for d in data)
    grads = jax.grad(loss_fn)(params, *batch_arrays)
    return {k: np.asarray(v) for k, v in grads.items()}


def to_numpy(params):
    return {k: np.asarray(v, dtype=np.float32) for k, v in params.items()}


# --------------------------------------------------------------------------
# per-model drivers
# --------------------------------------------------------------------------


def train_effnet(steps=700, qat_steps=250, seed=0):
    """Returns (fp32 params+grads, {fmt: qat params}, eval set, metrics)."""
    xs, ys = datasets.shapes10(4000, seed=seed + 1)
    xt, yt = datasets.shapes10(600, seed=seed + 2)
    params = M.effnet_params(jax.random.PRNGKey(seed))

    def loss(p, x, y, fmts=None):
        return xent(M.effnet_forward(p, x, fmts), y)

    params, _ = _train(loss, params, (xs, ys), steps, 64, 1e-3, seed)

    @functools.partial(jax.jit, static_argnames="fmts")
    def acc(p, fmts=None):
        pred = jnp.argmax(M.effnet_forward(p, jnp.asarray(xt), list(fmts) if fmts else None), 1)
        return jnp.mean((pred == jnp.asarray(yt)).astype(jnp.float32))

    metrics = {"fp32": float(acc(params))}
    # PTQ sweep
    for fmt in ql.ALL_FORMATS:
        if fmt == "fp32":
            continue
        metrics[f"ptq_{fmt}"] = float(acc(params, fmts=(fmt,) * 5))
    # QAT fine-tunes
    qat = {}
    for fmt in HW_FMTS:
        def qloss(p, x, y, fmt=fmt):
            return loss(p, x, y, fmts=[fmt] * 5)
        qp, _ = _train(qloss, dict(params), (xs, ys), qat_steps, 64, 3e-4, seed + 3)
        a_qat = float(acc(qp, fmts=(fmt,) * 5))
        # QAT can destabilize on some format/model pairs; keep the better
        # of {QAT, PTQ-from-fp32} — the paper's flow "preserves accuracy
        # degradation" (never ships a worse model).
        if a_qat < metrics[f"ptq_{fmt}"]:
            qp, a_qat = params, metrics[f"ptq_{fmt}"]
        qat[fmt] = to_numpy(qp)
        metrics[f"qat_{fmt}"] = a_qat
    grads = _grads_of(lambda p, x, y: loss(p, x, y), params, (xs, ys))
    return to_numpy(params), grads, (xt, yt), qat, metrics


def train_gaze(steps=800, qat_steps=250, seed=10):
    xs, ys = datasets.gaze(6000, seed=seed + 1)
    xt, yt = datasets.gaze(800, seed=seed + 2)
    params = M.gaze_params(jax.random.PRNGKey(seed))

    def loss(p, x, y, fmts=None):
        return jnp.mean((M.gaze_forward(p, x, fmts) - y) ** 2)

    params, _ = _train(loss, params, (xs, ys), steps, 128, 1e-3, seed)

    @functools.partial(jax.jit, static_argnames="fmts")
    def mse(p, fmts=None):
        out = M.gaze_forward(p, jnp.asarray(xt), list(fmts) if fmts else None)
        return jnp.mean((out - jnp.asarray(yt)) ** 2)

    metrics = {"fp32": float(mse(params))}
    for fmt in ql.ALL_FORMATS:
        if fmt == "fp32":
            continue
        metrics[f"ptq_{fmt}"] = float(mse(params, fmts=(fmt,) * 3))
    qat = {}
    for fmt in HW_FMTS:
        def qloss(p, x, y, fmt=fmt):
            return loss(p, x, y, fmts=[fmt] * 3)
        qp, _ = _train(qloss, dict(params), (xs, ys), qat_steps, 128, 3e-4, seed + 3)
        m_qat = float(mse(qp, fmts=(fmt,) * 3))
        if m_qat > metrics[f"ptq_{fmt}"]:
            qp, m_qat = params, metrics[f"ptq_{fmt}"]
        qat[fmt] = to_numpy(qp)
        metrics[f"qat_{fmt}"] = m_qat
    grads = _grads_of(lambda p, x, y: loss(p, x, y), params, (xs, ys))
    return to_numpy(params), grads, (xt, yt), qat, metrics


def train_mlp(steps=600, qat_steps=200, seed=30):
    """Table-IV-style MLP on flattened shapes-10."""
    xs, ys = datasets.shapes10(4000, seed=seed + 1)
    xs = xs.reshape(len(xs), -1)
    xt, yt = datasets.shapes10(600, seed=seed + 2)
    xt = xt.reshape(len(xt), -1)
    params = M.mlp_params(jax.random.PRNGKey(seed))

    def loss(p, x, y, fmts=None):
        return xent(M.mlp_forward(p, x, fmts), y)

    params, _ = _train(loss, params, (xs, ys), steps, 64, 1e-3, seed)

    @functools.partial(jax.jit, static_argnames="fmts")
    def acc(p, fmts=None):
        pred = jnp.argmax(M.mlp_forward(p, jnp.asarray(xt), list(fmts) if fmts else None), 1)
        return jnp.mean((pred == jnp.asarray(yt)).astype(jnp.float32))

    metrics = {"fp32": float(acc(params))}
    for fmt in ql.ALL_FORMATS:
        if fmt == "fp32":
            continue
        metrics[f"ptq_{fmt}"] = float(acc(params, fmts=(fmt,) * 3))
    qat = {}
    for fmt in HW_FMTS:
        def qloss(p, x, y, fmt=fmt):
            return loss(p, x, y, fmts=[fmt] * 3)
        qp, _ = _train(qloss, dict(params), (xs, ys), qat_steps, 64, 3e-4, seed + 3)
        a_qat = float(acc(qp, fmts=(fmt,) * 3))
        if a_qat < metrics[f"ptq_{fmt}"]:
            qp, a_qat = params, metrics[f"ptq_{fmt}"]
        qat[fmt] = to_numpy(qp)
        metrics[f"qat_{fmt}"] = a_qat
    grads = _grads_of(lambda p, x, y: loss(p, x, y), params, (xs, ys))
    return to_numpy(params), grads, (xt, yt), qat, metrics


# rotation channels are small radians — upweight so the optimizer cares
ROT_WEIGHT = 20.0


def train_ulvio(steps=900, qat_steps=300, seed=20):
    imgs, imus, poses = datasets.kitti_like(4000, seed=seed + 1)
    ti, tu, tp = datasets.kitti_like(500, seed=seed + 2)
    params = M.ulvio_params(jax.random.PRNGKey(seed))
    w = jnp.array([1.0, 1.0, 1.0, ROT_WEIGHT, ROT_WEIGHT, ROT_WEIGHT])

    def loss(p, img, imu, pose, fmts=None):
        out = M.ulvio_forward(p, img, imu, fmts)
        return jnp.mean(((out - pose) * w) ** 2)

    params, _ = _train(loss, params, (imgs, imus, poses), steps, 64, 1e-3, seed)

    @functools.partial(jax.jit, static_argnames="fmts")
    def err(p, fmts=None):
        out = M.ulvio_forward(p, jnp.asarray(ti), jnp.asarray(tu), list(fmts) if fmts else None)
        terr = jnp.sqrt(jnp.mean((out[:, :3] - tp[:, :3]) ** 2))
        rerr = jnp.sqrt(jnp.mean((out[:, 3:] - tp[:, 3:]) ** 2))
        return terr, rerr

    def err_m(p, fmts=None):
        t, r = err(p, fmts)
        return {"t_rmse": float(t), "r_rmse": float(r)}

    metrics = {"fp32": err_m(params)}
    for fmt in ql.ALL_FORMATS:
        if fmt == "fp32":
            continue
        metrics[f"ptq_{fmt}"] = err_m(params, fmts=(fmt,) * 4)
    qat = {}
    for fmt in HW_FMTS:
        def qloss(p, img, imu, pose, fmt=fmt):
            return loss(p, img, imu, pose, fmts=[fmt] * 4)
        qp, _ = _train(qloss, dict(params), (imgs, imus, poses), qat_steps, 64, 3e-4, seed + 3)
        m_qat = err_m(qp, fmts=(fmt,) * 4)
        if m_qat["t_rmse"] > metrics[f"ptq_{fmt}"]["t_rmse"]:
            qp, m_qat = params, metrics[f"ptq_{fmt}"]
        qat[fmt] = to_numpy(qp)
        metrics[f"qat_{fmt}"] = m_qat
    grads = _grads_of(lambda p, i, u, y: loss(p, i, u, y), params, (imgs, imus, poses))
    return to_numpy(params), grads, (ti, tu, tp), qat, metrics
