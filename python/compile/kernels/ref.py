"""Pure-jnp oracles for the Pallas kernels.

These are the *definitions of correctness* the kernels are tested
against (pytest + hypothesis sweeps in python/tests/test_kernels.py).
"""

from __future__ import annotations

import jax.numpy as jnp

from .. import quantlib as ql


def mpmatmul_ref(a: jnp.ndarray, b: jnp.ndarray, fmt: str) -> jnp.ndarray:
    """Mixed-precision matmul oracle: quantize both operands to `fmt`
    (codec-exact, no STE), multiply-accumulate in f32.

    f32 accumulation models the engine's quire over a tile's dot
    products: every product of <=16-bit-format operands is exact in f32's
    24-bit significand only for 4/8-bit formats; for posit16 the oracle
    (and the kernel) accumulate in f32 like the XLA dot they lower to —
    the Rust simulator is the stricter quire-exact reference.
    """
    if fmt == "fp32":
        return a.astype(jnp.float32) @ b.astype(jnp.float32)
    sa = ql.dyn_scale(a, fmt)
    sb = ql.dyn_scale(b, fmt)
    qa = ql.quantize_jnp(a / sa, fmt).astype(jnp.float32)
    qb = ql.quantize_jnp(b / sb, fmt).astype(jnp.float32)
    return (qa @ qb) * (sa * sb)


def quantize_ref(x: jnp.ndarray, fmt: str) -> jnp.ndarray:
    """Elementwise codec-exact quantization oracle."""
    return ql.quantize_jnp(x, fmt).astype(jnp.float32)
