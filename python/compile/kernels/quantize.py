"""L1 — elementwise tile quantizer as a Pallas kernel.

The standalone version of the input-processing stage: quantize a tensor
to a hardware format, tile by tile (BlockSpec expresses the HBM->VMEM
stream). Used by the activation-requantization step between layers and
as the simplest kernel for the hypothesis shape/dtype sweeps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import quantlib as ql


def _kernel(x_ref, pv_ref, th_ref, o_ref):
    x = x_ref[...]
    idx = jnp.searchsorted(th_ref[...], jnp.abs(x), side="right")
    q = pv_ref[...][idx]
    o_ref[...] = jnp.where(jnp.signbit(x), -q, q).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("fmt", "block"))
def quantize(x, fmt: str, block: int = 256):
    """Quantize a 2-D array to `fmt`, tiled along the leading axis."""
    if fmt == "fp32":
        return x.astype(jnp.float32)
    m, n = x.shape
    pv_np, th_np = ql.tables(fmt)
    pv = jnp.asarray(pv_np, jnp.float32)
    th = jnp.asarray(th_np, jnp.float32)
    bm = min(m, block)
    grid = (pl.cdiv(m, bm),)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec(pv.shape, lambda i: (0,)),
            pl.BlockSpec(th.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), pv, th)
