"""L1 — the mixed-precision blocked matmul as a Pallas kernel.

## Hardware adaptation (DESIGN.md §Hardware-Adaptation)

The paper's hot-spot is a GEMM on an 8x8 output-stationary MAC array
with per-layer `prec_sel`. On a TPU-shaped machine the same insight maps
to:

* **quantize at the VMEM boundary** — operand tiles are fake-quantized
  (threshold-table searchsorted, the vector-unit analogue of the input
  processing stage) right before the MXU consumes them, so HBM<->VMEM
  traffic is what sets the achievable arithmetic intensity, exactly like
  the paper's off-chip-movement argument;
* **accumulate wide** — `jnp.dot(..., preferred_element_type=f32)`
  stands in for the quire: one rounding at tile output;
* **BlockSpec tiling** — the grid expresses the HBM->VMEM schedule the
  ASIC's DMA + banked SPM implement (block sizes default to the MXU-
  friendly 128 but shrink to the problem).

Run with ``interpret=True`` everywhere: the CPU PJRT plugin cannot
execute Mosaic custom-calls; interpret-mode lowers to plain HLO so the
kernel runs inside the AOT artifacts the Rust runtime loads.

VMEM budget per grid step (f32): `bm*bk + bk*bn + bm*bn + tables` —
at the default 128³ blocks ≈ 192 KiB + ~0.5 MiB of posit16 tables,
comfortably under the ~16 MiB/core budget (see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import quantlib as ql


def _quant_tile(x, pv, th):
    """Codec-exact fake quantization of a tile via threshold tables
    (vectorized searchsorted — the input-processing stage)."""
    idx = jnp.searchsorted(th, jnp.abs(x), side="right")
    q = pv[idx]
    return jnp.where(jnp.signbit(x), -q, q).astype(jnp.float32)


def _kernel(a_ref, b_ref, pv_ref, th_ref, o_ref, *, n_k: int):
    """One (i, j, k) grid step: o[i,j] += quant(a[i,k]) @ quant(b[k,j])."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    qa = _quant_tile(a_ref[...], pv_ref[...], th_ref[...])
    qb = _quant_tile(b_ref[...], pv_ref[...], th_ref[...])
    o_ref[...] += jnp.dot(qa, qb, preferred_element_type=jnp.float32)
    del n_k


def _block(dim: int, pref: int) -> int:
    """Largest block <= pref that keeps the grid simple (dims here are
    small; real-TPU tuning would pin 128x128 MXU tiles)."""
    return min(dim, pref)


@functools.partial(jax.jit, static_argnames=("fmt", "bm", "bk", "bn"))
def mpmatmul(a, b, fmt: str, bm: int = 128, bk: int = 128, bn: int = 128):
    """Mixed-precision matmul: `quant(a) @ quant(b)` with f32 (quire-
    style) accumulation. `fmt` is any `quantlib` format; `fp32` skips
    quantization but keeps the same kernel path."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims {k} != {k2}"
    if fmt != "fp32":
        # per-tensor pow-2 scaling (the exponent-offset registers of the
        # input stage); folded back after the quire-style accumulate
        sa = ql.dyn_scale(a, fmt)
        sb = ql.dyn_scale(b, fmt)
        a = a / sa
        b = b / sb
        pv_np, th_np = ql.tables(fmt)
        pv = jnp.asarray(pv_np, jnp.float32)
        th = jnp.asarray(th_np, jnp.float32)

    if fmt == "fp32":
        # identity quantization: same blocked kernel without the tables
        def kern(a_ref, b_ref, o_ref, *, n_k):
            kk = pl.program_id(2)

            @pl.when(kk == 0)
            def _init():
                o_ref[...] = jnp.zeros_like(o_ref)

            o_ref[...] += jnp.dot(
                a_ref[...], b_ref[...], preferred_element_type=jnp.float32
            )
            del n_k

        bm_, bk_, bn_ = _block(m, bm), _block(k, bk), _block(n, bn)
        grid = (pl.cdiv(m, bm_), pl.cdiv(n, bn_), pl.cdiv(k, bk_))
        return pl.pallas_call(
            functools.partial(kern, n_k=grid[2]),
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
                pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
            ],
            out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
            out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
            interpret=True,
        )(a.astype(jnp.float32), b.astype(jnp.float32))

    bm_, bk_, bn_ = _block(m, bm), _block(k, bk), _block(n, bn)
    grid = (pl.cdiv(m, bm_), pl.cdiv(n, bn_), pl.cdiv(k, bk_))
    out = pl.pallas_call(
        functools.partial(_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
            # tables are broadcast to every grid step (resident in VMEM)
            pl.BlockSpec(pv.shape, lambda i, j, kk: (0,)),
            pl.BlockSpec(th.shape, lambda i, j, kk: (0,)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a.astype(jnp.float32), b.astype(jnp.float32), pv, th)
    return out * (sa * sb)


def vmem_bytes(bm: int, bk: int, bn: int, fmt: str) -> int:
    """Static VMEM footprint estimate per grid step (f32), for the
    DESIGN.md/EXPERIMENTS.md roofline discussion."""
    tiles = (bm * bk + bk * bn + bm * bn) * 4
    if fmt == "fp32":
        return tiles
    pv, th = ql.tables(fmt)
    return tiles + (len(pv) + len(th)) * 4
