"""AOT build driver: train → verify kernels → export everything the
Rust side consumes.

Outputs under ``--out`` (default ``../artifacts``):

* ``weights_{model}.bin``          FP32 QAT-ready params + per-layer
                                   loss gradients (``<layer>.g``) —
                                   XRT1 containers (rust `util::io`).
* ``weights_{model}_qat_{fmt}.bin``  QAT-fine-tuned params per HW format.
* ``eval_shapes.bin`` / ``eval_gaze.bin`` / ``eval_vio.bin``
                                   held-out evaluation sets.
* ``{model}_{variant}.hlo.txt``    inference graphs lowered to HLO TEXT
                                   (not .serialize() — xla_extension
                                   0.5.1 rejects jax>=0.5's 64-bit-id
                                   protos; the text parser round-trips).
* ``mpmatmul_{fmt}.hlo.txt``       the Pallas kernel lowered standalone.
* ``plan.json``                    the python-side layer-adaptive plan
                                   (mirrors rust `quant::policy`).
* ``metrics.json``                 training-side accuracy/MSE per
                                   precision (cross-checked by benches).

Python runs ONCE here; the Rust binary is self-contained afterwards.
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M, quantlib as ql, train, xrt


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def export_hlo(path: Path, fn, *example_args):
    lowered = jax.jit(fn).lower(*example_args)
    path.write_text(to_hlo_text(lowered))
    print(f"  wrote {path.name}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--fast", action="store_true", help="tiny training run (CI smoke)")
    args = ap.parse_args()
    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    t0 = time.time()

    scale = 0.15 if args.fast else 1.0

    def s(n):
        return max(20, int(n * scale))

    # ---------------- train ----------------
    print("[1/4] training EffNet-XR (shapes-10)…")
    eff_p, eff_g, (ex, ey), eff_qat, eff_m = train.train_effnet(s(700), s(250))
    print(f"      fp32 acc {eff_m['fp32']:.3f}  qat_fp4 {eff_m['qat_fp4']:.3f}")
    print("[2/4] training GazeNet…")
    gz_p, gz_g, (gx, gy), gz_qat, gz_m = train.train_gaze(s(800), s(250))
    print(f"      fp32 mse {gz_m['fp32']:.5f}")
    print("[3/4] training UL-VIO-lite (KITTI-like)…")
    vio_p, vio_g, (vi, vu, vp), vio_qat, vio_m = train.train_ulvio(s(900), s(300))
    print(f"      fp32 t_rmse {vio_m['fp32']['t_rmse']:.4f} r_rmse {vio_m['fp32']['r_rmse']:.5f}")
    print("[3b/4] training MLP-XR…")
    mlp_p, mlp_g, _, mlp_qat, mlp_m = train.train_mlp(s(600), s(200))
    print(f"      fp32 acc {mlp_m['fp32']:.3f}")

    # ---------------- plans ----------------
    def plan_for(params, grads, compute, pin_last):
        ws = [params[f"{n}.w"] for n in compute]
        gs = [grads[f"{n}.w"] for n in compute]
        pins = (len(compute) - 1,) if pin_last else ()
        return ql.plan_formats(ws, gs, avg_bits_budget=6.0, base4="fp4", pin_high=pins)

    plans = {
        "effnet": plan_for(eff_p, eff_g, M.EFFNET_COMPUTE, False),
        "gaze": plan_for(gz_p, gz_g, M.GAZE_COMPUTE, False),
        "ulvio": plan_for(vio_p, vio_g, M.ULVIO_COMPUTE, True),
        "mlp": plan_for(mlp_p, mlp_g, M.MLP_COMPUTE, False),
    }
    (out / "plan.json").write_text(json.dumps(plans, indent=2))
    print(f"      plans: {plans}")

    # ---------------- weights + eval sets ----------------
    print("[4/4] exporting artifacts…")
    for name, params, grads, qat in [
        ("effnet", eff_p, eff_g, eff_qat),
        ("gaze", gz_p, gz_g, gz_qat),
        ("ulvio", vio_p, vio_g, vio_qat),
        ("mlp", mlp_p, mlp_g, mlp_qat),
    ]:
        blob = dict(params)
        blob.update({k + ".g" if not k.endswith(".g") else k: v
                     for k, v in ((f"{kk[:-2]}.g", vv) for kk, vv in grads.items()
                                  if kk.endswith(".w"))})
        xrt.save_tensors(out / f"weights_{name}.bin", blob)
        for fmt, qp in qat.items():
            xrt.save_tensors(out / f"weights_{name}_qat_{fmt}.bin", qp)

    xrt.save_tensors(out / "eval_shapes.bin",
                     {"images": ex, "labels": ey.astype(np.float32)})
    xrt.save_tensors(out / "eval_gaze.bin", {"landmarks": gx, "gaze": gy})
    xrt.save_tensors(out / "eval_vio.bin", {"images": vi, "imu": vu, "poses": vp})

    # ---------------- metrics ----------------
    (out / "metrics.json").write_text(json.dumps(
        {"effnet": eff_m, "gaze": gz_m, "ulvio": vio_m, "mlp": mlp_m}, indent=2))

    # ---------------- HLO exports ----------------
    ep = {k: jnp.asarray(v) for k, v in eff_p.items()}
    gp = {k: jnp.asarray(v) for k, v in gz_p.items()}
    up = {k: jnp.asarray(v) for k, v in vio_p.items()}
    img1 = jnp.zeros((1, 1, 16, 16), jnp.float32)
    lnd1 = jnp.zeros((1, 16), jnp.float32)
    vimg1 = jnp.zeros((1, 2, 16, 16), jnp.float32)
    imu1 = jnp.zeros((1, 6), jnp.float32)

    export_hlo(out / "effnet_fp32.hlo.txt",
               lambda x: (M.effnet_forward(ep, x),), img1)
    export_hlo(out / "effnet_mxp.hlo.txt",
               lambda x: (M.effnet_forward(ep, x, plans["effnet"]),), img1)
    export_hlo(out / "gaze_fp32.hlo.txt",
               lambda x: (M.gaze_forward(gp, x),), lnd1)
    export_hlo(out / "gaze_mxp.hlo.txt",
               lambda x: (M.gaze_forward(gp, x, plans["gaze"]),), lnd1)
    export_hlo(out / "gaze_mxp_pallas.hlo.txt",
               lambda x: (M.gaze_forward_pallas(gp, x, plans["gaze"]),), lnd1)
    export_hlo(out / "ulvio_fp32.hlo.txt",
               lambda i, u: (M.ulvio_forward(up, i, u),), vimg1, imu1)
    export_hlo(out / "ulvio_mxp.hlo.txt",
               lambda i, u: (M.ulvio_forward(up, i, u, plans["ulvio"]),), vimg1, imu1)

    mp = {k: jnp.asarray(v) for k, v in mlp_p.items()}
    flat1 = jnp.zeros((1, 256), jnp.float32)
    export_hlo(out / "mlp_fp32.hlo.txt", lambda x: (M.mlp_forward(mp, x),), flat1)
    export_hlo(out / "mlp_mxp.hlo.txt",
               lambda x: (M.mlp_forward(mp, x, plans["mlp"]),), flat1)

    # standalone Pallas kernel artifact (the L1 demo the quickstart runs)
    from .kernels import mpmatmul
    export_hlo(out / "mpmatmul_posit8.hlo.txt",
               lambda a, b: (mpmatmul.mpmatmul(a, b, "posit8"),),
               jnp.zeros((16, 32), jnp.float32), jnp.zeros((32, 16), jnp.float32))

    manifest = {
        "models": sorted(p.name for p in out.glob("*.hlo.txt")),
        "weights": sorted(p.name for p in out.glob("weights_*.bin")),
        "eval_sets": sorted(p.name for p in out.glob("eval_*.bin")),
        "build_seconds": round(time.time() - t0, 1),
    }
    (out / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"done in {manifest['build_seconds']}s → {out}")


if __name__ == "__main__":
    main()
