"""Mixed-precision quantization library — the Python mirror of
`rust/src/arith` + `rust/src/quant` (paper eqs. 1-7).

Bit-exact posit/minifloat codecs (ported from the Rust implementation,
including posit *bit-string* rounding, which is NOT value-nearest when
the truncation point falls inside the regime/exponent field), value +
threshold tables for vectorized fake quantization, straight-through
estimators for QAT, PACT, the entropy clipping scheme and the layer
sensitivity metric.

`python/tests/test_quantlib.py` pins decode values and rounding
behaviour against golden vectors verified by the Rust test suite, so the
two sides cannot drift silently.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

# --------------------------------------------------------------------------
# posit codec (mirror of rust/src/arith/posit.rs)
# --------------------------------------------------------------------------


def posit_decode(bits: int, n: int, es: int) -> float:
    """Decode an n-bit posit encoding to a float (NaR → nan)."""
    mask = (1 << n) - 1
    bits &= mask
    if bits == 0:
        return 0.0
    nar = 1 << (n - 1)
    if bits == nar:
        return math.nan
    sign = bool(bits & nar)
    v = (-bits) & mask if sign else bits
    body_bits = n - 1
    r0 = (v >> (n - 2)) & 1
    run = 0
    while run < body_bits and ((v >> (n - 2 - run)) & 1) == r0:
        run += 1
    k = run - 1 if r0 == 1 else -run
    consumed = min(run + 1, body_bits)
    rem = body_bits - consumed
    e_avail = min(rem, es)
    e = ((v >> (rem - e_avail)) & ((1 << e_avail) - 1)) << (es - e_avail) if e_avail else 0
    fb = rem - e_avail
    frac = v & ((1 << fb) - 1) if fb else 0
    scale = (k << es) + e
    sig = (1 << fb) | frac
    val = sig * 2.0 ** (scale - fb)
    return -val if sign else val


def posit_encode(x: float, n: int, es: int) -> int:
    """Encode a float to the nearest n-bit posit (bit-string RNE)."""
    mask = (1 << n) - 1
    if x == 0.0:
        return 0
    if math.isnan(x) or math.isinf(x):
        return (1 << (n - 1)) & mask
    sign = x < 0.0
    a = abs(x)
    top = 2.0 ** ((n - 2) << es)
    bot = 1.0 / top
    if a >= top:
        body = mask >> 1
    elif a <= bot:
        body = 1
    else:
        m, e2 = math.frexp(a)  # a = m * 2**e2, m in [0.5, 1)
        scale = e2 - 1
        frac52 = int((m * 2 - 1) * (1 << 52))  # 52-bit fraction of 1.f
        k, e = divmod(scale, 1 << es)
        bs = 0
        ln = 0
        if k >= 0:
            for _ in range(k + 1):
                bs = (bs << 1) | 1
                ln += 1
            bs <<= 1
            ln += 1
        else:
            bs <<= -k
            ln += -k
            bs = (bs << 1) | 1
            ln += 1
        for i in reversed(range(es)):
            bs = (bs << 1) | ((e >> i) & 1)
            ln += 1
        bs = (bs << 52) | frac52
        ln += 52
        keep = n - 1
        if ln <= keep:
            body = bs << (keep - ln)
        else:
            drop = ln - keep
            topbits = bs >> drop
            guard = (bs >> (drop - 1)) & 1
            sticky = (bs & ((1 << (drop - 1)) - 1)) != 0 if drop > 1 else False
            r = topbits
            if guard == 1 and (sticky or (topbits & 1) == 1):
                r += 1
            if r >> keep:
                body = mask >> 1
            elif r == 0:
                body = 1
            else:
                body = r
    body &= mask >> 1
    return ((-body) & mask) if sign else body


# --------------------------------------------------------------------------
# minifloat codec (mirror of rust/src/arith/fp.rs)
# --------------------------------------------------------------------------

# (e_bits, m_bits, bias, flavor); flavor: 'ieee' | 'finite_nan' | 'finite'
MINIFLOATS = {
    "fp4": (2, 1, 1, "finite"),
    "e4m3": (4, 3, 7, "finite_nan"),
    "e5m2": (5, 2, 15, "ieee"),
    "fp16": (5, 10, 15, "ieee"),
    "bf16": (8, 7, 127, "ieee"),
}


def minifloat_decode(raw: int, fmt: str) -> float:
    e_bits, m_bits, bias, flavor = MINIFLOATS[fmt]
    total = 1 + e_bits + m_bits
    raw &= (1 << total) - 1
    sign = (raw >> (total - 1)) & 1
    exp = (raw >> m_bits) & ((1 << e_bits) - 1)
    mant = raw & ((1 << m_bits) - 1)
    emax = (1 << e_bits) - 1
    if exp == emax:
        if flavor == "ieee":
            return math.nan if mant else (-math.inf if sign else math.inf)
        if flavor == "finite_nan" and mant == (1 << m_bits) - 1:
            return math.nan
    if exp == 0:
        val = mant * 2.0 ** (1 - bias - m_bits)
    else:
        val = (1 + mant / (1 << m_bits)) * 2.0 ** (exp - bias)
    return -val if sign else val


# --------------------------------------------------------------------------
# value/threshold tables (codec-exact quantization, vectorized)
# --------------------------------------------------------------------------

POSITS = {"posit4": (4, 1), "posit8": (8, 0), "posit16": (16, 1), "posit32": (32, 2)}
FIXED = {"fxp4": (4, 2), "fxp8": (8, 4), "fxp16": (16, 8)}

HW_FORMATS = ["fp4", "posit4", "posit8", "posit16"]
ALL_FORMATS = ["fp32", "bf16", "fp16", "e4m3", "e5m2", "fp4",
               "posit16", "posit8", "posit4", "fxp8", "fxp4"]


def _decode_fn(fmt: str):
    if fmt in MINIFLOATS:
        return lambda b: minifloat_decode(b, fmt)
    if fmt in POSITS:
        n, es = POSITS[fmt]
        return lambda b: posit_decode(b, n, es)
    if fmt in FIXED:
        n, frac = FIXED[fmt]

        def dec(b):
            m = (1 << n) - 1
            v = b & m
            if v & (1 << (n - 1)):
                v -= 1 << n
            return v / (1 << frac)

        return dec
    raise ValueError(f"unknown format {fmt}")


@functools.lru_cache(maxsize=None)
def tables(fmt: str) -> tuple[np.ndarray, np.ndarray]:
    """(pos_vals, thresholds): non-negative representable values
    (ascending, from 0) and decision thresholds between them, matching
    `rust/src/arith/tables.rs` exactly.

    * posits: the threshold between adjacent bodies i, i+1 under
      bit-string RNE is the value of the guard-bit midpoint — i.e. the
      (n+1)-bit posit with body `2i+1`; an exact tie keeps the body with
      even LSB. Non-zero values never round to zero (minpos clamp), so
      the 0→minpos threshold is the smallest positive double.
    * minifloats / fixed point: value midpoints with ties to the even
      encoding (== even index in the value grid, since every exponent
      block holds an even count of values).
    """
    if fmt == "fp32":
        raise ValueError("fp32 is identity")
    if fmt in POSITS:
        n, es = POSITS[fmt]
        if n > 16:
            raise ValueError(f"{fmt}: tables only for <=16-bit formats")
        bodies = np.arange(1, 1 << (n - 1))
        pos_vals = np.array(
            [0.0] + [posit_decode(int(b), n, es) for b in bodies], dtype=np.float64
        )
        thresholds = np.empty(len(pos_vals) - 1, dtype=np.float64)
        thresholds[0] = 5e-324  # anything > 0 rounds to minpos
        for i in range(1, len(pos_vals) - 1):
            mid = posit_decode(2 * i + 1, n + 1, es)
            # tie keeps even body: body i even → tie stays at i → the
            # round-up threshold is just above mid
            thresholds[i] = np.nextafter(mid, np.inf) if i % 2 == 0 else mid
        return pos_vals, thresholds

    bits = {"fp4": 4, "e4m3": 8, "e5m2": 8, "fp16": 16, "bf16": 16}.get(fmt)
    if bits is None:
        bits = FIXED[fmt][0]
    dec = _decode_fn(fmt)
    vals = set()
    for b in range(1 << bits):
        v = dec(b)
        if not math.isnan(v) and not math.isinf(v) and v >= 0.0:
            vals.add(v)
    pos_vals = np.array(sorted(vals | {0.0}), dtype=np.float64)
    thresholds = np.empty(len(pos_vals) - 1, dtype=np.float64)
    for i in range(len(pos_vals) - 1):
        lo, hi = pos_vals[i], pos_vals[i + 1]
        mid = (lo + hi) / 2.0
        # tie → even index: if lo's index (i) is even, ties stay at lo
        thresholds[i] = np.nextafter(mid, np.inf) if i % 2 == 0 else mid
    return pos_vals, thresholds


def quantize_np(x: np.ndarray, fmt: str) -> np.ndarray:
    """Codec-exact fake quantization (numpy, for tests/offline)."""
    if fmt == "fp32":
        return np.asarray(x, dtype=np.float32).astype(np.float64)
    pos_vals, thr = tables(fmt)
    a = np.abs(x)
    idx = np.searchsorted(thr, a, side="right")
    q = pos_vals[idx]
    return np.where(np.signbit(x), -q, q)


def quantize_jnp(x: jnp.ndarray, fmt: str) -> jnp.ndarray:
    """Codec-exact fake quantization as a jax op (no gradient)."""
    if fmt == "fp32":
        return x
    pos_vals, thr = tables(fmt)
    pv = jnp.asarray(pos_vals, dtype=x.dtype)
    th = jnp.asarray(thr, dtype=x.dtype)
    idx = jnp.searchsorted(th, jnp.abs(x), side="right")
    q = pv[idx]
    return jnp.where(jnp.signbit(x), -q, q)


# largest finite value per format (for range-fit scaling)
FMT_MAX = {
    "fp4": 6.0, "e4m3": 448.0, "e5m2": 57344.0,
    "fxp4": 1.75, "fxp8": 127.0 / 16.0, "fxp16": 32767.0 / 256.0,
    "posit4": 16.0, "posit8": 64.0, "posit16": 2.0**28,
    "fp16": 65504.0, "bf16": 3.389e38,
}

#: formats that need range-fit scaling (narrow dynamic range)
_RANGE_FIT = {"fp4", "fxp4", "fxp8", "fxp16", "e4m3", "e5m2"}
#: tapered-precision formats, centered at 1.0 where resolution peaks
_CENTER = {"posit4", "posit8", "posit16", "posit32"}


def scale_for(x, fmt: str) -> float:
    """Host-side (numpy) per-tensor power-of-two scale — paper eq. (3)
    restricted to powers of two so hardware folds the scale into the
    exponent path. Range-fit for narrow formats (max|x| → format max),
    magnitude-centering for posits (tapered precision peaks at 1.0).
    Mirrored by `rust/src/models/exec.rs::scale_for` and by
    :func:`dyn_scale` inside traced graphs."""
    if fmt == "fp32" or fmt in ("fp16", "bf16"):
        return 1.0
    ax = np.abs(np.asarray(x, dtype=np.float64))
    if ax.size == 0:
        return 1.0
    if fmt in _RANGE_FIT:
        m = float(ax.max())
        if m == 0.0:
            return 1.0
        return 2.0 ** round(math.log2(m / FMT_MAX[fmt]))
    m = float(ax.mean())
    if m == 0.0:
        return 1.0
    return 2.0 ** round(math.log2(m))


def dyn_scale(x: jnp.ndarray, fmt: str) -> jnp.ndarray:
    """In-graph version of :func:`scale_for` (works on tracers, so
    activation scales are computed dynamically — the input-processing
    stage's exponent-offset register)."""
    if fmt == "fp32" or fmt in ("fp16", "bf16"):
        return jnp.float32(1.0)
    ax = jnp.abs(x)
    if fmt in _RANGE_FIT:
        m = jnp.max(ax)
        s = jnp.exp2(jnp.round(jnp.log2(jnp.maximum(m, 1e-12) / FMT_MAX[fmt])))
        return jnp.where(m > 0, s, 1.0).astype(x.dtype)
    m = jnp.mean(ax)
    s = jnp.exp2(jnp.round(jnp.log2(jnp.maximum(m, 1e-12))))
    return jnp.where(m > 0, s, 1.0).astype(x.dtype)


def scaled_quantize_jnp(x: jnp.ndarray, fmt: str, scale) -> jnp.ndarray:
    """`s · Q(x / s)` — codec-exact, no gradient."""
    if fmt == "fp32":
        return x
    return scale * quantize_jnp(x / scale, fmt)


def fake_quant(x: jnp.ndarray, fmt: str) -> jnp.ndarray:
    """Straight-through-estimator fake quantization (QAT) with the
    dynamic per-tensor pow-2 scale."""
    if fmt == "fp32":
        return x
    s = dyn_scale(jax.lax.stop_gradient(x), fmt)
    q = scaled_quantize_jnp(x, fmt, s)
    return x + jax.lax.stop_gradient(q - x)


# --------------------------------------------------------------------------
# PACT (eqs. 6-7)
# --------------------------------------------------------------------------


def pact(x: jnp.ndarray, alpha: jnp.ndarray) -> jnp.ndarray:
    """Eq. (6): y = 0.5 (|x| - |x - α| + α) == clip(x, 0, α)."""
    return 0.5 * (jnp.abs(x) - jnp.abs(x - alpha) + alpha)


def pact_quantize(x: jnp.ndarray, alpha: jnp.ndarray, n_bits: int) -> jnp.ndarray:
    """Eq. (7) with STE on the rounding."""
    y = pact(x, alpha)
    levels = (1 << n_bits) - 1
    q = jnp.round(y * levels / alpha) * alpha / levels
    return y + jax.lax.stop_gradient(q - y)


# --------------------------------------------------------------------------
# entropy clipping (eqs. 3-5) — offline, numpy
# --------------------------------------------------------------------------


def scale_k(w: np.ndarray, n_bits: int) -> float:
    """Eq. (3)."""
    mean_abs = float(np.mean(np.abs(w))) if w.size else 1.0
    return max(mean_abs * (2.0**n_bits - 1.0) / 2.0 ** (n_bits - 1), 1e-12)


def entropy_fit(w: np.ndarray, n_bits: int) -> tuple[float, float, float]:
    """Fit (k, w_l, w_h) by scanning tail-clip candidates for maximum
    bin entropy (mirror of rust/src/quant/entropy.rs)."""
    k = scale_k(w, n_bits)
    if w.size == 0:
        return k, -1.0, 1.0
    wn = np.sort(w.astype(np.float64) / k)
    best = (-np.inf, wn[0], wn[-1])
    bins = 1 << n_bits
    for tail in (0.0, 0.001, 0.005, 0.01, 0.025, 0.05):
        lo = wn[int(round((len(wn) - 1) * tail))]
        hi = wn[int(round((len(wn) - 1) * (1 - tail)))]
        if hi - lo < 1e-9:
            continue
        clipped = np.clip(wn, lo, hi)
        b = np.round((clipped - lo) / (hi - lo) * (bins - 1)).astype(int)
        hist = np.bincount(b, minlength=bins)
        p = hist[hist > 0] / len(wn)
        h = float(-(p * np.log2(p)).sum())
        if h > best[0]:
            best = (h, lo, hi)
    return k, best[1], best[2]


def entropy_quantize(w: np.ndarray, n_bits: int) -> np.ndarray:
    """Eqs. (4)+(5) (returns to weight space)."""
    k, lo, hi = entropy_fit(w, n_bits)
    levels = (1 << n_bits) - 1
    c = np.clip(w / k, lo, hi)
    w_hat = np.round((c - lo) * levels / (hi - lo))
    return (w_hat * (hi - lo) / levels + lo) * k


# --------------------------------------------------------------------------
# sensitivity metric (eqs. 1-2) — offline, numpy
# --------------------------------------------------------------------------


def distortion(w: np.ndarray, fmt: str) -> float:
    return float(np.linalg.norm(quantize_np(w, fmt) - w))


def sensitivity(w: np.ndarray, g: np.ndarray, current: str, cand: str) -> float:
    """Eq. (1)."""
    if w.size == 0:
        return 0.0
    d_cur = distortion(w, current)
    d_cand = distortion(w, cand)
    return (d_cur - d_cand) * float(np.linalg.norm(g)) / w.size


def layer_cost_low(w: np.ndarray, g: np.ndarray, fmt4: str = "fp4") -> float:
    """Gradient-weighted 4-bit distortion — the planner's ranking key
    (mirror of rust LayerSensitivity::cost_low)."""
    if w.size == 0:
        return 0.0
    return distortion(w, fmt4) * float(np.linalg.norm(g)) / w.size


def plan_formats(
    weights: list[np.ndarray],
    grads: list[np.ndarray],
    avg_bits_budget: float,
    base4: str = "fp4",
    pin_high: tuple[int, ...] = (),
) -> list[str]:
    """Budgeted 4→8→16 promotion, mirror of rust/src/quant/policy.rs."""
    fmt_bits = {"fp4": 4, "posit4": 4, "posit8": 8, "posit16": 16}
    ladder = {"fp4": "posit8", "posit4": "posit8", "posit8": "posit16"}
    params = [w.size for w in weights]
    fmts = [base4] * len(weights)
    for i in pin_high:
        fmts[i] = "posit16"

    def avg_bits():
        total = sum(params)
        return sum(fmt_bits[f] * p for f, p in zip(fmts, params)) / max(total, 1)

    order = sorted(range(len(weights)),
                   key=lambda i: -layer_cost_low(weights[i], grads[i], base4))
    while True:
        promoted = False
        for i in order:
            if i in pin_high or fmts[i] not in ladder:
                continue
            old = fmts[i]
            fmts[i] = ladder[old]
            if avg_bits() > avg_bits_budget:
                fmts[i] = old
            else:
                promoted = True
                break
        if not promoted:
            return fmts
