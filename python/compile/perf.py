"""§Perf probe for L1 (Pallas kernel structure) and L2 (lowered HLO).

Interpret-mode wallclock is *not* a TPU proxy, so L1 reporting is
structural: VMEM bytes per grid step, arithmetic intensity of the
schedule, MXU-tile alignment. L2 reporting inspects the lowered HLO for
each exported model: op counts, fusion opportunities left on the table,
and absence of retracing (one module per variant).

Run: `python -m compile.perf` (after `make artifacts`).
"""

from __future__ import annotations

import re
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from . import quantlib as ql
from .kernels import mpmatmul


def l1_report():
    print("== L1: Pallas mpmatmul structure ==")
    print(f"{'blocks (bm,bk,bn)':<22} {'fmt':<9} {'VMEM/step':>10} {'arith int.':>11} {'MXU tiles':>10}")
    for (bm, bk, bn) in [(128, 128, 128), (128, 256, 128), (256, 256, 256), (32, 32, 32)]:
        for fmt in ["fp4", "posit8", "posit16"]:
            vmem = mpmatmul.vmem_bytes(bm, bk, bn, fmt)
            # arithmetic intensity of one grid step: 2·bm·bk·bn FLOPs over
            # the HBM traffic of its tiles (f32 carrier)
            flops = 2 * bm * bk * bn
            hbm = (bm * bk + bk * bn + bm * bn) * 4
            mxu_ok = "8x128x128" if bm % 8 == 0 and bn % 128 == 0 and bk % 128 == 0 else "ragged"
            print(f"({bm:>3},{bk:>3},{bn:>3})          {fmt:<9} {vmem/1024:>8.0f}Ki {flops/hbm:>10.1f} {mxu_ok:>10}")
    print("\n  constraint: VMEM/step must stay well under ~16 MiB/core; the")
    print("  default (128,128,128) uses <1 MiB incl. posit16 tables, leaving")
    print("  room for double buffering. Tables are step-invariant (resident).")

    # interpret-mode wallclock, for completeness only
    a = jnp.asarray(np.random.default_rng(0).normal(0, 1, (256, 256)).astype(np.float32))
    for fmt in ["fp32", "posit8"]:
        f = jax.jit(lambda x, y, fmt=fmt: mpmatmul.mpmatmul(x, y, fmt))
        f(a, a).block_until_ready()
        t0 = time.time()
        for _ in range(3):
            f(a, a).block_until_ready()
        print(f"  (interpret wallclock, NOT a TPU proxy) 256³ {fmt}: {(time.time()-t0)/3*1e3:.1f} ms")


def l2_report():
    print("\n== L2: lowered HLO inspection ==")
    art = Path(__file__).resolve().parents[2] / "artifacts"
    if not art.exists():
        print("  (run `make artifacts` first)")
        return
    print(f"{'module':<28} {'KB':>7} {'insts':>6} {'dots':>5} {'searchsorted/while':>19} {'custom-calls':>13}")
    for p in sorted(art.glob("*.hlo.txt")):
        txt = p.read_text()
        insts = len(re.findall(r"^\s+\S+ = ", txt, re.M))
        dots = len(re.findall(r"= .*dot\(", txt))
        whiles = len(re.findall(r"= .*while\(", txt))
        cc = len(re.findall(r"custom-call", txt))
        print(f"{p.name:<28} {p.stat().st_size/1024:>7.0f} {insts:>6} {dots:>5} {whiles:>19} {cc:>13}")
    print("\n  checks: zero custom-calls (interpret-mode pallas lowers to pure")
    print("  HLO — runnable on the CPU PJRT client); one module per variant")
    print("  (no retracing); dot count == compute layers (no duplicated GEMMs).")


def l2_trace_stability():
    # the same jit retraces 0 extra times across calls with same shapes
    import jax
    from . import model as M
    p = M.gaze_params(jax.random.PRNGKey(0))
    traces = 0

    @jax.jit
    def f(x):
        nonlocal traces
        traces += 1
        return M.gaze_forward(p, x, ["posit8", "fp4", "posit16"])

    x = jnp.zeros((1, 16))
    for _ in range(5):
        f(x).block_until_ready()
    print(f"\n  retrace check: traced {traces} time(s) over 5 calls (must be 1)")
    assert traces == 1


if __name__ == "__main__":
    l1_report()
    l2_report()
    l2_trace_stability()
    # table-build cost (one-time per process)
    t0 = time.time()
    ql.tables.cache_clear()
    for fmt in ["fp4", "posit8", "posit16", "bf16"]:
        ql.tables(fmt)
    print(f"  quantlib table build (4 formats): {time.time()-t0:.2f}s one-time")
