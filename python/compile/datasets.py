"""Synthetic XR perception datasets (build-time, numpy).

Mirrors the *structure* of the paper's workloads on procedurally
generated data (substitution rule — see DESIGN.md):

* :func:`shapes10` — object classification: 16x16 grayscale images of 10
  procedural pattern classes (the EfficientNet/shapes stand-in).
* :func:`gaze` — eye-gaze extraction: 8 eye landmarks -> (yaw, pitch).
* :func:`kitti_like` — VIO: smooth 6-DoF trajectories with projected
  landmark feature frames + noisy IMU (the KITTI odometry stand-in; the
  Rust pipeline uses the same generator design in `vio::kitti`).
"""

from __future__ import annotations

import numpy as np


# --------------------------------------------------------------------------
# shapes-10 classification
# --------------------------------------------------------------------------

def _grid(size: int = 16):
    y, x = np.mgrid[0:size, 0:size].astype(np.float64)
    return x, y


def _shape_image(cls: int, rng: np.random.Generator, size: int = 16) -> np.ndarray:
    x, y = _grid(size)
    ph = rng.uniform(0, 2 * np.pi)
    cx, cy = rng.uniform(5, 11, size=2)
    f = rng.uniform(0.8, 1.3)
    if cls == 0:  # horizontal stripes
        img = np.sin(y * f + ph)
    elif cls == 1:  # vertical stripes
        img = np.sin(x * f + ph)
    elif cls == 2:  # diagonal stripes
        img = np.sin((x + y) * f * 0.8 + ph)
    elif cls == 3:  # checkerboard
        img = np.sin(x * f + ph) * np.sin(y * f + ph)
    elif cls == 4:  # filled disc
        r = np.hypot(x - cx, y - cy)
        img = (r < rng.uniform(3.5, 5.5)).astype(float)
    elif cls == 5:  # ring
        r = np.hypot(x - cx, y - cy)
        r0 = rng.uniform(4.0, 6.0)
        img = (np.abs(r - r0) < 1.2).astype(float)
    elif cls == 6:  # cross
        img = ((np.abs(x - cx) < 1.5) | (np.abs(y - cy) < 1.5)).astype(float)
    elif cls == 7:  # corner gradient
        img = (x / size) * (y / size)
        if rng.uniform() < 0.5:
            img = img[::-1]
        if rng.uniform() < 0.5:
            img = img[:, ::-1]
    elif cls == 8:  # sparse dots
        img = np.zeros((size, size))
        pts = rng.integers(0, size, size=(12, 2))
        img[pts[:, 0], pts[:, 1]] = 1.0
    else:  # 9: radial gradient
        r = np.hypot(x - cx, y - cy)
        img = 1.0 - r / r.max()
    img = img.astype(np.float64)
    img = (img - img.min()) / max(img.max() - img.min(), 1e-9)
    img *= rng.uniform(0.55, 1.0)          # contrast jitter
    img += rng.normal(0, 0.22, img.shape)  # sensor noise
    return img.astype(np.float32)


def shapes10(n: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """(images [n,1,16,16], labels [n]) balanced across 10 classes."""
    rng = np.random.default_rng(seed)
    labels = np.arange(n) % 10
    rng.shuffle(labels)
    imgs = np.stack([_shape_image(int(c), rng) for c in labels])
    return imgs[:, None, :, :].astype(np.float32), labels.astype(np.int32)


# --------------------------------------------------------------------------
# synthetic eye-gaze
# --------------------------------------------------------------------------

def gaze(n: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """(landmarks [n,16], gaze [n,2]).

    8 landmarks on an eye ellipse; the pupil (landmarks 6-7) displaces
    with gaze direction; lid openness couples to pitch. Targets in
    radians, |yaw| <= 0.6, |pitch| <= 0.4.
    """
    rng = np.random.default_rng(seed)
    yaw = rng.uniform(-0.6, 0.6, n)
    pitch = rng.uniform(-0.4, 0.4, n)
    feats = np.zeros((n, 16), dtype=np.float64)
    t = np.linspace(0, 2 * np.pi, 6, endpoint=False)
    for i in range(n):
        open_ = 0.5 + 0.3 * np.cos(pitch[i])
        ex = np.cos(t)
        ey = open_ * np.sin(t)
        px = 0.6 * np.sin(yaw[i])
        py = 0.5 * np.sin(pitch[i])
        pts = np.concatenate([np.stack([ex, ey], 1), [[px, py], [px, py * 0.8 + 0.05]]])
        pts += rng.normal(0, 0.015, pts.shape)
        feats[i] = pts.reshape(-1)
    targets = np.stack([yaw, pitch], 1)
    return feats.astype(np.float32), targets.astype(np.float32)


# --------------------------------------------------------------------------
# KITTI-like VIO sequences
# --------------------------------------------------------------------------

def kitti_like(frames: int, seed: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(images [frames,2,16,16], imu [frames,6], rel_pose [frames,6]).

    Same generator design as rust `vio::kitti`: landmark-cloud
    projection + vehicle dynamics + noisy IMU.
    """
    rng = np.random.default_rng(seed)
    cloud = np.stack([
        rng.uniform(-40, 40, 96),
        rng.uniform(-4, 8, 96),
        rng.uniform(-40, 40, 96),
    ], 1)
    pos = np.zeros(3)
    yaw = pitch = roll = 0.0
    v, yaw_rate = 0.8, 0.0
    prev = np.zeros((16, 16), dtype=np.float32)
    imgs, imus, poses = [], [], []
    for i in range(frames):
        if i % 40 == 0:
            yaw_rate = rng.uniform(-0.06, 0.06)
        v = np.clip(v + rng.normal(0, 0.016), 0.24, 1.44)
        dyaw = yaw_rate + rng.normal(0, 0.002)
        dpitch = -pitch * 0.2 + rng.normal(0, 0.004)
        droll = -roll * 0.2 + rng.normal(0, 0.003)
        dz, dx, dy = v, rng.normal(0, 0.01), rng.normal(0, 0.008)
        rel = np.array([dx, dy, dz, droll, dpitch, dyaw], dtype=np.float32)

        sy, cy = np.sin(yaw), np.cos(yaw)
        pos += [cy * dx + sy * dz, dy, -sy * dx + cy * dz]
        yaw += dyaw
        pitch += dpitch
        roll += droll

        # render feature frame
        img = np.zeros((16, 16), dtype=np.float32)
        d = cloud - pos
        bx = cy * d[:, 0] + sy * d[:, 2]
        bz = -sy * d[:, 0] + cy * d[:, 2]
        by = d[:, 1] - pitch * bz
        vis = (bz > 1.0) & (bz < 60.0)
        u = 8 + 8 * bx[vis] / bz[vis]
        w = 8 + 8 * by[vis] / bz[vis]
        inb = (u >= 0) & (u < 16) & (w >= 0) & (w < 16)
        inten = np.minimum(8.0 / bz[vis][inb], 1.0)
        np.add.at(img, (w[inb].astype(int), u[inb].astype(int)), inten)
        img = np.minimum(img, 1.0)

        imgs.append(np.stack([img, prev]))
        prev = img
        nstd = 0.02
        imus.append(rel + rng.normal(0, [nstd] * 3 + [nstd * 0.3] * 3).astype(np.float32))
        poses.append(rel)
    return (np.stack(imgs).astype(np.float32),
            np.stack(imus).astype(np.float32),
            np.stack(poses).astype(np.float32))
