"""XRT1 container round-trip + artifact presence checks."""

from pathlib import Path

import numpy as np
import pytest

from compile import xrt

ARTIFACTS = Path(__file__).resolve().parents[2] / "artifacts"


def test_roundtrip(tmp_path):
    t = {
        "a.w": np.arange(12, dtype=np.float32).reshape(3, 4),
        "b": np.array([1.5], dtype=np.float32),
        "deep": np.zeros((2, 3, 4, 5), dtype=np.float32),
    }
    p = tmp_path / "t.bin"
    xrt.save_tensors(p, t)
    back = xrt.load_tensors(p)
    assert set(back) == set(t)
    for k in t:
        np.testing.assert_array_equal(back[k], t[k])


def test_bad_magic(tmp_path):
    p = tmp_path / "bad.bin"
    p.write_bytes(b"NOPE\x00\x00\x00\x00")
    with pytest.raises(ValueError):
        xrt.load_tensors(p)


@pytest.mark.skipif(not (ARTIFACTS / "manifest.json").exists(),
                    reason="run `make artifacts` first")
def test_artifacts_complete():
    import json
    manifest = json.loads((ARTIFACTS / "manifest.json").read_text())
    for name in ["effnet_fp32.hlo.txt", "effnet_mxp.hlo.txt", "gaze_mxp_pallas.hlo.txt",
                 "ulvio_mxp.hlo.txt", "mpmatmul_posit8.hlo.txt"]:
        assert name in manifest["models"], name
    for name in ["weights_effnet.bin", "weights_ulvio.bin", "weights_gaze.bin"]:
        assert name in manifest["weights"]
    for name in ["eval_shapes.bin", "eval_gaze.bin", "eval_vio.bin"]:
        assert name in manifest["eval_sets"]


@pytest.mark.skipif(not (ARTIFACTS / "weights_effnet.bin").exists(),
                    reason="run `make artifacts` first")
def test_exported_weights_shape_contract():
    w = xrt.load_tensors(ARTIFACTS / "weights_effnet.bin")
    assert w["conv1.w"].shape == (3, 3, 1, 8)
    assert w["fc2.w"].shape == (64, 10)
    assert "conv1.g" in w  # gradients for the sensitivity planner
    assert w["act1.alpha"].shape == (1,)
