"""Model graph checks: shapes, quantization hooks, rust-layout parity,
and the Pallas-kerneled forward vs the jnp forward."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile import datasets


@pytest.fixture(scope="module")
def keys():
    return jax.random.split(jax.random.PRNGKey(0), 3)


def test_effnet_shapes(keys):
    p = M.effnet_params(keys[0])
    x = jnp.zeros((4, 1, 16, 16))
    out = M.effnet_forward(p, x)
    assert out.shape == (4, 10)
    # quantized path same shape
    out_q = M.effnet_forward(p, x, ["fp4"] * 5)
    assert out_q.shape == (4, 10)


def test_gaze_shapes(keys):
    p = M.gaze_params(keys[1])
    out = M.gaze_forward(p, jnp.zeros((7, 16)))
    assert out.shape == (7, 2)


def test_ulvio_shapes(keys):
    p = M.ulvio_params(keys[2])
    out = M.ulvio_forward(p, jnp.zeros((3, 2, 16, 16)), jnp.zeros((3, 6)))
    assert out.shape == (3, 6)


def test_param_layout_matches_rust_graph(keys):
    """Dims must agree with rust/src/models builders (HWIO conv, [in,out]
    fc) — the contract the XRT1 container relies on."""
    p = M.effnet_params(keys[0])
    assert p["conv1.w"].shape == (3, 3, 1, 8)
    assert p["conv2.w"].shape == (3, 3, 8, 16)
    assert p["conv3.w"].shape == (3, 3, 16, 32)
    assert p["fc1.w"].shape == (128, 64)
    assert p["fc2.w"].shape == (64, 10)
    u = M.ulvio_params(keys[2])
    assert u["fc1.w"].shape == (262, 64)  # 16*4*4 + 6 IMU


def test_quantization_changes_output(keys):
    p = M.effnet_params(keys[0])
    x = jnp.asarray(np.random.default_rng(1).normal(0, 1, (2, 1, 16, 16)).astype(np.float32))
    a = M.effnet_forward(p, x)
    b = M.effnet_forward(p, x, ["fp4"] * 5)
    assert not np.allclose(np.asarray(a), np.asarray(b))
    c = M.effnet_forward(p, x, ["posit16"] * 5)
    # 16-bit stays close to fp32
    np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=0.15)


def test_gaze_pallas_matches_jnp(keys):
    p = M.gaze_params(keys[1])
    x = jnp.asarray(np.random.default_rng(2).normal(0, 0.5, (5, 16)).astype(np.float32))
    fmts = ["posit8", "fp4", "posit16"]
    a = np.asarray(M.gaze_forward(p, x, fmts))
    b = np.asarray(M.gaze_forward_pallas(p, x, fmts))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_datasets_are_learnable_shapes():
    xs, ys = datasets.shapes10(200, seed=1)
    assert xs.shape == (200, 1, 16, 16)
    assert set(np.unique(ys)) == set(range(10))
    # images differ across classes
    m0 = xs[ys == 0].mean(axis=0)
    m1 = xs[ys == 1].mean(axis=0)
    assert np.abs(m0 - m1).mean() > 0.05


def test_gaze_dataset_correlates():
    x, y = datasets.gaze(500, seed=2)
    assert x.shape == (500, 16)
    # pupil x landmark (index 12) correlates with yaw
    c = np.corrcoef(x[:, 12], y[:, 0])[0, 1]
    assert c > 0.9, c


def test_kitti_like_structure():
    imgs, imus, poses = datasets.kitti_like(50, seed=3)
    assert imgs.shape == (50, 2, 16, 16)
    assert imus.shape == (50, 6)
    assert poses.shape == (50, 6)
    # previous-frame stacking
    np.testing.assert_array_equal(imgs[1, 1], imgs[0, 0])
    # IMU tracks forward motion
    assert np.abs(imus[:, 2] - poses[:, 2]).mean() < 0.1


def test_mlp_shapes_and_quant(keys):
    p = M.mlp_params(keys[0])
    x = jnp.zeros((3, 256))
    assert M.mlp_forward(p, x).shape == (3, 10)
    assert M.mlp_forward(p, x, ["fp4"] * 3).shape == (3, 10)
    assert p["fc1.w"].shape == (256, 128)  # rust models::mlp contract
