"""quantlib correctness: codec goldens pinned against the Rust test
suite, table/rounding semantics, STE gradients, PACT, entropy scheme,
sensitivity metric, planner."""

import math

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import quantlib as ql


# ---------------------------------------------------------------- codecs

# Golden vectors verified by rust/src/arith tests (posit.rs, fp.rs).
POSIT_GOLDENS = [
    # (bits, n, es, value)
    (0x40, 8, 0, 1.0),
    (0x20, 8, 0, 0.5),
    (0x60, 8, 0, 2.0),
    (0x01, 8, 0, 2.0**-6),
    (0x7F, 8, 0, 64.0),
    (0xC0, 8, 0, -1.0),
    (0x41, 8, 0, 1.03125),
    (0x4000, 16, 1, 1.0),
    (0x7FFF, 16, 1, 2.0**28),
    (0x0001, 16, 1, 2.0**-28),
    (0x5000, 16, 1, 2.0),
    (0x7, 4, 1, 16.0),
    (0x1, 4, 1, 0.0625),
]


@pytest.mark.parametrize("bits,n,es,value", POSIT_GOLDENS)
def test_posit_decode_goldens(bits, n, es, value):
    assert ql.posit_decode(bits, n, es) == value


def test_posit_nar_and_zero():
    assert ql.posit_decode(0, 16, 1) == 0.0
    assert math.isnan(ql.posit_decode(0x8000, 16, 1))
    assert ql.posit_encode(0.0, 16, 1) == 0
    assert ql.posit_encode(float("nan"), 16, 1) == 0x8000


@pytest.mark.parametrize("n,es", [(4, 1), (8, 0), (16, 1)])
def test_posit_roundtrip_exhaustive(n, es):
    for b in range(1 << n):
        v = ql.posit_decode(b, n, es)
        if math.isnan(v) or v == 0.0:
            continue
        assert ql.posit_encode(v, n, es) == b, f"bits {b:#x} value {v}"


def test_posit_bitstring_rounding_matches_rust():
    # rust arith::tables::tests::posit4_bitstring_rounding_threshold
    assert ql.quantize_np(np.array([9.0]), "posit4")[0] == 16.0
    assert ql.quantize_np(np.array([7.9]), "posit4")[0] == 4.0


def test_fp4_value_set_and_ties():
    vals = [ql.minifloat_decode(b, "fp4") for b in range(8)]
    assert vals == [0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0]
    # ties to even (rust fp.rs::fp4_encode_rounds_to_nearest_even)
    q = ql.quantize_np(np.array([0.25, 1.25, 1.75, 2.5, 5.0, 100.0]), "fp4")
    assert list(q) == [0.0, 1.0, 2.0, 2.0, 4.0, 6.0]


def test_e4m3_landmarks():
    assert ql.minifloat_decode(0x78, "e4m3") == 256.0
    assert math.isnan(ql.minifloat_decode(0x7F, "e4m3"))
    assert ql.minifloat_decode(0x01, "e4m3") == 2.0**-9
    q = ql.quantize_np(np.array([1e6]), "e4m3")
    assert q[0] == 448.0


@pytest.mark.parametrize("fmt", ["fp4", "posit4", "posit8", "posit16", "e4m3", "bf16"])
def test_quantize_idempotent(fmt):
    rng = np.random.default_rng(1)
    x = rng.normal(0, 4, 500)
    q1 = ql.quantize_np(x, fmt)
    q2 = ql.quantize_np(q1, fmt)
    np.testing.assert_array_equal(q1, q2)


@given(st.floats(min_value=-1e4, max_value=1e4, allow_nan=False))
@settings(max_examples=300, deadline=None)
def test_quantize_np_matches_scalar_codec_posit8(x):
    got = float(ql.quantize_np(np.array([x]), "posit8")[0])
    want = ql.posit_decode(ql.posit_encode(x, 8, 0), 8, 0)
    assert got == want


@given(st.floats(min_value=-1e6, max_value=1e6, allow_nan=False))
@settings(max_examples=300, deadline=None)
def test_quantize_np_matches_scalar_codec_posit16(x):
    got = float(ql.quantize_np(np.array([x]), "posit16")[0])
    want = ql.posit_decode(ql.posit_encode(x, 16, 1), 16, 1)
    assert got == want


def test_jnp_matches_np():
    rng = np.random.default_rng(2)
    x = rng.normal(0, 2, 400).astype(np.float32)
    for fmt in ["fp4", "posit8", "posit16"]:
        a = np.asarray(ql.quantize_jnp(jnp.asarray(x), fmt))
        b = ql.quantize_np(x.astype(np.float64), fmt).astype(np.float32)
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------- scaling

def test_scale_is_power_of_two():
    rng = np.random.default_rng(3)
    for fmt in ["fp4", "posit8", "e4m3"]:
        s = ql.scale_for(rng.normal(0, 0.05, 256), fmt)
        assert s > 0
        assert math.log2(s) == round(math.log2(s))


def test_scaled_quant_preserves_small_weights():
    rng = np.random.default_rng(4)
    w = rng.normal(0, 0.05, 1024)
    s = ql.scale_for(w, "fp4")
    q = s * ql.quantize_np(w / s, "fp4")
    # without scaling everything dies to 0; with scaling most survives
    assert np.mean(q != 0) > 0.5
    assert np.corrcoef(w, q)[0, 1] > 0.95


def test_dyn_scale_matches_host_scale():
    rng = np.random.default_rng(5)
    x = rng.normal(0, 0.3, 512).astype(np.float32)
    for fmt in ["fp4", "posit8", "posit16"]:
        a = float(ql.dyn_scale(jnp.asarray(x), fmt))
        b = ql.scale_for(x, fmt)
        assert a == pytest.approx(b, rel=1e-6), fmt


# ---------------------------------------------------------------- STE/PACT

def test_fake_quant_ste_gradient_is_identity():
    def f(x):
        return jnp.sum(ql.fake_quant(x, "fp4") ** 2)

    x = jnp.asarray(np.random.default_rng(6).normal(0, 0.2, 64).astype(np.float32))
    g = jax.grad(f)(x)
    q = ql.fake_quant(x, "fp4")
    np.testing.assert_allclose(np.asarray(g), np.asarray(2 * q), rtol=1e-5)


def test_pact_equals_clipped_relu():
    x = jnp.linspace(-3, 8, 101)
    y = ql.pact(x, jnp.float32(4.0))
    np.testing.assert_allclose(np.asarray(y), np.clip(np.asarray(x), 0, 4), atol=1e-6)


def test_pact_quantize_grid():
    x = jnp.linspace(-1, 6, 57)
    q = np.asarray(ql.pact_quantize(x, jnp.float32(4.0), 4))
    step = 4.0 / 15
    np.testing.assert_allclose(q / step, np.round(q / step), atol=1e-5)
    assert q.min() >= 0 and q.max() <= 4.0


def test_pact_alpha_gradient_flows():
    def f(alpha, x):
        return jnp.sum(ql.pact_quantize(x, alpha, 4))

    g = jax.grad(f)(jnp.float32(2.0), jnp.asarray([1.0, 3.0, 5.0]))
    # x >= α contributes dα = 1 (two elements)
    assert float(g) == pytest.approx(2.0, abs=0.3)


# ---------------------------------------------------------------- entropy / sensitivity / planner

def test_entropy_quantize_reduces_outlier_damage():
    rng = np.random.default_rng(7)
    w = rng.normal(0, 0.2, 4096)
    w[0], w[1] = 50.0, -50.0
    q = ql.entropy_quantize(w, 4)
    bulk_err = np.sqrt(np.mean((q[2:] - w[2:]) ** 2))
    assert bulk_err < 0.1


def test_scale_k_eq3():
    w = np.array([1.0, -1.0, 1.0, -1.0])
    assert ql.scale_k(w, 4) == pytest.approx(15 / 8)


def test_sensitivity_sign():
    rng = np.random.default_rng(8)
    w = rng.normal(0, 0.5, 256)
    g = rng.normal(0, 0.1, 256)
    assert ql.sensitivity(w, g, "fp4", "posit16") > 0
    assert ql.sensitivity(w, g, "posit16", "fp4") < 0


def test_planner_budget_and_pins():
    rng = np.random.default_rng(9)
    ws = [rng.normal(0, 2.0, 512), rng.normal(0, 0.1, 4096), rng.normal(0, 0.1, 64)]
    gs = [np.ones(512), 0.01 * np.ones(4096), np.ones(64)]
    fmts = ql.plan_formats(ws, gs, avg_bits_budget=6.0, pin_high=(2,))
    assert fmts[2] == "posit16"
    bits = {"fp4": 4, "posit4": 4, "posit8": 8, "posit16": 16}
    avg = sum(bits[f] * w.size for f, w in zip(fmts, ws)) / sum(w.size for w in ws)
    assert avg <= 6.0 + 1e-9
    # the fragile wide layer promoted before the robust big one
    assert bits[fmts[0]] >= bits[fmts[1]]
