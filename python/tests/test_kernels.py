"""Pallas kernels vs pure-jnp oracles — the CORE L1 correctness signal.

Hypothesis sweeps shapes and formats; assert_allclose against ref.py.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import mpmatmul, quantize, ref

FMTS = ["fp32", "fp4", "posit4", "posit8", "posit16", "e4m3"]


def rand(shape, seed, scale=1.0):
    return (np.random.default_rng(seed).normal(0, scale, shape)).astype(np.float32)


@pytest.mark.parametrize("fmt", FMTS)
def test_mpmatmul_matches_ref_square(fmt):
    a = rand((32, 32), 1)
    b = rand((32, 32), 2)
    got = np.asarray(mpmatmul.mpmatmul(jnp.asarray(a), jnp.asarray(b), fmt))
    want = np.asarray(ref.mpmatmul_ref(jnp.asarray(a), jnp.asarray(b), fmt))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@given(
    m=st.integers(1, 40),
    k=st.integers(1, 48),
    n=st.integers(1, 40),
    fmt=st.sampled_from(FMTS),
    seed=st.integers(0, 2**16),
)
@settings(max_examples=40, deadline=None)
def test_mpmatmul_matches_ref_hypothesis(m, k, n, fmt, seed):
    a = rand((m, k), seed, scale=0.7)
    b = rand((k, n), seed + 1, scale=0.7)
    got = np.asarray(mpmatmul.mpmatmul(jnp.asarray(a), jnp.asarray(b), fmt))
    want = np.asarray(ref.mpmatmul_ref(jnp.asarray(a), jnp.asarray(b), fmt))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_mpmatmul_blocking_invariance():
    # different block sizes must give identical results (bit-exact
    # accumulation order within f32 tolerance of the k-loop order change)
    a = rand((48, 64), 3)
    b = rand((64, 40), 4)
    full = np.asarray(mpmatmul.mpmatmul(jnp.asarray(a), jnp.asarray(b), "posit8"))
    tiled = np.asarray(
        mpmatmul.mpmatmul(jnp.asarray(a), jnp.asarray(b), "posit8", bm=16, bk=16, bn=16)
    )
    np.testing.assert_allclose(full, tiled, rtol=1e-5, atol=1e-6)


def test_mpmatmul_fp32_is_plain_matmul():
    a = rand((20, 30), 5)
    b = rand((30, 10), 6)
    got = np.asarray(mpmatmul.mpmatmul(jnp.asarray(a), jnp.asarray(b), "fp32"))
    np.testing.assert_allclose(got, a @ b, rtol=1e-5, atol=1e-5)


def test_mpmatmul_quantizes_coarsely_at_fp4():
    a = rand((16, 16), 7)
    b = rand((16, 16), 8)
    q4 = np.asarray(mpmatmul.mpmatmul(jnp.asarray(a), jnp.asarray(b), "fp4"))
    f32 = a @ b
    # correlated but not equal
    assert not np.allclose(q4, f32, atol=1e-4)
    c = np.corrcoef(q4.ravel(), f32.ravel())[0, 1]
    assert c > 0.85, c


@given(
    m=st.integers(1, 64),
    n=st.integers(1, 33),
    fmt=st.sampled_from(["fp4", "posit8", "posit16"]),
    seed=st.integers(0, 2**16),
)
@settings(max_examples=30, deadline=None)
def test_quantize_kernel_matches_ref(m, n, fmt, seed):
    x = rand((m, n), seed, scale=2.0)
    got = np.asarray(quantize.quantize(jnp.asarray(x), fmt))
    want = np.asarray(ref.quantize_ref(jnp.asarray(x), fmt))
    np.testing.assert_array_equal(got, want)


def test_vmem_budget_documented_blocks():
    # default 128-blocks stay far under a 16 MiB VMEM budget
    assert mpmatmul.vmem_bytes(128, 128, 128, "posit16") < 16 * 2**20
    assert mpmatmul.vmem_bytes(128, 128, 128, "fp4") < 1 * 2**20
