//! Quickstart: the whole stack in one page.
//!
//! 1. bit-accurate mixed-precision MACs on one XR-NPE engine,
//! 2. a GEMM through the morphable 8×8 co-processor (cycles + energy),
//! 3. an AOT-compiled JAX model served through the PJRT runtime
//!    (requires `make artifacts`; step 3 is skipped gracefully if the
//!    artifacts are missing).
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use xr_npe::arith::Precision;
use xr_npe::energy::AsicModel;
use xr_npe::npe::{Engine, PrecSel};
use xr_npe::soc::{Soc, SocConfig};
use xr_npe::util::{Matrix, Rng};

fn main() -> anyhow::Result<()> {
    // ---- 1. one engine, three precisions ----------------------------
    println!("== XR-NPE engine: fused dot product, per `prec_sel` ==");
    for sel in [PrecSel::Fp4x4, PrecSel::Posit8x2, PrecSel::Posit16x1] {
        let p = sel.precision();
        let mut eng = Engine::new(sel);
        // dot([0.5, 1.5, -2], [2, 1, 0.25]) = 1 + 1.5 - 0.5 = 2.0
        let xs = [0.5, 1.5, -2.0];
        let ys = [2.0, 1.0, 0.25];
        for (&x, &y) in xs.iter().zip(&ys) {
            let mut lanes_a = vec![0u32; sel.lanes()];
            let mut lanes_b = vec![0u32; sel.lanes()];
            lanes_a[0] = p.encode(x);
            lanes_b[0] = p.encode(y);
            eng.mac_word_fused(sel.pack(&lanes_a), sel.pack(&lanes_b));
        }
        println!(
            "  {:<11} dot = {:<8} ({} lanes/word, {} RMMEC blocks/lane, {} gated MACs)",
            p.name(),
            eng.read_lane_f64(0),
            sel.lanes(),
            xr_npe::npe::rmmec::blocks_for_width(p.mant_mult_bits()),
            eng.stats.gated_macs,
        );
    }

    // ---- 2. a GEMM on the co-processor -------------------------------
    println!("\n== 64x128x64 GEMM on the 8x8 morphable array ==");
    let mut rng = Rng::new(7);
    let a = Matrix::random(64, 128, 0.5, &mut rng);
    let b = Matrix::random(128, 64, 0.5, &mut rng);
    let asic = AsicModel::xr_npe();
    for sel in PrecSel::ALL {
        let mut soc = Soc::new(SocConfig::default());
        let (_, rep) = soc.gemm(&a, &b, sel, Precision::Fp32)?;
        let e_pj = asic.energy_from_stats_pj(sel, &rep.array.stats);
        println!(
            "  {:<10} {:>7} cycles  {:>5.1} MACs/cyc  {:>6} B moved  {:>7.1} nJ compute",
            format!("{:?}", sel),
            rep.total_cycles,
            rep.array.macs_per_cycle,
            rep.bytes_in + rep.bytes_out,
            e_pj / 1e3,
        );
    }

    // ---- 3. serve an AOT-compiled JAX model --------------------------
    println!("\n== PJRT: serving the AOT-compiled GazeNet (Pallas-kerneled MxP) ==");
    match xr_npe::runtime::Registry::open("artifacts") {
        Ok(mut reg) => {
            let landmarks = vec![0.1f32; 16];
            let out = reg.get("gaze_mxp_pallas")?.run_f32(&[(&landmarks, &[1, 16])])?;
            println!("  gaze(yaw, pitch) = {:?} rad", out[0]);
            let out32 = reg.get("gaze_fp32")?.run_f32(&[(&landmarks, &[1, 16])])?;
            println!("  fp32 reference   = {:?} rad", out32[0]);
        }
        Err(e) => println!("  (skipped: {e})"),
    }
    Ok(())
}
