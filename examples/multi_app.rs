//! Multi-app serving: several concurrent XR applications (each with its
//! own VIO/gaze/classification request streams) share co-processor
//! replicas through the coordinator's batcher + router — the serving-
//! layer scenario of the vLLM-style router architecture, specialized to
//! XR's latency regime.
//!
//! Shows: bounded batching (deadline flush), round-robin replica load
//! balance, per-app latency isolation, and replica-scaling throughput.
//!
//! ```bash
//! cargo run --release --example multi_app
//! ```

use anyhow::Result;
use xr_npe::artifacts;
use xr_npe::coordinator::batcher::{Batch, Request};
use xr_npe::coordinator::scheduler::ModelInstance;
use xr_npe::coordinator::{FrameBatcher, LatencyStats, Router, WorkloadKind};
use xr_npe::npe::PrecSel;
use xr_npe::quant::PlanBudget;
use xr_npe::soc::SocConfig;
use xr_npe::util::Rng;

const APPS: usize = 3;
const FRAMES_PER_APP: usize = 40;
const CLOCK: f64 = 250e6;

fn build_router(replicas: usize) -> Result<Router> {
    let mut router = Router::new(replicas, SocConfig::default());
    let budget = PlanBudget { avg_bits: 6.0 };
    router.register(
        WorkloadKind::Vio,
        ModelInstance::planned(
            xr_npe::models::ulvio::build(),
            artifacts::weights("ulvio")?,
            budget,
            PrecSel::Fp4x4,
            true,
        )?,
    )?;
    router.register(
        WorkloadKind::Gaze,
        ModelInstance::planned(
            xr_npe::models::gaze::build(),
            artifacts::weights("gaze")?,
            budget,
            PrecSel::Fp4x4,
            false,
        )?,
    )?;
    Ok(router)
}

fn main() -> Result<()> {
    let eval = artifacts::eval_vio()?;
    let gaze_eval = artifacts::eval_gaze()?;

    println!("== multi-app XR serving ({APPS} apps x {FRAMES_PER_APP} frames each) ==\n");
    for replicas in [1usize, 2, 4] {
        let mut router = build_router(replicas)?;
        // one batcher per workload kind: max 4, deadline = half a frame
        // period at 90 Hz (XR display class)
        let deadline = (CLOCK / 90.0 / 2.0) as u64;
        let mut vio_batcher = FrameBatcher::new(4, deadline);
        let mut gaze_batcher = FrameBatcher::new(4, deadline);
        let mut per_app: Vec<LatencyStats> = (0..APPS).map(|_| LatencyStats::new()).collect();
        let mut rng = Rng::new(99);
        let mut now = 0u64;
        let mut served = 0u64;
        let mut replica_hits = vec![0u64; replicas];

        // interleaved arrival pattern: apps are phase-shifted
        for f in 0..FRAMES_PER_APP {
            for app in 0..APPS {
                let i = (f * APPS + app) % eval.images.len();
                now += (CLOCK / 90.0 / APPS as f64) as u64 + rng.below(500);
                vio_batcher.push(eval.images[i].clone(), eval.imu[i].clone(), now);
                gaze_batcher.push(gaze_eval.landmarks[i % gaze_eval.landmarks.len()].clone(), vec![], now);

                for (kind, batcher) in [
                    (WorkloadKind::Vio, &mut vio_batcher),
                    (WorkloadKind::Gaze, &mut gaze_batcher),
                ] {
                    while let Some(batch) = batcher.poll(now) {
                        for req in batch.requests {
                            let res = router.route(kind, &req.input, &req.aux)?;
                            let cyc = res.report.total_cycles();
                            now += cyc / replicas as u64; // replicas work in parallel
                            replica_hits[res.replica] += 1;
                            served += 1;
                            if kind == WorkloadKind::Vio {
                                per_app[(req.id as usize) % APPS]
                                    .record(now.saturating_sub(req.arrived));
                            }
                        }
                    }
                }
            }
        }
        // drain
        for (kind, batcher) in [
            (WorkloadKind::Vio, &mut vio_batcher),
            (WorkloadKind::Gaze, &mut gaze_batcher),
        ] {
            if let Some(batch) = batcher.flush(now) {
                for req in batch.requests {
                    let _ = router.route(kind, &req.input, &req.aux)?;
                    served += 1;
                }
            }
        }

        let sim_secs = now as f64 / CLOCK;
        println!("-- {replicas} replica(s) --");
        println!("  served {served} requests in {:.1} sim-ms  ({:.0} req/s)", sim_secs * 1e3,
            served as f64 / sim_secs);
        print!("  replica load:");
        for (i, h) in replica_hits.iter().enumerate() {
            print!("  r{i}={h}");
        }
        println!();
        for (app, stats) in per_app.iter().enumerate() {
            println!("  app{app} VIO latency: mean {:.2} ms  p99 {:.2} ms",
                stats.mean() / CLOCK * 1e3, stats.p99() as f64 / CLOCK * 1e3);
        }
        println!();
    }
    println!("(bounded batching keeps p99 within the 90 Hz frame budget; replicas");
    println!(" scale throughput near-linearly with balanced load.)");

    // ---- async serving runtime: submission returns completion handles
    // (the batcher keeps admitting while replicas drain) and the
    // autoscaler unparks replicas from queue-latency pressure ----
    println!("\n== async serving runtime (4 replicas, warm floor 1, autoscaled) ==\n");
    let mut router = build_router(4)?;
    router.set_active(1); // start parked at the floor; pressure unparks
    let mut handles = Vec::new();
    let mut active_track = Vec::new();
    let n_batches = 8usize;
    for b in 0..n_batches {
        let requests: Vec<Request> = (0..8)
            .map(|i| {
                let idx = (b * 8 + i) % eval.images.len();
                Request {
                    id: (b * 8 + i) as u64,
                    input: eval.images[idx].clone(),
                    aux: eval.imu[idx].clone(),
                    arrived: b as u64,
                }
            })
            .collect();
        let batch = Batch { requests, released: b as u64 };
        // submit without waiting — consecutive batches pipeline on the
        // per-replica work queues
        handles.push(router.submit_batch(WorkloadKind::Vio, &batch)?);
        active_track.push(router.autoscale_tick());
    }
    let mut served = 0u64;
    for comps in handles {
        for c in comps {
            Router::resolve(c)?;
            served += 1;
        }
    }
    let m = router.runtime_metrics();
    println!("  served {served} async VIO requests ({n_batches} pipelined batches)");
    println!("  active replicas per autoscale tick: {active_track:?}");
    println!(
        "  host-side queue p95 {:.1} µs | service p95 {:.1} µs | completed {}",
        m.queue.p95() as f64 / 1e3,
        m.service.p95() as f64 / 1e3,
        m.completed
    );
    println!("(submission returns completion handles; the autoscaler grows the active");
    println!(" set from queue-latency p95 and parks back to the floor when idle.)");

    // ---- sharded serving: a model larger than one replica's resident
    // DRAM budget splits its per-layer GEMMs across the fleet; partial
    // quires reduce exactly at the coordinator, so outputs stay
    // bit-identical to whole-model serving ----
    println!("\n== sharded serving (mlp_xr split across 2 small replicas) ==\n");
    let g = xr_npe::models::mlp::build();
    let w = xr_npe::models::random_weights(&g, 7);
    // 128 KiB of DRAM per replica: the whole compiled model does not fit
    let small = SocConfig { dram_bytes: 1 << 17, ..SocConfig::default() };
    let mut sharded = Router::new(2, small);
    let whole_attempt = sharded.register(
        WorkloadKind::Classify,
        ModelInstance::uniform(g.clone(), w.clone(), PrecSel::Posit8x2)?,
    );
    println!("  whole-model registration on a small replica: {}",
        whole_attempt.err().map(|e| e.to_string()).unwrap_or_else(|| "fit".into()));
    sharded.register_auto(
        WorkloadKind::Classify,
        ModelInstance::uniform(g.clone(), w.clone(), PrecSel::Posit8x2)?,
    )?;
    let placement = sharded.shard_placement(WorkloadKind::Classify).unwrap().to_vec();
    println!("  register_auto placed {} shards on replicas {placement:?}", placement.len());
    let mut reference = Router::new(1, SocConfig::default());
    reference.register(WorkloadKind::Classify, ModelInstance::uniform(g, w, PrecSel::Posit8x2)?)?;
    let mut identical = true;
    let mut reduce_cycles = 0u64;
    for i in 0..8 {
        let input: Vec<f32> = (0..256).map(|j| ((i * 256 + j) as f32 * 0.013).sin() * 0.4).collect();
        let got = sharded.route(WorkloadKind::Classify, &input, &[])?;
        let want = reference.route(WorkloadKind::Classify, &input, &[])?;
        identical &= got.output == want.output;
        reduce_cycles = got.report.reduce_cycles;
    }
    println!("  8 requests served from shards: bit-identical to whole-model = {identical}");
    println!("  per-request reduction term: {reduce_cycles} cycles (exact quire merge)");
    println!("(the fleet serves a model none of its replicas could host alone.)");

    // ---- model catalog under a DRAM budget: three workloads whose
    // combined warm footprint exceeds the replica's resident budget
    // rotate through it — dispatch to a cold model LRU-evicts and
    // re-warms, with live compaction when the free list fragments ----
    println!("\n== model catalog & residency budget (3 models, 96 KiB budget, 1 replica) ==\n");
    use xr_npe::coordinator::RuntimeConfig;
    let rt = RuntimeConfig { resident_budget: Some(96 * 1024), ..Default::default() };
    let mut catalog = Router::with_runtime(1, SocConfig::default(), rt);
    let kinds = [WorkloadKind::Classify, WorkloadKind::Vio, WorkloadKind::Gaze];
    let graphs = [
        xr_npe::models::effnet::build(),
        xr_npe::models::ulvio::build(),
        xr_npe::models::gaze::build(),
    ];
    for (kind, g) in kinds.iter().zip(&graphs) {
        let w = xr_npe::models::random_weights(g, 11);
        catalog.register(*kind, ModelInstance::uniform(g.clone(), w, PrecSel::Posit8x2)?)?;
    }
    for round in 0..4 {
        for (kind, g) in kinds.iter().zip(&graphs) {
            let input: Vec<f32> = (0..g.input.numel())
                .map(|j| ((round * 61 + j) as f32 * 0.017).sin() * 0.4)
                .collect();
            let aux: Vec<f32> = if *kind == WorkloadKind::Vio { vec![0.05; 6] } else { vec![] };
            catalog.route(*kind, &input, &aux)?;
        }
    }
    let m = catalog.runtime_metrics();
    println!("  served {} rotating requests from one replica", catalog.total_served());
    println!(
        "  evictions {} | cold warms {} | compactions {} | resident high water {} B (budget {} B)",
        m.evictions,
        m.cold_warms,
        m.compactions,
        m.resident_high_water,
        96 * 1024
    );
    println!("(the catalog exceeds the replica's DRAM budget; the LRU policy rotates");
    println!(" models through it and in-flight/sharded models are never evicted.)");
    Ok(())
}
