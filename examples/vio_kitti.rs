//! VIO deep-dive: drive UL-VIO-lite over a long synthetic-KITTI
//! trajectory at every precision configuration, integrate the predicted
//! poses, and compare trajectories + Fig. 6-style RMSE + model sizes.
//!
//! Uses the *Rust* trajectory generator for the stream (streaming
//! workload) and the trained weights from `make artifacts` — QAT
//! variants where they exist.
//!
//! ```bash
//! cargo run --release --example vio_kitti
//! ```

use anyhow::Result;
use xr_npe::artifacts;
use xr_npe::coordinator::scheduler::ModelInstance;
use xr_npe::models::ulvio;
use xr_npe::npe::PrecSel;
use xr_npe::quant::PlanBudget;
use xr_npe::soc::{Soc, SocConfig};
use xr_npe::vio::kitti::{SequenceConfig, TrajectoryGenerator};
use xr_npe::vio::odometry::{self, RelPose};

fn main() -> Result<()> {
    let frames = 300usize;
    println!("UL-VIO-lite on a synthetic KITTI sequence ({frames} frames)\n");
    let seq = TrajectoryGenerator::new(SequenceConfig { frames, seed: 77, ..Default::default() })
        .sequence();
    let gt: Vec<RelPose> = seq.iter().map(|f| f.rel_pose).collect();

    // configurations: uniform per-mode (QAT weights where available) +
    // the layer-adaptive MxP plan on FP32 weights
    let configs: Vec<(String, ModelInstance)> = {
        let mut v = Vec::new();
        let w32 = artifacts::weights("ulvio")?;
        v.push((
            "Posit(16,1)".into(),
            ModelInstance::uniform(ulvio::build(), artifacts::weights_qat("ulvio", "posit16").unwrap_or_else(|_| w32.clone()), PrecSel::Posit16x1)?,
        ));
        v.push((
            "Posit(8,0)".into(),
            ModelInstance::uniform(ulvio::build(), artifacts::weights_qat("ulvio", "posit8").unwrap_or_else(|_| w32.clone()), PrecSel::Posit8x2)?,
        ));
        v.push((
            "FP4 (QAT)".into(),
            ModelInstance::uniform(ulvio::build(), artifacts::weights_qat("ulvio", "fp4").unwrap_or_else(|_| w32.clone()), PrecSel::Fp4x4)?,
        ));
        v.push((
            "Posit(4,1) (QAT)".into(),
            ModelInstance::uniform(ulvio::build(), artifacts::weights_qat("ulvio", "posit4").unwrap_or_else(|_| w32.clone()), PrecSel::Posit4x4)?,
        ));
        v.push((
            "MxP plan".into(),
            ModelInstance::planned(ulvio::build(), w32, PlanBudget { avg_bits: 6.0 }, PrecSel::Fp4x4, true)?,
        ));
        v
    };

    // FP32 reference trajectory
    let ref_inst = ModelInstance::uniform(ulvio::build(), artifacts::weights("ulvio")?, PrecSel::Posit16x1)?;
    let mut fp32_pred = Vec::with_capacity(frames);
    for f in &seq {
        let out = ref_inst.infer_ref(&f.image, &f.imu)?;
        let mut p = [0f32; 6];
        p.copy_from_slice(&out[..6]);
        fp32_pred.push(p);
    }
    let t32 = odometry::rmse_translation(&fp32_pred, &gt);
    let r32 = odometry::rmse_rotation_deg(&fp32_pred, &gt);
    println!("{:<18} {:>9} {:>12} {:>10} {:>10} {:>10}",
        "config", "t_rmse%", "r_rmse deg", "Δt pp", "ATE m", "size KB");
    println!("{:<18} {:>9.2} {:>12.4} {:>10} {:>10.2} {:>10.1}",
        "FP32 (ref)", t32, r32, "-", odometry::ate(&fp32_pred, &gt),
        ref_inst.graph.total_params() as f64 * 4.0 / 1e3);

    for (name, inst) in &configs {
        let mut soc = Soc::new(SocConfig::default());
        let mut pred = Vec::with_capacity(frames);
        for f in &seq {
            let (out, _) = inst.infer(&mut soc, &f.image, &f.imu)?;
            let mut p = [0f32; 6];
            p.copy_from_slice(&out[..6]);
            pred.push(p);
        }
        let t = odometry::rmse_translation(&pred, &gt);
        let r = odometry::rmse_rotation_deg(&pred, &gt);
        println!("{:<18} {:>9.2} {:>12.4} {:>+10.2} {:>10.2} {:>10.1}",
            name, t, r, t - t32, odometry::ate(&pred, &gt), inst.model_bytes() / 1e3);
    }

    // model-size report (paper §I: 13.5 MB FP32 → 2.42 MB MxP at UL-VIO scale)
    println!("\n-- model size scaling (paper's UL-VIO parameter count) --");
    for (scheme, mb) in xr_npe::quant::policy::size_report(&[13_500_000 / 4]) {
        println!("  {scheme:<28} {mb:>6.2} MB");
    }

    // trajectory endpoints (drift visual)
    let tr_gt = odometry::integrate_poses(&gt);
    let tr32 = odometry::integrate_poses(&fp32_pred);
    println!("\n-- integrated trajectory endpoints --");
    println!("  ground truth: ({:7.1}, {:7.1}, {:7.1}) m", tr_gt.last().unwrap()[0], tr_gt.last().unwrap()[1], tr_gt.last().unwrap()[2]);
    println!("  FP32        : ({:7.1}, {:7.1}, {:7.1}) m", tr32.last().unwrap()[0], tr32.last().unwrap()[1], tr32.last().unwrap()[2]);
    Ok(())
}
