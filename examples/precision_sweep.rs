//! Precision explorer: sweep every supported format over the three
//! workloads on the bit-accurate NPE, printing the accuracy/error vs
//! bits frontier (the data behind Figs. 5–8) together with the
//! per-format hardware cost from the calibrated models.
//!
//! ```bash
//! cargo run --release --example precision_sweep
//! ```

use anyhow::Result;
use xr_npe::artifacts;
use xr_npe::coordinator::scheduler::ModelInstance;
use xr_npe::energy::AsicModel;
use xr_npe::models::{effnet, gaze};
use xr_npe::npe::PrecSel;
use xr_npe::soc::{Soc, SocConfig};
use xr_npe::util::argmax;

fn main() -> Result<()> {
    let shapes = artifacts::eval_shapes()?;
    let gaze_set = artifacts::eval_gaze()?;
    let asic = AsicModel::xr_npe();
    let n_cls = 120.min(shapes.images.len());
    let n_gz = 200.min(gaze_set.landmarks.len());

    println!("{:<13} {:>6} {:>10} {:>12} {:>12} {:>12}",
        "mode", "bits", "cls acc%", "gaze MSE", "pJ/MAC", "MACs/cyc/PE");
    // FP32 reference row
    {
        let cls = ModelInstance::uniform(effnet::build(), artifacts::weights("effnet")?, PrecSel::Posit16x1)?;
        let gz = ModelInstance::uniform(gaze::build(), artifacts::weights("gaze")?, PrecSel::Posit16x1)?;
        let mut ok = 0;
        for i in 0..n_cls {
            ok += (argmax(&cls.infer_ref(&shapes.images[i], &[])?) == shapes.labels[i]) as usize;
        }
        let mut mse = 0f64;
        for i in 0..n_gz {
            let out = gz.infer_ref(&gaze_set.landmarks[i], &[])?;
            let t = gaze_set.gaze[i];
            mse += ((out[0] - t[0]).powi(2) + (out[1] - t[1]).powi(2)) as f64 / 2.0;
        }
        println!("{:<13} {:>6} {:>10.1} {:>12.6} {:>12} {:>12}",
            "FP32 (ref)", 32, 100.0 * ok as f64 / n_cls as f64, mse / n_gz as f64, "-", "-");
    }

    for sel in [PrecSel::Posit16x1, PrecSel::Posit8x2, PrecSel::Fp4x4, PrecSel::Posit4x4] {
        let prec = sel.precision();
        let fmt = match sel {
            PrecSel::Fp4x4 => "fp4",
            PrecSel::Posit4x4 => "posit4",
            PrecSel::Posit8x2 => "posit8",
            PrecSel::Posit16x1 => "posit16",
        };
        // QAT weights when available (the paper's protocol)
        let w_cls = artifacts::weights_qat("effnet", fmt)
            .unwrap_or(artifacts::weights("effnet")?);
        let w_gz = artifacts::weights_qat("gaze", fmt).unwrap_or(artifacts::weights("gaze")?);
        let cls = ModelInstance::uniform(effnet::build(), w_cls, sel)?;
        let gz = ModelInstance::uniform(gaze::build(), w_gz, sel)?;

        let mut soc = Soc::new(SocConfig::default());
        let mut ok = 0;
        for i in 0..n_cls {
            let (out, _) = cls.infer(&mut soc, &shapes.images[i], &[])?;
            ok += (argmax(&out) == shapes.labels[i]) as usize;
        }
        let mut mse = 0f64;
        for i in 0..n_gz {
            let (out, _) = gz.infer(&mut soc, &gaze_set.landmarks[i], &[])?;
            let t = gaze_set.gaze[i];
            mse += ((out[0] - t[0]).powi(2) + (out[1] - t[1]).powi(2)) as f64 / 2.0;
        }
        println!("{:<13} {:>6} {:>10.1} {:>12.6} {:>12.2} {:>12}",
            prec.name(),
            prec.bits(),
            100.0 * ok as f64 / n_cls as f64,
            mse / n_gz as f64,
            asic.energy_per_mac_pj(sel, 0.72, 0.15),
            sel.lanes());
    }

    println!("\n(QAT weights are used per mode where exported; the paper's claim is the");
    println!(" *shape*: 4-bit modes trade a small accuracy delta for 4x throughput and");
    println!(" ~4x lower pJ/MAC + bandwidth. Full series: cargo bench fig5/fig7/fig8.)");
    Ok(())
}
