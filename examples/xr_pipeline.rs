//! **End-to-end driver** (DESIGN.md §5): the full XR perception system on
//! a real (synthetic-KITTI) workload, proving all three layers compose.
//!
//! * L2/L1 artifacts: QAT-trained models, lowered by JAX (+ the Pallas
//!   kernel variant) to HLO text — loaded and *served from Rust* through
//!   PJRT.
//! * L3: the coordinator routes every frame's VIO/gaze/classification
//!   to the bit-accurate co-processor simulator under the layer-adaptive
//!   MxP plan computed from the exported sensitivities.
//!
//! Reports (recorded in EXPERIMENTS.md):
//! * Fig. 1 — application-runtime breakdown (perception ≈ 60 %),
//! * Fig. 6 — VIO translation/rotation RMSE, MxP vs FP32,
//! * Fig. 5 — classification accuracy on the NPE vs the FP32 reference,
//! * Table IV — measured energy efficiency (TOPS/W) of the co-processor,
//! * PJRT-vs-NPE cross-check: the same MxP GazeNet through both paths.
//!
//! ```bash
//! make artifacts && cargo run --release --example xr_pipeline
//! ```

use anyhow::Result;
use xr_npe::artifacts;
use xr_npe::coordinator::scheduler::ModelInstance;
use xr_npe::coordinator::{PerceptionPipeline, PipelineConfig, Router, WorkloadKind};
use xr_npe::energy::SystemModel;
use xr_npe::models::{effnet, gaze, ulvio};
use xr_npe::npe::PrecSel;
use xr_npe::quant::PlanBudget;
use xr_npe::soc::SocConfig;
use xr_npe::util::argmax;
use xr_npe::vio::odometry;

const FRAMES: usize = 200;

fn build_router() -> Result<Router> {
    let mut router = Router::new(1, SocConfig::default());
    let budget = PlanBudget { avg_bits: 6.0 };
    router.register(
        WorkloadKind::Vio,
        ModelInstance::planned(ulvio::build(), artifacts::weights("ulvio")?, budget, PrecSel::Fp4x4, true)?,
    )?;
    router.register(
        WorkloadKind::Gaze,
        ModelInstance::planned(gaze::build(), artifacts::weights("gaze")?, budget, PrecSel::Fp4x4, false)?,
    )?;
    router.register(
        WorkloadKind::Classify,
        ModelInstance::planned(effnet::build(), artifacts::weights("effnet")?, budget, PrecSel::Fp4x4, false)?,
    )?;
    Ok(router)
}

fn main() -> Result<()> {
    println!("XR-NPE end-to-end perception pipeline ({FRAMES} frames)\n");

    // ---- load the evaluation streams produced by the build path ----
    let vio_set = artifacts::eval_vio()?;
    let gaze_set = artifacts::eval_gaze()?;
    let shapes = artifacts::eval_shapes()?;
    let n = FRAMES.min(vio_set.images.len()).min(gaze_set.landmarks.len());

    // plans in use
    let router = build_router()?;
    for kind in WorkloadKind::ALL {
        let inst = router.model(kind).unwrap();
        let fmts: Vec<&str> =
            inst.plan.per_layer.iter().map(|s| s.precision().name()).collect();
        println!(
            "{:<9} plan: {:?}  ({:.2} avg bits, {:.1} KB)",
            kind.name(),
            fmts,
            inst.plan.avg_bits(),
            inst.model_bytes() / 1e3
        );
    }

    // ---- frames through the coordinator (probe → calibrate → run) ----
    let frames: Vec<xr_npe::vio::Frame> = (0..n)
        .map(|i| xr_npe::vio::Frame {
            image: vio_set.images[i].clone(),
            imu: vio_set.imu[i].clone(),
            rel_pose: vio_set.poses[i],
        })
        .collect();
    let gaze_in: Vec<Vec<f32>> = (0..n).map(|i| gaze_set.landmarks[i].clone()).collect();

    let mut probe_router = build_router()?;
    let probe = PerceptionPipeline::new(PipelineConfig {
        visual_cycles: 0,
        audio_cycles: 0,
        other_cycles: 0,
        classify_every: 5,
    });
    let base = probe.run(&mut probe_router, &frames, &gaze_in)?;
    let per_frame = base.breakdown.perception_cycles() / n as u64;

    let mut router = build_router()?;
    let pipe = PerceptionPipeline::new(PipelineConfig::calibrated_to(per_frame));
    let t0 = std::time::Instant::now();
    let rep = pipe.run(&mut router, &frames, &gaze_in)?;
    let wall = t0.elapsed();

    // ---- Fig. 1: runtime breakdown ----
    println!("\n-- Fig. 1: application runtime breakdown --");
    for (name, cyc, frac) in rep.breakdown.rows() {
        println!("  {name:<28} {cyc:>12} cycles {:>6.1}%", frac * 100.0);
    }
    println!("  perception share: {:.1}%  (paper/Aspen: ~60%)",
        rep.breakdown.perception_fraction() * 100.0);

    // ---- Fig. 6: VIO accuracy, MxP-on-NPE vs FP32 reference ----
    let vio_inst = router.model(WorkloadKind::Vio).unwrap();
    let mut ref_pred = Vec::new();
    for i in 0..n {
        let out = vio_inst.infer_ref(&vio_set.images[i], &vio_set.imu[i])?;
        let mut p = [0f32; 6];
        p.copy_from_slice(&out[..6]);
        ref_pred.push(p);
    }
    let gt = &rep.vio_gt;
    let t_mxp = odometry::rmse_translation(&rep.vio_pred, gt);
    let r_mxp = odometry::rmse_rotation_deg(&rep.vio_pred, gt);
    let t_ref = odometry::rmse_translation(&ref_pred, gt);
    let r_ref = odometry::rmse_rotation_deg(&ref_pred, gt);
    println!("\n-- Fig. 6: UL-VIO accuracy (NPE MxP vs FP32 ref) --");
    println!("  FP32 ref : t_rmse {t_ref:>6.2}%  r_rmse {r_ref:>7.4} deg/frame");
    println!("  MxP NPE  : t_rmse {t_mxp:>6.2}%  r_rmse {r_mxp:>7.4} deg/frame");
    println!("  deltas   : {:+.2} pp translation, {:+.4} deg rotation",
        t_mxp - t_ref, r_mxp - r_ref);

    // ---- Fig. 5: classification accuracy on the NPE ----
    let cls = router.model(WorkloadKind::Classify).unwrap();
    let mut soc = xr_npe::soc::Soc::new(SocConfig::default());
    let eval_n = 150.min(shapes.images.len());
    let (mut ok_npe, mut ok_ref) = (0usize, 0usize);
    for i in 0..eval_n {
        let (out, _) = cls.infer(&mut soc, &shapes.images[i], &[])?;
        ok_npe += (argmax(&out) == shapes.labels[i]) as usize;
        let r = cls.infer_ref(&shapes.images[i], &[])?;
        ok_ref += (argmax(&r) == shapes.labels[i]) as usize;
    }
    println!("\n-- Fig. 5: classification accuracy ({eval_n} samples) --");
    println!("  FP32 ref : {:.1}%", 100.0 * ok_ref as f64 / eval_n as f64);
    println!("  MxP NPE  : {:.1}%", 100.0 * ok_npe as f64 / eval_n as f64);

    // ---- Table IV: energy efficiency of the measured run ----
    let sys = SystemModel::asic_coprocessor();
    let life = router.replica_lifetime(0);
    let sel = PrecSel::Posit8x2; // representative mode of the mix
    println!("\n-- Table IV: co-processor metrics (measured workload) --");
    println!("  total MACs       {:>12}", life.array.macs);
    println!("  achieved TOPS    {:>12.4}", sys.job_tops(&life));
    println!("  TOPS/W           {:>12.2}", sys.job_tops_per_w(sel, &life));
    println!("  TOPS/mm^2        {:>12.2}", sys.job_tops_per_mm2(&life));
    let e = sys.job_energy(sel, &life);
    println!("  energy breakdown : compute {:.1}% | SRAM {:.1}% | off-chip {:.1}%",
        100.0 * e.compute_j / e.total_j(),
        100.0 * e.sram_j / e.total_j(),
        100.0 * e.offchip_fraction());

    // ---- frame-rate ----
    let clock = 250e6;
    println!("\n-- serving metrics --");
    println!("  frame latency mean {:.2} ms  p99 {:.2} ms  -> {:.0} fps (sim clock {} MHz)",
        rep.frame_latency.mean() / clock * 1e3,
        rep.frame_latency.p99() as f64 / clock * 1e3,
        rep.frame_latency.fps(clock),
        clock / 1e6);
    println!("  host wall time {:.2}s for {n} frames ({:.1} sim-fps on this machine)",
        wall.as_secs_f64(), n as f64 / wall.as_secs_f64());

    // ---- PJRT cross-check: same MxP model through JAX-lowered HLO ----
    println!("\n-- PJRT vs NPE cross-check (GazeNet MxP) --");
    let mut reg = xr_npe::runtime::Registry::open(artifacts::dir())?;
    let gz = router.model(WorkloadKind::Gaze).unwrap();
    let mut soc2 = xr_npe::soc::Soc::new(SocConfig::default());
    let mut max_diff = 0f32;
    for i in 0..20.min(n) {
        let x = &gaze_set.landmarks[i];
        let jax_out = reg.get("gaze_mxp")?.run_f32(&[(x, &[1, 16])])?;
        let (npe_out, _) = gz.infer(&mut soc2, x, &[])?;
        for (a, b) in jax_out[0].iter().zip(&npe_out) {
            max_diff = max_diff.max((a - b).abs());
        }
    }
    println!("  max |jax_mxp - npe_mxp| over 20 frames: {max_diff:.4} rad");
    println!("  (bounded by the FP4 mid-layer's quantization step; the FP32 paths agree");
    println!("   to <1e-4 — see rust/tests/integration.rs)");
    Ok(())
}
