//! Cross-layer integration tests (require `make artifacts`).
//!
//! The central contract: the same trained model, run (a) through the
//! JAX-lowered HLO on PJRT and (b) through the bit-accurate NPE
//! simulator, must agree — exactly-ish at FP32, and within the coarsest
//! format's quantization step under the MxP plan.

use xr_npe::artifacts;
use xr_npe::coordinator::scheduler::ModelInstance;
use xr_npe::models::{effnet, gaze, ulvio};
use xr_npe::npe::PrecSel;
use xr_npe::runtime::Registry;
use xr_npe::soc::{Soc, SocConfig};

fn have_artifacts() -> bool {
    artifacts::dir().join("manifest.json").exists()
}

macro_rules! need_artifacts {
    () => {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
    };
}

/// The JAX-comparison tests additionally need the real PJRT runtime;
/// the default offline build stubs it behind the `pjrt` feature.
macro_rules! need_pjrt {
    () => {
        if !cfg!(feature = "pjrt") {
            eprintln!("skipping: built without the `pjrt` feature (PJRT runtime stubbed)");
            return;
        }
    };
}

#[test]
fn fp32_rust_executor_matches_jax_hlo_gaze() {
    need_artifacts!();
    need_pjrt!();
    let mut reg = Registry::open(artifacts::dir()).unwrap();
    let inst = ModelInstance::uniform(
        gaze::build(),
        artifacts::weights("gaze").unwrap(),
        PrecSel::Posit16x1,
    )
    .unwrap();
    let eval = artifacts::eval_gaze().unwrap();
    for i in 0..10 {
        let x = &eval.landmarks[i];
        let jax = reg.get("gaze_fp32").unwrap().run_f32(&[(x, &[1, 16])]).unwrap();
        let rust = inst.infer_ref(x, &[]).unwrap();
        for (a, b) in jax[0].iter().zip(&rust) {
            assert!(
                (a - b).abs() < 1e-4,
                "frame {i}: jax {a} vs rust {b} (full: {:?} vs {:?})",
                jax[0],
                rust
            );
        }
    }
}

#[test]
fn fp32_rust_executor_matches_jax_hlo_effnet() {
    need_artifacts!();
    need_pjrt!();
    let mut reg = Registry::open(artifacts::dir()).unwrap();
    let inst = ModelInstance::uniform(
        effnet::build(),
        artifacts::weights("effnet").unwrap(),
        PrecSel::Posit16x1,
    )
    .unwrap();
    let eval = artifacts::eval_shapes().unwrap();
    for i in 0..5 {
        let x = &eval.images[i];
        let jax = reg.get("effnet_fp32").unwrap().run_f32(&[(x, &[1, 1, 16, 16])]).unwrap();
        let rust = inst.infer_ref(x, &[]).unwrap();
        for (a, b) in jax[0].iter().zip(&rust) {
            assert!((a - b).abs() < 1e-3, "sample {i}: jax {a} vs rust {b}");
        }
    }
}

#[test]
fn fp32_rust_executor_matches_jax_hlo_ulvio() {
    need_artifacts!();
    need_pjrt!();
    let mut reg = Registry::open(artifacts::dir()).unwrap();
    let inst = ModelInstance::uniform(
        ulvio::build(),
        artifacts::weights("ulvio").unwrap(),
        PrecSel::Posit16x1,
    )
    .unwrap();
    let eval = artifacts::eval_vio().unwrap();
    for i in 0..5 {
        let (img, imu) = (&eval.images[i], &eval.imu[i]);
        let jax = reg
            .get("ulvio_fp32")
            .unwrap()
            .run_f32(&[(img, &[1, 2, 16, 16]), (imu, &[1, 6])])
            .unwrap();
        let rust = inst.infer_ref(img, imu).unwrap();
        for (a, b) in jax[0].iter().zip(&rust) {
            assert!((a - b).abs() < 1e-4, "frame {i}: jax {a} vs rust {b}");
        }
    }
}

#[test]
fn mxp_npe_close_to_jax_mxp_gaze() {
    need_artifacts!();
    need_pjrt!();
    let mut reg = Registry::open(artifacts::dir()).unwrap();
    // python plan for gaze (plan.json): [posit8, fp4, posit16] — build
    // the identical plan on the rust side.
    let plan_txt = std::fs::read_to_string(artifacts::dir().join("plan.json")).unwrap();
    assert!(plan_txt.contains("posit8"), "plan.json: {plan_txt}");
    let inst = ModelInstance::planned(
        gaze::build(),
        artifacts::weights("gaze").unwrap(),
        xr_npe::quant::PlanBudget { avg_bits: 6.0 },
        PrecSel::Fp4x4,
        false,
    )
    .unwrap();
    let mut soc = Soc::new(SocConfig::default());
    let eval = artifacts::eval_gaze().unwrap();
    let mut worst = 0f32;
    for i in 0..20 {
        let x = &eval.landmarks[i];
        let jax = reg.get("gaze_mxp").unwrap().run_f32(&[(x, &[1, 16])]).unwrap();
        let (rust, _) = inst.infer(&mut soc, x, &[]).unwrap();
        for (a, b) in jax[0].iter().zip(&rust) {
            worst = worst.max((a - b).abs());
        }
    }
    // FP4 mid-layer step at gaze activation scale bounds the divergence;
    // outputs are radians in (-0.7, 0.7)
    assert!(worst < 0.15, "MxP divergence {worst} rad too large");
}

#[test]
fn pallas_kernel_artifact_runs() {
    need_artifacts!();
    need_pjrt!();
    let mut reg = Registry::open(artifacts::dir()).unwrap();
    let a = vec![0.5f32; 16 * 32];
    let b = vec![0.25f32; 32 * 16];
    let out = reg
        .get("mpmatmul_posit8")
        .unwrap()
        .run_f32(&[(&a, &[16, 32]), (&b, &[32, 16])])
        .unwrap();
    // 0.5·0.25·32 = 4.0 per element (all values posit8-exact)
    assert_eq!(out[0].len(), 256);
    for &v in &out[0] {
        assert!((v - 4.0).abs() < 1e-5, "got {v}");
    }
}

#[test]
fn qat_weights_improve_low_precision_accuracy() {
    need_artifacts!();
    let eval = artifacts::eval_shapes().unwrap();
    let n = 100.min(eval.images.len());
    let mut soc = Soc::new(SocConfig::default());
    let run = |w, soc: &mut Soc| {
        let inst = ModelInstance::uniform(effnet::build(), w, PrecSel::Fp4x4).unwrap();
        let mut ok = 0;
        for i in 0..n {
            let (out, _) = inst.infer(soc, &eval.images[i], &[]).unwrap();
            ok += (xr_npe::util::argmax(&out) == eval.labels[i]) as usize;
        }
        ok as f64 / n as f64
    };
    let ptq = run(artifacts::weights("effnet").unwrap(), &mut soc);
    let qat = run(artifacts::weights_qat("effnet", "fp4").unwrap(), &mut soc);
    assert!(
        qat >= ptq - 0.02,
        "QAT ({qat:.2}) should not be worse than PTQ ({ptq:.2}) at FP4"
    );
    assert!(qat > 0.8, "QAT FP4 accuracy {qat:.2} should be high");
}
