//! System-level invariants that need no artifacts: multi-workload
//! router sessions, morph sequences, failure injection, and the async
//! serving runtime under mixed load.

use xr_npe::arith::Precision;
use xr_npe::array::ArrayMorph;
use xr_npe::npe::PrecSel;
use xr_npe::soc::{Command, GemmJob, Soc, SocConfig};
use xr_npe::util::{Matrix, Rng};

#[test]
fn long_mixed_session_is_stable() {
    // many jobs, random shapes/precisions/morphs — results always match
    // the oracle, counters monotone, no state leaks between jobs.
    let mut soc = Soc::new(SocConfig::default());
    let mut rng = Rng::new(2024);
    let mut last_macs = 0u64;
    for i in 0..40 {
        if i % 11 == 5 {
            let m = if rng.coin(0.5) { ArrayMorph::M8x8 } else { ArrayMorph::M16x16 };
            soc.submit(Command::Morph(m));
            soc.process_all().unwrap();
        }
        let m = 1 + (rng.next_u64() % 24) as usize;
        let k = 1 + (rng.next_u64() % 48) as usize;
        let n = 1 + (rng.next_u64() % 24) as usize;
        let sel = PrecSel::ALL[(rng.next_u64() % 4) as usize];
        let a = Matrix::random(m, k, 1.0, &mut rng);
        let b = Matrix::random(k, n, 1.0, &mut rng);
        let (got, rep) = soc.gemm(&a, &b, sel, sel.precision()).unwrap();
        // oracle with EXACT accumulation (an f64-summing oracle can
        // differ from the quire by 1 ulp on posit16 dot products — the
        // engine is the more exact one)
        let p = sel.precision();
        let t = xr_npe::arith::tables::table(p);
        let mut want = Matrix::zeros(m, n);
        for i2 in 0..m {
            for j2 in 0..n {
                let mut q = xr_npe::arith::Quire::new();
                for k2 in 0..k {
                    let da = t.decode(t.encode(a.at(i2, k2) as f64));
                    let db = t.decode(t.encode(b.at(k2, j2) as f64));
                    q.add_product(da, db);
                }
                want.set(i2, j2, xr_npe::arith::tables::quantize(p, q.to_f64()) as f32);
            }
        }
        assert_eq!(got.data, want.data, "job {i} {sel:?} {m}x{k}x{n}");
        assert!(soc.lifetime.array.macs > last_macs);
        last_macs = soc.lifetime.array.macs;
        assert_eq!(rep.array.macs, (m * k * n) as u64);
    }
}

#[test]
fn dram_oob_job_fails_cleanly_and_soc_survives() {
    let mut soc = Soc::new(SocConfig::default());
    let job = GemmJob {
        m: 8, k: 8, n: 8,
        sel: PrecSel::Posit8x2,
        out_prec: Precision::Posit8,
        a_addr: u64::MAX - 100, b_addr: 0, c_addr: 1024,
    };
    soc.submit(Command::Gemm(job));
    assert!(soc.process_all().is_err());
    // the SoC remains usable afterwards
    let mut rng = Rng::new(1);
    let a = Matrix::random(4, 4, 1.0, &mut rng);
    let b = Matrix::random(4, 4, 1.0, &mut rng);
    assert!(soc.gemm(&a, &b, PrecSel::Posit8x2, Precision::Posit8).is_ok());
}

#[test]
fn degenerate_and_edge_shapes() {
    let mut soc = Soc::new(SocConfig::default());
    let mut rng = Rng::new(3);
    // 1x1x1, single row/col, prime sizes crossing tile boundaries
    for (m, k, n) in [(1, 1, 1), (1, 64, 1), (17, 1, 19), (9, 65, 7), (16, 16, 17)] {
        let a = Matrix::random(m, k, 1.0, &mut rng);
        let b = Matrix::random(k, n, 1.0, &mut rng);
        let (c, rep) = soc.gemm(&a, &b, PrecSel::Fp4x4, Precision::Fp4).unwrap();
        assert_eq!((c.rows, c.cols), (m, n));
        assert_eq!(rep.array.macs, (m * k * n) as u64);
    }
}

#[test]
fn extreme_values_saturate_not_poison() {
    // huge/tiny values: saturating formats must not produce NaN/Inf
    let mut soc = Soc::new(SocConfig::default());
    let a = Matrix::from_vec(2, 2, vec![1e30, -1e30, 1e-30, 0.0]);
    let b = Matrix::from_vec(2, 2, vec![1e30, 1.0, -1.0, 1e-30]);
    let (c, _) = soc.gemm(&a, &b, PrecSel::Fp4x4, Precision::Fp32).unwrap();
    assert!(c.data.iter().all(|x| x.is_finite()), "{:?}", c.data);
}

#[test]
fn async_runtime_serves_mixed_workloads_bit_identically() {
    // interleave every workload kind through the async submission API
    // (handles redeemed out of submission order) and check each result
    // against a fresh serial router — values must match exactly, and
    // the runtime must account every job.
    use xr_npe::coordinator::{ModelInstance, Router, WorkloadKind};
    use xr_npe::models::{effnet, gaze, random_weights, ulvio};

    let build = || {
        let mut r = Router::new(2, SocConfig::default());
        for (kind, graph, sel, seed) in [
            (WorkloadKind::Vio, ulvio::build(), PrecSel::Posit8x2, 70u64),
            (WorkloadKind::Gaze, gaze::build(), PrecSel::Fp4x4, 71),
            (WorkloadKind::Classify, effnet::build(), PrecSel::Posit16x1, 72),
        ] {
            let w = random_weights(&graph, seed);
            r.register(kind, ModelInstance::uniform(graph, w, sel).unwrap()).unwrap();
        }
        r
    };
    let mut async_r = build();
    let mut serial_r = build();
    let in_len = |kind| match kind {
        WorkloadKind::Vio => 512,
        WorkloadKind::Gaze => 16,
        WorkloadKind::Classify => 256,
    };
    let aux_len = |kind| if kind == WorkloadKind::Vio { 6 } else { 0 };
    let reqs: Vec<(WorkloadKind, Vec<f32>, Vec<f32>)> = (0..12)
        .map(|i| {
            let kind = WorkloadKind::ALL[i % 3];
            let input: Vec<f32> =
                (0..in_len(kind)).map(|j| ((i * 31 + j) as f32 * 0.017).sin() * 0.4).collect();
            let aux: Vec<f32> = (0..aux_len(kind)).map(|j| 0.05 * (j as f32 + i as f32)).collect();
            (kind, input, aux)
        })
        .collect();
    // submit everything before redeeming anything — the queues pipeline
    let handles: Vec<_> = reqs
        .iter()
        .map(|(kind, input, aux)| async_r.submit(*kind, input.clone(), aux.clone()).unwrap())
        .collect();
    for ((kind, input, aux), h) in reqs.iter().zip(handles) {
        let got = Router::resolve(h).unwrap();
        let want = serial_r.route(*kind, input, aux).unwrap();
        assert_eq!(got.output, want.output, "{kind:?}: async diverged from serial");
        assert_eq!(got.report, want.report, "{kind:?}: reports diverged");
        assert_eq!(got.replica, want.replica, "{kind:?}: assignment diverged");
    }
    async_r.quiesce();
    let m = async_r.runtime_metrics();
    assert_eq!(m.completed, 12);
    assert_eq!(async_r.total_served(), 12);
    for i in 0..2 {
        assert_eq!(
            async_r.replica_lifetime(i),
            serial_r.replica_lifetime(i),
            "replica {i} lifetime stats diverged"
        );
    }
}

#[test]
fn sharded_and_whole_models_share_a_fleet_bit_identically() {
    // a sharded model (scatter → partial quires → exact reduce) and a
    // whole-resident model serve interleaved traffic from the same
    // 2-replica fleet; every result matches a whole-model reference
    // router bit for bit, and the runtime accounts all the work
    use xr_npe::coordinator::{ModelInstance, Router, WorkloadKind};
    use xr_npe::models::{gaze, mlp, random_weights};

    let gg = gaze::build();
    let wg = random_weights(&gg, 80);
    let gm = mlp::build();
    let wm = random_weights(&gm, 81);
    let mut fleet = Router::new(2, SocConfig::default());
    fleet
        .register(
            WorkloadKind::Gaze,
            ModelInstance::uniform(gg.clone(), wg.clone(), PrecSel::Fp4x4).unwrap(),
        )
        .unwrap();
    fleet
        .register_sharded(
            WorkloadKind::Classify,
            ModelInstance::uniform(gm.clone(), wm.clone(), PrecSel::Posit8x2).unwrap(),
            2,
        )
        .unwrap();
    let mut reference = Router::new(1, SocConfig::default());
    reference
        .register(WorkloadKind::Gaze, ModelInstance::uniform(gg, wg, PrecSel::Fp4x4).unwrap())
        .unwrap();
    reference
        .register(WorkloadKind::Classify, ModelInstance::uniform(gm, wm, PrecSel::Posit8x2).unwrap())
        .unwrap();
    let input_of = |kind: WorkloadKind, i: usize| -> Vec<f32> {
        let len = if kind == WorkloadKind::Gaze { 16 } else { 256 };
        (0..len).map(|j| ((i * 31 + j) as f32 * 0.017).sin() * 0.4).collect()
    };
    // interleave, submitting everything before redeeming anything —
    // sharded coordinators and whole-model jobs pipeline together
    let reqs: Vec<(WorkloadKind, Vec<f32>)> = (0..8)
        .map(|i| {
            let kind = if i % 2 == 0 { WorkloadKind::Gaze } else { WorkloadKind::Classify };
            (kind, input_of(kind, i))
        })
        .collect();
    let handles: Vec<_> = reqs
        .iter()
        .map(|(kind, input)| fleet.submit(*kind, input.clone(), vec![]).unwrap())
        .collect();
    for ((kind, input), h) in reqs.iter().zip(handles) {
        let got = Router::resolve(h).unwrap();
        let want = reference.route(*kind, input, &[]).unwrap();
        assert_eq!(got.output, want.output, "{kind:?}: sharded fleet diverged");
        if *kind == WorkloadKind::Classify {
            assert!(got.report.reduce_cycles > 0, "sharded report must carry the reduction term");
            assert_eq!(
                got.report.jobs.array.macs, want.report.jobs.array.macs,
                "sharded MAC work must be conserved"
            );
        }
    }
    fleet.quiesce();
    assert_eq!(fleet.total_served(), 8);
    // every partial GEMM ran through the runtime workers: 3 layers x 4
    // classify requests x 2 shards = 24 partial jobs + 4 gaze infers
    assert_eq!(fleet.runtime_metrics().completed as usize, 24 + 4);
}

#[test]
fn nan_inputs_flag_nar_posit() {
    use xr_npe::soc::csr;
    let mut soc = Soc::new(SocConfig::default());
    let mut a = Matrix::eye(4);
    a.data[5] = f32::NAN;
    let b = Matrix::eye(4);
    let _ = soc.gemm(&a, &b, PrecSel::Posit16x1, Precision::Posit16).unwrap();
    let status = soc.csrs.read(csr::STATUS).unwrap();
    assert_ne!(status & csr::STATUS_ERR_NAR, 0, "NaR error bit must latch");
}
