//! Cross-module property tests (seeded in-repo harness, no artifacts
//! needed).

use xr_npe::arith::{tables, Class, Precision, Quire};
use xr_npe::array::{ArrayMorph, MatrixArray};
use xr_npe::npe::PrecSel;
use xr_npe::quant::policy::{self, PlanBudget};
use xr_npe::quant::sensitivity::analyze_layers;
use xr_npe::soc::control::{pack_matrix, packed_bytes};
use xr_npe::util::proptest::{self, Config, Draw};
use xr_npe::util::Matrix;

#[test]
fn array_results_invariant_under_morph() {
    // the SAME gemm on 8x8 vs 16x16 must produce identical values
    // (geometry affects cycles, never numerics)
    proptest::run(Config { cases: 16, seed: 0xBEEF }, |rng, _| {
        let m = rng.usize_in(1, 20);
        let k = rng.usize_in(1, 30);
        let n = rng.usize_in(1, 20);
        let sel = PrecSel::ALL[rng.usize_in(0, 3)];
        let a = Matrix::random(m, k, 1.0, rng);
        let b = Matrix::random(k, n, 1.0, rng);
        let (small, _) = MatrixArray::new(ArrayMorph::M8x8, sel).gemm(&a, &b, sel.precision());
        let (big, _) = MatrixArray::new(ArrayMorph::M16x16, sel).gemm(&a, &b, sel.precision());
        assert_eq!(small.data, big.data);
    });
}

#[test]
fn quire_dot_matches_f64_for_short_posit8_dots() {
    // posit8 products are exact in f64 and short sums stay exact, so the
    // quire and f64 must agree perfectly
    proptest::check(|rng, _| {
        let t = tables::table(Precision::Posit8);
        let k = rng.usize_in(1, 64);
        let mut q = Quire::new();
        let mut f = 0f64;
        for _ in 0..k {
            let a = t.decode((rng.next_u64() & 0xFF) as u32);
            let b = t.decode((rng.next_u64() & 0xFF) as u32);
            if a.class != Class::Normal || b.class != Class::Normal {
                continue;
            }
            q.add_product(a, b);
            f += a.to_f64() * b.to_f64();
        }
        assert_eq!(q.to_f64(), f);
    });
}

#[test]
fn pack_matrix_length_and_roundtrip() {
    proptest::check(|rng, _| {
        let r = rng.usize_in(1, 12);
        let c = rng.usize_in(1, 24);
        let sel = PrecSel::ALL[rng.usize_in(0, 3)];
        let m = Matrix::random(r, c, 1.0, rng);
        let bytes = pack_matrix(&m, sel);
        assert_eq!(bytes.len(), packed_bytes(r, c, sel));
        // every packed word decodes to a quantized value of the source
        let t = tables::table(sel.precision());
        let words_per_row = c.div_ceil(sel.lanes());
        for row in 0..r {
            for (wi, chunk) in bytes[row * words_per_row * 2..(row + 1) * words_per_row * 2]
                .chunks_exact(2)
                .enumerate()
            {
                let word = u16::from_le_bytes([chunk[0], chunk[1]]);
                for (li, enc) in sel.unpack(word).enumerate() {
                    let idx = wi * sel.lanes() + li;
                    if idx < c {
                        let want = t.encode(m.at(row, idx) as f64);
                        assert_eq!(enc, want);
                    }
                }
            }
        }
    });
}

#[test]
fn planner_always_legal_and_monotone_in_budget() {
    proptest::run(Config { cases: 64, seed: 7 }, |rng, _| {
        let layers = rng.usize_in(1, 10);
        let ws: Vec<Vec<f32>> = (0..layers)
            .map(|_| {
                let len = rng.usize_in(4, 512);
                rng.vec_normal(len, 0.5)
            })
            .collect();
        let gs: Vec<Vec<f32>> =
            (0..layers).map(|i| rng.vec_normal(ws[i].len(), 0.1)).collect();
        let params: Vec<usize> = ws.iter().map(Vec::len).collect();
        let sens = analyze_layers(&ws, &gs);
        let lo = policy::plan(&sens, &params, PlanBudget { avg_bits: 4.5 }, PrecSel::Fp4x4, &[]);
        let hi = policy::plan(&sens, &params, PlanBudget { avg_bits: 9.0 }, PrecSel::Fp4x4, &[]);
        assert_eq!(lo.per_layer.len(), layers);
        assert!(lo.avg_bits() <= 4.5 + 1e-9);
        assert!(hi.avg_bits() <= 9.0 + 1e-9);
        // bigger budget never allocates FEWER bits in total (per-layer
        // monotonicity does NOT hold for greedy knapsack promotion — a
        // loose budget spends on big fragile layers a tight one can't
        // afford, skipping the small ones it promoted instead)
        assert!(
            hi.avg_bits() >= lo.avg_bits() - 1e-9,
            "total allocation must be monotone: {} vs {}",
            hi.avg_bits(),
            lo.avg_bits()
        );
    });
}

#[test]
fn quantize_is_projection_and_monotone() {
    // idempotent + order-preserving for every format
    proptest::check(|rng, _| {
        let p = [
            Precision::Fp4,
            Precision::Posit4,
            Precision::Posit8,
            Precision::Posit16,
            Precision::Fp8E4M3,
        ][rng.usize_in(0, 4)];
        let x = rng.nasty_f64();
        let y = rng.nasty_f64();
        let qx = tables::quantize(p, x);
        assert_eq!(tables::quantize(p, qx), qx, "{p:?} idempotent at {x}");
        let qy = tables::quantize(p, y);
        if x <= y {
            assert!(qx <= qy, "{p:?} monotone: q({x})={qx} q({y})={qy}");
        }
    });
}

#[test]
fn engine_stats_conserved_under_splitting() {
    // running a dot in one engine vs split across two engines conserves
    // total MAC/gating counts
    proptest::check(|rng, _| {
        use xr_npe::npe::Engine;
        let sel = PrecSel::Posit8x2;
        let k = rng.usize_in(2, 64) & !1;
        let words: Vec<(u16, u16)> =
            (0..k).map(|_| (rng.next_u64() as u16, rng.next_u64() as u16)).collect();
        let mut one = Engine::new(sel);
        for &(a, b) in &words {
            one.mac_word_fused(a, b);
        }
        let mut e1 = Engine::new(sel);
        let mut e2 = Engine::new(sel);
        for (i, &(a, b)) in words.iter().enumerate() {
            if i % 2 == 0 {
                e1.mac_word_fused(a, b);
            } else {
                e2.mac_word_fused(a, b);
            }
        }
        assert_eq!(one.stats.macs, e1.stats.macs + e2.stats.macs);
        assert_eq!(one.stats.gated_macs, e1.stats.gated_macs + e2.stats.gated_macs);
        assert_eq!(
            one.stats.blocks_switched,
            e1.stats.blocks_switched + e2.stats.blocks_switched
        );
        // and the split quires merge to the same value
        let mut q1 = one.read_lane_f64(0);
        let merged = e1.read_lane_f64(0) + e2.read_lane_f64(0);
        if q1.is_nan() {
            assert!(merged.is_nan());
            q1 = 0.0;
        } else {
            assert!((q1 - merged).abs() < 1e-9, "{q1} vs {merged}");
        }
        let _ = q1;
    });
}
