//! Residency invariants under churn: the DRAM-budgeted catalog never
//! exceeds its budget, compaction round-trips every live byte, and
//! register→evict→register loops keep the watermark flat (seeded
//! in-repo property harness, no artifacts needed).

use std::sync::Arc;
use xr_npe::models::compile::compile;
use xr_npe::models::graph::{Layer, LayerKind, ModelGraph, Shape};
use xr_npe::models::{
    compact_resident, random_weights, CompiledModel, ResidencyManager, ResidentImage,
};
use xr_npe::npe::PrecSel;
use xr_npe::quant::PrecisionPlan;
use xr_npe::soc::{Soc, SocConfig};
use xr_npe::util::proptest::{self, Config, Draw};

fn fc_model(name: &str, k: usize, n: usize, sel: PrecSel, seed: u64) -> Arc<CompiledModel> {
    let g = ModelGraph {
        name: name.into(),
        input: Shape::vec(k),
        layers: vec![Layer { name: "fc".into(), kind: LayerKind::Fc { in_f: k, out_f: n } }],
    };
    let w = random_weights(&g, seed);
    let plan = PrecisionPlan::uniform(sel, &g.compute_layer_params());
    Arc::new(compile(&g, &w, &plan).unwrap())
}

fn as_image(m: &Arc<CompiledModel>) -> Arc<dyn ResidentImage> {
    Arc::clone(m) as Arc<dyn ResidentImage>
}

/// Occupied resident bytes: live spans below the watermark.
fn occupancy(soc: &Soc) -> u64 {
    soc.resident_mark() - soc.resident_free_bytes()
}

#[test]
fn resident_usage_never_exceeds_budget_under_random_churn() {
    // (a) random admit (dispatch) churn over a 5-model catalog on a
    // budget that holds ~2 of them: accounting AND the device's actual
    // occupancy stay under the budget after every operation, every
    // admissible model admits successfully, and a warmed model always
    // serves the same bits as a fresh big-DRAM reference.
    proptest::run(Config { cases: 8, seed: 0xD0D0 }, |rng, case| {
        let sel = PrecSel::ALL[rng.usize_in(0, 3)];
        let mut soc = Soc::new(SocConfig { dram_bytes: 1 << 15, ..Default::default() });
        let budget = soc.resident_limit(); // 24576
        let mut mgr = ResidencyManager::lru(budget);
        let shapes = [(64usize, 32usize), (48, 40), (32, 24), (64, 48), (16, 56)];
        let models: Vec<Arc<CompiledModel>> = shapes
            .iter()
            .enumerate()
            .map(|(i, &(k, n))| {
                fc_model(&format!("m{i}"), k, n, sel, 1000 + case as u64 * 8 + i as u64)
            })
            .collect();
        // reference outputs on an unconstrained device
        let inputs: Vec<Vec<f32>> = shapes
            .iter()
            .map(|&(k, _)| (0..k).map(|j| ((j * 7 + case as usize) as f32 * 0.11).sin()).collect())
            .collect();
        let want: Vec<Vec<f32>> = models
            .iter()
            .zip(&inputs)
            .map(|(m, x)| {
                let mut big = Soc::new(SocConfig::default());
                m.replay(&mut big, x, &[]).unwrap().0
            })
            .collect();
        for _ in 0..40 {
            let i = rng.usize_in(0, models.len() - 1);
            match mgr.admit(&mut soc, &as_image(&models[i])) {
                Ok(()) => {
                    let (got, _) = models[i].replay(&mut soc, &inputs[i], &[]).unwrap();
                    assert_eq!(got, want[i], "model {i} diverged under churn");
                }
                Err(e) => panic!("every model fits the budget alone, admit failed: {e}"),
            }
            assert!(
                mgr.warm_bytes(&soc) <= budget,
                "accounted warm bytes exceed the budget"
            );
            assert!(
                occupancy(&soc) <= budget,
                "device occupancy {} exceeds budget {}",
                occupancy(&soc),
                budget
            );
        }
        let s = mgr.stats();
        assert!(s.resident_high_water <= budget);
        assert_eq!(s.cold_warms, s.evictions + mgr_warm_count(&mgr, &soc, &models));
    });
}

/// Models currently warm (by device ground truth).
fn mgr_warm_count(_mgr: &ResidencyManager, soc: &Soc, models: &[Arc<CompiledModel>]) -> u64 {
    models.iter().filter(|m| soc.has_model_state(m.uid())).count() as u64
}

#[test]
fn compaction_round_trips_every_live_image_hash() {
    // (b) random evict subsets then compact: every surviving weight
    // image's bytes hash identically at the relocated addresses, the
    // free list drains, and serving stays bit-identical.
    proptest::run(Config { cases: 8, seed: 0xFEED }, |rng, case| {
        let sel = PrecSel::ALL[rng.usize_in(0, 3)];
        let mut soc = Soc::new(SocConfig::default());
        let models: Vec<Arc<CompiledModel>> = (0..4)
            .map(|i| {
                let k = 16 * (1 + rng.usize_in(0, 3));
                let n = 8 * (1 + rng.usize_in(0, 5));
                fc_model(&format!("m{i}"), k, n, sel, 2000 + case as u64 * 4 + i as u64)
            })
            .collect();
        for m in &models {
            m.ensure_warm(&mut soc).unwrap();
        }
        // evict a random (possibly empty) strict subset
        let survivors: Vec<&Arc<CompiledModel>> =
            models.iter().filter(|_| rng.coin(0.6)).collect();
        for m in &models {
            if !survivors.iter().any(|s| s.uid() == m.uid()) {
                m.evict(&mut soc);
            }
        }
        let live: Vec<Arc<dyn ResidentImage>> =
            survivors.iter().copied().map(as_image).collect();
        let hash = |soc: &Soc, img: &Arc<dyn ResidentImage>| -> u64 {
            let mut h = 0xcbf29ce484222325u64; // FNV-1a
            for &(a, l) in &img.live_blocks(soc) {
                for &b in soc.ext.read(a, l).unwrap() {
                    h = (h ^ b as u64).wrapping_mul(0x100000001b3);
                }
            }
            h
        };
        let before: Vec<u64> = live.iter().map(|img| hash(&soc, img)).collect();
        compact_resident(&mut soc, &live);
        assert_eq!(soc.resident_free_bytes(), 0, "compaction must drain the free list");
        let after: Vec<u64> = live.iter().map(|img| hash(&soc, img)).collect();
        assert_eq!(before, after, "live image bytes must survive relocation");
        for m in &survivors {
            let x: Vec<f32> = (0..m.input_len).map(|j| (j as f32 * 0.07).sin()).collect();
            let mut fresh = Soc::new(SocConfig::default());
            let (want, wrep) = m.replay(&mut fresh, &x, &[]).unwrap();
            let (got, grep) = m.replay(&mut soc, &x, &[]).unwrap();
            assert_eq!(got, want, "compacted model diverged");
            assert_eq!(grep, wrep, "compaction must not change cost accounting");
        }
    });
}

#[test]
fn register_evict_register_loops_keep_the_watermark_flat() {
    // (c) refresh churn over >2 models: repeatedly replacing each
    // catalog slot with a same-shape recompile never grows the
    // watermark past the initial full-catalog peak (extends the PR-3
    // single-model regression to a rotating multi-model catalog).
    let mut soc = Soc::new(SocConfig::default());
    let budget = soc.resident_limit();
    let mut mgr = ResidencyManager::lru(budget);
    let shapes = [(64usize, 32usize), (48, 40), (32, 24)];
    let mut models: Vec<Arc<CompiledModel>> = shapes
        .iter()
        .enumerate()
        .map(|(i, &(k, n))| fc_model(&format!("m{i}"), k, n, PrecSel::Posit8x2, 3000 + i as u64))
        .collect();
    for m in &models {
        mgr.admit(&mut soc, &as_image(m)).unwrap();
    }
    let peak = soc.resident_mark();
    for round in 0u64..6 {
        for (i, &(k, n)) in shapes.iter().enumerate() {
            // replace slot i: evict + drop the old, compile + admit new
            mgr.remove(&mut soc, models[i].uid());
            models[i] =
                fc_model(&format!("m{i}"), k, n, PrecSel::Posit8x2, 4000 + round * 3 + i as u64);
            mgr.admit(&mut soc, &as_image(&models[i])).unwrap();
            assert!(
                soc.resident_mark() <= peak,
                "round {round} slot {i}: watermark {} grew past the peak {peak}",
                soc.resident_mark()
            );
        }
        // the whole refreshed catalog still serves
        for m in &models {
            let x: Vec<f32> = (0..m.input_len).map(|j| (j as f32 * 0.05).sin()).collect();
            m.replay(&mut soc, &x, &[]).unwrap();
        }
    }
    assert_eq!(mgr.catalog_len(), 3);
    assert_eq!(mgr.stats().evictions, 0, "everything fits — churn must not force evictions");
}
