//! Fig. 7 — impact of precision on application accuracy for object
//! detection and eye-gaze (LLE) estimation.
//!
//! Eye gaze: MSE per precision on the NPE simulator (QAT weights).
//! Object detection: the paper uses a detection model; our substitution
//! (DESIGN.md) proxies detection quality with the localization-bearing
//! classification workload — both stress the same quantized conv
//! features. Rows are labeled accordingly.

#[path = "common/mod.rs"]
mod common;

use xr_npe::coordinator::scheduler::ModelInstance;
use xr_npe::npe::PrecSel;

const EVAL_N: usize = 300;

fn main() {
    common::require_artifacts();
    println!("== Fig. 7: gaze MSE + detection-proxy accuracy vs precision ==\n");
    println!(
        "{:<22} {:>6} {:>13} {:>14}",
        "precision", "bits", "gaze MSE", "det-proxy acc%"
    );

    let gz32 = ModelInstance::uniform(
        common::graph_of("gaze"),
        xr_npe::artifacts::weights("gaze").unwrap(),
        PrecSel::Posit16x1,
    ).unwrap();
    let cls32 = ModelInstance::uniform(
        common::graph_of("effnet"),
        xr_npe::artifacts::weights("effnet").unwrap(),
        PrecSel::Posit16x1,
    ).unwrap();
    println!(
        "{:<22} {:>6} {:>13.6} {:>14.1}",
        "FP32 (baseline)",
        32,
        common::gaze_mse_ref(&gz32, EVAL_N),
        100.0 * common::cls_accuracy_ref(&cls32, 120)
    );

    // software-framework rows for non-native formats
    for (label, bits, key) in [
        ("BF16", 16, "ptq_bf16"),
        ("FP8-E4M3", 8, "ptq_e4m3"),
        ("FxP8", 8, "ptq_fxp8"),
        ("FxP4", 4, "ptq_fxp4"),
    ] {
        let g = common::py_metric("gaze", key);
        let c = common::py_metric("effnet", key);
        if let (Some(g), Some(c)) = (g, c) {
            println!("{:<22} {:>6} {:>13.6} {:>14.1}   (emulated sw)", label, bits, g, 100.0 * c);
        }
    }

    // hardware modes on the NPE
    for sel in [PrecSel::Posit16x1, PrecSel::Posit8x2, PrecSel::Fp4x4, PrecSel::Posit4x4] {
        let gz = ModelInstance::uniform(
            common::graph_of("gaze"),
            common::weights_for("gaze", sel),
            sel,
        ).unwrap();
        let cls = ModelInstance::uniform(
            common::graph_of("effnet"),
            common::weights_for("effnet", sel),
            sel,
        ).unwrap();
        println!(
            "{:<22} {:>6} {:>13.6} {:>14.1}   (NPE sim, QAT)",
            sel.precision().name(),
            sel.precision().bits(),
            common::gaze_mse_npe(&gz, EVAL_N),
            100.0 * common::cls_accuracy_npe(&cls, 120)
        );
    }

    println!("\nshape to check (paper): FP4 gaze MSE acceptable (same order as FP8),");
    println!("8-bit formats indistinguishable from FP32.");
}
