//! Fig. 1 — workload analysis for application runtime: the perception
//! pipeline dominates (~60%) XR application runtime.
//!
//! Reproduced by driving the full perception pipeline (VIO + gaze +
//! classification on the simulated co-processor) against host-stage
//! budgets calibrated at the FP32-equivalent operating point, then
//! *measuring* the same breakdown under the layer-adaptive MxP plan —
//! showing how XR-NPE's 4-bit throughput shrinks the perception share.

#[path = "common/mod.rs"]
mod common;

use xr_npe::coordinator::scheduler::ModelInstance;
use xr_npe::coordinator::{PerceptionPipeline, PipelineConfig, Router, WorkloadKind};
use xr_npe::npe::PrecSel;
use xr_npe::quant::PlanBudget;
use xr_npe::soc::SocConfig;

const FRAMES: usize = 60;

fn router_with(sel_vio: PrecSel, sel_gaze: PrecSel, sel_cls: PrecSel, mxp: bool) -> Router {
    let mut r = Router::new(1, SocConfig::default());
    let mk = |model: &str, sel: PrecSel| {
        if mxp {
            ModelInstance::planned(
                common::graph_of(model),
                xr_npe::artifacts::weights(model).unwrap(),
                PlanBudget { avg_bits: 6.0 },
                PrecSel::Fp4x4,
                model == "ulvio",
            ).unwrap()
        } else {
            ModelInstance::uniform(common::graph_of(model), common::weights_for(model, sel), sel).unwrap()
        }
    };
    r.register(WorkloadKind::Vio, mk("ulvio", sel_vio)).unwrap();
    r.register(WorkloadKind::Gaze, mk("gaze", sel_gaze)).unwrap();
    r.register(WorkloadKind::Classify, mk("effnet", sel_cls)).unwrap();
    r
}

fn main() {
    common::require_artifacts();
    let eval = xr_npe::artifacts::eval_vio().unwrap();
    let gaze_eval = xr_npe::artifacts::eval_gaze().unwrap();
    let n = FRAMES.min(eval.images.len()).min(gaze_eval.landmarks.len());
    let frames: Vec<xr_npe::vio::Frame> = (0..n)
        .map(|i| xr_npe::vio::Frame {
            image: eval.images[i].clone(),
            imu: eval.imu[i].clone(),
            rel_pose: eval.poses[i],
        })
        .collect();
    let gaze_in: Vec<Vec<f32>> = (0..n).map(|i| gaze_eval.landmarks[i].clone()).collect();

    // baseline operating point: everything at 16-bit (the "existing
    // accelerator" Aspen characterizes) → calibrate host stages to 60%
    let hi = PrecSel::Posit16x1;
    let mut base_router = router_with(hi, hi, hi, false);
    let probe = PerceptionPipeline::new(PipelineConfig {
        visual_cycles: 0,
        audio_cycles: 0,
        other_cycles: 0,
        classify_every: 5,
    });
    let base = probe.run(&mut base_router, &frames, &gaze_in).unwrap();
    let per_frame = base.breakdown.perception_cycles() / n as u64;
    let cfg = PipelineConfig::calibrated_to(per_frame);

    println!("== Fig. 1: application runtime breakdown ==");
    for (label, mxp) in [("16-bit perception (baseline accelerator)", false), ("layer-adaptive MxP on XR-NPE", true)] {
        let mut router = if mxp {
            router_with(hi, hi, hi, true)
        } else {
            router_with(hi, hi, hi, false)
        };
        let pipe = PerceptionPipeline::new(cfg);
        let rep = pipe.run(&mut router, &frames, &gaze_in).unwrap();
        println!("\n-- {label} --");
        for (name, cyc, frac) in rep.breakdown.rows() {
            let bar = "#".repeat((frac * 50.0).round() as usize);
            println!("  {name:<28} {:>5.1}% {bar}", frac * 100.0);
            let _ = cyc;
        }
        println!(
            "  perception share: {:.1}%   frame p99 {:.2} ms @250MHz ({:.0} fps)",
            rep.breakdown.perception_fraction() * 100.0,
            rep.frame_latency.p99() as f64 / 250e6 * 1e3,
            rep.frame_latency.fps(250e6)
        );
    }
    println!("\n(paper/Aspen: perception ~60% of runtime at the baseline point;");
    println!(" MxP shrinks the perception share, freeing headroom for the 630-FPS-class");
    println!(" targets Aspen reports.)");
}
