//! Table III — FPGA accelerator comparison at iso-compute (64 MACs).
//!
//! Published SoTA rows next to the modeled XR-NPE co-processor
//! (LUT/FF/DSP from the component resource model; power/GOPS/W on the
//! mixed-precision VIO layer mix), with the paper's 1.4×/1.77×/1.2×
//! ratio claims. Also measures the simulated co-processor's GEMM
//! throughput on VIO-shaped layers (host wall time, §Perf).

#[path = "common/mod.rs"]
mod common;

use xr_npe::energy::baselines::{TABLE3_BASELINES, TABLE3_THIS_WORK};
use xr_npe::energy::FpgaModel;
use xr_npe::npe::PrecSel;
use xr_npe::soc::{Soc, SocConfig};
use xr_npe::util::{Matrix, Rng};

fn main() {
    println!("== Table III: FPGA accelerator comparison (iso 64 compute units) ==\n");
    println!(
        "{:<22} {:<10} {:>5} {:<13} {:>6} {:>9} {:>8} {:>8} {:>5} {:>7} {:>8}",
        "design", "board", "nm", "model", "MHz", "bits", "LUTs k", "FFs k", "DSP", "W", "GOPS/W"
    );
    for r in TABLE3_BASELINES {
        println!(
            "{:<22} {:<10} {:>5} {:<13} {:>6.0} {:>9} {:>8.2} {:>8.2} {:>5} {:>7.2} {:>8.2}",
            r.design, r.board, r.tech_nm, r.model, r.freq_mhz, r.bitwidths, r.luts_k, r.ffs_k,
            r.dsp, r.power_w, r.gops_per_w
        );
    }
    let m = FpgaModel::xr_npe_8x8();
    let (luts, ffs) = (m.luts_k(), m.ffs_k());
    let power = m.power_w(0.55);
    let eff = m.gops_per_w(2.0, 0.55);
    println!(
        "{:<22} {:<10} {:>5} {:<13} {:>6.0} {:>9} {:>8.2} {:>8.2} {:>5} {:>7.2} {:>8.2}   <- modeled",
        "This work (modeled)", "XCZU7EV", 16, "VIO", m.freq_mhz, "4/8/16", luts, ffs, m.dsps(),
        power, eff
    );
    let t = TABLE3_THIS_WORK;
    println!(
        "{:<22} {:<10} {:>5} {:<13} {:>6.0} {:>9} {:>8.2} {:>8.2} {:>5} {:>7.2} {:>8.2}   <- paper",
        "This work (paper)", t.board, t.tech_nm, t.model, t.freq_mhz, t.bitwidths, t.luts_k,
        t.ffs_k, t.dsp, t.power_w, t.gops_per_w
    );

    let r29 = TABLE3_BASELINES.iter().find(|r| r.design.contains("[29]")).unwrap();
    println!("\n-- headline claims (paper §III, vs [29]) --");
    println!("  LUT ratio:        {:.2}x fewer (paper: 1.4x)", r29.luts_k / luts);
    println!("  FF ratio:         {:.2}x fewer (paper: 1.77x)", r29.ffs_k / ffs);
    println!("  energy-eff ratio: {:.2}x better (paper: 1.2x)", eff / r29.gops_per_w);

    println!("\n-- morph scaling --");
    let big = FpgaModel::xr_npe_16x16();
    println!(
        "  8x8:   {:.2}k LUT {:.2}k FF  peak {:.1} GOPS (posit8)",
        m.luts_k(), m.ffs_k(), m.gops(2.0)
    );
    println!(
        "  16x16: {:.2}k LUT {:.2}k FF  peak {:.1} GOPS (posit8)  ({:.2}x LUT for 4x compute)",
        big.luts_k(), big.ffs_k(), big.gops(2.0), big.luts_k() / m.luts_k()
    );

    // measured co-processor GEMM throughput on VIO-shaped layers
    println!("\n-- simulated co-processor on VIO layer shapes (wall time) --");
    let mut rng = Rng::new(42);
    for (name, m_, k_, n_, sel) in [
        ("conv1 im2col (64x19x8)", 64usize, 19usize, 8usize, PrecSel::Posit16x1),
        ("conv2 im2col (16x73x16)", 16, 73, 16, PrecSel::Posit16x1),
        ("fc1 (1x262x64)", 1, 262, 64, PrecSel::Fp4x4),
        ("fc2 (1x64x6)", 1, 64, 6, PrecSel::Posit16x1),
    ] {
        let a = Matrix::random(m_, k_, 0.5, &mut rng);
        let b = Matrix::random(k_, n_, 0.5, &mut rng);
        let mut soc = Soc::new(SocConfig::default());
        let mut cycles = 0u64;
        let ns = common::time_ns(20, || {
            let (_, rep) = soc.gemm(&a, &b, sel, sel.precision()).unwrap();
            cycles = rep.total_cycles;
        });
        println!(
            "  {name:<26} {cycles:>6} sim-cycles ({:>6.1} us @250MHz) | host {:>8.1} us",
            cycles as f64 / 250.0,
            ns / 1e3
        );
    }
}
