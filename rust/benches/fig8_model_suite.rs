//! Fig. 8 — comparative application accuracy for the different AI models
//! used in XR applications, at every precision, against the FP32
//! baseline: the full (model × precision) matrix.
//!
//! Metrics are normalized to "% of FP32 quality" so the three workloads
//! (top-1 accuracy, gaze MSE, VIO t_rmse) print on one scale, like the
//! figure's grouped bars: 100 = FP32, higher is better.

#[path = "common/mod.rs"]
mod common;

use xr_npe::coordinator::scheduler::ModelInstance;
use xr_npe::npe::PrecSel;

fn main() {
    common::require_artifacts();
    println!("== Fig. 8: model suite accuracy vs precision (% of FP32 quality) ==\n");

    // FP32 baselines
    let eff32 = ModelInstance::uniform(
        common::graph_of("effnet"),
        xr_npe::artifacts::weights("effnet").unwrap(),
        PrecSel::Posit16x1,
    ).unwrap();
    let gz32 = ModelInstance::uniform(
        common::graph_of("gaze"),
        xr_npe::artifacts::weights("gaze").unwrap(),
        PrecSel::Posit16x1,
    ).unwrap();
    let vio32 = ModelInstance::uniform(
        common::graph_of("ulvio"),
        xr_npe::artifacts::weights("ulvio").unwrap(),
        PrecSel::Posit16x1,
    ).unwrap();
    let mlp32 = ModelInstance::uniform(
        common::graph_of("mlp"),
        xr_npe::artifacts::weights("mlp").unwrap(),
        PrecSel::Posit16x1,
    ).unwrap();
    let acc32 = common::cls_accuracy_ref(&eff32, 120);
    let mse32 = common::gaze_mse_ref(&gz32, 200);
    let (t32, _) = common::vio_rmse_ref(&vio32, 200);
    let macc32 = common::cls_accuracy_ref(&mlp32, 120);

    println!(
        "{:<22} {:>12} {:>12} {:>12} {:>12}",
        "precision", "EffNet-XR", "GazeNet", "UL-VIO-lite", "MLP-XR"
    );
    println!(
        "{:<22} {:>12.1} {:>12.1} {:>12.1} {:>12.1}",
        "FP32 (baseline)", 100.0, 100.0, 100.0, 100.0
    );

    for sel in [PrecSel::Posit16x1, PrecSel::Posit8x2, PrecSel::Fp4x4, PrecSel::Posit4x4] {
        let eff = ModelInstance::uniform(
            common::graph_of("effnet"),
            common::weights_for("effnet", sel),
            sel,
        ).unwrap();
        let gz = ModelInstance::uniform(
            common::graph_of("gaze"),
            common::weights_for("gaze", sel),
            sel,
        ).unwrap();
        let vio = ModelInstance::uniform(
            common::graph_of("ulvio"),
            common::weights_for("ulvio", sel),
            sel,
        ).unwrap();
        let mlp = ModelInstance::uniform(
            common::graph_of("mlp"),
            common::weights_for("mlp", sel),
            sel,
        ).unwrap();
        let acc = common::cls_accuracy_npe(&eff, 120);
        let mse = common::gaze_mse_npe(&gz, 200);
        let (t, _) = common::vio_rmse_npe(&vio, 200);
        let macc = common::cls_accuracy_npe(&mlp, 120);
        // quality scores: accuracy ratio; error ratios inverted
        println!(
            "{:<22} {:>12.1} {:>12.1} {:>12.1} {:>12.1}",
            format!("{} (QAT, NPE)", sel.precision().name()),
            100.0 * acc / acc32,
            100.0 * (mse32 / mse).min(1.2),
            100.0 * (t32 / t).min(1.2),
            100.0 * macc / macc32
        );
    }

    // software-framework rows for the non-native formats
    for (label, ek, gk) in [
        ("BF16 (sw)", "ptq_bf16", "ptq_bf16"),
        ("FP8-E4M3 (sw)", "ptq_e4m3", "ptq_e4m3"),
        ("FxP4 (sw)", "ptq_fxp4", "ptq_fxp4"),
    ] {
        let ea = common::py_metric("effnet", ek);
        let gm = common::py_metric("gaze", gk);
        if let (Some(ea), Some(gm)) = (ea, gm) {
            let mm = common::py_metric("mlp", ek);
            println!(
                "{:<22} {:>12.1} {:>12.1} {:>12} {:>12}",
                label,
                100.0 * ea / acc32,
                100.0 * (mse32 / gm).min(1.2),
                "-",
                mm.map(|m| format!("{:.1}", 100.0 * m / macc32)).unwrap_or("-".into())
            );
        }
    }
    println!("\n(error metrics inverted and capped at 120% so all columns read");
    println!(" \"% of FP32 quality\"; paper shape: 8-bit ~ FP32 everywhere, QAT-4-bit");
    println!(" close behind, PTQ-4-bit collapses.)");
}
