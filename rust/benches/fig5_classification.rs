//! Fig. 5 — object-classification accuracy per precision vs the FP32
//! baseline and the FxP SoTA ([11]) implementation.
//!
//! Hardware modes (FP4, Posit-4/8/16) run **on the bit-accurate NPE
//! simulator** with QAT weights (the paper's protocol). Non-native
//! formats (BF16/FP8/FxP…) come from the emulated software framework —
//! exactly as in the paper ("quantized algorithmic analysis (emulated
//! software framework)") — i.e. the python QAT/PTQ sweep recorded in
//! `artifacts/metrics.json`.

#[path = "common/mod.rs"]
mod common;

use xr_npe::coordinator::scheduler::ModelInstance;
use xr_npe::npe::PrecSel;

const EVAL_N: usize = 150;

fn main() {
    common::require_artifacts();
    println!("== Fig. 5: EffNet-XR (shapes-10) accuracy vs precision ==\n");
    println!("{:<22} {:>6} {:>10} {:<28}", "precision", "bits", "top-1 %", "path");

    // FP32 baseline (rust reference executor)
    let base = ModelInstance::uniform(
        common::graph_of("effnet"),
        xr_npe::artifacts::weights("effnet").unwrap(),
        PrecSel::Posit16x1,
    ).unwrap();
    let fp32 = common::cls_accuracy_ref(&base, EVAL_N);
    println!("{:<22} {:>6} {:>10.1} {:<28}", "FP32 (baseline)", 32, 100.0 * fp32, "rust f32 reference");

    // software-framework rows (python emulation)
    for (label, bits, key) in [
        ("BF16", 16, "ptq_bf16"),
        ("FP16", 16, "ptq_fp16"),
        ("FP8-E4M3", 8, "ptq_e4m3"),
        ("FP8-E5M2", 8, "ptq_e5m2"),
        ("FxP8 (SoTA [11])", 8, "ptq_fxp8"),
        ("FxP4 (SoTA [11])", 4, "ptq_fxp4"),
    ] {
        if let Some(acc) = common::py_metric("effnet", key) {
            println!(
                "{:<22} {:>6} {:>10.1} {:<28}",
                label, bits, 100.0 * acc, "emulated sw framework (PTQ)"
            );
        }
    }

    // hardware modes on the NPE simulator, QAT weights
    for sel in [PrecSel::Posit16x1, PrecSel::Posit8x2, PrecSel::Fp4x4, PrecSel::Posit4x4] {
        let inst = ModelInstance::uniform(
            common::graph_of("effnet"),
            common::weights_for("effnet", sel),
            sel,
        ).unwrap();
        let acc = common::cls_accuracy_npe(&inst, EVAL_N);
        println!(
            "{:<22} {:>6} {:>10.1} {:<28}",
            format!("{} (QAT)", sel.precision().name()),
            sel.precision().bits(),
            100.0 * acc,
            "bit-accurate NPE sim"
        );
    }

    // PTQ collapse rows (the paper's "sensitive to quantization errors,
    // accuracy loss up to 83%" motivation): 4-bit without QAT
    for sel in [PrecSel::Fp4x4, PrecSel::Posit4x4] {
        let inst = ModelInstance::uniform(
            common::graph_of("effnet"),
            xr_npe::artifacts::weights("effnet").unwrap(),
            sel,
        ).unwrap();
        let acc = common::cls_accuracy_npe(&inst, EVAL_N);
        println!(
            "{:<22} {:>6} {:>10.1} {:<28}",
            format!("{} (PTQ)", sel.precision().name()),
            sel.precision().bits(),
            100.0 * acc,
            "bit-accurate NPE sim"
        );
    }

    println!("\nshape to check (paper): QAT-FP4 ~ BF16/FP8 >> PTQ-4bit; posit8 lossless.");
}
