//! Fig. 6 — precision-adaptive accuracy for the UL-VIO model:
//! translation/rotation RMSE per precision + the §I model-size series
//! (13.5 MB FP32 → 2.42 MB MxP at UL-VIO scale).

#[path = "common/mod.rs"]
mod common;

use xr_npe::coordinator::scheduler::ModelInstance;
use xr_npe::npe::PrecSel;
use xr_npe::quant::PlanBudget;

const FRAMES: usize = 300;

fn main() {
    common::require_artifacts();
    println!("== Fig. 6: UL-VIO precision-adaptive accuracy ({FRAMES} eval frames) ==\n");
    println!(
        "{:<22} {:>9} {:>12} {:>8} {:>8} {:>9}",
        "config", "t_rmse %", "r_rmse deg", "Δt pp", "Δr deg", "size KB"
    );

    let w32 = xr_npe::artifacts::weights("ulvio").unwrap();
    let ref_inst =
        ModelInstance::uniform(common::graph_of("ulvio"), w32.clone(), PrecSel::Posit16x1).unwrap();
    let (t32, r32) = common::vio_rmse_ref(&ref_inst, FRAMES);
    println!(
        "{:<22} {:>9.2} {:>12.4} {:>8} {:>8} {:>9.1}",
        "FP32 (baseline)",
        t32,
        r32,
        "-",
        "-",
        ref_inst.graph.total_params() as f64 * 4.0 / 1e3
    );

    for sel in [PrecSel::Posit16x1, PrecSel::Posit8x2, PrecSel::Fp4x4, PrecSel::Posit4x4] {
        let inst = ModelInstance::uniform(
            common::graph_of("ulvio"),
            common::weights_for("ulvio", sel),
            sel,
        ).unwrap();
        let (t, r) = common::vio_rmse_npe(&inst, FRAMES);
        println!(
            "{:<22} {:>9.2} {:>12.4} {:>+8.2} {:>+8.4} {:>9.1}",
            format!("{} (QAT)", sel.precision().name()),
            t,
            r,
            t - t32,
            r - r32,
            inst.model_bytes() / 1e3
        );
    }

    // the paper's MxP (Posit-8/FP4) trade-off configuration
    let mxp = ModelInstance::planned(
        common::graph_of("ulvio"),
        w32,
        PlanBudget { avg_bits: 6.0 },
        PrecSel::Fp4x4,
        true,
    ).unwrap();
    let (t, r) = common::vio_rmse_npe(&mxp, FRAMES);
    println!(
        "{:<22} {:>9.2} {:>12.4} {:>+8.2} {:>+8.4} {:>9.1}",
        "MxP (FP4/P8/P16 plan)",
        t,
        r,
        t - t32,
        r - r32,
        mxp.model_bytes() / 1e3
    );
    let fmts: Vec<&str> = mxp.plan.per_layer.iter().map(|s| s.precision().name()).collect();
    println!("  MxP plan: {:?} ({:.2} avg bits)", fmts, mxp.plan.avg_bits());

    println!("\n-- §I model-size series at UL-VIO's published parameter count --");
    println!("   paper: 13.5 MB FP32 | 3.4 MB FP8/INT8 | 3.6 MB Posit-8/16 | 2.42 MB MxP");
    for (scheme, mb) in xr_npe::quant::policy::size_report(&[13_500_000 / 4]) {
        println!("   {scheme:<28} {mb:>6.2} MB");
    }

    println!("\nshape to check (paper): FP4 costs ≈ +0.72 pp translation / +0.13 pp rotation;");
    println!("Posit-8/16 near-lossless; MxP sits between FP4 error and Posit-8 cost.");
}
