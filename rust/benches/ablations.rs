//! Ablations over the design choices DESIGN.md calls out — the paper's
//! implicit "why this microarchitecture" arguments, made quantitative:
//!
//! 1. RMMEC reconfigurable pool  vs dedicated per-precision multipliers
//! 2. zero power gating          on vs off (energy on real activations)
//! 3. quire accumulation         vs rounded per-MAC accumulation (accuracy)
//! 4. per-tensor pow-2 scaling   vs raw format range (accuracy)
//! 5. output-stationary          vs weight-stationary dataflow (traffic)

#[path = "common/mod.rs"]
mod common;

use xr_npe::arith::{tables, Precision, Quire, Decoded};
use xr_npe::array::{dataflow_cost, Dataflow};
use xr_npe::coordinator::scheduler::ModelInstance;
use xr_npe::energy::AsicModel;
use xr_npe::npe::PrecSel;
use xr_npe::util::Rng;

fn main() {
    println!("== ablations over XR-NPE design choices ==\n");

    // ---- 1. RMMEC vs dedicated multiplier banks ----
    let ours = AsicModel::xr_npe();
    let base = AsicModel::dedicated_baseline();
    println!("-- 1. RMMEC reconfigurable pool vs dedicated banks --");
    println!("  area:        {:.4} vs {:.4} mm2  ({:.2}x)", ours.area_mm2(), base.area_mm2(),
        base.area_mm2() / ours.area_mm2());
    for sel in PrecSel::ALL {
        println!("  {:?}: {:.2} vs {:.2} pJ/MAC ({:.2}x)", sel,
            ours.energy_per_mac_pj(sel, 0.72, 0.0),
            base.energy_per_mac_baseline_pj(sel),
            base.energy_per_mac_baseline_pj(sel) / ours.energy_per_mac_pj(sel, 0.72, 0.0));
    }

    // ---- 2. zero gating on/off with REAL activation sparsity ----
    println!("\n-- 2. zero power gating (real post-PACT activations) --");
    if common::have_artifacts() {
        let inst = ModelInstance::uniform(
            common::graph_of("effnet"),
            xr_npe::artifacts::weights("effnet").unwrap(),
            PrecSel::Fp4x4,
        ).unwrap();
        let eval = xr_npe::artifacts::eval_shapes().unwrap();
        let mut soc = xr_npe::soc::Soc::new(xr_npe::soc::SocConfig::default());
        for img in eval.images.iter().take(10) {
            let _ = inst.infer(&mut soc, img, &[]).unwrap();
        }
        let stats = &soc.lifetime.array.stats;
        let gating = stats.gating_ratio();
        let e_gated = ours.energy_from_stats_pj(PrecSel::Fp4x4, stats);
        // "no gating": every gated MAC charged as a live one
        let mut no_gate = *stats;
        no_gate.blocks_switched += no_gate.gated_macs
            * xr_npe::npe::rmmec::blocks_for_width(4) as u64 / 2;
        no_gate.gated_macs = 0;
        let e_ungated = ours.energy_from_stats_pj(PrecSel::Fp4x4, &no_gate);
        println!("  measured zero-operand MAC ratio: {:.1}%", 100.0 * gating);
        println!("  energy with gating: {:.1} nJ | without: {:.1} nJ  (saves {:.1}%)",
            e_gated / 1e3, e_ungated / 1e3, 100.0 * (1.0 - e_gated / e_ungated));
    } else {
        println!("  (needs artifacts)");
    }

    // ---- 3. quire vs per-MAC rounding ----
    println!("\n-- 3. quire accumulation vs per-MAC rounded accumulation --");
    let mut rng = Rng::new(31);
    for (prec, k) in [(Precision::Posit8, 256), (Precision::Fp4, 256), (Precision::Posit16, 1024)] {
        let t = tables::table(prec);
        let mut err_quire = 0f64;
        let mut err_round = 0f64;
        let trials = 200;
        for _ in 0..trials {
            let mut q = Quire::new();
            let mut acc_rounded = 0f64;
            let mut exact = 0f64;
            for _ in 0..k {
                let a = t.quantize(rng.normal() * 0.5);
                let b = t.quantize(rng.normal() * 0.5);
                exact += a * b;
                q.add_product(Decoded::from_f64(a), Decoded::from_f64(b));
                // non-quire datapath: round the running sum every MAC
                acc_rounded = t.quantize(acc_rounded + t.quantize(a * b));
            }
            let qv = t.quantize(q.to_f64()); // single final rounding
            err_quire += (qv - exact).abs();
            err_round += (acc_rounded - exact).abs();
        }
        println!("  {:<11} K={k}: |err| quire {:.4} vs rounded {:.4}  ({:.0}x better)",
            prec.name(), err_quire / trials as f64, err_round / trials as f64,
            err_round / err_quire.max(1e-12));
    }

    // ---- 4. pow-2 scaling vs raw range ----
    println!("\n-- 4. per-tensor pow-2 scaling vs raw format range (FP4 weights) --");
    let mut rng = Rng::new(32);
    let w: Vec<f32> = (0..4096).map(|_| (rng.normal() * 0.05) as f32).collect();
    let t = tables::table(Precision::Fp4);
    let s = xr_npe::models::exec::scale_for(&w, Precision::Fp4);
    let (mut e_raw, mut e_scaled, mut zeros_raw) = (0f64, 0f64, 0usize);
    for &x in &w {
        let raw = t.quantize(x as f64);
        let sc = s * t.quantize(x as f64 / s);
        e_raw += (raw - x as f64).powi(2);
        e_scaled += (sc - x as f64).powi(2);
        zeros_raw += (raw == 0.0) as usize;
    }
    println!("  N(0, 0.05) weights: raw kills {:.1}% to zero; RMS err {:.4} vs {:.5} scaled ({:.0}x)",
        100.0 * zeros_raw as f64 / w.len() as f64,
        (e_raw / w.len() as f64).sqrt(),
        (e_scaled / w.len() as f64).sqrt(),
        (e_raw / e_scaled).sqrt());

    // ---- 5. dataflow ----
    println!("\n-- 5. output-stationary vs weight-stationary (8x8, posit16) --");
    println!("  {:<26} {:>10} {:>12} {:>11} {:>13}", "GEMM", "OS cycles", "WS cycles", "WS psum", "WS spills");
    for (m, k, n) in [(64, 64, 64), (32, 1024, 32), (256, 16, 256), (64, 262, 64)] {
        let os = dataflow_cost(Dataflow::OutputStationary, m, k, n, 8, 8, PrecSel::Posit16x1);
        let ws = dataflow_cost(Dataflow::WeightStationary, m, k, n, 8, 8, PrecSel::Posit16x1);
        println!("  {m:>4}x{k:>4}x{n:<4}              {:>10} {:>12} {:>11} {:>13}",
            os.cycles, ws.cycles, ws.psum_words, ws.quire_spill_rounds);
    }
    println!("\n  OS keeps every dot product in one quire (zero spill roundings),");
    println!("  which is why the paper pairs output-stationary with the quire.");
}
