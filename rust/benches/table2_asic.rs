//! Table II — ASIC comparison of SIMD MAC compute engines.
//!
//! Prints the published SoTA rows verbatim next to our *modeled* XR-NPE
//! row (component-analytic 28 nm model driven by the simulator's
//! microarchitecture), then regenerates the paper's headline ratios:
//! 42 % area / 38 % power vs [24] and the 2.85× arithmetic-intensity
//! improvement over the dedicated-datapath baseline. Also times the
//! simulator's MAC hot path (the §Perf L3 metric).

#[path = "common/mod.rs"]
mod common;

use xr_npe::energy::baselines::{TABLE2_BASELINES, TABLE2_THIS_WORK};
use xr_npe::energy::AsicModel;
use xr_npe::npe::{Engine, PrecSel};

fn main() {
    println!("== Table II: ASIC comparison of SIMD MAC compute engines ==\n");
    println!(
        "{:<26} {:>5} {:>6} {:>6} {:>9} {:>8} {:>9}",
        "design", "tech", "V", "GHz", "area mm2", "mW", "pJ/Op"
    );
    for r in TABLE2_BASELINES {
        println!(
            "{:<26} {:>5} {:>6.2} {:>6.2} {:>9.4} {:>8.2} {:>9.2}",
            r.design, r.tech_nm, r.voltage_v, r.freq_ghz, r.area_mm2, r.power_mw, r.pj_per_op
        );
    }
    let m = AsicModel::xr_npe();
    let (area, power, pj) = m.table2_point();
    println!(
        "{:<26} {:>5} {:>6.2} {:>6.2} {:>9.4} {:>8.2} {:>9.2}   <- modeled from simulator structure",
        "This work (modeled)", 28, 0.9, m.freq_ghz, area, power, pj
    );
    let t = TABLE2_THIS_WORK;
    println!(
        "{:<26} {:>5} {:>6.2} {:>6.2} {:>9.4} {:>8.2} {:>9.2}   <- paper's reported row",
        "This work (paper)", t.tech_nm, t.voltage_v, t.freq_ghz, t.area_mm2, t.power_mw, t.pj_per_op
    );

    // headline ratios
    let r24 = TABLE2_BASELINES.iter().find(|r| r.design.contains("[24]")).unwrap();
    println!("\n-- headline claims (paper §III) --");
    println!(
        "  area reduction vs [24]:  {:>5.1}%   (paper: 42%)",
        100.0 * (1.0 - area / r24.area_mm2)
    );
    println!(
        "  power reduction vs [24]: {:>5.1}%   (paper: 38%)",
        100.0 * (1.0 - power / r24.power_mw)
    );
    println!(
        "  arithmetic-intensity gain vs dedicated SIMD baseline: {:.2}x (paper: 2.85x)",
        AsicModel::arith_intensity_gain(0.15)
    );

    // per-mode energy (the quantity Table II summarizes at one point)
    println!("\n-- modeled energy per MAC by prec_sel (dense, 72% block activity) --");
    for sel in PrecSel::ALL {
        println!(
            "  {:<11} {:>6.2} pJ/MAC  ({} lanes -> {:>6.2} pJ/word-op)",
            format!("{sel:?}"),
            m.energy_per_mac_pj(sel, 0.72, 0.0),
            sel.lanes(),
            m.energy_per_mac_pj(sel, 0.72, 0.0) * sel.lanes() as f64
        );
    }

    // simulator hot-path timing (host-side performance, §Perf)
    println!("\n-- simulator hot path (host wall time) --");
    for sel in PrecSel::ALL {
        let mut eng = Engine::new(sel);
        let a: Vec<u16> = (0..256).map(|i| (i * 2654435761u64 as usize) as u16).collect();
        let ns = common::time_ns(2000, || {
            for i in 0..256 {
                eng.mac_word_fused(a[i], a[(i * 7 + 3) % 256]);
            }
        });
        let macs_per_word = sel.lanes() as f64;
        println!(
            "  {:<11} {:>7.1} ns / 256 word-ops  -> {:>6.1} M simulated MACs/s",
            format!("{sel:?}"),
            ns,
            256.0 * macs_per_word / ns * 1e3
        );
    }
}
