//! Shared helpers for the table/figure benches (no criterion in the
//! offline image — a thin timing harness + workload evaluators).

#![allow(dead_code)]

use xr_npe::artifacts;
use xr_npe::coordinator::scheduler::ModelInstance;
use xr_npe::models::{effnet, gaze, mlp, ulvio, ModelGraph};
use xr_npe::npe::PrecSel;
use xr_npe::soc::{Soc, SocConfig};
use xr_npe::util::argmax;
use xr_npe::util::io::TensorMap;
use xr_npe::vio::odometry::{self, RelPose};

/// He-init random weights for benches that exercise the serving
/// machinery without trained artifacts (re-exported from the library so
/// there is exactly one weight-layout builder to maintain).
pub use xr_npe::models::random_weights;

/// Bench smoke mode (`XR_NPE_BENCH_QUICK=1`, used by the CI smoke
/// step): tiny iteration counts and no wall-clock comparative asserts —
/// the run proves the bench executes end to end and still emits its
/// `BENCH_*.json` trajectory artifacts.
pub fn quick() -> bool {
    std::env::var("XR_NPE_BENCH_QUICK").map(|v| v != "0" && !v.is_empty()).unwrap_or(false)
}

/// Measure wall time of `f` over `iters` runs; returns ns/iter.
pub fn time_ns(iters: u32, mut f: impl FnMut()) -> f64 {
    // warmup
    f();
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_nanos() as f64 / iters as f64
}

pub fn fmt_of(sel: PrecSel) -> &'static str {
    match sel {
        PrecSel::Fp4x4 => "fp4",
        PrecSel::Posit4x4 => "posit4",
        PrecSel::Posit8x2 => "posit8",
        PrecSel::Posit16x1 => "posit16",
    }
}

/// Weights for a model at a mode: QAT variant when exported, else FP32.
pub fn weights_for(model: &str, sel: PrecSel) -> TensorMap {
    artifacts::weights_qat(model, fmt_of(sel))
        .or_else(|_| artifacts::weights(model))
        .expect("run `make artifacts` first")
}

pub fn graph_of(model: &str) -> ModelGraph {
    match model {
        "effnet" => effnet::build(),
        "gaze" => gaze::build(),
        "ulvio" => ulvio::build(),
        "mlp" => mlp::build(),
        _ => panic!("unknown model {model}"),
    }
}

/// Classification accuracy of a model instance on the NPE simulator.
/// (`flatten` feeds the image as a flat vector — the MLP workload.)
pub fn cls_accuracy_npe(inst: &ModelInstance, n: usize) -> f64 {
    let eval = artifacts::eval_shapes().expect("eval_shapes");
    let n = n.min(eval.images.len());
    let mut soc = Soc::new(SocConfig::default());
    let mut ok = 0usize;
    for i in 0..n {
        let (out, _) = inst.infer(&mut soc, &eval.images[i], &[]).unwrap();
        ok += (argmax(&out) == eval.labels[i]) as usize;
    }
    ok as f64 / n as f64
}

/// Classification accuracy of the FP32 reference path.
pub fn cls_accuracy_ref(inst: &ModelInstance, n: usize) -> f64 {
    let eval = artifacts::eval_shapes().expect("eval_shapes");
    let n = n.min(eval.images.len());
    let mut ok = 0usize;
    for i in 0..n {
        let out = inst.infer_ref(&eval.images[i], &[]).unwrap();
        ok += (argmax(&out) == eval.labels[i]) as usize;
    }
    ok as f64 / n as f64
}

/// Gaze MSE on the NPE simulator.
pub fn gaze_mse_npe(inst: &ModelInstance, n: usize) -> f64 {
    let eval = artifacts::eval_gaze().expect("eval_gaze");
    let n = n.min(eval.landmarks.len());
    let mut soc = Soc::new(SocConfig::default());
    let mut se = 0f64;
    for i in 0..n {
        let (out, _) = inst.infer(&mut soc, &eval.landmarks[i], &[]).unwrap();
        let t = eval.gaze[i];
        se += ((out[0] - t[0]).powi(2) + (out[1] - t[1]).powi(2)) as f64 / 2.0;
    }
    se / n as f64
}

pub fn gaze_mse_ref(inst: &ModelInstance, n: usize) -> f64 {
    let eval = artifacts::eval_gaze().expect("eval_gaze");
    let n = n.min(eval.landmarks.len());
    let mut se = 0f64;
    for i in 0..n {
        let out = inst.infer_ref(&eval.landmarks[i], &[]).unwrap();
        let t = eval.gaze[i];
        se += ((out[0] - t[0]).powi(2) + (out[1] - t[1]).powi(2)) as f64 / 2.0;
    }
    se / n as f64
}

/// VIO (t_rmse %, r_rmse deg) on the NPE simulator over the eval
/// sequence.
pub fn vio_rmse_npe(inst: &ModelInstance, n: usize) -> (f64, f64) {
    let eval = artifacts::eval_vio().expect("eval_vio");
    let n = n.min(eval.images.len());
    let mut soc = Soc::new(SocConfig::default());
    let mut pred: Vec<RelPose> = Vec::with_capacity(n);
    for i in 0..n {
        let (out, _) = inst.infer(&mut soc, &eval.images[i], &eval.imu[i]).unwrap();
        let mut p = [0f32; 6];
        p.copy_from_slice(&out[..6]);
        pred.push(p);
    }
    let gt = &eval.poses[..n];
    (odometry::rmse_translation(&pred, gt), odometry::rmse_rotation_deg(&pred, gt))
}

pub fn vio_rmse_ref(inst: &ModelInstance, n: usize) -> (f64, f64) {
    let eval = artifacts::eval_vio().expect("eval_vio");
    let n = n.min(eval.images.len());
    let mut pred: Vec<RelPose> = Vec::with_capacity(n);
    for i in 0..n {
        let out = inst.infer_ref(&eval.images[i], &eval.imu[i]).unwrap();
        let mut p = [0f32; 6];
        p.copy_from_slice(&out[..6]);
        pred.push(p);
    }
    let gt = &eval.poses[..n];
    (odometry::rmse_translation(&pred, gt), odometry::rmse_rotation_deg(&pred, gt))
}

/// Pull a python-side (emulated software framework) metric for formats
/// the NPE has no native mode for.
pub fn py_metric(model: &str, key: &str) -> Option<f64> {
    let j = artifacts::metrics_json().ok()?;
    artifacts::metric_f64(&j, model, key)
}

pub fn have_artifacts() -> bool {
    artifacts::dir().join("manifest.json").exists()
}

pub fn require_artifacts() {
    if !have_artifacts() {
        eprintln!("ERROR: artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }
}
