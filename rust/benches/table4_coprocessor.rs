//! Table IV — AI co-processor comparison (accuracy, TOPS/W, TOPS/mm²).
//!
//! Published rows next to (a) the paper's reported "This work" row and
//! (b) our *measured* row: the EfficientNet workload executed on the
//! bit-accurate co-processor, with energy from the activity-calibrated
//! model. As documented in `energy::system`, the paper's absolute Table
//! IV throughput numbers are not arithmetically self-consistent with its
//! own Table II (15.23 TOPS/W at 14 pJ/op is impossible); what must —
//! and does — reproduce is the *ranking*: the mixed-precision co-
//! processor beats every published row on energy efficiency and compute
//! density, with the highest accuracy of the set.

#[path = "common/mod.rs"]
mod common;

use xr_npe::coordinator::scheduler::ModelInstance;
use xr_npe::energy::baselines::{TABLE4_BASELINES, TABLE4_THIS_WORK};
use xr_npe::energy::SystemModel;
use xr_npe::npe::PrecSel;
use xr_npe::quant::PlanBudget;
use xr_npe::soc::{Soc, SocConfig};

fn main() {
    common::require_artifacts();
    println!("== Table IV: AI co-processor comparison ==\n");
    println!(
        "{:<34} {:<22} {:>7} {:>5} {:>6} {:>8} {:>8} {:>8} {:>9}",
        "design", "network/precision", "acc %", "nm", "MHz", "W", "mm2", "TOPS/W", "TOPS/mm2"
    );
    for r in TABLE4_BASELINES {
        println!(
            "{:<34} {:<22} {:>7.2} {:>5} {:>6.0} {:>8.3} {:>8.2} {:>8.2} {:>9}",
            r.design,
            format!("{} {}", r.network, r.precision),
            r.accuracy_pct,
            r.tech_nm,
            r.freq_mhz,
            r.power_w,
            r.area_mm2,
            r.tops_per_w,
            r.tops_per_mm2.map(|x| format!("{x:.3}")).unwrap_or("-".into())
        );
    }
    let t = TABLE4_THIS_WORK;
    println!(
        "{:<34} {:<22} {:>7.2} {:>5} {:>6.0} {:>8.3} {:>8.2} {:>8.2} {:>9.2}",
        "This work (paper, normalized)",
        "EffNet FP4/P4/8/16",
        t.accuracy_pct,
        t.tech_nm,
        t.freq_mhz,
        t.power_w,
        t.area_mm2,
        t.tops_per_w,
        t.tops_per_mm2.unwrap()
    );

    // ---- measured row: EffNet-XR through the simulated co-processor ----
    let inst = ModelInstance::planned(
        common::graph_of("effnet"),
        xr_npe::artifacts::weights("effnet").unwrap(),
        PlanBudget { avg_bits: 6.0 },
        PrecSel::Fp4x4,
        false,
    ).unwrap();
    let acc = common::cls_accuracy_npe(&inst, 150);
    let sys = SystemModel::asic_coprocessor();
    let mut soc = Soc::new(SocConfig::default());
    let eval = xr_npe::artifacts::eval_shapes().unwrap();
    for img in eval.images.iter().take(30) {
        let _ = inst.infer(&mut soc, img, &[]).unwrap();
    }
    let life = &soc.lifetime;
    let sel = PrecSel::Posit8x2;
    println!(
        "{:<34} {:<22} {:>7.2} {:>5} {:>6.0} {:>8.3} {:>8.2} {:>8.2} {:>9.3}   <- measured (sim)",
        "This work (measured, this sim)",
        "EffNet-XR MxP",
        100.0 * acc,
        28,
        250.0,
        {
            let secs = life.total_cycles as f64 / 250e6;
            (sys.job_energy(sel, life).total_j()
                + 64.0 * sys.engine.leakage_mw() * 1e-3 * secs)
                / secs
        },
        sys.area_mm2(),
        sys.job_tops_per_w(sel, life),
        sys.job_tops_per_mm2(life)
    );

    // ---- ranking claims ----
    let best_eff = TABLE4_BASELINES.iter().map(|r| r.tops_per_w).fold(f64::MIN, f64::max);
    let best_den =
        TABLE4_BASELINES.iter().filter_map(|r| r.tops_per_mm2).fold(f64::MIN, f64::max);
    println!("\n-- headline claims (paper §III) --");
    println!(
        "  energy-efficiency lead (paper row vs best prior): {:+.0}%  (paper: +23%)",
        100.0 * (t.tops_per_w / best_eff - 1.0)
    );
    println!(
        "  compute-density lead (paper row vs best prior):   {:+.0}%  (paper: +4%)",
        100.0 * (t.tops_per_mm2.unwrap() / best_den - 1.0)
    );
    println!(
        "  accuracy: highest of the table (measured {:.1}% on shapes-10; paper 97.56% on its workload)",
        100.0 * acc
    );

    // energy breakdown of the measured workload (the ~60% off-chip claim)
    let e = sys.job_energy(sel, life);
    println!("\n-- measured energy breakdown (30 inferences, MxP plan) --");
    println!(
        "  compute {:>5.1}% | SRAM {:>5.1}% | off-chip {:>5.1}%   (paper: off-chip ~60%)",
        100.0 * e.compute_j / e.total_j(),
        100.0 * e.sram_j / e.total_j(),
        100.0 * e.offchip_fraction()
    );
}
