//! §Perf micro-benchmarks — the simulator's hot paths, used to drive the
//! optimization loop (EXPERIMENTS.md §Perf): engine MACs, table decode /
//! quantize, array GEMM, end-to-end model inference.

#[path = "common/mod.rs"]
mod common;

use xr_npe::arith::{tables, Precision};
use xr_npe::array::{ArrayMorph, MatrixArray};
use xr_npe::npe::{Engine, PrecSel};
use xr_npe::util::{Matrix, Rng};

fn main() {
    // XR_NPE_BENCH_QUICK=1 → CI smoke: tiny iteration counts, no
    // wall-clock comparative asserts (bit-identity asserts always run)
    let quick = common::quick();
    let it = |n: u32| if quick { 1 } else { n };
    let mut bench_json: Vec<String> = Vec::new();
    println!("== hot-path micro-benchmarks (host wall time{}) ==\n",
        if quick { ", QUICK smoke mode" } else { "" });

    // 1. engine word-MAC throughput per mode
    println!("-- engine mac_word_fused --");
    let mut rng = Rng::new(1);
    let words: Vec<u16> = (0..4096).map(|_| rng.next_u64() as u16).collect();
    for sel in PrecSel::ALL {
        let mut eng = Engine::new(sel);
        let ns = common::time_ns(it(200), || {
            for i in 0..4096 {
                eng.mac_word_fused(words[i], words[(i * 13 + 7) & 4095]);
            }
        });
        println!(
            "  {:<11} {:>7.2} ns/word-op   {:>7.1} M MACs/s",
            format!("{sel:?}"),
            ns / 4096.0,
            4096.0 * sel.lanes() as f64 / ns * 1e3
        );
    }

    // 2. decode-table quantization throughput
    println!("\n-- table quantize (1024 f32) --");
    let xs: Vec<f32> = (0..1024).map(|_| rng.normal() as f32).collect();
    for p in [Precision::Fp4, Precision::Posit8, Precision::Posit16, Precision::Bf16] {
        let t = tables::table(p);
        let mut acc = 0f64;
        let ns = common::time_ns(it(2000), || {
            for &x in &xs {
                acc += t.quantize(x as f64);
            }
        });
        std::hint::black_box(acc);
        println!("  {:<11} {:>7.2} ns/elem", p.name(), ns / 1024.0);
    }

    // 3. encode throughput (input-processing stage of the DMA pack path)
    println!("\n-- codec encode (1024 f32) --");
    for p in [Precision::Fp4, Precision::Posit8, Precision::Posit16] {
        let mut acc = 0u32;
        let ns = common::time_ns(it(1000), || {
            for &x in &xs {
                acc = acc.wrapping_add(p.encode(x as f64));
            }
        });
        std::hint::black_box(acc);
        println!("  {:<11} {:>7.2} ns/elem", p.name(), ns / 1024.0);
    }

    // 4. array GEMM end to end
    println!("\n-- array GEMM 64x256x64 (bit-accurate) --");
    let a = Matrix::random(64, 256, 0.5, &mut rng);
    let b = Matrix::random(256, 64, 0.5, &mut rng);
    for sel in PrecSel::ALL {
        let mut arr = MatrixArray::new(ArrayMorph::M8x8, sel);
        let mut cycles = 0u64;
        let ns = common::time_ns(it(10), || {
            let (_, rep) = arr.gemm(&a, &b, sel.precision());
            cycles = rep.cycles;
        });
        let macs = 64.0 * 256.0 * 64.0;
        println!(
            "  {:<11} host {:>7.2} ms  {:>6.1} M MACs/s  ({} sim-cycles)",
            format!("{sel:?}"),
            ns / 1e6,
            macs / ns * 1e3,
            cycles
        );
    }

    // 4b. serial vs parallel tile executor on a serving-size GEMM —
    // the parallel path must win on ≥256×256 while staying bit-identical
    // (values, cycles, activity stats, flags)
    println!("\n-- array GEMM 256x256x256: serial vs parallel tile executor --");
    println!("   ({} worker threads)", xr_npe::array::morphable::worker_threads());
    let big_a = Matrix::random(256, 256, 0.5, &mut rng);
    let big_b = Matrix::random(256, 256, 0.5, &mut rng);
    for sel in PrecSel::ALL {
        let mut arr = MatrixArray::new(ArrayMorph::M8x8, sel);
        let ns_serial = common::time_ns(it(3), || {
            std::hint::black_box(arr.gemm_serial(&big_a, &big_b, sel.precision()));
        });
        let ns_par = common::time_ns(it(3), || {
            std::hint::black_box(arr.gemm_parallel(&big_a, &big_b, sel.precision()));
        });
        let (cs, rs) = arr.gemm_serial(&big_a, &big_b, sel.precision());
        let (cp, rp) = arr.gemm_parallel(&big_a, &big_b, sel.precision());
        let identical = cs.data == cp.data
            && rs.cycles == rp.cycles
            && rs.stats == rp.stats
            && rs.overflow == rp.overflow
            && rs.nar == rp.nar;
        println!(
            "  {:<11} serial {:>8.2} ms  parallel {:>8.2} ms  speedup {:>5.2}x  bit-identical: {}",
            format!("{sel:?}"),
            ns_serial / 1e6,
            ns_par / 1e6,
            ns_serial / ns_par,
            identical
        );
        assert!(identical, "parallel executor diverged from serial reference for {sel:?}");
    }

    // 4c. interpreted vs compiled serving path — 64 consecutive gaze
    // inferences. The compiled path replays a pre-lowered program
    // (weights scaled + encoded once at registration, im2col as a
    // gather, ping-pong activation arena); the interpreted path re-does
    // that work per request. Simulated cycles are bit-identical; host
    // wall time is where compile-once pays off.
    println!("\n-- serving path: interpreted vs compiled (64 gaze inferences) --");
    {
        use xr_npe::coordinator::scheduler::ModelInstance;
        use xr_npe::models::gaze;
        use xr_npe::soc::{Soc, SocConfig};

        let g = gaze::build();
        let w = common::random_weights(&g, 17);
        let inst = ModelInstance::uniform(g, w, PrecSel::Posit8x2).unwrap();
        const REQS: usize = 64;
        let inputs: Vec<Vec<f32>> = (0..REQS)
            .map(|i| (0..16).map(|j| ((i * 16 + j) as f32 * 0.07).sin() * 0.5).collect())
            .collect();

        // best-of-5 timings: the min is robust to scheduler noise, and
        // the compiled path strictly does less work per request, so the
        // comparison below is meaningful even on a loaded host
        let reps = if quick { 1 } else { 5 };
        let mut soc_i = Soc::new(SocConfig::default());
        let mut cycles_i = 0u64;
        let ns_interp = (0..reps)
            .map(|_| {
                common::time_ns(it(2), || {
                    cycles_i = 0;
                    for x in &inputs {
                        let (_, rep) = inst.infer_interpret(&mut soc_i, x, &[]).unwrap();
                        cycles_i += rep.total_cycles();
                    }
                })
            })
            .fold(f64::MAX, f64::min);

        let mut soc_c = Soc::new(SocConfig::default());
        inst.warm(&mut soc_c).unwrap(); // registration-time work, off the request path
        let mut cycles_c = 0u64;
        let ns_comp = (0..reps)
            .map(|_| {
                common::time_ns(it(2), || {
                    cycles_c = 0;
                    for x in &inputs {
                        let (_, rep) = inst.infer(&mut soc_c, x, &[]).unwrap();
                        cycles_c += rep.total_cycles();
                    }
                })
            })
            .fold(f64::MAX, f64::min);

        // bit-identity of outputs across the two paths
        for x in inputs.iter().take(4) {
            let (oi, _) = inst.infer_interpret(&mut soc_i, x, &[]).unwrap();
            let (oc, _) = inst.infer(&mut soc_c, x, &[]).unwrap();
            assert_eq!(oi, oc, "compiled path diverged from interpreted");
        }
        assert_eq!(cycles_i, cycles_c, "simulated cycles must be identical");
        let per_req_i = ns_interp / REQS as f64;
        let per_req_c = ns_comp / REQS as f64;
        let speedup = per_req_i / per_req_c;
        println!(
            "  interpreted {:>8.2} µs/req   compiled {:>8.2} µs/req   speedup {:>5.2}x   ({} sim-cycles/req, bit-identical)",
            per_req_i / 1e3,
            per_req_c / 1e3,
            speedup,
            cycles_c / REQS as u64
        );
        assert!(
            quick || speedup > 1.0,
            "compiled repeated inference must be strictly faster than interpreted \
             (interpreted {per_req_i:.0} ns/req vs compiled {per_req_c:.0} ns/req)"
        );
        bench_json.push(format!(
            "{{\"bench\":\"hotpath\",\"section\":\"compiled_vs_interpreted\",\"model\":\"gaze\",\
             \"requests\":{REQS},\"interpreted_ns_per_req\":{per_req_i:.1},\
             \"compiled_ns_per_req\":{per_req_c:.1},\"speedup\":{speedup:.3},\
             \"sim_cycles_per_req\":{}}}",
            cycles_c / REQS as u64
        ));
    }

    // 4d. serving runtime: the PR-2 synchronous scoped-thread fan-out
    // (barrier per batch, thread spawns per batch) vs the PR-3 async
    // runtime (long-lived per-replica workers, submit_batch returns
    // completion handles, consecutive batches pipeline on the queues).
    // Outputs and cycle reports are bit-identical; wall-clock throughput
    // is where the runtime pays off.
    println!("\n-- serving runtime: sync route_batch_fanout vs async submit_batch --");
    {
        use xr_npe::coordinator::batcher::{Batch, Request};
        use xr_npe::coordinator::{ModelInstance, Router, WorkloadKind};
        use xr_npe::soc::SocConfig;

        const REPLICAS: usize = 4;
        const BATCH: usize = 8;
        let n_batches: usize = if quick { 4 } else { 16 };
        let mk_router = || {
            let mut r = Router::new(REPLICAS, SocConfig::default());
            let g = xr_npe::models::gaze::build();
            let w = common::random_weights(&g, 17);
            r.register(WorkloadKind::Gaze, ModelInstance::uniform(g, w, PrecSel::Posit8x2).unwrap())
                .unwrap();
            r
        };
        let batches: Vec<Batch> = (0..n_batches)
            .map(|b| Batch {
                requests: (0..BATCH)
                    .map(|i| Request {
                        id: (b * BATCH + i) as u64,
                        input: (0..16)
                            .map(|j| (((b * BATCH + i) * 16 + j) as f32 * 0.07).sin() * 0.5)
                            .collect(),
                        aux: vec![],
                        arrived: 0,
                    })
                    .collect(),
                released: 0,
            })
            .collect();
        let mut r_sync = mk_router();
        let mut r_async = mk_router();
        // warm pass: every replica warms on demand (default floor is 1)
        // so the timed loops measure steady-state serving
        for r in [&mut r_sync, &mut r_async] {
            for b in &batches {
                r.route_batch(WorkloadKind::Gaze, b).unwrap();
            }
        }
        let reps = if quick { 1 } else { 5 };
        let ns_sync = (0..reps)
            .map(|_| {
                common::time_ns(1, || {
                    for b in &batches {
                        std::hint::black_box(
                            r_sync.route_batch_fanout(WorkloadKind::Gaze, b).unwrap(),
                        );
                    }
                })
            })
            .fold(f64::MAX, f64::min);
        let ns_async = (0..reps)
            .map(|_| {
                common::time_ns(1, || {
                    let handles: Vec<_> = batches
                        .iter()
                        .map(|b| r_async.submit_batch(WorkloadKind::Gaze, b).unwrap())
                        .collect();
                    for comps in handles {
                        for c in comps {
                            std::hint::black_box(Router::resolve(c).unwrap());
                        }
                    }
                })
            })
            .fold(f64::MAX, f64::min);
        // bit-identity across the two paths (same inputs, same weights)
        let want = r_sync.route_batch_fanout(WorkloadKind::Gaze, &batches[0]).unwrap();
        let got = r_async.route_batch(WorkloadKind::Gaze, &batches[0]).unwrap();
        for (w, g) in want.iter().zip(&got) {
            assert_eq!(w.output, g.output, "async serving diverged from sync fan-out");
            assert_eq!(w.report, g.report, "async cycle reports diverged from sync fan-out");
        }
        let reqs = (n_batches * BATCH) as f64;
        let tput_sync = reqs / (ns_sync / 1e9);
        let tput_async = reqs / (ns_async / 1e9);
        println!(
            "  sync fan-out  {:>9.0} req/s   async runtime {:>9.0} req/s   speedup {:>5.2}x   ({} batches x {BATCH} reqs, {REPLICAS} replicas, bit-identical)",
            tput_sync,
            tput_async,
            tput_async / tput_sync,
            n_batches
        );
        assert!(
            quick || tput_async >= tput_sync,
            "async submit_batch throughput ({tput_async:.0} req/s) must be >= the synchronous \
             fan-out ({tput_sync:.0} req/s)"
        );
        bench_json.push(format!(
            "{{\"bench\":\"hotpath\",\"section\":\"async_vs_sync_serving\",\"model\":\"gaze\",\
             \"replicas\":{REPLICAS},\"batches\":{n_batches},\"batch_size\":{BATCH},\
             \"sync_req_per_s\":{tput_sync:.1},\"async_req_per_s\":{tput_async:.1},\
             \"speedup\":{:.3}}}",
            tput_async / tput_sync
        ));
    }

    // 4e. sharded vs whole-model serving — the same MLP on the same
    // 2-replica fleet, once whole-resident (round-robin) and once
    // K-split across both replicas (scatter → partial quires → exact
    // reduce at the coordinator). On a model that fits either way the
    // whole path wins wall-clock (no reduction hop); sharding is the
    // capacity lever for models no replica could host alone — so the
    // assert here is bit-identity, and the JSON records the cost of the
    // reduction hop.
    println!("\n-- serving: whole-resident vs 2-way sharded (32 mlp_xr inferences) --");
    {
        use xr_npe::coordinator::{ModelInstance, Router, WorkloadKind};
        use xr_npe::soc::SocConfig;

        const REQS: usize = 32;
        let g = xr_npe::models::mlp::build();
        let w = common::random_weights(&g, 19);
        let inputs: Vec<Vec<f32>> = (0..REQS)
            .map(|i| (0..256).map(|j| ((i * 256 + j) as f32 * 0.011).sin() * 0.5).collect())
            .collect();
        let mut r_whole = Router::new(2, SocConfig::default());
        r_whole
            .register(
                WorkloadKind::Classify,
                ModelInstance::uniform(g.clone(), w.clone(), PrecSel::Posit8x2).unwrap(),
            )
            .unwrap();
        let mut r_shard = Router::new(2, SocConfig::default());
        r_shard
            .register_sharded(
                WorkloadKind::Classify,
                ModelInstance::uniform(g.clone(), w.clone(), PrecSel::Posit8x2).unwrap(),
                2,
            )
            .unwrap();
        // warm pass + bit-identity: every request must match exactly,
        // and the sharded reports must carry the documented reduction
        // term on top of conserved MAC work
        let mut reduce_cycles = 0u64;
        for x in &inputs {
            let a = r_whole.route(WorkloadKind::Classify, x, &[]).unwrap();
            let b = r_shard.route(WorkloadKind::Classify, x, &[]).unwrap();
            assert_eq!(a.output, b.output, "sharded serving diverged from whole-model");
            assert_eq!(a.report.jobs.array.macs, b.report.jobs.array.macs);
            reduce_cycles = b.report.reduce_cycles;
        }
        let reps = if quick { 1 } else { 5 };
        let mut bench = |r: &mut Router| {
            (0..reps)
                .map(|_| {
                    common::time_ns(1, || {
                        let handles: Vec<_> = inputs
                            .iter()
                            .map(|x| {
                                r.submit(WorkloadKind::Classify, x.clone(), vec![]).unwrap()
                            })
                            .collect();
                        for h in handles {
                            std::hint::black_box(Router::resolve(h).unwrap());
                        }
                    })
                })
                .fold(f64::MAX, f64::min)
        };
        let ns_whole = bench(&mut r_whole);
        let ns_shard = bench(&mut r_shard);
        let tput_whole = REQS as f64 / (ns_whole / 1e9);
        let tput_shard = REQS as f64 / (ns_shard / 1e9);
        println!(
            "  whole-resident {:>9.0} req/s   2-way sharded {:>9.0} req/s   ratio {:>5.2}x   ({} reduce-cycles/req, bit-identical)",
            tput_whole,
            tput_shard,
            tput_shard / tput_whole,
            reduce_cycles
        );
        bench_json.push(format!(
            "{{\"bench\":\"hotpath\",\"section\":\"sharded_vs_whole_serving\",\"model\":\"mlp_xr\",\
             \"replicas\":2,\"shards\":2,\"requests\":{REQS},\
             \"whole_req_per_s\":{tput_whole:.1},\"sharded_req_per_s\":{tput_shard:.1},\
             \"sharded_over_whole\":{:.3},\"reduce_cycles_per_req\":{reduce_cycles}}}",
            tput_shard / tput_whole
        ));
    }

    // 4f. sharded dataflow: per-layer barrier vs streaming pipeline —
    // the same 2-way K-split mlp_xr program driven through
    // `run_sharded` twice on the same warm shard SoCs. The exact quire
    // merge is order-independent, so outputs and reports are
    // bit-identical (asserted, modulo the overlap counter only the
    // streaming flow records); streaming additionally hides incremental
    // merge passes and next-layer weight prefetch behind the slowest
    // shard, so its simulated critical path per request is strictly
    // shorter. The sim_* fields are host-independent and ratcheted by
    // tools/bench_gate.rs.
    println!("\n-- sharded dataflow: per-layer barrier vs streaming (2-way mlp_xr) --");
    {
        use std::sync::Arc;
        use xr_npe::models::{
            compile, shard, ExecReport, PartialOut, ShardChannel, ShardFlow, ShardedModel,
        };
        use xr_npe::quant::PrecisionPlan;
        use xr_npe::soc::{JobReport, Soc, SocConfig};

        // synchronous inline channel: dispatch runs the shard GEMM on
        // the spot, wait_any hands completions back FIFO — the flow
        // difference under test is purely the engine's dispatch window
        // and timing model, not host concurrency
        struct SyncChannel<'a> {
            shards: &'a [Arc<ShardedModel>],
            socs: &'a mut [Soc],
            ready: Vec<(usize, PartialOut, JobReport)>,
        }
        impl ShardChannel for SyncChannel<'_> {
            fn dispatch(
                &mut self,
                si: usize,
                gi: usize,
                a: Matrix,
                s_a: f64,
            ) -> anyhow::Result<()> {
                let (part, rep) = self.shards[si].run_gemm(&mut self.socs[si], gi, &a, s_a)?;
                self.ready.push((si, part, rep));
                Ok(())
            }
            fn wait_any(&mut self) -> anyhow::Result<(usize, PartialOut, JobReport)> {
                if self.ready.is_empty() {
                    anyhow::bail!("wait_any with nothing in flight");
                }
                Ok(self.ready.remove(0))
            }
        }

        let reqs: usize = if quick { 4 } else { 32 };
        let g = xr_npe::models::mlp::build();
        let w = common::random_weights(&g, 29);
        let plan = PrecisionPlan::uniform(PrecSel::Posit8x2, &g.compute_layer_params());
        let c = compile(&g, &w, &plan).unwrap();
        let shards: Vec<Arc<ShardedModel>> =
            shard(&c, 2).unwrap().into_iter().map(Arc::new).collect();
        let mut socs: Vec<Soc> = (0..2).map(|_| Soc::new(SocConfig::default())).collect();
        let inputs: Vec<Vec<f32>> = (0..reqs)
            .map(|i| (0..256).map(|j| ((i * 256 + j) as f32 * 0.013).sin() * 0.5).collect())
            .collect();
        let run_all = |socs: &mut [Soc], flow: ShardFlow| -> Vec<(Vec<f32>, ExecReport)> {
            inputs
                .iter()
                .map(|x| {
                    let mut ch =
                        SyncChannel { shards: &shards, socs: &mut *socs, ready: Vec::new() };
                    c.run_sharded(&shards, x, &[], &mut ch, flow).unwrap()
                })
                .collect()
        };
        let barrier = run_all(&mut socs, ShardFlow::Barrier);
        let streaming = run_all(&mut socs, ShardFlow::Streaming);

        let (mut b_total, mut s_crit, mut hidden, mut prefetch, mut stall, mut reduce) =
            (0u64, 0u64, 0u64, 0u64, 0u64, 0u64);
        for ((bo, br), (so, sr)) in barrier.iter().zip(&streaming) {
            assert_eq!(bo, so, "streaming dataflow diverged from the barrier reference");
            assert_eq!(br.overlap_cycles_hidden, 0, "barrier flow must not record overlap");
            assert_eq!(br.axi_stall_cycles, 0, "barrier flow must not record AXI stall");
            let mut scrub = sr.clone();
            scrub.overlap_cycles_hidden = 0;
            scrub.axi_stall_cycles = 0;
            scrub.prefetch_hidden_cycles = 0;
            assert_eq!(&scrub, br, "streaming report drifted beyond the overlap counters");
            assert!(
                sr.axi_stall_cycles + sr.overlap_cycles_hidden <= sr.total_cycles(),
                "stall + hidden must stay within the request total"
            );
            b_total += br.total_cycles();
            s_crit += sr.total_cycles() - sr.overlap_cycles_hidden;
            hidden += sr.overlap_cycles_hidden;
            prefetch += sr.prefetch_hidden_cycles;
            stall += sr.axi_stall_cycles;
            reduce += sr.reduce_cycles;
        }
        assert!(
            s_crit < b_total,
            "streaming critical path ({s_crit} sim-cycles) must be strictly shorter than \
             the per-layer barrier ({b_total} sim-cycles)"
        );
        let n = reqs as u64;
        println!(
            "  barrier {:>8} sim-cycles/req   streaming {:>8} sim-cycles/req   hidden {:>6} cycles/req   stalled {:>5} cycles/req   ({:.1}% shorter critical path, bit-identical)",
            b_total / n,
            s_crit / n,
            hidden / n,
            stall / n,
            100.0 * hidden as f64 / b_total as f64
        );
        bench_json.push(format!(
            "{{\"bench\":\"hotpath\",\"section\":\"sharded_streaming_vs_barrier\",\
             \"model\":\"mlp_xr\",\"shards\":2,\"requests\":{reqs},\
             \"sim_cycles_per_round\":{},\"sim_reduce_cycles_per_round\":{},\
             \"sim_overlap_hidden_per_round\":{},\"sim_prefetch_hidden_per_round\":{},\
             \"sim_axi_stall_per_round\":{},\"barrier_sim_cycles_per_round\":{}}}",
            s_crit / n,
            reduce / n,
            hidden / n,
            prefetch / n,
            stall / n,
            b_total / n
        ));
    }

    // 4g. multi-model residency: a 3-model catalog (~187 KiB combined
    // warm footprint) rotating through one replica under a 96 KiB
    // resident-DRAM budget — every dispatch to a cold model LRU-evicts
    // and re-warms. The assert is bit-identity vs fresh single-model
    // routers; the JSONL records the simulated rotation counters and
    // cycle cost (host-independent, gated by tools/bench_gate.rs) plus
    // informational wall-clock throughput. The gated fields are
    // per-round, so quick and full runs agree.
    println!("\n-- serving: DRAM-budgeted catalog rotation (3 models, 1 replica, 96 KiB) --");
    {
        use xr_npe::coordinator::{ModelInstance, Router, RuntimeConfig, WorkloadKind};
        use xr_npe::soc::SocConfig;

        const BUDGET: usize = 96 * 1024;
        let kinds = [WorkloadKind::Classify, WorkloadKind::Vio, WorkloadKind::Gaze];
        let graphs = [
            xr_npe::models::effnet::build(),
            xr_npe::models::ulvio::build(),
            xr_npe::models::gaze::build(),
        ];
        let weights: Vec<_> =
            graphs.iter().enumerate().map(|(i, g)| common::random_weights(g, 23 + i as u64)).collect();
        let rt = RuntimeConfig { resident_budget: Some(BUDGET), ..Default::default() };
        let mut catalog = Router::with_runtime(1, SocConfig::default(), rt);
        let mut refs: Vec<Router> = Vec::new();
        for ((kind, g), w) in kinds.iter().zip(&graphs).zip(&weights) {
            catalog
                .register(*kind, ModelInstance::uniform(g.clone(), w.clone(), PrecSel::Posit8x2).unwrap())
                .unwrap();
            let mut r = Router::new(1, SocConfig::default());
            r.register(*kind, ModelInstance::uniform(g.clone(), w.clone(), PrecSel::Posit8x2).unwrap())
                .unwrap();
            refs.push(r);
        }
        let m0 = catalog.runtime_metrics();
        let rounds: usize = if quick { 2 } else { 6 };
        let mut sim_cycles_total = 0u64;
        let t0 = std::time::Instant::now();
        for round in 0..rounds {
            for (ki, kind) in kinds.iter().enumerate() {
                let g = &graphs[ki];
                let input: Vec<f32> = (0..g.input.numel())
                    .map(|j| ((round * 131 + j) as f32 * 0.017).sin() * 0.4)
                    .collect();
                let aux: Vec<f32> = if *kind == WorkloadKind::Vio { vec![0.05; 6] } else { vec![] };
                let got = catalog.route(*kind, &input, &aux).unwrap();
                let want = refs[ki].route(*kind, &input, &aux).unwrap();
                assert_eq!(
                    got.output, want.output,
                    "catalog rotation diverged from a fresh single-model fleet ({kind:?})"
                );
                sim_cycles_total += got.report.total_cycles();
            }
        }
        let wall_ns = t0.elapsed().as_nanos() as f64;
        let m = catalog.runtime_metrics();
        let evictions = m.evictions - m0.evictions;
        let cold_warms = m.cold_warms - m0.cold_warms;
        assert!(evictions > 0, "a catalog over budget must rotate");
        assert!(m.resident_high_water <= BUDGET as u64, "budget must hold");
        let reqs = (rounds * kinds.len()) as f64;
        println!(
            "  {} rounds x 3 kinds: {:>7.0} req/s host   {} evictions, {} cold warms, high water {} B (budget {} B, bit-identical)",
            rounds,
            reqs / (wall_ns / 1e9),
            evictions,
            cold_warms,
            m.resident_high_water,
            BUDGET
        );
        bench_json.push(format!(
            "{{\"bench\":\"hotpath\",\"section\":\"catalog_rotation\",\"models\":3,\
             \"replicas\":1,\"resident_budget\":{BUDGET},\"rounds\":{rounds},\
             \"sim_cycles_per_round\":{},\"sim_evictions_per_round\":{},\
             \"sim_resident_high_water\":{},\"req_per_s\":{:.1}}}",
            sim_cycles_total / rounds as u64,
            evictions / rounds as u64,
            m.resident_high_water,
            reqs / (wall_ns / 1e9)
        ));
    }

    // 4h. load-adaptive precision ladder: one logical gaze model served
    // as three co-resident precision rungs (high-fidelity → balanced →
    // FP4-heavy) on a 2-replica fleet. A seeded queue-depth trace drives
    // `LadderPolicy` through an idle → burst → idle cycle; the policy is
    // a pure function of simulated service cycles and the seeded depths,
    // so the switch sequence, per-request rung stamps and the whole
    // fleet snapshot replay byte-identically — asserted by running the
    // trace twice. The JSONL records the gated `sim_ladder_*` keys plus
    // the per-request cycle cost at the top and bottom rungs; all of
    // them are simulated, so quick and full runs agree.
    println!("\n-- serving: load-adaptive precision ladder (gaze, 2 replicas, seeded burst) --");
    {
        use std::collections::BTreeMap;
        use xr_npe::coordinator::{ModelInstance, Router, WorkloadKind};
        use xr_npe::serve::{LadderConfig, LadderPolicy};
        use xr_npe::soc::SocConfig;

        let depths = [0usize, 16, 16, 16, 16, 16, 0, 0, 0, 0, 0, 0, 0];
        let run = || {
            let mut r = Router::new(2, SocConfig::default());
            let g = xr_npe::models::gaze::build();
            let w = common::random_weights(&g, 140);
            r.register_ladder(
                WorkloadKind::Gaze,
                ModelInstance::ladder(g, w, PrecSel::Fp4x4, true).unwrap(),
            )
            .unwrap();
            let mut policy = LadderPolicy::new(LadderConfig {
                shift_down: 50_000,
                shift_up: 5_000,
                window: 64,
                dwell_ticks: 2,
                idle_patience: 2,
            });
            // prime the service-cost window on the high-fidelity rung
            for q in 0..4 {
                r.route(WorkloadKind::Gaze, &vec![0.02 * q as f32; 16], &[]).unwrap();
            }
            r.quiesce();
            let mut seq = Vec::new();
            let mut cycles_by_rung = [0u64; 3];
            let mut reqs_by_rung = [0u64; 3];
            for &d in &depths {
                let rung = r.ladder_tick_with(&mut policy, d);
                let res = r.route(WorkloadKind::Gaze, &vec![0.05; 16], &[]).unwrap();
                assert_eq!(res.report.rung as usize, rung, "stamp must match the decided rung");
                cycles_by_rung[rung] += res.report.total_cycles();
                reqs_by_rung[rung] += 1;
                seq.push(rung);
                r.quiesce();
            }
            let snap = xr_npe::obs::snapshot(&r);
            (seq, cycles_by_rung, reqs_by_rung, snap)
        };
        let (seq, cycles, nreqs, snap) = run();
        let again = run();
        assert_eq!(
            (&seq, &cycles, &nreqs, &snap),
            (&again.0, &again.1, &again.2, &again.3),
            "the ladder trace must replay byte-identically"
        );
        assert_eq!(seq.iter().max().copied(), Some(2), "burst must reach the FP4-heavy rung: {seq:?}");
        assert_eq!(seq.last().copied(), Some(0), "idle must recover high fidelity: {seq:?}");
        let per_req = |r: usize| if nreqs[r] == 0 { 0 } else { cycles[r] / nreqs[r] };
        println!(
            "  trace {:?}\n  rung0 {:>6} sim-cycles/req   rung2 {:>6} sim-cycles/req   {} switches (deterministic, bit-identical replay)",
            seq,
            per_req(0),
            per_req(2),
            snap["sim_ladder_switches"],
        );
        let mut gated: BTreeMap<String, u64> = snap
            .iter()
            .filter(|(k, _)| k.starts_with("sim_ladder_"))
            .map(|(k, &v)| (k.clone(), v))
            .collect();
        gated.insert("sim_rung0_cycles_per_req".into(), per_req(0));
        gated.insert("sim_rung2_cycles_per_req".into(), per_req(2));
        bench_json
            .push(xr_npe::obs::to_bench_jsonl("precision_ladder", &gated).trim_end().to_string());
    }

    // trajectory artifacts: one JSON object per line (JSONL)
    let json = bench_json.join("\n") + "\n";
    if let Err(e) = std::fs::write("BENCH_hotpath.json", &json) {
        eprintln!("  (could not write BENCH_hotpath.json: {e})");
    } else {
        println!("\nwrote BENCH_hotpath.json ({} sections)", bench_json.len());
    }

    // 5. full model inference on the co-processor (if artifacts exist)
    if common::have_artifacts() {
        println!("\n-- EffNet-XR inference on the simulated co-processor --");
        let inst = xr_npe::coordinator::scheduler::ModelInstance::uniform(
            common::graph_of("effnet"),
            xr_npe::artifacts::weights("effnet").unwrap(),
            PrecSel::Posit8x2,
        ).unwrap();
        let eval = xr_npe::artifacts::eval_shapes().unwrap();
        let mut soc = xr_npe::soc::Soc::new(xr_npe::soc::SocConfig::default());
        let ns = common::time_ns(20, || {
            let _ = inst.infer(&mut soc, &eval.images[0], &[]).unwrap();
        });
        println!(
            "  posit8       host {:>7.2} ms/inference  ({:.0} sim-inferences/s/host-core)",
            ns / 1e6,
            1e9 / ns
        );
    }
}
