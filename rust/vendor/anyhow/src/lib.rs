//! Minimal, dependency-free drop-in for the `anyhow` error crate.
//!
//! The build image has no crates.io registry access, so the workspace
//! vendors the small subset of `anyhow` the simulator actually uses:
//! [`Error`], [`Result`], the [`Context`] extension trait, and the
//! `anyhow!` / `bail!` / `ensure!` macros. Semantics match the real crate
//! where it matters here:
//!
//! * `Display` shows the outermost message; `{:#}` shows the whole
//!   context chain joined with `": "`.
//! * `Debug` shows the message plus a `Caused by:` chain (what a
//!   `main() -> anyhow::Result<()>` prints on error).
//! * Any `std::error::Error + Send + Sync + 'static` converts via `?`.

use std::error::Error as StdError;
use std::fmt;

/// `Result` specialized to [`Error`] (same default type parameter trick
/// as the real crate, so `anyhow::Result<T>` and `Result<T, E>` both
/// work).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A context-carrying error chain. Outermost message first.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// Messages from outermost to innermost.
    pub fn chain(&self) -> Vec<&str> {
        let mut v = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            v.push(e.msg.as_str());
            cur = e.source.as_deref();
        }
        v
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain().last().copied().unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if f.alternate() {
            let mut cur = self.source.as_deref();
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        let mut cur = self.source.as_deref();
        if cur.is_some() {
            f.write_str("\n\nCaused by:")?;
        }
        while let Some(e) = cur {
            write!(f, "\n    {}", e.msg)?;
            cur = e.source.as_deref();
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        fn build(e: &dyn StdError) -> Error {
            Error { msg: e.to_string(), source: e.source().map(|s| Box::new(build(s))) }
        }
        build(&e)
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)` to
/// `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an [`Error`] if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: `", stringify!($cond), "`")));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fallible(ok: bool) -> Result<u32> {
        ensure!(ok, "flag was {}", ok);
        Ok(7)
    }

    #[test]
    fn ensure_and_bail_flow() {
        assert_eq!(fallible(true).unwrap(), 7);
        let e = fallible(false).unwrap_err();
        assert_eq!(e.to_string(), "flag was false");
    }

    #[test]
    fn context_chain_display() {
        let inner: Result<()> = Err(anyhow!("root cause"));
        let outer = inner.map_err(|e| e.context("while serving")).unwrap_err();
        assert_eq!(outer.to_string(), "while serving");
        assert_eq!(format!("{outer:#}"), "while serving: root cause");
        assert_eq!(outer.root_cause(), "root cause");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing thing").unwrap_err();
        assert!(e.to_string().contains("missing thing"));
    }

    #[test]
    fn std_error_converts_with_source_chain() {
        let io = std::io::Error::new(std::io::ErrorKind::Other, "disk on fire");
        let e: Error = io.into();
        assert!(e.to_string().contains("disk on fire"));
        let parse: Result<i32> = "x".parse::<i32>().map_err(Error::from);
        assert!(parse.is_err());
    }

    #[test]
    fn debug_shows_caused_by() {
        let e = Error::msg("root").context("mid").context("top");
        let dbg = format!("{e:?}");
        assert!(dbg.starts_with("top"));
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("root"));
    }
}
