//! Deterministic perf-trajectory gate for CI.
//!
//! The benches emit JSONL trajectory records (`BENCH_*.json`) mixing
//! host wall-clock numbers (noisy, machine-dependent) with **simulated**
//! cycle/byte fields that are exact functions of the code — the same on
//! every host. This gate compares only the simulated fields of the
//! current run against the committed `BENCH_baseline.json` ratchet and
//! fails CI when any of them regress (more cycles / more bytes).
//! Wall-clock fields stay informational.
//!
//! A *simulated* field is one whose key starts with `sim_` or contains
//! `cycles`/`bytes`. Records pair up by their `section` field.
//!
//! Bootstrapping: a baseline value of `null` (or a missing key/section)
//! means "ratchet not yet armed" — the gate adopts the observed value,
//! writes the filled-in file to `BENCH_baseline.proposed.json` (uploaded
//! as a CI artifact) and passes; committing that file over
//! `BENCH_baseline.json` arms the gate. Improvements print a reminder to
//! ratchet the baseline down the same way.
//!
//! ```bash
//! cargo run --release --bin bench_gate -- BENCH_baseline.json BENCH_hotpath.json
//! ```
//!
//! Exit codes: 0 = no regression, 1 = regression, 2 = usage/parse error.
//! The gate only compares like-for-like runs: CI runs the benches in
//! `XR_NPE_BENCH_QUICK=1` mode, so the committed baseline records
//! quick-mode values (the gated fields are chosen to be identical in
//! quick and full runs).

use std::collections::HashMap;
use std::fmt::Write as _;

/// Flat JSON scalar.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Num(f64),
    Str(String),
    Bool(bool),
    Null,
}

/// One JSONL record, key order preserved for faithful re-serialization.
pub type Record = Vec<(String, Value)>;

fn get<'a>(r: &'a Record, key: &str) -> Option<&'a Value> {
    r.iter().find(|(k, _)| k == key).map(|(_, v)| v)
}

fn set(r: &mut Record, key: &str, v: Value) {
    match r.iter_mut().find(|(k, _)| k == key) {
        Some(slot) => slot.1 = v,
        None => r.push((key.to_string(), v)),
    }
}

/// Parse one flat JSON object (strings, numbers, booleans, null).
pub fn parse_record(line: &str) -> Result<Record, String> {
    let mut cs = line.trim().chars().peekable();
    let err = |m: &str| format!("{m} in: {line}");
    if cs.next() != Some('{') {
        return Err(err("expected '{'"));
    }
    let mut rec = Record::new();
    loop {
        while cs.peek().is_some_and(|c| c.is_whitespace()) {
            cs.next();
        }
        match cs.peek() {
            Some('}') => {
                cs.next();
                break;
            }
            Some('"') => {}
            _ => return Err(err("expected key or '}'")),
        }
        let key = parse_string(&mut cs).ok_or_else(|| err("bad key string"))?;
        while cs.peek().is_some_and(|c| c.is_whitespace()) {
            cs.next();
        }
        if cs.next() != Some(':') {
            return Err(err("expected ':'"));
        }
        while cs.peek().is_some_and(|c| c.is_whitespace()) {
            cs.next();
        }
        let val = match cs.peek() {
            Some('"') => Value::Str(parse_string(&mut cs).ok_or_else(|| err("bad string"))?),
            Some('t') => {
                for want in "true".chars() {
                    if cs.next() != Some(want) {
                        return Err(err("bad literal"));
                    }
                }
                Value::Bool(true)
            }
            Some('f') => {
                for want in "false".chars() {
                    if cs.next() != Some(want) {
                        return Err(err("bad literal"));
                    }
                }
                Value::Bool(false)
            }
            Some('n') => {
                for want in "null".chars() {
                    if cs.next() != Some(want) {
                        return Err(err("bad literal"));
                    }
                }
                Value::Null
            }
            _ => {
                let mut num = String::new();
                while cs
                    .peek()
                    .is_some_and(|&c| c.is_ascii_digit() || "+-.eE".contains(c))
                {
                    num.push(cs.next().unwrap());
                }
                Value::Num(num.parse::<f64>().map_err(|_| err("bad number"))?)
            }
        };
        rec.push((key, val));
        while cs.peek().is_some_and(|c| c.is_whitespace()) {
            cs.next();
        }
        match cs.next() {
            Some(',') => continue,
            Some('}') => break,
            _ => return Err(err("expected ',' or '}'")),
        }
    }
    Ok(rec)
}

fn parse_string(cs: &mut std::iter::Peekable<std::str::Chars>) -> Option<String> {
    if cs.next() != Some('"') {
        return None;
    }
    let mut out = String::new();
    loop {
        match cs.next()? {
            '"' => return Some(out),
            '\\' => match cs.next()? {
                'n' => out.push('\n'),
                't' => out.push('\t'),
                'u' => {
                    let hex: String = (0..4).filter_map(|_| cs.next()).collect();
                    let code = u32::from_str_radix(&hex, 16).ok()?;
                    out.push(char::from_u32(code)?);
                }
                c => out.push(c),
            },
            c => out.push(c),
        }
    }
}

/// Parse a JSONL file (one flat object per non-empty line).
pub fn parse_jsonl(text: &str) -> Result<Vec<Record>, String> {
    text.lines()
        .map(str::trim)
        .filter(|l| !l.is_empty())
        .map(parse_record)
        .collect()
}

/// Is `key` a host-independent simulated metric (gated) rather than a
/// wall-clock one (informational)?
pub fn is_sim_key(key: &str) -> bool {
    key.starts_with("sim_") || key.contains("cycles") || key.contains("bytes")
}

/// Is the ratchet armed at all? A baseline whose simulated fields are
/// all `null` (or absent) gates nothing — every comparison falls into
/// the bootstrap path and the run trivially passes. That state is easy
/// to ship by accident (e.g. committing the template instead of the
/// proposed file), so `main` warns about it loudly.
pub fn baseline_armed(baseline: &[Record]) -> bool {
    baseline
        .iter()
        .flat_map(|rec| rec.iter())
        .any(|(k, v)| is_sim_key(k) && matches!(v, Value::Num(_)))
}

/// Gate outcome.
#[derive(Debug, Default)]
pub struct GateReport {
    /// `section.key: baseline -> current` lines for every regression.
    pub regressions: Vec<String>,
    /// Improvements (current strictly better) — ratchet candidates.
    pub improvements: Vec<String>,
    /// Un-armed fields adopted from the current run.
    pub pending: Vec<String>,
    /// Baseline records with pending values filled in (commit to arm).
    pub proposed: Vec<Record>,
}

/// Compare the simulated fields of `current` against `baseline`.
pub fn gate(baseline: &[Record], current: &[Record]) -> GateReport {
    let mut report = GateReport { proposed: baseline.to_vec(), ..Default::default() };
    let mut index: HashMap<String, usize> = HashMap::new();
    for (i, rec) in report.proposed.iter().enumerate() {
        if let Some(Value::Str(s)) = get(rec, "section") {
            index.insert(s.clone(), i);
        }
    }
    for cur in current {
        let Some(Value::Str(section)) = get(cur, "section") else { continue };
        let slot = match index.get(section) {
            Some(&i) => i,
            None => {
                // new bench section: adopt its sim fields wholesale
                let mut rec = Record::new();
                set(&mut rec, "section", Value::Str(section.clone()));
                report.proposed.push(rec);
                let i = report.proposed.len() - 1;
                index.insert(section.clone(), i);
                i
            }
        };
        for (key, val) in cur {
            if !is_sim_key(key) {
                continue;
            }
            let Value::Num(c) = val else { continue };
            match get(&report.proposed[slot], key) {
                Some(Value::Num(b)) => {
                    if *c > *b {
                        report
                            .regressions
                            .push(format!("{section}.{key}: baseline {b} -> current {c}"));
                    } else if *c < *b {
                        report
                            .improvements
                            .push(format!("{section}.{key}: baseline {b} -> current {c}"));
                    }
                }
                Some(Value::Null) | None => {
                    report.pending.push(format!("{section}.{key} = {c}"));
                    set(&mut report.proposed[slot], key, Value::Num(*c));
                }
                _ => {}
            }
        }
    }
    report
}

/// Serialize records back to JSONL.
pub fn to_jsonl(records: &[Record]) -> String {
    let mut out = String::new();
    for rec in records {
        out.push('{');
        for (i, (k, v)) in rec.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let _ = write!(out, "\"{k}\":");
            match v {
                Value::Num(n) => {
                    if n.fract() == 0.0 && n.abs() < 9.0e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{n}");
                    }
                }
                Value::Str(s) => {
                    let _ = write!(out, "\"{s}\"");
                }
                Value::Bool(b) => {
                    let _ = write!(out, "{b}");
                }
                Value::Null => out.push_str("null"),
            }
        }
        out.push_str("}\n");
    }
    out
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.len() < 2 {
        eprintln!("usage: bench_gate <BENCH_baseline.json> <BENCH_current.json>...");
        std::process::exit(2);
    }
    let read = |path: &str| -> String {
        std::fs::read_to_string(path).unwrap_or_else(|e| {
            eprintln!("bench_gate: cannot read {path}: {e}");
            std::process::exit(2);
        })
    };
    let parse = |path: &str| -> Vec<Record> {
        parse_jsonl(&read(path)).unwrap_or_else(|e| {
            eprintln!("bench_gate: {path}: {e}");
            std::process::exit(2);
        })
    };
    let baseline = parse(&args[0]);
    let mut current = Vec::new();
    for path in &args[1..] {
        current.extend(parse(path));
    }
    let report = gate(&baseline, &current);
    if !baseline_armed(&baseline) {
        println!(
            "WARNING  ratchet un-armed (baseline null): {} gates no simulated metrics — \
             commit BENCH_baseline.proposed.json to arm it",
            args[0]
        );
    }
    for line in &report.pending {
        println!("PENDING  {line}   (ratchet not yet armed)");
    }
    for line in &report.improvements {
        println!("IMPROVED {line}   (consider ratcheting the baseline)");
    }
    for line in &report.regressions {
        println!("REGRESSED {line}");
    }
    if !report.pending.is_empty() {
        let proposed = to_jsonl(&report.proposed);
        match std::fs::write("BENCH_baseline.proposed.json", &proposed) {
            Ok(()) => println!(
                "wrote BENCH_baseline.proposed.json — commit it over BENCH_baseline.json \
                 to arm the ratchet for {} field(s)",
                report.pending.len()
            ),
            Err(e) => eprintln!("bench_gate: cannot write proposed baseline: {e}"),
        }
    }
    if report.regressions.is_empty() {
        println!(
            "bench gate OK: {} section(s) checked, {} pending, {} improved",
            current.len(),
            report.pending.len(),
            report.improvements.len()
        );
    } else {
        eprintln!(
            "bench gate FAILED: {} simulated metric(s) regressed vs BENCH_baseline.json",
            report.regressions.len()
        );
        std::process::exit(1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base(cycles: f64) -> Vec<Record> {
        parse_jsonl(&format!(
            "{{\"section\":\"compiled_vs_interpreted\",\"sim_cycles_per_req\":{cycles}}}\n\
             {{\"section\":\"sharded_vs_whole_serving\",\"reduce_cycles_per_req\":500}}\n"
        ))
        .unwrap()
    }

    fn cur(cycles: f64) -> Vec<Record> {
        parse_jsonl(&format!(
            "{{\"bench\":\"hotpath\",\"section\":\"compiled_vs_interpreted\",\
             \"interpreted_ns_per_req\":99.5,\"speedup\":3.1,\
             \"sim_cycles_per_req\":{cycles}}}\n\
             {{\"section\":\"sharded_vs_whole_serving\",\"reduce_cycles_per_req\":500}}\n"
        ))
        .unwrap()
    }

    #[test]
    fn parses_flat_jsonl() {
        let recs = parse_jsonl(
            "{\"a\":1,\"b\":\"x\",\"c\":true,\"d\":null,\"e\":-2.5e3}\n\n{\"f\":0}\n",
        )
        .unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(get(&recs[0], "a"), Some(&Value::Num(1.0)));
        assert_eq!(get(&recs[0], "b"), Some(&Value::Str("x".into())));
        assert_eq!(get(&recs[0], "c"), Some(&Value::Bool(true)));
        assert_eq!(get(&recs[0], "d"), Some(&Value::Null));
        assert_eq!(get(&recs[0], "e"), Some(&Value::Num(-2500.0)));
        assert!(parse_jsonl("{\"unterminated\":").is_err());
    }

    #[test]
    fn sim_key_predicate() {
        assert!(is_sim_key("sim_cycles_per_req"));
        assert!(is_sim_key("reduce_cycles_per_req"));
        assert!(is_sim_key("sim_resident_high_water"));
        assert!(is_sim_key("fetch_bytes"));
        assert!(!is_sim_key("speedup"));
        assert!(!is_sim_key("interpreted_ns_per_req"));
        assert!(!is_sim_key("whole_req_per_s"));
    }

    #[test]
    fn matching_run_passes() {
        let r = gate(&base(1000.0), &cur(1000.0));
        assert!(r.regressions.is_empty() && r.pending.is_empty() && r.improvements.is_empty());
    }

    #[test]
    fn gate_fails_on_seeded_regression() {
        // the acceptance check: perturb one baseline number below the
        // observed value — the gate must flag exactly that field
        let r = gate(&base(999.0), &cur(1000.0));
        assert_eq!(r.regressions.len(), 1, "{:?}", r.regressions);
        assert!(r.regressions[0].contains("sim_cycles_per_req"));
        assert!(r.regressions[0].contains("999"));
        // ...and reverting the perturbation passes again
        assert!(gate(&base(1000.0), &cur(1000.0)).regressions.is_empty());
    }

    #[test]
    fn improvement_passes_and_suggests_ratchet() {
        let r = gate(&base(1001.0), &cur(1000.0));
        assert!(r.regressions.is_empty());
        assert_eq!(r.improvements.len(), 1);
    }

    #[test]
    fn null_baseline_adopts_and_proposes() {
        let baseline =
            parse_jsonl("{\"section\":\"compiled_vs_interpreted\",\"sim_cycles_per_req\":null}\n")
                .unwrap();
        let r = gate(&baseline, &cur(1234.0));
        assert!(r.regressions.is_empty());
        // sim_cycles adopted from null; the sharded section (absent from
        // the baseline) is adopted wholesale
        assert_eq!(r.pending.len(), 2, "{:?}", r.pending);
        let txt = to_jsonl(&r.proposed);
        assert!(txt.contains("\"sim_cycles_per_req\":1234"), "{txt}");
        assert!(txt.contains("\"reduce_cycles_per_req\":500"), "{txt}");
        // the proposed file is a fully-armed baseline
        let rearmed = parse_jsonl(&txt).unwrap();
        assert!(gate(&rearmed, &cur(1234.0)).pending.is_empty());
    }

    #[test]
    fn armed_detection_tracks_sim_fields() {
        // a fully-null baseline gates nothing: un-armed
        let nulls = parse_jsonl(
            "{\"section\":\"compiled_vs_interpreted\",\"sim_cycles_per_req\":null}\n\
             {\"section\":\"sharded_vs_whole_serving\",\"reduce_cycles_per_req\":null}\n",
        )
        .unwrap();
        assert!(!baseline_armed(&nulls));
        // wall-clock numbers alone don't arm it either
        let wall = parse_jsonl("{\"section\":\"s\",\"speedup\":3.1,\"sim_cycles_per_req\":null}\n")
            .unwrap();
        assert!(!baseline_armed(&wall));
        // one concrete simulated number arms the gate
        assert!(baseline_armed(&base(1000.0)));
        let partial = parse_jsonl(
            "{\"section\":\"a\",\"sim_cycles_per_req\":null}\n{\"section\":\"b\",\"fetch_bytes\":7}\n",
        )
        .unwrap();
        assert!(baseline_armed(&partial));
        assert!(!baseline_armed(&[]));
    }

    #[test]
    fn wall_clock_fields_are_ignored() {
        // host-speed fields differ wildly between runs: never gated
        let mut c = cur(1000.0);
        set(&mut c[0], "interpreted_ns_per_req", Value::Num(1.0e9));
        set(&mut c[0], "speedup", Value::Num(0.01));
        assert!(gate(&base(1000.0), &c).regressions.is_empty());
    }

    #[test]
    fn registry_snapshot_records_gate_like_any_bench_section() {
        // the obs::to_bench_jsonl shape: one flat record, every key
        // following the simulated-field convention — the gate must arm
        // on it, pass a matching run, and catch a seeded cycle regression
        let line = "{\"section\":\"trace_snapshot\",\"sim_completed_jobs\":8,\
                    \"sim_lifetime_cycles_r0\":52000,\"sim_lifetime_cycles_r1\":48000,\
                    \"sim_trace_events\":64,\"sim_trace_dropped\":0}\n";
        let baseline = parse_jsonl(line).unwrap();
        assert!(baseline_armed(&baseline));
        for key in baseline[0].iter().map(|(k, _)| k).filter(|k| *k != "section") {
            assert!(is_sim_key(key), "registry key `{key}` must be gateable");
        }
        let same = parse_jsonl(line).unwrap();
        let rep = gate(&baseline, &same);
        assert!(rep.regressions.is_empty(), "{:?}", rep.regressions);
        let mut worse = parse_jsonl(line).unwrap();
        set(&mut worse[0], "sim_lifetime_cycles_r0", Value::Num(60000.0));
        let rep = gate(&baseline, &worse);
        assert_eq!(rep.regressions.len(), 1, "{:?}", rep.regressions);
        assert!(rep.regressions[0].contains("sim_lifetime_cycles_r0"));
    }
}
