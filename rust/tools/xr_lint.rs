//! Repo-invariant lint for the XR-NPE source tree.
//!
//! A deliberately small, std-only token linter that enforces the
//! invariants the simulator's determinism and the serving stack's
//! robustness depend on — things `clippy` has no opinion about:
//!
//! * **wall-clock** — `Instant::now` / `SystemTime` must not appear in
//!   library code. Simulated time lives in `service_cycles`; host time
//!   sneaking into the model path breaks replay determinism.
//! * **no-panic** — `.unwrap()` / `.expect(` / `panic!(` / `todo!(` /
//!   `unimplemented!(` are banned in non-test library code. The serving
//!   stack holds locks across calls; a stray panic poisons them.
//!   (`unreachable!`, `assert!`/`debug_assert!` and `.unwrap_or*` are
//!   fine: the first documents impossibility, the rest don't panic on
//!   the data path.)
//! * **spawn-fence** — in `serve/` and `coordinator/` files, every
//!   thread `spawn(` must have a `catch_unwind` fence nearby (the task
//!   body or the spawn site), so a worker panic surfaces as an error
//!   instead of a deadlocked queue.
//! * **lock-order** — within one function, the first `device_lock`
//!   acquisition must precede the first `residency_lock`/`shared_lock`
//!   when both appear. This is the static shadow of the runtime
//!   lockdep in `util::lockdep` (Device < Residency < Shared).
//! * **doc-hygiene** — every file under the repo's `docs/` tree must be
//!   named in `README.md`, so the documentation index can't silently
//!   rot as docs are added. Runs only in the default (argument-less)
//!   invocation, which is what CI uses.
//!
//! Sites where the invariant is deliberately broken carry an inline
//! waiver on the same line or the line above:
//!
//! ```text
//! // xr_lint: allow(no-panic) -- reason the panic is unreachable/intended
//! ```
//!
//! The reason is mandatory; a bare `allow` is itself reported.
//!
//! Findings print as JSONL on stdout. Exit codes: 0 = clean,
//! 1 = findings, 2 = usage/IO error.
//!
//! ```bash
//! cargo run --release --bin xr_lint            # lints src/
//! cargo run --release --bin xr_lint -- path/   # lints another tree
//! ```

use std::path::{Path, PathBuf};

/// One lint finding.
#[derive(Debug, Clone, PartialEq)]
pub struct Finding {
    /// Path of the offending file, as reported.
    pub file: String,
    /// 1-indexed line.
    pub line: usize,
    /// Which rule fired (`wall-clock`, `no-panic`, …).
    pub rule: &'static str,
    /// The matched token or path.
    pub token: String,
    /// Human-readable explanation.
    pub message: String,
}

/// Source text with literals and comments blanked out (newlines kept, so
/// line/column arithmetic still works), plus the per-line comment text
/// (where waivers live).
struct Masked {
    lines: Vec<String>,
    comments: Vec<String>,
}

/// Strip string/char literals (including raw strings `r#"…"#` and byte
/// strings) and comments (line + nested block) from `src`. Literal and
/// comment bytes become spaces; everything else passes through.
fn mask(src: &str) -> Masked {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut out = String::with_capacity(n);
    let mut comments = vec![String::new()];
    let mut line = 0usize;
    let mut i = 0usize;

    let mut newline = |out: &mut String, comments: &mut Vec<String>, line: &mut usize| {
        out.push('\n');
        comments.push(String::new());
        *line += 1;
    };

    while i < n {
        let c = chars[i];
        let next = if i + 1 < n { Some(chars[i + 1]) } else { None };
        // raw (byte) string start: r"…", r#"…"#, br#"…"# — only when the
        // `r` is not the tail of an identifier
        let prev_ident = i > 0 && (chars[i - 1].is_alphanumeric() || chars[i - 1] == '_');
        let raw_at = if !prev_ident && c == 'b' && next == Some('r') { Some(i + 1) }
                     else if !prev_ident && c == 'r' { Some(i) }
                     else { None };
        if let Some(r_pos) = raw_at {
            let mut j = r_pos + 1;
            let mut hashes = 0usize;
            while j < n && chars[j] == '#' {
                hashes += 1;
                j += 1;
            }
            if j < n && chars[j] == '"' {
                // it is a raw string: blank from i through the closing "##…
                j += 1;
                loop {
                    if j >= n {
                        break;
                    }
                    if chars[j] == '"'
                        && j + hashes < n
                        && chars[j + 1..j + 1 + hashes].iter().all(|&h| h == '#')
                    {
                        j += 1 + hashes;
                        break;
                    }
                    j += 1;
                }
                for &ch in &chars[i..j.min(n)] {
                    if ch == '\n' {
                        newline(&mut out, &mut comments, &mut line);
                    } else {
                        out.push(' ');
                    }
                }
                i = j;
                continue;
            }
        }
        match c {
            '\n' => {
                newline(&mut out, &mut comments, &mut line);
                i += 1;
            }
            '/' if next == Some('/') => {
                while i < n && chars[i] != '\n' {
                    comments[line].push(chars[i]);
                    out.push(' ');
                    i += 1;
                }
            }
            '/' if next == Some('*') => {
                let mut depth = 1usize;
                out.push_str("  ");
                i += 2;
                while i < n && depth > 0 {
                    if chars[i] == '/' && i + 1 < n && chars[i + 1] == '*' {
                        depth += 1;
                        out.push_str("  ");
                        i += 2;
                    } else if chars[i] == '*' && i + 1 < n && chars[i + 1] == '/' {
                        depth -= 1;
                        out.push_str("  ");
                        i += 2;
                    } else if chars[i] == '\n' {
                        newline(&mut out, &mut comments, &mut line);
                        i += 1;
                    } else {
                        comments[line].push(chars[i]);
                        out.push(' ');
                        i += 1;
                    }
                }
            }
            '"' => {
                out.push(' ');
                i += 1;
                while i < n {
                    if chars[i] == '\\' {
                        out.push(' ');
                        i += 1;
                        if i < n {
                            if chars[i] == '\n' {
                                newline(&mut out, &mut comments, &mut line);
                            } else {
                                out.push(' ');
                            }
                            i += 1;
                        }
                        continue;
                    }
                    if chars[i] == '"' {
                        out.push(' ');
                        i += 1;
                        break;
                    }
                    if chars[i] == '\n' {
                        newline(&mut out, &mut comments, &mut line);
                    } else {
                        out.push(' ');
                    }
                    i += 1;
                }
            }
            '\'' => {
                // char literal vs lifetime: '\…' or 'x' are literals;
                // anything else ('a in generics) is a lifetime
                if next == Some('\\') {
                    out.push_str("  ");
                    i += 2;
                    while i < n && chars[i] != '\'' {
                        out.push(' ');
                        i += 1;
                    }
                    if i < n {
                        out.push(' ');
                        i += 1;
                    }
                } else if i + 2 < n && chars[i + 2] == '\'' {
                    out.push_str("   ");
                    i += 3;
                } else {
                    out.push('\'');
                    i += 1;
                }
            }
            _ => {
                out.push(c);
                i += 1;
            }
        }
    }
    Masked { lines: out.lines().map(str::to_string).collect(), comments }
}

/// Does `line` contain `word` with identifier boundaries on both sides?
fn contains_word(line: &str, word: &str) -> bool {
    find_word(line, word).is_some()
}

/// Byte offset of the first identifier-bounded occurrence of `word`.
fn find_word(line: &str, word: &str) -> Option<usize> {
    let bytes = line.as_bytes();
    let is_ident = |b: u8| b.is_ascii_alphanumeric() || b == b'_';
    let mut from = 0;
    while let Some(rel) = line[from..].find(word) {
        let at = from + rel;
        let left_ok = at == 0 || !is_ident(bytes[at - 1]);
        let end = at + word.len();
        let right_ok = end >= bytes.len() || !is_ident(bytes[end]);
        if left_ok && right_ok {
            return Some(at);
        }
        from = at + 1;
    }
    None
}

/// Waivers parsed from the comment text: `(line, rule)` pairs plus
/// malformed-waiver findings.
fn parse_waivers(file: &str, comments: &[String]) -> (Vec<(usize, String)>, Vec<Finding>) {
    let mut waivers = Vec::new();
    let mut bad = Vec::new();
    for (ln, text) in comments.iter().enumerate() {
        let Some(at) = text.find("xr_lint: allow(") else { continue };
        let rest = &text[at + "xr_lint: allow(".len()..];
        let Some(close) = rest.find(')') else {
            bad.push(Finding {
                file: file.to_string(),
                line: ln + 1,
                rule: "waiver-syntax",
                token: text.trim().to_string(),
                message: "unterminated xr_lint: allow(rule)".to_string(),
            });
            continue;
        };
        let rule = rest[..close].trim().to_string();
        let tail = &rest[close + 1..];
        let reason_ok = tail
            .find("--")
            .map(|d| !tail[d + 2..].trim().is_empty())
            .unwrap_or(false);
        if !reason_ok {
            bad.push(Finding {
                file: file.to_string(),
                line: ln + 1,
                rule: "waiver-syntax",
                token: text.trim().to_string(),
                message: "waiver needs a reason: xr_lint: allow(rule) -- why".to_string(),
            });
            continue;
        }
        waivers.push((ln, rule));
    }
    (waivers, bad)
}

/// Per-line "inside a `#[cfg(test)] mod`" flags, via brace depth on the
/// masked text.
fn test_regions(lines: &[String]) -> Vec<bool> {
    let mut skip = vec![false; lines.len()];
    let mut depth = 0i64;
    let mut skip_floor: Option<i64> = None;
    let mut pending_attr = false;
    for (ln, l) in lines.iter().enumerate() {
        if skip_floor.is_some() {
            skip[ln] = true;
        }
        let trimmed = l.trim();
        let is_test_attr = trimmed.contains("#[cfg(") && contains_word(trimmed, "test");
        if skip_floor.is_none() && is_test_attr {
            pending_attr = true;
            skip[ln] = true;
        }
        let starts_mod = pending_attr && contains_word(l, "mod");
        for ch in l.chars() {
            match ch {
                '{' => {
                    if starts_mod && skip_floor.is_none() {
                        skip_floor = Some(depth);
                        pending_attr = false;
                        skip[ln] = true;
                    }
                    depth += 1;
                }
                '}' => {
                    depth -= 1;
                    if skip_floor.is_some_and(|f| depth <= f) {
                        skip_floor = None;
                        skip[ln] = true;
                    }
                }
                _ => {}
            }
        }
        // the attr stuck to a non-mod item (e.g. a cfg-gated fn): treat
        // the attr as consumed so a later unrelated `mod` isn't skipped
        if pending_attr && !starts_mod && !trimmed.is_empty() && !trimmed.starts_with("#[") {
            pending_attr = false;
        }
    }
    skip
}

/// One acquired-lock event inside a function body.
#[derive(Debug, Clone, Copy, PartialEq)]
enum LockKind {
    Device,
    Other,
}

/// Lint one file's source text. `file` is the path reported in findings
/// and also drives the path-scoped rules (spawn-fence).
pub fn lint_source(file: &str, src: &str) -> Vec<Finding> {
    let masked = mask(src);
    let (waivers, mut findings) = parse_waivers(file, &masked.comments);
    let skip = test_regions(&masked.lines);
    let waived = |line: usize, rule: &str| {
        waivers
            .iter()
            .any(|(wl, wr)| wr == rule && (*wl == line || *wl + 1 == line))
    };
    let fenced_dir = {
        let p = file.replace('\\', "/");
        p.contains("/serve/") || p.contains("/coordinator/")
            || p.starts_with("serve/") || p.starts_with("coordinator/")
    };
    let has_catch_unwind_near = |ln: usize| {
        let lo = ln.saturating_sub(40);
        let hi = (ln + 60).min(masked.lines.len().saturating_sub(1));
        masked.lines[lo..=hi].iter().any(|l| contains_word(l, "catch_unwind"))
    };

    // lock-order state: stack of (fn base depth, first-event kinds seen)
    let mut depth = 0i64;
    let mut fn_stack: Vec<(i64, Vec<LockKind>)> = Vec::new();
    let mut awaiting_body: Option<i64> = None;

    const PANIC_TOKENS: [&str; 5] = [".unwrap()", ".expect(", "panic!(", "todo!(", "unimplemented!("];

    for (ln, l) in masked.lines.iter().enumerate() {
        if !skip[ln] {
            // wall-clock
            for tok in ["Instant::now", "SystemTime"] {
                if l.contains(tok) && !waived(ln, "wall-clock") {
                    findings.push(Finding {
                        file: file.to_string(),
                        line: ln + 1,
                        rule: "wall-clock",
                        token: tok.to_string(),
                        message: "host wall-clock in library code; simulated time lives in service_cycles"
                            .to_string(),
                    });
                }
            }
            // no-panic
            for tok in PANIC_TOKENS {
                if l.contains(tok) && !waived(ln, "no-panic") {
                    findings.push(Finding {
                        file: file.to_string(),
                        line: ln + 1,
                        rule: "no-panic",
                        token: tok.to_string(),
                        message: "panicking call in non-test library code".to_string(),
                    });
                }
            }
            // spawn-fence
            if fenced_dir && find_word(l, "spawn").is_some_and(|at| l[at + "spawn".len()..].starts_with('('))
                && !waived(ln, "spawn-fence")
                && !has_catch_unwind_near(ln)
            {
                findings.push(Finding {
                    file: file.to_string(),
                    line: ln + 1,
                    rule: "spawn-fence",
                    token: "spawn(".to_string(),
                    message: "thread spawn without a catch_unwind fence nearby".to_string(),
                });
            }
            // lock-order events (record in declaration order on the line)
            if let Some((_, events)) = fn_stack.last_mut() {
                let mut hits: Vec<(usize, LockKind)> = Vec::new();
                if let Some(at) = find_word(l, "device_lock") {
                    hits.push((at, LockKind::Device));
                }
                for name in ["residency_lock", "shared_lock"] {
                    if let Some(at) = find_word(l, name) {
                        hits.push((at, LockKind::Other));
                    }
                }
                hits.sort_by_key(|&(at, _)| at);
                for (_, kind) in hits {
                    events.push(kind);
                }
                if events.first() == Some(&LockKind::Other)
                    && events.contains(&LockKind::Device)
                    && !waived(ln, "lock-order")
                {
                    findings.push(Finding {
                        file: file.to_string(),
                        line: ln + 1,
                        rule: "lock-order",
                        token: "device_lock".to_string(),
                        message: "device_lock acquired after residency/shared lock (Device < Residency < Shared)"
                            .to_string(),
                    });
                    // report once per function
                    events.clear();
                }
            }
            if contains_word(l, "fn") && awaiting_body.is_none() {
                awaiting_body = Some(depth);
            }
        }
        // depth bookkeeping runs on every line, skipped or not, so the
        // fn/test-region spans stay consistent
        for ch in l.chars() {
            match ch {
                '{' => {
                    if let Some(base) = awaiting_body.take() {
                        fn_stack.push((base, Vec::new()));
                    }
                    depth += 1;
                }
                '}' => {
                    depth -= 1;
                    if fn_stack.last().is_some_and(|&(base, _)| depth <= base) {
                        fn_stack.pop();
                    }
                }
                ';' => {
                    // trait method declaration: `fn f(...) -> T;` has no body
                    if awaiting_body.is_some_and(|base| base == depth) {
                        awaiting_body = None;
                    }
                }
                _ => {}
            }
        }
    }
    findings
}

/// doc-hygiene: every path in `doc_paths` (repo-relative, e.g.
/// `docs/ARCHITECTURE.md`) must appear verbatim in the README text.
pub fn lint_doc_tree(readme: &str, doc_paths: &[String]) -> Vec<Finding> {
    let mut findings = Vec::new();
    for p in doc_paths {
        if !readme.contains(p.as_str()) {
            findings.push(Finding {
                file: p.clone(),
                line: 1,
                rule: "doc-hygiene",
                token: p.clone(),
                message: "docs/ file not named in README.md; link it from the Documentation section"
                    .to_string(),
            });
        }
    }
    findings
}

/// Recursively collect every file under `root`, sorted for stable output.
fn collect_files(root: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let entries =
        std::fs::read_dir(root).map_err(|e| format!("read_dir {}: {e}", root.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| format!("read_dir {}: {e}", root.display()))?;
        let path = entry.path();
        if path.is_dir() {
            collect_files(&path, out)?;
        } else {
            out.push(path);
        }
    }
    out.sort();
    Ok(())
}

/// Locate `README.md` + `docs/` relative to the working directory (the
/// tool runs from `rust/` in CI and from the repo root locally) and
/// apply the doc-hygiene rule. A repo without a docs tree is clean.
fn doc_hygiene_findings() -> Result<Vec<Finding>, String> {
    for base in ["..", "."] {
        let readme = Path::new(base).join("README.md");
        let docs = Path::new(base).join("docs");
        if !(readme.is_file() && docs.is_dir()) {
            continue;
        }
        let text = std::fs::read_to_string(&readme)
            .map_err(|e| format!("read {}: {e}", readme.display()))?;
        let mut files = Vec::new();
        collect_files(&docs, &mut files)?;
        let rels: Vec<String> = files
            .iter()
            .map(|p| {
                let s = p.to_string_lossy().replace('\\', "/");
                // report repo-relative "docs/…" regardless of which base matched
                match s.find("docs/") {
                    Some(at) => s[at..].to_string(),
                    None => s,
                }
            })
            .collect();
        return Ok(lint_doc_tree(&text, &rels));
    }
    Ok(Vec::new())
}

/// Recursively collect `*.rs` files under `root`, sorted for stable output.
fn collect_rs(root: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let entries =
        std::fs::read_dir(root).map_err(|e| format!("read_dir {}: {e}", root.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| format!("read_dir {}: {e}", root.display()))?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|x| x == "rs") {
            out.push(path);
        }
    }
    out.sort();
    Ok(())
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn run(root: &Path) -> Result<Vec<Finding>, String> {
    let mut files = Vec::new();
    collect_rs(root, &mut files)?;
    let mut findings = Vec::new();
    for path in &files {
        let src = std::fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        let rel = path.to_string_lossy().replace('\\', "/");
        findings.extend(lint_source(&rel, &src));
    }
    Ok(findings)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let root = match args.first() {
        Some(p) => PathBuf::from(p),
        // default: the library tree, whether invoked from rust/ or the
        // repo root
        None if Path::new("src").is_dir() => PathBuf::from("src"),
        None => PathBuf::from("rust/src"),
    };
    let mut findings = match run(&root) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("xr_lint: {e}");
            std::process::exit(2);
        }
    };
    // repo-level rules only apply in the default invocation — an explicit
    // path argument means "lint that tree", nothing else
    if args.is_empty() {
        match doc_hygiene_findings() {
            Ok(f) => findings.extend(f),
            Err(e) => {
                eprintln!("xr_lint: {e}");
                std::process::exit(2);
            }
        }
    }
    for f in &findings {
        println!(
            "{{\"file\":\"{}\",\"line\":{},\"rule\":\"{}\",\"token\":\"{}\",\"message\":\"{}\"}}",
            json_escape(&f.file),
            f.line,
            f.rule,
            json_escape(&f.token),
            json_escape(&f.message)
        );
    }
    if findings.is_empty() {
        eprintln!("xr_lint: clean ({})", root.display());
    } else {
        eprintln!("xr_lint: {} finding(s) in {}", findings.len(), root.display());
        std::process::exit(1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules(findings: &[Finding]) -> Vec<&'static str> {
        findings.iter().map(|f| f.rule).collect()
    }

    #[test]
    fn no_panic_fires_on_each_token() {
        let src = "fn f(x: Option<u32>) -> u32 {\n\
                   \x20   let a = x.unwrap();\n\
                   \x20   let b = x.expect(\"msg\");\n\
                   \x20   if a == 0 { panic!(\"zero\"); }\n\
                   \x20   todo!(\"later\");\n\
                   }\n";
        let f = lint_source("src/lib.rs", src);
        assert_eq!(rules(&f), vec!["no-panic"; 4], "{f:?}");
        assert_eq!(f[0].line, 2);
    }

    #[test]
    fn non_panicking_lookalikes_are_fine() {
        let src = "fn f(x: Option<u32>) -> u32 {\n\
                   \x20   let a = x.unwrap_or(3);\n\
                   \x20   let b = x.unwrap_or_else(|| 4);\n\
                   \x20   assert!(a + b > 0);\n\
                   \x20   match a { 0..=7 => a, _ => unreachable!() }\n\
                   }\n";
        assert!(lint_source("src/lib.rs", src).is_empty());
    }

    #[test]
    fn waiver_on_line_above_or_same_line_suppresses() {
        let above = "fn f(x: Option<u32>) -> u32 {\n\
                     \x20   // xr_lint: allow(no-panic) -- contract: caller checked\n\
                     \x20   x.unwrap()\n\
                     }\n";
        assert!(lint_source("src/lib.rs", above).is_empty());
        let inline = "fn f(x: Option<u32>) -> u32 {\n\
                      \x20   x.unwrap() // xr_lint: allow(no-panic) -- contract: caller checked\n\
                      }\n";
        assert!(lint_source("src/lib.rs", inline).is_empty());
    }

    #[test]
    fn waiver_without_reason_is_reported_and_does_not_suppress() {
        let src = "fn f(x: Option<u32>) -> u32 {\n\
                   \x20   // xr_lint: allow(no-panic)\n\
                   \x20   x.unwrap()\n\
                   }\n";
        let f = lint_source("src/lib.rs", src);
        assert!(f.iter().any(|x| x.rule == "waiver-syntax"), "{f:?}");
        assert!(f.iter().any(|x| x.rule == "no-panic"), "{f:?}");
    }

    #[test]
    fn wall_clock_fires_outside_tests_only() {
        let src = "fn f() {\n\
                   \x20   let t = std::time::Instant::now();\n\
                   }\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                   \x20   fn g() { let t = std::time::Instant::now(); }\n\
                   }\n";
        let f = lint_source("src/lib.rs", src);
        assert_eq!(rules(&f), vec!["wall-clock"]);
        assert_eq!(f[0].line, 2);
    }

    #[test]
    fn wall_clock_rule_covers_the_obs_tracing_module() {
        // the deterministic-tracing contract: obs/ stamps spans with
        // simulated cycles only, so a wall-clock read sneaking into the
        // tracer must fail the lint like any other library code — and
        // the sanctioned hosttime boundary needs its explicit waiver
        let src = "fn stamp() -> u64 {\n\
                   \x20   let t = std::time::Instant::now();\n\
                   \x20   0\n\
                   }\n";
        let f = lint_source("src/obs/sink.rs", src);
        assert_eq!(rules(&f), vec!["wall-clock"], "{f:?}");
        assert_eq!(f[0].line, 2);
        let waived = "fn stamp() {\n\
                      \x20   // xr_lint: allow(wall-clock) -- sole sanctioned host-time boundary\n\
                      \x20   let t = std::time::Instant::now();\n\
                      }\n";
        assert!(lint_source("src/util/hosttime.rs", waived).is_empty());
    }

    #[test]
    fn tokens_inside_strings_and_comments_are_masked() {
        let src = "fn f() -> &'static str {\n\
                   \x20   // this mentions .unwrap() and Instant::now in prose\n\
                   \x20   \"a string with .unwrap() and panic!( inside\"\n\
                   }\n";
        assert!(lint_source("src/lib.rs", src).is_empty());
    }

    #[test]
    fn raw_string_braces_do_not_derail_region_tracking() {
        // the TEST_HLO hazard: a raw string full of unbalanced braces and
        // banned tokens, inside a test mod, followed by library code
        let src = "#[cfg(test)]\n\
                   mod tests {\n\
                   \x20   const HLO: &str = r#\"ENTRY main { x.unwrap() } } } {\"#;\n\
                   \x20   fn g(x: Option<u32>) { x.unwrap(); }\n\
                   }\n\
                   fn library(x: Option<u32>) -> u32 {\n\
                   \x20   x.unwrap()\n\
                   }\n";
        let f = lint_source("src/lib.rs", src);
        assert_eq!(rules(&f), vec!["no-panic"], "{f:?}");
        assert_eq!(f[0].line, 7);
    }

    #[test]
    fn cfg_all_test_regions_are_skipped() {
        let src = "#[cfg(all(test, feature = \"pjrt\"))]\n\
                   mod tests {\n\
                   \x20   fn g(x: Option<u32>) { x.unwrap(); }\n\
                   }\n";
        assert!(lint_source("src/lib.rs", src).is_empty());
    }

    #[test]
    fn spawn_fence_scoped_to_serving_dirs() {
        let bare = "fn f() {\n\
                    \x20   std::thread::spawn(|| {});\n\
                    }\n";
        let f = lint_source("src/serve/worker.rs", bare);
        assert_eq!(rules(&f), vec!["spawn-fence"]);
        // same code outside serve/ and coordinator/: no finding
        assert!(lint_source("src/array/morphable.rs", bare).is_empty());
        // a catch_unwind fence within the window satisfies the rule
        let fenced = "fn f() {\n\
                      \x20   let job = || { let _ = std::panic::catch_unwind(|| {}); };\n\
                      \x20   std::thread::spawn(job);\n\
                      }\n";
        assert!(lint_source("src/coordinator/router.rs", fenced).is_empty());
    }

    #[test]
    fn lock_order_inversion_is_flagged() {
        let bad = "fn f(&self) {\n\
                   \x20   let mgr = residency_lock(&self.residency[0]);\n\
                   \x20   let soc = device_lock(self.runtime.soc(0));\n\
                   }\n";
        let f = lint_source("src/coordinator/router.rs", bad);
        assert_eq!(rules(&f), vec!["lock-order"], "{f:?}");
        let good = "fn f(&self) {\n\
                    \x20   let soc = device_lock(self.runtime.soc(0));\n\
                    \x20   let mgr = residency_lock(&self.residency[0]);\n\
                    }\n";
        assert!(lint_source("src/coordinator/router.rs", good).is_empty());
        // single-class functions never trip the rule
        let single = "fn f(&self) {\n\
                      \x20   let mgr = shared_lock(&self.shared);\n\
                      }\n";
        assert!(lint_source("src/serve/worker.rs", single).is_empty());
    }

    #[test]
    fn lock_order_is_per_function() {
        // an Other-first function followed by a Device-using function
        // must not cross-contaminate
        let src = "fn a(&self) {\n\
                   \x20   let mgr = residency_lock(&self.residency[0]);\n\
                   }\n\
                   fn b(&self) {\n\
                   \x20   let soc = device_lock(self.runtime.soc(0));\n\
                   }\n";
        assert!(lint_source("src/coordinator/router.rs", src).is_empty());
    }

    #[test]
    fn doc_hygiene_flags_unlinked_docs_files() {
        let readme = "# repo\nSee [docs/ARCHITECTURE.md](docs/ARCHITECTURE.md).\n";
        let docs = vec![
            "docs/ARCHITECTURE.md".to_string(),
            "docs/PRECISION.md".to_string(),
        ];
        let f = lint_doc_tree(readme, &docs);
        assert_eq!(rules(&f), vec!["doc-hygiene"], "{f:?}");
        assert_eq!(f[0].file, "docs/PRECISION.md");
    }

    #[test]
    fn doc_hygiene_clean_when_every_docs_file_is_named() {
        let readme = "docs line: docs/A.md and docs/sub/B.md are both linked";
        let docs = vec!["docs/A.md".to_string(), "docs/sub/B.md".to_string()];
        assert!(lint_doc_tree(readme, &docs).is_empty());
        // an empty docs tree gates nothing
        assert!(lint_doc_tree("no docs mentioned", &[]).is_empty());
    }

    #[test]
    fn json_escape_controls() {
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
    }
}
