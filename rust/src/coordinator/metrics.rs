//! Latency/throughput accounting for the serving layer.

/// Online latency statistics over cycle counts.
#[derive(Debug, Clone, Default)]
pub struct LatencyStats {
    samples: Vec<u64>,
}

impl LatencyStats {
    pub fn new() -> LatencyStats {
        LatencyStats::default()
    }

    pub fn record(&mut self, cycles: u64) {
        self.samples.push(cycles);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    /// Raw samples in recording order (the serving runtime's autoscale
    /// tick feeds the new tail to the policy).
    pub fn samples(&self) -> &[u64] {
        &self.samples
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<u64>() as f64 / self.samples.len() as f64
    }

    /// Percentile by nearest-rank (p in [0, 100]).
    pub fn percentile(&self, p: f64) -> u64 {
        if self.samples.is_empty() {
            return 0;
        }
        let mut s = self.samples.clone();
        s.sort_unstable();
        let rank = ((p / 100.0) * (s.len() as f64 - 1.0)).round() as usize;
        s[rank.min(s.len() - 1)]
    }

    pub fn p50(&self) -> u64 {
        self.percentile(50.0)
    }

    pub fn p95(&self) -> u64 {
        self.percentile(95.0)
    }

    pub fn p99(&self) -> u64 {
        self.percentile(99.0)
    }

    pub fn max(&self) -> u64 {
        self.samples.iter().copied().max().unwrap_or(0)
    }

    /// Frames per second at a clock, if each sample is one frame's
    /// latency and frames are processed back-to-back.
    pub fn fps(&self, clock_hz: f64) -> f64 {
        let m = self.mean();
        if m == 0.0 {
            0.0
        } else {
            clock_hz / m
        }
    }
}

/// Per-request latency stamp from the batched serving path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RequestStamp {
    /// Batcher-assigned request id.
    pub id: u64,
    /// Cycles spent queued in the batcher (batch release − arrival).
    pub queue_cycles: u64,
    /// Co-processor cycles until this request's result was ready: every
    /// job its replica ran earlier in the batch, plus its own
    /// (intra-batch serialization on one replica).
    pub service_cycles: u64,
}

impl RequestStamp {
    /// End-to-end latency in coordinator cycles.
    pub fn total_cycles(&self) -> u64 {
        self.queue_cycles + self.service_cycles
    }
}

/// Aggregated metrics for the batched serving path: raw per-request
/// stamps plus queue/service/total latency distributions.
#[derive(Debug, Clone, Default)]
pub struct BatchMetrics {
    pub stamps: Vec<RequestStamp>,
    pub queue: LatencyStats,
    pub service: LatencyStats,
    pub total: LatencyStats,
    /// Batches executed.
    pub batches: usize,
}

impl BatchMetrics {
    pub fn new() -> BatchMetrics {
        BatchMetrics::default()
    }

    /// Record one executed batch's stamps.
    pub fn record_batch(&mut self, stamps: &[RequestStamp]) {
        self.batches += 1;
        for s in stamps {
            self.queue.record(s.queue_cycles);
            self.service.record(s.service_cycles);
            self.total.record(s.total_cycles());
            self.stamps.push(*s);
        }
    }

    /// Requests recorded.
    pub fn count(&self) -> usize {
        self.stamps.len()
    }

    /// Mean requests per batch (0 if none).
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.stamps.len() as f64 / self.batches as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_zero() {
        let s = LatencyStats::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.p99(), 0);
        assert_eq!(s.fps(1e9), 0.0);
    }

    #[test]
    fn percentiles_ordered() {
        let mut s = LatencyStats::new();
        for i in 1..=100 {
            s.record(i);
        }
        assert!(s.p50() <= s.p95());
        assert!(s.p95() <= s.p99());
        assert_eq!(s.max(), 100);
        assert_eq!(s.mean(), 50.5);
    }

    #[test]
    fn percentile_of_empty_stats_is_zero_for_all_p() {
        let s = LatencyStats::new();
        for p in [0.0, 50.0, 95.0, 99.0, 100.0] {
            assert_eq!(s.percentile(p), 0, "p={p}");
        }
        assert_eq!(s.max(), 0);
        assert_eq!(s.count(), 0);
        assert!(s.samples().is_empty());
    }

    #[test]
    fn percentile_of_single_sample_is_that_sample() {
        let mut s = LatencyStats::new();
        s.record(42);
        for p in [0.0, 50.0, 95.0, 99.0, 100.0] {
            assert_eq!(s.percentile(p), 42, "p={p}");
        }
        assert_eq!(s.mean(), 42.0);
    }

    #[test]
    fn percentile_extremes_are_min_and_max() {
        let mut s = LatencyStats::new();
        // record out of order: percentile must sort, not trust insertion
        for v in [70u64, 10, 90, 30, 50] {
            s.record(v);
        }
        assert_eq!(s.percentile(0.0), 10, "p0 is the minimum");
        assert_eq!(s.percentile(100.0), 90, "p100 is the maximum");
        assert_eq!(s.percentile(50.0), 50);
        assert_eq!(s.samples(), &[70, 10, 90, 30, 50], "samples keep recording order");
    }

    #[test]
    fn fps_conversion() {
        let mut s = LatencyStats::new();
        s.record(1_000_000); // 1M cycles @ 250MHz = 4ms → 250 fps
        assert!((s.fps(250e6) - 250.0).abs() < 1e-9);
    }

    #[test]
    fn fps_of_zero_samples_is_zero_never_nan() {
        let s = LatencyStats::new();
        let f = s.fps(250e6);
        assert_eq!(f, 0.0);
        assert!(!f.is_nan() && !f.is_infinite());
    }

    #[test]
    fn fps_edge_cases_stay_finite() {
        // all-zero-cycle samples: mean 0 would divide to infinity —
        // the guard returns 0 instead
        let mut s = LatencyStats::new();
        s.record(0);
        s.record(0);
        assert_eq!(s.fps(250e6), 0.0);
        // a zero clock yields zero fps, not NaN
        let mut t = LatencyStats::new();
        t.record(1_000);
        let f = t.fps(0.0);
        assert_eq!(f, 0.0);
        assert!(!f.is_nan());
    }

    #[test]
    fn batch_metrics_accumulate() {
        let mut m = BatchMetrics::new();
        m.record_batch(&[
            RequestStamp { id: 0, queue_cycles: 10, service_cycles: 100 },
            RequestStamp { id: 1, queue_cycles: 5, service_cycles: 200 },
        ]);
        m.record_batch(&[RequestStamp { id: 2, queue_cycles: 0, service_cycles: 50 }]);
        assert_eq!(m.count(), 3);
        assert_eq!(m.batches, 2);
        assert_eq!(m.stamps[1].total_cycles(), 205);
        assert_eq!(m.total.max(), 205);
        assert_eq!(m.queue.max(), 10);
        assert!((m.mean_batch_size() - 1.5).abs() < 1e-12);
    }
}
