//! Latency/throughput accounting for the serving layer.

/// Online latency statistics over cycle counts.
#[derive(Debug, Clone, Default)]
pub struct LatencyStats {
    samples: Vec<u64>,
}

impl LatencyStats {
    pub fn new() -> LatencyStats {
        LatencyStats::default()
    }

    pub fn record(&mut self, cycles: u64) {
        self.samples.push(cycles);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<u64>() as f64 / self.samples.len() as f64
    }

    /// Percentile by nearest-rank (p in [0, 100]).
    pub fn percentile(&self, p: f64) -> u64 {
        if self.samples.is_empty() {
            return 0;
        }
        let mut s = self.samples.clone();
        s.sort_unstable();
        let rank = ((p / 100.0) * (s.len() as f64 - 1.0)).round() as usize;
        s[rank.min(s.len() - 1)]
    }

    pub fn p50(&self) -> u64 {
        self.percentile(50.0)
    }

    pub fn p95(&self) -> u64 {
        self.percentile(95.0)
    }

    pub fn p99(&self) -> u64 {
        self.percentile(99.0)
    }

    pub fn max(&self) -> u64 {
        self.samples.iter().copied().max().unwrap_or(0)
    }

    /// Frames per second at a clock, if each sample is one frame's
    /// latency and frames are processed back-to-back.
    pub fn fps(&self, clock_hz: f64) -> f64 {
        let m = self.mean();
        if m == 0.0 {
            0.0
        } else {
            clock_hz / m
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_zero() {
        let s = LatencyStats::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.p99(), 0);
        assert_eq!(s.fps(1e9), 0.0);
    }

    #[test]
    fn percentiles_ordered() {
        let mut s = LatencyStats::new();
        for i in 1..=100 {
            s.record(i);
        }
        assert!(s.p50() <= s.p95());
        assert!(s.p95() <= s.p99());
        assert_eq!(s.max(), 100);
        assert_eq!(s.mean(), 50.5);
    }

    #[test]
    fn fps_conversion() {
        let mut s = LatencyStats::new();
        s.record(1_000_000); // 1M cycles @ 250MHz = 4ms → 250 fps
        assert!((s.fps(250e6) - 250.0).abs() < 1e-9);
    }
}
