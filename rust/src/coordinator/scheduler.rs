//! Model scheduling: sensitivity-driven precision planning + execution
//! of a model instance on a SoC.
//!
//! A [`ModelInstance`] bundles graph + weights + the computed plan. The
//! plan comes from the paper's flow: per-layer sensitivity (eqs. 1–2,
//! using the gradient tensors the QAT trainer exports as `<layer>.g`;
//! falling back to unit gradients when absent) → budgeted promotion
//! (`quant::policy::plan`). The output head of a regression model can be
//! pinned high — the UL-VIO configuration pins `fc2`.

use crate::models::{Executor, ExecReport, ModelGraph};
use crate::npe::PrecSel;
use crate::quant::policy::{self, PlanBudget};
use crate::quant::sensitivity::{analyze_layers, LayerSensitivity};
use crate::quant::PrecisionPlan;
use crate::soc::Soc;
use crate::util::io::TensorMap;
use anyhow::Result;

/// A servable model with its precision plan.
pub struct ModelInstance {
    pub graph: ModelGraph,
    pub weights: TensorMap,
    pub plan: PrecisionPlan,
    pub sensitivities: Vec<LayerSensitivity>,
}

impl ModelInstance {
    /// Build with the layer-adaptive MxP plan.
    ///
    /// * `budget` — target average bits/weight.
    /// * `base4` — the 4-bit mode for robust layers (FP4 in the headline
    ///   config).
    /// * `pin_high_last` — pin the final compute layer to Posit(16,1)
    ///   (regression heads).
    pub fn planned(
        graph: ModelGraph,
        weights: TensorMap,
        budget: PlanBudget,
        base4: PrecSel,
        pin_high_last: bool,
    ) -> ModelInstance {
        let (ws, gs) = layer_tensors(&graph, &weights);
        let sens = analyze_layers(&ws, &gs);
        let params = graph.compute_layer_params();
        let pins: Vec<usize> =
            if pin_high_last && !params.is_empty() { vec![params.len() - 1] } else { vec![] };
        let plan = policy::plan(&sens, &params, budget, base4, &pins);
        ModelInstance { graph, weights, plan, sensitivities: sens }
    }

    /// Build with a uniform plan (precision sweeps).
    pub fn uniform(graph: ModelGraph, weights: TensorMap, sel: PrecSel) -> ModelInstance {
        let params = graph.compute_layer_params();
        let (ws, gs) = layer_tensors(&graph, &weights);
        let sens = analyze_layers(&ws, &gs);
        ModelInstance { graph, weights, plan: PrecisionPlan::uniform(sel, &params), sensitivities: sens }
    }

    /// Run one request on the co-processor.
    pub fn infer(
        &self,
        soc: &mut Soc,
        input: &[f32],
        aux: &[f32],
    ) -> Result<(Vec<f32>, ExecReport)> {
        Executor::new(&self.graph, &self.weights).forward_npe(input, aux, soc, &self.plan)
    }

    /// f32 reference output (accuracy baselines).
    pub fn infer_ref(&self, input: &[f32], aux: &[f32]) -> Result<Vec<f32>> {
        Executor::new(&self.graph, &self.weights).forward_ref(input, aux)
    }

    /// Model size under the plan, bytes.
    pub fn model_bytes(&self) -> f64 {
        self.plan.model_bytes()
    }
}

/// Extract per-compute-layer weight and gradient tensors (gradients from
/// `<layer>.g` when the trainer exported them, else unit vectors).
fn layer_tensors(graph: &ModelGraph, weights: &TensorMap) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
    let mut ws = Vec::new();
    let mut gs = Vec::new();
    for layer in &graph.layers {
        if !layer.kind.is_compute() {
            continue;
        }
        let w = weights
            .get(&format!("{}.w", layer.name))
            .map(|t| t.data.clone())
            .unwrap_or_default();
        let g = weights
            .get(&format!("{}.g", layer.name))
            .map(|t| t.data.clone())
            .unwrap_or_else(|| vec![1.0; w.len()]);
        let g = if g.len() == w.len() { g } else { vec![1.0; w.len()] };
        ws.push(w);
        gs.push(g);
    }
    (ws, gs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::effnet;
    use crate::soc::SocConfig;
    use crate::util::io::Tensor;
    use crate::util::Rng;

    pub fn random_weights(graph: &ModelGraph, seed: u64) -> TensorMap {
        let mut rng = Rng::new(seed);
        let mut m = TensorMap::new();
        for layer in &graph.layers {
            match &layer.kind {
                crate::models::LayerKind::Conv2d { in_c, out_c, k, .. } => {
                    let n = in_c * out_c * k * k;
                    let mut w = vec![0f32; n];
                    rng.fill_normal(&mut w, (2.0 / (in_c * k * k) as f64).sqrt());
                    m.insert(format!("{}.w", layer.name), Tensor::new(vec![*k, *k, *in_c, *out_c], w));
                    m.insert(format!("{}.b", layer.name), Tensor::new(vec![*out_c], vec![0.0; *out_c]));
                }
                crate::models::LayerKind::Fc { in_f, out_f } => {
                    let mut w = vec![0f32; in_f * out_f];
                    rng.fill_normal(&mut w, (2.0 / *in_f as f64).sqrt());
                    m.insert(format!("{}.w", layer.name), Tensor::new(vec![*in_f, *out_f], w));
                    m.insert(format!("{}.b", layer.name), Tensor::new(vec![*out_f], vec![0.0; *out_f]));
                }
                crate::models::LayerKind::Act(crate::models::ActKind::Pact) => {
                    m.insert(format!("{}.alpha", layer.name), Tensor::new(vec![1], vec![4.0]));
                }
                _ => {}
            }
        }
        m
    }

    #[test]
    fn planned_instance_respects_budget_and_pin() {
        let g = crate::models::ulvio::build();
        let w = random_weights(&g, 1);
        let inst = ModelInstance::planned(
            g,
            w,
            PlanBudget { avg_bits: 6.0 },
            PrecSel::Fp4x4,
            true,
        );
        assert!(inst.plan.avg_bits() <= 6.0 + 1e-9);
        assert_eq!(*inst.plan.per_layer.last().unwrap(), PrecSel::Posit16x1);
    }

    #[test]
    fn inference_runs_end_to_end() {
        let g = effnet::build();
        let w = random_weights(&g, 2);
        let inst = ModelInstance::uniform(g, w, PrecSel::Posit8x2);
        let mut soc = Soc::new(SocConfig::default());
        let input = vec![0.3f32; 256];
        let (out, rep) = inst.infer(&mut soc, &input, &[]).unwrap();
        assert_eq!(out.len(), 10);
        assert!(rep.jobs.total_cycles > 0);
        assert_eq!(rep.per_layer_cycles.len(), 5);
    }

    #[test]
    fn plan_uses_exported_gradients() {
        let g = crate::models::gaze::build();
        let mut w = random_weights(&g, 3);
        // huge gradient on fc3 → it should be promoted first
        let n = 64 * 2;
        w.insert("fc3.g".into(), Tensor::new(vec![n], vec![50.0; n]));
        let inst = ModelInstance::planned(
            g,
            w,
            PlanBudget { avg_bits: 4.6 },
            PrecSel::Fp4x4,
            false,
        );
        let bits: Vec<u32> =
            inst.plan.per_layer.iter().map(|s| s.precision().bits()).collect();
        assert!(bits[2] > 4, "fc3 (huge grad) should be promoted: {bits:?}");
    }
}
