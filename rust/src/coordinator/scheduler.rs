//! Model scheduling: sensitivity-driven precision planning + compiled
//! execution of a model instance on a SoC.
//!
//! A [`ModelInstance`] bundles graph + weights + the computed plan +
//! the **compiled program** ([`CompiledModel`]) lowered from them at
//! construction time: weights are scaled and encoded exactly once here,
//! then every replica the instance is registered on serves requests by
//! replaying the program from warm state. The plan comes from the
//! paper's flow: per-layer sensitivity (eqs. 1–2, using the gradient
//! tensors the QAT trainer exports as `<layer>.g`; falling back to unit
//! gradients when absent) → budgeted promotion (`quant::policy::plan`).
//! The output head of a regression model can be pinned high — the
//! UL-VIO configuration pins `fc2`.

use crate::models::compile::{compile, CompiledModel};
use crate::models::{ExecReport, Executor, ModelGraph};
use crate::npe::PrecSel;
use crate::quant::policy::{self, PlanBudget};
use crate::quant::sensitivity::{analyze_layers, LayerSensitivity};
use crate::quant::PrecisionPlan;
use crate::soc::Soc;
use crate::util::io::TensorMap;
use anyhow::Result;
use std::sync::Arc;

/// A servable model: its precision plan plus the compiled program.
pub struct ModelInstance {
    pub graph: ModelGraph,
    pub weights: TensorMap,
    pub plan: PrecisionPlan,
    pub sensitivities: Vec<LayerSensitivity>,
    /// The program compiled from (graph, weights, plan) — shared across
    /// replicas; each replica's warm state references these encodings.
    pub compiled: Arc<CompiledModel>,
}

impl ModelInstance {
    /// Build with the layer-adaptive MxP plan and compile.
    ///
    /// * `budget` — target average bits/weight.
    /// * `base4` — the 4-bit mode for robust layers (FP4 in the headline
    ///   config).
    /// * `pin_high_last` — pin the final compute layer to Posit(16,1)
    ///   (regression heads).
    pub fn planned(
        graph: ModelGraph,
        weights: TensorMap,
        budget: PlanBudget,
        base4: PrecSel,
        pin_high_last: bool,
    ) -> Result<ModelInstance> {
        let (ws, gs) = layer_tensors(&graph, &weights);
        let sens = analyze_layers(&ws, &gs);
        let params = graph.compute_layer_params();
        let pins: Vec<usize> =
            if pin_high_last && !params.is_empty() { vec![params.len() - 1] } else { vec![] };
        let plan = policy::plan(&sens, &params, budget, base4, &pins);
        Self::build(graph, weights, plan, sens)
    }

    /// Build the load-adaptive precision **ladder**: one instance per
    /// [`crate::quant::LADDER_BUDGETS`] rung, highest fidelity first,
    /// each compiled from its own budgeted plan over the *same*
    /// sensitivity analysis and tagged with its rung index
    /// ([`CompiledModel::rung`] — the per-request plan stamp). Returns
    /// each instance paired with its gradient-weighted distortion score
    /// in fixed-point micro-units (the accuracy-delta accounting the
    /// differential harness and the `sim_ladder_score_*` registry keys
    /// surface): rung 0 scores lowest (best), the FP4-heavy congestion
    /// rung highest.
    pub fn ladder(
        graph: ModelGraph,
        weights: TensorMap,
        base4: PrecSel,
        pin_high_last: bool,
    ) -> Result<Vec<(ModelInstance, u64)>> {
        let (ws, gs) = layer_tensors(&graph, &weights);
        let sens = analyze_layers(&ws, &gs);
        let params = graph.compute_layer_params();
        let pins: Vec<usize> =
            if pin_high_last && !params.is_empty() { vec![params.len() - 1] } else { vec![] };
        policy::ladder_plans(&sens, &params, base4, &pins)
            .into_iter()
            .enumerate()
            .map(|(rung, plan)| {
                let score = (plan.distortion_score(&ws, &gs) * 1e6).round() as u64;
                let mut compiled = compile(&graph, &weights, &plan)?;
                compiled.rung = rung as u32;
                let inst = ModelInstance {
                    graph: graph.clone(),
                    weights: weights.clone(),
                    plan,
                    sensitivities: sens.clone(),
                    compiled: Arc::new(compiled),
                };
                Ok((inst, score))
            })
            .collect()
    }

    /// Build with a uniform plan (precision sweeps) and compile.
    pub fn uniform(graph: ModelGraph, weights: TensorMap, sel: PrecSel) -> Result<ModelInstance> {
        let params = graph.compute_layer_params();
        let plan = PrecisionPlan::uniform(sel, &params);
        Self::with_plan(graph, weights, plan)
    }

    /// Build from an explicit plan. Validates the plan against the graph
    /// and the weight map against the layers (typed
    /// [`crate::models::CompileError`]s — a mismatched plan is rejected
    /// here, at registration time, instead of panicking mid-inference).
    pub fn with_plan(
        graph: ModelGraph,
        weights: TensorMap,
        plan: PrecisionPlan,
    ) -> Result<ModelInstance> {
        let (ws, gs) = layer_tensors(&graph, &weights);
        let sens = analyze_layers(&ws, &gs);
        Self::build(graph, weights, plan, sens)
    }

    fn build(
        graph: ModelGraph,
        weights: TensorMap,
        plan: PrecisionPlan,
        sensitivities: Vec<LayerSensitivity>,
    ) -> Result<ModelInstance> {
        let compiled = Arc::new(compile(&graph, &weights, &plan)?);
        Ok(ModelInstance { graph, weights, plan, sensitivities, compiled })
    }

    /// Run one request on the co-processor by replaying the compiled
    /// program (warming the SoC on first use).
    pub fn infer(
        &self,
        soc: &mut Soc,
        input: &[f32],
        aux: &[f32],
    ) -> Result<(Vec<f32>, ExecReport)> {
        self.compiled.replay(soc, input, aux)
    }

    /// Run one request through the per-request interpreted lowering —
    /// the reference path the compiled program is differentially tested
    /// against. Bit-identical to [`ModelInstance::infer`].
    pub fn infer_interpret(
        &self,
        soc: &mut Soc,
        input: &[f32],
        aux: &[f32],
    ) -> Result<(Vec<f32>, ExecReport)> {
        Executor::new(&self.graph, &self.weights).forward_interpret(input, aux, soc, &self.plan)
    }

    /// f32 reference output (accuracy baselines).
    pub fn infer_ref(&self, input: &[f32], aux: &[f32]) -> Result<Vec<f32>> {
        Executor::new(&self.graph, &self.weights).forward_ref(input, aux)
    }

    /// Pre-warm this instance's compiled program on a SoC (resident
    /// weights + pinned encodings + run arena). [`ModelInstance::infer`]
    /// does this lazily; the router does it eagerly per replica.
    pub fn warm(&self, soc: &mut Soc) -> Result<()> {
        self.compiled.ensure_warm(soc)?;
        Ok(())
    }

    /// Model size under the plan, bytes.
    pub fn model_bytes(&self) -> f64 {
        self.plan.model_bytes()
    }
}

/// Extract per-compute-layer weight and gradient tensors (gradients from
/// `<layer>.g` when the trainer exported them, else unit vectors).
fn layer_tensors(graph: &ModelGraph, weights: &TensorMap) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
    let mut ws = Vec::new();
    let mut gs = Vec::new();
    for layer in &graph.layers {
        if !layer.kind.is_compute() {
            continue;
        }
        let w = weights
            .get(&format!("{}.w", layer.name))
            .map(|t| t.data.clone())
            .unwrap_or_default();
        let g = weights
            .get(&format!("{}.g", layer.name))
            .map(|t| t.data.clone())
            .unwrap_or_else(|| vec![1.0; w.len()]);
        let g = if g.len() == w.len() { g } else { vec![1.0; w.len()] };
        ws.push(w);
        gs.push(g);
    }
    (ws, gs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::effnet;
    use crate::models::random_weights;
    use crate::soc::SocConfig;
    use crate::util::io::Tensor;

    #[test]
    fn planned_instance_respects_budget_and_pin() {
        let g = crate::models::ulvio::build();
        let w = random_weights(&g, 1);
        let inst = ModelInstance::planned(
            g,
            w,
            PlanBudget { avg_bits: 6.0 },
            PrecSel::Fp4x4,
            true,
        )
        .unwrap();
        assert!(inst.plan.avg_bits() <= 6.0 + 1e-9);
        assert_eq!(*inst.plan.per_layer.last().unwrap(), PrecSel::Posit16x1);
    }

    #[test]
    fn inference_runs_end_to_end() {
        let g = effnet::build();
        let w = random_weights(&g, 2);
        let inst = ModelInstance::uniform(g, w, PrecSel::Posit8x2).unwrap();
        let mut soc = Soc::new(SocConfig::default());
        let input = vec![0.3f32; 256];
        let (out, rep) = inst.infer(&mut soc, &input, &[]).unwrap();
        assert_eq!(out.len(), 10);
        assert!(rep.jobs.total_cycles > 0);
        assert_eq!(rep.per_layer_cycles.len(), 5);
    }

    #[test]
    fn compiled_infer_matches_interpreted_infer() {
        let g = crate::models::ulvio::build();
        let w = random_weights(&g, 7);
        let inst = ModelInstance::planned(
            g,
            w,
            PlanBudget { avg_bits: 6.0 },
            PrecSel::Fp4x4,
            true,
        )
        .unwrap();
        let input: Vec<f32> = (0..inst.graph.input.numel())
            .map(|i| ((i as f32) * 0.17).sin() * 0.4)
            .collect();
        let aux = vec![0.05f32; 6];
        let mut soc_c = Soc::new(SocConfig::default());
        let mut soc_i = Soc::new(SocConfig::default());
        let (oc, rc) = inst.infer(&mut soc_c, &input, &aux).unwrap();
        let (oi, ri) = inst.infer_interpret(&mut soc_i, &input, &aux).unwrap();
        assert_eq!(oc, oi);
        assert_eq!(rc, ri);
    }

    #[test]
    fn mismatched_plan_is_rejected_at_registration() {
        let g = crate::models::gaze::build();
        let w = random_weights(&g, 8);
        let bad = crate::quant::PrecisionPlan::uniform(PrecSel::Fp4x4, &[1]);
        let err = ModelInstance::with_plan(g, w, bad).unwrap_err();
        assert!(err.to_string().contains("precision plan"), "{err}");
    }

    #[test]
    fn plan_uses_exported_gradients() {
        let g = crate::models::gaze::build();
        let mut w = random_weights(&g, 3);
        // huge gradient on fc3 → it should be promoted first
        let n = 64 * 2;
        w.insert("fc3.g".into(), Tensor::new(vec![n], vec![50.0; n]));
        let inst = ModelInstance::planned(
            g,
            w,
            PlanBudget { avg_bits: 4.6 },
            PrecSel::Fp4x4,
            false,
        )
        .unwrap();
        let bits: Vec<u32> =
            inst.plan.per_layer.iter().map(|s| s.precision().bits()).collect();
        assert!(bits[2] > 4, "fc3 (huge grad) should be promoted: {bits:?}");
    }
}
