//! The L3 coordinator — the serving layer that turns XR perception
//! requests into layer-adaptive work on the simulated co-processor(s).
//!
//! * [`scheduler`] — computes the per-layer [`crate::quant::PrecisionPlan`]
//!   for a model (sensitivity analysis → budgeted assignment) and owns
//!   the layer→GEMM lowering order.
//! * [`batcher`] — frame-request batching with deadline flush (XR is
//!   latency-critical; batching is bounded, never unbounded-throughput
//!   greedy).
//! * [`router`] — routes {VIO, gaze, classification} requests to model
//!   instances and their SoCs; round-robins across replicas. Built on
//!   the [`crate::serve`] runtime: `submit`/`submit_batch` return
//!   completion handles immediately, `route`/`route_batch` are blocking
//!   wrappers, and an autoscaler grows/parks the active replica set
//!   from queue-latency percentiles.
//! * [`pipeline`] — the end-to-end perception pipeline of Fig. 1:
//!   camera/IMU frames → VIO + gaze + classification per frame, with the
//!   non-perception stages (visual/audio/runtime) modeled by calibrated
//!   host budgets; reports the application-runtime breakdown.
//! * [`metrics`] — latency/throughput accounting.

pub mod batcher;
pub mod metrics;
pub mod pipeline;
pub mod router;
pub mod scheduler;

pub use batcher::{Batch, FrameBatcher};
pub use metrics::{BatchMetrics, LatencyStats, RequestStamp};
pub use pipeline::{
    execute_batch, serve_with_batcher, serve_with_batcher_async, BatchServeReport,
    PerceptionPipeline, PipelineConfig, RuntimeBreakdown,
};
pub use router::{CacheStats, InferCompletion, RoutedResult, Router, RuntimeConfig, WorkloadKind};
pub use scheduler::ModelInstance;
