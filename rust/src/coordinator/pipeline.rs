//! The end-to-end XR perception pipeline (paper Fig. 1).
//!
//! Per camera frame (30 fps-class): VIO and gaze run every frame,
//! classification every `classify_every` frames (scene understanding is
//! slower-rate). Non-perception stages — visual pipeline (reprojection /
//! composition), audio pipeline, and runtime/other — are modeled by host
//! cycle budgets calibrated to Aspen's workload characterization, where
//! the perception pipeline is ~60% of application runtime at baseline
//! precision. The pipeline then *measures* how layer-adaptive
//! mixed-precision shifts that breakdown.

use super::batcher::{Batch, FrameBatcher};
use super::metrics::{BatchMetrics, LatencyStats, RequestStamp};
use super::router::{InferCompletion, RoutedResult, Router, WorkloadKind};
use crate::vio::kitti::Frame;
use crate::vio::RelPose;
use anyhow::Result;

/// Host-stage cycle budgets + rates.
#[derive(Debug, Clone, Copy)]
pub struct PipelineConfig {
    pub visual_cycles: u64,
    pub audio_cycles: u64,
    pub other_cycles: u64,
    /// Run classification every N frames.
    pub classify_every: usize,
}

impl PipelineConfig {
    /// Calibrate the non-perception budgets to Aspen's Fig.-1 proportions
    /// (perception ≈ 60%, visual ≈ 22%, audio ≈ 10%, other ≈ 8%) around a
    /// measured baseline per-frame perception cost.
    pub fn calibrated_to(perception_baseline_cycles: u64) -> PipelineConfig {
        let total = perception_baseline_cycles as f64 / 0.60;
        PipelineConfig {
            visual_cycles: (total * 0.22) as u64,
            audio_cycles: (total * 0.10) as u64,
            other_cycles: (total * 0.08) as u64,
            classify_every: 5,
        }
    }
}

/// Measured application-runtime breakdown.
#[derive(Debug, Clone, Default)]
pub struct RuntimeBreakdown {
    pub vio_cycles: u64,
    pub gaze_cycles: u64,
    pub classify_cycles: u64,
    pub visual_cycles: u64,
    pub audio_cycles: u64,
    pub other_cycles: u64,
}

impl RuntimeBreakdown {
    pub fn perception_cycles(&self) -> u64 {
        self.vio_cycles + self.gaze_cycles + self.classify_cycles
    }

    pub fn total_cycles(&self) -> u64 {
        self.perception_cycles() + self.visual_cycles + self.audio_cycles + self.other_cycles
    }

    pub fn perception_fraction(&self) -> f64 {
        if self.total_cycles() == 0 {
            0.0
        } else {
            self.perception_cycles() as f64 / self.total_cycles() as f64
        }
    }

    /// (stage, cycles, fraction) rows for reports.
    pub fn rows(&self) -> Vec<(&'static str, u64, f64)> {
        let t = self.total_cycles().max(1) as f64;
        vec![
            ("VIO (perception)", self.vio_cycles, self.vio_cycles as f64 / t),
            ("Eye gaze (perception)", self.gaze_cycles, self.gaze_cycles as f64 / t),
            ("Classification (perception)", self.classify_cycles, self.classify_cycles as f64 / t),
            ("Visual pipeline", self.visual_cycles, self.visual_cycles as f64 / t),
            ("Audio pipeline", self.audio_cycles, self.audio_cycles as f64 / t),
            ("Runtime/other", self.other_cycles, self.other_cycles as f64 / t),
        ]
    }
}

/// Result of a pipeline run.
#[derive(Debug, Clone, Default)]
pub struct PipelineReport {
    pub frames: usize,
    pub breakdown: RuntimeBreakdown,
    pub frame_latency: LatencyStats,
    /// Predicted relative poses (for odometry evaluation downstream).
    pub vio_pred: Vec<RelPose>,
    /// Ground-truth relative poses.
    pub vio_gt: Vec<RelPose>,
    /// Classification outputs (argmax per classified frame).
    pub class_preds: Vec<usize>,
}

/// The pipeline driver.
pub struct PerceptionPipeline {
    pub cfg: PipelineConfig,
}

impl PerceptionPipeline {
    pub fn new(cfg: PipelineConfig) -> PerceptionPipeline {
        PerceptionPipeline { cfg }
    }

    /// Drive `frames` through the router. `gaze_inputs` supplies the eye
    /// tracker stream (one 16-vector per frame); classification reuses
    /// the camera feature frame (current half, 16×16 = 256).
    pub fn run(
        &self,
        router: &mut Router,
        frames: &[Frame],
        gaze_inputs: &[Vec<f32>],
    ) -> Result<PipelineReport> {
        assert_eq!(frames.len(), gaze_inputs.len(), "frame/gaze stream length mismatch");
        let mut report = PipelineReport { frames: frames.len(), ..Default::default() };
        for (i, frame) in frames.iter().enumerate() {
            let mut frame_cycles = 0u64;

            // VIO every frame
            let vio = router.route(WorkloadKind::Vio, &frame.image, &frame.imu)?;
            let c = vio.report.total_cycles();
            report.breakdown.vio_cycles += c;
            frame_cycles += c;
            let mut pose = [0f32; 6];
            pose.copy_from_slice(&vio.output[..6]);
            report.vio_pred.push(pose);
            report.vio_gt.push(frame.rel_pose);

            // gaze every frame
            let gz = router.route(WorkloadKind::Gaze, &gaze_inputs[i], &[])?;
            let c = gz.report.total_cycles();
            report.breakdown.gaze_cycles += c;
            frame_cycles += c;

            // classification every Nth frame
            if i % self.cfg.classify_every == 0 && router.has(WorkloadKind::Classify) {
                let cl = router.route(WorkloadKind::Classify, &frame.image[..256], &[])?;
                let c = cl.report.total_cycles();
                report.breakdown.classify_cycles += c;
                frame_cycles += c;
                report.class_preds.push(crate::util::argmax(&cl.output));
            }

            // host stages
            report.breakdown.visual_cycles += self.cfg.visual_cycles;
            report.breakdown.audio_cycles += self.cfg.audio_cycles;
            report.breakdown.other_cycles += self.cfg.other_cycles;
            frame_cycles +=
                self.cfg.visual_cycles + self.cfg.audio_cycles + self.cfg.other_cycles;
            report.frame_latency.record(frame_cycles);
        }
        Ok(report)
    }
}

/// Result of serving a request stream through the batched parallel path.
#[derive(Debug, Clone, Default)]
pub struct BatchServeReport {
    /// Outputs ordered by request id (= submission order).
    pub outputs: Vec<Vec<f32>>,
    /// Per-request latency stamps + distributions.
    pub metrics: BatchMetrics,
}

/// Execute one released [`Batch`] through the parallel router path
/// ([`Router::route_batch`]), stamping per-request latency into
/// `metrics`: queue time from the batcher (release − arrival) plus
/// intra-batch service serialization on the request's replica.
pub fn execute_batch(
    router: &mut Router,
    kind: WorkloadKind,
    batch: &Batch,
    metrics: &mut BatchMetrics,
) -> Result<Vec<RoutedResult>> {
    let results = router.route_batch(kind, batch)?;
    metrics.record_batch(&stamp_batch(batch, &results, router.n_replicas()));
    Ok(results)
}

/// Per-request latency stamps of one executed batch: batcher queue time
/// (release − arrival) plus intra-batch service serialization on the
/// request's replica. Pure accounting over the deterministic replica
/// assignment — the sync and async execution paths produce identical
/// stamps for identical batches.
fn stamp_batch(batch: &Batch, results: &[RoutedResult], n_replicas: usize) -> Vec<RequestStamp> {
    let mut replica_busy = vec![0u64; n_replicas];
    let mut stamps = Vec::with_capacity(results.len());
    for (req, res) in batch.requests.iter().zip(results) {
        replica_busy[res.replica] += res.report.total_cycles();
        stamps.push(RequestStamp {
            id: req.id,
            queue_cycles: batch.released.saturating_sub(req.arrived),
            service_cycles: replica_busy[res.replica],
        });
    }
    stamps
}

/// Drive a full arrival trace through a [`FrameBatcher`] and the
/// parallel batch executor. `arrivals` is `(input, aux, arrival_cycle)`
/// in non-decreasing arrival order; batches release per the batcher's
/// max-size/deadline policy, with a final flush at the last arrival.
pub fn serve_with_batcher(
    router: &mut Router,
    kind: WorkloadKind,
    batcher: &mut FrameBatcher,
    arrivals: Vec<(Vec<f32>, Vec<f32>, u64)>,
) -> Result<BatchServeReport> {
    let mut report = BatchServeReport::default();
    let mut outputs: Vec<(u64, Vec<f32>)> = Vec::new();
    let mut now = 0u64;
    let mut run = |batch: Batch,
                   router: &mut Router,
                   metrics: &mut BatchMetrics,
                   outputs: &mut Vec<(u64, Vec<f32>)>|
     -> Result<()> {
        let res = execute_batch(router, kind, &batch, metrics)?;
        for (req, r) in batch.requests.iter().zip(res) {
            outputs.push((req.id, r.output));
        }
        Ok(())
    };
    for (input, aux, at) in arrivals {
        now = now.max(at);
        batcher.push(input, aux, now);
        while let Some(batch) = batcher.poll(now) {
            run(batch, router, &mut report.metrics, &mut outputs)?;
        }
    }
    if let Some(batch) = batcher.flush(now) {
        run(batch, router, &mut report.metrics, &mut outputs)?;
    }
    outputs.sort_by_key(|(id, _)| *id);
    report.outputs = outputs.into_iter().map(|(_, o)| o).collect();
    Ok(report)
}

/// [`serve_with_batcher`], but pipelined on the async serving runtime:
/// every released batch is **submitted** ([`Router::submit_batch`])
/// without waiting, so the batcher keeps admitting while replicas drain
/// and consecutive batches overlap on the per-replica queues; the
/// completions are redeemed at the end. Outputs, per-request stamps,
/// and distributions are bit-identical to the synchronous driver for
/// the same arrival trace (replica assignment is deterministic and the
/// stamps are simulated-cycle accounting, not wall clock) — asserted by
/// the differential test below.
pub fn serve_with_batcher_async(
    router: &mut Router,
    kind: WorkloadKind,
    batcher: &mut FrameBatcher,
    arrivals: Vec<(Vec<f32>, Vec<f32>, u64)>,
) -> Result<BatchServeReport> {
    let mut report = BatchServeReport::default();
    let mut inflight: Vec<(Batch, Vec<InferCompletion>)> = Vec::new();
    let mut now = 0u64;
    for (input, aux, at) in arrivals {
        now = now.max(at);
        batcher.push(input, aux, now);
        while let Some(batch) = batcher.poll(now) {
            let comps = router.submit_batch(kind, &batch)?;
            inflight.push((batch, comps));
        }
    }
    if let Some(batch) = batcher.flush(now) {
        let comps = router.submit_batch(kind, &batch)?;
        inflight.push((batch, comps));
    }
    let n_replicas = router.n_replicas();
    let mut outputs: Vec<(u64, Vec<f32>)> = Vec::new();
    for (batch, comps) in inflight {
        let results: Vec<RoutedResult> =
            comps.into_iter().map(Router::resolve).collect::<Result<_>>()?;
        report.metrics.record_batch(&stamp_batch(&batch, &results, n_replicas));
        for (req, r) in batch.requests.iter().zip(results) {
            outputs.push((req.id, r.output));
        }
    }
    outputs.sort_by_key(|(id, _)| *id);
    report.outputs = outputs.into_iter().map(|(_, o)| o).collect();
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scheduler::ModelInstance;
    use crate::models::random_weights as weights_for;
    use crate::models::{effnet, gaze, ulvio};
    use crate::npe::PrecSel;
    use crate::soc::SocConfig;
    use crate::vio::kitti::{SequenceConfig, TrajectoryGenerator};

    fn rigged_router() -> Router {
        let mut r = Router::new(1, SocConfig::default());
        let gv = ulvio::build();
        let wv = weights_for(&gv, 1);
        r.register(WorkloadKind::Vio, ModelInstance::uniform(gv, wv, PrecSel::Posit8x2).unwrap()).unwrap();
        let gg = gaze::build();
        let wg = weights_for(&gg, 2);
        r.register(WorkloadKind::Gaze, ModelInstance::uniform(gg, wg, PrecSel::Fp4x4).unwrap()).unwrap();
        let gc = effnet::build();
        let wc = weights_for(&gc, 3);
        r.register(WorkloadKind::Classify, ModelInstance::uniform(gc, wc, PrecSel::Fp4x4).unwrap()).unwrap();
        r
    }

    #[test]
    fn pipeline_runs_and_accounts() {
        let mut router = rigged_router();
        let frames = TrajectoryGenerator::new(SequenceConfig { frames: 12, ..Default::default() })
            .sequence();
        let gaze_in: Vec<Vec<f32>> = (0..12).map(|i| vec![(i as f32) * 0.01; 16]).collect();
        let pipe = PerceptionPipeline::new(PipelineConfig {
            visual_cycles: 1000,
            audio_cycles: 500,
            other_cycles: 200,
            classify_every: 4,
        });
        let rep = pipe.run(&mut router, &frames, &gaze_in).unwrap();
        assert_eq!(rep.frames, 12);
        assert_eq!(rep.vio_pred.len(), 12);
        assert_eq!(rep.class_preds.len(), 3); // frames 0, 4, 8
        assert!(rep.breakdown.vio_cycles > 0);
        assert!(rep.breakdown.perception_fraction() > 0.0);
        assert_eq!(rep.frame_latency.count(), 12);
    }

    #[test]
    fn batched_serving_matches_serial_and_stamps_latency() {
        let mut router = rigged_router();
        let inputs: Vec<Vec<f32>> = (0..9).map(|i| vec![0.01 * i as f32; 16]).collect();
        let mut batcher = FrameBatcher::new(4, 25);
        let arrivals: Vec<(Vec<f32>, Vec<f32>, u64)> = inputs
            .iter()
            .enumerate()
            .map(|(i, x)| (x.clone(), vec![], (i as u64) * 10))
            .collect();
        let rep =
            serve_with_batcher(&mut router, WorkloadKind::Gaze, &mut batcher, arrivals).unwrap();
        assert_eq!(rep.outputs.len(), 9);
        assert_eq!(rep.metrics.count(), 9);
        assert_eq!(rep.metrics.batches, 3); // 4 + 4 + flush(1)
        assert_eq!(batcher.pending(), 0);
        // outputs are bit-identical to serial routing, in request order
        let mut serial = rigged_router();
        for (i, x) in inputs.iter().enumerate() {
            let want = serial.route(WorkloadKind::Gaze, x, &[]).unwrap().output;
            assert_eq!(rep.outputs[i], want, "request {i}");
        }
        // stamps: in-order ids, batcher-bounded queueing, non-zero service
        let ids: Vec<u64> = rep.metrics.stamps.iter().map(|s| s.id).collect();
        assert_eq!(ids, (0..9).collect::<Vec<u64>>());
        for s in &rep.metrics.stamps {
            assert!(s.queue_cycles <= 30, "queue {} exceeds batcher policy", s.queue_cycles);
            assert!(s.service_cycles > 0);
            assert_eq!(s.total_cycles(), s.queue_cycles + s.service_cycles);
        }
        assert!(rep.metrics.total.p99() >= rep.metrics.service.p50());
    }

    #[test]
    fn async_batched_serving_is_bit_identical_to_sync() {
        // identical arrival traces through the blocking driver and the
        // pipelined async driver: outputs, stamps and distributions must
        // match exactly (stamps are simulated-cycle accounting over a
        // deterministic replica assignment)
        let arrivals = |n: usize| -> Vec<(Vec<f32>, Vec<f32>, u64)> {
            (0..n).map(|i| (vec![0.013 * i as f32; 16], vec![], (i as u64) * 7)).collect()
        };
        let mut sync_router = rigged_router();
        let mut sync_batcher = FrameBatcher::new(3, 20);
        let sync_rep =
            serve_with_batcher(&mut sync_router, WorkloadKind::Gaze, &mut sync_batcher, arrivals(11))
                .unwrap();
        let mut async_router = rigged_router();
        let mut async_batcher = FrameBatcher::new(3, 20);
        let async_rep = serve_with_batcher_async(
            &mut async_router,
            WorkloadKind::Gaze,
            &mut async_batcher,
            arrivals(11),
        )
        .unwrap();
        assert_eq!(async_rep.outputs, sync_rep.outputs, "values diverged");
        assert_eq!(async_rep.metrics.stamps, sync_rep.metrics.stamps, "stamps diverged");
        assert_eq!(async_rep.metrics.batches, sync_rep.metrics.batches);
        assert_eq!(async_rep.metrics.queue.samples(), sync_rep.metrics.queue.samples());
        assert_eq!(async_rep.metrics.total.p99(), sync_rep.metrics.total.p99());
    }

    #[test]
    fn execute_batch_spreads_service_across_replicas() {
        use crate::coordinator::batcher::Request;
        let mut r = Router::new(2, crate::soc::SocConfig::default());
        let g = gaze::build();
        let w = weights_for(&g, 9);
        r.register(WorkloadKind::Gaze, ModelInstance::uniform(g, w, PrecSel::Fp4x4).unwrap()).unwrap();
        let batch = Batch {
            requests: (0..4)
                .map(|i| Request {
                    id: i,
                    input: vec![0.1; 16],
                    aux: vec![],
                    arrived: 0,
                })
                .collect(),
            released: 7,
        };
        let mut metrics = BatchMetrics::new();
        let res = execute_batch(&mut r, WorkloadKind::Gaze, &batch, &mut metrics).unwrap();
        assert_eq!(res.len(), 4);
        // 2 replicas × 2 requests: the second request on a replica waits
        // for the first, so its service stamp is strictly larger
        assert!(metrics.stamps[2].service_cycles > metrics.stamps[0].service_cycles);
        assert!(metrics.stamps[3].service_cycles > metrics.stamps[1].service_cycles);
        assert!(metrics.stamps.iter().all(|s| s.queue_cycles == 7));
    }

    #[test]
    fn calibration_puts_perception_near_60pct() {
        let mut router = rigged_router();
        let frames = TrajectoryGenerator::new(SequenceConfig { frames: 10, ..Default::default() })
            .sequence();
        let gaze_in: Vec<Vec<f32>> = (0..10).map(|_| vec![0.1; 16]).collect();
        // measure baseline perception cost on one frame batch
        let probe = PerceptionPipeline::new(PipelineConfig {
            visual_cycles: 0,
            audio_cycles: 0,
            other_cycles: 0,
            classify_every: 5,
        });
        let baseline = probe.run(&mut router, &frames, &gaze_in).unwrap();
        let per_frame = baseline.breakdown.perception_cycles() / 10;
        // calibrated run
        let mut router2 = rigged_router();
        let pipe = PerceptionPipeline::new(PipelineConfig::calibrated_to(per_frame));
        let rep = pipe.run(&mut router2, &frames, &gaze_in).unwrap();
        let f = rep.breakdown.perception_fraction();
        assert!((f - 0.6).abs() < 0.05, "perception fraction {f:.2}");
    }
}
