//! Workload routing: {VIO, gaze, classification} → model instances on
//! co-processor replicas.
//!
//! Each workload kind owns one [`ModelInstance`]; SoC replicas are shared
//! round-robin. The router is the only component that touches both the
//! serving queue and the hardware handles — the paper's "scheduling and
//! control mechanisms as per workload configurations".
//!
//! Since PR 3 the router sits on the async serving runtime
//! ([`crate::serve::ServeRuntime`]): every replica is drained by a
//! long-lived worker thread through a bounded work queue, submission
//! ([`Router::submit`] / [`Router::submit_batch`]) returns
//! [`InferCompletion`] handles immediately, and the blocking
//! [`Router::route`] / [`Router::route_batch`] are thin wrappers that
//! submit and wait. Registration warms a configurable **floor** of
//! replicas eagerly ([`RuntimeConfig::warm_floor`]); the rest warm on
//! demand at their first request. An [`Autoscaler`] consuming the
//! runtime's queue-latency percentiles grows and parks the **active**
//! dispatch set between the floor and the fleet size
//! ([`Router::autoscale_tick`]).

use super::batcher::Batch;
use super::scheduler::ModelInstance;
use crate::models::residency::{residency_lock, ResidencyManager, ResidencyStats, ResidentImage};
use crate::models::{
    shard, verify_ladder, verify_program, verify_shard_plan, ExecReport, PartialOut, ShardChannel,
    ShardFlow, ShardedModel,
};
use crate::obs::{ShardLaneTracer, TraceCtx, TraceEvent, TraceSink};
use crate::serve::{
    device_lock, AutoscaleConfig, Autoscaler, Completion, CompletionSet, CycleAutoscaler, Job,
    JobPayload, LadderPolicy, RuntimeMetrics, ServeRuntime, WorkQueue,
};
use crate::soc::{InitiatorStats, JobReport, SocConfig};
use crate::util::hosttime::host_now;
use crate::util::Matrix;
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Perception workload kinds (paper Fig. 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum WorkloadKind {
    Vio,
    Gaze,
    Classify,
}

impl WorkloadKind {
    pub const ALL: [WorkloadKind; 3] =
        [WorkloadKind::Vio, WorkloadKind::Gaze, WorkloadKind::Classify];

    pub fn name(self) -> &'static str {
        match self {
            WorkloadKind::Vio => "vio",
            WorkloadKind::Gaze => "gaze",
            WorkloadKind::Classify => "classify",
        }
    }
}

/// Completed inference.
#[derive(Debug, Clone)]
pub struct RoutedResult {
    pub kind: WorkloadKind,
    pub output: Vec<f32>,
    pub report: ExecReport,
    /// Which replica served it.
    pub replica: usize,
}

/// Handle for one submitted request: redeem with [`Router::resolve`]
/// (or [`Completion::wait`] directly).
pub type InferCompletion = Completion<Result<RoutedResult>>;

/// Operand-encoding cache counters of one replica — the observable
/// proof that registered weights encode zero times on the serving
/// path: weight operands ride their trusted pins past the cache
/// entirely (`trusted`), only per-request activations encode
/// (`misses`). Supersedes the old anonymous `(u64, u64, u64, u64)`
/// return of [`Router::replica_cache_stats`]; every field is
/// registered under a `sim_cache_*` key by [`crate::obs::snapshot`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Encoded-operand reuse hits.
    pub hits: u64,
    /// Cold encodes (per-request activations).
    pub misses: u64,
    /// Weight panels encoded once at warm/registration time.
    pub preloads: u64,
    /// Weight operands served straight off their trusted pins.
    pub trusted: u64,
}

impl CacheStats {
    /// The legacy `(hits, misses, preloads, trusted)` tuple view, for
    /// compact assertions.
    pub fn as_tuple(self) -> (u64, u64, u64, u64) {
        (self.hits, self.misses, self.preloads, self.trusted)
    }
}

/// Serving-runtime knobs for a router.
#[derive(Debug, Clone, Copy)]
pub struct RuntimeConfig {
    /// Per-replica work-queue depth (bounded admission back-pressure).
    pub queue_capacity: usize,
    /// Replicas warmed eagerly at registration (clamped to `[1, n]`);
    /// the rest warm on demand at their first request.
    pub warm_floor: usize,
    /// Autoscaling policy ([`Router::autoscale_tick`] applies it).
    pub autoscale: AutoscaleConfig,
    /// Per-replica resident-DRAM budget in bytes for the model catalog
    /// (`None` = the replica's full [`crate::soc::Soc::resident_limit`];
    /// always clamped to it). A catalog whose combined footprint
    /// exceeds the budget rotates: dispatch to a cold model evicts the
    /// least recently dispatched unpinned model(s) and re-warms, with
    /// live compaction when the free list fragments.
    pub resident_budget: Option<usize>,
    /// Warm-affinity dispatch for whole-model kinds (default on). Only
    /// engages when the round-robin target's catalog **rotates**
    /// (combined footprint over budget): the dispatch then prefers an
    /// active replica whose manager believes the model is already warm,
    /// saving the evict → re-warm churn of landing on a cold one. The
    /// round-robin cursor still advances one step per request, and an
    /// under-budget fleet keeps exact round-robin placement.
    pub warm_affinity: bool,
    /// Gateway-predicted cold-model **warm-ahead** (default off): each
    /// whole-model dispatch predicts the next registered model still
    /// cold on its replica (fixed [`WorkloadKind::ALL`] scan order —
    /// deterministic) and the worker streams it into the catalog right
    /// after the job, charged to the AXI **management** initiator. The
    /// next request for that model then skips its cold warm. Purely
    /// additive: serving values are bit-identical with it on or off.
    pub warm_ahead: bool,
}

impl Default for RuntimeConfig {
    fn default() -> Self {
        RuntimeConfig {
            queue_capacity: 64,
            warm_floor: 1,
            autoscale: AutoscaleConfig::default(),
            resident_budget: None,
            warm_affinity: true,
            warm_ahead: false,
        }
    }
}

/// How a workload's model lives on the fleet.
enum ModelEntry {
    /// The fast path: the whole compiled model is resident per replica.
    Whole(Arc<ModelInstance>),
    /// The model is split into per-replica weight shards; requests serve
    /// through the coordinator's scatter → quire-reduce loop.
    Sharded(Arc<ShardedEntry>),
    /// A **precision ladder**: several co-resident compiled plans of the
    /// same logical model, ordered from highest fidelity (rung 0) to
    /// most aggressive quantization. Dispatch serves the router's
    /// current rung; [`Router::ladder_tick_cycles`] shifts it with load.
    Ladder(Arc<LadderEntry>),
}

/// A precision-ladder registration: one logical model compiled under
/// several [`crate::quant::PrecisionPlan`]s of descending fidelity,
/// all co-resident in the replica catalogs under distinct program uids.
struct LadderEntry {
    /// Rung 0 is the highest-fidelity plan; each later rung lowers the
    /// same graph at a strictly-not-higher average bit width
    /// (cross-checked by [`verify_ladder`] at registration).
    rungs: Vec<Arc<ModelInstance>>,
    /// Per-rung accuracy-proxy scores from the quantization sensitivity
    /// model (fixed-point `distortion_score × 1e6`; rung 0 is the
    /// reference). Surfaced as `sim_ladder_score_rung{r}` so the bench
    /// differential can account the quality cost of each switch.
    scores: Vec<u64>,
}

/// A sharded registration: the shard views plus their placement.
pub struct ShardedEntry {
    kind: WorkloadKind,
    /// The instance the shards were planned from (kept for metadata and
    /// the graph/plan accessors).
    inst: Arc<ModelInstance>,
    shards: Vec<Arc<ShardedModel>>,
    /// `replicas[i]` hosts shard `i`.
    replicas: Vec<usize>,
}

/// The router's [`ShardChannel`]: `dispatch` enqueues a partial-GEMM
/// job on the owning shard replica's bounded work queue (the workers
/// execute concurrently), `wait_any` drains **whichever** outstanding
/// partial completes first through a [`CompletionSet`] — the streaming
/// engine merges in true completion-arrival order instead of joining
/// shard 0 first.
struct RuntimeShardChannel<'a> {
    entry: &'a ShardedEntry,
    rt: &'a ServeRuntime,
    set: CompletionSet<Result<(PartialOut, JobReport)>>,
    /// Per-shard lane cursors stamping [`TraceEvent::ShardPartial`] /
    /// [`TraceEvent::QuireMerge`] spans at the coordinator (partial
    /// jobs themselves carry no trace context — the coordinator owns
    /// the request's trace id). `None` when tracing is off.
    lanes: Option<ShardLaneTracer>,
}

impl ShardChannel for RuntimeShardChannel<'_> {
    fn dispatch(&mut self, si: usize, gemm_idx: usize, a: Matrix, s_a: f64) -> Result<()> {
        let done = self.set.sender(si);
        let job = Job {
            enqueued: host_now(),
            trace: None,
            payload: JobPayload::Partial {
                shard: Arc::clone(&self.entry.shards[si]),
                gemm_idx,
                a,
                s_a,
                done,
            },
        };
        if self.rt.dispatch(self.entry.replicas[si], job).is_err() {
            bail!("serving runtime is shut down");
        }
        Ok(())
    }

    fn wait_any(&mut self) -> Result<(usize, PartialOut, JobReport)> {
        match self.set.wait_any() {
            None => bail!("wait_any with no partial GEMM in flight"),
            Some((si, Ok(Ok((part, rep))))) => {
                if let Some(lanes) = &mut self.lanes {
                    lanes.on_partial(si, rep.total_cycles);
                }
                Ok((si, part, rep))
            }
            Some((_, Ok(Err(e)))) => Err(e),
            Some((_, Err(canceled))) => Err(canceled.into()),
        }
    }

    fn on_merge(&mut self, shard_idx: usize, merge_cycles: u64) {
        if let Some(lanes) = &mut self.lanes {
            lanes.on_merge(shard_idx, merge_cycles);
        }
    }
}

impl ShardedEntry {
    /// Serve one request through the streaming pipeline: each layer's
    /// partial GEMMs stream out to the shard replicas within the
    /// in-flight window and their partials merge in completion-arrival
    /// order ([`crate::models::CompiledModel::run_sharded`] under
    /// [`ShardFlow::Streaming`]). Values are bit-identical to
    /// whole-model serving; `replica` in the result is the first
    /// shard's home (the merge runs at the coordinator).
    fn serve(
        &self,
        rt: &ServeRuntime,
        input: Vec<f32>,
        aux: Vec<f32>,
        trace: Option<TraceCtx>,
    ) -> Result<RoutedResult> {
        let lanes =
            trace.as_ref().map(|tr| ShardLaneTracer::new(tr.clone(), self.replicas.clone()));
        let mut ch = RuntimeShardChannel { entry: self, rt, set: CompletionSet::new(), lanes };
        let (output, report) = self.inst.compiled.run_sharded(
            &self.shards,
            &input,
            &aux,
            &mut ch,
            ShardFlow::Streaming,
        )?;
        if let Some(tr) = &trace {
            // overlap/stall lanes: the hidden next-layer weight
            // prefetch span trails into the end of the request (merge
            // overlap already shows as QuireMerge lanes), the exposed
            // stall directly precedes it — both derived from already-
            // computed report values, so emission cannot perturb the
            // accounting
            let total = report.total_cycles();
            if report.prefetch_hidden_cycles > 0 {
                tr.emit(
                    self.replicas[0],
                    total - report.prefetch_hidden_cycles,
                    report.prefetch_hidden_cycles,
                    TraceEvent::Prefetch,
                );
            }
            if report.axi_stall_cycles > 0 {
                tr.emit(
                    self.replicas[0],
                    total - report.axi_stall_cycles,
                    report.axi_stall_cycles,
                    TraceEvent::AxiStall,
                );
            }
            tr.emit(self.replicas[0], total, 0, TraceEvent::Complete);
        }
        Ok(RoutedResult { kind: self.kind, output, report, replica: self.replicas[0] })
    }
}

/// A small reusable thread pool for the per-request sharded
/// coordinators (the ROADMAP "coordinator thread pool" follow-up):
/// [`Router::submit`] used to spawn a throwaway thread per sharded
/// request; now a fixed set of long-lived threads drains a bounded task
/// queue — a full queue back-pressures submission exactly like the
/// replica work queues. [`Router::route`] doesn't need the pool at all:
/// it runs the coordinator loop inline on the submitting thread.
struct CoordinatorPool {
    queue: Arc<WorkQueue<Box<dyn FnOnce() + Send>>>,
    threads: Vec<JoinHandle<()>>,
}

impl CoordinatorPool {
    fn new(workers: usize, capacity: usize) -> CoordinatorPool {
        let queue: Arc<WorkQueue<Box<dyn FnOnce() + Send>>> =
            Arc::new(WorkQueue::bounded(capacity.max(workers)));
        let threads = (0..workers)
            .map(|i| {
                let q = Arc::clone(&queue);
                std::thread::Builder::new()
                    .name(format!("xr-npe-coord-{i}"))
                    // xr_lint: allow(spawn-fence) -- every task is wrapped in catch_unwind by the submitter before enqueue
                    .spawn(move || {
                        // tasks are panic-fenced by the submitter (the
                        // same catch_unwind fence the spawned path had)
                        while let Some(task) = q.pop() {
                            task();
                        }
                    })
                    // xr_lint: allow(no-panic) -- thread-spawn failure at pool construction is unrecoverable by design
                    .expect("spawn coordinator pool thread")
            })
            .collect();
        CoordinatorPool { queue, threads }
    }
}

impl Drop for CoordinatorPool {
    fn drop(&mut self) {
        self.queue.close();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// The router.
pub struct Router {
    models: HashMap<WorkloadKind, ModelEntry>,
    /// Reused coordinator threads for sharded `submit`s (lazily created
    /// at the first sharded submission). Declared before `runtime` so
    /// its drop joins the coordinators while the fleet is still up.
    coordinator_pool: Option<CoordinatorPool>,
    /// Shared with per-request sharded coordinator threads.
    runtime: Arc<ServeRuntime>,
    /// Per-replica DRAM-budget catalogs: every resident allocation on a
    /// replica goes through its manager (dispatch admits, registration
    /// floor-warms, replacement removes). Lock order: device lock
    /// first, then the manager — never the reverse.
    residency: Vec<Arc<Mutex<ResidencyManager>>>,
    queue_capacity: usize,
    autoscaler: Autoscaler,
    /// Replicas currently receiving dispatch (`1..=n_replicas`).
    active: usize,
    /// Total queue-latency samples already fed to the autoscaler
    /// (checkpoint for [`ServeRuntime::queue_samples_since`]).
    fed_samples: u64,
    /// Checkpoint for [`ServeRuntime::service_cycle_samples_since`].
    fed_cycle_samples: u64,
    /// The ladder tick's own sample checkpoint — the ladder policy and
    /// the cycle autoscaler must not steal each other's fresh samples.
    fed_ladder_samples: u64,
    /// Current precision-ladder dispatch rung (0 = highest fidelity;
    /// meaningful only while a ladder is registered).
    ladder_rung: usize,
    /// Rung switches applied by ladder ticks since registration.
    ladder_switches: u64,
    /// Requests dispatched per rung (sized at ladder registration;
    /// empty when no ladder is registered — the registry snapshot keys
    /// off that).
    ladder_served: Vec<u64>,
    warm_floor: usize,
    /// Warm-affinity dispatch toggle ([`RuntimeConfig::warm_affinity`]).
    warm_affinity: bool,
    /// Warm-ahead prediction toggle ([`RuntimeConfig::warm_ahead`]).
    warm_ahead: bool,
    /// Active count last steered explicitly (autoscaler tick or
    /// [`Router::set_active`]); registration warms
    /// `max(warm_floor, steered)` so a scaled-up fleet never pays
    /// first-request warming after a model refresh, while an un-steered
    /// fleet keeps the cheap floor-only registration.
    steered_active: Option<usize>,
    next_replica: usize,
    /// In-flight sharded coordinator requests (count + wakeup), so
    /// [`Router::quiesce`] covers the scatter/reduce loops too.
    sharded_inflight: Arc<(Mutex<usize>, Condvar)>,
    /// Per-kind request counters (admitted to the runtime).
    pub served: HashMap<WorkloadKind, u64>,
    /// Optional fleet trace sink ([`Router::set_trace_sink`]): when
    /// attached, every submission mints a [`crate::obs::TraceId`] and
    /// the request's span events ride the job through the workers and
    /// shard coordinators. `None` (the default) is provably
    /// zero-overhead — no event is constructed, and results stay
    /// bit-identical to an untraced run.
    trace: Option<Arc<TraceSink>>,
}

impl Router {
    /// `n_replicas` co-processors with the given config and default
    /// runtime settings (warm floor 1, all replicas active).
    pub fn new(n_replicas: usize, cfg: SocConfig) -> Router {
        Router::with_runtime(n_replicas, cfg, RuntimeConfig::default())
    }

    /// `n_replicas` co-processors with explicit runtime settings.
    pub fn with_runtime(n_replicas: usize, cfg: SocConfig, rt: RuntimeConfig) -> Router {
        assert!(n_replicas >= 1);
        let runtime = Arc::new(ServeRuntime::new(n_replicas, cfg, rt.queue_capacity));
        let residency = (0..n_replicas)
            .map(|i| {
                let limit = device_lock(runtime.soc(i)).resident_limit();
                let budget = rt.resident_budget.map(|b| b as u64).unwrap_or(limit).min(limit);
                Arc::new(Mutex::new(ResidencyManager::lru(budget)))
            })
            .collect();
        Router {
            models: HashMap::new(),
            coordinator_pool: None,
            runtime,
            residency,
            queue_capacity: rt.queue_capacity,
            autoscaler: Autoscaler::new(rt.autoscale),
            active: n_replicas,
            fed_samples: 0,
            fed_cycle_samples: 0,
            fed_ladder_samples: 0,
            ladder_rung: 0,
            ladder_switches: 0,
            ladder_served: Vec::new(),
            warm_floor: rt.warm_floor.clamp(1, n_replicas),
            warm_affinity: rt.warm_affinity,
            warm_ahead: rt.warm_ahead,
            steered_active: None,
            next_replica: 0,
            sharded_inflight: Arc::new((Mutex::new(0), Condvar::new())),
            served: HashMap::new(),
            trace: None,
        }
    }

    /// Attach a bounded trace sink: every subsequent submission mints a
    /// fresh [`crate::obs::TraceId`] and records simulated-cycle span
    /// events from submit to completion. Tracing is purely additive —
    /// outputs and reports are bit-identical with or without a sink.
    pub fn set_trace_sink(&mut self, sink: Arc<TraceSink>) {
        self.trace = Some(sink);
    }

    /// Detach the trace sink (tracing off; already-recorded events stay
    /// in the sink the caller holds).
    pub fn clear_trace_sink(&mut self) {
        self.trace = None;
    }

    /// The attached trace sink, if any.
    pub fn trace_sink(&self) -> Option<&Arc<TraceSink>> {
        self.trace.as_ref()
    }

    /// Mint a per-request trace context when tracing is on.
    fn mint_ctx(&self) -> Option<TraceCtx> {
        self.trace.as_ref().map(|sink| TraceCtx { id: sink.mint(), sink: Arc::clone(sink) })
    }

    /// Record a router-level (no request span) fleet event:
    /// autoscale decisions, verification rejects.
    fn emit_fleet_event(&self, event: TraceEvent) {
        if let Some(sink) = &self.trace {
            let id = sink.mint();
            sink.emit(id, 0, 0, 0, event);
        }
    }

    /// Register the model for a workload kind with **whole-model
    /// residency** (the fast path): the compiled program joins every
    /// replica's DRAM-budget catalog, and the first
    /// [`RuntimeConfig::warm_floor`] replicas — or the whole **steered
    /// active set** when the autoscaler (or [`Router::set_active`]) has
    /// grown it past the floor — warm it eagerly through their
    /// [`ResidencyManager`] (which may evict colder models to make
    /// room). A full replica no longer fails the registration: the
    /// model simply **queues cold** in the catalog, and its first
    /// dispatch performs the policy-driven evict → warm.
    ///
    /// The only registration error left is a model whose footprint
    /// exceeds the replica budget outright — it could never serve whole
    /// here; use [`Router::register_auto`] /
    /// [`Router::register_sharded`] to split it across the fleet.
    /// Replacing a model quiesces the runtime first so in-flight
    /// requests against the old instance drain, then drops it from
    /// every catalog (resident DRAM returns to the allocator).
    pub fn register(&mut self, kind: WorkloadKind, inst: ModelInstance) -> Result<()> {
        self.register_whole(kind, Arc::new(inst))
    }

    fn register_whole(&mut self, kind: WorkloadKind, inst: Arc<ModelInstance>) -> Result<()> {
        // tier-1 static verification: prove the compiled program's
        // resident layout, gather bounds and activation chain are safe
        // *before* it can touch any replica's catalog or DRAM. The
        // typed `VerifyError` stays downcastable through anyhow.
        let limit = device_lock(self.runtime.soc(0)).resident_limit();
        if let Err(e) = verify_program(&inst.compiled, limit) {
            self.emit_fleet_event(TraceEvent::VerifyReject);
            return Err(e.into());
        }
        let image: Arc<dyn ResidentImage> = Arc::clone(&inst.compiled) as Arc<dyn ResidentImage>;
        let needed = image.warm_footprint_bytes() as u64;
        let n_rep = self.runtime.n_replicas();
        let min_budget = (0..n_rep)
            .map(|i| residency_lock(&self.residency[i]).budget())
            .min()
            .unwrap_or(0);
        if needed > min_budget {
            bail!(
                "model `{}` needs {} resident bytes but the replica budget is {} — \
                 register_auto/register_sharded can split it across the fleet",
                inst.compiled.name,
                needed,
                min_budget
            );
        }
        // catalog-join every replica; eager warm on the floor/steered
        // set is best effort — a replica whose budget is hogged by
        // pinned models leaves the model cold until demand (or a
        // replacement) frees the space
        let warm_n = self.warm_floor.max(self.steered_active.unwrap_or(0)).min(n_rep);
        for i in 0..n_rep {
            residency_lock(&self.residency[i]).insert(Arc::clone(&image));
        }
        for i in 0..warm_n {
            let soc = Arc::clone(self.runtime.soc(i));
            let mut dev = device_lock(&soc);
            let mut mgr = residency_lock(&self.residency[i]);
            let _ = mgr.admit(&mut dev, &image);
        }
        self.replace_entry(kind, ModelEntry::Whole(inst));
        Ok(())
    }

    /// Register a **precision ladder** for a workload kind: several
    /// compiled plans of the same logical model — rung 0 the highest
    /// fidelity, each later rung a more aggressive quantization (built
    /// by [`ModelInstance::ladder`], which also supplies the per-rung
    /// sensitivity scores). All rungs join every replica's DRAM-budget
    /// catalog as independent evictable images; only rung 0 warms
    /// eagerly on the floor/steered set — lower rungs warm on their
    /// first dispatch, exactly like a cold whole model.
    ///
    /// The ladder is cross-verified before any catalog changes:
    /// [`verify_ladder`] proves the rung tags, the shared model shape
    /// and the descending-fidelity ordering, then runs the full
    /// [`verify_program`] proof per rung. Dispatch serves the router's
    /// **current rung** ([`Router::ladder_rung`]), which
    /// [`Router::ladder_tick_cycles`] moves under congestion; with no
    /// ticks the ladder serves rung 0 forever — bit-identical to
    /// registering that plan alone via [`Router::register`].
    pub fn register_ladder(
        &mut self,
        kind: WorkloadKind,
        rungs: Vec<(ModelInstance, u64)>,
    ) -> Result<()> {
        if rungs.is_empty() {
            bail!("a precision ladder needs at least one rung");
        }
        let (insts, scores): (Vec<Arc<ModelInstance>>, Vec<u64>) =
            rungs.into_iter().map(|(inst, score)| (Arc::new(inst), score)).unzip();
        let limit = device_lock(self.runtime.soc(0)).resident_limit();
        let compiled: Vec<&crate::models::CompiledModel> =
            insts.iter().map(|i| i.compiled.as_ref()).collect();
        if let Err(e) = verify_ladder(&compiled, limit) {
            self.emit_fleet_event(TraceEvent::VerifyReject);
            return Err(e.into());
        }
        let n_rep = self.runtime.n_replicas();
        let min_budget = (0..n_rep)
            .map(|i| residency_lock(&self.residency[i]).budget())
            .min()
            .unwrap_or(0);
        for inst in &insts {
            let needed = inst.compiled.warm_footprint_bytes() as u64;
            if needed > min_budget {
                bail!(
                    "ladder rung {} of `{}` needs {} resident bytes but the replica budget is {}",
                    inst.compiled.rung,
                    inst.compiled.name,
                    needed,
                    min_budget
                );
            }
        }
        // catalog-join every rung everywhere; eager warm only rung 0 on
        // the floor/steered set (best effort, like register_whole)
        let warm_n = self.warm_floor.max(self.steered_active.unwrap_or(0)).min(n_rep);
        for i in 0..n_rep {
            let mut mgr = residency_lock(&self.residency[i]);
            for inst in &insts {
                mgr.insert(Arc::clone(&inst.compiled) as Arc<dyn ResidentImage>);
            }
        }
        for i in 0..warm_n {
            let image: Arc<dyn ResidentImage> =
                Arc::clone(&insts[0].compiled) as Arc<dyn ResidentImage>;
            let soc = Arc::clone(self.runtime.soc(i));
            let mut dev = device_lock(&soc);
            let mut mgr = residency_lock(&self.residency[i]);
            let _ = mgr.admit(&mut dev, &image);
        }
        let n_rungs = insts.len();
        self.replace_entry(kind, ModelEntry::Ladder(Arc::new(LadderEntry { rungs: insts, scores })));
        self.ladder_rung = 0;
        self.ladder_served = vec![0; n_rungs];
        Ok(())
    }

    /// Register a model **sharded `n_shards` ways**: each per-layer GEMM
    /// is K-split (N-split fallback) across `n_shards` replicas chosen
    /// by free resident-DRAM budget, each shard's weight slices are
    /// warmed eagerly on its home replica, and requests serve through
    /// the scatter → partial-quire → exact-reduce loop — bit-identical
    /// values to whole-model serving. `n_shards == 1` **is literally the
    /// whole-model path** ([`Router::register`]). A failed warm or an
    /// unsplittable plan rolls back fully.
    pub fn register_sharded(
        &mut self,
        kind: WorkloadKind,
        inst: ModelInstance,
        n_shards: usize,
    ) -> Result<()> {
        if n_shards == 1 {
            return self.register(kind, inst);
        }
        self.register_shards(kind, Arc::new(inst), n_shards)
    }

    /// Register with **automatic placement**: whole-model residency
    /// when the compiled footprint fits every replica's
    /// **post-eviction** resident budget (what the replica could free
    /// by evicting every unpinned model — the catalog rotates, so
    /// currently-resident evictable models don't force sharding),
    /// otherwise the smallest shard count whose slices fit — the fleet
    /// serves models no single replica could host.
    pub fn register_auto(&mut self, kind: WorkloadKind, inst: ModelInstance) -> Result<()> {
        let n_rep = self.runtime.n_replicas();
        let budgets = self.post_eviction_budgets();
        let needed = inst.compiled.warm_footprint_bytes() as u64;
        if budgets.iter().all(|&b| needed <= b) {
            return self.register(kind, inst);
        }
        if n_rep < 2 {
            bail!(
                "model `{}` needs {} resident bytes but the single replica has only {} free \
                 (sharding needs >= 2 replicas)",
                inst.compiled.name,
                needed,
                budgets.first().copied().unwrap_or(0)
            );
        }
        let max_free = budgets.iter().copied().max().unwrap_or(0).max(1);
        let mut n = (needed.div_ceil(max_free) as usize).clamp(2, n_rep);
        let inst = Arc::new(inst);
        loop {
            match self.register_shards(kind, Arc::clone(&inst), n) {
                Ok(()) => return Ok(()),
                Err(_) if n < n_rep => n += 1, // try a finer split
                Err(e) => return Err(e),
            }
        }
    }

    /// Per-replica resident budget a new model could claim after
    /// evicting every unpinned resident model — shard planning and
    /// placement work against these *post-eviction* numbers, not the
    /// momentary free bytes.
    fn post_eviction_budgets(&self) -> Vec<u64> {
        (0..self.runtime.n_replicas())
            .map(|i| {
                let dev = device_lock(self.runtime.soc(i));
                residency_lock(&self.residency[i]).available_after_eviction(&dev)
            })
            .collect()
    }

    fn register_shards(
        &mut self,
        kind: WorkloadKind,
        inst: Arc<ModelInstance>,
        n_shards: usize,
    ) -> Result<()> {
        let n_rep = self.runtime.n_replicas();
        if n_shards > n_rep {
            bail!("cannot place {n_shards} shards on a {n_rep}-replica fleet");
        }
        let shards: Vec<Arc<ShardedModel>> =
            shard(&inst.compiled, n_shards)?.into_iter().map(Arc::new).collect();
        // tier-1 static verification of the parent program AND the
        // freshly planned shard set — K/N coverage, alignment, slice
        // dims, reduction costs and per-shard layouts are all proven
        // before any replica's catalog or DRAM changes. The parent is
        // checked without a staging limit: a sharded model's whole
        // program never warms on one replica (that's the point of
        // sharding) — only the per-shard footprints face the limit.
        let limit = device_lock(self.runtime.soc(0)).resident_limit();
        if let Err(e) = verify_program(&inst.compiled, u64::MAX) {
            self.emit_fleet_event(TraceEvent::VerifyReject);
            return Err(e.into());
        }
        if let Err(e) = verify_shard_plan(&inst.compiled, &shards, limit) {
            self.emit_fleet_event(TraceEvent::VerifyReject);
            return Err(e.into());
        }
        // DRAM-budget placement against **post-eviction** budgets: the
        // heaviest shard goes to the replica that could free the most
        // resident budget, and so on down the ranks (the final K-shard
        // absorbs the split remainder, so shard footprints are not
        // uniform; pairing by rank avoids rejecting a placement whose
        // swapped assignment would fit). Stable by index on ties.
        let budgets = self.post_eviction_budgets();
        let mut order: Vec<usize> = (0..n_rep).collect();
        order.sort_by_key(|&i| std::cmp::Reverse(budgets[i]));
        let mut shard_order: Vec<usize> = (0..n_shards).collect();
        shard_order.sort_by_key(|&s| std::cmp::Reverse(shards[s].warm_footprint_bytes()));
        let mut replicas = vec![0usize; n_shards];
        for (rank, &s) in shard_order.iter().enumerate() {
            replicas[s] = order[rank];
        }
        for (sh, &ri) in shards.iter().zip(&replicas) {
            let need = sh.warm_footprint_bytes() as u64;
            if need > budgets[ri] {
                bail!(
                    "shard {} of `{}` needs {} resident bytes but replica {} can free only {}",
                    sh.shard_idx,
                    sh.name,
                    need,
                    ri,
                    budgets[ri]
                );
            }
        }
        // warm every shard on its home replica through the catalog,
        // holding a **coordinator pin** for the registration's lifetime
        // — a sharded layer must never lose a shard mid-rotation, so
        // shards are not evictable (whole models evict around them).
        // Roll back fully on any failure.
        let unregister = |router: &Router, upto: usize| {
            for (sh2, &rj) in shards.iter().zip(&replicas).take(upto) {
                let soc = Arc::clone(router.runtime.soc(rj));
                let mut dev = device_lock(&soc);
                let mut mgr = residency_lock(&router.residency[rj]);
                mgr.unpin(sh2.uid());
                mgr.remove(&mut dev, sh2.uid());
            }
        };
        for (idx, (sh, &ri)) in shards.iter().zip(&replicas).enumerate() {
            let image: Arc<dyn ResidentImage> = Arc::clone(sh) as Arc<dyn ResidentImage>;
            let soc = Arc::clone(self.runtime.soc(ri));
            let mut dev = device_lock(&soc);
            let mut mgr = residency_lock(&self.residency[ri]);
            mgr.pin_image(&image);
            if let Err(e) = mgr.admit(&mut dev, &image) {
                mgr.unpin(image.uid());
                mgr.remove(&mut dev, image.uid());
                drop(mgr);
                drop(dev);
                unregister(self, idx);
                return Err(e.into());
            }
        }
        self.replace_entry(
            kind,
            ModelEntry::Sharded(Arc::new(ShardedEntry { kind, inst, shards, replicas })),
        );
        Ok(())
    }

    /// Swap in a new registration, quiescing and dropping the replaced
    /// model (whole or sharded) from every replica catalog first — its
    /// warm state is evicted and its resident DRAM returns to the
    /// allocator.
    fn replace_entry(&mut self, kind: WorkloadKind, entry: ModelEntry) {
        if let Some(old) = self.models.remove(&kind) {
            self.quiesce();
            self.evict_entry(&old);
            if matches!(old, ModelEntry::Ladder(_)) {
                // the ladder's dispatch state dies with its registration
                self.ladder_rung = 0;
                self.ladder_switches = 0;
                self.ladder_served.clear();
            }
        }
        self.models.insert(kind, entry);
    }

    fn evict_entry(&self, entry: &ModelEntry) {
        match entry {
            ModelEntry::Whole(inst) => {
                for i in 0..self.runtime.n_replicas() {
                    let soc = Arc::clone(self.runtime.soc(i));
                    let mut dev = device_lock(&soc);
                    residency_lock(&self.residency[i]).remove(&mut dev, inst.compiled.uid());
                }
            }
            ModelEntry::Sharded(se) => {
                for (sh, &ri) in se.shards.iter().zip(&se.replicas) {
                    let soc = Arc::clone(self.runtime.soc(ri));
                    let mut dev = device_lock(&soc);
                    let mut mgr = residency_lock(&self.residency[ri]);
                    mgr.unpin(sh.uid());
                    mgr.remove(&mut dev, sh.uid());
                }
            }
            ModelEntry::Ladder(le) => {
                for i in 0..self.runtime.n_replicas() {
                    let soc = Arc::clone(self.runtime.soc(i));
                    let mut dev = device_lock(&soc);
                    let mut mgr = residency_lock(&self.residency[i]);
                    for inst in &le.rungs {
                        mgr.remove(&mut dev, inst.compiled.uid());
                    }
                }
            }
        }
    }

    pub fn has(&self, kind: WorkloadKind) -> bool {
        self.models.contains_key(&kind)
    }

    pub fn model(&self, kind: WorkloadKind) -> Option<&ModelInstance> {
        self.models.get(&kind).map(|e| match e {
            ModelEntry::Whole(inst) => inst.as_ref(),
            ModelEntry::Sharded(se) => se.inst.as_ref(),
            // a ladder's canonical metadata is its highest-fidelity rung
            ModelEntry::Ladder(le) => le.rungs[0].as_ref(),
        })
    }

    /// Shard placement of a kind: `Some(replicas)` (shard `i` on
    /// `replicas[i]`) when the model is sharded, `None` when whole.
    pub fn shard_placement(&self, kind: WorkloadKind) -> Option<&[usize]> {
        match self.models.get(&kind)? {
            ModelEntry::Whole(_) | ModelEntry::Ladder(_) => None,
            ModelEntry::Sharded(se) => Some(&se.replicas),
        }
    }

    /// Choose the serving replica for one whole-model dispatch. Strict
    /// round-robin over the active set by default; when warm affinity
    /// is enabled **and** the round-robin target's catalog rotates
    /// (combined footprint over budget), the dispatch prefers an active
    /// replica whose manager believes `uid` is already warm — a cold
    /// landing on a rotating catalog costs an evict → re-warm cycle.
    /// The cursor advances exactly one step per request either way, so
    /// affinity never changes the placement of an under-budget fleet
    /// (the round-robin differentials stay exact) and traffic keeps
    /// probing forward when no warm home exists.
    fn pick_replica(&mut self, uid: u64) -> usize {
        let rr = self.next_replica % self.active;
        self.next_replica = (rr + 1) % self.active;
        if !self.warm_affinity {
            return rr;
        }
        {
            let mgr = residency_lock(&self.residency[rr]);
            if mgr.catalog_bytes() <= mgr.budget() || mgr.warm_hint(uid) {
                return rr;
            }
        }
        // the round-robin target would have to rotate for this model —
        // scan the rest of the active set for a believed-warm home
        for off in 1..self.active {
            let cand = (rr + off) % self.active;
            if residency_lock(&self.residency[cand]).warm_hint(uid) {
                return cand;
            }
        }
        rr
    }

    /// Gateway prediction for worker warm-ahead
    /// ([`RuntimeConfig::warm_ahead`]): the next registered whole model
    /// believed **cold** on `replica`, scanning kinds in the fixed
    /// [`WorkloadKind::ALL`] order so the prediction is deterministic.
    /// `None` when the feature is off, or every other registered whole
    /// model is already warm there.
    fn predict_warm_ahead(&self, replica: usize, current: u64) -> Option<Arc<ModelInstance>> {
        if !self.warm_ahead {
            return None;
        }
        let mgr = residency_lock(&self.residency[replica]);
        for kind in WorkloadKind::ALL {
            if let Some(ModelEntry::Whole(inst)) = self.models.get(&kind) {
                let uid = inst.compiled.uid();
                if uid != current && !mgr.warm_hint(uid) {
                    return Some(Arc::clone(inst));
                }
            }
        }
        None
    }

    /// Submit one request to the runtime; returns immediately with a
    /// completion handle. Whole-model kinds round-robin over the active
    /// replica set (same-replica requests serialize in FIFO order),
    /// with warm-affinity refinement on rotating catalogs
    /// ([`RuntimeConfig::warm_affinity`]); a
    /// sharded kind serves through a per-request coordinator that
    /// scatters each layer to the shard-holding replicas and reduces the
    /// partial quires — shard replicas receive their partial jobs
    /// directly, regardless of the active set.
    pub fn submit(
        &mut self,
        kind: WorkloadKind,
        input: Vec<f32>,
        aux: Vec<f32>,
    ) -> Result<InferCompletion> {
        let Some(entry) = self.models.get(&kind) else {
            bail!("no model registered for {:?}", kind);
        };
        let (inst, rung) = match entry {
            ModelEntry::Whole(inst) => (Arc::clone(inst), None),
            ModelEntry::Ladder(le) => {
                // serve the router's current rung (ticks move it; the
                // clamp is defensive — registration sizes the counters)
                let r = self.ladder_rung.min(le.rungs.len() - 1);
                (Arc::clone(&le.rungs[r]), Some(r))
            }
            ModelEntry::Sharded(se) => {
                let se = Arc::clone(se);
                let rt = Arc::clone(&self.runtime);
                let gate = Arc::clone(&self.sharded_inflight);
                {
                    let mut n = match gate.0.lock() {
                        Ok(g) => g,
                        Err(poisoned) => poisoned.into_inner(),
                    };
                    *n += 1;
                }
                let (tx, rx) = crate::serve::completion();
                let trace = self.mint_ctx();
                if let Some(tr) = &trace {
                    tr.emit(se.replicas[0], 0, 0, TraceEvent::Submit { kind: kind.name() });
                    tr.emit(se.replicas[0], 0, 0, TraceEvent::Enqueue);
                }
                let task: Box<dyn FnOnce() + Send> = Box::new(move || {
                    // panic-fenced like the replica workers: a dying
                    // coordinator must still release the quiesce gate
                    // and fail its waiter with a typed error, never
                    // wedge the router
                    let panic_trace = trace.clone();
                    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        se.serve(&rt, input, aux, trace)
                    }));
                    // account before fulfilling (the worker invariant)
                    {
                        let mut n = match gate.0.lock() {
                            Ok(g) => g,
                            Err(poisoned) => poisoned.into_inner(),
                        };
                        *n -= 1;
                        gate.1.notify_all();
                    }
                    tx.fulfill(match res {
                        Ok(r) => r,
                        Err(p) => {
                            if let Some(tr) = &panic_trace {
                                tr.emit(se.replicas[0], 0, 0, TraceEvent::WorkerPanic);
                            }
                            Err(crate::serve::WorkerPanic::new(se.replicas[0], p).into())
                        }
                    });
                });
                // the coordinator pool replaces the PR-4 per-request
                // thread spawn; a full task queue back-pressures here
                let n_rep = self.runtime.n_replicas();
                let cap = self.queue_capacity;
                let pool = self
                    .coordinator_pool
                    .get_or_insert_with(|| CoordinatorPool::new(n_rep.clamp(2, 8), cap));
                if pool.queue.push(task).is_err() {
                    let (lock, cv) = &*self.sharded_inflight;
                    let mut n = match lock.lock() {
                        Ok(g) => g,
                        Err(poisoned) => poisoned.into_inner(),
                    };
                    *n -= 1;
                    cv.notify_all();
                    drop(n);
                    bail!("coordinator pool is shut down");
                }
                *self.served.entry(kind).or_insert(0) += 1;
                return Ok(rx);
            }
        };
        let replica = self.pick_replica(inst.compiled.uid());
        // in-flight pin: from dispatch to job completion the model
        // cannot be an eviction victim on its replica
        let image: Arc<dyn ResidentImage> = Arc::clone(&inst.compiled) as Arc<dyn ResidentImage>;
        residency_lock(&self.residency[replica]).pin_image(&image);
        let warm_ahead = self.predict_warm_ahead(replica, inst.compiled.uid());
        let (tx, rx) = crate::serve::completion();
        let trace = self.mint_ctx();
        if let Some(tr) = &trace {
            tr.emit(replica, 0, 0, TraceEvent::Submit { kind: kind.name() });
            tr.emit(replica, 0, 0, TraceEvent::Enqueue);
        }
        let job = Job {
            enqueued: host_now(),
            trace,
            payload: JobPayload::Infer {
                kind,
                inst,
                input,
                aux,
                residency: Some(Arc::clone(&self.residency[replica])),
                warm_ahead,
                done: tx,
            },
        };
        if self.runtime.dispatch(replica, job).is_err() {
            residency_lock(&self.residency[replica]).unpin(image.uid());
            bail!("serving runtime is shut down");
        }
        if let Some(r) = rung {
            self.ladder_served[r] += 1;
        }
        *self.served.entry(kind).or_insert(0) += 1;
        Ok(rx)
    }

    /// Submit every request of a released [`Batch`]; returns completion
    /// handles in request order. Requests spread round-robin over the
    /// active replicas, continuing where [`Router::submit`] left off;
    /// the per-replica queues preserve batch order, so results are
    /// bit-identical to routing each request through [`Router::route`].
    pub fn submit_batch(
        &mut self,
        kind: WorkloadKind,
        batch: &Batch,
    ) -> Result<Vec<InferCompletion>> {
        let reqs = &batch.requests;
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        if matches!(self.models.get(&kind), Some(ModelEntry::Sharded(_))) {
            // sharded kinds pipeline through per-request coordinators
            return reqs
                .iter()
                .map(|r| self.submit(kind, r.input.clone(), r.aux.clone()))
                .collect();
        }
        let (inst, rung) = match self.models.get(&kind) {
            None => bail!("no model registered for {:?}", kind),
            Some(ModelEntry::Sharded(_)) => unreachable!("handled above"),
            Some(ModelEntry::Whole(inst)) => (Arc::clone(inst), None),
            Some(ModelEntry::Ladder(le)) => {
                // the whole batch serves on one rung — a tick between
                // batches, not within one, is what moves the ladder
                let r = self.ladder_rung.min(le.rungs.len() - 1);
                (Arc::clone(&le.rungs[r]), Some(r))
            }
        };
        let offset = self.next_replica % self.active;
        self.next_replica = (offset + reqs.len()) % self.active;
        let image: Arc<dyn ResidentImage> = Arc::clone(&inst.compiled) as Arc<dyn ResidentImage>;
        let mut handles = Vec::with_capacity(reqs.len());
        for (i, r) in reqs.iter().enumerate() {
            let replica = (offset + i) % self.active;
            residency_lock(&self.residency[replica]).pin_image(&image);
            let (tx, rx) = crate::serve::completion();
            let trace = self.mint_ctx();
            if let Some(tr) = &trace {
                tr.emit(replica, 0, 0, TraceEvent::Submit { kind: kind.name() });
                tr.emit(replica, 0, 0, TraceEvent::Enqueue);
            }
            let job = Job {
                enqueued: host_now(),
                trace,
                payload: JobPayload::Infer {
                    kind,
                    inst: Arc::clone(&inst),
                    input: r.input.clone(),
                    aux: r.aux.clone(),
                    residency: Some(Arc::clone(&self.residency[replica])),
                    warm_ahead: self.predict_warm_ahead(replica, inst.compiled.uid()),
                    done: tx,
                },
            };
            if self.runtime.dispatch(replica, job).is_err() {
                residency_lock(&self.residency[replica]).unpin(image.uid());
                bail!("serving runtime is shut down");
            }
            handles.push(rx);
        }
        if let Some(r) = rung {
            self.ladder_served[r] += reqs.len() as u64;
        }
        *self.served.entry(kind).or_insert(0) += reqs.len() as u64;
        Ok(handles)
    }

    /// Redeem a completion handle (blocking).
    pub fn resolve(c: InferCompletion) -> Result<RoutedResult> {
        match c.wait() {
            Ok(res) => res,
            Err(canceled) => Err(canceled.into()),
        }
    }

    /// Route one request and wait for it — a blocking wrapper over
    /// [`Router::submit`] for whole-model kinds. For a **sharded** kind
    /// the coordinator loop runs **inline on the submitting thread**
    /// (the ROADMAP follow-up): route is going to block for the result
    /// anyway, so a handoff to a coordinator thread would buy nothing
    /// but spawn/queue overhead — only the partial GEMMs hop to the
    /// shard replicas' workers.
    pub fn route(&mut self, kind: WorkloadKind, input: &[f32], aux: &[f32]) -> Result<RoutedResult> {
        if let Some(ModelEntry::Sharded(se)) = self.models.get(&kind) {
            let se = Arc::clone(se);
            *self.served.entry(kind).or_insert(0) += 1;
            let trace = self.mint_ctx();
            if let Some(tr) = &trace {
                tr.emit(se.replicas[0], 0, 0, TraceEvent::Submit { kind: kind.name() });
                tr.emit(se.replicas[0], 0, 0, TraceEvent::Enqueue);
            }
            return se.serve(&self.runtime, input.to_vec(), aux.to_vec(), trace);
        }
        Router::resolve(self.submit(kind, input.to_vec(), aux.to_vec())?)
    }

    /// Execute every request of a released [`Batch`] and wait for all of
    /// them — a blocking wrapper over [`Router::submit_batch`]. Results
    /// come back in request order.
    pub fn route_batch(&mut self, kind: WorkloadKind, batch: &Batch) -> Result<Vec<RoutedResult>> {
        self.submit_batch(kind, batch)?.into_iter().map(Router::resolve).collect()
    }

    /// The legacy PR 2 synchronous fan-out: scoped threads per batch,
    /// blocking until the slowest replica drains. Kept as the reference
    /// the runtime path is differentially tested against (identical
    /// replica assignment, values, and cycle/stat reports) and as the
    /// baseline of the `hotpath` bench's async-vs-sync section.
    pub fn route_batch_fanout(
        &mut self,
        kind: WorkloadKind,
        batch: &Batch,
    ) -> Result<Vec<RoutedResult>> {
        let reqs = &batch.requests;
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        let inst = match self.models.get(&kind) {
            None => bail!("no model registered for {:?}", kind),
            Some(ModelEntry::Sharded(_)) => {
                bail!("sharded models serve via submit/route (the runtime path), not the fan-out")
            }
            Some(ModelEntry::Ladder(_)) => {
                bail!("ladder models serve via submit/route (the runtime path), not the fan-out")
            }
            Some(ModelEntry::Whole(inst)) => inst,
        };
        let offset = self.next_replica % self.active;
        self.next_replica = (offset + reqs.len()) % self.active;
        let mut buckets: Vec<Vec<usize>> = vec![Vec::new(); self.active];
        for i in 0..reqs.len() {
            buckets[(offset + i) % self.active].push(i);
        }
        // budget admission, exactly like the runtime path: warm (and
        // pin) the model on every replica that will serve a bucket
        // through its catalog manager, so the legacy fan-out neither
        // over-commits a rotating catalog's budget nor fails where
        // `route` would evict-and-serve; only the serving itself stays
        // synchronous (admission adds no device cycles — the
        // fanout-vs-async differentials stay bit-identical)
        let image: Arc<dyn ResidentImage> = Arc::clone(&inst.compiled) as Arc<dyn ResidentImage>;
        let mut pinned: Vec<usize> = Vec::new();
        for (ri, idxs) in buckets.iter().enumerate() {
            if idxs.is_empty() {
                continue;
            }
            let soc = Arc::clone(self.runtime.soc(ri));
            let mut dev = device_lock(&soc);
            let mut mgr = residency_lock(&self.residency[ri]);
            mgr.pin_image(&image);
            if let Err(e) = mgr.admit(&mut dev, &image) {
                mgr.unpin(image.uid());
                drop(mgr);
                drop(dev);
                for &rj in &pinned {
                    residency_lock(&self.residency[rj]).unpin(image.uid());
                }
                return Err(e.into());
            }
            pinned.push(ri);
        }
        // panic-fenced so a dying serving thread cannot leak the batch
        // pins past the unpin below (the worker path contains job
        // panics the same way)
        let fanned = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            std::thread::scope(|s| {
                let handles: Vec<_> = buckets
                    .into_iter()
                    .enumerate()
                    .map(|(ri, idxs)| {
                        let soc = Arc::clone(self.runtime.soc(ri));
                        let inst = Arc::clone(inst);
                        s.spawn(move || {
                            let mut soc = device_lock(&soc);
                            idxs.into_iter()
                                .map(|i| {
                                    let r = &reqs[i];
                                    let (output, report) =
                                        inst.infer(&mut soc, &r.input, &r.aux)?;
                                    Ok((i, RoutedResult { kind, output, report, replica: ri }))
                                })
                                .collect::<Result<Vec<_>>>()
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    // xr_lint: allow(no-panic) -- a scoped-thread panic is re-raised here on purpose; the outer catch_unwind fence contains it
                    .map(|h| h.join().expect("replica worker panicked"))
                    .collect::<Vec<Result<Vec<(usize, RoutedResult)>>>>()
            })
        }));
        // release the batch pins before surfacing any error or panic
        for &ri in &pinned {
            residency_lock(&self.residency[ri]).unpin(image.uid());
        }
        let per_replica = match fanned {
            Ok(v) => v,
            Err(p) => std::panic::resume_unwind(p),
        };
        let mut slots: Vec<Option<RoutedResult>> = Vec::new();
        slots.resize_with(reqs.len(), || None);
        for chunk in per_replica {
            for (i, res) in chunk? {
                slots[i] = Some(res);
            }
        }
        *self.served.entry(kind).or_insert(0) += reqs.len() as u64;
        // xr_lint: allow(no-panic) -- the buckets partition 0..reqs.len(), so every slot is filled
        Ok(slots.into_iter().map(|r| r.expect("missing batch result")).collect())
    }

    /// One autoscaling tick: feed the queue-latency samples recorded
    /// since the last tick to the policy and apply its decision to the
    /// active dispatch set (in-flight load gates idle parking — a
    /// backlogged fleet is never parked). Returns the new active count.
    pub fn autoscale_tick(&mut self) -> usize {
        let (fresh, total) = self.runtime.queue_samples_since(self.fed_samples);
        self.fed_samples = total;
        self.autoscaler.observe_samples(&fresh);
        let target = self.autoscaler.decide(self.active, self.runtime.in_flight());
        self.active = target.clamp(1, self.runtime.n_replicas());
        self.steered_active = Some(self.active);
        self.emit_fleet_event(TraceEvent::AutoscaleDecision { active: self.active });
        self.active
    }

    /// Replicas currently receiving dispatch.
    pub fn active_replicas(&self) -> usize {
        self.active
    }

    /// Force the active dispatch set (clamped to `[1, n_replicas]`) —
    /// load-shaping for tests/benches; the autoscaler adjusts from here.
    pub fn set_active(&mut self, n: usize) {
        self.active = n.clamp(1, self.runtime.n_replicas());
        self.steered_active = Some(self.active);
        self.next_replica %= self.active;
    }

    /// Block until every submitted request has completed — including
    /// in-flight sharded coordinator loops and the partial jobs they
    /// scattered.
    pub fn quiesce(&self) {
        let (lock, cv) = &*self.sharded_inflight;
        let mut n = match lock.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        while *n > 0 {
            n = match cv.wait(n) {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
        }
        drop(n);
        self.runtime.quiesce();
    }

    /// One wall-clock-free autoscaling tick: feed the runtime's fresh
    /// **simulated service-cycle** samples to the [`CycleAutoscaler`]
    /// and apply its congestion decision (queue depth × mean service
    /// cycles) to the active dispatch set. Fully reproducible — every
    /// input is simulator output, so tests need no host-speed-tuned
    /// thresholds (the alternative to the nanosecond-driven
    /// [`Router::autoscale_tick`]).
    pub fn autoscale_tick_cycles(&mut self, policy: &mut CycleAutoscaler) -> usize {
        let (fresh, total) = self.runtime.service_cycle_samples_since(self.fed_cycle_samples);
        self.fed_cycle_samples = total;
        policy.observe_samples(&fresh);
        let depth: usize =
            (0..self.runtime.n_replicas()).map(|i| self.runtime.queue_len(i)).sum();
        let target = policy.decide(self.active, self.runtime.in_flight(), depth);
        self.active = target.clamp(1, self.runtime.n_replicas());
        self.steered_active = Some(self.active);
        self.emit_fleet_event(TraceEvent::AutoscaleDecision { active: self.active });
        self.active
    }

    /// The registered ladder entry, if any (fixed [`WorkloadKind::ALL`]
    /// scan order, so multi-kind fleets resolve deterministically).
    fn ladder_entry(&self) -> Option<&Arc<LadderEntry>> {
        WorkloadKind::ALL.iter().find_map(|k| match self.models.get(k) {
            Some(ModelEntry::Ladder(le)) => Some(le),
            _ => None,
        })
    }

    /// One wall-clock-free **precision-ladder** tick: feed the
    /// runtime's fresh simulated service-cycle samples to the
    /// [`LadderPolicy`] (its own sample checkpoint — it never steals
    /// the cycle autoscaler's feed) and apply its congestion decision
    /// to the dispatch rung. Live queue depth is sampled from the
    /// replica queues; for deterministic tests and benches drive
    /// [`Router::ladder_tick_with`] with a seeded depth trace instead.
    /// Returns the rung subsequent dispatch will serve.
    pub fn ladder_tick_cycles(&mut self, policy: &mut LadderPolicy) -> usize {
        let depth: usize =
            (0..self.runtime.n_replicas()).map(|i| self.runtime.queue_len(i)).sum();
        self.ladder_tick_with(policy, depth)
    }

    /// [`Router::ladder_tick_cycles`] with an **explicit queue depth**
    /// — the deterministic form: every input (service-cycle samples,
    /// depth, in-flight count at a quiesced checkpoint) is simulator
    /// output or caller-seeded, so a fixed congestion trace replays to
    /// a byte-identical switch sequence. No-op (returns 0) when no
    /// ladder is registered.
    pub fn ladder_tick_with(&mut self, policy: &mut LadderPolicy, queue_depth: usize) -> usize {
        let Some(n_rungs) = self.ladder_entry().map(|le| le.rungs.len()) else {
            return 0;
        };
        let (fresh, total) = self.runtime.service_cycle_samples_since(self.fed_ladder_samples);
        self.fed_ladder_samples = total;
        policy.observe_samples(&fresh);
        let target = policy.decide(n_rungs, self.runtime.in_flight(), queue_depth);
        if target != self.ladder_rung {
            self.ladder_rung = target;
            self.ladder_switches += 1;
            self.emit_fleet_event(TraceEvent::LadderSwitch { rung: target });
        }
        self.ladder_rung
    }

    /// The precision-ladder rung subsequent dispatch will serve (0 when
    /// no ladder is registered).
    pub fn ladder_rung(&self) -> usize {
        self.ladder_rung
    }

    /// Rung switches applied by ladder ticks since registration.
    pub fn ladder_switches(&self) -> u64 {
        self.ladder_switches
    }

    /// Requests dispatched per rung — empty when no ladder is
    /// registered (the registry snapshot gates its `sim_ladder_*` keys
    /// on that).
    pub fn ladder_served(&self) -> Vec<u64> {
        self.ladder_served.clone()
    }

    /// Per-rung accuracy-proxy scores from the quantization
    /// sensitivity model (fixed-point `distortion_score × 1e6`; see
    /// [`ModelInstance::ladder`]). Empty when no ladder is registered.
    pub fn ladder_scores(&self) -> Vec<u64> {
        self.ladder_entry().map(|le| le.scores.clone()).unwrap_or_default()
    }

    /// Force the dispatch rung (clamped to the ladder length; no-op
    /// when no ladder is registered) — load-shaping for tests and
    /// benches, exactly like [`Router::set_active`] for replicas.
    /// Ladder ticks adjust from here; a forced move does not count as a
    /// switch.
    pub fn set_ladder_rung(&mut self, rung: usize) {
        if let Some(n) = self.ladder_entry().map(|le| le.rungs.len()) {
            self.ladder_rung = rung.min(n - 1);
        }
    }

    /// Host-side queue/service latency metrics from the runtime, with
    /// the fleet's residency counters folded in: evictions /
    /// compactions / cold-warms summed across replicas,
    /// `resident_high_water` the maximum over them.
    pub fn runtime_metrics(&self) -> RuntimeMetrics {
        let mut m = self.runtime.metrics();
        for mgr in &self.residency {
            let s = residency_lock(mgr).stats();
            m.evictions += s.evictions;
            m.compactions += s.compactions;
            m.cold_warms += s.cold_warms;
            m.resident_high_water = m.resident_high_water.max(s.resident_high_water);
        }
        m
    }

    /// Residency counters of replica `i`'s catalog manager.
    pub fn replica_residency_stats(&self, i: usize) -> ResidencyStats {
        residency_lock(&self.residency[i]).stats()
    }

    /// Jobs queued (not yet picked up) on replica `i`.
    pub fn replica_queue_len(&self, i: usize) -> usize {
        self.runtime.queue_len(i)
    }

    /// Total requests served.
    pub fn total_served(&self) -> u64 {
        self.served.values().sum()
    }

    /// Lifetime job report of replica `i` (snapshot).
    pub fn replica_lifetime(&self, i: usize) -> JobReport {
        device_lock(self.runtime.soc(i)).lifetime.clone()
    }

    /// AXI **management**-initiator traffic of replica `i`: resident-
    /// arena relocations, compaction copies and cold-model warm
    /// uploads, as charged by the shared-channel arbiter
    /// ([`crate::soc::AxiInitiator::Management`]). Snapshotted into the
    /// `sim_mgmt_*` registry keys by [`crate::obs::snapshot`].
    pub fn replica_axi_mgmt(&self, i: usize) -> InitiatorStats {
        device_lock(self.runtime.soc(i)).management_traffic()
    }

    /// [`CacheStats`] of replica `i`'s operand-encoding cache.
    pub fn replica_cache_stats(&self, i: usize) -> CacheStats {
        let soc = device_lock(self.runtime.soc(i));
        let c = &soc.enc_cache;
        CacheStats { hits: c.hits, misses: c.misses, preloads: c.preloads, trusted: c.trusted }
    }

    /// Pinned (weight-preload) entries resident in replica `i`'s cache.
    pub fn replica_pinned_len(&self, i: usize) -> usize {
        device_lock(self.runtime.soc(i)).enc_cache.pinned_len()
    }

    /// Resident-DRAM accounting of replica `i`: `(bump watermark bytes,
    /// reclaimed-but-buried free-list bytes)`.
    pub fn replica_resident(&self, i: usize) -> (u64, u64) {
        let soc = device_lock(self.runtime.soc(i));
        (soc.resident_mark(), soc.resident_free_bytes())
    }

    pub fn n_replicas(&self) -> usize {
        self.runtime.n_replicas()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::random_weights as weights_for;
    use crate::models::{effnet, gaze};
    use crate::npe::PrecSel;

    #[test]
    fn routes_to_registered_model() {
        let mut r = Router::new(1, SocConfig::default());
        let g = gaze::build();
        let w = weights_for(&g, 1);
        r.register(WorkloadKind::Gaze, ModelInstance::uniform(g, w, PrecSel::Posit8x2).unwrap()).unwrap();
        let out = r.route(WorkloadKind::Gaze, &vec![0.1; 16], &[]).unwrap();
        assert_eq!(out.output.len(), 2);
        assert_eq!(r.total_served(), 1);
    }

    #[test]
    fn unregistered_kind_errors() {
        let mut r = Router::new(1, SocConfig::default());
        assert!(r.route(WorkloadKind::Vio, &[], &[]).is_err());
        assert!(r.submit(WorkloadKind::Vio, vec![], vec![]).is_err());
    }

    #[test]
    fn round_robin_across_replicas() {
        let mut r = Router::new(3, SocConfig::default());
        let g = gaze::build();
        let w = weights_for(&g, 2);
        r.register(WorkloadKind::Gaze, ModelInstance::uniform(g, w, PrecSel::Fp4x4).unwrap()).unwrap();
        let mut hits = vec![0u32; 3];
        for _ in 0..9 {
            let res = r.route(WorkloadKind::Gaze, &vec![0.1; 16], &[]).unwrap();
            hits[res.replica] += 1;
        }
        assert_eq!(hits, vec![3, 3, 3]);
    }

    #[test]
    fn batch_route_matches_serial_route() {
        use crate::coordinator::batcher::Request;
        let mut r = Router::new(3, SocConfig::default());
        let g = gaze::build();
        let w = weights_for(&g, 5);
        r.register(WorkloadKind::Gaze, ModelInstance::uniform(g, w, PrecSel::Posit8x2).unwrap()).unwrap();
        let inputs: Vec<Vec<f32>> = (0..7).map(|i| vec![0.02 * i as f32; 16]).collect();
        // serial reference outputs (numerics are replica-independent)
        let mut want = Vec::new();
        for x in &inputs {
            want.push(r.route(WorkloadKind::Gaze, x, &[]).unwrap().output);
        }
        let batch = Batch {
            requests: inputs
                .iter()
                .enumerate()
                .map(|(i, x)| Request {
                    id: i as u64,
                    input: x.clone(),
                    aux: vec![],
                    arrived: i as u64,
                })
                .collect(),
            released: 10,
        };
        let res = r.route_batch(WorkloadKind::Gaze, &batch).unwrap();
        assert_eq!(res.len(), 7);
        for (i, got) in res.iter().enumerate() {
            assert_eq!(got.output, want[i], "request {i}");
            // round-robin continues where the 7 serial route() calls left off
            assert_eq!(got.replica, (7 + i) % 3);
        }
        assert_eq!(r.served[&WorkloadKind::Gaze], 14);
    }

    #[test]
    fn consecutive_small_batches_rotate_replicas() {
        use crate::coordinator::batcher::Request;
        let mut r = Router::new(3, SocConfig::default());
        let g = gaze::build();
        let w = weights_for(&g, 6);
        r.register(WorkloadKind::Gaze, ModelInstance::uniform(g, w, PrecSel::Fp4x4).unwrap()).unwrap();
        let mut hits = vec![0u32; 3];
        for b in 0..6 {
            let batch = Batch {
                requests: vec![Request {
                    id: b,
                    input: vec![0.1; 16],
                    aux: vec![],
                    arrived: b,
                }],
                released: b,
            };
            let res = r.route_batch(WorkloadKind::Gaze, &batch).unwrap();
            hits[res[0].replica] += 1;
        }
        assert_eq!(hits, vec![2, 2, 2], "size-1 batches must still rotate replicas");
    }

    #[test]
    fn batch_route_empty_and_unregistered() {
        let mut r = Router::new(2, SocConfig::default());
        let empty = Batch { requests: vec![], released: 0 };
        assert!(r.route_batch(WorkloadKind::Vio, &empty).unwrap().is_empty());
        assert!(r.submit_batch(WorkloadKind::Vio, &empty).unwrap().is_empty());
        use crate::coordinator::batcher::Request;
        let one = Batch {
            requests: vec![Request { id: 0, input: vec![], aux: vec![], arrived: 0 }],
            released: 0,
        };
        assert!(r.route_batch(WorkloadKind::Vio, &one).is_err());
    }

    #[test]
    fn registration_warms_floor_then_serving_warms_on_demand() {
        // default runtime: warm floor 1 — replica 0 is warm at
        // registration, the others warm at their first request
        let mut r = Router::new(3, SocConfig::default());
        let g = gaze::build();
        let n_gemm = g.compute_layers().len() as u64;
        let w = weights_for(&g, 7);
        r.register(WorkloadKind::Gaze, ModelInstance::uniform(g, w, PrecSel::Posit8x2).unwrap())
            .unwrap();
        let stats: Vec<_> = (0..3).map(|i| r.replica_cache_stats(i).as_tuple()).collect();
        assert_eq!(stats[0], (0, 0, n_gemm, 0), "floor replica is warm");
        assert_eq!(stats[1], (0, 0, 0, 0), "replica 1 not warmed yet");
        assert_eq!(stats[2], (0, 0, 0, 0), "replica 2 not warmed yet");
        // 6 distinct requests round-robin over 3 replicas: each replica
        // warms at its first request, weights ride trusted pins past the
        // cache, only activations encode
        for q in 0..6 {
            r.route(WorkloadKind::Gaze, &vec![0.01 * q as f32; 16], &[]).unwrap();
        }
        for i in 0..3 {
            let CacheStats { hits, misses, preloads, trusted } = r.replica_cache_stats(i);
            assert_eq!(preloads, n_gemm, "replica {i} warmed (eagerly or on demand)");
            assert_eq!(hits, 0, "replica {i}: weights never consult the cache");
            assert_eq!(misses, 2 * n_gemm, "replica {i}: only activations encode");
            assert_eq!(trusted, 2 * n_gemm, "replica {i}: weights ride trusted pins");
        }
    }

    #[test]
    fn warm_floor_covers_all_replicas_when_configured() {
        let rt = RuntimeConfig { warm_floor: 3, ..Default::default() };
        let mut r = Router::with_runtime(3, SocConfig::default(), rt);
        let g = gaze::build();
        let n_gemm = g.compute_layers().len() as u64;
        let w = weights_for(&g, 8);
        r.register(WorkloadKind::Gaze, ModelInstance::uniform(g, w, PrecSel::Posit8x2).unwrap())
            .unwrap();
        for i in 0..3 {
            let (hits, misses, preloads, trusted) = r.replica_cache_stats(i).as_tuple();
            assert_eq!((hits, misses, preloads, trusted), (0, 0, n_gemm, 0), "replica {i}");
        }
    }

    #[test]
    fn failed_registration_leaves_router_usable() {
        // 32 KiB DRAM → 24 KiB resident budget: effnet (~83 KiB warm
        // footprint) can never fit, gaze (~21 KiB) can
        let cfg = SocConfig { dram_bytes: 1 << 15, ..Default::default() };
        let mut r = Router::new(2, cfg);
        let ge = effnet::build();
        let we = weights_for(&ge, 20);
        assert!(r
            .register(WorkloadKind::Classify, ModelInstance::uniform(ge, we, PrecSel::Posit8x2).unwrap())
            .is_err());
        let gg = gaze::build();
        let wg = weights_for(&gg, 21);
        r.register(WorkloadKind::Gaze, ModelInstance::uniform(gg, wg, PrecSel::Posit8x2).unwrap())
            .unwrap();
        let out = r.route(WorkloadKind::Gaze, &vec![0.1; 16], &[]).unwrap();
        assert_eq!(out.output.len(), 2);
    }

    #[test]
    fn reregistering_a_kind_evicts_the_old_warm_state() {
        let rt = RuntimeConfig { warm_floor: 2, ..Default::default() };
        let mut r = Router::with_runtime(2, SocConfig::default(), rt);
        let g = gaze::build();
        let n_gemm = g.compute_layers().len();
        let w1 = weights_for(&g, 30);
        r.register(WorkloadKind::Gaze, ModelInstance::uniform(g.clone(), w1, PrecSel::Posit8x2).unwrap())
            .unwrap();
        let w2 = weights_for(&g, 31);
        r.register(WorkloadKind::Gaze, ModelInstance::uniform(g.clone(), w2, PrecSel::Posit8x2).unwrap())
            .unwrap();
        for i in 0..2 {
            // the replaced model's pinned encodings are gone — only the
            // live model's weights stay pinned
            assert_eq!(r.replica_pinned_len(i), n_gemm, "replica {i}");
        }
        let out = r.route(WorkloadKind::Gaze, &vec![0.1; 16], &[]).unwrap();
        assert_eq!(out.output.len(), 2);
    }

    #[test]
    fn reregister_refresh_loop_keeps_resident_watermark_flat() {
        // the PR-2 leak: Router::register warms the new model *above*
        // the old one, so the evicted old image is always buried and —
        // without the free list — every refresh grew resident DRAM by a
        // full model. Now the freed spans are reused first-fit.
        let mut r = Router::new(1, SocConfig::default());
        let g = gaze::build();
        let w0 = weights_for(&g, 50);
        r.register(WorkloadKind::Gaze, ModelInstance::uniform(g.clone(), w0, PrecSel::Posit8x2).unwrap())
            .unwrap();
        let w1 = weights_for(&g, 51);
        r.register(WorkloadKind::Gaze, ModelInstance::uniform(g.clone(), w1, PrecSel::Posit8x2).unwrap())
            .unwrap();
        // peak: the moment both old and new coexist during the handover
        let (peak, _) = r.replica_resident(0);
        for seed in 52..57 {
            let w = weights_for(&g, seed);
            r.register(
                WorkloadKind::Gaze,
                ModelInstance::uniform(g.clone(), w, PrecSel::Posit8x2).unwrap(),
            )
            .unwrap();
            let (mark, _) = r.replica_resident(0);
            assert!(
                mark <= peak,
                "seed {seed}: resident watermark {mark} grew past the two-model peak {peak}"
            );
            // the refreshed model still serves
            let out = r.route(WorkloadKind::Gaze, &vec![0.1; 16], &[]).unwrap();
            assert_eq!(out.output.len(), 2);
        }
    }

    #[test]
    fn mixed_workloads_share_replicas() {
        let mut r = Router::new(2, SocConfig::default());
        let gg = gaze::build();
        let wg = weights_for(&gg, 3);
        r.register(WorkloadKind::Gaze, ModelInstance::uniform(gg, wg, PrecSel::Posit8x2).unwrap()).unwrap();
        let gc = effnet::build();
        let wc = weights_for(&gc, 4);
        r.register(WorkloadKind::Classify, ModelInstance::uniform(gc, wc, PrecSel::Fp4x4).unwrap()).unwrap();
        r.route(WorkloadKind::Gaze, &vec![0.1; 16], &[]).unwrap();
        r.route(WorkloadKind::Classify, &vec![0.1; 256], &[]).unwrap();
        assert_eq!(r.total_served(), 2);
        assert_eq!(r.served[&WorkloadKind::Gaze], 1);
    }

    #[test]
    fn warm_ahead_streams_the_predicted_cold_model_behind_a_request() {
        // gateway-predicted warm-ahead: the second gaze request lands on
        // never-warmed replica 1, and its worker streams the still-cold
        // classify model in right behind it on the management budget —
        // while an identical warm-ahead-off fleet leaves classify cold
        // there. Serving values are bit-identical either way.
        let gg = gaze::build();
        let wg = weights_for(&gg, 70);
        let gc = effnet::build();
        let wc = weights_for(&gc, 71);
        let build_router = |warm_ahead: bool| {
            let rt = RuntimeConfig { warm_ahead, ..Default::default() };
            let mut r = Router::with_runtime(2, SocConfig::default(), rt);
            r.register(
                WorkloadKind::Gaze,
                ModelInstance::uniform(gg.clone(), wg.clone(), PrecSel::Posit8x2).unwrap(),
            )
            .unwrap();
            r.register(
                WorkloadKind::Classify,
                ModelInstance::uniform(gc.clone(), wc.clone(), PrecSel::Fp4x4).unwrap(),
            )
            .unwrap();
            r
        };
        let mut on = build_router(true);
        let mut off = build_router(false);
        let classify_uid = on.model(WorkloadKind::Classify).unwrap().compiled.uid();
        for q in 0..2 {
            let input = vec![0.03 * (q + 1) as f32; 16];
            let a = on.route(WorkloadKind::Gaze, &input, &[]).unwrap();
            let b = off.route(WorkloadKind::Gaze, &input, &[]).unwrap();
            assert_eq!(a.output, b.output, "req {q}: warm-ahead must not perturb values");
            assert_eq!(a.replica, b.replica, "req {q}: placement must match");
        }
        on.quiesce();
        off.quiesce();
        // request 1 served on replica 1 (round-robin), whose worker
        // warm-ahead-streamed classify in behind it
        assert!(
            residency_lock(&on.residency[1]).warm_hint(classify_uid),
            "warm-ahead must leave the predicted model warm on replica 1"
        );
        assert!(
            !residency_lock(&off.residency[1]).warm_hint(classify_uid),
            "test premise: without warm-ahead, classify stays cold on replica 1"
        );
        let mgmt_on = on.replica_axi_mgmt(1);
        let mgmt_off = off.replica_axi_mgmt(1);
        assert!(
            mgmt_on.bytes_written > mgmt_off.bytes_written,
            "the warm-ahead upload must be charged to the management initiator \
             ({mgmt_on:?} vs {mgmt_off:?})"
        );
        assert!(mgmt_on.cycles > 0);
    }

    #[test]
    fn set_active_confines_dispatch_and_parked_replicas_idle() {
        let mut r = Router::new(3, SocConfig::default());
        let g = gaze::build();
        let w = weights_for(&g, 40);
        r.register(WorkloadKind::Gaze, ModelInstance::uniform(g, w, PrecSel::Fp4x4).unwrap()).unwrap();
        r.set_active(1);
        for q in 0..4 {
            let res = r.route(WorkloadKind::Gaze, &vec![0.05 * q as f32; 16], &[]).unwrap();
            assert_eq!(res.replica, 0, "parked replicas must not receive dispatch");
        }
        assert_eq!(r.replica_lifetime(1).total_cycles, 0);
        assert_eq!(r.replica_lifetime(2).total_cycles, 0);
        // unpark: dispatch spreads again
        r.set_active(3);
        let mut hits = vec![0u32; 3];
        for _ in 0..6 {
            hits[r.route(WorkloadKind::Gaze, &vec![0.1; 16], &[]).unwrap().replica] += 1;
        }
        assert_eq!(hits, vec![2, 2, 2]);
    }

    #[test]
    fn sharded_serving_bit_identical_to_whole_all_modes() {
        // router-level acceptance differential: the same traffic through
        // a whole-model fleet and a 2-shard fleet must produce
        // bit-identical values in every mode; MAC work is conserved and
        // the sharded reports carry the documented reduction term
        let g = gaze::build();
        for (i, sel) in PrecSel::ALL.into_iter().enumerate() {
            let w = weights_for(&g, 60 + i as u64);
            let mut whole = Router::new(1, SocConfig::default());
            whole
                .register(
                    WorkloadKind::Gaze,
                    ModelInstance::uniform(g.clone(), w.clone(), sel).unwrap(),
                )
                .unwrap();
            let mut sharded = Router::new(2, SocConfig::default());
            sharded
                .register_sharded(
                    WorkloadKind::Gaze,
                    ModelInstance::uniform(g.clone(), w.clone(), sel).unwrap(),
                    2,
                )
                .unwrap();
            assert_eq!(sharded.shard_placement(WorkloadKind::Gaze).unwrap().len(), 2);
            for q in 0..3 {
                let input: Vec<f32> =
                    (0..16).map(|j| ((q * 16 + j) as f32 * 0.11).sin() * 0.4).collect();
                let want = whole.route(WorkloadKind::Gaze, &input, &[]).unwrap();
                let got = sharded.route(WorkloadKind::Gaze, &input, &[]).unwrap();
                assert_eq!(got.output, want.output, "{sel:?} req {q}: values diverged");
                assert_eq!(
                    got.report.jobs.array.macs, want.report.jobs.array.macs,
                    "{sel:?} req {q}: MAC work must be conserved"
                );
                assert!(got.report.reduce_cycles > 0, "{sel:?}: reduction term must appear");
                assert_eq!(want.report.reduce_cycles, 0, "{sel:?}: whole path has no reduction");
            }
            sharded.quiesce();
        }
    }

    #[test]
    fn register_auto_shards_an_oversized_model_and_serves_it() {
        // a model whose compiled footprint exceeds one replica's
        // resident budget: whole registration fails, register_auto
        // splits it across the fleet and serves bit-identically to a
        // big-DRAM whole-model reference
        let g = crate::models::mlp::build();
        let w = weights_for(&g, 61);
        let small = SocConfig { dram_bytes: 1 << 17, ..Default::default() };
        let mut r = Router::new(3, small);
        assert!(
            r.register(
                WorkloadKind::Classify,
                ModelInstance::uniform(g.clone(), w.clone(), PrecSel::Posit8x2).unwrap()
            )
            .is_err(),
            "test premise: the whole model must not fit a small replica"
        );
        r.register_auto(
            WorkloadKind::Classify,
            ModelInstance::uniform(g.clone(), w.clone(), PrecSel::Posit8x2).unwrap(),
        )
        .unwrap();
        let placement = r.shard_placement(WorkloadKind::Classify).expect("must be sharded");
        assert!(placement.len() >= 2, "needs >= 2 shards, got {placement:?}");
        let mut reference = Router::new(1, SocConfig::default());
        reference
            .register(WorkloadKind::Classify, ModelInstance::uniform(g, w, PrecSel::Posit8x2).unwrap())
            .unwrap();
        for q in 0..2 {
            let input: Vec<f32> =
                (0..256).map(|j| ((q * 7 + j) as f32 * 0.013).sin() * 0.4).collect();
            let want = reference.route(WorkloadKind::Classify, &input, &[]).unwrap();
            let got = r.route(WorkloadKind::Classify, &input, &[]).unwrap();
            assert_eq!(got.output, want.output, "req {q}: oversized sharded serving diverged");
        }
        r.quiesce();
        assert_eq!(r.served[&WorkloadKind::Classify], 2);
    }

    #[test]
    fn register_auto_keeps_whole_residency_when_the_model_fits() {
        let mut r = Router::new(2, SocConfig::default());
        let g = gaze::build();
        let w = weights_for(&g, 62);
        r.register_auto(WorkloadKind::Gaze, ModelInstance::uniform(g, w, PrecSel::Fp4x4).unwrap())
            .unwrap();
        assert!(r.shard_placement(WorkloadKind::Gaze).is_none(), "fitting model stays whole");
        assert_eq!(r.route(WorkloadKind::Gaze, &vec![0.1; 16], &[]).unwrap().output.len(), 2);
    }

    #[test]
    fn shard_count_one_is_literally_the_whole_path() {
        let mut r = Router::new(2, SocConfig::default());
        let g = gaze::build();
        let w = weights_for(&g, 63);
        r.register_sharded(
            WorkloadKind::Gaze,
            ModelInstance::uniform(g, w, PrecSel::Posit8x2).unwrap(),
            1,
        )
        .unwrap();
        assert!(r.shard_placement(WorkloadKind::Gaze).is_none());
        // round-robins like any whole registration
        let a = r.route(WorkloadKind::Gaze, &vec![0.1; 16], &[]).unwrap().replica;
        let b = r.route(WorkloadKind::Gaze, &vec![0.1; 16], &[]).unwrap().replica;
        assert_ne!(a, b);
    }

    #[test]
    fn sharded_submissions_pipeline_and_reregistration_evicts_shards() {
        let g = gaze::build();
        let w = weights_for(&g, 64);
        let mut r = Router::new(2, SocConfig::default());
        r.register_sharded(
            WorkloadKind::Gaze,
            ModelInstance::uniform(g.clone(), w.clone(), PrecSel::Posit8x2).unwrap(),
            2,
        )
        .unwrap();
        let n_gemm = g.compute_layers().len();
        for i in 0..2 {
            assert_eq!(r.replica_pinned_len(i), n_gemm, "replica {i}: one slice pin per layer");
        }
        // several requests in flight before any is redeemed
        let inputs: Vec<Vec<f32>> = (0..5).map(|i| vec![0.02 * i as f32; 16]).collect();
        let handles: Vec<_> = inputs
            .iter()
            .map(|x| r.submit(WorkloadKind::Gaze, x.clone(), vec![]).unwrap())
            .collect();
        let got: Vec<Vec<f32>> =
            handles.into_iter().map(|h| Router::resolve(h).unwrap().output).collect();
        // identical inputs give identical outputs later (warm state intact)
        let again = r.route(WorkloadKind::Gaze, &inputs[0], &[]).unwrap();
        assert_eq!(again.output, got[0]);
        // re-registering replaces the shard set and releases the old pins
        let w2 = weights_for(&g, 65);
        r.register_sharded(
            WorkloadKind::Gaze,
            ModelInstance::uniform(g.clone(), w2, PrecSel::Posit8x2).unwrap(),
            2,
        )
        .unwrap();
        for i in 0..2 {
            assert_eq!(r.replica_pinned_len(i), n_gemm, "replica {i}: old shard pins released");
        }
        r.quiesce();
        assert_eq!(r.served[&WorkloadKind::Gaze], 6);
    }

    #[test]
    fn steered_registration_warms_the_active_set() {
        // PR-3 follow-up: a fleet the operator/autoscaler has grown past
        // the warm floor warms the whole active set at registration, so
        // a model refresh pays no first-request warming
        let mut r = Router::new(3, SocConfig::default());
        let g = gaze::build();
        let n_gemm = g.compute_layers().len() as u64;
        r.set_active(3);
        let w = weights_for(&g, 66);
        r.register(WorkloadKind::Gaze, ModelInstance::uniform(g, w, PrecSel::Posit8x2).unwrap())
            .unwrap();
        for i in 0..3 {
            let preloads = r.replica_cache_stats(i).preloads;
            assert_eq!(preloads, n_gemm, "replica {i} must be warm at registration");
        }
    }

    #[test]
    fn cycle_autoscaler_ticks_are_reproducible() {
        use crate::serve::{CycleAutoscaleConfig, CycleAutoscaler};
        let mut r = Router::new(3, SocConfig::default());
        let g = gaze::build();
        let w = weights_for(&g, 67);
        r.register(WorkloadKind::Gaze, ModelInstance::uniform(g, w, PrecSel::Posit8x2).unwrap())
            .unwrap();
        let mut policy = CycleAutoscaler::new(CycleAutoscaleConfig {
            floor: 1,
            max: 3,
            scale_up: 1_000_000,
            scale_down: 10,
            window: 64,
            step: 1,
            idle_patience: 2,
        });
        for q in 0..4 {
            r.route(WorkloadKind::Gaze, &vec![0.01 * q as f32; 16], &[]).unwrap();
        }
        // traffic has fully drained: fresh samples arrive, zero queue
        // depth → congestion 0 <= scale_down → deterministic step-down
        assert_eq!(r.autoscale_tick_cycles(&mut policy), 2);
        // no fresh samples, nothing queued or in flight: idle patience
        assert_eq!(r.autoscale_tick_cycles(&mut policy), 2);
        assert_eq!(r.autoscale_tick_cycles(&mut policy), 1, "idle fleet parks to the floor");
    }

    /// Single-fc model with a precisely controllable warm footprint:
    /// align64(k·n·4) + align64(k·4) + align64(n·4).
    fn fc_inst(name: &str, k: usize, n: usize, sel: PrecSel, seed: u64) -> ModelInstance {
        use crate::models::graph::{Layer, LayerKind, ModelGraph, Shape};
        let g = ModelGraph {
            name: name.into(),
            input: Shape::vec(k),
            layers: vec![Layer { name: "fc".into(), kind: LayerKind::Fc { in_f: k, out_f: n } }],
        };
        let w = weights_for(&g, seed);
        ModelInstance::uniform(g, w, sel).unwrap()
    }

    #[test]
    fn catalog_rotation_serves_bit_identically_all_modes() {
        // THE residency acceptance differential: a 3-model catalog whose
        // combined warm footprint (~187 KiB) exceeds the replica's
        // 96 KiB resident budget — every dispatch to a cold model evicts
        // the LRU model and re-warms, and every response stays
        // bit-identical (values AND ExecReports) to fresh single-model
        // routers, in every hardware mode. Counters are exact: one model
        // warm at a time, so each warm after the first evicts exactly
        // one victim.
        use crate::models::ulvio;
        const BUDGET: usize = 96 * 1024;
        let kinds = [WorkloadKind::Classify, WorkloadKind::Vio, WorkloadKind::Gaze];
        for (mi, sel) in PrecSel::ALL.into_iter().enumerate() {
            let graphs = [effnet::build(), ulvio::build(), gaze::build()];
            let weights: Vec<_> =
                graphs.iter().enumerate().map(|(i, g)| weights_for(g, 200 + (mi * 3 + i) as u64)).collect();
            let rt = RuntimeConfig { resident_budget: Some(BUDGET), ..Default::default() };
            let mut catalog = Router::with_runtime(1, SocConfig::default(), rt);
            let mut refs: Vec<Router> = Vec::new();
            for ((kind, g), w) in kinds.iter().zip(&graphs).zip(&weights) {
                catalog
                    .register(*kind, ModelInstance::uniform(g.clone(), w.clone(), sel).unwrap())
                    .unwrap();
                let mut r = Router::new(1, SocConfig::default());
                r.register(*kind, ModelInstance::uniform(g.clone(), w.clone(), sel).unwrap())
                    .unwrap();
                refs.push(r);
            }
            let rounds = 2;
            for round in 0..rounds {
                for (ki, kind) in kinds.iter().enumerate() {
                    let g = &graphs[ki];
                    let input: Vec<f32> = (0..g.input.numel())
                        .map(|j| ((round * 97 + j) as f32 * 0.013).sin() * 0.4)
                        .collect();
                    let aux: Vec<f32> =
                        if *kind == WorkloadKind::Vio { vec![0.05; 6] } else { vec![] };
                    let got = catalog.route(*kind, &input, &aux).unwrap();
                    let want = refs[ki].route(*kind, &input, &aux).unwrap();
                    assert_eq!(
                        got.output, want.output,
                        "{sel:?} {kind:?} round {round}: rotation diverged"
                    );
                    assert_eq!(
                        got.report, want.report,
                        "{sel:?} {kind:?} round {round}: reports diverged"
                    );
                }
            }
            let m = catalog.runtime_metrics();
            // 3 registration warms + 3 per rotation round, each warm
            // after the first evicting exactly one model
            assert_eq!(m.cold_warms, 3 + 3 * rounds as u64, "{sel:?}");
            assert_eq!(m.evictions, m.cold_warms - 1, "{sel:?}");
            assert_eq!(m.compactions, 0, "{sel:?}: single-model stack never fragments");
            assert!(m.resident_high_water <= BUDGET as u64, "{sel:?}: budget exceeded");
            assert!(m.resident_high_water > 0, "{sel:?}");
        }
    }

    #[test]
    fn catalog_compaction_counters_surface_in_runtime_metrics() {
        // induced fragmentation at the router level: 32 KiB DRAM
        // (24576-byte budget), three fc models sized so admitting the
        // third needs the evicted first model's space — which only
        // compaction can make contiguous. Counters surface through
        // RuntimeMetrics and serving stays bit-identical throughout.
        let cfg = SocConfig { dram_bytes: 1 << 15, ..Default::default() };
        let mut r = Router::new(1, cfg);
        let specs = [
            (WorkloadKind::Vio, 64usize, 32usize, 300u64), // 8576 B
            (WorkloadKind::Gaze, 64, 48, 301),             // 12736 B
            (WorkloadKind::Classify, 64, 40, 302),         // 10688 B
        ];
        for (kind, k, n, seed) in specs {
            r.register(kind, fc_inst(kind.name(), k, n, PrecSel::Posit8x2, seed)).unwrap();
        }
        // registration alone forced evict(a) + compact(b) for c
        let m0 = r.runtime_metrics();
        assert_eq!(m0.evictions, 1);
        assert_eq!(m0.compactions, 1, "fragmented admission must compact");
        assert_eq!(m0.cold_warms, 3);
        // every kind serves bit-identically to a fresh big-DRAM router
        for (kind, k, n, seed) in specs {
            let input: Vec<f32> = (0..k).map(|j| (j as f32 * 0.21).sin() * 0.4).collect();
            let mut reference = Router::new(1, SocConfig::default());
            reference.register(kind, fc_inst(kind.name(), k, n, PrecSel::Posit8x2, seed)).unwrap();
            let want = reference.route(kind, &input, &[]).unwrap();
            let got = r.route(kind, &input, &[]).unwrap();
            assert_eq!(got.output, want.output, "{kind:?} diverged after rotation");
            assert_eq!(got.output.len(), n);
        }
        let m = r.runtime_metrics();
        assert!(m.evictions >= 3, "rotation keeps evicting: {}", m.evictions);
        assert!(m.resident_high_water <= 24576);
        assert_eq!(m.resident_high_water, r.replica_residency_stats(0).resident_high_water);
    }

    #[test]
    fn register_queues_cold_and_serves_once_pins_release() {
        // a fleet whose budget is hogged by a *pinned* sharded model:
        // whole registration no longer fails — the model queues cold,
        // dispatch fails with a typed pinned-budget error, and once the
        // sharded kind is replaced the cold model warms and serves
        let cfg = SocConfig { dram_bytes: 1 << 15, ..Default::default() };
        let mut r = Router::new(2, cfg);
        // 2-way K-split of a 64x150 fc: each shard ~21888 B of the
        // 24576 B budget, pinned for the registration's lifetime
        r.register_sharded(WorkloadKind::Vio, fc_inst("hog", 64, 150, PrecSel::Posit8x2, 310), 2)
            .unwrap();
        // 8576 B model: fits the budget, but not around the pinned shard
        r.register(WorkloadKind::Gaze, fc_inst("small", 64, 32, PrecSel::Posit8x2, 311))
            .unwrap();
        let input: Vec<f32> = (0..64).map(|j| (j as f32 * 0.17).sin() * 0.4).collect();
        let err = r.route(WorkloadKind::Gaze, &input, &[]).unwrap_err();
        assert!(err.to_string().contains("pinned"), "want typed pinned error, got: {err}");
        // replacing the sharded kind releases its pins and space
        r.register(WorkloadKind::Vio, fc_inst("tiny", 64, 8, PrecSel::Posit8x2, 312)).unwrap();
        let out = r.route(WorkloadKind::Gaze, &input, &[]).unwrap();
        assert_eq!(out.output.len(), 32);
        assert_eq!(r.route(WorkloadKind::Vio, &input, &[]).unwrap().output.len(), 8);
        assert!(r.runtime_metrics().cold_warms >= 2);
    }

    #[test]
    fn autoscale_grows_under_queue_pressure_and_parks_when_idle() {
        use crate::coordinator::batcher::Request;
        let rt = RuntimeConfig {
            autoscale: AutoscaleConfig {
                floor: 1,
                max: 4,
                scale_up_p95: 1, // any measurable queueing is pressure
                scale_down_p95: 0,
                window: 64,
                step: 1,
                idle_patience: 2,
            },
            ..Default::default()
        };
        let mut r = Router::with_runtime(4, SocConfig::default(), rt);
        let g = gaze::build();
        let w = weights_for(&g, 41);
        r.register(WorkloadKind::Gaze, ModelInstance::uniform(g, w, PrecSel::Posit8x2).unwrap())
            .unwrap();
        r.set_active(1);
        // sustained pressure: batches serialize on the single active
        // replica, so queue latency accumulates; each tick scales up
        let mut rounds = 0;
        while r.active_replicas() < 4 {
            let batch = Batch {
                requests: (0..12)
                    .map(|i| Request {
                        id: rounds * 12 + i,
                        input: vec![0.01 * i as f32; 16],
                        aux: vec![],
                        arrived: 0,
                    })
                    .collect(),
                released: 0,
            };
            r.route_batch(WorkloadKind::Gaze, &batch).unwrap();
            r.autoscale_tick();
            rounds += 1;
            assert!(rounds < 20, "autoscaler failed to scale up under sustained pressure");
        }
        assert_eq!(r.active_replicas(), 4);
        // idle: no traffic between ticks → parks back to the floor
        r.autoscale_tick();
        let after_idle = r.autoscale_tick();
        assert_eq!(after_idle, 1, "idle runtime must park to the floor");
    }

    #[test]
    fn warm_affinity_evicts_less_than_round_robin_on_a_rotating_catalog() {
        // the satellite regression: two replicas whose 24576-byte budget
        // fits exactly ONE of two 21056-byte models, serving A,A,B,B
        // traffic. Pure round-robin lands every second request on a
        // replica holding the other model (evict + re-warm each time);
        // warm affinity routes repeats to the replica that already holds
        // the model and provably thrashes less. route() blocks per
        // request, so both runs are deterministic.
        let run = |affinity: bool| -> u64 {
            let cfg = SocConfig { dram_bytes: 1 << 15, ..Default::default() };
            let rt = RuntimeConfig { warm_affinity: affinity, ..Default::default() };
            let mut r = Router::with_runtime(2, cfg, rt);
            r.register(WorkloadKind::Gaze, fc_inst("a", 64, 80, PrecSel::Posit8x2, 400))
                .unwrap();
            r.register(WorkloadKind::Vio, fc_inst("b", 64, 80, PrecSel::Posit8x2, 401))
                .unwrap();
            let input: Vec<f32> = (0..64).map(|j| (j as f32 * 0.17).sin() * 0.4).collect();
            for _ in 0..4 {
                for kind in [
                    WorkloadKind::Gaze,
                    WorkloadKind::Gaze,
                    WorkloadKind::Vio,
                    WorkloadKind::Vio,
                ] {
                    r.route(kind, &input, &[]).unwrap();
                }
            }
            r.runtime_metrics().evictions
        };
        let rr = run(false);
        let affine = run(true);
        assert!(
            affine < rr,
            "warm affinity must thrash less than round-robin: {affine} vs {rr} evictions"
        );
    }

    #[test]
    fn registration_statically_rejects_a_corrupt_program() {
        use crate::models::VerifyError;
        let mut r = Router::new(1, SocConfig::default());
        let mut inst = fc_inst("corrupt", 64, 32, PrecSel::Posit8x2, 410);
        // corrupt the compiled program after the fact: an undersized
        // A-operand scratch span would let replay write past its span
        Arc::get_mut(&mut inst.compiled).unwrap().a_len = 1;
        let err = r.register(WorkloadKind::Gaze, inst).unwrap_err();
        let v = err.downcast_ref::<VerifyError>().expect("typed VerifyError through anyhow");
        assert!(matches!(v, VerifyError::SpanOverlap { .. }), "got {v:?}");
        // rejected before any catalog or DRAM mutation
        assert!(!r.has(WorkloadKind::Gaze));
        assert_eq!(r.replica_resident(0), (0, 0), "no resident DRAM may be touched");
        // the router stays fully usable
        r.register(WorkloadKind::Gaze, fc_inst("good", 64, 32, PrecSel::Posit8x2, 411))
            .unwrap();
        assert_eq!(r.route(WorkloadKind::Gaze, &vec![0.1; 64], &[]).unwrap().output.len(), 32);
    }

    #[test]
    fn sharded_registration_statically_verifies_the_shard_set() {
        // the happy path exercises verify_shard_plan on every sharded
        // registration; a corrupt parent *program* also fails the
        // registration's verification before placement
        use crate::models::VerifyError;
        let mut r = Router::new(2, SocConfig::default());
        let mut inst = fc_inst("corrupt", 64, 150, PrecSel::Posit8x2, 412);
        Arc::get_mut(&mut inst.compiled).unwrap().c_len = 1;
        let err = r.register_sharded(WorkloadKind::Vio, inst, 2).unwrap_err();
        assert!(err.downcast_ref::<VerifyError>().is_some(), "typed VerifyError: {err}");
        assert!(!r.has(WorkloadKind::Vio));
        assert_eq!(r.replica_resident(0), (0, 0));
        assert_eq!(r.replica_resident(1), (0, 0));
    }

    #[test]
    fn tracing_on_is_bit_identical_to_tracing_off_in_every_prec_sel() {
        // the zero-overhead contract: attaching a sink must not perturb
        // outputs, reports, or placement in any precision mode
        use crate::obs::TraceSink;
        for prec in [PrecSel::Fp4x4, PrecSel::Posit4x4, PrecSel::Posit8x2, PrecSel::Posit16x1] {
            let run = |traced: bool| {
                let mut r = Router::new(2, SocConfig::default());
                if traced {
                    r.set_trace_sink(TraceSink::new(4096));
                }
                let g = gaze::build();
                let w = weights_for(&g, 91);
                r.register(WorkloadKind::Gaze, ModelInstance::uniform(g, w, prec).unwrap())
                    .unwrap();
                (0..4)
                    .map(|q| r.route(WorkloadKind::Gaze, &vec![0.02 * q as f32; 16], &[]).unwrap())
                    .collect::<Vec<_>>()
            };
            let off = run(false);
            let on = run(true);
            for (a, b) in off.iter().zip(&on) {
                assert_eq!(a.output, b.output, "{prec:?}: outputs must be bit-identical");
                assert_eq!(a.report, b.report, "{prec:?}: reports must be bit-identical");
                assert_eq!(a.replica, b.replica, "{prec:?}: placement must match");
            }
        }
    }

    #[test]
    fn serial_trace_export_is_byte_identical_for_a_fixed_seed() {
        use crate::obs::{export_chrome_trace, TraceSink};
        let run = || {
            let mut r = Router::new(1, SocConfig::default());
            let sink = TraceSink::new(4096);
            r.set_trace_sink(Arc::clone(&sink));
            let g = gaze::build();
            let w = weights_for(&g, 92);
            r.register(WorkloadKind::Gaze, ModelInstance::uniform(g, w, PrecSel::Posit8x2).unwrap())
                .unwrap();
            for q in 0..3 {
                r.route(WorkloadKind::Gaze, &vec![0.03 * q as f32; 16], &[]).unwrap();
            }
            r.quiesce();
            export_chrome_trace(&sink.records())
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "fixed-seed serial runs must export byte-identically");
        assert!(a.contains("\"ph\":\"X\""), "complete events present");
        assert!(a.contains("Submit") && a.contains("GemmJob") && a.contains("Complete"));
    }

    #[test]
    fn traced_request_spans_cover_submit_to_completion() {
        use crate::obs::{TraceEvent, TraceSink};
        let mut r = Router::new(1, SocConfig::default());
        let sink = TraceSink::new(4096);
        r.set_trace_sink(Arc::clone(&sink));
        let g = gaze::build();
        let n_gemm = g.compute_layers().len();
        let w = weights_for(&g, 93);
        r.register(WorkloadKind::Gaze, ModelInstance::uniform(g, w, PrecSel::Posit8x2).unwrap())
            .unwrap();
        let out = r.route(WorkloadKind::Gaze, &vec![0.1; 16], &[]).unwrap();
        r.quiesce();
        let recs = sink.records();
        let names: Vec<&str> = recs.iter().map(|rec| rec.event.name()).collect();
        for want in ["Submit", "Enqueue", "Dispatch", "GemmJob", "Requantize", "Complete"] {
            assert!(names.contains(&want), "missing {want} in {names:?}");
        }
        assert_eq!(
            recs.iter().filter(|rec| matches!(rec.event, TraceEvent::GemmJob { .. })).count(),
            n_gemm,
            "one GemmJob span per compute layer"
        );
        let gemm_span_cycles: u64 = recs
            .iter()
            .filter(|rec| matches!(rec.event, TraceEvent::GemmJob { .. }))
            .map(|rec| rec.dur_cycles)
            .sum();
        assert_eq!(
            gemm_span_cycles,
            out.report.gemm_cycles(),
            "GemmJob spans re-lay the report's own accounting, never a second one"
        );
        let complete = recs
            .iter()
            .find(|rec| matches!(rec.event, TraceEvent::Complete))
            .expect("Complete marker");
        assert_eq!(
            complete.begin_cycles,
            out.report.total_cycles(),
            "Complete is stamped at the request's total simulated cost"
        );
        assert_eq!(sink.dropped(), 0);
    }

    #[test]
    fn registry_snapshot_folds_fleet_counters() {
        use crate::obs::TraceSink;
        let mut r = Router::new(2, SocConfig::default());
        let sink = TraceSink::new(4096);
        r.set_trace_sink(Arc::clone(&sink));
        let g = gaze::build();
        let w = weights_for(&g, 94);
        r.register(WorkloadKind::Gaze, ModelInstance::uniform(g, w, PrecSel::Posit8x2).unwrap())
            .unwrap();
        for q in 0..4 {
            r.route(WorkloadKind::Gaze, &vec![0.01 * q as f32; 16], &[]).unwrap();
        }
        r.quiesce();
        let snap = crate::obs::snapshot(&r);
        assert_eq!(snap["sim_requests_served"], 4);
        assert_eq!(snap["sim_served_gaze"], 4);
        assert_eq!(snap["sim_completed_jobs"], 4);
        assert!(snap["sim_trace_events"] > 0, "sink events surface in the snapshot");
        assert_eq!(snap["sim_trace_dropped"], 0);
        assert!(snap.contains_key("sim_cache_misses_r0"));
        assert!(snap.contains_key("sim_lifetime_cycles_r1"));
        // the management-budget traffic surfaces per replica, and the
        // registration floor-warm of replica 0 already charged it
        assert!(snap["sim_mgmt_bytes_r0"] > 0, "floor warm rides the management budget");
        assert!(snap["sim_mgmt_cycles_r0"] > 0);
        assert!(snap.contains_key("sim_mgmt_bytes_r1"));
        // every key follows the bench_gate simulated-field convention
        assert!(snap
            .keys()
            .all(|k| k.starts_with("sim_") || k.contains("cycles") || k.contains("bytes")));
        // no ladder registered: the sim_ladder_* keys must be absent, so
        // pre-ladder baselines never see them
        assert!(snap.keys().all(|k| !k.starts_with("sim_ladder_")));
    }

    /// The ladder's core differential: every rung must serve
    /// bit-identically to a **fresh single-plan compile** of that
    /// rung's plan, in all four hardware modes — values and (rung-stamp
    /// aside) the full `ExecReport`. Rung 0 doubles as the
    /// "ladder off ≡ pre-ladder serving" proof.
    #[test]
    fn every_ladder_rung_serves_bit_identical_to_a_fresh_single_plan_compile() {
        for (i, sel) in PrecSel::ALL.into_iter().enumerate() {
            let g = gaze::build();
            let w = weights_for(&g, 150 + i as u64);
            let plans: Vec<_> = ModelInstance::ladder(g.clone(), w.clone(), sel, true)
                .unwrap()
                .into_iter()
                .map(|(inst, _)| inst.plan.clone())
                .collect();
            assert_eq!(plans.len(), 3, "{sel:?}: one instance per ladder budget");
            for (rung, plan) in plans.into_iter().enumerate() {
                let x = vec![0.01 + 0.03 * rung as f32; 16];
                let mut lad = Router::new(1, SocConfig::default());
                lad.register_ladder(
                    WorkloadKind::Gaze,
                    ModelInstance::ladder(g.clone(), w.clone(), sel, true).unwrap(),
                )
                .unwrap();
                lad.set_ladder_rung(rung);
                let got = lad.route(WorkloadKind::Gaze, &x, &[]).unwrap();
                assert_eq!(got.report.rung, rung as u32, "{sel:?}: per-request plan stamp");
                let mut fresh = Router::new(1, SocConfig::default());
                fresh
                    .register(
                        WorkloadKind::Gaze,
                        ModelInstance::with_plan(g.clone(), w.clone(), plan).unwrap(),
                    )
                    .unwrap();
                let want = fresh.route(WorkloadKind::Gaze, &x, &[]).unwrap();
                assert_eq!(got.output, want.output, "{sel:?} rung {rung}: values diverged");
                let mut scrub = got.report.clone();
                scrub.rung = want.report.rung; // a single-plan compile stamps rung 0
                assert_eq!(scrub, want.report, "{sel:?} rung {rung}: reports diverged");
            }
        }
    }

    /// A seeded congestion trace drives the ladder down to the
    /// FP4-heavy rung during the burst and back to high fidelity when
    /// idle, respecting dwell-tick hysteresis — and the whole switch
    /// sequence (plus the registry snapshot) replays byte-identically.
    #[test]
    fn ladder_congestion_burst_shifts_to_fp4_and_recovers_deterministically() {
        use crate::serve::{LadderConfig, LadderPolicy};
        let run = || {
            let mut r = Router::new(2, SocConfig::default());
            let g = gaze::build();
            let w = weights_for(&g, 140);
            r.register_ladder(
                WorkloadKind::Gaze,
                ModelInstance::ladder(g, w, PrecSel::Fp4x4, true).unwrap(),
            )
            .unwrap();
            let mut policy = LadderPolicy::new(LadderConfig {
                shift_down: 50_000,
                shift_up: 5_000,
                window: 64,
                dwell_ticks: 2,
                idle_patience: 2,
            });
            // prime the service-cost window on the high-fidelity rung
            for q in 0..4 {
                r.route(WorkloadKind::Gaze, &vec![0.02 * q as f32; 16], &[]).unwrap();
            }
            r.quiesce();
            // seeded depth trace: idle → congestion burst → idle. Each
            // tick then serves one request on the decided rung.
            let depths = [0usize, 16, 16, 16, 16, 16, 0, 0, 0, 0, 0, 0, 0];
            let mut seq = Vec::new();
            let mut stamps = Vec::new();
            for &d in &depths {
                let rung = r.ladder_tick_with(&mut policy, d);
                seq.push(rung);
                let res = r.route(WorkloadKind::Gaze, &vec![0.05; 16], &[]).unwrap();
                stamps.push(res.report.rung);
                r.quiesce();
            }
            let snap = crate::obs::snapshot(&r);
            (seq, stamps, r.ladder_switches(), snap)
        };
        let (seq_a, stamps_a, switches_a, snap_a) = run();
        let (seq_b, stamps_b, switches_b, snap_b) = run();
        assert_eq!(seq_a, seq_b, "switch sequence must replay identically");
        assert_eq!(stamps_a, stamps_b);
        assert_eq!(switches_a, switches_b);
        assert_eq!(snap_a, snap_b, "the whole fleet snapshot must replay identically");
        // the burst reaches the FP4-heavy bottom rung; idle recovers to
        // the high-fidelity top
        assert_eq!(seq_a.iter().max().copied(), Some(2), "{seq_a:?}");
        assert_eq!(seq_a.last().copied(), Some(0), "{seq_a:?}");
        // every request is stamped with the rung that served it
        let as_stamps: Vec<u32> = seq_a.iter().map(|&r| r as u32).collect();
        assert_eq!(stamps_a, as_stamps);
        // hysteresis: the ladder moves one rung at a time, and dwell
        // ticks enforce a minimum residence between switches
        for w in seq_a.windows(2) {
            assert!(w[0].abs_diff(w[1]) <= 1, "{seq_a:?}");
        }
        assert!(switches_a >= 4, "down to rung 2 and back is at least 4 switches");
        // the snapshot carries the gated ladder keys
        assert_eq!(snap_a["sim_ladder_rung"], 0);
        assert_eq!(snap_a["sim_ladder_switches"], switches_a);
        let rung2_serves = seq_a.iter().filter(|&&r| r == 2).count() as u64;
        assert_eq!(snap_a["sim_ladder_served_rung2"], rung2_serves);
        assert!(snap_a.contains_key("sim_ladder_score_rung0"));
        // quality accounting: scores rise monotonically down the ladder
        let scores = (0..3).map(|r| snap_a[&format!("sim_ladder_score_rung{r}")]).collect::<Vec<_>>();
        assert!(scores.windows(2).all(|w| w[0] <= w[1]), "{scores:?}");
    }

    /// Rotating rungs through a two-rung DRAM budget evicts only cold
    /// rungs, and the rung being served keeps producing bit-identical
    /// outputs through its neighbors' evictions and re-warms.
    #[test]
    fn evicting_cold_rungs_never_perturbs_hot_rung_serving() {
        let g = gaze::build();
        let w = weights_for(&g, 160);
        let rungs = ModelInstance::ladder(g, w, PrecSel::Fp4x4, true).unwrap();
        let fp: Vec<u64> =
            rungs.iter().map(|(inst, _)| inst.compiled.warm_footprint_bytes() as u64).collect();
        // room for any two rungs but never all three: admitting the
        // third rotates the least-recently-dispatched cold one out
        let rt = RuntimeConfig {
            resident_budget: Some((fp[0] + fp[1] + fp[2] / 2) as usize),
            ..RuntimeConfig::default()
        };
        let mut r = Router::with_runtime(1, SocConfig::default(), rt);
        r.register_ladder(WorkloadKind::Gaze, rungs).unwrap();
        let x = vec![0.09; 16];
        let serve = |r: &mut Router, rung: usize| {
            r.set_ladder_rung(rung);
            r.route(WorkloadKind::Gaze, &x, &[]).unwrap()
        };
        let out0 = serve(&mut r, 0).output;
        let out1 = serve(&mut r, 1).output;
        // admitting rung 2 must evict the LRU cold rung (rung 0)...
        let out2 = serve(&mut r, 2).output;
        assert!(r.replica_residency_stats(0).evictions >= 1, "the budget forces a rotation");
        // ...and the rung that serves next is untouched by that eviction
        assert_eq!(serve(&mut r, 2).output, out2, "hot rung must survive its neighbor's eviction");
        // evicted rungs re-warm and serve bit-identically
        assert_eq!(serve(&mut r, 0).output, out0);
        assert_eq!(serve(&mut r, 1).output, out1);
        assert_eq!(r.ladder_served(), vec![2, 2, 2]);
        assert_ne!(out0, out2, "rungs really are different precision plans");
    }
}
