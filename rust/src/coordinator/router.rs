//! Workload routing: {VIO, gaze, classification} → model instances on
//! co-processor replicas.
//!
//! Each workload kind owns one [`ModelInstance`]; SoC replicas are shared
//! round-robin. The router is the only component that touches both the
//! serving queue and the hardware handles — the paper's "scheduling and
//! control mechanisms as per workload configurations".

use super::batcher::Batch;
use super::scheduler::ModelInstance;
use crate::models::ExecReport;
use crate::soc::{Soc, SocConfig};
use anyhow::{bail, Result};
use std::collections::HashMap;

/// Perception workload kinds (paper Fig. 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum WorkloadKind {
    Vio,
    Gaze,
    Classify,
}

impl WorkloadKind {
    pub const ALL: [WorkloadKind; 3] =
        [WorkloadKind::Vio, WorkloadKind::Gaze, WorkloadKind::Classify];

    pub fn name(self) -> &'static str {
        match self {
            WorkloadKind::Vio => "vio",
            WorkloadKind::Gaze => "gaze",
            WorkloadKind::Classify => "classify",
        }
    }
}

/// Completed inference.
#[derive(Debug, Clone)]
pub struct RoutedResult {
    pub kind: WorkloadKind,
    pub output: Vec<f32>,
    pub report: ExecReport,
    /// Which replica served it.
    pub replica: usize,
}

/// The router.
pub struct Router {
    models: HashMap<WorkloadKind, ModelInstance>,
    replicas: Vec<Soc>,
    next_replica: usize,
    /// Per-kind request counters.
    pub served: HashMap<WorkloadKind, u64>,
}

impl Router {
    /// `n_replicas` co-processors with the given config.
    pub fn new(n_replicas: usize, cfg: SocConfig) -> Router {
        assert!(n_replicas >= 1);
        Router {
            models: HashMap::new(),
            replicas: (0..n_replicas).map(|_| Soc::new(cfg)).collect(),
            next_replica: 0,
            served: HashMap::new(),
        }
    }

    /// Register the model for a workload kind.
    pub fn register(&mut self, kind: WorkloadKind, inst: ModelInstance) {
        self.models.insert(kind, inst);
    }

    pub fn has(&self, kind: WorkloadKind) -> bool {
        self.models.contains_key(&kind)
    }

    pub fn model(&self, kind: WorkloadKind) -> Option<&ModelInstance> {
        self.models.get(&kind)
    }

    /// Route one request; returns output + execution report.
    pub fn route(&mut self, kind: WorkloadKind, input: &[f32], aux: &[f32]) -> Result<RoutedResult> {
        let Some(inst) = self.models.get(&kind) else {
            bail!("no model registered for {:?}", kind);
        };
        let replica = self.next_replica;
        self.next_replica = (self.next_replica + 1) % self.replicas.len();
        let (output, report) = inst.infer(&mut self.replicas[replica], input, aux)?;
        *self.served.entry(kind).or_insert(0) += 1;
        Ok(RoutedResult { kind, output, report, replica })
    }

    /// Execute every request of a released [`Batch`], fanning the work
    /// out across the SoC replicas with std scoped threads (each replica
    /// is an independent co-processor; requests assigned to the same
    /// replica serialize in batch order). Results come back in request
    /// order. Outputs are bit-identical to routing each request through
    /// [`Router::route`] — replica assignment never affects numerics.
    pub fn route_batch(&mut self, kind: WorkloadKind, batch: &Batch) -> Result<Vec<RoutedResult>> {
        let reqs = &batch.requests;
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        let Some(inst) = self.models.get(&kind) else {
            bail!("no model registered for {:?}", kind);
        };
        let n = self.replicas.len();
        // Continue the round-robin where route() left off (and advance
        // it), so a stream of small/flushed batches still spreads across
        // replicas instead of always hammering replica 0.
        let offset = self.next_replica;
        self.next_replica = (self.next_replica + reqs.len()) % n;
        let mut buckets: Vec<Vec<usize>> = vec![Vec::new(); n];
        for i in 0..reqs.len() {
            buckets[(offset + i) % n].push(i);
        }
        let per_replica: Vec<Result<Vec<(usize, RoutedResult)>>> = std::thread::scope(|s| {
            let handles: Vec<_> = self
                .replicas
                .iter_mut()
                .zip(buckets)
                .enumerate()
                .map(|(ri, (soc, idxs))| {
                    let inst = &*inst;
                    s.spawn(move || {
                        idxs.into_iter()
                            .map(|i| {
                                let r = &reqs[i];
                                let (output, report) = inst.infer(soc, &r.input, &r.aux)?;
                                Ok((i, RoutedResult { kind, output, report, replica: ri }))
                            })
                            .collect::<Result<Vec<_>>>()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("replica worker panicked")).collect()
        });
        let mut slots: Vec<Option<RoutedResult>> = Vec::new();
        slots.resize_with(reqs.len(), || None);
        for chunk in per_replica {
            for (i, res) in chunk? {
                slots[i] = Some(res);
            }
        }
        *self.served.entry(kind).or_insert(0) += reqs.len() as u64;
        Ok(slots.into_iter().map(|r| r.expect("missing batch result")).collect())
    }

    /// Total requests served.
    pub fn total_served(&self) -> u64 {
        self.served.values().sum()
    }

    /// Lifetime job report per replica.
    pub fn replica_lifetime(&self, i: usize) -> &crate::soc::JobReport {
        &self.replicas[i].lifetime
    }

    pub fn n_replicas(&self) -> usize {
        self.replicas.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{effnet, gaze};
    use crate::npe::PrecSel;
    use crate::util::io::{Tensor, TensorMap};
    use crate::util::Rng;

    fn weights_for(graph: &crate::models::ModelGraph, seed: u64) -> TensorMap {
        // shared helper duplicated from scheduler tests (kept local to
        // avoid exposing test-only code in the public API)
        let mut rng = Rng::new(seed);
        let mut m = TensorMap::new();
        for layer in &graph.layers {
            match &layer.kind {
                crate::models::LayerKind::Conv2d { in_c, out_c, k, .. } => {
                    let n = in_c * out_c * k * k;
                    let mut w = vec![0f32; n];
                    rng.fill_normal(&mut w, 0.2);
                    m.insert(format!("{}.w", layer.name), Tensor::new(vec![*k, *k, *in_c, *out_c], w));
                    m.insert(format!("{}.b", layer.name), Tensor::new(vec![*out_c], vec![0.0; *out_c]));
                }
                crate::models::LayerKind::Fc { in_f, out_f } => {
                    let mut w = vec![0f32; in_f * out_f];
                    rng.fill_normal(&mut w, 0.2);
                    m.insert(format!("{}.w", layer.name), Tensor::new(vec![*in_f, *out_f], w));
                    m.insert(format!("{}.b", layer.name), Tensor::new(vec![*out_f], vec![0.0; *out_f]));
                }
                crate::models::LayerKind::Act(crate::models::ActKind::Pact) => {
                    m.insert(format!("{}.alpha", layer.name), Tensor::new(vec![1], vec![4.0]));
                }
                _ => {}
            }
        }
        m
    }

    #[test]
    fn routes_to_registered_model() {
        let mut r = Router::new(1, SocConfig::default());
        let g = gaze::build();
        let w = weights_for(&g, 1);
        r.register(WorkloadKind::Gaze, ModelInstance::uniform(g, w, PrecSel::Posit8x2));
        let out = r.route(WorkloadKind::Gaze, &vec![0.1; 16], &[]).unwrap();
        assert_eq!(out.output.len(), 2);
        assert_eq!(r.total_served(), 1);
    }

    #[test]
    fn unregistered_kind_errors() {
        let mut r = Router::new(1, SocConfig::default());
        assert!(r.route(WorkloadKind::Vio, &[], &[]).is_err());
    }

    #[test]
    fn round_robin_across_replicas() {
        let mut r = Router::new(3, SocConfig::default());
        let g = gaze::build();
        let w = weights_for(&g, 2);
        r.register(WorkloadKind::Gaze, ModelInstance::uniform(g, w, PrecSel::Fp4x4));
        let mut hits = vec![0u32; 3];
        for _ in 0..9 {
            let res = r.route(WorkloadKind::Gaze, &vec![0.1; 16], &[]).unwrap();
            hits[res.replica] += 1;
        }
        assert_eq!(hits, vec![3, 3, 3]);
    }

    #[test]
    fn batch_route_matches_serial_route() {
        use crate::coordinator::batcher::Request;
        let mut r = Router::new(3, SocConfig::default());
        let g = gaze::build();
        let w = weights_for(&g, 5);
        r.register(WorkloadKind::Gaze, ModelInstance::uniform(g, w, PrecSel::Posit8x2));
        let inputs: Vec<Vec<f32>> = (0..7).map(|i| vec![0.02 * i as f32; 16]).collect();
        // serial reference outputs (numerics are replica-independent)
        let mut want = Vec::new();
        for x in &inputs {
            want.push(r.route(WorkloadKind::Gaze, x, &[]).unwrap().output);
        }
        let batch = Batch {
            requests: inputs
                .iter()
                .enumerate()
                .map(|(i, x)| Request {
                    id: i as u64,
                    input: x.clone(),
                    aux: vec![],
                    arrived: i as u64,
                })
                .collect(),
            released: 10,
        };
        let res = r.route_batch(WorkloadKind::Gaze, &batch).unwrap();
        assert_eq!(res.len(), 7);
        for (i, got) in res.iter().enumerate() {
            assert_eq!(got.output, want[i], "request {i}");
            // round-robin continues where the 7 serial route() calls left off
            assert_eq!(got.replica, (7 + i) % 3);
        }
        assert_eq!(r.served[&WorkloadKind::Gaze], 14);
    }

    #[test]
    fn consecutive_small_batches_rotate_replicas() {
        use crate::coordinator::batcher::Request;
        let mut r = Router::new(3, SocConfig::default());
        let g = gaze::build();
        let w = weights_for(&g, 6);
        r.register(WorkloadKind::Gaze, ModelInstance::uniform(g, w, PrecSel::Fp4x4));
        let mut hits = vec![0u32; 3];
        for b in 0..6 {
            let batch = Batch {
                requests: vec![Request {
                    id: b,
                    input: vec![0.1; 16],
                    aux: vec![],
                    arrived: b,
                }],
                released: b,
            };
            let res = r.route_batch(WorkloadKind::Gaze, &batch).unwrap();
            hits[res[0].replica] += 1;
        }
        assert_eq!(hits, vec![2, 2, 2], "size-1 batches must still rotate replicas");
    }

    #[test]
    fn batch_route_empty_and_unregistered() {
        let mut r = Router::new(2, SocConfig::default());
        let empty = Batch { requests: vec![], released: 0 };
        assert!(r.route_batch(WorkloadKind::Vio, &empty).unwrap().is_empty());
        use crate::coordinator::batcher::Request;
        let one = Batch {
            requests: vec![Request { id: 0, input: vec![], aux: vec![], arrived: 0 }],
            released: 0,
        };
        assert!(r.route_batch(WorkloadKind::Vio, &one).is_err());
    }

    #[test]
    fn mixed_workloads_share_replicas() {
        let mut r = Router::new(2, SocConfig::default());
        let gg = gaze::build();
        let wg = weights_for(&gg, 3);
        r.register(WorkloadKind::Gaze, ModelInstance::uniform(gg, wg, PrecSel::Posit8x2));
        let gc = effnet::build();
        let wc = weights_for(&gc, 4);
        r.register(WorkloadKind::Classify, ModelInstance::uniform(gc, wc, PrecSel::Fp4x4));
        r.route(WorkloadKind::Gaze, &vec![0.1; 16], &[]).unwrap();
        r.route(WorkloadKind::Classify, &vec![0.1; 256], &[]).unwrap();
        assert_eq!(r.total_served(), 2);
        assert_eq!(r.served[&WorkloadKind::Gaze], 1);
    }
}
