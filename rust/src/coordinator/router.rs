//! Workload routing: {VIO, gaze, classification} → model instances on
//! co-processor replicas.
//!
//! Each workload kind owns one [`ModelInstance`]; SoC replicas are shared
//! round-robin. The router is the only component that touches both the
//! serving queue and the hardware handles — the paper's "scheduling and
//! control mechanisms as per workload configurations".
//!
//! Registration **warms every replica**: the instance's compiled program
//! uploads its resident weight images and preloads their pinned operand
//! encodings on each SoC, so [`Router::route`] / [`Router::route_batch`]
//! always serve from warm state — no request ever pays weight scaling or
//! encoding costs.

use super::batcher::Batch;
use super::scheduler::ModelInstance;
use crate::models::ExecReport;
use crate::soc::{Soc, SocConfig};
use anyhow::{bail, Result};
use std::collections::HashMap;

/// Perception workload kinds (paper Fig. 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum WorkloadKind {
    Vio,
    Gaze,
    Classify,
}

impl WorkloadKind {
    pub const ALL: [WorkloadKind; 3] =
        [WorkloadKind::Vio, WorkloadKind::Gaze, WorkloadKind::Classify];

    pub fn name(self) -> &'static str {
        match self {
            WorkloadKind::Vio => "vio",
            WorkloadKind::Gaze => "gaze",
            WorkloadKind::Classify => "classify",
        }
    }
}

/// Completed inference.
#[derive(Debug, Clone)]
pub struct RoutedResult {
    pub kind: WorkloadKind,
    pub output: Vec<f32>,
    pub report: ExecReport,
    /// Which replica served it.
    pub replica: usize,
}

/// The router.
pub struct Router {
    models: HashMap<WorkloadKind, ModelInstance>,
    replicas: Vec<Soc>,
    next_replica: usize,
    /// Per-kind request counters.
    pub served: HashMap<WorkloadKind, u64>,
}

impl Router {
    /// `n_replicas` co-processors with the given config.
    pub fn new(n_replicas: usize, cfg: SocConfig) -> Router {
        assert!(n_replicas >= 1);
        Router {
            models: HashMap::new(),
            replicas: (0..n_replicas).map(|_| Soc::new(cfg)).collect(),
            next_replica: 0,
            served: HashMap::new(),
        }
    }

    /// Register the model for a workload kind, warming its compiled
    /// program on every replica (resident weights + pinned encodings +
    /// run arena), so the first request is as fast as the thousandth.
    ///
    /// The new model warms on *every* replica before the replaced one is
    /// evicted or the registry updated, and a failed warm rolls back the
    /// replicas already warmed — so an error leaves the router exactly
    /// as it was (the previous model, if any, keeps serving).
    pub fn register(&mut self, kind: WorkloadKind, inst: ModelInstance) -> Result<()> {
        let marks: Vec<u64> = self.replicas.iter().map(|s| s.resident_mark()).collect();
        for i in 0..self.replicas.len() {
            if let Err(e) = inst.warm(&mut self.replicas[i]) {
                // replica i cleaned up after itself inside warm; roll
                // back the replicas that fully warmed before it,
                // including their resident-DRAM bumps (this register
                // call held &mut self, so those bumps are top-of-stack)
                for (j, soc) in self.replicas[..i].iter_mut().enumerate() {
                    inst.compiled.evict(soc);
                    soc.resident_rollback(marks[j]);
                }
                return Err(e);
            }
        }
        if let Some(old) = self.models.remove(&kind) {
            for soc in &mut self.replicas {
                old.compiled.evict(soc);
            }
        }
        self.models.insert(kind, inst);
        Ok(())
    }

    pub fn has(&self, kind: WorkloadKind) -> bool {
        self.models.contains_key(&kind)
    }

    pub fn model(&self, kind: WorkloadKind) -> Option<&ModelInstance> {
        self.models.get(&kind)
    }

    /// Route one request; returns output + execution report.
    pub fn route(&mut self, kind: WorkloadKind, input: &[f32], aux: &[f32]) -> Result<RoutedResult> {
        let Some(inst) = self.models.get(&kind) else {
            bail!("no model registered for {:?}", kind);
        };
        let replica = self.next_replica;
        self.next_replica = (self.next_replica + 1) % self.replicas.len();
        let (output, report) = inst.infer(&mut self.replicas[replica], input, aux)?;
        *self.served.entry(kind).or_insert(0) += 1;
        Ok(RoutedResult { kind, output, report, replica })
    }

    /// Execute every request of a released [`Batch`], fanning the work
    /// out across the SoC replicas with std scoped threads (each replica
    /// is an independent co-processor; requests assigned to the same
    /// replica serialize in batch order). Results come back in request
    /// order. Outputs are bit-identical to routing each request through
    /// [`Router::route`] — replica assignment never affects numerics.
    pub fn route_batch(&mut self, kind: WorkloadKind, batch: &Batch) -> Result<Vec<RoutedResult>> {
        let reqs = &batch.requests;
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        let Some(inst) = self.models.get(&kind) else {
            bail!("no model registered for {:?}", kind);
        };
        let n = self.replicas.len();
        // Continue the round-robin where route() left off (and advance
        // it), so a stream of small/flushed batches still spreads across
        // replicas instead of always hammering replica 0.
        let offset = self.next_replica;
        self.next_replica = (self.next_replica + reqs.len()) % n;
        let mut buckets: Vec<Vec<usize>> = vec![Vec::new(); n];
        for i in 0..reqs.len() {
            buckets[(offset + i) % n].push(i);
        }
        let per_replica: Vec<Result<Vec<(usize, RoutedResult)>>> = std::thread::scope(|s| {
            let handles: Vec<_> = self
                .replicas
                .iter_mut()
                .zip(buckets)
                .enumerate()
                .map(|(ri, (soc, idxs))| {
                    let inst = &*inst;
                    s.spawn(move || {
                        idxs.into_iter()
                            .map(|i| {
                                let r = &reqs[i];
                                let (output, report) = inst.infer(soc, &r.input, &r.aux)?;
                                Ok((i, RoutedResult { kind, output, report, replica: ri }))
                            })
                            .collect::<Result<Vec<_>>>()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("replica worker panicked")).collect()
        });
        let mut slots: Vec<Option<RoutedResult>> = Vec::new();
        slots.resize_with(reqs.len(), || None);
        for chunk in per_replica {
            for (i, res) in chunk? {
                slots[i] = Some(res);
            }
        }
        *self.served.entry(kind).or_insert(0) += reqs.len() as u64;
        Ok(slots.into_iter().map(|r| r.expect("missing batch result")).collect())
    }

    /// Total requests served.
    pub fn total_served(&self) -> u64 {
        self.served.values().sum()
    }

    /// Lifetime job report per replica.
    pub fn replica_lifetime(&self, i: usize) -> &crate::soc::JobReport {
        &self.replicas[i].lifetime
    }

    /// (hits, misses, preloads) of replica `i`'s operand-encoding cache
    /// — the observable proof that registered weights encode zero times
    /// on the serving path.
    pub fn replica_cache_stats(&self, i: usize) -> (u64, u64, u64) {
        let c = &self.replicas[i].enc_cache;
        (c.hits, c.misses, c.preloads)
    }

    /// Pinned (weight-preload) entries resident in replica `i`'s cache.
    pub fn replica_pinned_len(&self, i: usize) -> usize {
        self.replicas[i].enc_cache.pinned_len()
    }

    pub fn n_replicas(&self) -> usize {
        self.replicas.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::random_weights as weights_for;
    use crate::models::{effnet, gaze};
    use crate::npe::PrecSel;

    #[test]
    fn routes_to_registered_model() {
        let mut r = Router::new(1, SocConfig::default());
        let g = gaze::build();
        let w = weights_for(&g, 1);
        r.register(WorkloadKind::Gaze, ModelInstance::uniform(g, w, PrecSel::Posit8x2).unwrap()).unwrap();
        let out = r.route(WorkloadKind::Gaze, &vec![0.1; 16], &[]).unwrap();
        assert_eq!(out.output.len(), 2);
        assert_eq!(r.total_served(), 1);
    }

    #[test]
    fn unregistered_kind_errors() {
        let mut r = Router::new(1, SocConfig::default());
        assert!(r.route(WorkloadKind::Vio, &[], &[]).is_err());
    }

    #[test]
    fn round_robin_across_replicas() {
        let mut r = Router::new(3, SocConfig::default());
        let g = gaze::build();
        let w = weights_for(&g, 2);
        r.register(WorkloadKind::Gaze, ModelInstance::uniform(g, w, PrecSel::Fp4x4).unwrap()).unwrap();
        let mut hits = vec![0u32; 3];
        for _ in 0..9 {
            let res = r.route(WorkloadKind::Gaze, &vec![0.1; 16], &[]).unwrap();
            hits[res.replica] += 1;
        }
        assert_eq!(hits, vec![3, 3, 3]);
    }

    #[test]
    fn batch_route_matches_serial_route() {
        use crate::coordinator::batcher::Request;
        let mut r = Router::new(3, SocConfig::default());
        let g = gaze::build();
        let w = weights_for(&g, 5);
        r.register(WorkloadKind::Gaze, ModelInstance::uniform(g, w, PrecSel::Posit8x2).unwrap()).unwrap();
        let inputs: Vec<Vec<f32>> = (0..7).map(|i| vec![0.02 * i as f32; 16]).collect();
        // serial reference outputs (numerics are replica-independent)
        let mut want = Vec::new();
        for x in &inputs {
            want.push(r.route(WorkloadKind::Gaze, x, &[]).unwrap().output);
        }
        let batch = Batch {
            requests: inputs
                .iter()
                .enumerate()
                .map(|(i, x)| Request {
                    id: i as u64,
                    input: x.clone(),
                    aux: vec![],
                    arrived: i as u64,
                })
                .collect(),
            released: 10,
        };
        let res = r.route_batch(WorkloadKind::Gaze, &batch).unwrap();
        assert_eq!(res.len(), 7);
        for (i, got) in res.iter().enumerate() {
            assert_eq!(got.output, want[i], "request {i}");
            // round-robin continues where the 7 serial route() calls left off
            assert_eq!(got.replica, (7 + i) % 3);
        }
        assert_eq!(r.served[&WorkloadKind::Gaze], 14);
    }

    #[test]
    fn consecutive_small_batches_rotate_replicas() {
        use crate::coordinator::batcher::Request;
        let mut r = Router::new(3, SocConfig::default());
        let g = gaze::build();
        let w = weights_for(&g, 6);
        r.register(WorkloadKind::Gaze, ModelInstance::uniform(g, w, PrecSel::Fp4x4).unwrap()).unwrap();
        let mut hits = vec![0u32; 3];
        for b in 0..6 {
            let batch = Batch {
                requests: vec![Request {
                    id: b,
                    input: vec![0.1; 16],
                    aux: vec![],
                    arrived: b,
                }],
                released: b,
            };
            let res = r.route_batch(WorkloadKind::Gaze, &batch).unwrap();
            hits[res[0].replica] += 1;
        }
        assert_eq!(hits, vec![2, 2, 2], "size-1 batches must still rotate replicas");
    }

    #[test]
    fn batch_route_empty_and_unregistered() {
        let mut r = Router::new(2, SocConfig::default());
        let empty = Batch { requests: vec![], released: 0 };
        assert!(r.route_batch(WorkloadKind::Vio, &empty).unwrap().is_empty());
        use crate::coordinator::batcher::Request;
        let one = Batch {
            requests: vec![Request { id: 0, input: vec![], aux: vec![], arrived: 0 }],
            released: 0,
        };
        assert!(r.route_batch(WorkloadKind::Vio, &one).is_err());
    }

    #[test]
    fn registration_warms_every_replica() {
        let mut r = Router::new(3, SocConfig::default());
        let g = gaze::build();
        let n_gemm = g.compute_layers().len() as u64;
        let w = weights_for(&g, 7);
        r.register(WorkloadKind::Gaze, ModelInstance::uniform(g, w, PrecSel::Posit8x2).unwrap())
            .unwrap();
        for i in 0..3 {
            let (hits, misses, preloads) = r.replica_cache_stats(i);
            assert_eq!((hits, misses, preloads), (0, 0, n_gemm), "replica {i}");
        }
        // 6 distinct requests round-robin over 3 replicas: every weight
        // lookup hits the preloaded encoding; only activations encode
        for q in 0..6 {
            r.route(WorkloadKind::Gaze, &vec![0.01 * q as f32; 16], &[]).unwrap();
        }
        for i in 0..3 {
            let (hits, misses, preloads) = r.replica_cache_stats(i);
            assert_eq!(preloads, n_gemm);
            assert_eq!(hits, 2 * n_gemm, "replica {i}: weights must hit");
            assert_eq!(misses, 2 * n_gemm, "replica {i}: only activations encode");
        }
    }

    #[test]
    fn reregistering_a_kind_evicts_the_old_warm_state() {
        let mut r = Router::new(2, SocConfig::default());
        let g = gaze::build();
        let n_gemm = g.compute_layers().len();
        let w1 = weights_for(&g, 30);
        r.register(WorkloadKind::Gaze, ModelInstance::uniform(g.clone(), w1, PrecSel::Posit8x2).unwrap())
            .unwrap();
        let w2 = weights_for(&g, 31);
        r.register(WorkloadKind::Gaze, ModelInstance::uniform(g.clone(), w2, PrecSel::Posit8x2).unwrap())
            .unwrap();
        for i in 0..2 {
            // the replaced model's pinned encodings are gone — only the
            // live model's weights stay pinned
            assert_eq!(r.replica_pinned_len(i), n_gemm, "replica {i}");
        }
        let out = r.route(WorkloadKind::Gaze, &vec![0.1; 16], &[]).unwrap();
        assert_eq!(out.output.len(), 2);
    }

    #[test]
    fn mixed_workloads_share_replicas() {
        let mut r = Router::new(2, SocConfig::default());
        let gg = gaze::build();
        let wg = weights_for(&gg, 3);
        r.register(WorkloadKind::Gaze, ModelInstance::uniform(gg, wg, PrecSel::Posit8x2).unwrap()).unwrap();
        let gc = effnet::build();
        let wc = weights_for(&gc, 4);
        r.register(WorkloadKind::Classify, ModelInstance::uniform(gc, wc, PrecSel::Fp4x4).unwrap()).unwrap();
        r.route(WorkloadKind::Gaze, &vec![0.1; 16], &[]).unwrap();
        r.route(WorkloadKind::Classify, &vec![0.1; 256], &[]).unwrap();
        assert_eq!(r.total_served(), 2);
        assert_eq!(r.served[&WorkloadKind::Gaze], 1);
    }
}
