//! Workload routing: {VIO, gaze, classification} → model instances on
//! co-processor replicas.
//!
//! Each workload kind owns one [`ModelInstance`]; SoC replicas are shared
//! round-robin. The router is the only component that touches both the
//! serving queue and the hardware handles — the paper's "scheduling and
//! control mechanisms as per workload configurations".
//!
//! Since PR 3 the router sits on the async serving runtime
//! ([`crate::serve::ServeRuntime`]): every replica is drained by a
//! long-lived worker thread through a bounded work queue, submission
//! ([`Router::submit`] / [`Router::submit_batch`]) returns
//! [`InferCompletion`] handles immediately, and the blocking
//! [`Router::route`] / [`Router::route_batch`] are thin wrappers that
//! submit and wait. Registration warms a configurable **floor** of
//! replicas eagerly ([`RuntimeConfig::warm_floor`]); the rest warm on
//! demand at their first request. An [`Autoscaler`] consuming the
//! runtime's queue-latency percentiles grows and parks the **active**
//! dispatch set between the floor and the fleet size
//! ([`Router::autoscale_tick`]).

use super::batcher::Batch;
use super::scheduler::ModelInstance;
use crate::models::ExecReport;
use crate::serve::{AutoscaleConfig, Autoscaler, Completion, Job, RuntimeMetrics, ServeRuntime};
use crate::soc::{JobReport, SocConfig};
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

/// Perception workload kinds (paper Fig. 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum WorkloadKind {
    Vio,
    Gaze,
    Classify,
}

impl WorkloadKind {
    pub const ALL: [WorkloadKind; 3] =
        [WorkloadKind::Vio, WorkloadKind::Gaze, WorkloadKind::Classify];

    pub fn name(self) -> &'static str {
        match self {
            WorkloadKind::Vio => "vio",
            WorkloadKind::Gaze => "gaze",
            WorkloadKind::Classify => "classify",
        }
    }
}

/// Completed inference.
#[derive(Debug, Clone)]
pub struct RoutedResult {
    pub kind: WorkloadKind,
    pub output: Vec<f32>,
    pub report: ExecReport,
    /// Which replica served it.
    pub replica: usize,
}

/// Handle for one submitted request: redeem with [`Router::resolve`]
/// (or [`Completion::wait`] directly).
pub type InferCompletion = Completion<Result<RoutedResult>>;

/// Serving-runtime knobs for a router.
#[derive(Debug, Clone, Copy)]
pub struct RuntimeConfig {
    /// Per-replica work-queue depth (bounded admission back-pressure).
    pub queue_capacity: usize,
    /// Replicas warmed eagerly at registration (clamped to `[1, n]`);
    /// the rest warm on demand at their first request.
    pub warm_floor: usize,
    /// Autoscaling policy ([`Router::autoscale_tick`] applies it).
    pub autoscale: AutoscaleConfig,
}

impl Default for RuntimeConfig {
    fn default() -> Self {
        RuntimeConfig {
            queue_capacity: 64,
            warm_floor: 1,
            autoscale: AutoscaleConfig::default(),
        }
    }
}

/// The router.
pub struct Router {
    models: HashMap<WorkloadKind, Arc<ModelInstance>>,
    runtime: ServeRuntime,
    autoscaler: Autoscaler,
    /// Replicas currently receiving dispatch (`1..=n_replicas`).
    active: usize,
    /// Total queue-latency samples already fed to the autoscaler
    /// (checkpoint for [`ServeRuntime::queue_samples_since`]).
    fed_samples: u64,
    warm_floor: usize,
    next_replica: usize,
    /// Per-kind request counters (admitted to the runtime).
    pub served: HashMap<WorkloadKind, u64>,
}

impl Router {
    /// `n_replicas` co-processors with the given config and default
    /// runtime settings (warm floor 1, all replicas active).
    pub fn new(n_replicas: usize, cfg: SocConfig) -> Router {
        Router::with_runtime(n_replicas, cfg, RuntimeConfig::default())
    }

    /// `n_replicas` co-processors with explicit runtime settings.
    pub fn with_runtime(n_replicas: usize, cfg: SocConfig, rt: RuntimeConfig) -> Router {
        assert!(n_replicas >= 1);
        Router {
            models: HashMap::new(),
            runtime: ServeRuntime::new(n_replicas, cfg, rt.queue_capacity),
            autoscaler: Autoscaler::new(rt.autoscale),
            active: n_replicas,
            fed_samples: 0,
            warm_floor: rt.warm_floor.clamp(1, n_replicas),
            next_replica: 0,
            served: HashMap::new(),
        }
    }

    /// Register the model for a workload kind, warming its compiled
    /// program (resident weights + pinned encodings + run arena) on the
    /// first [`RuntimeConfig::warm_floor`] replicas; the remaining
    /// replicas warm on demand when their worker first serves it.
    ///
    /// A failed warm evicts the replicas already warmed — an error
    /// leaves the router exactly as it was (the previous model, if any,
    /// keeps serving). Replacing a model quiesces the runtime first so
    /// in-flight requests against the old instance drain, then evicts
    /// its warm state (resident DRAM returns to the free list) on every
    /// replica.
    pub fn register(&mut self, kind: WorkloadKind, inst: ModelInstance) -> Result<()> {
        let inst = Arc::new(inst);
        for i in 0..self.warm_floor {
            let res = inst.warm(&mut self.runtime.soc(i).lock().unwrap());
            if let Err(e) = res {
                for j in 0..i {
                    inst.compiled.evict(&mut self.runtime.soc(j).lock().unwrap());
                }
                return Err(e);
            }
        }
        if let Some(old) = self.models.remove(&kind) {
            self.runtime.quiesce();
            for i in 0..self.runtime.n_replicas() {
                old.compiled.evict(&mut self.runtime.soc(i).lock().unwrap());
            }
        }
        self.models.insert(kind, inst);
        Ok(())
    }

    pub fn has(&self, kind: WorkloadKind) -> bool {
        self.models.contains_key(&kind)
    }

    pub fn model(&self, kind: WorkloadKind) -> Option<&ModelInstance> {
        self.models.get(&kind).map(Arc::as_ref)
    }

    /// Submit one request to the runtime; returns immediately with a
    /// completion handle. Dispatch round-robins over the active replica
    /// set; requests queued on the same replica serialize in FIFO order.
    pub fn submit(
        &mut self,
        kind: WorkloadKind,
        input: Vec<f32>,
        aux: Vec<f32>,
    ) -> Result<InferCompletion> {
        let Some(inst) = self.models.get(&kind) else {
            bail!("no model registered for {:?}", kind);
        };
        let replica = self.next_replica % self.active;
        self.next_replica = (replica + 1) % self.active;
        let (tx, rx) = crate::serve::completion();
        let job = Job {
            kind,
            inst: Arc::clone(inst),
            input,
            aux,
            enqueued: Instant::now(),
            done: tx,
        };
        if self.runtime.dispatch(replica, job).is_err() {
            bail!("serving runtime is shut down");
        }
        *self.served.entry(kind).or_insert(0) += 1;
        Ok(rx)
    }

    /// Submit every request of a released [`Batch`]; returns completion
    /// handles in request order. Requests spread round-robin over the
    /// active replicas, continuing where [`Router::submit`] left off;
    /// the per-replica queues preserve batch order, so results are
    /// bit-identical to routing each request through [`Router::route`].
    pub fn submit_batch(
        &mut self,
        kind: WorkloadKind,
        batch: &Batch,
    ) -> Result<Vec<InferCompletion>> {
        let reqs = &batch.requests;
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        let Some(inst) = self.models.get(&kind) else {
            bail!("no model registered for {:?}", kind);
        };
        let inst = Arc::clone(inst);
        let offset = self.next_replica % self.active;
        self.next_replica = (offset + reqs.len()) % self.active;
        let mut handles = Vec::with_capacity(reqs.len());
        for (i, r) in reqs.iter().enumerate() {
            let (tx, rx) = crate::serve::completion();
            let job = Job {
                kind,
                inst: Arc::clone(&inst),
                input: r.input.clone(),
                aux: r.aux.clone(),
                enqueued: Instant::now(),
                done: tx,
            };
            if self.runtime.dispatch((offset + i) % self.active, job).is_err() {
                bail!("serving runtime is shut down");
            }
            handles.push(rx);
        }
        *self.served.entry(kind).or_insert(0) += reqs.len() as u64;
        Ok(handles)
    }

    /// Redeem a completion handle (blocking).
    pub fn resolve(c: InferCompletion) -> Result<RoutedResult> {
        match c.wait() {
            Ok(res) => res,
            Err(canceled) => Err(canceled.into()),
        }
    }

    /// Route one request and wait for it — a blocking wrapper over
    /// [`Router::submit`].
    pub fn route(&mut self, kind: WorkloadKind, input: &[f32], aux: &[f32]) -> Result<RoutedResult> {
        Router::resolve(self.submit(kind, input.to_vec(), aux.to_vec())?)
    }

    /// Execute every request of a released [`Batch`] and wait for all of
    /// them — a blocking wrapper over [`Router::submit_batch`]. Results
    /// come back in request order.
    pub fn route_batch(&mut self, kind: WorkloadKind, batch: &Batch) -> Result<Vec<RoutedResult>> {
        self.submit_batch(kind, batch)?.into_iter().map(Router::resolve).collect()
    }

    /// The legacy PR 2 synchronous fan-out: scoped threads per batch,
    /// blocking until the slowest replica drains. Kept as the reference
    /// the runtime path is differentially tested against (identical
    /// replica assignment, values, and cycle/stat reports) and as the
    /// baseline of the `hotpath` bench's async-vs-sync section.
    pub fn route_batch_fanout(
        &mut self,
        kind: WorkloadKind,
        batch: &Batch,
    ) -> Result<Vec<RoutedResult>> {
        let reqs = &batch.requests;
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        let Some(inst) = self.models.get(&kind) else {
            bail!("no model registered for {:?}", kind);
        };
        let offset = self.next_replica % self.active;
        self.next_replica = (offset + reqs.len()) % self.active;
        let mut buckets: Vec<Vec<usize>> = vec![Vec::new(); self.active];
        for i in 0..reqs.len() {
            buckets[(offset + i) % self.active].push(i);
        }
        let per_replica: Vec<Result<Vec<(usize, RoutedResult)>>> = std::thread::scope(|s| {
            let handles: Vec<_> = buckets
                .into_iter()
                .enumerate()
                .map(|(ri, idxs)| {
                    let soc = Arc::clone(self.runtime.soc(ri));
                    let inst = Arc::clone(inst);
                    s.spawn(move || {
                        let mut soc = soc.lock().unwrap();
                        idxs.into_iter()
                            .map(|i| {
                                let r = &reqs[i];
                                let (output, report) = inst.infer(&mut soc, &r.input, &r.aux)?;
                                Ok((i, RoutedResult { kind, output, report, replica: ri }))
                            })
                            .collect::<Result<Vec<_>>>()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("replica worker panicked")).collect()
        });
        let mut slots: Vec<Option<RoutedResult>> = Vec::new();
        slots.resize_with(reqs.len(), || None);
        for chunk in per_replica {
            for (i, res) in chunk? {
                slots[i] = Some(res);
            }
        }
        *self.served.entry(kind).or_insert(0) += reqs.len() as u64;
        Ok(slots.into_iter().map(|r| r.expect("missing batch result")).collect())
    }

    /// One autoscaling tick: feed the queue-latency samples recorded
    /// since the last tick to the policy and apply its decision to the
    /// active dispatch set (in-flight load gates idle parking — a
    /// backlogged fleet is never parked). Returns the new active count.
    pub fn autoscale_tick(&mut self) -> usize {
        let (fresh, total) = self.runtime.queue_samples_since(self.fed_samples);
        self.fed_samples = total;
        self.autoscaler.observe_samples(&fresh);
        let target = self.autoscaler.decide(self.active, self.runtime.in_flight());
        self.active = target.clamp(1, self.runtime.n_replicas());
        self.active
    }

    /// Replicas currently receiving dispatch.
    pub fn active_replicas(&self) -> usize {
        self.active
    }

    /// Force the active dispatch set (clamped to `[1, n_replicas]`) —
    /// load-shaping for tests/benches; the autoscaler adjusts from here.
    pub fn set_active(&mut self, n: usize) {
        self.active = n.clamp(1, self.runtime.n_replicas());
        self.next_replica %= self.active;
    }

    /// Block until every submitted request has completed.
    pub fn quiesce(&self) {
        self.runtime.quiesce();
    }

    /// Host-side queue/service latency metrics from the runtime.
    pub fn runtime_metrics(&self) -> RuntimeMetrics {
        self.runtime.metrics()
    }

    /// Jobs queued (not yet picked up) on replica `i`.
    pub fn replica_queue_len(&self, i: usize) -> usize {
        self.runtime.queue_len(i)
    }

    /// Total requests served.
    pub fn total_served(&self) -> u64 {
        self.served.values().sum()
    }

    /// Lifetime job report of replica `i` (snapshot).
    pub fn replica_lifetime(&self, i: usize) -> JobReport {
        self.runtime.soc(i).lock().unwrap().lifetime.clone()
    }

    /// (hits, misses, preloads, trusted) of replica `i`'s
    /// operand-encoding cache — the observable proof that registered
    /// weights encode zero times on the serving path: weight operands
    /// ride their trusted pins past the cache entirely (`trusted`),
    /// only per-request activations encode (`misses`).
    pub fn replica_cache_stats(&self, i: usize) -> (u64, u64, u64, u64) {
        let soc = self.runtime.soc(i).lock().unwrap();
        let c = &soc.enc_cache;
        (c.hits, c.misses, c.preloads, c.trusted)
    }

    /// Pinned (weight-preload) entries resident in replica `i`'s cache.
    pub fn replica_pinned_len(&self, i: usize) -> usize {
        self.runtime.soc(i).lock().unwrap().enc_cache.pinned_len()
    }

    /// Resident-DRAM accounting of replica `i`: `(bump watermark bytes,
    /// reclaimed-but-buried free-list bytes)`.
    pub fn replica_resident(&self, i: usize) -> (u64, u64) {
        let soc = self.runtime.soc(i).lock().unwrap();
        (soc.resident_mark(), soc.resident_free_bytes())
    }

    pub fn n_replicas(&self) -> usize {
        self.runtime.n_replicas()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::random_weights as weights_for;
    use crate::models::{effnet, gaze};
    use crate::npe::PrecSel;

    #[test]
    fn routes_to_registered_model() {
        let mut r = Router::new(1, SocConfig::default());
        let g = gaze::build();
        let w = weights_for(&g, 1);
        r.register(WorkloadKind::Gaze, ModelInstance::uniform(g, w, PrecSel::Posit8x2).unwrap()).unwrap();
        let out = r.route(WorkloadKind::Gaze, &vec![0.1; 16], &[]).unwrap();
        assert_eq!(out.output.len(), 2);
        assert_eq!(r.total_served(), 1);
    }

    #[test]
    fn unregistered_kind_errors() {
        let mut r = Router::new(1, SocConfig::default());
        assert!(r.route(WorkloadKind::Vio, &[], &[]).is_err());
        assert!(r.submit(WorkloadKind::Vio, vec![], vec![]).is_err());
    }

    #[test]
    fn round_robin_across_replicas() {
        let mut r = Router::new(3, SocConfig::default());
        let g = gaze::build();
        let w = weights_for(&g, 2);
        r.register(WorkloadKind::Gaze, ModelInstance::uniform(g, w, PrecSel::Fp4x4).unwrap()).unwrap();
        let mut hits = vec![0u32; 3];
        for _ in 0..9 {
            let res = r.route(WorkloadKind::Gaze, &vec![0.1; 16], &[]).unwrap();
            hits[res.replica] += 1;
        }
        assert_eq!(hits, vec![3, 3, 3]);
    }

    #[test]
    fn batch_route_matches_serial_route() {
        use crate::coordinator::batcher::Request;
        let mut r = Router::new(3, SocConfig::default());
        let g = gaze::build();
        let w = weights_for(&g, 5);
        r.register(WorkloadKind::Gaze, ModelInstance::uniform(g, w, PrecSel::Posit8x2).unwrap()).unwrap();
        let inputs: Vec<Vec<f32>> = (0..7).map(|i| vec![0.02 * i as f32; 16]).collect();
        // serial reference outputs (numerics are replica-independent)
        let mut want = Vec::new();
        for x in &inputs {
            want.push(r.route(WorkloadKind::Gaze, x, &[]).unwrap().output);
        }
        let batch = Batch {
            requests: inputs
                .iter()
                .enumerate()
                .map(|(i, x)| Request {
                    id: i as u64,
                    input: x.clone(),
                    aux: vec![],
                    arrived: i as u64,
                })
                .collect(),
            released: 10,
        };
        let res = r.route_batch(WorkloadKind::Gaze, &batch).unwrap();
        assert_eq!(res.len(), 7);
        for (i, got) in res.iter().enumerate() {
            assert_eq!(got.output, want[i], "request {i}");
            // round-robin continues where the 7 serial route() calls left off
            assert_eq!(got.replica, (7 + i) % 3);
        }
        assert_eq!(r.served[&WorkloadKind::Gaze], 14);
    }

    #[test]
    fn consecutive_small_batches_rotate_replicas() {
        use crate::coordinator::batcher::Request;
        let mut r = Router::new(3, SocConfig::default());
        let g = gaze::build();
        let w = weights_for(&g, 6);
        r.register(WorkloadKind::Gaze, ModelInstance::uniform(g, w, PrecSel::Fp4x4).unwrap()).unwrap();
        let mut hits = vec![0u32; 3];
        for b in 0..6 {
            let batch = Batch {
                requests: vec![Request {
                    id: b,
                    input: vec![0.1; 16],
                    aux: vec![],
                    arrived: b,
                }],
                released: b,
            };
            let res = r.route_batch(WorkloadKind::Gaze, &batch).unwrap();
            hits[res[0].replica] += 1;
        }
        assert_eq!(hits, vec![2, 2, 2], "size-1 batches must still rotate replicas");
    }

    #[test]
    fn batch_route_empty_and_unregistered() {
        let mut r = Router::new(2, SocConfig::default());
        let empty = Batch { requests: vec![], released: 0 };
        assert!(r.route_batch(WorkloadKind::Vio, &empty).unwrap().is_empty());
        assert!(r.submit_batch(WorkloadKind::Vio, &empty).unwrap().is_empty());
        use crate::coordinator::batcher::Request;
        let one = Batch {
            requests: vec![Request { id: 0, input: vec![], aux: vec![], arrived: 0 }],
            released: 0,
        };
        assert!(r.route_batch(WorkloadKind::Vio, &one).is_err());
    }

    #[test]
    fn registration_warms_floor_then_serving_warms_on_demand() {
        // default runtime: warm floor 1 — replica 0 is warm at
        // registration, the others warm at their first request
        let mut r = Router::new(3, SocConfig::default());
        let g = gaze::build();
        let n_gemm = g.compute_layers().len() as u64;
        let w = weights_for(&g, 7);
        r.register(WorkloadKind::Gaze, ModelInstance::uniform(g, w, PrecSel::Posit8x2).unwrap())
            .unwrap();
        let stats: Vec<_> = (0..3).map(|i| r.replica_cache_stats(i)).collect();
        assert_eq!(stats[0], (0, 0, n_gemm, 0), "floor replica is warm");
        assert_eq!(stats[1], (0, 0, 0, 0), "replica 1 not warmed yet");
        assert_eq!(stats[2], (0, 0, 0, 0), "replica 2 not warmed yet");
        // 6 distinct requests round-robin over 3 replicas: each replica
        // warms at its first request, weights ride trusted pins past the
        // cache, only activations encode
        for q in 0..6 {
            r.route(WorkloadKind::Gaze, &vec![0.01 * q as f32; 16], &[]).unwrap();
        }
        for i in 0..3 {
            let (hits, misses, preloads, trusted) = r.replica_cache_stats(i);
            assert_eq!(preloads, n_gemm, "replica {i} warmed (eagerly or on demand)");
            assert_eq!(hits, 0, "replica {i}: weights never consult the cache");
            assert_eq!(misses, 2 * n_gemm, "replica {i}: only activations encode");
            assert_eq!(trusted, 2 * n_gemm, "replica {i}: weights ride trusted pins");
        }
    }

    #[test]
    fn warm_floor_covers_all_replicas_when_configured() {
        let rt = RuntimeConfig { warm_floor: 3, ..Default::default() };
        let mut r = Router::with_runtime(3, SocConfig::default(), rt);
        let g = gaze::build();
        let n_gemm = g.compute_layers().len() as u64;
        let w = weights_for(&g, 8);
        r.register(WorkloadKind::Gaze, ModelInstance::uniform(g, w, PrecSel::Posit8x2).unwrap())
            .unwrap();
        for i in 0..3 {
            let (hits, misses, preloads, trusted) = r.replica_cache_stats(i);
            assert_eq!((hits, misses, preloads, trusted), (0, 0, n_gemm, 0), "replica {i}");
        }
    }

    #[test]
    fn failed_registration_leaves_router_usable() {
        // 16 KiB DRAM: the effnet fc image does not fit, gaze does
        let cfg = SocConfig { dram_bytes: 1 << 14, ..Default::default() };
        let mut r = Router::new(2, cfg);
        let ge = effnet::build();
        let we = weights_for(&ge, 20);
        assert!(r
            .register(WorkloadKind::Classify, ModelInstance::uniform(ge, we, PrecSel::Posit8x2).unwrap())
            .is_err());
        let gg = gaze::build();
        let wg = weights_for(&gg, 21);
        r.register(WorkloadKind::Gaze, ModelInstance::uniform(gg, wg, PrecSel::Posit8x2).unwrap())
            .unwrap();
        let out = r.route(WorkloadKind::Gaze, &vec![0.1; 16], &[]).unwrap();
        assert_eq!(out.output.len(), 2);
    }

    #[test]
    fn reregistering_a_kind_evicts_the_old_warm_state() {
        let rt = RuntimeConfig { warm_floor: 2, ..Default::default() };
        let mut r = Router::with_runtime(2, SocConfig::default(), rt);
        let g = gaze::build();
        let n_gemm = g.compute_layers().len();
        let w1 = weights_for(&g, 30);
        r.register(WorkloadKind::Gaze, ModelInstance::uniform(g.clone(), w1, PrecSel::Posit8x2).unwrap())
            .unwrap();
        let w2 = weights_for(&g, 31);
        r.register(WorkloadKind::Gaze, ModelInstance::uniform(g.clone(), w2, PrecSel::Posit8x2).unwrap())
            .unwrap();
        for i in 0..2 {
            // the replaced model's pinned encodings are gone — only the
            // live model's weights stay pinned
            assert_eq!(r.replica_pinned_len(i), n_gemm, "replica {i}");
        }
        let out = r.route(WorkloadKind::Gaze, &vec![0.1; 16], &[]).unwrap();
        assert_eq!(out.output.len(), 2);
    }

    #[test]
    fn reregister_refresh_loop_keeps_resident_watermark_flat() {
        // the PR-2 leak: Router::register warms the new model *above*
        // the old one, so the evicted old image is always buried and —
        // without the free list — every refresh grew resident DRAM by a
        // full model. Now the freed spans are reused first-fit.
        let mut r = Router::new(1, SocConfig::default());
        let g = gaze::build();
        let w0 = weights_for(&g, 50);
        r.register(WorkloadKind::Gaze, ModelInstance::uniform(g.clone(), w0, PrecSel::Posit8x2).unwrap())
            .unwrap();
        let w1 = weights_for(&g, 51);
        r.register(WorkloadKind::Gaze, ModelInstance::uniform(g.clone(), w1, PrecSel::Posit8x2).unwrap())
            .unwrap();
        // peak: the moment both old and new coexist during the handover
        let (peak, _) = r.replica_resident(0);
        for seed in 52..57 {
            let w = weights_for(&g, seed);
            r.register(
                WorkloadKind::Gaze,
                ModelInstance::uniform(g.clone(), w, PrecSel::Posit8x2).unwrap(),
            )
            .unwrap();
            let (mark, _) = r.replica_resident(0);
            assert!(
                mark <= peak,
                "seed {seed}: resident watermark {mark} grew past the two-model peak {peak}"
            );
            // the refreshed model still serves
            let out = r.route(WorkloadKind::Gaze, &vec![0.1; 16], &[]).unwrap();
            assert_eq!(out.output.len(), 2);
        }
    }

    #[test]
    fn mixed_workloads_share_replicas() {
        let mut r = Router::new(2, SocConfig::default());
        let gg = gaze::build();
        let wg = weights_for(&gg, 3);
        r.register(WorkloadKind::Gaze, ModelInstance::uniform(gg, wg, PrecSel::Posit8x2).unwrap()).unwrap();
        let gc = effnet::build();
        let wc = weights_for(&gc, 4);
        r.register(WorkloadKind::Classify, ModelInstance::uniform(gc, wc, PrecSel::Fp4x4).unwrap()).unwrap();
        r.route(WorkloadKind::Gaze, &vec![0.1; 16], &[]).unwrap();
        r.route(WorkloadKind::Classify, &vec![0.1; 256], &[]).unwrap();
        assert_eq!(r.total_served(), 2);
        assert_eq!(r.served[&WorkloadKind::Gaze], 1);
    }

    #[test]
    fn set_active_confines_dispatch_and_parked_replicas_idle() {
        let mut r = Router::new(3, SocConfig::default());
        let g = gaze::build();
        let w = weights_for(&g, 40);
        r.register(WorkloadKind::Gaze, ModelInstance::uniform(g, w, PrecSel::Fp4x4).unwrap()).unwrap();
        r.set_active(1);
        for q in 0..4 {
            let res = r.route(WorkloadKind::Gaze, &vec![0.05 * q as f32; 16], &[]).unwrap();
            assert_eq!(res.replica, 0, "parked replicas must not receive dispatch");
        }
        assert_eq!(r.replica_lifetime(1).total_cycles, 0);
        assert_eq!(r.replica_lifetime(2).total_cycles, 0);
        // unpark: dispatch spreads again
        r.set_active(3);
        let mut hits = vec![0u32; 3];
        for _ in 0..6 {
            hits[r.route(WorkloadKind::Gaze, &vec![0.1; 16], &[]).unwrap().replica] += 1;
        }
        assert_eq!(hits, vec![2, 2, 2]);
    }

    #[test]
    fn autoscale_grows_under_queue_pressure_and_parks_when_idle() {
        use crate::coordinator::batcher::Request;
        let rt = RuntimeConfig {
            autoscale: AutoscaleConfig {
                floor: 1,
                max: 4,
                scale_up_p95: 1, // any measurable queueing is pressure
                scale_down_p95: 0,
                window: 64,
                step: 1,
                idle_patience: 2,
            },
            ..Default::default()
        };
        let mut r = Router::with_runtime(4, SocConfig::default(), rt);
        let g = gaze::build();
        let w = weights_for(&g, 41);
        r.register(WorkloadKind::Gaze, ModelInstance::uniform(g, w, PrecSel::Posit8x2).unwrap())
            .unwrap();
        r.set_active(1);
        // sustained pressure: batches serialize on the single active
        // replica, so queue latency accumulates; each tick scales up
        let mut rounds = 0;
        while r.active_replicas() < 4 {
            let batch = Batch {
                requests: (0..12)
                    .map(|i| Request {
                        id: rounds * 12 + i,
                        input: vec![0.01 * i as f32; 16],
                        aux: vec![],
                        arrived: 0,
                    })
                    .collect(),
                released: 0,
            };
            r.route_batch(WorkloadKind::Gaze, &batch).unwrap();
            r.autoscale_tick();
            rounds += 1;
            assert!(rounds < 20, "autoscaler failed to scale up under sustained pressure");
        }
        assert_eq!(r.active_replicas(), 4);
        // idle: no traffic between ticks → parks back to the floor
        r.autoscale_tick();
        let after_idle = r.autoscale_tick();
        assert_eq!(after_idle, 1, "idle runtime must park to the floor");
    }
}
