//! Frame-request batching with deadline flush.
//!
//! XR perception is latency-critical: the batcher accumulates requests
//! only up to `max_batch` or `deadline_cycles` (whichever first), so a
//! lone request never waits for company longer than the deadline. This
//! is the standard dynamic-batching policy of serving routers (vLLM-style)
//! restricted to XR's real-time regime.

use std::collections::VecDeque;

/// One queued request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub input: Vec<f32>,
    pub aux: Vec<f32>,
    /// Arrival time in coordinator cycles.
    pub arrived: u64,
}

/// A flushed batch.
#[derive(Debug, Clone)]
pub struct Batch {
    pub requests: Vec<Request>,
    /// Cycle at which the batch was released.
    pub released: u64,
}

/// Batching policy + queue.
#[derive(Debug)]
pub struct FrameBatcher {
    pub max_batch: usize,
    pub deadline_cycles: u64,
    queue: VecDeque<Request>,
    next_id: u64,
}

impl FrameBatcher {
    pub fn new(max_batch: usize, deadline_cycles: u64) -> FrameBatcher {
        assert!(max_batch >= 1);
        FrameBatcher { max_batch, deadline_cycles, queue: VecDeque::new(), next_id: 0 }
    }

    /// Enqueue a request at `now`; returns its id.
    pub fn push(&mut self, input: Vec<f32>, aux: Vec<f32>, now: u64) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back(Request { id, input, aux, arrived: now });
        id
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Release a batch if policy allows at `now`.
    pub fn poll(&mut self, now: u64) -> Option<Batch> {
        let oldest = self.queue.front()?.arrived;
        if self.queue.len() >= self.max_batch || now.saturating_sub(oldest) >= self.deadline_cycles
        {
            let take = self.queue.len().min(self.max_batch);
            let requests: Vec<Request> = self.queue.drain(..take).collect();
            return Some(Batch { requests, released: now });
        }
        None
    }

    /// Force-release everything (pipeline shutdown).
    pub fn flush(&mut self, now: u64) -> Option<Batch> {
        if self.queue.is_empty() {
            return None;
        }
        let requests: Vec<Request> = self.queue.drain(..).collect();
        Some(Batch { requests, released: now })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{self, Draw};

    #[test]
    fn full_batch_releases_immediately() {
        let mut b = FrameBatcher::new(2, 1000);
        b.push(vec![1.0], vec![], 0);
        assert!(b.poll(1).is_none());
        b.push(vec![2.0], vec![], 1);
        let batch = b.poll(1).unwrap();
        assert_eq!(batch.requests.len(), 2);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn deadline_releases_partial_batch() {
        let mut b = FrameBatcher::new(8, 100);
        b.push(vec![1.0], vec![], 0);
        assert!(b.poll(50).is_none());
        let batch = b.poll(100).unwrap();
        assert_eq!(batch.requests.len(), 1);
    }

    #[test]
    fn poll_releases_exactly_at_the_deadline_tick() {
        let mut b = FrameBatcher::new(8, 100);
        b.push(vec![1.0], vec![], 5);
        assert!(b.poll(104).is_none(), "one tick before the deadline holds");
        let batch = b.poll(105).expect("age == deadline_cycles must release");
        assert_eq!(batch.requests.len(), 1);
        assert_eq!(batch.released, 105);
        // the deadline clock runs from the *oldest* pending request
        b.push(vec![2.0], vec![], 200);
        b.push(vec![3.0], vec![], 290);
        assert!(b.poll(299).is_none());
        let batch = b.poll(300).expect("oldest request's age drives the deadline");
        assert_eq!(batch.requests.len(), 2, "a due deadline flushes everything pending");
    }

    #[test]
    fn flush_releases_partial_batch_before_any_policy_fires() {
        let mut b = FrameBatcher::new(4, 1000);
        let i0 = b.push(vec![1.0], vec![], 0);
        let i1 = b.push(vec![2.0], vec![], 1);
        assert!(b.poll(2).is_none(), "neither size nor deadline is due");
        let batch = b.flush(2).expect("flush must release the partial batch");
        assert_eq!(batch.requests.len(), 2);
        assert_eq!(batch.released, 2);
        assert_eq!(
            batch.requests.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![i0, i1],
            "flush preserves FIFO order"
        );
        assert_eq!(b.pending(), 0);
        assert!(b.flush(3).is_none(), "empty batcher flushes nothing");
    }

    #[test]
    fn fifo_order_and_unique_ids() {
        let mut b = FrameBatcher::new(4, 10);
        let i0 = b.push(vec![], vec![], 0);
        let i1 = b.push(vec![], vec![], 1);
        let i2 = b.push(vec![], vec![], 2);
        b.push(vec![], vec![], 3);
        let batch = b.poll(3).unwrap();
        let ids: Vec<u64> = batch.requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![i0, i1, i2, ids[3]]);
    }

    #[test]
    fn property_batch_never_exceeds_max_and_conserves_requests() {
        proptest::check(|rng, _| {
            let max_batch = rng.usize_in(1, 8);
            let deadline = rng.usize_in(1, 50) as u64;
            let mut b = FrameBatcher::new(max_batch, deadline);
            let mut pushed = 0u64;
            let mut released = 0u64;
            let mut now = 0u64;
            for _ in 0..rng.usize_in(1, 60) {
                now += rng.usize_in(0, 20) as u64;
                if rng.coin(0.7) {
                    b.push(vec![], vec![], now);
                    pushed += 1;
                }
                while let Some(batch) = b.poll(now) {
                    assert!(batch.requests.len() <= max_batch);
                    released += batch.requests.len() as u64;
                    // no request waited longer than the deadline past a poll
                    for r in &batch.requests {
                        assert!(now >= r.arrived);
                    }
                }
            }
            if let Some(batch) = b.flush(now) {
                released += batch.requests.len() as u64;
            }
            assert_eq!(pushed, released, "requests conserved");
        });
    }
}
