//! Forward execution of a [`ModelGraph`] — the f32 reference path and the
//! bit-accurate NPE paths.
//!
//! The NPE paths lower every compute layer to an im2col GEMM on the
//! simulated co-processor ([`crate::soc::Soc`]) under a per-layer
//! [`PrecisionPlan`]: weights *and* activations are quantized to the
//! layer's `prec_sel` on entry (the engine's input stage), accumulation
//! is quire-exact, and the output is rounded once to the layer's format —
//! precisely the paper's inference configuration ("activations are
//! retained with particular precision across all layers"). Per-tensor
//! power-of-two scales (eq. 3 restricted to 2^k — an exponent offset in
//! hardware) normalize operands into each format's sweet spot; bias is
//! preloaded into the accumulation at full scale and the output is
//! requantized once.
//!
//! There are two NPE backends with bit-identical results (values,
//! cycles, engine stats — asserted by the differential tests in
//! [`super::compile`]):
//!
//! * [`Backend::Npe`] **replays a compiled program**
//!   ([`super::compile::CompiledModel`]): weights were scaled + encoded
//!   once at compile time, im2col is a precomputed gather, activations
//!   flow through a preallocated ping-pong arena. This is the serving
//!   path.
//! * [`Backend::NpeInterpret`] lowers the graph **per request** —
//!   re-running im2col, weight scaling and operand materialization every
//!   time. It is kept as the independent reference the compiled path is
//!   differentially tested against.
//!
//! Weight layout (must match `python/compile/model.py`):
//! * conv `<name>.w`: dims `[k, k, in_c, out_c]` (HWIO), `<name>.b`: `[out_c]`
//! * fc `<name>.w`: dims `[in_f, out_f]`, `<name>.b`: `[out_f]`
//! * pact `<name>.alpha`: `[1]`

use super::compile::{CompileError, CompiledModel};
use super::graph::{ActKind, LayerKind, ModelGraph, PoolKind, Shape};
use crate::arith::{tables, Precision};
use crate::quant::PrecisionPlan;
use crate::soc::{JobReport, Soc};
use crate::util::io::TensorMap;
use crate::util::Matrix;
use anyhow::{bail, Context, Result};

/// Execution statistics for one forward pass (NPE path).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ExecReport {
    /// Merged co-processor job reports over all compute layers (for a
    /// sharded request: summed over every shard's partial GEMMs).
    pub jobs: JobReport,
    /// Vector-unit (pool/act) element operations, charged at `lanes`
    /// elems/cycle on the output stage.
    pub vector_cycles: u64,
    /// Cross-shard quire-reduction cycles (the **documented reduction
    /// term**, [`crate::models::compile::reduction_cost`]); zero on the
    /// whole-model path.
    pub reduce_cycles: u64,
    /// Cross-shard quire traffic in bytes (partial-quire images moved to
    /// the reducer); zero on the whole-model path.
    pub reduce_bytes: u64,
    /// Simulated straggler cycles the **streaming** sharded pipeline
    /// hides: quire-merge passes overlapped with in-flight shard compute
    /// plus next-layer weight-DMA prefetched behind the coordinator's
    /// merge/vector tail. Observability only — [`ExecReport::total_cycles`]
    /// stays the barrier-schedule sum (subtract this to get the
    /// streaming critical path). Zero on the whole-model path and under
    /// the barrier shard flow; deterministic (derived from per-shard
    /// [`JobReport`] components, never host arrival order).
    pub overlap_cycles_hidden: u64,
    /// Prefetch demand the shared AXI channel could **not** absorb
    /// inside the coordinator's merge/vector window: the part of the
    /// next layer's weight stream left exposed on the streaming critical
    /// path. Together with the hidden counter it is bounded by the work
    /// actually performed (`axi_stall_cycles + overlap_cycles_hidden ≤
    /// total_cycles()`, property-tested in `models::compile`).
    /// Observability only, like `overlap_cycles_hidden`; zero on the
    /// whole-model path and under the barrier shard flow.
    pub axi_stall_cycles: u64,
    /// The prefetch share of [`ExecReport::overlap_cycles_hidden`]:
    /// next-layer weight streaming hidden behind the coordinator's
    /// merge/vector tail (the remainder of the hidden counter is
    /// incremental quire-merge overlap). What the bench gate ratchets
    /// as `sim_prefetch_hidden_per_round` and the tracer renders as the
    /// `Prefetch` span. Always `≤ overlap_cycles_hidden`.
    pub prefetch_hidden_cycles: u64,
    /// Per-layer (layer index, cycles) breakdown.
    pub per_layer_cycles: Vec<(usize, u64)>,
    /// Precision-ladder rung that produced this report (0 = highest
    /// fidelity; also 0 for every single-plan model, so pre-ladder
    /// reports are unchanged). Stamped by
    /// [`super::compile::CompiledModel::replay`] from the compiled
    /// program's rung tag — the per-request plan stamp the tracer
    /// renders as `PlanStamp` and the registry rolls up under
    /// `sim_ladder_*`. [`ExecReport::merge`] keeps `self`'s rung: a
    /// sharded request's partials all come from the same rung.
    pub rung: u32,
}

impl ExecReport {
    pub fn total_cycles(&self) -> u64 {
        self.jobs.total_cycles + self.vector_cycles + self.reduce_cycles
    }

    /// Sum of the per-layer GEMM cycles — the portion of
    /// [`ExecReport::total_cycles`] the tracer renders as `GemmJob`
    /// spans; the remainder splits into the `Requantize` span
    /// (`vector_cycles`) and, on the sharded path, the `QuireMerge`
    /// spans (`reduce_cycles`). The trace decomposition in
    /// [`crate::obs`] is therefore exactly this report, re-laid-out on
    /// a timeline — never a second accounting.
    pub fn gemm_cycles(&self) -> u64 {
        self.per_layer_cycles.iter().map(|&(_, c)| c).sum()
    }

    pub fn merge(&mut self, o: &ExecReport) {
        self.jobs.merge(&o.jobs);
        self.vector_cycles += o.vector_cycles;
        self.reduce_cycles += o.reduce_cycles;
        self.reduce_bytes += o.reduce_bytes;
        self.overlap_cycles_hidden += o.overlap_cycles_hidden;
        self.axi_stall_cycles += o.axi_stall_cycles;
        self.prefetch_hidden_cycles += o.prefetch_hidden_cycles;
    }
}

/// How to run the graph.
pub enum Backend<'a> {
    /// Pure f32 reference.
    Ref,
    /// Bit-accurate co-processor path replaying a compiled program
    /// (weights encoded once per registration — the serving path).
    Npe { soc: &'a mut Soc, model: &'a CompiledModel },
    /// Bit-accurate co-processor path interpreted per request (reference
    /// for differential testing of the compiled path).
    NpeInterpret { soc: &'a mut Soc, plan: &'a PrecisionPlan },
}

/// The executor.
pub struct Executor<'a> {
    pub graph: &'a ModelGraph,
    pub weights: &'a TensorMap,
}

impl<'a> Executor<'a> {
    pub fn new(graph: &'a ModelGraph, weights: &'a TensorMap) -> Executor<'a> {
        Executor { graph, weights }
    }

    fn tensor(&self, name: &str) -> Result<&crate::util::io::Tensor> {
        self.weights
            .get(name)
            .with_context(|| format!("missing weight tensor `{name}` for {}", self.graph.name))
    }

    /// Forward pass. `aux` feeds `ConcatAux` layers (in order).
    pub fn forward(
        &self,
        input: &[f32],
        aux: &[f32],
        backend: &mut Backend,
    ) -> Result<(Vec<f32>, ExecReport)> {
        match backend {
            // The compiled backend replays its pre-lowered program; the
            // graph walk below is the reference lowering.
            // The replay uses the compiled model's own weights; the
            // name check catches graph mix-ups, but pairing the model
            // with the weights it was compiled from is the caller's
            // responsibility (`ModelInstance` guarantees it).
            Backend::Npe { soc, model } => {
                if model.name != self.graph.name {
                    bail!(
                        "compiled model was built for graph `{}` but the executor holds `{}`",
                        model.name,
                        self.graph.name
                    );
                }
                return model.replay(soc, input, aux);
            }
            // Validate the plan against the graph up front — a length
            // mismatch is a registration bug and must surface as a typed
            // error, not an index panic mid-inference.
            Backend::NpeInterpret { plan, .. } => {
                let compute = self.graph.compute_layers().len();
                if plan.per_layer.len() != compute {
                    return Err(CompileError::PlanLayerMismatch {
                        model: self.graph.name.clone(),
                        plan_layers: plan.per_layer.len(),
                        compute_layers: compute,
                    }
                    .into());
                }
            }
            Backend::Ref => {}
        }
        let shapes = self.graph.shapes();
        if input.len() != shapes[0].numel() {
            bail!("input length {} != {}", input.len(), shapes[0].numel());
        }
        let mut act: Vec<f32> = input.to_vec();
        let mut report = ExecReport::default();
        let mut compute_idx = 0usize; // index among compute layers (plan granularity)

        for (li, layer) in self.graph.layers.iter().enumerate() {
            let in_shape = shapes[li];
            match &layer.kind {
                LayerKind::Conv2d { in_c, out_c, k, stride, pad } => {
                    let a = im2col(&act, in_shape, *k, *stride, *pad);
                    let wt = self.tensor(&format!("{}.w", layer.name))?;
                    if wt.dims != vec![*k, *k, *in_c, *out_c] {
                        bail!("{}.w dims {:?} unexpected", layer.name, wt.dims);
                    }
                    let b = Matrix::from_vec(in_c * k * k, *out_c, wt.data.clone());
                    let bias = self.tensor(&format!("{}.b", layer.name))?;
                    let out_shape = layer.kind.out_shape(in_shape);
                    let out = self.run_gemm(
                        li,
                        compute_idx,
                        &a,
                        &b,
                        &bias.data,
                        backend,
                        &mut report,
                    )?;
                    // out: (oh*ow) × out_c → CHW
                    act = hwc_to_chw(&out, out_shape);
                    compute_idx += 1;
                }
                LayerKind::Fc { in_f, out_f } => {
                    let a = Matrix::from_vec(1, *in_f, act.clone());
                    let wt = self.tensor(&format!("{}.w", layer.name))?;
                    if wt.dims != vec![*in_f, *out_f] {
                        bail!("{}.w dims {:?} unexpected", layer.name, wt.dims);
                    }
                    let b = Matrix::from_vec(*in_f, *out_f, wt.data.clone());
                    let bias = self.tensor(&format!("{}.b", layer.name))?;
                    let out =
                        self.run_gemm(li, compute_idx, &a, &b, &bias.data, backend, &mut report)?;
                    act = out.data;
                    compute_idx += 1;
                }
                LayerKind::Pool { kind, size } => {
                    act = pool(&act, in_shape, *kind, *size);
                    report.vector_cycles += (in_shape.numel() / 2) as u64;
                }
                LayerKind::Act(kind) => {
                    let alpha = match kind {
                        ActKind::Pact => {
                            self.tensor(&format!("{}.alpha", layer.name))?.data[0] as f64
                        }
                        _ => 0.0,
                    };
                    for v in act.iter_mut() {
                        *v = activate(*v as f64, *kind, alpha) as f32;
                    }
                    report.vector_cycles += (act.len() / 4) as u64;
                }
                LayerKind::Flatten => { /* CHW storage is already flat */ }
                LayerKind::ConcatAux { n } => {
                    if aux.len() != *n {
                        bail!("aux length {} != {}", aux.len(), n);
                    }
                    act.extend_from_slice(aux);
                }
            }
        }
        Ok((act, report))
    }

    /// GEMM + bias on the selected backend (bias via ones-column
    /// augmentation so it lands in the quire).
    #[allow(clippy::too_many_arguments)]
    fn run_gemm(
        &self,
        layer_idx: usize,
        compute_idx: usize,
        a: &Matrix,
        b: &Matrix,
        bias: &[f32],
        backend: &mut Backend,
        report: &mut ExecReport,
    ) -> Result<Matrix> {
        match backend {
            Backend::Ref => {
                let out = a.matmul(b).add_row(bias);
                Ok(out)
            }
            Backend::NpeInterpret { soc, plan } => {
                let sel = plan.per_layer[compute_idx];
                let prec = sel.precision();
                let out_prec = plan.layer_precision(compute_idx);
                // Per-tensor pow-2 scales (exponent-offset registers of
                // the input stage — mirror of quantlib.scale_for /
                // dyn_scale).
                let s_a = scale_for(&a.data, prec);
                let s_b = scale_for(&b.data, prec);
                let a_s = a.map(|x| (x as f64 / s_a) as f32);
                let b_s = b.map(|x| (x as f64 / s_b) as f32);
                // GEMM with quire-exact accumulate; output processing
                // folds the combined scale back in (f32 carrier, single
                // requant below). The compiled path precomputes the
                // scaled weight matrix and its packed encoding instead
                // of redoing this work per request.
                let (raw, rep) = soc.gemm(&a_s, &b_s, sel, Precision::Fp32)?;
                report.per_layer_cycles.push((layer_idx, rep.total_cycles));
                report.jobs.merge(&rep);
                let mut out = Matrix::zeros(a.rows, b.cols);
                postprocess_gemm(&raw, s_a, s_b, bias, out_prec, &mut out);
                Ok(out)
            }
            Backend::Npe { .. } => unreachable!("compiled backend handled in forward()"),
        }
    }

    /// Convenience: f32 reference forward.
    pub fn forward_ref(&self, input: &[f32], aux: &[f32]) -> Result<Vec<f32>> {
        Ok(self.forward(input, aux, &mut Backend::Ref)?.0)
    }

    /// Convenience: interpreted NPE forward under a plan (the reference
    /// lowering the compiled path is differentially tested against).
    pub fn forward_interpret(
        &self,
        input: &[f32],
        aux: &[f32],
        soc: &mut Soc,
        plan: &PrecisionPlan,
    ) -> Result<(Vec<f32>, ExecReport)> {
        self.forward(input, aux, &mut Backend::NpeInterpret { soc, plan })
    }

    /// Convenience: NPE forward replaying a compiled program.
    pub fn forward_compiled(
        &self,
        input: &[f32],
        aux: &[f32],
        soc: &mut Soc,
        model: &CompiledModel,
    ) -> Result<(Vec<f32>, ExecReport)> {
        self.forward(input, aux, &mut Backend::Npe { soc, model })
    }
}

/// Shared GEMM output processing: fold the operand scales back in, add
/// the bias at full scale (quire-side preload), then requantize once to
/// the layer's activation format at its own pow-2 scale. Both NPE
/// backends call this with identical inputs, so the expression — and its
/// f64 rounding — is shared rather than duplicated.
pub(crate) fn postprocess_gemm(
    raw: &Matrix,
    s_a: f64,
    s_b: f64,
    bias: &[f32],
    out_prec: Precision,
    out: &mut Matrix,
) {
    postprocess_fold(raw, s_a, s_b, bias, out);
    requantize(out_prec, out);
}

/// First half of [`postprocess_gemm`]: fold the operand scales back in
/// and add the bias — **purely element-wise**, so a disjoint column
/// block computed on a shard replica (the N-split local tail, with the
/// bias sliced to the block) is bit-identical to the same columns of the
/// full-matrix fold. Split out for exactly that reuse.
pub(crate) fn postprocess_fold(raw: &Matrix, s_a: f64, s_b: f64, bias: &[f32], out: &mut Matrix) {
    debug_assert_eq!((out.rows, out.cols), (raw.rows, raw.cols));
    for r in 0..raw.rows {
        for c in 0..raw.cols {
            out.set(r, c, ((raw.at(r, c) as f64) * s_a * s_b) as f32 + bias[c]);
        }
    }
}

/// Second half of [`postprocess_gemm`]: requantize once to the layer's
/// activation format at its own pow-2 scale. `s_out` is computed over
/// the **full** output tensor — a global data dependence, which is why
/// the N-split local tail stops at the fold and the coordinator runs
/// this pass on the assembled output.
pub(crate) fn requantize(out_prec: Precision, out: &mut Matrix) {
    let s_out = scale_for(&out.data, out_prec);
    for v in out.data.iter_mut() {
        *v = (s_out * tables::quantize(out_prec, *v as f64 / s_out)) as f32;
    }
}

/// Per-tensor power-of-two scale — mirror of
/// `python/compile/quantlib.py::scale_for` (paper eq. 3 restricted to
/// powers of two so hardware folds the scale into the exponent path).
/// Range-fit for narrow formats; magnitude-centering for posits (their
/// tapered precision peaks at 1.0); identity for wide formats.
pub fn scale_for(xs: &[f32], prec: Precision) -> f64 {
    use Precision::*;
    match prec {
        Fp32 | Fp16 | Bf16 => return 1.0,
        _ => {}
    }
    if xs.is_empty() {
        return 1.0;
    }
    let range_fit = matches!(prec, Fp4 | Fxp4 | Fxp8 | Fxp16 | Fp8E4M3 | Fp8E5M2);
    if range_fit {
        let m = xs.iter().fold(0.0f64, |m, &x| m.max(x.abs() as f64));
        if m == 0.0 {
            return 1.0;
        }
        2f64.powi((m / prec.max_value()).log2().round() as i32)
    } else {
        let m = xs.iter().map(|&x| x.abs() as f64).sum::<f64>() / xs.len() as f64;
        if m == 0.0 {
            return 1.0;
        }
        2f64.powi(m.log2().round() as i32)
    }
}

/// im2col: CHW input → (oh·ow) × (in_c·k·k) patch matrix with patch
/// element order (ky, kx, ic) — matching the HWIO weight flattening.
pub fn im2col(input: &[f32], s: Shape, k: usize, stride: usize, pad: usize) -> Matrix {
    let oh = (s.h + 2 * pad - k) / stride + 1;
    let ow = (s.w + 2 * pad - k) / stride + 1;
    let mut m = Matrix::zeros(oh * ow, s.c * k * k);
    for oy in 0..oh {
        for ox in 0..ow {
            let row = oy * ow + ox;
            for ky in 0..k {
                for kx in 0..k {
                    let iy = (oy * stride + ky) as isize - pad as isize;
                    let ix = (ox * stride + kx) as isize - pad as isize;
                    if iy < 0 || ix < 0 || iy >= s.h as isize || ix >= s.w as isize {
                        continue; // zero pad
                    }
                    for ic in 0..s.c {
                        let v = input[ic * s.h * s.w + iy as usize * s.w + ix as usize];
                        m.set(row, (ky * k + kx) * s.c + ic, v);
                    }
                }
            }
        }
    }
    m
}

/// (oh·ow)×out_c GEMM output → CHW, into a preallocated slice (the
/// compiled path's arena buffer).
pub(crate) fn chw_into(out: &Matrix, s: Shape, v: &mut [f32]) {
    debug_assert_eq!(v.len(), s.numel());
    for p in 0..s.h * s.w {
        for c in 0..s.c {
            v[c * s.h * s.w + p] = out.at(p, c);
        }
    }
}

/// (oh·ow)×out_c GEMM output → CHW.
fn hwc_to_chw(out: &Matrix, s: Shape) -> Vec<f32> {
    let mut v = vec![0.0f32; s.numel()];
    chw_into(out, s, &mut v);
    v
}

/// Spatial pooling into a preallocated slice (compiled-path arena).
pub(crate) fn pool_into(input: &[f32], s: Shape, kind: PoolKind, size: usize, out: &mut [f32]) {
    let oh = s.h / size;
    let ow = s.w / size;
    debug_assert_eq!(out.len(), s.c * oh * ow);
    for c in 0..s.c {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut acc = match kind {
                    PoolKind::Max => f32::MIN,
                    PoolKind::Avg => 0.0,
                };
                for dy in 0..size {
                    for dx in 0..size {
                        let v = input[c * s.h * s.w + (oy * size + dy) * s.w + (ox * size + dx)];
                        match kind {
                            PoolKind::Max => acc = acc.max(v),
                            PoolKind::Avg => acc += v,
                        }
                    }
                }
                if kind == PoolKind::Avg {
                    acc /= (size * size) as f32;
                }
                out[c * oh * ow + oy * ow + ox] = acc;
            }
        }
    }
}

fn pool(input: &[f32], s: Shape, kind: PoolKind, size: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; s.c * (s.h / size) * (s.w / size)];
    pool_into(input, s, kind, size, &mut out);
    out
}

pub(crate) fn activate(x: f64, kind: ActKind, alpha: f64) -> f64 {
    match kind {
        ActKind::Relu => x.max(0.0),
        // eqs. (6)+(7): clip AND quantize to the 8-bit PACT grid —
        // matching python model.pact_act (n_bits = 8)
        ActKind::Pact => crate::quant::pact::pact_quantize(x, alpha.max(1e-3), 8),
        ActKind::Tanh => x.tanh(),
        ActKind::Identity => x,
    }
}

/// Quantize a weight map to a per-layer plan (for size accounting and
/// sensitivity sweeps — the NPE path re-quantizes on entry anyway).
pub fn quantize_weights(
    graph: &ModelGraph,
    weights: &TensorMap,
    prec: Precision,
) -> TensorMap {
    let mut out = weights.clone();
    for layer in &graph.layers {
        for suffix in ["w", "b"] {
            if let Some(t) = out.get_mut(&format!("{}.{}", layer.name, suffix)) {
                for v in t.data.iter_mut() {
                    *v = tables::quantize(prec, *v as f64) as f32;
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::graph::Layer;
    use crate::npe::PrecSel;
    use crate::soc::SocConfig;
    use crate::util::io::Tensor;
    use crate::util::Rng;

    fn toy_graph() -> ModelGraph {
        ModelGraph {
            name: "toy".into(),
            input: Shape { c: 2, h: 6, w: 6 },
            layers: vec![
                Layer {
                    name: "conv1".into(),
                    kind: LayerKind::Conv2d { in_c: 2, out_c: 4, k: 3, stride: 1, pad: 1 },
                },
                Layer { name: "act1".into(), kind: LayerKind::Act(ActKind::Relu) },
                Layer { name: "pool1".into(), kind: LayerKind::Pool { kind: PoolKind::Max, size: 2 } },
                Layer { name: "flat".into(), kind: LayerKind::Flatten },
                Layer { name: "fc1".into(), kind: LayerKind::Fc { in_f: 36, out_f: 5 } },
            ],
        }
    }

    fn toy_weights(g: &ModelGraph, rng: &mut Rng) -> TensorMap {
        let mut m = TensorMap::new();
        m.insert("conv1.w".into(), Tensor::new(vec![3, 3, 2, 4], {
            let mut v = vec![0f32; 72];
            rng.fill_normal(&mut v, 0.4);
            v
        }));
        m.insert("conv1.b".into(), Tensor::new(vec![4], vec![0.1, -0.1, 0.05, 0.0]));
        m.insert("fc1.w".into(), Tensor::new(vec![36, 5], {
            let mut v = vec![0f32; 180];
            rng.fill_normal(&mut v, 0.3);
            v
        }));
        m.insert("fc1.b".into(), Tensor::new(vec![5], vec![0.0; 5]));
        let _ = g;
        m
    }

    #[test]
    fn im2col_identity_kernel() {
        // k=1 conv im2col is the identity permutation
        let s = Shape { c: 2, h: 3, w: 3 };
        let input: Vec<f32> = (0..18).map(|i| i as f32).collect();
        let m = im2col(&input, s, 1, 1, 0);
        assert_eq!(m.rows, 9);
        assert_eq!(m.cols, 2);
        // row p, col ic = input[ic*9 + p]
        assert_eq!(m.at(4, 1), input[9 + 4]);
    }

    #[test]
    fn im2col_padding_zeroes_border() {
        let s = Shape { c: 1, h: 2, w: 2 };
        let input = vec![1.0, 2.0, 3.0, 4.0];
        let m = im2col(&input, s, 3, 1, 1);
        // top-left output patch: corner elements padded
        assert_eq!(m.at(0, 0), 0.0); // ky=0,kx=0 out of bounds
        assert_eq!(m.at(0, 4), 1.0); // center = input(0,0)
    }

    #[test]
    fn ref_forward_shapes() {
        let g = toy_graph();
        let mut rng = Rng::new(1);
        let w = toy_weights(&g, &mut rng);
        let ex = Executor::new(&g, &w);
        let input: Vec<f32> = (0..72).map(|i| (i as f32 * 0.1).sin()).collect();
        let out = ex.forward_ref(&input, &[]).unwrap();
        assert_eq!(out.len(), 5);
    }

    #[test]
    fn npe_at_posit16_close_to_ref() {
        let g = toy_graph();
        let mut rng = Rng::new(2);
        let w = toy_weights(&g, &mut rng);
        let ex = Executor::new(&g, &w);
        let input: Vec<f32> = (0..72).map(|i| ((i as f32) * 0.13).cos() * 0.5).collect();
        let ref_out = ex.forward_ref(&input, &[]).unwrap();
        let mut soc = Soc::new(SocConfig::default());
        let plan = PrecisionPlan::uniform(PrecSel::Posit16x1, &g.compute_layer_params());
        let (npe_out, rep) = ex.forward_interpret(&input, &[], &mut soc, &plan).unwrap();
        for (a, b) in ref_out.iter().zip(&npe_out) {
            assert!((a - b).abs() < 2e-2, "ref {a} npe {b}");
        }
        assert!(rep.jobs.total_cycles > 0);
        assert_eq!(rep.per_layer_cycles.len(), 2);
    }

    #[test]
    fn npe_fp4_degrades_gracefully() {
        let g = toy_graph();
        let mut rng = Rng::new(3);
        let w = toy_weights(&g, &mut rng);
        let ex = Executor::new(&g, &w);
        let input: Vec<f32> = (0..72).map(|i| ((i as f32) * 0.07).sin()).collect();
        let ref_out = ex.forward_ref(&input, &[]).unwrap();
        let mut soc = Soc::new(SocConfig::default());
        let plan = PrecisionPlan::uniform(PrecSel::Fp4x4, &g.compute_layer_params());
        let (out4, _) = ex.forward_interpret(&input, &[], &mut soc, &plan).unwrap();
        // correlated but not equal
        let err = crate::util::rmse(&ref_out, &out4);
        assert!(err > 0.0, "fp4 must differ from fp32");
        assert!(err < 2.0, "fp4 should stay in the ballpark (err {err})");
    }

    #[test]
    fn repeated_inference_hits_operand_cache() {
        let g = toy_graph();
        let mut rng = Rng::new(11);
        let w = toy_weights(&g, &mut rng);
        let ex = Executor::new(&g, &w);
        let input: Vec<f32> = (0..72).map(|i| ((i as f32) * 0.11).sin()).collect();
        let mut soc = Soc::new(SocConfig::default());
        let plan = PrecisionPlan::uniform(PrecSel::Posit8x2, &g.compute_layer_params());
        let (out1, _) = ex.forward_interpret(&input, &[], &mut soc, &plan).unwrap();
        let misses_after_first = soc.enc_cache.misses;
        assert_eq!(soc.enc_cache.hits, 0);
        assert!(misses_after_first > 0);
        let (out2, _) = ex.forward_interpret(&input, &[], &mut soc, &plan).unwrap();
        assert_eq!(out1, out2);
        // the second pass re-encodes nothing: every operand (im2col
        // activations and scaled weights) hits the encoding cache
        assert_eq!(soc.enc_cache.misses, misses_after_first);
        assert_eq!(soc.enc_cache.hits, misses_after_first);
    }

    #[test]
    fn bias_preload_is_exact() {
        // FC layer: y = Wx + b must hold exactly in posit16 for exact
        // representable values.
        let g = ModelGraph {
            name: "fc".into(),
            input: Shape::vec(4),
            layers: vec![Layer { name: "fc".into(), kind: LayerKind::Fc { in_f: 4, out_f: 2 } }],
        };
        let mut w = TensorMap::new();
        w.insert("fc.w".into(), Tensor::new(vec![4, 2], vec![1.0, 0.5, -1.0, 2.0, 0.25, -0.5, 1.5, 1.0]));
        w.insert("fc.b".into(), Tensor::new(vec![2], vec![0.5, -0.25]));
        let ex = Executor::new(&g, &w);
        let input = vec![1.0, -1.0, 0.5, 2.0];
        let want = ex.forward_ref(&input, &[]).unwrap();
        let mut soc = Soc::new(SocConfig::default());
        let plan = PrecisionPlan::uniform(PrecSel::Posit16x1, &g.compute_layer_params());
        let (got, _) = ex.forward_interpret(&input, &[], &mut soc, &plan).unwrap();
        assert_eq!(got, want);
    }

    #[test]
    fn plan_length_mismatch_is_typed_error_not_panic() {
        let g = toy_graph(); // 2 compute layers
        let mut rng = Rng::new(13);
        let w = toy_weights(&g, &mut rng);
        let ex = Executor::new(&g, &w);
        let mut soc = Soc::new(SocConfig::default());
        let bad = PrecisionPlan::uniform(PrecSel::Posit8x2, &[10]); // 1 layer
        let err = ex.forward_interpret(&vec![0.1; 72], &[], &mut soc, &bad).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("precision plan"), "unexpected error: {msg}");
        assert!(msg.contains('1') && msg.contains('2'), "unexpected error: {msg}");
    }

    #[test]
    fn missing_weight_is_clear_error() {
        let g = toy_graph();
        let w = TensorMap::new();
        let ex = Executor::new(&g, &w);
        let err = ex.forward_ref(&vec![0.0; 72], &[]).unwrap_err();
        assert!(err.to_string().contains("conv1.w"));
    }
}
