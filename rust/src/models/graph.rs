//! The layer-graph IR.
//!
//! Shapes are CHW (channels, height, width); fully-connected layers work
//! on flattened vectors (c = features, h = w = 1). Convolutions lower to
//! im2col GEMMs of shape `M = out_h·out_w`, `K = in_c·k·k`, `N = out_c`
//! — the mapping `exec` feeds the 8×8 array with.

/// Activation kinds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ActKind {
    Relu,
    /// Clipped ReLU with trained α (PACT, eq. 6). The α lives in the
    /// weight map as `<layer>.alpha`.
    Pact,
    Tanh,
    Identity,
}

/// Pooling kinds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PoolKind {
    Max,
    Avg,
}

/// Layer kinds.
#[derive(Debug, Clone, PartialEq)]
pub enum LayerKind {
    /// 2-D convolution, 'same'-style explicit padding.
    Conv2d { in_c: usize, out_c: usize, k: usize, stride: usize, pad: usize },
    /// Fully connected.
    Fc { in_f: usize, out_f: usize },
    /// Spatial pooling (square window, stride = window).
    Pool { kind: PoolKind, size: usize },
    /// Elementwise activation.
    Act(ActKind),
    /// Flatten CHW → vector.
    Flatten,
    /// Concatenate an auxiliary input vector (e.g. IMU features) onto a
    /// flattened feature vector.
    ConcatAux { n: usize },
}

/// A named layer.
#[derive(Debug, Clone, PartialEq)]
pub struct Layer {
    pub name: String,
    pub kind: LayerKind,
}

/// Shape in CHW.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Shape {
    pub c: usize,
    pub h: usize,
    pub w: usize,
}

impl Shape {
    pub fn vec(n: usize) -> Shape {
        Shape { c: n, h: 1, w: 1 }
    }

    pub fn numel(&self) -> usize {
        self.c * self.h * self.w
    }
}

/// A whole model.
#[derive(Debug, Clone)]
pub struct ModelGraph {
    pub name: String,
    pub input: Shape,
    pub layers: Vec<Layer>,
}

impl LayerKind {
    /// Output shape given an input shape. Panics on shape mismatch (a
    /// model-construction bug, not a runtime condition).
    pub fn out_shape(&self, s: Shape) -> Shape {
        match *self {
            LayerKind::Conv2d { in_c, out_c, k, stride, pad } => {
                assert_eq!(s.c, in_c, "conv in_c mismatch");
                let oh = (s.h + 2 * pad - k) / stride + 1;
                let ow = (s.w + 2 * pad - k) / stride + 1;
                Shape { c: out_c, h: oh, w: ow }
            }
            LayerKind::Fc { in_f, out_f } => {
                assert_eq!(s.numel(), in_f, "fc in_f mismatch");
                Shape::vec(out_f)
            }
            LayerKind::Pool { size, .. } => {
                Shape { c: s.c, h: s.h / size, w: s.w / size }
            }
            LayerKind::Act(_) => s,
            LayerKind::Flatten => Shape::vec(s.numel()),
            LayerKind::ConcatAux { n } => {
                assert_eq!(s.h * s.w, 1, "concat requires flattened input");
                Shape::vec(s.c + n)
            }
        }
    }

    /// Trainable parameter count (weights + bias).
    pub fn params(&self) -> usize {
        match *self {
            LayerKind::Conv2d { in_c, out_c, k, .. } => in_c * out_c * k * k + out_c,
            LayerKind::Fc { in_f, out_f } => in_f * out_f + out_f,
            LayerKind::Act(ActKind::Pact) => 1, // the trained α
            _ => 0,
        }
    }

    /// MACs for one forward pass at the given input shape.
    pub fn macs(&self, s: Shape) -> u64 {
        match *self {
            LayerKind::Conv2d { in_c, out_c, k, .. } => {
                let o = self.out_shape(s);
                (o.h * o.w * in_c * k * k * out_c) as u64
            }
            LayerKind::Fc { in_f, out_f } => (in_f * out_f) as u64,
            _ => 0,
        }
    }

    /// Does this layer run on the MAC array?
    pub fn is_compute(&self) -> bool {
        matches!(self, LayerKind::Conv2d { .. } | LayerKind::Fc { .. })
    }

    /// im2col GEMM shape (M, K, N) for compute layers.
    pub fn gemm_shape(&self, s: Shape) -> Option<(usize, usize, usize)> {
        match *self {
            LayerKind::Conv2d { in_c, out_c, k, .. } => {
                let o = self.out_shape(s);
                Some((o.h * o.w, in_c * k * k, out_c))
            }
            LayerKind::Fc { in_f, out_f } => Some((1, in_f, out_f)),
            _ => None,
        }
    }
}

impl ModelGraph {
    /// Shapes at every layer boundary (len = layers + 1, starting with
    /// the input).
    pub fn shapes(&self) -> Vec<Shape> {
        let mut out = Vec::with_capacity(self.layers.len() + 1);
        let mut cur = self.input;
        out.push(cur);
        for l in &self.layers {
            cur = l.kind.out_shape(cur);
            out.push(cur);
        }
        out
    }

    pub fn out_shape(&self) -> Shape {
        self.layers.iter().fold(self.input, |s, l| l.kind.out_shape(s))
    }

    /// Total trainable parameters.
    pub fn total_params(&self) -> usize {
        self.layers.iter().map(|l| l.kind.params()).sum()
    }

    /// Total MACs per forward pass.
    pub fn total_macs(&self) -> u64 {
        let shapes = self.shapes();
        self.layers.iter().zip(&shapes).map(|(l, &s)| l.kind.macs(s)).sum()
    }

    /// Indices of compute (GEMM-lowered) layers.
    pub fn compute_layers(&self) -> Vec<usize> {
        self.layers
            .iter()
            .enumerate()
            .filter(|(_, l)| l.kind.is_compute())
            .map(|(i, _)| i)
            .collect()
    }

    /// Parameter count per *compute* layer (the precision planner's
    /// granularity).
    pub fn compute_layer_params(&self) -> Vec<usize> {
        self.layers
            .iter()
            .filter(|l| l.kind.is_compute())
            .map(|l| l.kind.params())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> ModelGraph {
        ModelGraph {
            name: "toy".into(),
            input: Shape { c: 1, h: 16, w: 16 },
            layers: vec![
                Layer { name: "conv1".into(), kind: LayerKind::Conv2d { in_c: 1, out_c: 8, k: 3, stride: 1, pad: 1 } },
                Layer { name: "act1".into(), kind: LayerKind::Act(ActKind::Relu) },
                Layer { name: "pool1".into(), kind: LayerKind::Pool { kind: PoolKind::Max, size: 2 } },
                Layer { name: "flat".into(), kind: LayerKind::Flatten },
                Layer { name: "fc1".into(), kind: LayerKind::Fc { in_f: 512, out_f: 10 } },
            ],
        }
    }

    #[test]
    fn shape_propagation() {
        let g = toy();
        let shapes = g.shapes();
        assert_eq!(shapes[1], Shape { c: 8, h: 16, w: 16 });
        assert_eq!(shapes[3], Shape { c: 8, h: 8, w: 8 });
        assert_eq!(g.out_shape(), Shape::vec(10));
    }

    #[test]
    fn param_and_mac_accounting() {
        let g = toy();
        // conv: 1*8*9+8 = 80; fc: 512*10+10 = 5130
        assert_eq!(g.total_params(), 80 + 5130);
        // conv macs: 16*16*9*8 = 18432; fc: 5120
        assert_eq!(g.total_macs(), 18432 + 5120);
    }

    #[test]
    fn gemm_shapes() {
        let g = toy();
        let s = g.shapes();
        assert_eq!(g.layers[0].kind.gemm_shape(s[0]), Some((256, 9, 8)));
        assert_eq!(g.layers[4].kind.gemm_shape(s[4]), Some((1, 512, 10)));
    }

    #[test]
    #[should_panic(expected = "conv in_c mismatch")]
    fn bad_shape_panics() {
        let k = LayerKind::Conv2d { in_c: 3, out_c: 8, k: 3, stride: 1, pad: 1 };
        k.out_shape(Shape { c: 1, h: 8, w: 8 });
    }

    #[test]
    fn concat_aux_shape() {
        let k = LayerKind::ConcatAux { n: 6 };
        assert_eq!(k.out_shape(Shape::vec(256)), Shape::vec(262));
    }
}
