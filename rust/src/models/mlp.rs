//! MLP-XR — the Table-IV-style MLP workload (the comparison table's
//! "784-200-100-10"-class baselines run MLPs; ours is a flattened
//! shapes-10 classifier of the same structure).
//!
//! ```text
//! fc1 256→128 · PACT
//! fc2 128→64  · PACT
//! fc3 64→10
//! ```
//!
//! Weight names match `python/compile/model.py::mlp_params`.

use super::graph::{ActKind, Layer, LayerKind, ModelGraph, Shape};

/// Flattened 16×16 input.
pub const INPUT_DIM: usize = 256;
/// 10 classes.
pub const NUM_CLASSES: usize = 10;

/// Build the graph.
pub fn build() -> ModelGraph {
    let l = |name: &str, kind: LayerKind| Layer { name: name.into(), kind };
    ModelGraph {
        name: "mlp_xr".into(),
        input: Shape::vec(INPUT_DIM),
        layers: vec![
            l("fc1", LayerKind::Fc { in_f: INPUT_DIM, out_f: 128 }),
            l("act1", LayerKind::Act(ActKind::Pact)),
            l("fc2", LayerKind::Fc { in_f: 128, out_f: 64 }),
            l("act2", LayerKind::Act(ActKind::Pact)),
            l("fc3", LayerKind::Fc { in_f: 64, out_f: NUM_CLASSES }),
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes() {
        let g = build();
        assert_eq!(g.out_shape(), Shape::vec(10));
        assert_eq!(g.compute_layers().len(), 3);
        // 256·128 + 128 + 128·64 + 64 + 64·10 + 10 = 41802
        assert_eq!(g.total_params(), 41802 + 2); // + two PACT alphas
    }
}
