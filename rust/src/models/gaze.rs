//! GazeNet — the eye-gaze extraction workload (paper Fig. 7: "eye-gaze
//! LLE estimation" MSE vs precision).
//!
//! Input: 16 eye-landmark coordinates (8 points × (x, y)) from the
//! synthetic eye model in `python/compile/datasets.py`; output: gaze
//! direction (yaw, pitch). A compact MLP — gaze nets on XR SoCs are
//! latency-critical and tiny.
//!
//! ```text
//! fc1 16→64 · PACT
//! fc2 64→64 · PACT
//! fc3 64→2  (linear, radians)
//! ```

use super::graph::{ActKind, Layer, LayerKind, ModelGraph, Shape};

/// Input landmark features.
pub const INPUT_DIM: usize = 16;
/// Output: (yaw, pitch).
pub const OUTPUT_DIM: usize = 2;

/// Build the graph.
pub fn build() -> ModelGraph {
    let l = |name: &str, kind: LayerKind| Layer { name: name.into(), kind };
    ModelGraph {
        name: "gazenet".into(),
        input: Shape::vec(INPUT_DIM),
        layers: vec![
            l("fc1", LayerKind::Fc { in_f: INPUT_DIM, out_f: 64 }),
            l("act1", LayerKind::Act(ActKind::Pact)),
            l("fc2", LayerKind::Fc { in_f: 64, out_f: 64 }),
            l("act2", LayerKind::Act(ActKind::Pact)),
            l("fc3", LayerKind::Fc { in_f: 64, out_f: OUTPUT_DIM }),
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes() {
        let g = build();
        assert_eq!(g.out_shape(), Shape::vec(2));
        assert_eq!(g.compute_layers().len(), 3);
        // ~5.5k params
        assert!((5_000..7_000).contains(&g.total_params()));
    }
}
