//! Budgeted multi-model residency: the catalog layer that turns a
//! replica from a monotonically-growing set of warm models into a
//! rotating, DRAM-budgeted cache of them.
//!
//! The paper's co-processor serves several XR perception workloads from
//! one engine by keeping weights resident; before this module a replica
//! simply accumulated every registered model's resident image until the
//! allocator refused the next one. [`ResidencyManager`] makes residency
//! a first-class, evictable resource:
//!
//! * every compiled/shard arena is tracked as a [`ResidentImage`]
//!   against an explicit **DRAM budget** (at most the SoC's
//!   [`Soc::resident_limit`]);
//! * [`ResidencyManager::admit`] warms a cold model through a pluggable
//!   [`EvictionPolicy`] — the default [`LruPolicy`] evicts the least
//!   recently **dispatched** model first, and pinned entries (in-flight
//!   requests pin at dispatch, sharded registrations pin for their
//!   lifetime) are never victims;
//! * when the budget math says a model fits but the free list is too
//!   fragmented for the bump allocator, the manager performs **live
//!   compaction** ([`compact_resident`]): live weight images slide down
//!   over the holes via [`Soc::move_resident`] and the owning arenas'
//!   addresses are patched — serving is bit-identical before and after
//!   (differential-tested in every `PrecSel` mode).
//!
//! Eviction/compaction/cold-warm counters and the resident high-water
//! mark surface through [`ResidencyStats`] into the router's
//! `RuntimeMetrics`.
//!
//! Lock discipline: manager methods that touch the device take
//! `&mut Soc` — callers acquire the replica device lock *first*, then
//! the manager lock ([`residency_lock`]), and never the reverse.

use super::compile::{CompiledModel, ShardedModel};
use crate::soc::{Soc, SocError};
use crate::util::lockdep::{lock_tracked, LockClass, Tracked};
use std::collections::HashMap;
use std::fmt;
use std::sync::{Arc, Mutex, MutexGuard};

/// Anything whose warm state occupies resident DRAM on a replica and
/// can be evicted, re-warmed and relocated: whole compiled models and
/// per-replica shard views implement it.
pub trait ResidentImage: Send + Sync {
    /// Stable warm-state key on a [`Soc`].
    fn uid(&self) -> u64;
    /// Model name (diagnostics).
    fn name(&self) -> &str;
    /// Conservative resident footprint of one warm instance, bytes —
    /// the budget accounting unit.
    fn warm_footprint_bytes(&self) -> usize;
    /// Is this image warm on `soc`? (Ground truth — the manager derives
    /// its accounting from the device, so unmanaged warms never drift.)
    fn is_warm(&self, soc: &Soc) -> bool;
    /// Warm on `soc` (idempotent; rolls back fully on failure).
    fn ensure_warm(&self, soc: &mut Soc) -> Result<(), SocError>;
    /// Tear down the warm state on `soc` (no-op when not warm).
    fn evict(&self, soc: &mut Soc);
    /// Live resident data blocks `(addr, len_bytes)` on `soc`, in a
    /// fixed per-image order; empty when not warm.
    fn live_blocks(&self, soc: &Soc) -> Vec<(u64, usize)>;
    /// Patch the warm arena after compaction relocated the blocks
    /// (`new_addrs` parallel to [`ResidentImage::live_blocks`]).
    fn rebase(&self, soc: &mut Soc, new_addrs: &[u64]);
}

impl ResidentImage for CompiledModel {
    fn uid(&self) -> u64 {
        CompiledModel::uid(self)
    }
    fn name(&self) -> &str {
        &self.name
    }
    fn warm_footprint_bytes(&self) -> usize {
        CompiledModel::warm_footprint_bytes(self)
    }
    fn is_warm(&self, soc: &Soc) -> bool {
        soc.has_model_state(CompiledModel::uid(self))
    }
    fn ensure_warm(&self, soc: &mut Soc) -> Result<(), SocError> {
        CompiledModel::ensure_warm(self, soc)
    }
    fn evict(&self, soc: &mut Soc) {
        CompiledModel::evict(self, soc)
    }
    fn live_blocks(&self, soc: &Soc) -> Vec<(u64, usize)> {
        self.live_blocks_on(soc)
    }
    fn rebase(&self, soc: &mut Soc, new_addrs: &[u64]) {
        self.rebase_on(soc, new_addrs)
    }
}

impl ResidentImage for ShardedModel {
    fn uid(&self) -> u64 {
        ShardedModel::uid(self)
    }
    fn name(&self) -> &str {
        &self.name
    }
    fn warm_footprint_bytes(&self) -> usize {
        ShardedModel::warm_footprint_bytes(self)
    }
    fn is_warm(&self, soc: &Soc) -> bool {
        soc.has_model_state(ShardedModel::uid(self))
    }
    fn ensure_warm(&self, soc: &mut Soc) -> Result<(), SocError> {
        ShardedModel::ensure_warm(self, soc)
    }
    fn evict(&self, soc: &mut Soc) {
        ShardedModel::evict(self, soc)
    }
    fn live_blocks(&self, soc: &Soc) -> Vec<(u64, usize)> {
        self.live_blocks_on(soc)
    }
    fn rebase(&self, soc: &mut Soc, new_addrs: &[u64]) {
        self.rebase_on(soc, new_addrs)
    }
}

/// Take a residency-manager lock, clearing poisoning (mirror of
/// [`crate::serve::device_lock`] — a contained worker panic must not
/// turn into a poisoned-lock cascade). Tracked at
/// [`LockClass::Residency`]: debug builds assert the replica device
/// lock is never acquired *after* this guard on the same thread.
pub fn residency_lock(m: &Mutex<ResidencyManager>) -> Tracked<MutexGuard<'_, ResidencyManager>> {
    lock_tracked(m, LockClass::Residency)
}

/// One eviction candidate as seen by the policy: a **warm, unpinned**
/// catalog entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Candidate {
    /// The entry's stable warm-state key ([`ResidentImage::uid`]).
    pub uid: u64,
    /// Logical dispatch clock of the entry's last admit/touch.
    pub last_use: u64,
    /// Budgeted footprint, bytes.
    pub bytes: u64,
}

/// Pluggable victim selection. Candidates arrive sorted by `uid` for
/// determinism; pinned and cold entries are filtered out before the
/// policy ever sees them.
pub trait EvictionPolicy: Send {
    /// Pick the uid to evict next; `None` refuses (admission fails).
    fn pick(&mut self, candidates: &[Candidate]) -> Option<u64>;
}

/// Least-recently-dispatched eviction (ties broken by uid).
#[derive(Debug, Default, Clone, Copy)]
pub struct LruPolicy;

impl EvictionPolicy for LruPolicy {
    fn pick(&mut self, candidates: &[Candidate]) -> Option<u64> {
        candidates.iter().min_by_key(|c| (c.last_use, c.uid)).map(|c| c.uid)
    }
}

/// Typed admission errors.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ResidencyError {
    /// The model's footprint exceeds the replica budget outright — it
    /// can never be warm here (shard it across the fleet instead).
    ExceedsBudget { model: String, need: u64, budget: u64 },
    /// Every candidate the budget would need back is pinned (in-flight
    /// or a coordinator-pinned shard) — the model stays cold.
    Pinned { model: String, need: u64, budget: u64, pinned: u64 },
    /// The device rejected the warm even after eviction + compaction.
    Soc(SocError),
}

impl fmt::Display for ResidencyError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ResidencyError::ExceedsBudget { model, need, budget } => write!(
                f,
                "model `{model}` needs {need} resident bytes but the replica budget is {budget}"
            ),
            ResidencyError::Pinned { model, need, budget, pinned } => write!(
                f,
                "cannot admit `{model}` ({need} bytes, budget {budget}): {pinned} bytes are \
                 pinned by in-flight or sharded models"
            ),
            ResidencyError::Soc(e) => write!(f, "warm rejected by the device: {e}"),
        }
    }
}

impl std::error::Error for ResidencyError {}

impl From<SocError> for ResidencyError {
    fn from(e: SocError) -> Self {
        ResidencyError::Soc(e)
    }
}

/// Residency counters, surfaced through the router's `RuntimeMetrics`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ResidencyStats {
    /// Models evicted to make room for an admission.
    pub evictions: u64,
    /// Live compactions performed (fragmented free list defragmented).
    pub compactions: u64,
    /// Cold models made warm by an admission (registration floor warms
    /// and dispatch-triggered warms alike).
    pub cold_warms: u64,
    /// Highest budgeted warm-set footprint ever reached, bytes.
    pub resident_high_water: u64,
}

/// What one admission actually did — the per-request delta of
/// [`ResidencyStats`], returned by [`ResidencyManager::admit_outcome`]
/// so the serving worker can stamp `Evict`/`Compact`/`ColdWarm` trace
/// events against the request that caused them.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AdmitOutcome {
    /// Catalog entries this admission evicted.
    pub evictions: u64,
    /// Compaction passes this admission triggered.
    pub compactions: u64,
    /// 1 when this admission cold-warmed the model, 0 when it was
    /// already warm.
    pub cold_warms: u64,
}

struct Entry {
    image: Arc<dyn ResidentImage>,
    /// Budgeted footprint, bytes (frozen at insert).
    bytes: u64,
    last_use: u64,
    /// Eviction protection: in-flight dispatch pins + coordinator pins.
    pins: u32,
    /// Manager's belief about warmness, maintained on admit/evict so the
    /// router's warm-affinity dispatch can probe it **without** the
    /// device lock. A hint only — warmness ground truth stays on the
    /// device ([`ResidentImage::is_warm`]) and admission re-derives it.
    warm_hint: bool,
}

/// Per-replica DRAM-budgeted model catalog with policy-driven eviction
/// and live compaction. The manager must mediate **every** resident
/// allocation on its replica (the router guarantees this); warmness
/// itself is always read back from the device, so the accounting cannot
/// drift from reality.
pub struct ResidencyManager {
    budget: u64,
    entries: HashMap<u64, Entry>,
    /// Logical dispatch clock driving LRU.
    clock: u64,
    policy: Box<dyn EvictionPolicy>,
    stats: ResidencyStats,
}

impl ResidencyManager {
    /// Manager with the default [`LruPolicy`]. `budget_bytes` should be
    /// at most the replica's [`Soc::resident_limit`] — admissions the
    /// budget approves are then guaranteed to warm (after compaction at
    /// worst).
    pub fn lru(budget_bytes: u64) -> ResidencyManager {
        ResidencyManager::with_policy(budget_bytes, Box::new(LruPolicy))
    }

    /// Manager with an explicit eviction policy.
    pub fn with_policy(budget_bytes: u64, policy: Box<dyn EvictionPolicy>) -> ResidencyManager {
        ResidencyManager {
            budget: budget_bytes,
            entries: HashMap::new(),
            clock: 0,
            policy,
            stats: ResidencyStats::default(),
        }
    }

    /// The configured resident-DRAM budget, bytes.
    pub fn budget(&self) -> u64 {
        self.budget
    }

    /// Counters snapshot.
    pub fn stats(&self) -> ResidencyStats {
        self.stats
    }

    /// Catalog entries (warm or cold).
    pub fn catalog_len(&self) -> usize {
        self.entries.len()
    }

    /// Total budgeted footprint of the catalog (warm **and** cold
    /// entries) — the router's warm-affinity gate: when this exceeds
    /// the budget, the replica is rotating models and placement starts
    /// to matter.
    pub fn catalog_bytes(&self) -> u64 {
        self.entries.values().map(|e| e.bytes).sum()
    }

    /// Does the manager believe `uid` is warm here? Lock-free of the
    /// device — see [`Entry::warm_hint`] for the (benign) ways this can
    /// lag ground truth.
    pub fn warm_hint(&self, uid: u64) -> bool {
        self.entries.get(&uid).is_some_and(|e| e.warm_hint)
    }

    /// Budgeted footprint of the models currently warm on `soc`.
    pub fn warm_bytes(&self, soc: &Soc) -> u64 {
        self.entries.values().filter(|e| e.image.is_warm(soc)).map(|e| e.bytes).sum()
    }

    /// Budget a new model could claim after evicting every *unpinned*
    /// resident model: `budget − pinned warm bytes`. The post-eviction
    /// number `register_auto` plans shard counts against.
    pub fn available_after_eviction(&self, soc: &Soc) -> u64 {
        let pinned: u64 = self
            .entries
            .values()
            .filter(|e| e.pins > 0 && e.image.is_warm(soc))
            .map(|e| e.bytes)
            .sum();
        self.budget.saturating_sub(pinned)
    }

    /// Add `image` to the catalog (cold; idempotent by uid — an
    /// existing entry keeps its pins and LRU position).
    pub fn insert(&mut self, image: Arc<dyn ResidentImage>) {
        let uid = image.uid();
        self.entries.entry(uid).or_insert_with(|| Entry {
            bytes: image.warm_footprint_bytes() as u64,
            image,
            last_use: 0,
            pins: 0,
            warm_hint: false,
        });
    }

    /// Pin `image` against eviction (inserting it if unknown). The
    /// router pins at dispatch and unpins at job completion; sharded
    /// registrations hold a pin for their whole lifetime.
    pub fn pin_image(&mut self, image: &Arc<dyn ResidentImage>) {
        self.insert(Arc::clone(image));
        if let Some(e) = self.entries.get_mut(&image.uid()) {
            e.pins += 1;
        }
    }

    /// Release one pin of `uid`. Saturating and tolerant of unknown
    /// entries: the worker unpins unconditionally after every managed
    /// job, but only router-dispatched jobs pinned at submission —
    /// direct runtime users may not have.
    pub fn unpin(&mut self, uid: u64) {
        if let Some(e) = self.entries.get_mut(&uid) {
            e.pins = e.pins.saturating_sub(1);
        }
    }

    /// Drop `uid` from the catalog, evicting its warm state. Ignores
    /// pins — the caller (model replacement) must have quiesced first.
    pub fn remove(&mut self, soc: &mut Soc, uid: u64) {
        if let Some(e) = self.entries.remove(&uid) {
            e.image.evict(soc);
        }
    }

    /// Admit `image` for dispatch: bump its LRU clock and make sure it
    /// is warm within the budget — evicting policy-chosen victims and
    /// compacting a fragmented free list as needed. Errors leave the
    /// device rolled back (the model simply stays cold).
    pub fn admit(
        &mut self,
        soc: &mut Soc,
        image: &Arc<dyn ResidentImage>,
    ) -> Result<(), ResidencyError> {
        self.admit_outcome(soc, image).map(|_| ())
    }

    /// [`ResidencyManager::admit`], additionally reporting what the
    /// admission did as an [`AdmitOutcome`] delta (the trace layer's
    /// source for `Evict`/`Compact`/`ColdWarm` events).
    pub fn admit_outcome(
        &mut self,
        soc: &mut Soc,
        image: &Arc<dyn ResidentImage>,
    ) -> Result<AdmitOutcome, ResidencyError> {
        let before = self.stats;
        let uid = image.uid();
        self.clock += 1;
        let clock = self.clock;
        let need = self
            .entries
            .get(&uid)
            .map(|e| e.bytes)
            .unwrap_or_else(|| image.warm_footprint_bytes() as u64);
        // an oversized model never joins the catalog here — it could
        // never warm, and one dead Arc'd entry per probe would leak
        // (explicit `insert`/`pin_image` callers can still hold one)
        if need > self.budget && !image.is_warm(soc) {
            return Err(ResidencyError::ExceedsBudget {
                model: image.name().to_string(),
                need,
                budget: self.budget,
            });
        }
        self.insert(Arc::clone(image));
        let warm = image.is_warm(soc);
        if let Some(e) = self.entries.get_mut(&uid) {
            e.last_use = clock;
            e.warm_hint = warm;
        }
        if warm {
            return Ok(AdmitOutcome::default());
        }
        // policy-driven eviction until the budgeted warm set fits
        while self.warm_bytes(soc) + need > self.budget {
            let mut candidates: Vec<Candidate> = self
                .entries
                .values()
                .filter(|e| e.pins == 0 && e.image.is_warm(soc))
                .map(|e| Candidate { uid: e.image.uid(), last_use: e.last_use, bytes: e.bytes })
                .collect();
            candidates.sort_by_key(|c| c.uid);
            let pick = self.policy.pick(&candidates);
            // containment for custom policies: a pick outside the
            // candidate list (a pinned or cold uid) would either evict
            // a pinned model or spin this loop forever — treat it as a
            // refusal instead
            let victim_uid = match pick {
                Some(v) if candidates.iter().any(|c| c.uid == v) => Some(v),
                _ => None,
            };
            let Some(victim_uid) = victim_uid else {
                let pinned: u64 = self
                    .entries
                    .values()
                    .filter(|e| e.pins > 0 && e.image.is_warm(soc))
                    .map(|e| e.bytes)
                    .sum();
                return Err(ResidencyError::Pinned {
                    model: image.name().to_string(),
                    need,
                    budget: self.budget,
                    pinned,
                });
            };
            // the candidate check above proves the entry exists
            if let Some(victim) = self.entries.get_mut(&victim_uid) {
                victim.image.evict(soc);
                victim.warm_hint = false;
            }
            self.stats.evictions += 1;
        }
        // warm; a fragmented free list — or the sub-64-byte alignment
        // gaps a previous compaction's tight rebase leaves between
        // blocks — can refuse a fit the budget math guarantees.
        // Defragment once and retry unconditionally: compaction
        // reclaims both, and when nothing is reclaimable the retry
        // fails exactly like the first attempt did.
        if image.ensure_warm(soc).is_err() {
            self.compact(soc)?;
            image.ensure_warm(soc)?;
        }
        if let Some(e) = self.entries.get_mut(&uid) {
            e.warm_hint = true;
        }
        // the cold→warm image upload rides the management budget on the
        // shared AXI channel, like the compaction moves — eviction churn
        // (re-upload on the next admission) and compaction (move once)
        // now weigh against each other in the same counters
        soc.charge_management_upload(need as usize);
        self.stats.cold_warms += 1;
        let now = self.warm_bytes(soc);
        self.stats.resident_high_water = self.stats.resident_high_water.max(now);
        Ok(AdmitOutcome {
            evictions: self.stats.evictions - before.evictions,
            compactions: self.stats.compactions - before.compactions,
            cold_warms: self.stats.cold_warms - before.cold_warms,
        })
    }

    /// Defragment the resident region: slide every warm catalog model's
    /// live blocks down over the reclaimed holes and patch their
    /// arenas. Serving is bit-identical afterwards. An `Err` means the
    /// simulated device refused a relocation ([`compact_resident`]) —
    /// nothing was counted and the caller's admission fails typed.
    pub fn compact(&mut self, soc: &mut Soc) -> Result<(), SocError> {
        let mut images: Vec<Arc<dyn ResidentImage>> = self
            .entries
            .values()
            .filter(|e| e.image.is_warm(soc))
            .map(|e| Arc::clone(&e.image))
            .collect();
        images.sort_by_key(|i| i.uid());
        compact_resident(soc, &images)?;
        self.stats.compactions += 1;
        Ok(())
    }
}

/// Mark-compact the resident region of `soc`: every live block of
/// `images` slides down to the lowest 64-byte-aligned address (ascending
/// source order, so moves never clobber unmoved data — each destination
/// is provably at or below its source), the stale free list is dropped
/// ([`Soc::resident_compacted`]) and every arena is patched
/// ([`ResidentImage::rebase`]). `images` must cover **every** live
/// resident allocation on the SoC. Returns the new watermark. A failed
/// relocation (`dst <= addr` is proven by the ascending sort, so only a
/// simulator bug can refuse one) propagates as a typed [`SocError`]
/// instead of panicking — the admission that triggered the compaction
/// fails, the fleet keeps serving.
pub fn compact_resident(
    soc: &mut Soc,
    images: &[Arc<dyn ResidentImage>],
) -> Result<u64, SocError> {
    // (addr, len, image idx, block idx); zero-length blocks sort before
    // a same-address live block so their relocation target stays <= src
    let mut blocks: Vec<(u64, usize, usize, usize)> = Vec::new();
    let mut new_addrs: Vec<Vec<u64>> = Vec::with_capacity(images.len());
    for (ii, img) in images.iter().enumerate() {
        let bs = img.live_blocks(soc);
        new_addrs.push(vec![0; bs.len()]);
        for (bi, (addr, len)) in bs.into_iter().enumerate() {
            blocks.push((addr, len, ii, bi));
        }
    }
    blocks.sort_unstable();
    let mut top = 0u64;
    for &(addr, len, ii, bi) in &blocks {
        let dst = top.next_multiple_of(64);
        debug_assert!(dst <= addr, "compaction must only move blocks down");
        if dst != addr && len > 0 {
            soc.move_resident(addr, dst, len)?;
        }
        new_addrs[ii][bi] = dst;
        top = dst + len as u64;
    }
    soc.resident_compacted(top);
    for (img, addrs) in images.iter().zip(&new_addrs) {
        img.rebase(soc, addrs);
    }
    Ok(top)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::compile::compile;
    use crate::models::graph::{Layer, LayerKind, ModelGraph, Shape};
    use crate::models::random_weights;
    use crate::npe::PrecSel;
    use crate::quant::PrecisionPlan;
    use crate::soc::SocConfig;

    /// Single-fc model: footprint = align64(k·n·4) + align64(k·4) +
    /// align64(n·4), precisely controllable from (k, n).
    fn fc_model(name: &str, k: usize, n: usize, sel: PrecSel, seed: u64) -> Arc<CompiledModel> {
        let g = ModelGraph {
            name: name.into(),
            input: Shape::vec(k),
            layers: vec![Layer { name: "fc".into(), kind: LayerKind::Fc { in_f: k, out_f: n } }],
        };
        let w = random_weights(&g, seed);
        let plan = PrecisionPlan::uniform(sel, &g.compute_layer_params());
        Arc::new(compile(&g, &w, &plan).unwrap())
    }

    fn as_image(m: &Arc<CompiledModel>) -> Arc<dyn ResidentImage> {
        Arc::clone(m) as Arc<dyn ResidentImage>
    }

    fn input_of(k: usize, phase: f32) -> Vec<f32> {
        (0..k).map(|i| ((i as f32) * 0.19 + phase).sin() * 0.5).collect()
    }

    /// 32 KiB DRAM → resident limit (and budget) 24576 bytes.
    fn small_soc() -> Soc {
        Soc::new(SocConfig { dram_bytes: 1 << 15, ..Default::default() })
    }

    #[test]
    fn lru_evicts_least_recently_dispatched_and_counts() {
        let mut soc = small_soc();
        let budget = soc.resident_limit();
        assert_eq!(budget, 24576);
        let mut mgr = ResidencyManager::lru(budget);
        let a = fc_model("a", 64, 32, PrecSel::Posit8x2, 1); // 8576
        let b = fc_model("b", 64, 48, PrecSel::Posit8x2, 2); // 12736
        let c = fc_model("c", 64, 40, PrecSel::Posit8x2, 3); // 10688
        assert_eq!(a.warm_footprint_bytes(), 8576);
        assert_eq!(b.warm_footprint_bytes(), 12736);
        assert_eq!(c.warm_footprint_bytes(), 10688);
        mgr.admit(&mut soc, &as_image(&a)).unwrap();
        mgr.admit(&mut soc, &as_image(&b)).unwrap();
        // touch a so b becomes the LRU victim
        mgr.admit(&mut soc, &as_image(&a)).unwrap();
        mgr.admit(&mut soc, &as_image(&c)).unwrap();
        assert!(soc.has_model_state(a.uid()), "recently dispatched model must survive");
        assert!(!soc.has_model_state(b.uid()), "LRU model must be evicted");
        assert!(soc.has_model_state(c.uid()));
        let s = mgr.stats();
        assert_eq!(s.evictions, 1);
        assert_eq!(s.cold_warms, 3);
        assert!(s.resident_high_water <= mgr.budget());
        assert_eq!(mgr.catalog_len(), 3, "evicted models stay in the catalog (cold)");
    }

    #[test]
    fn pinned_entries_are_never_evicted() {
        let mut soc = small_soc();
        let mut mgr = ResidencyManager::lru(soc.resident_limit());
        let a = fc_model("a", 64, 60, PrecSel::Fp4x4, 4); // 15360+256+256 = 15872
        let b = fc_model("b", 64, 48, PrecSel::Fp4x4, 5); // 12736
        let ia = as_image(&a);
        mgr.admit(&mut soc, &ia).unwrap();
        mgr.pin_image(&ia);
        // b needs a's space, but a is pinned → typed Pinned error
        match mgr.admit(&mut soc, &as_image(&b)) {
            Err(ResidencyError::Pinned { pinned, .. }) => assert_eq!(pinned, 15872),
            other => panic!("expected Pinned, got {other:?}"),
        }
        assert!(soc.has_model_state(a.uid()), "pinned model must survive");
        assert!(!soc.has_model_state(b.uid()));
        // unpin → the same admission now evicts a
        mgr.unpin(a.uid());
        mgr.admit(&mut soc, &as_image(&b)).unwrap();
        assert!(!soc.has_model_state(a.uid()));
        assert!(soc.has_model_state(b.uid()));
    }

    #[test]
    fn oversized_model_is_a_typed_budget_error() {
        let mut soc = small_soc();
        let mut mgr = ResidencyManager::lru(soc.resident_limit());
        let big = fc_model("big", 64, 200, PrecSel::Posit8x2, 6); // 51200 > 24576
        match mgr.admit(&mut soc, &as_image(&big)) {
            Err(ResidencyError::ExceedsBudget { need, budget, .. }) => {
                assert!(need > budget);
            }
            other => panic!("expected ExceedsBudget, got {other:?}"),
        }
        assert_eq!(mgr.stats().cold_warms, 0);
    }

    #[test]
    fn fragmented_admission_compacts_and_serves_bit_identically() {
        // the compaction trace: warm a+b, evict a (hole at the bottom),
        // admit c whose weight block fits neither the hole nor the bump
        // headroom — only compaction makes the budgeted fit real
        let mut soc = small_soc();
        let mut mgr = ResidencyManager::lru(soc.resident_limit());
        let a = fc_model("a", 64, 32, PrecSel::Posit8x2, 7);
        let b = fc_model("b", 64, 48, PrecSel::Posit8x2, 8);
        let c = fc_model("c", 64, 40, PrecSel::Posit8x2, 9);
        mgr.admit(&mut soc, &as_image(&a)).unwrap();
        mgr.admit(&mut soc, &as_image(&b)).unwrap();
        // reference output for b before any compaction
        let xb = input_of(64, 0.3);
        let (want_b, want_rep_b) = b.replay(&mut soc, &xb, &[]).unwrap();
        mgr.admit(&mut soc, &as_image(&c)).unwrap();
        let s = mgr.stats();
        assert_eq!(s.evictions, 1, "a must be evicted for c");
        assert_eq!(s.compactions, 1, "the fragmented free list must be compacted");
        assert!(soc.has_model_state(b.uid()) && soc.has_model_state(c.uid()));
        assert_eq!(soc.resident_free_bytes(), 0, "compaction drains the free list");
        // compaction + cold-warm uploads are charged to the management
        // budget on the shared AXI channel: the relocation reads the
        // moved bytes back over the bus, the three admissions upload
        // their images — nonzero cost, visible per initiator
        let mgmt = soc.management_traffic();
        assert!(mgmt.bytes_read > 0, "compaction moves must charge management reads");
        assert!(
            mgmt.bytes_written > mgmt.bytes_read,
            "uploads + move writes must exceed the move reads"
        );
        assert!(mgmt.cycles > 0);
        // b was relocated live: values AND reports bit-identical
        let (got_b, got_rep_b) = b.replay(&mut soc, &xb, &[]).unwrap();
        assert_eq!(got_b, want_b, "relocated model diverged");
        assert_eq!(got_rep_b, want_rep_b, "relocation must not change cost accounting");
        // c serves identically to a fresh big-DRAM reference
        let xc = input_of(64, 0.6);
        let mut big = Soc::new(SocConfig::default());
        let (want_c, _) = c.replay(&mut big, &xc, &[]).unwrap();
        let (got_c, _) = c.replay(&mut soc, &xc, &[]).unwrap();
        assert_eq!(got_c, want_c);
        assert!(s.resident_high_water <= mgr.budget());
    }

    #[test]
    fn compact_resident_round_trips_every_live_byte() {
        // direct compaction: every weight image's bytes are bit-equal
        // at the relocated addresses, in every hardware mode
        for (mi, sel) in PrecSel::ALL.into_iter().enumerate() {
            let mut soc = Soc::new(SocConfig::default());
            let models: Vec<Arc<CompiledModel>> = [(64usize, 32usize), (48, 24), (32, 40)]
                .iter()
                .enumerate()
                .map(|(i, &(k, n))| {
                    fc_model(&format!("m{i}"), k, n, sel, 20 + (mi * 3 + i) as u64)
                })
                .collect();
            for m in &models {
                m.ensure_warm(&mut soc).unwrap();
            }
            // evict the middle model: a buried hole
            models[1].evict(&mut soc);
            assert!(soc.resident_free_bytes() > 0);
            let live: Vec<Arc<dyn ResidentImage>> =
                [&models[0], &models[2]].into_iter().map(as_image).collect();
            let before: Vec<Vec<u8>> = live
                .iter()
                .map(|img| {
                    img.live_blocks(&soc)
                        .iter()
                        .flat_map(|&(a, l)| soc.ext.read(a, l).unwrap().to_vec())
                        .collect()
                })
                .collect();
            let old_mark = soc.resident_mark();
            let new_top = compact_resident(&mut soc, &live).unwrap();
            assert!(new_top < old_mark, "{sel:?}: compaction must reclaim the hole");
            assert_eq!(soc.resident_free_bytes(), 0);
            let after: Vec<Vec<u8>> = live
                .iter()
                .map(|img| {
                    img.live_blocks(&soc)
                        .iter()
                        .flat_map(|&(a, l)| soc.ext.read(a, l).unwrap().to_vec())
                        .collect()
                })
                .collect();
            assert_eq!(before, after, "{sel:?}: live bytes must survive relocation");
            // and the relocated models still serve
            for (i, m) in [&models[0], &models[2]].iter().enumerate() {
                let x = input_of(m.input_len, i as f32);
                let mut fresh = Soc::new(SocConfig::default());
                let (want, _) = m.replay(&mut fresh, &x, &[]).unwrap();
                let (got, _) = m.replay(&mut soc, &x, &[]).unwrap();
                assert_eq!(got, want, "{sel:?}: model {i} diverged after compaction");
            }
        }
    }

    #[test]
    fn warm_hint_tracks_admissions_and_evictions() {
        let mut soc = small_soc();
        let mut mgr = ResidencyManager::lru(soc.resident_limit());
        let a = fc_model("a", 64, 32, PrecSel::Posit8x2, 40); // 8576
        let b = fc_model("b", 64, 80, PrecSel::Posit8x2, 41); // 21056
        assert!(!mgr.warm_hint(a.uid()), "unknown uid is never hinted warm");
        mgr.admit(&mut soc, &as_image(&a)).unwrap();
        assert!(mgr.warm_hint(a.uid()));
        assert_eq!(mgr.catalog_bytes(), 8576);
        // 8576 + 21056 > 24576 → admitting b evicts a
        mgr.admit(&mut soc, &as_image(&b)).unwrap();
        assert!(!mgr.warm_hint(a.uid()), "evicted victim's hint must clear");
        assert!(mgr.warm_hint(b.uid()));
        assert_eq!(mgr.catalog_bytes(), 8576 + 21056, "cold entries still count");
    }

    #[test]
    fn remove_evicts_and_drops_the_entry() {
        let mut soc = small_soc();
        let mut mgr = ResidencyManager::lru(soc.resident_limit());
        let a = fc_model("a", 64, 32, PrecSel::Posit16x1, 30);
        mgr.admit(&mut soc, &as_image(&a)).unwrap();
        let mark = soc.resident_mark();
        assert!(mark > 0);
        mgr.remove(&mut soc, a.uid());
        assert_eq!(mgr.catalog_len(), 0);
        assert!(!soc.has_model_state(a.uid()));
        assert_eq!(soc.resident_mark(), 0, "top-of-stack eviction unwinds the watermark");
    }

    #[test]
    fn available_after_eviction_subtracts_only_pinned_warm_bytes() {
        let mut soc = small_soc();
        let mut mgr = ResidencyManager::lru(soc.resident_limit());
        let a = fc_model("a", 64, 32, PrecSel::Posit8x2, 31); // 8576
        let b = fc_model("b", 64, 48, PrecSel::Posit8x2, 32); // 12736
        let ia = as_image(&a);
        mgr.admit(&mut soc, &ia).unwrap();
        mgr.admit(&mut soc, &as_image(&b)).unwrap();
        assert_eq!(mgr.available_after_eviction(&soc), mgr.budget(), "nothing pinned");
        mgr.pin_image(&ia);
        assert_eq!(mgr.available_after_eviction(&soc), mgr.budget() - 8576);
        // a pinned-but-cold entry reserves nothing
        mgr.remove(&mut soc, b.uid());
        a.evict(&mut soc);
        assert_eq!(mgr.available_after_eviction(&soc), mgr.budget());
    }
}
