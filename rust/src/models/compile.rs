//! The lowering pass: `(ModelGraph, TensorMap, PrecisionPlan)` →
//! [`CompiledModel`] — compile once, serve many.
//!
//! The interpreted executor pays compile-time costs on every request: it
//! re-runs im2col, re-reads and re-scales every weight tensor, and
//! re-materializes operand matrices per inference. This pass hoists all
//! of that to model-registration time:
//!
//! * **Weights are scaled and encoded exactly once** per `(layer,
//!   PrecSel)`: the scaled f32 weight matrix becomes a resident DRAM
//!   image on each warmed replica, and its packed
//!   [`EncodedOperand`] (column layout, shared by the DMA byte image and
//!   the compute array) is preloaded into the replica's
//!   [`crate::array::OperandCache`] as a pinned entry — so the control
//!   FSM's per-job lookup always hits and never encodes.
//! * **im2col becomes a gather**: a precomputed index map from the CHW
//!   activation buffer into the patch matrix (sentinel = zero padding).
//! * **Activations flow through a preallocated ping-pong arena** — two
//!   buffers sized to the widest layer boundary plus operand scratch, no
//!   per-layer `Vec` churn.
//! * **The morph schedule is fixed**: each GEMM step carries its
//!   `PrecSel`, so the array re-morphs per layer exactly as the
//!   interpreted path does.
//!
//! Per-request activation scales (`scale_for` over the live operand) are
//! recomputed — they depend on the data — but the weight scale `s_b` is
//! frozen at compile time. The replayed program is bit-identical to the
//! interpreted path in values, cycles and engine statistics; the
//! differential tests below assert this across every hardware mode and a
//! mixed per-layer plan for all three paper workloads.
//!
//! Warm state ([`Arena`]) lives on the [`Soc`] itself (keyed by the
//! compiled model's uid), like device memory: the coordinator registers
//! a model once per replica and every later request served by that
//! replica replays from warm state.

use super::exec::{self, ExecReport};
use super::graph::{ActKind, LayerKind, ModelGraph, PoolKind, Shape};
use crate::arith::{Precision, QuireMatrix, QUIRE_SPILL_BYTES};
use crate::array::EncodedOperand;
use crate::npe::PrecSel;
use crate::quant::PrecisionPlan;
use crate::soc::{AxiBus, JobReport, Soc, SocError};
use crate::util::io::TensorMap;
use crate::util::Matrix;
use anyhow::{bail, Result};
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Typed lowering/registration errors — a malformed model must be
/// rejected when it is compiled or registered, not panic mid-inference.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CompileError {
    /// The precision plan's layer count does not match the graph's
    /// compute-layer count.
    PlanLayerMismatch { model: String, plan_layers: usize, compute_layers: usize },
    /// A weight/bias/alpha tensor named by the graph is absent.
    MissingTensor { model: String, name: String },
    /// A tensor is present but its dims disagree with the graph.
    TensorShape { model: String, name: String, got: Vec<usize>, want: Vec<usize> },
}

impl fmt::Display for CompileError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CompileError::PlanLayerMismatch { model, plan_layers, compute_layers } => write!(
                f,
                "precision plan for `{model}` has {plan_layers} layers but the graph has \
                 {compute_layers} compute layers"
            ),
            CompileError::MissingTensor { model, name } => {
                write!(f, "missing weight tensor `{name}` for {model}")
            }
            CompileError::TensorShape { model, name, got, want } => {
                write!(f, "weight tensor `{name}` for {model} has dims {got:?}, want {want:?}")
            }
        }
    }
}

impl std::error::Error for CompileError {}

/// Typed warm-state access errors. The warm arena is installed by
/// `ensure_warm` immediately before use, so these states are
/// unreachable by construction — but the serving workers contain
/// panics per job, and a request must surface an impossible state as
/// an error the caller can route, not a panic that strands the
/// replica (the repo-wide no-panic rule).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WarmStateError {
    /// The model's warm state vanished between `ensure_warm` and use.
    Missing { model: String },
    /// The state stored under this model's uid is of a different type
    /// (a uid collision — uids are globally unique by construction).
    Mismatch { model: String },
}

impl fmt::Display for WarmStateError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WarmStateError::Missing { model } => {
                write!(f, "warm state for `{model}` vanished between ensure_warm and use")
            }
            WarmStateError::Mismatch { model } => {
                write!(f, "warm state for `{model}` holds a different arena type (uid collision)")
            }
        }
    }
}

impl std::error::Error for WarmStateError {}

/// Precomputed im2col: for every (patch-row, patch-col) slot the source
/// index into the CHW activation buffer, or [`GatherMap::PAD`] for a
/// zero-padded slot. `gather` reproduces [`exec::im2col`] bit for bit.
#[derive(Debug, Clone)]
pub struct GatherMap {
    /// Patch-matrix rows (`out_h · out_w`).
    pub rows: usize,
    /// Patch-matrix cols (`in_c · k · k`).
    pub cols: usize,
    idx: Vec<u32>,
}

impl GatherMap {
    /// Sentinel for zero-padded slots.
    pub const PAD: u32 = u32::MAX;

    /// Build the map for a conv layer's im2col (mirrors
    /// [`exec::im2col`]'s loop structure exactly).
    pub fn for_conv(s: Shape, k: usize, stride: usize, pad: usize) -> GatherMap {
        let oh = (s.h + 2 * pad - k) / stride + 1;
        let ow = (s.w + 2 * pad - k) / stride + 1;
        let cols = s.c * k * k;
        let mut idx = vec![GatherMap::PAD; oh * ow * cols];
        for oy in 0..oh {
            for ox in 0..ow {
                let row = oy * ow + ox;
                for ky in 0..k {
                    for kx in 0..k {
                        let iy = (oy * stride + ky) as isize - pad as isize;
                        let ix = (ox * stride + kx) as isize - pad as isize;
                        if iy < 0 || ix < 0 || iy >= s.h as isize || ix >= s.w as isize {
                            continue; // zero pad
                        }
                        for ic in 0..s.c {
                            let src = ic * s.h * s.w + iy as usize * s.w + ix as usize;
                            idx[row * cols + (ky * k + kx) * s.c + ic] = src as u32;
                        }
                    }
                }
            }
        }
        GatherMap { rows: oh * ow, cols, idx }
    }

    /// The raw index table (verifier access: bounds are checked against
    /// the live activation extent without copying the map).
    pub(crate) fn indices(&self) -> &[u32] {
        &self.idx
    }

    /// Build a map from raw parts — only for the verifier's seeded
    /// corruption tests; `for_conv` is the one production constructor.
    pub(crate) fn from_raw(rows: usize, cols: usize, idx: Vec<u32>) -> GatherMap {
        GatherMap { rows, cols, idx }
    }

    /// Fill `dst` (resized to rows×cols) with the gathered patch matrix.
    pub fn gather(&self, src: &[f32], dst: &mut Matrix) {
        dst.rows = self.rows;
        dst.cols = self.cols;
        dst.data.clear();
        dst.data.resize(self.rows * self.cols, 0.0);
        for (d, &i) in dst.data.iter_mut().zip(&self.idx) {
            if i != GatherMap::PAD {
                *d = src[i as usize];
            }
        }
    }
}

/// One pre-lowered GEMM (conv-as-im2col or fc).
#[derive(Debug, Clone)]
pub struct GemmStep {
    /// Index in `graph.layers` (for per-layer cycle reporting).
    pub layer_idx: usize,
    /// Index among GEMM steps (= compute-layer index, the plan's
    /// granularity; also indexes the arena's resident weight addresses).
    pub gemm_idx: usize,
    /// Engine mode this step morphs the array into.
    pub sel: PrecSel,
    /// Activation format the output is requantized to.
    pub out_prec: Precision,
    /// GEMM M dim (output rows; 1 for fc on a single request).
    pub m: usize,
    /// GEMM K dim (reduction extent).
    pub k: usize,
    /// GEMM N dim (output columns).
    pub n: usize,
    /// im2col gather (conv); `None` for fc (the activation vector is the
    /// 1×K operand directly).
    pub gather: Option<GatherMap>,
    /// Conv output shape — triggers the HWC→CHW scatter; `None` for fc.
    pub conv_out: Option<Shape>,
    /// Pre-scaled K×N weight operand (the resident DRAM image).
    pub weight: Matrix,
    /// Packed column-layout encoding of `weight` at `sel`, built exactly
    /// once at compile time and shared (via `Arc`) with every replica's
    /// operand cache.
    pub w_enc: Arc<EncodedOperand>,
    /// Per-output-column bias, added in the postprocess fold.
    pub bias: Vec<f32>,
    /// Frozen per-tensor pow-2 weight scale.
    pub s_b: f64,
}

/// One step of the compiled program. The GEMM payload is boxed: it
/// dwarfs the vector-unit steps (resident weight image + gather map).
#[derive(Debug, Clone)]
pub enum Step {
    /// A conv/fc layer lowered to one GEMM on the array.
    Gemm(Box<GemmStep>),
    /// A pooling layer on the vector unit.
    Pool { kind: PoolKind, size: usize, in_shape: Shape, out_len: usize },
    /// An activation layer on the vector unit.
    Act { kind: ActKind, alpha: f64, len: usize },
    /// Append `n` auxiliary input elements to the activation vector.
    ConcatAux { n: usize },
}

/// A model lowered for serving. Immutable and `Arc`-shareable across
/// replicas/threads; per-replica mutable state lives in the [`Arena`]
/// the model installs on each [`Soc`] it is warmed on.
#[derive(Debug, Clone)]
pub struct CompiledModel {
    /// Graph name (sanity-checked against executors).
    pub name: String,
    /// The morph schedule: per-compute-layer engine modes + params.
    pub plan: PrecisionPlan,
    /// The lowered program, in graph order (`Flatten` lowers to nothing).
    pub steps: Vec<Step>,
    /// Flat input element count the program expects.
    pub input_len: usize,
    /// Flat output element count the program produces.
    pub output_len: usize,
    /// Elements per ping-pong activation buffer (widest layer boundary).
    pub buf_len: usize,
    /// Elements of A-operand scratch (max m·k over GEMM steps).
    pub a_len: usize,
    /// Elements of output scratch (max m·n over GEMM steps).
    pub c_len: usize,
    /// Precision-ladder rung this compilation serves (0 = highest
    /// fidelity; also 0 for every single-plan compile, so non-ladder
    /// models are unchanged). The ladder constructor
    /// ([`crate::coordinator::ModelInstance::ladder`]) tags each rung
    /// before the program is shared; every [`ExecReport`] the program
    /// produces carries the tag as its per-request plan stamp.
    pub rung: u32,
    uid: u64,
}

/// Per-(replica, model) warm state: the resident DRAM addresses. The
/// host-side run buffers used to live here too; they are now the
/// replica-wide [`ReplicaScratch`] shared across every resident model.
struct Arena {
    /// Resident weight base address per GEMM step.
    w_addrs: Vec<u64>,
    /// Stable per-request A-operand / result scratch addresses.
    a_addr: u64,
    c_addr: u64,
    /// Every resident span this arena owns (`(start, end)` byte ranges,
    /// alignment padding included), handed back to
    /// [`Soc::free_resident`] on eviction.
    allocs: Vec<(u64, u64)>,
}

/// Replica-wide host run scratch shared by **all** resident compiled
/// models: the ping-pong activation buffers plus the operand/result
/// staging matrices, grown to the largest model ever replayed on the
/// replica (the ROADMAP "arena reuse" item — one sized-to-max arena per
/// replica instead of one per (model, replica)). Safe to share because
/// every access in [`CompiledModel::run`] is length-bounded by the
/// current layer (`[..cur_len]` etc.), so stale bytes from another
/// model are never read — the differential tests stay bit-identical.
struct ReplicaScratch {
    bufs: [Vec<f32>; 2],
    a_mat: Matrix,
    out_mat: Matrix,
}

impl Default for ReplicaScratch {
    fn default() -> Self {
        ReplicaScratch {
            bufs: [Vec::new(), Vec::new()],
            a_mat: Matrix { rows: 0, cols: 0, data: Vec::new() },
            out_mat: Matrix { rows: 0, cols: 0, data: Vec::new() },
        }
    }
}

impl ReplicaScratch {
    /// Grow (never shrink) to fit `model`'s widest layer boundary.
    fn fit(&mut self, model: &CompiledModel) {
        if self.bufs[0].len() < model.buf_len {
            self.bufs[0].resize(model.buf_len, 0.0);
            self.bufs[1].resize(model.buf_len, 0.0);
        }
        // `reserve` is relative to len: request exactly what lifts the
        // capacity to the model's operand sizes
        if self.a_mat.data.capacity() < model.a_len {
            let len = self.a_mat.data.len();
            self.a_mat.data.reserve(model.a_len - len);
        }
        if self.out_mat.data.capacity() < model.c_len {
            let len = self.out_mat.data.len();
            self.out_mat.data.reserve(model.c_len - len);
        }
    }
}

/// Allocate `bytes` of resident DRAM and record the span (including the
/// bump path's alignment padding, so freeing the spans in order unwinds
/// the watermark exactly).
fn alloc_span(soc: &mut Soc, bytes: usize, allocs: &mut Vec<(u64, u64)>) -> Result<u64, SocError> {
    let pre = soc.resident_mark();
    let addr = soc.alloc_resident(bytes)?;
    let end = addr + bytes as u64;
    // a free-list hit sits below the pre-alloc watermark; its padding
    // fragment (if any) went back to the free list inside the allocator
    allocs.push((if addr >= pre { pre } else { addr }, end));
    Ok(addr)
}

static NEXT_UID: AtomicU64 = AtomicU64::new(1);

/// Lower a graph + weights + plan into a [`CompiledModel`].
pub fn compile(
    graph: &ModelGraph,
    weights: &TensorMap,
    plan: &PrecisionPlan,
) -> Result<CompiledModel, CompileError> {
    let compute = graph.compute_layers().len();
    if plan.per_layer.len() != compute {
        return Err(CompileError::PlanLayerMismatch {
            model: graph.name.clone(),
            plan_layers: plan.per_layer.len(),
            compute_layers: compute,
        });
    }
    let tensor = |name: String| {
        weights.get(&name).ok_or_else(|| CompileError::MissingTensor {
            model: graph.name.clone(),
            name: name.clone(),
        })
    };
    let shapes = graph.shapes();
    let mut steps = Vec::with_capacity(graph.layers.len());
    let mut gemm_idx = 0usize;
    for (li, layer) in graph.layers.iter().enumerate() {
        let in_shape = shapes[li];
        match &layer.kind {
            LayerKind::Conv2d { in_c, out_c, k, stride, pad } => {
                let wt = tensor(format!("{}.w", layer.name))?;
                let want = vec![*k, *k, *in_c, *out_c];
                if wt.dims != want {
                    return Err(CompileError::TensorShape {
                        model: graph.name.clone(),
                        name: format!("{}.w", layer.name),
                        got: wt.dims.clone(),
                        want,
                    });
                }
                let bias = tensor(format!("{}.b", layer.name))?;
                if bias.data.len() != *out_c {
                    return Err(CompileError::TensorShape {
                        model: graph.name.clone(),
                        name: format!("{}.b", layer.name),
                        got: bias.dims.clone(),
                        want: vec![*out_c],
                    });
                }
                let b = Matrix::from_vec(in_c * k * k, *out_c, wt.data.clone());
                let out_shape = layer.kind.out_shape(in_shape);
                steps.push(Step::Gemm(Box::new(lower_gemm(
                    li,
                    gemm_idx,
                    plan,
                    b,
                    bias.data.clone(),
                    Some(GatherMap::for_conv(in_shape, *k, *stride, *pad)),
                    Some(out_shape),
                    out_shape.h * out_shape.w,
                ))));
                gemm_idx += 1;
            }
            LayerKind::Fc { in_f, out_f } => {
                let wt = tensor(format!("{}.w", layer.name))?;
                let want = vec![*in_f, *out_f];
                if wt.dims != want {
                    return Err(CompileError::TensorShape {
                        model: graph.name.clone(),
                        name: format!("{}.w", layer.name),
                        got: wt.dims.clone(),
                        want,
                    });
                }
                let bias = tensor(format!("{}.b", layer.name))?;
                if bias.data.len() != *out_f {
                    return Err(CompileError::TensorShape {
                        model: graph.name.clone(),
                        name: format!("{}.b", layer.name),
                        got: bias.dims.clone(),
                        want: vec![*out_f],
                    });
                }
                let b = Matrix::from_vec(*in_f, *out_f, wt.data.clone());
                steps.push(Step::Gemm(Box::new(lower_gemm(
                    li,
                    gemm_idx,
                    plan,
                    b,
                    bias.data.clone(),
                    None,
                    None,
                    1,
                ))));
                gemm_idx += 1;
            }
            LayerKind::Pool { kind, size } => {
                steps.push(Step::Pool {
                    kind: *kind,
                    size: *size,
                    in_shape,
                    out_len: layer.kind.out_shape(in_shape).numel(),
                });
            }
            LayerKind::Act(kind) => {
                let alpha = match kind {
                    ActKind::Pact => {
                        let t = tensor(format!("{}.alpha", layer.name))?;
                        t.data[0] as f64
                    }
                    _ => 0.0,
                };
                steps.push(Step::Act { kind: *kind, alpha, len: in_shape.numel() });
            }
            LayerKind::Flatten => { /* CHW storage is already flat */ }
            LayerKind::ConcatAux { n } => steps.push(Step::ConcatAux { n: *n }),
        }
    }
    let buf_len = shapes.iter().map(Shape::numel).max().unwrap_or(0);
    let (mut a_len, mut c_len) = (0usize, 0usize);
    for step in &steps {
        if let Step::Gemm(g) = step {
            a_len = a_len.max(g.m * g.k);
            c_len = c_len.max(g.m * g.n);
        }
    }
    Ok(CompiledModel {
        name: graph.name.clone(),
        plan: plan.clone(),
        steps,
        input_len: graph.input.numel(),
        output_len: graph.out_shape().numel(),
        buf_len,
        a_len,
        c_len,
        rung: 0,
        uid: NEXT_UID.fetch_add(1, Ordering::Relaxed),
    })
}

/// Scale + encode one weight operand (the only place weight encoding
/// happens — once per (layer, mode) per compile).
#[allow(clippy::too_many_arguments)]
fn lower_gemm(
    layer_idx: usize,
    gemm_idx: usize,
    plan: &PrecisionPlan,
    b: Matrix,
    bias: Vec<f32>,
    gather: Option<GatherMap>,
    conv_out: Option<Shape>,
    m: usize,
) -> GemmStep {
    let sel = plan.per_layer[gemm_idx];
    let prec = sel.precision();
    let out_prec = plan.layer_precision(gemm_idx);
    let s_b = exec::scale_for(&b.data, prec);
    let weight = b.map(|x| (x as f64 / s_b) as f32);
    let w_enc = Arc::new(EncodedOperand::cols(&weight, sel));
    GemmStep {
        layer_idx,
        gemm_idx,
        sel,
        out_prec,
        m,
        k: b.rows,
        n: b.cols,
        gather,
        conv_out,
        weight,
        w_enc,
        bias,
        s_b,
    }
}

impl CompiledModel {
    /// Stable identity of this compilation (keys warm state on a `Soc`).
    pub fn uid(&self) -> u64 {
        self.uid
    }

    /// Number of GEMM (compute) steps — each encoded its weight operand
    /// exactly once at compile time (the real encode-once proof on the
    /// serving path is the operand cache's preloads/hits/misses
    /// counters, asserted in the registration tests).
    pub fn n_gemm(&self) -> usize {
        self.steps.iter().filter(|s| matches!(s, Step::Gemm(_))).count()
    }

    /// Resident f32 weight-image footprint in bytes.
    pub fn resident_bytes(&self) -> usize {
        self.steps
            .iter()
            .map(|s| if let Step::Gemm(g) = s { g.weight.data.len() * 4 } else { 0 })
            .sum()
    }

    /// Conservative resident-DRAM footprint of one warm instance: every
    /// span [`CompiledModel::ensure_warm`] allocates (weight images +
    /// request scratch), each rounded up to the allocator's 64-byte
    /// alignment. The router's DRAM-budget accounting compares this
    /// against a replica's free resident budget to decide whether a
    /// model needs sharding.
    pub fn warm_footprint_bytes(&self) -> usize {
        let spans = self
            .steps
            .iter()
            .filter_map(|s| if let Step::Gemm(g) = s { Some(g.weight.data.len() * 4) } else { None })
            .chain([self.a_len * 4, self.c_len * 4]);
        spans.map(|b| b.next_multiple_of(64)).sum()
    }

    /// Ensure this model is warm on `soc`: allocate the resident weight
    /// region, upload the scaled weight images, preload their packed
    /// encodings into the replica's [`crate::array::OperandCache`] (pinned — weights
    /// are never encoded again on this replica), and install the run
    /// arena. Idempotent per (model, soc).
    pub fn ensure_warm(&self, soc: &mut Soc) -> Result<(), SocError> {
        if soc.has_model_state(self.uid) {
            return Ok(());
        }
        let arena = self.warm_inner(soc)?;
        soc.put_model_state(self.uid, Box::new(arena));
        Ok(())
    }

    /// Warm on `soc`, cleaning up after itself on failure: exactly the
    /// pins it placed are released (never more — over-unpinning would
    /// steal pins from another live model sharing identical weight
    /// content) and every resident span it allocated is freed, so a
    /// rejected model leaves the SoC exactly as it found it.
    fn warm_inner(&self, soc: &mut Soc) -> Result<Arena, SocError> {
        let gemms = self.gemm_steps();
        let mut allocs: Vec<(u64, u64)> = Vec::with_capacity(gemms.len() + 2);
        let mut w_addrs = Vec::with_capacity(gemms.len());
        let fail = |me: &Self, soc: &mut Soc, pins: usize, allocs: &[(u64, u64)], e: SocError| {
            me.unpin_first(soc, pins);
            for &(s, end) in allocs {
                soc.free_resident(s, end);
            }
            e
        };
        for (i, g) in gemms.iter().enumerate() {
            let addr = match alloc_span(soc, g.weight.data.len() * 4, &mut allocs) {
                Ok(a) => a,
                Err(e) => return Err(fail(self, soc, i, &allocs, e)),
            };
            if let Err(e) = soc.ext.write_f32(addr, &g.weight.data) {
                return Err(fail(self, soc, i, &allocs, e));
            }
            soc.enc_cache.preload_cols(&g.weight, Arc::clone(&g.w_enc));
            w_addrs.push(addr);
        }
        let a_addr = match alloc_span(soc, self.a_len * 4, &mut allocs) {
            Ok(a) => a,
            Err(e) => return Err(fail(self, soc, gemms.len(), &allocs, e)),
        };
        let c_addr = match alloc_span(soc, self.c_len * 4, &mut allocs) {
            Ok(a) => a,
            Err(e) => return Err(fail(self, soc, gemms.len(), &allocs, e)),
        };
        Ok(Arena { w_addrs, a_addr, c_addr, allocs })
    }

    fn gemm_steps(&self) -> Vec<&GemmStep> {
        self.steps
            .iter()
            .filter_map(|s| if let Step::Gemm(g) = s { Some(&**g) } else { None })
            .collect()
    }

    /// Release the pins of the first `count` GEMM steps only.
    fn unpin_first(&self, soc: &mut Soc, count: usize) {
        for g in self.gemm_steps().into_iter().take(count) {
            soc.enc_cache.unpin_cols(&g.weight, g.sel);
        }
    }

    /// Tear down this model's warm state on `soc`: drop the run arena,
    /// unpin its weight encodings from the operand cache, and hand every
    /// resident span back to the allocator. A top-of-stack model unwinds
    /// the watermark directly; a model buried under later registrations
    /// goes onto the free list, where [`Soc::alloc_resident`] reuses it
    /// first-fit — so a register→evict→register refresh loop no longer
    /// leaks the buried image (regression-tested in the router).
    ///
    /// A no-op on a SoC this model was never warmed on: in the
    /// warm-on-demand world a replica may never have seen the model, and
    /// unpinning there could steal cache pins from a *different* live
    /// model that preloaded identical weight content.
    pub fn evict(&self, soc: &mut Soc) {
        let Some(arena) = soc.take_model_state(self.uid).and_then(|b| b.downcast::<Arena>().ok())
        else {
            return;
        };
        self.unpin(soc);
        for &(s, e) in &arena.allocs {
            soc.free_resident(s, e);
        }
    }

    fn unpin(&self, soc: &mut Soc) {
        for step in &self.steps {
            if let Step::Gemm(g) = step {
                soc.enc_cache.unpin_cols(&g.weight, g.sel);
            }
        }
    }

    /// Serve one request by replaying the compiled program on `soc`
    /// (warming it first if needed). Bit-identical to
    /// [`exec::Executor::forward_interpret`] in values, cycles and
    /// engine statistics.
    pub fn replay(
        &self,
        soc: &mut Soc,
        input: &[f32],
        aux: &[f32],
    ) -> Result<(Vec<f32>, ExecReport)> {
        // the static verifier is the registration-time gate; re-assert it
        // in debug builds on first warm so a program that dodged the
        // router (tests, examples, direct replay) is still checked before
        // its first DRAM write
        #[cfg(debug_assertions)]
        if !soc.has_model_state(self.uid) {
            let checked = super::verify::verify_program(self, soc.resident_limit());
            debug_assert!(
                checked.is_ok(),
                "replay of unverifiable program `{}`: {:?}",
                self.name,
                checked.err()
            );
        }
        self.ensure_warm(soc)?;
        let state = match soc.take_model_state(self.uid) {
            Some(s) => s,
            None => return Err(WarmStateError::Missing { model: self.name.clone() }.into()),
        };
        let mut arena = match state.downcast::<Arena>() {
            Ok(a) => a,
            Err(state) => {
                // put the foreign state back before erroring — it is
                // some other owner's only record of its resident spans
                soc.put_model_state(self.uid, state);
                return Err(WarmStateError::Mismatch { model: self.name.clone() }.into());
            }
        };
        // the replica-wide shared run scratch, grown to this model
        let mut scratch = soc
            .take_scratch()
            .and_then(|b| b.downcast::<ReplicaScratch>().ok())
            .unwrap_or_default();
        scratch.fit(self);
        // The arena is the only record of this model's resident spans
        // and cache pins; it (and the shared scratch) must go back on
        // the SoC even if the run panics (the serving workers contain
        // panics per job — dropping it here would leak the spans
        // forever and strand stale pins, since `evict` has nothing to
        // unwind without it). The buffers are overwritten from scratch
        // on every request, so restoring half-written state is sound.
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            self.run(soc, &mut arena, &mut scratch, input, aux)
        }));
        soc.put_model_state(self.uid, arena);
        soc.put_scratch(scratch);
        match res {
            Ok(r) => r,
            Err(p) => std::panic::resume_unwind(p),
        }
    }

    /// Serve one request with the per-layer GEMMs **scattered across
    /// shard replicas** as a streaming pipeline. The coordinator builds
    /// each layer's activation operand (gather + the same dynamic
    /// per-request scale as [`CompiledModel::replay`]), slices it per
    /// shard and dispatches the partial-GEMM jobs through `ch`; partials
    /// are then drained in **completion-arrival order**
    /// ([`ShardChannel::wait_any`]) and merged incrementally:
    ///
    /// * **K-split** layers merge each arriving full-width quire image
    ///   into the accumulator as it lands ([`QuireMatrix::merge_block`]
    ///   — exact, associative and commutative, so arrival order cannot
    ///   change a bit), round **once**, and postprocess centrally.
    /// * **N-split** layers arrive as rounded + scale/bias-folded f32
    ///   column blocks (the shard-local tail, [`LocalTail`]) written
    ///   straight into the output; only the global requantization
    ///   ([`exec::requantize`] — its pow-2 scale spans the full tensor)
    ///   runs at the coordinator, and the layer charges **zero**
    ///   reduction traffic.
    ///
    /// Under [`ShardFlow::Streaming`] dispatch is bounded by
    /// [`SHARD_INFLIGHT_WINDOW`] (back-pressure: one new dispatch per
    /// drained completion) and the report's
    /// `overlap_cycles_hidden` counter accrues the simulated straggler
    /// cycles the pipeline hides; [`ShardFlow::Barrier`] dispatches the
    /// whole layer upfront and keeps the counter at zero. The two flows
    /// are bit-identical in values and in every other report field.
    ///
    /// Values are bit-identical to the whole-model replay in every mode;
    /// the returned [`ExecReport`] sums every shard's job work and
    /// carries the documented cross-shard reduction term
    /// ([`reduction_cost`]) in `reduce_cycles`/`reduce_bytes`. The
    /// router drives this with the async serving runtime (a
    /// [`crate::serve::CompletionSet`] behind `ch`); tests drive it
    /// inline with seeded arrival permutations.
    pub fn run_sharded(
        &self,
        shards: &[Arc<ShardedModel>],
        input: &[f32],
        aux: &[f32],
        ch: &mut dyn ShardChannel,
        flow: ShardFlow,
    ) -> Result<(Vec<f32>, ExecReport)> {
        if shards.is_empty() {
            bail!("no shards supplied for `{}`", self.name);
        }
        for sh in shards {
            if sh.model_uid != self.uid {
                bail!("shard of a different compilation supplied for `{}`", self.name);
            }
        }
        let n_shards = shards.len();
        let mut scratch = ReplicaScratch::default();
        scratch.fit(self);
        // streaming-overlap bookkeeping: the previous gemm layer's
        // per-shard cycles + its streaming finish time, and the vector
        // cycles charged at the coordinator since that layer — the
        // window the next layer's weight DMA can hide behind
        let mut prev_timing: Option<LayerTiming> = None;
        let mut vec_mark = 0u64;
        self.walk_steps(&mut scratch, input, aux, &mut |g, a_mat, s_a, out_mat, report| {
            let kind = shards[0].steps[g.gemm_idx].slice;
            let slice_a = |si: usize| -> Matrix {
                match shards[si].steps[g.gemm_idx].slice {
                    ShardSlice::K { k0, k1 } => Matrix::from_vec(
                        a_mat.rows,
                        k1 - k0,
                        (0..a_mat.rows)
                            .flat_map(|r| a_mat.row(r)[k0..k1].iter().copied())
                            .collect(),
                    ),
                    // N-split consumes the full A (the weight is column-
                    // sliced instead)
                    ShardSlice::N { .. } => a_mat.clone(),
                }
            };
            // windowed dispatch: Streaming keeps at most
            // SHARD_INFLIGHT_WINDOW partials outstanding (back-pressure
            // and clean quiesce); Barrier scatters the full layer
            let window = match flow {
                ShardFlow::Barrier => n_shards,
                ShardFlow::Streaming => SHARD_INFLIGHT_WINDOW.min(n_shards),
            };
            for si in 0..window {
                ch.dispatch(si, g.gemm_idx, slice_a(si), s_a)?;
            }
            let mut next_dispatch = window;
            let mut quires = QuireMatrix::zeros(g.m, g.n);
            let mut layer_jobs = JobReport::default();
            let mut shard_cycles = vec![0u64; n_shards];
            let mut shard_dma = vec![0u64; n_shards];
            // drain in completion-arrival order, refilling the window
            for _ in 0..n_shards {
                let (si, part, rep) = ch.wait_any()?;
                if next_dispatch < n_shards {
                    ch.dispatch(next_dispatch, g.gemm_idx, slice_a(next_dispatch), s_a)?;
                    next_dispatch += 1;
                }
                match (part, shards[si].steps[g.gemm_idx].slice) {
                    // incremental merge as each partial lands — exact,
                    // so arrival order cannot change the result
                    (PartialOut::Quires(p), ShardSlice::K { .. }) => {
                        quires.merge_block(0, &p);
                        ch.on_merge(si, merge_pass_cycles(si, (g.m * g.n) as u64));
                    }
                    // local-tail block: already rounded + folded on the
                    // shard, lands in its disjoint columns
                    (PartialOut::Cols(block), ShardSlice::N { n0, n1 }) => {
                        debug_assert_eq!((block.rows, block.cols), (g.m, n1 - n0));
                        for r in 0..block.rows {
                            for c in 0..block.cols {
                                out_mat.set(r, n0 + c, block.at(r, c));
                            }
                        }
                    }
                    _ => bail!(
                        "shard {si} of `{}` returned the wrong partial kind for gemm {}",
                        self.name,
                        g.gemm_idx
                    ),
                }
                shard_cycles[si] = rep.total_cycles;
                shard_dma[si] = rep.dma_cycles;
                layer_jobs.merge(&rep);
            }
            let (rc, rb) = layer_reduction_cost(shards, g);
            report.per_layer_cycles.push((g.layer_idx, layer_jobs.total_cycles + rc));
            report.jobs.merge(&layer_jobs);
            report.reduce_cycles += rc;
            report.reduce_bytes += rb;
            match kind {
                ShardSlice::K { .. } => {
                    // exactly one rounding of the merged quires — the
                    // same output-processing expression as the engine's
                    let raw = Matrix::from_vec(g.m, g.n, quires.round_to(Precision::Fp32));
                    exec::postprocess_gemm(&raw, s_a, g.s_b, &g.bias, g.out_prec, out_mat);
                }
                ShardSlice::N { .. } => {
                    // blocks are pre-folded; only the global requant
                    // pass (full-tensor scale) remains
                    exec::requantize(g.out_prec, out_mat);
                }
            }
            // simulated-overlap accounting (Streaming only): derived
            // from per-shard JobReport components and the documented
            // cost model — deterministic, independent of the host
            // arrival order that actually occurred
            let finish = if flow == ShardFlow::Streaming {
                // only K-split quire merges interleave with arrivals —
                // the N-split gather share of `rc` is a coordinator-side
                // column-block read with no per-partial pass structure,
                // so it must not fabricate merge passes here
                let merge_rc = if matches!(kind, ShardSlice::K { .. }) { rc } else { 0 };
                let (finish, hidden_merge) =
                    streamed_merge_timing(&shard_cycles, (g.m * g.n) as u64, merge_rc);
                let mut hidden = hidden_merge;
                if let Some(prev) = &prev_timing {
                    let v_coord = report.vector_cycles - vec_mark;
                    let (ph, stall) = prefetch_overlap(
                        shards,
                        g.gemm_idx,
                        prev,
                        v_coord,
                        &shard_cycles,
                        &shard_dma,
                    );
                    hidden += ph;
                    report.prefetch_hidden_cycles += ph;
                    report.axi_stall_cycles += stall;
                }
                report.overlap_cycles_hidden += hidden;
                Some(LayerTiming { cycles: shard_cycles, finish })
            } else {
                None
            };
            prev_timing = finish;
            vec_mark = report.vector_cycles;
            Ok(())
        })
    }

    /// The one step-walk shared by the whole-model and sharded paths
    /// (closing the PR 4/5 mirror debt): input copy, gather / fc
    /// operand build, the dynamic per-request activation scale, the
    /// vector-unit steps and the ping-pong arena all live here once.
    /// `gemm_exec` fills `out_mat` (pre-sized m×n, zeroed) with the
    /// layer's postprocessed output and charges its own job/reduction
    /// stats — `gemm_trusted` + postprocess for the whole path, the
    /// streaming shard engine for the sharded path.
    fn walk_steps(
        &self,
        scratch: &mut ReplicaScratch,
        input: &[f32],
        aux: &[f32],
        gemm_exec: &mut dyn FnMut(
            &GemmStep,
            &Matrix,
            f64,
            &mut Matrix,
            &mut ExecReport,
        ) -> Result<()>,
    ) -> Result<(Vec<f32>, ExecReport)> {
        if input.len() != self.input_len {
            bail!("input length {} != {}", input.len(), self.input_len);
        }
        let ReplicaScratch { bufs, a_mat, out_mat } = scratch;
        let mut report = ExecReport { rung: self.rung, ..ExecReport::default() };
        let mut cur = 0usize;
        let mut cur_len = input.len();
        bufs[0][..cur_len].copy_from_slice(input);
        for step in &self.steps {
            match step {
                Step::Gemm(g) => {
                    match &g.gather {
                        Some(map) => map.gather(&bufs[cur][..cur_len], a_mat),
                        None => {
                            a_mat.rows = 1;
                            a_mat.cols = g.k;
                            a_mat.data.clear();
                            a_mat.data.extend_from_slice(&bufs[cur][..cur_len]);
                        }
                    }
                    // dynamic per-request activation scale — identical
                    // fold + element expression on every path (sharded
                    // slicing happens after, so every shard sees the
                    // same element values)
                    let s_a = exec::scale_for(&a_mat.data, g.sel.precision());
                    for v in a_mat.data.iter_mut() {
                        *v = (*v as f64 / s_a) as f32;
                    }
                    out_mat.rows = g.m;
                    out_mat.cols = g.n;
                    out_mat.data.clear();
                    out_mat.data.resize(g.m * g.n, 0.0);
                    gemm_exec(g, a_mat, s_a, out_mat, &mut report)?;
                    let nxt = 1 - cur;
                    match g.conv_out {
                        Some(shape) => {
                            exec::chw_into(out_mat, shape, &mut bufs[nxt][..shape.numel()]);
                            cur_len = shape.numel();
                        }
                        None => {
                            bufs[nxt][..g.n].copy_from_slice(&out_mat.data);
                            cur_len = g.n;
                        }
                    }
                    cur = nxt;
                }
                Step::Pool { kind, size, in_shape, out_len } => {
                    let nxt = 1 - cur;
                    let (lo, hi) = bufs.split_at_mut(1);
                    let (src, dst) =
                        if cur == 0 { (&lo[0], &mut hi[0]) } else { (&hi[0], &mut lo[0]) };
                    exec::pool_into(
                        &src[..in_shape.numel()],
                        *in_shape,
                        *kind,
                        *size,
                        &mut dst[..*out_len],
                    );
                    report.vector_cycles += (in_shape.numel() / 2) as u64;
                    cur = nxt;
                    cur_len = *out_len;
                }
                Step::Act { kind, alpha, len } => {
                    debug_assert_eq!(*len, cur_len);
                    for v in bufs[cur][..cur_len].iter_mut() {
                        *v = exec::activate(*v as f64, *kind, *alpha) as f32;
                    }
                    report.vector_cycles += (cur_len / 4) as u64;
                }
                Step::ConcatAux { n } => {
                    if aux.len() != *n {
                        bail!("aux length {} != {}", aux.len(), n);
                    }
                    bufs[cur][cur_len..cur_len + n].copy_from_slice(aux);
                    cur_len += n;
                }
            }
        }
        Ok((bufs[cur][..cur_len].to_vec(), report))
    }

    fn run(
        &self,
        soc: &mut Soc,
        arena: &mut Arena,
        scratch: &mut ReplicaScratch,
        input: &[f32],
        aux: &[f32],
    ) -> Result<(Vec<f32>, ExecReport)> {
        self.walk_steps(scratch, input, aux, &mut |g, a_mat, s_a, out_mat, report| {
            // trusted pin: the compiled weight encoding rides the
            // job, so warm serving never re-reads or hash-verifies
            // the resident image (cycle/byte stats identical to
            // `gemm_resident`)
            let (raw, rep) = soc.gemm_trusted(
                a_mat,
                g.k,
                g.n,
                arena.w_addrs[g.gemm_idx],
                &g.w_enc,
                arena.a_addr,
                arena.c_addr,
                g.sel,
                Precision::Fp32,
            )?;
            report.per_layer_cycles.push((g.layer_idx, rep.total_cycles));
            report.jobs.merge(&rep);
            exec::postprocess_gemm(&raw, s_a, g.s_b, &g.bias, g.out_prec, out_mat);
            Ok(())
        })
    }

    /// Byte sizes of this model's warm blocks in the fixed block order
    /// (one per GEMM weight image, then A-operand scratch, then result
    /// scratch) — the single source the live-block walk and the
    /// compaction rebase both derive from.
    fn block_sizes(&self) -> Vec<usize> {
        self.gemm_steps()
            .iter()
            .map(|g| g.weight.data.len() * 4)
            .chain([self.a_len * 4, self.c_len * 4])
            .collect()
    }

    /// Live resident data blocks of this model's warm arena on `soc`
    /// (`(addr, len_bytes)` in [`CompiledModel::block_sizes`] order).
    /// Empty when the model is not warm there. The compaction pass
    /// relocates exactly these blocks and hands the new addresses back
    /// through [`CompiledModel::rebase_on`].
    pub(crate) fn live_blocks_on(&self, soc: &Soc) -> Vec<(u64, usize)> {
        let Some(arena) = soc.model_state_ref(self.uid).and_then(|s| s.downcast_ref::<Arena>())
        else {
            return Vec::new();
        };
        paired_blocks(&arena.w_addrs, [arena.a_addr, arena.c_addr], &self.block_sizes())
    }

    /// Patch this model's warm arena after compaction moved its blocks:
    /// `new_addrs[i]` is the relocated base of block `i` (same order as
    /// [`CompiledModel::live_blocks_on`]).
    pub(crate) fn rebase_on(&self, soc: &mut Soc, new_addrs: &[u64]) {
        let Some(mut state) = soc.take_model_state(self.uid) else { return };
        if let Some(arena) = state.downcast_mut::<Arena>() {
            let Arena { w_addrs, a_addr, c_addr, allocs, .. } = arena;
            rebase_blocks(w_addrs, [a_addr, c_addr], allocs, new_addrs, &self.block_sizes());
        }
        soc.put_model_state(self.uid, state);
    }
}

/// Pair a warm arena's block addresses with the owner's block sizes —
/// the one live-block walk shared by [`CompiledModel::live_blocks_on`]
/// and [`ShardedModel::live_blocks_on`] (weight images in order, then
/// the two scratch blocks).
fn paired_blocks(w_addrs: &[u64], scratch_addrs: [u64; 2], sizes: &[usize]) -> Vec<(u64, usize)> {
    debug_assert_eq!(sizes.len(), w_addrs.len() + 2);
    w_addrs.iter().copied().chain(scratch_addrs).zip(sizes.iter().copied()).collect()
}

/// Patch a warm arena's addresses after compaction — the one rebase
/// shared by [`CompiledModel::rebase_on`] and
/// [`ShardedModel::rebase_on`]. `new_addrs[i]` is the relocated base of
/// block `i` in [`paired_blocks`] order; the recorded spans are rebuilt
/// tight around the blocks — the old spans' alignment padding was
/// reclaimed by the compaction itself.
fn rebase_blocks(
    w_addrs: &mut [u64],
    scratch_addrs: [&mut u64; 2],
    allocs: &mut Vec<(u64, u64)>,
    new_addrs: &[u64],
    sizes: &[usize],
) {
    let n_w = w_addrs.len();
    debug_assert_eq!(new_addrs.len(), n_w + 2);
    debug_assert_eq!(sizes.len(), n_w + 2);
    w_addrs.copy_from_slice(&new_addrs[..n_w]);
    let [sc0, sc1] = scratch_addrs;
    *sc0 = new_addrs[n_w];
    *sc1 = new_addrs[n_w + 1];
    *allocs = new_addrs.iter().zip(sizes).map(|(&a, &s)| (a, a + s as u64)).collect();
}

// --------------------------------------------------------------- sharding

/// K-split boundaries snap to multiples of this (the lcm of every
/// mode's lane count), so each non-final slice packs into whole engine
/// words and the per-shard fetch byte accounting sums exactly to the
/// whole-model job's. Values are split-exact regardless — padding lanes
/// are zero and zero products are power-gated into the quire.
pub const SHARD_K_ALIGN: usize = 4;

/// Typed shard-planning errors: a plan the fleet cannot execute must be
/// rejected when the shard plan is built, never mid-request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ShardError {
    /// A plan with zero shards (an empty shard set) is meaningless.
    ZeroShards { model: String },
    /// A GEMM step too small to split `n_shards` ways in either
    /// dimension (K < [`SHARD_K_ALIGN`]·n_shards and N < n_shards).
    Unsplittable { model: String, gemm_idx: usize, k: usize, n: usize, n_shards: usize },
}

impl fmt::Display for ShardError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ShardError::ZeroShards { model } => {
                write!(f, "shard plan for `{model}` has zero shards")
            }
            ShardError::Unsplittable { model, gemm_idx, k, n, n_shards } => write!(
                f,
                "gemm step {gemm_idx} of `{model}` ({k}x{n} weight) cannot be split \
                 {n_shards} ways (needs K >= {} or N >= {n_shards})",
                SHARD_K_ALIGN * n_shards
            ),
        }
    }
}

impl std::error::Error for ShardError {}

/// Which slice of a GEMM step's weight a shard holds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardSlice {
    /// Rows `k0..k1` of the K×N weight: the shard consumes the matching
    /// column slice of A and produces **full-width partial quires** that
    /// reduce across shards.
    K { k0: usize, k1: usize },
    /// Columns `n0..n1` of the weight (the fallback when K is too small
    /// to split): the shard consumes the full A, owns a disjoint output
    /// column block outright, and runs the [`LocalTail`] on it — no
    /// quires cross to the coordinator.
    N { n0: usize, n1: usize },
}

/// The shard-local output tail of an N-split slice: round the slice's
/// quires once, then fold the element-wise part of the compiled
/// postprocess (`(raw · s_a · s_b) + bias[c]` — see
/// [`exec::postprocess_fold`]) on the replica that owns the columns.
/// The fold touches each output element independently, so running it on
/// disjoint column blocks is bit-exact; only the **global**
/// requantization ([`exec::requantize`], whose scale spans the full
/// output tensor) must wait for the assembled result at the
/// coordinator. `bias` is the parent layer's bias sliced to this
/// block's columns; `s_b` is the frozen whole-tensor weight scale.
#[derive(Debug, Clone)]
pub struct LocalTail {
    /// Frozen whole-tensor weight scale of the parent layer.
    pub s_b: f64,
    /// Parent bias sliced to this block's output columns.
    pub bias: Vec<f32>,
}

/// One GEMM step's slice as held by one shard.
#[derive(Debug, Clone)]
pub struct ShardStep {
    /// Index among the parent model's GEMM steps.
    pub gemm_idx: usize,
    /// Engine mode of the parent step (shared by every shard).
    pub sel: PrecSel,
    /// Output rows of the layer (shared by every shard).
    pub m: usize,
    /// This slice's K extent.
    pub k: usize,
    /// This slice's N extent.
    pub n: usize,
    /// Which K rows / N columns of the parent operand this shard holds.
    pub slice: ShardSlice,
    /// The pre-scaled weight slice (resident DRAM image of this shard).
    pub weight: Matrix,
    /// Packed encoding of `weight`, built once at plan time — rides the
    /// partial-GEMM job as a trusted pin exactly like the whole-model
    /// path's weight encodings.
    pub w_enc: Arc<EncodedOperand>,
    /// `Some` exactly when `slice` is an N-split: the shard-local
    /// round + fold stage. K-split slices must **not** carry one (the
    /// fold runs once, centrally, after the quire merge — a per-shard
    /// fold would double-apply the bias).
    pub tail: Option<LocalTail>,
}

/// One replica's view of a sharded [`CompiledModel`]: per-GEMM weight
/// slices plus warm state sized for partial-quire serving. Reuses the
/// compiled-model residency machinery — resident spans from
/// [`Soc::alloc_resident`], pinned operand-cache entries, opaque warm
/// state keyed by uid — so shard eviction and rollback behave exactly
/// like whole-model eviction.
#[derive(Debug)]
pub struct ShardedModel {
    /// Parent graph name (diagnostics).
    pub name: String,
    /// Uid of the [`CompiledModel`] this shard was planned from.
    pub model_uid: u64,
    /// This shard's position in the plan (`0..n_shards`).
    pub shard_idx: usize,
    /// Total shards in the plan this view belongs to.
    pub n_shards: usize,
    /// One slice per parent GEMM step, indexed by `gemm_idx`.
    pub steps: Vec<ShardStep>,
    /// Elements of A-slice scratch (max m·k over slices).
    a_len: usize,
    /// Quire-spill scratch slots (max m·n over slices).
    q_len: usize,
    /// This shard's own warm-state key.
    uid: u64,
}

/// Warm state of one shard on one replica.
struct ShardArena {
    w_addrs: Vec<u64>,
    a_addr: u64,
    q_addr: u64,
    allocs: Vec<(u64, u64)>,
}

/// One shard's partial result for one GEMM layer.
#[derive(Debug)]
pub enum PartialOut {
    /// K-split: full-width raw partial quires; the coordinator merges
    /// them exactly and rounds once.
    Quires(QuireMatrix),
    /// N-split: the shard-local tail already rounded + folded this
    /// disjoint f32 column block ([`LocalTail`]).
    Cols(Matrix),
}

/// How [`CompiledModel::run_sharded`] schedules one layer's shard jobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardFlow {
    /// Scatter the whole layer upfront, keep `overlap_cycles_hidden`
    /// at zero — the PR 4 schedule, kept as the differential oracle.
    Barrier,
    /// Windowed dispatch ([`SHARD_INFLIGHT_WINDOW`]) with arrival-order
    /// incremental merge and the simulated-overlap counter.
    /// Bit-identical to `Barrier` in values and in every report field
    /// except `overlap_cycles_hidden`.
    Streaming,
}

/// Cap on outstanding partial-GEMM dispatches per layer under
/// [`ShardFlow::Streaming`]: one fresh dispatch per drained completion
/// once the window fills. Keeps the serving queues' bounded-admission
/// back-pressure meaningful and lets `Router::quiesce` drain a known,
/// small set of in-flight jobs.
pub const SHARD_INFLIGHT_WINDOW: usize = 4;

/// The transport [`CompiledModel::run_sharded`] drives shard jobs
/// through. `dispatch` hands shard `shard_idx` its A slice for
/// `gemm_idx` (plus the layer's dynamic scale `s_a`, which the
/// shard-local tail folds); `wait_any` blocks for **whichever**
/// outstanding job completes next and returns its shard index with the
/// partial. The router implements this over the async serving runtime's
/// [`crate::serve::CompletionSet`]; tests implement it inline with
/// seeded arrival permutations.
pub trait ShardChannel {
    /// Hand shard `shard_idx` its sliced activation operand for GEMM
    /// step `gemm_idx` (`s_a` is the request's dynamic activation
    /// scale). Must not block on the job finishing.
    fn dispatch(&mut self, shard_idx: usize, gemm_idx: usize, a: Matrix, s_a: f64) -> Result<()>;
    /// Block until **any** outstanding dispatch completes; return its
    /// shard index, partial output and job report.
    fn wait_any(&mut self) -> Result<(usize, PartialOut, JobReport)>;
    /// Observability hook: called right after shard `shard_idx`'s
    /// K-split partial is merged into the layer's quires, with that
    /// shard's deterministic share of the layer reduction cost
    /// ([`merge_pass_cycles`]). Default is a no-op so transports that
    /// do not trace (inline test channels) need no code.
    fn on_merge(&mut self, _shard_idx: usize, _merge_cycles: u64) {}
}

/// Per-layer timing snapshot for the streaming-overlap model: each
/// shard's simulated job cycles for the layer, and the simulated cycle
/// at which the coordinator's incremental merge of the layer finished.
struct LayerTiming {
    cycles: Vec<u64>,
    finish: u64,
}

/// Simulated finish time of the incremental quire merge and the
/// straggler cycles it hides relative to the barrier schedule.
///
/// Model: shard completions land at their job-cycle times `t` (sorted —
/// the model is a function of the *costs*, never of the host-side
/// arrival order that actually occurred, so the counter is
/// deterministic). The reduction is split into one merge pass per
/// arriving partial; pass `p` costs
/// `(p·outs).div_ceil(4) − ((p−1)·outs).div_ceil(4)` cycles, so the
/// passes tile [`reduction_cost`]'s cycle term exactly. The barrier
/// schedule serializes the whole reduction after the last arrival
/// (`max(t) + rc`); streaming interleaves passes with waits
/// (`f = max(t_p, f) + c_p`), and the difference is the hidden time.
/// Zero when `rc == 0` (single shard, or an N-split layer with no
/// central reduction at all).
fn streamed_merge_timing(cycles: &[u64], outs: u64, rc: u64) -> (u64, u64) {
    let s = cycles.len();
    let mut t = cycles.to_vec();
    t.sort_unstable();
    let barrier_finish = t[s - 1] + rc;
    if rc == 0 {
        return (barrier_finish, 0);
    }
    let mut finish = t[0];
    for (p, &tp) in t.iter().enumerate().skip(1) {
        let c_p = (p as u64 * outs).div_ceil(4) - ((p as u64 - 1) * outs).div_ceil(4);
        finish = finish.max(tp) + c_p;
    }
    (finish, barrier_finish.saturating_sub(finish))
}

/// Simulated double-buffered weight-prefetch schedule for one streaming
/// layer transition: returns `(hidden, stall)` cycles.
///
/// Between finishing layer *i* and receiving layer *i+1*'s A slice, a
/// shard sits idle for `prev.finish − prev.cycles[si]` simulated cycles
/// (its own early finish against the coordinator's merge tail) plus
/// `v_coord` (the coordinator's vector-unit steps between the two
/// layers). The weight slice for layer *i+1* is already resident and
/// its identity is known before any request data, so during that window
/// the control FSM streams it into the staging half of the weight
/// ping-pong (an FSM-reserved slot, not capacity-gated — see the README
/// memory-hierarchy section). The stream is costed as a real [`AxiBus`]
/// burst read of the slice's packed image (`n · k.div_ceil(lanes) · 2`
/// bytes — the engine's fetch model), i.e. the bus's *idle* read
/// bandwidth, replacing the old `dma × w_bytes / bytes_in` proration
/// proxy. What the prefetch can usefully hide is capped by the shard's
/// actual layer-(i+1) DMA cycles (`want`): hiding more than the fetch
/// work that exists is meaningless. `hid = min(window, want)` comes off
/// that shard's completion time; the **hidden** total is the drop in
/// the layer's critical path `max(t)`, and the demand the window could
/// not absorb (`want − hid`, summed over shards) is the **stall** —
/// the exposed share of the streaming critical path, surfaced as
/// [`ExecReport::axi_stall_cycles`]. Every term is a function of the
/// simulated *costs*, never of host arrival order, so both counters
/// are deterministic (asserted by the arrival-order test below).
fn prefetch_overlap(
    shards: &[Arc<ShardedModel>],
    gemm_idx: usize,
    prev: &LayerTiming,
    v_coord: u64,
    cycles: &[u64],
    dma: &[u64],
) -> (u64, u64) {
    let before = cycles.iter().copied().max().unwrap_or(0);
    let bus = AxiBus::default();
    let mut after = 0u64;
    let mut stall = 0u64;
    for (si, sh) in shards.iter().enumerate() {
        let st = &sh.steps[gemm_idx];
        let w_bytes = st.n * st.k.div_ceil(st.sel.lanes()) * 2;
        let stream = bus.read_cycles(w_bytes);
        let window = prev.finish.saturating_sub(prev.cycles[si]) + v_coord;
        let want = stream.min(dma[si]);
        let hid = window.min(want);
        stall += want - hid;
        after = after.max(cycles[si].saturating_sub(hid));
    }
    (before.saturating_sub(after), stall)
}

/// Documented cross-shard reduction cost model for one **K-split** m×n
/// GEMM layer reduced from `n_shards` overlapping partials: every
/// shard's full-width partial-quire image moves to the reducer
/// (`n_shards · m·n ·` [`QUIRE_SPILL_BYTES`] bytes) and the merge runs
/// `(n_shards − 1) · m·n` exact quire adds through a 4-wide SIMD add
/// block (the paper's precision-adaptive ADD/SUB stage), 4 adds per
/// cycle. This is the term by which a sharded [`ExecReport`] exceeds
/// the sum of its shard job reports — zero adds when `n_shards == 1`.
/// N-split layers pay no quire traffic here: the shard-local tail
/// ([`LocalTail`]) rounds and folds on the replica, so no quire image
/// ever crosses to the coordinator — they charge the much cheaper f32
/// column-block gather instead ([`layer_reduction_cost`]).
pub fn reduction_cost(n_shards: usize, m: usize, n: usize) -> (u64, u64) {
    let outs = (m * n) as u64;
    let bytes = n_shards as u64 * outs * QUIRE_SPILL_BYTES as u64;
    let cycles = (n_shards.saturating_sub(1) as u64 * outs).div_ceil(4);
    (cycles, bytes)
}

/// Deterministic per-shard share of [`reduction_cost`]'s cycle term,
/// used to stamp trace merge spans ([`ShardChannel::on_merge`]): shard
/// `si` is charged merge pass `si` of [`streamed_merge_timing`]'s
/// tiling, so the shares sum to the layer's exact reduction cycles
/// (the sum telescopes to `(n_shards−1)·outs` div-ceil 4) and are a
/// function of the shard *index*, never of the host arrival order —
/// Barrier and Streaming runs stamp identical spans. Pass 0 (the first
/// merge into the zeroed quires) is free, matching the timing model.
pub fn merge_pass_cycles(si: usize, outs: u64) -> u64 {
    if si == 0 {
        0
    } else {
        (si as u64 * outs).div_ceil(4) - ((si as u64 - 1) * outs).div_ceil(4)
    }
}

/// Reduction term for one layer given how it was actually sliced
/// (every shard of a layer shares one slice kind, fixed by
/// [`plan_slices`]): K-split partials overlap the full output and pay
/// [`reduction_cost`]; N-split partials run the shard-local tail and
/// return rounded f32 column blocks — no quire image ever crosses, but
/// the blocks themselves are real traffic on the shared AXI channel:
/// each shard's `m·(n1−n0)` f32s (4 bytes apiece) are charged at the
/// bus's burst read cost. Per output element that is 4 bytes total
/// (blocks are disjoint) against a K-split's `n_shards ·`
/// [`QUIRE_SPILL_BYTES`] — the asymmetry the audit test pins.
/// (Activation traffic, like every path's, is charged by the per-job
/// DMA model, not here.)
fn layer_reduction_cost(shards: &[Arc<ShardedModel>], g: &GemmStep) -> (u64, u64) {
    match shards[0].steps[g.gemm_idx].slice {
        ShardSlice::K { .. } => reduction_cost(shards.len(), g.m, g.n),
        ShardSlice::N { .. } => {
            let slices: Vec<ShardSlice> =
                shards.iter().map(|sh| sh.steps[g.gemm_idx].slice).collect();
            gather_cost(&slices, g.m)
        }
    }
}

/// Documented cross-shard gather cost for one **N-split** m×n GEMM
/// layer: each shard's rounded f32 column block (`m·(n1−n0)·4` bytes)
/// crosses the shared AXI read channel at the default bus's burst cost
/// ([`AxiBus::read_cycles`]). K slices contribute nothing here. The
/// static verifier re-derives this literally from the bus parameters
/// (double-entry, like [`reduction_cost`]'s K formula).
pub fn gather_cost(slices: &[ShardSlice], m: usize) -> (u64, u64) {
    let bus = AxiBus::default();
    let mut cycles = 0u64;
    let mut bytes = 0u64;
    for s in slices {
        if let ShardSlice::N { n0, n1 } = *s {
            let block = m * (n1 - n0) * 4;
            cycles += bus.read_cycles(block);
            bytes += block as u64;
        }
    }
    (cycles, bytes)
}

/// Slice boundaries for one GEMM step. `None` = unsplittable.
fn plan_slices(k: usize, n: usize, n_shards: usize) -> Option<Vec<ShardSlice>> {
    if n_shards == 1 {
        return Some(vec![ShardSlice::K { k0: 0, k1: k }]);
    }
    if k >= SHARD_K_ALIGN * n_shards {
        // equal-ish K slices, boundaries snapped to the lane alignment;
        // the final shard absorbs the remainder (possibly unaligned —
        // only non-final boundaries need to land on whole words)
        let chunk = (k / n_shards) / SHARD_K_ALIGN * SHARD_K_ALIGN;
        Some(
            (0..n_shards)
                .map(|i| {
                    let k0 = i * chunk;
                    let k1 = if i == n_shards - 1 { k } else { k0 + chunk };
                    ShardSlice::K { k0, k1 }
                })
                .collect(),
        )
    } else if n >= n_shards {
        // N-split fallback: disjoint output column blocks, no cross-
        // shard accumulation (columns pack independently, so byte
        // accounting still sums exactly)
        let chunk = n / n_shards;
        Some(
            (0..n_shards)
                .map(|i| {
                    let n0 = i * chunk;
                    let n1 = if i == n_shards - 1 { n } else { n0 + chunk };
                    ShardSlice::N { n0, n1 }
                })
                .collect(),
        )
    } else {
        None
    }
}

/// The shard planner: split every GEMM step of `model` across
/// `n_shards` replica-sized views. K-splits by preference (weights and
/// A slices shrink together), N-split fallback for K too small to
/// split; a step too small for either is a typed plan-time error. Each
/// slice's weight is sliced from the **pre-scaled** compiled weight
/// image (the frozen `s_b` stays the whole-tensor scale) and encoded
/// exactly once here.
pub fn shard(model: &CompiledModel, n_shards: usize) -> Result<Vec<ShardedModel>, ShardError> {
    if n_shards == 0 {
        return Err(ShardError::ZeroShards { model: model.name.clone() });
    }
    let gemms = model.gemm_steps();
    let mut per_shard: Vec<Vec<ShardStep>> = (0..n_shards).map(|_| Vec::new()).collect();
    for g in &gemms {
        let slices = plan_slices(g.k, g.n, n_shards).ok_or_else(|| ShardError::Unsplittable {
            model: model.name.clone(),
            gemm_idx: g.gemm_idx,
            k: g.k,
            n: g.n,
            n_shards,
        })?;
        for (si, slice) in slices.into_iter().enumerate() {
            let (weight, ks, ns) = match slice {
                ShardSlice::K { k0, k1 } => (
                    Matrix::from_vec(k1 - k0, g.n, g.weight.data[k0 * g.n..k1 * g.n].to_vec()),
                    k1 - k0,
                    g.n,
                ),
                ShardSlice::N { n0, n1 } => (
                    Matrix::from_vec(
                        g.k,
                        n1 - n0,
                        (0..g.k).flat_map(|r| g.weight.row(r)[n0..n1].iter().copied()).collect(),
                    ),
                    g.k,
                    n1 - n0,
                ),
            };
            let w_enc = Arc::new(EncodedOperand::cols(&weight, g.sel));
            // N-split slices carry the shard-local tail: the bias block
            // for their columns plus the frozen whole-tensor weight
            // scale, so the replica can round + fold without the
            // coordinator
            let tail = match slice {
                ShardSlice::K { .. } => None,
                ShardSlice::N { n0, n1 } => {
                    Some(LocalTail { s_b: g.s_b, bias: g.bias[n0..n1].to_vec() })
                }
            };
            per_shard[si].push(ShardStep {
                gemm_idx: g.gemm_idx,
                sel: g.sel,
                m: g.m,
                k: ks,
                n: ns,
                slice,
                weight,
                w_enc,
                tail,
            });
        }
    }
    Ok(per_shard
        .into_iter()
        .enumerate()
        .map(|(shard_idx, steps)| {
            let a_len = steps.iter().map(|s| s.m * s.k).max().unwrap_or(0);
            let q_len = steps.iter().map(|s| s.m * s.n).max().unwrap_or(0);
            ShardedModel {
                name: model.name.clone(),
                model_uid: model.uid,
                shard_idx,
                n_shards,
                steps,
                a_len,
                q_len,
                uid: NEXT_UID.fetch_add(1, Ordering::Relaxed),
            }
        })
        .collect())
}

impl ShardedModel {
    /// Stable identity of this shard's warm state on a `Soc`.
    pub fn uid(&self) -> u64 {
        self.uid
    }

    /// Scratch extents `(a_len, q_len)` in elements/slots — the
    /// verifier re-derives the warm layout from these.
    pub(crate) fn scratch_lens(&self) -> (usize, usize) {
        (self.a_len, self.q_len)
    }

    /// Resident f32 weight-slice footprint in bytes.
    pub fn resident_bytes(&self) -> usize {
        self.steps.iter().map(|s| s.weight.data.len() * 4).sum()
    }

    /// Conservative warm footprint (weight slices + A-slice scratch +
    /// quire-spill scratch, 64-byte aligned) — the router's placement
    /// budget, mirror of [`CompiledModel::warm_footprint_bytes`].
    pub fn warm_footprint_bytes(&self) -> usize {
        self.steps
            .iter()
            .map(|s| s.weight.data.len() * 4)
            .chain([self.a_len * 4, self.q_len * QUIRE_SPILL_BYTES])
            .map(|b| b.next_multiple_of(64))
            .sum()
    }

    /// Warm this shard on `soc`: upload the weight slices as resident
    /// images, pin their encodings, allocate A/quire scratch. Idempotent
    /// per (shard, soc); failure rolls back exactly like whole-model
    /// warming.
    pub fn ensure_warm(&self, soc: &mut Soc) -> Result<(), SocError> {
        if soc.has_model_state(self.uid) {
            return Ok(());
        }
        let arena = self.warm_inner(soc)?;
        soc.put_model_state(self.uid, Box::new(arena));
        Ok(())
    }

    fn warm_inner(&self, soc: &mut Soc) -> Result<ShardArena, SocError> {
        let mut allocs: Vec<(u64, u64)> = Vec::with_capacity(self.steps.len() + 2);
        let mut w_addrs = Vec::with_capacity(self.steps.len());
        let fail = |me: &Self, soc: &mut Soc, pins: usize, allocs: &[(u64, u64)], e: SocError| {
            for st in me.steps.iter().take(pins) {
                soc.enc_cache.unpin_cols(&st.weight, st.sel);
            }
            for &(s, end) in allocs {
                soc.free_resident(s, end);
            }
            e
        };
        for (i, st) in self.steps.iter().enumerate() {
            let addr = match alloc_span(soc, st.weight.data.len() * 4, &mut allocs) {
                Ok(a) => a,
                Err(e) => return Err(fail(self, soc, i, &allocs, e)),
            };
            if let Err(e) = soc.ext.write_f32(addr, &st.weight.data) {
                return Err(fail(self, soc, i, &allocs, e));
            }
            soc.enc_cache.preload_cols(&st.weight, Arc::clone(&st.w_enc));
            w_addrs.push(addr);
        }
        let a_addr = match alloc_span(soc, self.a_len * 4, &mut allocs) {
            Ok(a) => a,
            Err(e) => return Err(fail(self, soc, self.steps.len(), &allocs, e)),
        };
        let q_addr = match alloc_span(soc, self.q_len * QUIRE_SPILL_BYTES, &mut allocs) {
            Ok(a) => a,
            Err(e) => return Err(fail(self, soc, self.steps.len(), &allocs, e)),
        };
        Ok(ShardArena { w_addrs, a_addr, q_addr, allocs })
    }

    /// Tear down this shard's warm state (mirror of
    /// [`CompiledModel::evict`]; a no-op on a SoC never warmed).
    pub fn evict(&self, soc: &mut Soc) {
        let Some(arena) =
            soc.take_model_state(self.uid).and_then(|b| b.downcast::<ShardArena>().ok())
        else {
            return;
        };
        for st in &self.steps {
            soc.enc_cache.unpin_cols(&st.weight, st.sel);
        }
        for &(s, e) in &arena.allocs {
            soc.free_resident(s, e);
        }
    }

    /// Run this shard's partial GEMM for step `gemm_idx` on `soc`
    /// (warming on demand): `a` is the coordinator-scaled A slice for
    /// this shard, `s_a` the dynamic activation scale the coordinator
    /// divided out (every shard of a layer receives the same value).
    /// K-split slices return raw partial quires for the central
    /// reduction; N-split slices run the [`LocalTail`] here — one
    /// rounding of this block's (already-complete) quires, then the
    /// element-wise scale/bias fold — and return an f32 column block.
    pub fn run_gemm(
        &self,
        soc: &mut Soc,
        gemm_idx: usize,
        a: &Matrix,
        s_a: f64,
    ) -> Result<(PartialOut, JobReport)> {
        self.ensure_warm(soc)?;
        // Only the addresses are needed — copy them out and restore the
        // warm state *before* any fallible/panicky work, so a contained
        // worker panic can never drop the arena (the sole record of the
        // resident spans and cache pins).
        let state = match soc.take_model_state(self.uid) {
            Some(s) => s,
            None => return Err(WarmStateError::Missing { model: self.name.clone() }.into()),
        };
        let addrs = state
            .downcast_ref::<ShardArena>()
            .map(|arena| (arena.w_addrs[gemm_idx], arena.a_addr, arena.q_addr));
        soc.put_model_state(self.uid, state);
        let Some((w_addr, a_addr, q_addr)) = addrs else {
            return Err(WarmStateError::Mismatch { model: self.name.clone() }.into());
        };
        let st = &self.steps[gemm_idx];
        debug_assert_eq!(st.gemm_idx, gemm_idx);
        let (quires, rep) =
            soc.gemm_partial(a, st.k, st.n, w_addr, &st.w_enc, a_addr, q_addr, st.sel)?;
        match &st.tail {
            None => Ok((PartialOut::Quires(quires), rep)),
            Some(tail) => {
                // the slice's quires are the block's *complete*
                // accumulation (full K), so this is the one rounding —
                // the same Fp32 round + fold expressions as the central
                // path, on this shard's disjoint columns
                let raw = Matrix::from_vec(st.m, st.n, quires.round_to(Precision::Fp32));
                let mut out = Matrix::zeros(st.m, st.n);
                exec::postprocess_fold(&raw, s_a, tail.s_b, &tail.bias, &mut out);
                Ok((PartialOut::Cols(out), rep))
            }
        }
    }

    /// Byte sizes of this shard's warm blocks (weight slices, then
    /// A-slice scratch, then quire-spill scratch) — mirror of
    /// [`CompiledModel::block_sizes`], feeding the same shared
    /// live-block/rebase helpers.
    fn block_sizes(&self) -> Vec<usize> {
        self.steps
            .iter()
            .map(|st| st.weight.data.len() * 4)
            .chain([self.a_len * 4, self.q_len * QUIRE_SPILL_BYTES])
            .collect()
    }

    /// Live resident blocks of this shard's warm arena
    /// ([`paired_blocks`] over [`ShardedModel::block_sizes`], exactly
    /// like [`CompiledModel::live_blocks_on`]).
    pub(crate) fn live_blocks_on(&self, soc: &Soc) -> Vec<(u64, usize)> {
        let Some(arena) =
            soc.model_state_ref(self.uid).and_then(|s| s.downcast_ref::<ShardArena>())
        else {
            return Vec::new();
        };
        paired_blocks(&arena.w_addrs, [arena.a_addr, arena.q_addr], &self.block_sizes())
    }

    /// Patch this shard's warm arena after compaction ([`rebase_blocks`],
    /// exactly like [`CompiledModel::rebase_on`]).
    pub(crate) fn rebase_on(&self, soc: &mut Soc, new_addrs: &[u64]) {
        let Some(mut state) = soc.take_model_state(self.uid) else { return };
        if let Some(arena) = state.downcast_mut::<ShardArena>() {
            let ShardArena { w_addrs, a_addr, q_addr, allocs } = arena;
            rebase_blocks(w_addrs, [a_addr, q_addr], allocs, new_addrs, &self.block_sizes());
        }
        soc.put_model_state(self.uid, state);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::exec::{im2col, Executor};
    use crate::models::{effnet, gaze, random_weights, ulvio};
    use crate::soc::SocConfig;
    use crate::util::io::Tensor;
    use crate::util::Rng;

    fn aux_len(g: &ModelGraph) -> usize {
        g.layers
            .iter()
            .find_map(|l| match l.kind {
                LayerKind::ConcatAux { n } => Some(n),
                _ => None,
            })
            .unwrap_or(0)
    }

    fn test_input(n: usize, phase: f32) -> Vec<f32> {
        (0..n).map(|i| ((i as f32) * 0.13 + phase).sin() * 0.5).collect()
    }

    /// Run both paths on fresh SoCs over several distinct requests and
    /// assert full bit-identity (values + every cycle/byte/engine stat).
    fn assert_diff_identical(g: &ModelGraph, seed: u64, plan: &PrecisionPlan) {
        let w = random_weights(g, seed);
        let compiled = compile(g, &w, plan).expect("compile");
        let ex = Executor::new(g, &w);
        let mut soc_i = Soc::new(SocConfig::default());
        let mut soc_c = Soc::new(SocConfig::default());
        let aux: Vec<f32> = test_input(aux_len(g), 0.7);
        for req in 0..3 {
            let input = test_input(g.input.numel(), req as f32);
            let (oi, ri) = ex.forward_interpret(&input, &aux, &mut soc_i, plan).unwrap();
            let (oc, rc) = compiled.replay(&mut soc_c, &input, &aux).unwrap();
            assert_eq!(oi, oc, "{} req {req}: values diverged", g.name);
            assert_eq!(ri, rc, "{} req {req}: reports diverged", g.name);
        }
        assert_eq!(soc_i.lifetime, soc_c.lifetime, "{}: lifetime stats diverged", g.name);
    }

    #[test]
    fn gather_map_reproduces_im2col() {
        let mut rng = Rng::new(31);
        for (c, h, w, k, stride, pad) in
            [(1, 4, 4, 3, 1, 1), (2, 6, 6, 3, 1, 1), (3, 8, 8, 3, 2, 1), (2, 5, 7, 1, 1, 0), (1, 6, 6, 5, 1, 2)]
        {
            let s = Shape { c, h, w };
            let input = Matrix::random(1, s.numel(), 1.0, &mut rng).data;
            let want = im2col(&input, s, k, stride, pad);
            let map = GatherMap::for_conv(s, k, stride, pad);
            let mut got = Matrix::zeros(0, 0);
            map.gather(&input, &mut got);
            assert_eq!(got, want, "c{c} {h}x{w} k{k} s{stride} p{pad}");
        }
    }

    #[test]
    fn compiled_matches_interpreted_gaze_all_modes() {
        let g = gaze::build();
        for (i, sel) in PrecSel::ALL.into_iter().enumerate() {
            let plan = PrecisionPlan::uniform(sel, &g.compute_layer_params());
            assert_diff_identical(&g, 40 + i as u64, &plan);
        }
    }

    #[test]
    fn compiled_matches_interpreted_vio_all_modes() {
        let g = ulvio::build();
        for (i, sel) in PrecSel::ALL.into_iter().enumerate() {
            let plan = PrecisionPlan::uniform(sel, &g.compute_layer_params());
            assert_diff_identical(&g, 50 + i as u64, &plan);
        }
    }

    #[test]
    fn compiled_matches_interpreted_classify_all_modes() {
        let g = effnet::build();
        for (i, sel) in PrecSel::ALL.into_iter().enumerate() {
            let plan = PrecisionPlan::uniform(sel, &g.compute_layer_params());
            assert_diff_identical(&g, 60 + i as u64, &plan);
        }
    }

    #[test]
    fn compiled_matches_interpreted_mixed_plan() {
        // a per-layer morph schedule cycling through every mode
        for (g, seed) in [(ulvio::build(), 70u64), (gaze::build(), 71), (effnet::build(), 72)] {
            let params = g.compute_layer_params();
            let mut plan = PrecisionPlan::uniform(PrecSel::Fp4x4, &params);
            for (i, sel) in plan.per_layer.iter_mut().enumerate() {
                *sel = PrecSel::ALL[i % PrecSel::ALL.len()];
            }
            assert_diff_identical(&g, seed, &plan);
        }
    }

    #[test]
    fn weights_encode_once_per_registration() {
        let g = gaze::build();
        let w = random_weights(&g, 80);
        let plan = PrecisionPlan::uniform(PrecSel::Posit8x2, &g.compute_layer_params());
        let compiled = compile(&g, &w, &plan).unwrap();
        let n = compiled.n_gemm();
        assert_eq!(n, 3, "gaze has 3 fc layers");
        let mut soc = Soc::new(SocConfig::default());
        compiled.ensure_warm(&mut soc).unwrap();
        // warming preloads — it never encodes through the cache
        assert_eq!(soc.enc_cache.preloads as usize, n);
        assert_eq!(soc.enc_cache.misses, 0);
        assert_eq!(soc.enc_cache.pinned_len(), n);
        // idempotent
        compiled.ensure_warm(&mut soc).unwrap();
        assert_eq!(soc.enc_cache.preloads as usize, n);
        let reqs = 4u64;
        for r in 0..reqs {
            let input = test_input(g.input.numel(), r as f32);
            compiled.replay(&mut soc, &input, &[]).unwrap();
        }
        // weights ride their trusted pins past the cache entirely; only
        // the per-request activation operands are encoded
        assert_eq!(soc.enc_cache.trusted, reqs * n as u64, "weights must ride trusted pins");
        assert_eq!(soc.enc_cache.hits, 0, "weights must never consult the cache");
        assert_eq!(soc.enc_cache.misses, reqs * n as u64, "one A-operand encode per gemm");
    }

    #[test]
    fn plan_length_mismatch_is_typed_error() {
        let g = gaze::build();
        let w = random_weights(&g, 81);
        let plan = PrecisionPlan::uniform(PrecSel::Fp4x4, &[1, 2]); // graph has 3
        let err = compile(&g, &w, &plan).unwrap_err();
        assert_eq!(
            err,
            CompileError::PlanLayerMismatch {
                model: g.name.clone(),
                plan_layers: 2,
                compute_layers: 3
            }
        );
    }

    #[test]
    fn missing_tensor_is_typed_error() {
        let g = gaze::build();
        let mut w = random_weights(&g, 82);
        let name = format!("{}.w", g.layers.iter().find(|l| l.kind.is_compute()).unwrap().name);
        w.remove(&name);
        let plan = PrecisionPlan::uniform(PrecSel::Fp4x4, &g.compute_layer_params());
        match compile(&g, &w, &plan).unwrap_err() {
            CompileError::MissingTensor { name: got, .. } => assert_eq!(got, name),
            other => panic!("unexpected error {other:?}"),
        }
    }

    #[test]
    fn bad_tensor_dims_is_typed_error() {
        let g = gaze::build();
        let mut w = random_weights(&g, 83);
        let name = format!("{}.w", g.layers.iter().find(|l| l.kind.is_compute()).unwrap().name);
        let t = w.get(&name).unwrap().clone();
        w.insert(name.clone(), Tensor::new(vec![t.data.len()], t.data.clone()));
        let plan = PrecisionPlan::uniform(PrecSel::Fp4x4, &g.compute_layer_params());
        match compile(&g, &w, &plan).unwrap_err() {
            CompileError::TensorShape { name: got, .. } => assert_eq!(got, name),
            other => panic!("unexpected error {other:?}"),
        }
    }

    #[test]
    fn executor_backend_npe_replays_compiled() {
        let g = gaze::build();
        let w = random_weights(&g, 84);
        let plan = PrecisionPlan::uniform(PrecSel::Posit16x1, &g.compute_layer_params());
        let compiled = compile(&g, &w, &plan).unwrap();
        let ex = Executor::new(&g, &w);
        let input = test_input(g.input.numel(), 0.2);
        let mut soc_c = Soc::new(SocConfig::default());
        let (out_c, _) = ex.forward_compiled(&input, &[], &mut soc_c, &compiled).unwrap();
        let mut soc_i = Soc::new(SocConfig::default());
        let (out_i, _) = ex.forward_interpret(&input, &[], &mut soc_i, &plan).unwrap();
        assert_eq!(out_c, out_i);
    }

    #[test]
    fn two_models_coexist_on_one_soc() {
        // multi-model residency smoke test: the bump allocator keeps the
        // two weight regions + scratch disjoint
        let gg = gaze::build();
        let wg = random_weights(&gg, 85);
        let pg = PrecisionPlan::uniform(PrecSel::Posit8x2, &gg.compute_layer_params());
        let cg = compile(&gg, &wg, &pg).unwrap();
        let ge = effnet::build();
        let we = random_weights(&ge, 86);
        let pe = PrecisionPlan::uniform(PrecSel::Fp4x4, &ge.compute_layer_params());
        let ce = compile(&ge, &we, &pe).unwrap();
        let mut soc = Soc::new(SocConfig::default());
        let in_g = test_input(gg.input.numel(), 0.1);
        let in_e = test_input(ge.input.numel(), 0.2);
        let (g1, _) = cg.replay(&mut soc, &in_g, &[]).unwrap();
        let (e1, _) = ce.replay(&mut soc, &in_e, &[]).unwrap();
        // interleave again: outputs must be stable (no clobbered weights)
        let (g2, _) = cg.replay(&mut soc, &in_g, &[]).unwrap();
        let (e2, _) = ce.replay(&mut soc, &in_e, &[]).unwrap();
        assert_eq!(g1, g2);
        assert_eq!(e1, e2);
    }

    #[test]
    fn evict_unpins_and_replay_rewarms() {
        let g = gaze::build();
        let w = random_weights(&g, 88);
        let plan = PrecisionPlan::uniform(PrecSel::Posit8x2, &g.compute_layer_params());
        let compiled = compile(&g, &w, &plan).unwrap();
        let mut soc = Soc::new(SocConfig::default());
        let input = test_input(g.input.numel(), 0.3);
        let (o1, _) = compiled.replay(&mut soc, &input, &[]).unwrap();
        assert_eq!(soc.enc_cache.pinned_len(), compiled.n_gemm());
        compiled.evict(&mut soc);
        assert_eq!(soc.enc_cache.pinned_len(), 0, "evict must unpin weight encodings");
        assert!(!soc.has_model_state(compiled.uid()));
        // replay after evict re-warms and still serves identical results
        let (o2, _) = compiled.replay(&mut soc, &input, &[]).unwrap();
        assert_eq!(o1, o2);
    }

    #[test]
    fn failed_warm_rolls_back_dram_and_pins() {
        let g = effnet::build();
        let w = random_weights(&g, 89);
        let plan = PrecisionPlan::uniform(PrecSel::Posit8x2, &g.compute_layer_params());
        let compiled = compile(&g, &w, &plan).unwrap();
        // 16 KiB DRAM: the first conv weight fits, the fc image does not
        let mut soc = Soc::new(SocConfig { dram_bytes: 1 << 14, ..Default::default() });
        let mark = soc.resident_mark();
        assert!(compiled.ensure_warm(&mut soc).is_err());
        assert_eq!(soc.resident_mark(), mark, "failed warm must roll back resident DRAM");
        assert_eq!(soc.resident_free_bytes(), 0, "failed warm must not strand free blocks");
        assert_eq!(soc.enc_cache.pinned_len(), 0, "failed warm must release its pins");
        assert!(!soc.has_model_state(compiled.uid()));
    }

    #[test]
    fn evicting_a_buried_model_reclaims_dram_via_free_list() {
        // gaze warms first (bottom of the stack), effnet on top of it:
        // evicting gaze cannot move the watermark, but its spans must
        // land on the free list and be reused by the next same-shape
        // model — the refresh-loop leak fixed in this PR
        let gg = gaze::build();
        let pg = PrecisionPlan::uniform(PrecSel::Posit8x2, &gg.compute_layer_params());
        let c1 = compile(&gg, &random_weights(&gg, 90), &pg).unwrap();
        let ge = effnet::build();
        let pe = PrecisionPlan::uniform(PrecSel::Fp4x4, &ge.compute_layer_params());
        let ce = compile(&ge, &random_weights(&ge, 91), &pe).unwrap();
        let mut soc = Soc::new(SocConfig::default());
        c1.ensure_warm(&mut soc).unwrap();
        ce.ensure_warm(&mut soc).unwrap();
        let peak = soc.resident_mark();
        c1.evict(&mut soc);
        assert_eq!(soc.resident_mark(), peak, "buried eviction cannot move the watermark");
        assert!(soc.resident_free_bytes() > 0, "buried spans must reach the free list");
        // a same-shape model slots into the freed region: watermark flat
        let c2 = compile(&gg, &random_weights(&gg, 92), &pg).unwrap();
        c2.ensure_warm(&mut soc).unwrap();
        assert_eq!(soc.resident_mark(), peak, "free-list reuse must keep the watermark flat");
        assert_eq!(soc.resident_free_bytes(), 0);
        // both resident models still serve correctly from reused DRAM
        let in_g = test_input(gg.input.numel(), 0.4);
        let in_e = test_input(ge.input.numel(), 0.5);
        let (g1, _) = c2.replay(&mut soc, &in_g, &[]).unwrap();
        let (e1, _) = ce.replay(&mut soc, &in_e, &[]).unwrap();
        let (g2, _) = c2.replay(&mut soc, &in_g, &[]).unwrap();
        let (e2, _) = ce.replay(&mut soc, &in_e, &[]).unwrap();
        assert_eq!(g1, g2);
        assert_eq!(e1, e2);
    }

    #[test]
    fn compaction_preserves_serving_bit_identically_all_modes() {
        // the live-compaction acceptance differential: induce
        // fragmentation (evict the middle of three resident models),
        // mark-compact the survivors, and assert both values and
        // ExecReports are unchanged after relocation — in all 4 modes
        use crate::models::graph::Layer;
        use crate::models::residency::{compact_resident, ResidentImage};
        for (mi, sel) in PrecSel::ALL.into_iter().enumerate() {
            let fc = |name: &str, k: usize, n: usize, seed: u64| {
                let g = ModelGraph {
                    name: name.into(),
                    input: Shape::vec(k),
                    layers: vec![Layer {
                        name: "fc".into(),
                        kind: LayerKind::Fc { in_f: k, out_f: n },
                    }],
                };
                let w = random_weights(&g, seed);
                let plan = PrecisionPlan::uniform(sel, &g.compute_layer_params());
                Arc::new(compile(&g, &w, &plan).unwrap())
            };
            let a = fc("a", 64, 32, 400 + mi as u64);
            let b = fc("b", 48, 40, 410 + mi as u64);
            let c = fc("c", 32, 24, 420 + mi as u64);
            let mut soc = Soc::new(SocConfig::default());
            for m in [&a, &b, &c] {
                m.ensure_warm(&mut soc).unwrap();
            }
            let xa = test_input(64, 0.1);
            let xc = test_input(32, 0.2);
            let (want_a, want_ra) = a.replay(&mut soc, &xa, &[]).unwrap();
            let (want_c, want_rc) = c.replay(&mut soc, &xc, &[]).unwrap();
            // fragment: the middle model leaves a buried hole
            b.evict(&mut soc);
            assert!(soc.resident_free_bytes() > 0, "{sel:?}: premise — fragmentation");
            let mark = soc.resident_mark();
            let live: Vec<Arc<dyn ResidentImage>> = vec![
                Arc::clone(&a) as Arc<dyn ResidentImage>,
                Arc::clone(&c) as Arc<dyn ResidentImage>,
            ];
            let new_top = compact_resident(&mut soc, &live).unwrap();
            assert!(new_top < mark, "{sel:?}: compaction must reclaim the hole");
            assert_eq!(soc.resident_free_bytes(), 0, "{sel:?}");
            let (got_a, got_ra) = a.replay(&mut soc, &xa, &[]).unwrap();
            let (got_c, got_rc) = c.replay(&mut soc, &xc, &[]).unwrap();
            assert_eq!(got_a, want_a, "{sel:?}: values diverged after relocation");
            assert_eq!(got_c, want_c, "{sel:?}: values diverged after relocation");
            assert_eq!(got_ra, want_ra, "{sel:?}: reports diverged after relocation");
            assert_eq!(got_rc, want_rc, "{sel:?}: reports diverged after relocation");
        }
    }

    #[test]
    fn shared_scratch_installs_once_per_replica_and_survives_eviction() {
        // the arena-reuse item: the ping-pong run scratch is replica-
        // wide — installed at the first replay, shared by every model,
        // and untouched by evictions (it holds no per-model state)
        let gg = gaze::build();
        let pg = PrecisionPlan::uniform(PrecSel::Posit8x2, &gg.compute_layer_params());
        let cg = compile(&gg, &random_weights(&gg, 95), &pg).unwrap();
        let ge = effnet::build();
        let pe = PrecisionPlan::uniform(PrecSel::Fp4x4, &ge.compute_layer_params());
        let ce = compile(&ge, &random_weights(&ge, 96), &pe).unwrap();
        let mut soc = Soc::new(SocConfig::default());
        assert!(!soc.has_scratch());
        let in_g = test_input(gg.input.numel(), 0.1);
        let in_e = test_input(ge.input.numel(), 0.2);
        let (g1, _) = cg.replay(&mut soc, &in_g, &[]).unwrap();
        assert!(soc.has_scratch(), "first replay installs the shared scratch");
        let (e1, _) = ce.replay(&mut soc, &in_e, &[]).unwrap();
        cg.evict(&mut soc);
        ce.evict(&mut soc);
        assert!(soc.has_scratch(), "eviction must not tear down the replica scratch");
        // re-warmed models serve bit-identically through the reused
        // (larger-than-needed for gaze) scratch
        let (g2, _) = cg.replay(&mut soc, &in_g, &[]).unwrap();
        let (e2, _) = ce.replay(&mut soc, &in_e, &[]).unwrap();
        assert_eq!(g1, g2);
        assert_eq!(e1, e2);
    }

    /// Synchronous in-test [`ShardChannel`]: `dispatch` runs the shard
    /// GEMM immediately, `wait_any` hands completions back FIFO — or,
    /// with an order RNG, in a seeded random permutation of whatever is
    /// outstanding, modelling stragglers finishing first / last /
    /// interleaved.
    struct InlineChannel<'a> {
        shards: &'a [Arc<ShardedModel>],
        socs: &'a mut [Soc],
        ready: Vec<(usize, PartialOut, JobReport)>,
        order: Option<Rng>,
    }

    impl ShardChannel for InlineChannel<'_> {
        fn dispatch(&mut self, si: usize, gi: usize, a: Matrix, s_a: f64) -> Result<()> {
            let (part, rep) = self.shards[si].run_gemm(&mut self.socs[si], gi, &a, s_a)?;
            self.ready.push((si, part, rep));
            Ok(())
        }

        fn wait_any(&mut self) -> Result<(usize, PartialOut, JobReport)> {
            if self.ready.is_empty() {
                bail!("wait_any with nothing in flight");
            }
            match &mut self.order {
                Some(rng) => {
                    let i = (rng.next_u64() as usize) % self.ready.len();
                    Ok(self.ready.swap_remove(i))
                }
                None => Ok(self.ready.remove(0)),
            }
        }
    }

    /// Drive `run_sharded` inline: shard `n_shards` ways, one fresh SoC
    /// per shard, synchronous dispatch, arrival order FIFO or seeded by
    /// `order_seed`. Returns outputs + report.
    fn run_sharded_inline_flow(
        compiled: &CompiledModel,
        n_shards: usize,
        socs: &mut [Soc],
        input: &[f32],
        aux: &[f32],
        flow: ShardFlow,
        order_seed: Option<u64>,
    ) -> (Vec<f32>, ExecReport) {
        let shards: Vec<Arc<ShardedModel>> =
            shard(compiled, n_shards).expect("plan").into_iter().map(Arc::new).collect();
        let mut ch = InlineChannel {
            shards: &shards,
            socs,
            ready: Vec::new(),
            order: order_seed.map(Rng::new),
        };
        compiled.run_sharded(&shards, input, aux, &mut ch, flow).expect("sharded run")
    }

    /// The default inline drive: streaming flow, FIFO arrivals.
    fn run_sharded_inline(
        compiled: &CompiledModel,
        n_shards: usize,
        socs: &mut [Soc],
        input: &[f32],
        aux: &[f32],
    ) -> (Vec<f32>, ExecReport) {
        run_sharded_inline_flow(compiled, n_shards, socs, input, aux, ShardFlow::Streaming, None)
    }

    /// [`ShardChannel`] adapter that records trace spans around any
    /// inner transport — the same wiring the router's runtime channel
    /// uses, reused here to differential-test the determinism contract.
    struct TracingChannel<C: ShardChannel> {
        inner: C,
        lanes: crate::obs::ShardLaneTracer,
    }

    impl<C: ShardChannel> ShardChannel for TracingChannel<C> {
        fn dispatch(&mut self, si: usize, gi: usize, a: Matrix, s_a: f64) -> Result<()> {
            self.inner.dispatch(si, gi, a, s_a)
        }

        fn wait_any(&mut self) -> Result<(usize, PartialOut, JobReport)> {
            let (si, part, rep) = self.inner.wait_any()?;
            self.lanes.on_partial(si, rep.total_cycles);
            Ok((si, part, rep))
        }

        fn on_merge(&mut self, si: usize, merge_cycles: u64) {
            self.lanes.on_merge(si, merge_cycles);
        }
    }

    #[test]
    fn barrier_and_streaming_traces_have_equal_event_multisets() {
        // the obs determinism contract: span stamps are functions of the
        // per-shard costs, so the dispatch flow (and a scrambled arrival
        // permutation) must not change the canonical event multiset
        use crate::obs::{canonical_multiset, ShardLaneTracer, TraceCtx, TraceSink};
        let g = ulvio::build();
        let w = random_weights(&g, 430);
        let plan = PrecisionPlan::uniform(PrecSel::Posit8x2, &g.compute_layer_params());
        let compiled = compile(&g, &w, &plan).unwrap();
        let input = test_input(g.input.numel(), 0.3);
        let aux = test_input(aux_len(&g), 0.7);
        let n_shards = 3;
        let shards: Vec<Arc<ShardedModel>> =
            shard(&compiled, n_shards).unwrap().into_iter().map(Arc::new).collect();
        let mut run = |flow: ShardFlow, order_seed: Option<u64>| {
            let sink = TraceSink::new(8192);
            let ctx = TraceCtx { sink: Arc::clone(&sink), id: sink.mint() };
            let mut socs: Vec<Soc> =
                (0..n_shards).map(|_| Soc::new(SocConfig::default())).collect();
            let inner = InlineChannel {
                shards: &shards,
                socs: &mut socs,
                ready: Vec::new(),
                order: order_seed.map(Rng::new),
            };
            let mut ch = TracingChannel {
                inner,
                lanes: ShardLaneTracer::new(ctx, (0..n_shards).collect()),
            };
            compiled.run_sharded(&shards, &input, &aux, &mut ch, flow).expect("sharded run");
            sink.records()
        };
        let barrier = run(ShardFlow::Barrier, None);
        let streaming = run(ShardFlow::Streaming, Some(0x5eed));
        assert!(!barrier.is_empty(), "sharded run must emit shard spans");
        let has_k_split =
            shards[0].steps.iter().any(|st| matches!(st.slice, ShardSlice::K { .. }));
        assert_eq!(
            barrier.iter().any(|r| matches!(r.event, crate::obs::TraceEvent::QuireMerge { .. })),
            has_k_split,
            "K-split layers (and only those) produce merge spans"
        );
        assert_eq!(
            canonical_multiset(&barrier),
            canonical_multiset(&streaming),
            "flows must trace the same canonical event multiset"
        );
    }

    #[test]
    fn shard_plan_rejects_zero_and_unsplittable() {
        let g = gaze::build();
        let w = random_weights(&g, 100);
        let plan = PrecisionPlan::uniform(PrecSel::Posit8x2, &g.compute_layer_params());
        let compiled = compile(&g, &w, &plan).unwrap();
        assert_eq!(
            shard(&compiled, 0).unwrap_err(),
            ShardError::ZeroShards { model: "gazenet".into() }
        );
        // 40 shards: fc3 (64×2) has K < 4·40 and N < 40 — rejected at
        // plan time, never mid-request
        match shard(&compiled, 40).unwrap_err() {
            ShardError::Unsplittable { n_shards: 40, .. } => {}
            other => panic!("unexpected error {other:?}"),
        }
    }

    #[test]
    fn shard_slices_cover_align_and_are_never_empty() {
        // K not divisible by the shard count, K exactly divisible, and
        // the N-split fallback — slices always cover the axis exactly,
        // are non-empty, and non-final K boundaries land on whole words
        for (k, n, shards) in [(22, 5, 2), (16, 64, 3), (64, 2, 4), (6, 9, 3), (12, 3, 3)] {
            let slices = plan_slices(k, n, shards).unwrap_or_else(|| panic!("{k}x{n}/{shards}"));
            assert_eq!(slices.len(), shards);
            match slices[0] {
                ShardSlice::K { .. } => {
                    let mut next = 0;
                    for (i, s) in slices.iter().enumerate() {
                        let ShardSlice::K { k0, k1 } = *s else { panic!("mixed slice kinds") };
                        assert_eq!(k0, next, "K slices must tile the axis");
                        assert!(k1 > k0, "empty K slice");
                        if i < shards - 1 {
                            assert_eq!((k1 - k0) % SHARD_K_ALIGN, 0, "unaligned non-final slice");
                        }
                        next = k1;
                    }
                    assert_eq!(next, k);
                }
                ShardSlice::N { .. } => {
                    assert!(k < SHARD_K_ALIGN * shards, "N-split only when K is too small");
                    let mut next = 0;
                    for s in &slices {
                        let ShardSlice::N { n0, n1 } = *s else { panic!("mixed slice kinds") };
                        assert_eq!(n0, next);
                        assert!(n1 > n0, "empty N slice");
                        next = n1;
                    }
                    assert_eq!(next, n);
                }
            }
        }
        assert!(plan_slices(7, 2, 3).is_none(), "too small in both axes");
    }

    #[test]
    fn single_shard_degenerate_matches_whole_values() {
        let g = gaze::build();
        let w = random_weights(&g, 101);
        let plan = PrecisionPlan::uniform(PrecSel::Posit8x2, &g.compute_layer_params());
        let compiled = compile(&g, &w, &plan).unwrap();
        let mut soc_w = Soc::new(SocConfig::default());
        let mut socs = vec![Soc::new(SocConfig::default())];
        let input = test_input(g.input.numel(), 0.3);
        let (want, wrep) = compiled.replay(&mut soc_w, &input, &[]).unwrap();
        let (got, grep) = run_sharded_inline(&compiled, 1, &mut socs, &input, &[]);
        assert_eq!(got, want, "single-shard degenerate must match the whole path");
        assert_eq!(grep.jobs.array.macs, wrep.jobs.array.macs);
        assert_eq!(grep.reduce_cycles, 0, "one shard has nothing to reduce");
    }

    #[test]
    fn sharded_matches_whole_bit_identically_all_modes() {
        // THE sharding acceptance differential: for every hardware mode
        // and 2- and 3-way shard plans, serving through scatter →
        // partial quires → exact merge → single round is bit-identical
        // in values to the whole-model replay; MAC work is conserved,
        // fetch traffic sums exactly (aligned K splits), and the report
        // carries exactly the documented reduction term.
        let g = gaze::build();
        for (mi, sel) in PrecSel::ALL.into_iter().enumerate() {
            let w = random_weights(&g, 110 + mi as u64);
            let plan = PrecisionPlan::uniform(sel, &g.compute_layer_params());
            let compiled = compile(&g, &w, &plan).unwrap();
            for n_shards in [2usize, 3] {
                let mut soc_w = Soc::new(SocConfig::default());
                let mut socs: Vec<Soc> =
                    (0..n_shards).map(|_| Soc::new(SocConfig::default())).collect();
                for req in 0..2 {
                    let input = test_input(g.input.numel(), req as f32 + mi as f32);
                    let (want, wrep) = compiled.replay(&mut soc_w, &input, &[]).unwrap();
                    let (got, grep) =
                        run_sharded_inline(&compiled, n_shards, &mut socs, &input, &[]);
                    assert_eq!(got, want, "{sel:?} x{n_shards} req {req}: values diverged");
                    assert_eq!(
                        grep.jobs.array.macs, wrep.jobs.array.macs,
                        "{sel:?} x{n_shards}: MAC work must be conserved"
                    );
                    assert_eq!(
                        grep.jobs.bytes_in, wrep.jobs.bytes_in,
                        "{sel:?} x{n_shards}: aligned K splits must sum fetch bytes exactly"
                    );
                    let (want_rc, want_rb) = compiled
                        .steps
                        .iter()
                        .filter_map(|s| {
                            if let Step::Gemm(g) = s {
                                Some(reduction_cost(n_shards, g.m, g.n))
                            } else {
                                None
                            }
                        })
                        .fold((0u64, 0u64), |(c, b), (rc, rb)| (c + rc, b + rb));
                    assert_eq!((grep.reduce_cycles, grep.reduce_bytes), (want_rc, want_rb));
                }
            }
        }
    }

    #[test]
    fn nsplit_fallback_matches_whole_and_charges_no_merge() {
        // a K too small to split 3 ways forces the N-split fallback:
        // values still bit-identical through the shard-local tail, and
        // the layer charges no quire-merge traffic — only the f32
        // column-block gather over the shared AXI channel
        use crate::models::graph::Layer;
        let g = ModelGraph {
            name: "tiny_fc".into(),
            input: Shape::vec(6),
            layers: vec![Layer {
                name: "fc".into(),
                kind: LayerKind::Fc { in_f: 6, out_f: 9 },
            }],
        };
        let w = random_weights(&g, 140);
        let plan = PrecisionPlan::uniform(PrecSel::Posit8x2, &g.compute_layer_params());
        let compiled = compile(&g, &w, &plan).unwrap();
        let shards = shard(&compiled, 3).unwrap();
        assert!(
            shards.iter().all(|s| matches!(s.steps[0].slice, ShardSlice::N { .. })),
            "k=6 < 4*3 must take the N-split fallback"
        );
        for s in &shards {
            let st = &s.steps[0];
            let ShardSlice::N { n0, n1 } = st.slice else { unreachable!() };
            let tail = st.tail.as_ref().expect("N slices must carry the local tail");
            assert_eq!(tail.bias.len(), n1 - n0, "tail bias must cover exactly this block");
        }
        let mut soc_w = Soc::new(SocConfig::default());
        let mut socs: Vec<Soc> = (0..3).map(|_| Soc::new(SocConfig::default())).collect();
        let input = test_input(6, 0.2);
        let (want, _) = compiled.replay(&mut soc_w, &input, &[]).unwrap();
        let (got, grep) = run_sharded_inline(&compiled, 3, &mut socs, &input, &[]);
        assert_eq!(got, want, "N-split sharded run diverged");
        // expected gather charge: three disjoint 1×3 f32 column blocks
        // (m=1, n=9 split 3/3/3), each a burst read on the shared bus
        let bus = AxiBus::default();
        let block = 3 * 4; // m·(n1−n0)·4 bytes
        assert_eq!(
            (grep.reduce_cycles, grep.reduce_bytes),
            (3 * bus.read_cycles(block), 3 * block as u64),
            "N-split gather must charge each shard's f32 column block over the AXI model"
        );
    }

    #[test]
    fn sharded_matches_whole_conv_and_mixed_plans() {
        // conv workloads (im2col gather at the coordinator) and a mixed
        // per-layer morph schedule shard just as exactly
        for (g, seed) in [(effnet::build(), 120u64), (ulvio::build(), 121)] {
            let params = g.compute_layer_params();
            let mut plan = PrecisionPlan::uniform(PrecSel::Fp4x4, &params);
            for (i, sel) in plan.per_layer.iter_mut().enumerate() {
                *sel = PrecSel::ALL[i % PrecSel::ALL.len()];
            }
            let w = random_weights(&g, seed);
            let compiled = compile(&g, &w, &plan).unwrap();
            let aux: Vec<f32> = test_input(aux_len(&g), 0.7);
            let mut soc_w = Soc::new(SocConfig::default());
            let mut socs = vec![Soc::new(SocConfig::default()), Soc::new(SocConfig::default())];
            let input = test_input(g.input.numel(), 0.4);
            let (want, _) = compiled.replay(&mut soc_w, &input, &aux).unwrap();
            let (got, _) = run_sharded_inline(&compiled, 2, &mut socs, &input, &aux);
            assert_eq!(got, want, "{}: sharded conv/mixed run diverged", g.name);
        }
    }

    #[test]
    fn streaming_matches_barrier_bit_identically_all_modes() {
        // THE streaming acceptance differential: for every hardware mode
        // and 2- and 3-way plans, the streaming flow (windowed dispatch,
        // arrival-order incremental merge, overlap accounting) is
        // bit-identical to the barrier flow in values AND in the whole
        // ExecReport modulo the overlap counter — which is zero under
        // the barrier and strictly positive under streaming (merge tail
        // + weight prefetch both hide real simulated cycles on gaze)
        let g = gaze::build();
        for (mi, sel) in PrecSel::ALL.into_iter().enumerate() {
            let w = random_weights(&g, 150 + mi as u64);
            let plan = PrecisionPlan::uniform(sel, &g.compute_layer_params());
            let compiled = compile(&g, &w, &plan).unwrap();
            for n_shards in [2usize, 3] {
                let mut socs_b: Vec<Soc> =
                    (0..n_shards).map(|_| Soc::new(SocConfig::default())).collect();
                let mut socs_s: Vec<Soc> =
                    (0..n_shards).map(|_| Soc::new(SocConfig::default())).collect();
                let input = test_input(g.input.numel(), 0.5 + mi as f32);
                let (want, brep) = run_sharded_inline_flow(
                    &compiled,
                    n_shards,
                    &mut socs_b,
                    &input,
                    &[],
                    ShardFlow::Barrier,
                    None,
                );
                let (got, srep) = run_sharded_inline_flow(
                    &compiled,
                    n_shards,
                    &mut socs_s,
                    &input,
                    &[],
                    ShardFlow::Streaming,
                    None,
                );
                assert_eq!(got, want, "{sel:?} x{n_shards}: streaming values diverged");
                assert_eq!(brep.overlap_cycles_hidden, 0, "barrier must hide nothing");
                assert!(
                    srep.overlap_cycles_hidden > 0,
                    "{sel:?} x{n_shards}: streaming must hide simulated cycles"
                );
                assert!(
                    srep.overlap_cycles_hidden < srep.total_cycles(),
                    "{sel:?} x{n_shards}: hidden time must stay below the barrier schedule"
                );
                let mut scrubbed = srep.clone();
                scrubbed.overlap_cycles_hidden = 0;
                scrubbed.axi_stall_cycles = 0;
                scrubbed.prefetch_hidden_cycles = 0;
                assert_eq!(
                    scrubbed, brep,
                    "{sel:?} x{n_shards}: reports diverged beyond the overlap counters"
                );
            }
        }
    }

    #[test]
    fn streaming_matches_barrier_conv_and_mixed_plans() {
        // conv workloads (im2col gather at the coordinator) and a mixed
        // per-layer morph schedule stream just as exactly
        for (g, seed) in [(effnet::build(), 160u64), (ulvio::build(), 161)] {
            let params = g.compute_layer_params();
            let mut plan = PrecisionPlan::uniform(PrecSel::Fp4x4, &params);
            for (i, sel) in plan.per_layer.iter_mut().enumerate() {
                *sel = PrecSel::ALL[i % PrecSel::ALL.len()];
            }
            let w = random_weights(&g, seed);
            let compiled = compile(&g, &w, &plan).unwrap();
            let aux: Vec<f32> = test_input(aux_len(&g), 0.7);
            let input = test_input(g.input.numel(), 0.4);
            let mut socs_b = vec![Soc::new(SocConfig::default()), Soc::new(SocConfig::default())];
            let mut socs_s = vec![Soc::new(SocConfig::default()), Soc::new(SocConfig::default())];
            let (want, brep) = run_sharded_inline_flow(
                &compiled,
                2,
                &mut socs_b,
                &input,
                &aux,
                ShardFlow::Barrier,
                None,
            );
            let (got, srep) = run_sharded_inline_flow(
                &compiled,
                2,
                &mut socs_s,
                &input,
                &aux,
                ShardFlow::Streaming,
                None,
            );
            assert_eq!(got, want, "{}: streaming conv/mixed run diverged", g.name);
            let mut scrubbed = srep.clone();
            scrubbed.overlap_cycles_hidden = 0;
            scrubbed.axi_stall_cycles = 0;
            scrubbed.prefetch_hidden_cycles = 0;
            assert_eq!(scrubbed, brep, "{}: reports diverged beyond the counters", g.name);
        }
    }

    #[test]
    fn streaming_is_arrival_order_independent() {
        // seeded permutations of shard completion arrival (stragglers
        // first, last, interleaved — whatever the seeds produce) must
        // leave outputs AND the full report, overlap counter included,
        // bit-identical: the merge is exact and the overlap model is a
        // function of the simulated costs, not of host arrival order
        let g = gaze::build();
        let w = random_weights(&g, 170);
        let plan = PrecisionPlan::uniform(PrecSel::Posit8x2, &g.compute_layer_params());
        let compiled = compile(&g, &w, &plan).unwrap();
        let input = test_input(g.input.numel(), 0.6);
        let mut base: Option<(Vec<f32>, ExecReport)> = None;
        for seed in [None, Some(1u64), Some(2), Some(3)] {
            let mut socs: Vec<Soc> = (0..3).map(|_| Soc::new(SocConfig::default())).collect();
            let got = run_sharded_inline_flow(
                &compiled,
                3,
                &mut socs,
                &input,
                &[],
                ShardFlow::Streaming,
                seed,
            );
            match &base {
                None => base = Some(got),
                Some((want, wrep)) => {
                    assert_eq!(&got.0, want, "seed {seed:?}: values depend on arrival order");
                    assert_eq!(&got.1, wrep, "seed {seed:?}: report depends on arrival order");
                }
            }
        }
    }

    #[test]
    fn oversized_model_serves_from_shards_none_could_host_whole() {
        // the capacity win sharding exists for: a model whose resident
        // image exceeds one replica's DRAM budget registers and serves
        // across 2 shards, bit-identical to a big-DRAM whole-model run
        let g = crate::models::mlp::build();
        let w = random_weights(&g, 130);
        let plan = PrecisionPlan::uniform(PrecSel::Posit8x2, &g.compute_layer_params());
        let compiled = compile(&g, &w, &plan).unwrap();
        let small = SocConfig { dram_bytes: 1 << 17, ..Default::default() };
        // the whole model does not fit a small replica...
        let mut probe = Soc::new(small);
        assert!(
            compiled.ensure_warm(&mut probe).is_err(),
            "test premise: whole model must exceed one small replica"
        );
        // ...but each half-shard does
        let mut socs = vec![Soc::new(small), Soc::new(small)];
        let mut soc_big = Soc::new(SocConfig::default());
        for req in 0..2 {
            let input = test_input(g.input.numel(), req as f32);
            let (want, _) = compiled.replay(&mut soc_big, &input, &[]).unwrap();
            let (got, _) = run_sharded_inline(&compiled, 2, &mut socs, &input, &[]);
            assert_eq!(got, want, "req {req}: oversized sharded serving diverged");
        }
    }

    #[test]
    fn shard_evict_releases_pins_and_dram() {
        let g = gaze::build();
        let w = random_weights(&g, 131);
        let plan = PrecisionPlan::uniform(PrecSel::Posit8x2, &g.compute_layer_params());
        let compiled = compile(&g, &w, &plan).unwrap();
        let shards = shard(&compiled, 2).unwrap();
        assert!(
            shards.iter().flat_map(|s| &s.steps).all(|st| st.tail.is_none()),
            "K slices must never carry a local tail (the fold runs once, centrally)"
        );
        let mut soc = Soc::new(SocConfig::default());
        let mark = soc.resident_mark();
        shards[0].ensure_warm(&mut soc).unwrap();
        assert_eq!(soc.enc_cache.pinned_len(), compiled.n_gemm());
        shards[0].evict(&mut soc);
        assert_eq!(soc.enc_cache.pinned_len(), 0, "shard evict must unpin");
        assert_eq!(soc.resident_mark(), mark, "shard evict must return its DRAM");
        assert_eq!(soc.resident_free_bytes(), 0);
    }

    #[test]
    fn replay_rejects_bad_input_and_aux_lengths() {
        let g = ulvio::build();
        let w = random_weights(&g, 87);
        let plan = PrecisionPlan::uniform(PrecSel::Posit8x2, &g.compute_layer_params());
        let compiled = compile(&g, &w, &plan).unwrap();
        let mut soc = Soc::new(SocConfig::default());
        assert!(compiled.replay(&mut soc, &[0.0; 3], &[]).is_err());
        let input = test_input(g.input.numel(), 0.0);
        let bad_aux = vec![0.0; aux_len(&g) + 1];
        assert!(compiled.replay(&mut soc, &input, &bad_aux).is_err());
    }

    #[test]
    fn streaming_stall_and_hidden_stay_within_totals() {
        // conservation invariants of the overlap model, all modes and
        // shard counts: the barrier flow exposes no stall, and under
        // streaming the hidden + stalled cycles can never exceed the
        // job work they are carved from (per shard hid ≤ want ≤ dma ≤
        // job cycles, so both counters are bounded by the layer totals)
        let g = gaze::build();
        for (mi, sel) in PrecSel::ALL.into_iter().enumerate() {
            let w = random_weights(&g, 600 + mi as u64);
            let plan = PrecisionPlan::uniform(sel, &g.compute_layer_params());
            let compiled = compile(&g, &w, &plan).unwrap();
            let input = test_input(g.input.numel(), 0.3 + mi as f32);
            for n_shards in [2usize, 3] {
                let mut socs_b: Vec<Soc> =
                    (0..n_shards).map(|_| Soc::new(SocConfig::default())).collect();
                let mut socs_s: Vec<Soc> =
                    (0..n_shards).map(|_| Soc::new(SocConfig::default())).collect();
                let (_, brep) = run_sharded_inline_flow(
                    &compiled,
                    n_shards,
                    &mut socs_b,
                    &input,
                    &[],
                    ShardFlow::Barrier,
                    None,
                );
                let (_, srep) = run_sharded_inline_flow(
                    &compiled,
                    n_shards,
                    &mut socs_s,
                    &input,
                    &[],
                    ShardFlow::Streaming,
                    None,
                );
                assert_eq!(
                    brep.axi_stall_cycles, 0,
                    "{sel:?} x{n_shards}: the barrier flow exposes no stall"
                );
                assert!(
                    srep.axi_stall_cycles + srep.overlap_cycles_hidden <= srep.total_cycles(),
                    "{sel:?} x{n_shards}: stall + hidden must stay within the total"
                );
                assert!(
                    srep.prefetch_hidden_cycles <= srep.overlap_cycles_hidden,
                    "{sel:?} x{n_shards}: the prefetch share cannot exceed the hidden total"
                );
                // flows agree on the total, so hidden > 0 (asserted by
                // the bit-identity differential) makes the streaming
                // critical path strictly shorter than the barrier one
                assert!(
                    srep.total_cycles() - srep.overlap_cycles_hidden < brep.total_cycles(),
                    "{sel:?} x{n_shards}: prefetch must shorten the critical path"
                );
            }
        }
    }

    #[test]
    fn streaming_moves_identical_bytes_to_barrier() {
        // the prefetch schedule re-times weight traffic, it never adds
        // or removes bytes: job and reduction byte totals are identical
        // with overlap on (Streaming) and off (Barrier), for a K-split
        // plan (gaze) and the N-split fallback (tiny fc)
        use crate::models::graph::Layer;
        let tiny = ModelGraph {
            name: "tiny_fc".into(),
            input: Shape::vec(6),
            layers: vec![Layer { name: "fc".into(), kind: LayerKind::Fc { in_f: 6, out_f: 9 } }],
        };
        for (g, n_shards, seed) in [(gaze::build(), 3usize, 630u64), (tiny, 3, 631)] {
            let w = random_weights(&g, seed);
            let plan = PrecisionPlan::uniform(PrecSel::Posit8x2, &g.compute_layer_params());
            let compiled = compile(&g, &w, &plan).unwrap();
            let input = test_input(g.input.numel(), 0.2);
            let mut socs_b: Vec<Soc> =
                (0..n_shards).map(|_| Soc::new(SocConfig::default())).collect();
            let mut socs_s: Vec<Soc> =
                (0..n_shards).map(|_| Soc::new(SocConfig::default())).collect();
            let (_, brep) = run_sharded_inline_flow(
                &compiled,
                n_shards,
                &mut socs_b,
                &input,
                &[],
                ShardFlow::Barrier,
                None,
            );
            let (_, srep) = run_sharded_inline_flow(
                &compiled,
                n_shards,
                &mut socs_s,
                &input,
                &[],
                ShardFlow::Streaming,
                None,
            );
            assert_eq!(srep.jobs, brep.jobs, "{}: job work/bytes must be conserved", g.name);
            assert_eq!(
                srep.reduce_bytes, brep.reduce_bytes,
                "{}: reduction bytes must be conserved",
                g.name
            );
        }
    }

    #[test]
    fn shard_axi_accounting_telescopes_under_seeded_arrivals() {
        // the shared-channel property referenced from `soc/axi.rs`:
        // every AXI mutation goes through per-initiator attribution, so
        // the per-initiator sums equal the shared totals on every shard
        // SoC — under seeded arrival permutations, and with management
        // traffic (a compaction-style move) mixed onto one bus
        use crate::soc::AxiInitiator;
        let g = gaze::build();
        let w = random_weights(&g, 610);
        let plan = PrecisionPlan::uniform(PrecSel::Posit8x2, &g.compute_layer_params());
        let compiled = compile(&g, &w, &plan).unwrap();
        let input = test_input(g.input.numel(), 0.4);
        for seed in [None, Some(7u64), Some(8), Some(9)] {
            let mut socs: Vec<Soc> = (0..3).map(|_| Soc::new(SocConfig::default())).collect();
            let _ = run_sharded_inline_flow(
                &compiled,
                3,
                &mut socs,
                &input,
                &[],
                ShardFlow::Streaming,
                seed,
            );
            socs[0].move_resident(0, 0, 256).unwrap();
            for (si, soc) in socs.iter().enumerate() {
                let s = &soc.bus.stats;
                let sum_r: u64 = s.initiators.iter().map(|i| i.bytes_read).sum();
                let sum_w: u64 = s.initiators.iter().map(|i| i.bytes_written).sum();
                let sum_c: u64 = s.initiators.iter().map(|i| i.cycles).sum();
                assert_eq!(
                    (sum_r, sum_w, sum_c),
                    (s.bytes_read, s.bytes_written, s.cycles),
                    "seed {seed:?} shard {si}: initiator accounting must telescope"
                );
                assert!(
                    s.of(AxiInitiator::FsmFetch).bytes_read > 0,
                    "seed {seed:?} shard {si}: FSM weight fetch must be attributed"
                );
            }
            let mgmt = socs[0].management_traffic();
            assert!(mgmt.bytes_read == 256 && mgmt.bytes_written == 256 && mgmt.cycles > 0);
        }
    }

    #[test]
    fn reduction_traffic_audit_nsplit_f32_vs_ksplit_quire() {
        // the split-asymmetry audit: the same logical 1×9 output costs
        // 4 bytes per element to gather under an N split (one rounded
        // f32; blocks are disjoint) but n_shards · 17 bytes per element
        // of full quire images under a K split — the asymmetry the
        // planner and the residency benches must weigh
        use crate::models::graph::Layer;
        let fc = |k: usize| ModelGraph {
            name: "audit".into(),
            input: Shape::vec(k),
            layers: vec![Layer { name: "fc".into(), kind: LayerKind::Fc { in_f: k, out_f: 9 } }],
        };
        let n_shards = 3usize;
        let run = |k: usize, seed: u64| {
            let g = fc(k);
            let w = random_weights(&g, seed);
            let plan = PrecisionPlan::uniform(PrecSel::Posit8x2, &g.compute_layer_params());
            let compiled = compile(&g, &w, &plan).unwrap();
            let mut socs: Vec<Soc> =
                (0..n_shards).map(|_| Soc::new(SocConfig::default())).collect();
            let (_, rep) =
                run_sharded_inline(&compiled, n_shards, &mut socs, &test_input(k, 0.1), &[]);
            rep
        };
        // k = 24 ≥ SHARD_K_ALIGN·3 → K split; k = 6 forces the fallback
        let rep_k = run(24, 620);
        let rep_n = run(6, 621);
        let outs = 9u64; // m = 1
        assert_eq!(rep_k.reduce_bytes, n_shards as u64 * outs * QUIRE_SPILL_BYTES as u64);
        assert_eq!(rep_n.reduce_bytes, outs * 4);
        // cross-product form of the per-element ratio 4 : n_shards·17
        assert_eq!(
            rep_n.reduce_bytes * n_shards as u64 * QUIRE_SPILL_BYTES as u64,
            rep_k.reduce_bytes * 4
        );
    }
}
