//! XR perception workload models: a small layer-graph IR, builders for
//! the three paper workloads, and a bit-accurate executor that lowers
//! every layer to GEMMs on the simulated co-processor.
//!
//! * [`graph`] — the IR: conv / depthwise / fc / pool / activation /
//!   concat, with shape, parameter and MAC accounting.
//! * [`compile`] — the lowering pass: graph + weights + plan →
//!   [`compile::CompiledModel`] (weights scaled/encoded once, im2col as
//!   a precomputed gather, ping-pong activation arena) — the serving
//!   path replays this program per request.
//! * [`exec`] — forward execution: f32 reference path, compiled replay
//!   ([`exec::Backend::Npe`]) and the per-request interpreted lowering
//!   kept as the differential-testing reference
//!   ([`exec::Backend::NpeInterpret`]).
//! * [`residency`] — the DRAM-budgeted model catalog: every compiled /
//!   shard arena is an evictable [`residency::ResidentImage`] tracked by
//!   a per-replica [`residency::ResidencyManager`] (pluggable LRU
//!   eviction, pin-aware, live compaction) so a replica rotates a large
//!   catalog instead of growing resident memory monotonically.
//! * [`verify`] — tier-1 static verification: [`verify::verify_program`]
//!   proves a compiled program's resident layout, gather bounds and
//!   activation chain are safe before any DRAM write; the router calls
//!   it on every registration path.
//! * [`effnet`] / [`gaze`] / [`ulvio`] — the EfficientNet-style
//!   classifier, the eye-gaze regressor and the UL-VIO-lite odometry
//!   net. Weight layouts match `python/compile/model.py` exactly
//!   (documented per builder).

pub mod compile;
#[allow(missing_docs)]
pub mod effnet;
#[allow(missing_docs)]
pub mod exec;
#[allow(missing_docs)]
pub mod gaze;
#[allow(missing_docs)]
pub mod graph;
#[allow(missing_docs)]
pub mod mlp;
pub mod residency;
#[allow(missing_docs)]
pub mod ulvio;
pub mod verify;

pub use compile::{
    compile, merge_pass_cycles, reduction_cost, shard, CompileError, CompiledModel, GatherMap,
    LocalTail, PartialOut, ShardChannel, ShardError, ShardFlow, ShardSlice, ShardStep,
    ShardedModel, WarmStateError, SHARD_INFLIGHT_WINDOW,
};
pub use exec::{Backend, ExecReport, Executor};
pub use graph::{ActKind, Layer, LayerKind, ModelGraph, PoolKind};
pub use residency::{
    compact_resident, residency_lock, AdmitOutcome, Candidate, EvictionPolicy, LruPolicy,
    ResidencyError, ResidencyManager, ResidencyStats, ResidentImage,
};
pub use verify::{verify_ladder, verify_program, verify_shard_plan, ProgramProof, VerifyError};

/// He-initialized random weight map for a graph (bias zero, PACT α = 4)
/// — the one init shared by CLI demos, benches and tests that exercise
/// the stack without trained artifacts. Kept in the library so a new
/// `LayerKind` has exactly one place to grow a weight layout.
pub fn random_weights(graph: &ModelGraph, seed: u64) -> crate::util::io::TensorMap {
    use crate::util::io::Tensor;
    let mut rng = crate::util::Rng::new(seed);
    let mut m = crate::util::io::TensorMap::new();
    for layer in &graph.layers {
        match &layer.kind {
            LayerKind::Conv2d { in_c, out_c, k, .. } => {
                let n = in_c * out_c * k * k;
                let mut w = vec![0f32; n];
                rng.fill_normal(&mut w, (2.0 / (in_c * k * k) as f64).sqrt());
                m.insert(format!("{}.w", layer.name), Tensor::new(vec![*k, *k, *in_c, *out_c], w));
                m.insert(format!("{}.b", layer.name), Tensor::new(vec![*out_c], vec![0.0; *out_c]));
            }
            LayerKind::Fc { in_f, out_f } => {
                let mut w = vec![0f32; in_f * out_f];
                rng.fill_normal(&mut w, (2.0 / *in_f as f64).sqrt());
                m.insert(format!("{}.w", layer.name), Tensor::new(vec![*in_f, *out_f], w));
                m.insert(format!("{}.b", layer.name), Tensor::new(vec![*out_f], vec![0.0; *out_f]));
            }
            LayerKind::Act(ActKind::Pact) => {
                m.insert(format!("{}.alpha", layer.name), Tensor::new(vec![1], vec![4.0]));
            }
            _ => {}
        }
    }
    m
}
