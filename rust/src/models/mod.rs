//! XR perception workload models: a small layer-graph IR, builders for
//! the three paper workloads, and a bit-accurate executor that lowers
//! every layer to GEMMs on the simulated co-processor.
//!
//! * [`graph`] — the IR: conv / depthwise / fc / pool / activation /
//!   concat, with shape, parameter and MAC accounting.
//! * [`exec`] — forward execution: f32 reference path and the NPE path
//!   (im2col → `soc::Soc::gemm` per layer under a
//!   [`crate::quant::PrecisionPlan`], activations quantized per layer).
//! * [`effnet`] / [`gaze`] / [`ulvio`] — the EfficientNet-style
//!   classifier, the eye-gaze regressor and the UL-VIO-lite odometry
//!   net. Weight layouts match `python/compile/model.py` exactly
//!   (documented per builder).

pub mod effnet;
pub mod exec;
pub mod gaze;
pub mod graph;
pub mod mlp;
pub mod ulvio;

pub use exec::{ExecReport, Executor};
pub use graph::{ActKind, Layer, LayerKind, ModelGraph, PoolKind};
