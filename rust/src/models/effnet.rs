//! EffNet-XR — the object-classification workload (paper Fig. 5 / 8,
//! Table IV's EfficientNet row), scaled to the synthetic shapes-10
//! dataset (16×16 grayscale, 10 classes).
//!
//! Architecture (compound-scaled conv stack in the EfficientNet spirit —
//! stem → stages → head):
//!
//! ```text
//! conv1 1→8  3×3 s1 p1 · PACT · maxpool2      (16×16 → 8×8)
//! conv2 8→16 3×3 s1 p1 · PACT · maxpool2      (8×8 → 4×4)
//! conv3 16→32 3×3 s1 p1 · PACT · maxpool2     (4×4 → 2×2)
//! fc1 128→64 · PACT
//! fc2 64→10
//! ```
//!
//! Weight names match `python/compile/model.py::effnet_params`.

use super::graph::{ActKind, Layer, LayerKind, ModelGraph, PoolKind, Shape};

/// Number of classes in shapes-10.
pub const NUM_CLASSES: usize = 10;

/// Input shape.
pub const INPUT: Shape = Shape { c: 1, h: 16, w: 16 };

/// Build the graph.
pub fn build() -> ModelGraph {
    let l = |name: &str, kind: LayerKind| Layer { name: name.into(), kind };
    ModelGraph {
        name: "effnet_xr".into(),
        input: INPUT,
        layers: vec![
            l("conv1", LayerKind::Conv2d { in_c: 1, out_c: 8, k: 3, stride: 1, pad: 1 }),
            l("act1", LayerKind::Act(ActKind::Pact)),
            l("pool1", LayerKind::Pool { kind: PoolKind::Max, size: 2 }),
            l("conv2", LayerKind::Conv2d { in_c: 8, out_c: 16, k: 3, stride: 1, pad: 1 }),
            l("act2", LayerKind::Act(ActKind::Pact)),
            l("pool2", LayerKind::Pool { kind: PoolKind::Max, size: 2 }),
            l("conv3", LayerKind::Conv2d { in_c: 16, out_c: 32, k: 3, stride: 1, pad: 1 }),
            l("act3", LayerKind::Act(ActKind::Pact)),
            l("pool3", LayerKind::Pool { kind: PoolKind::Max, size: 2 }),
            l("flat", LayerKind::Flatten),
            l("fc1", LayerKind::Fc { in_f: 128, out_f: 64 }),
            l("act4", LayerKind::Act(ActKind::Pact)),
            l("fc2", LayerKind::Fc { in_f: 64, out_f: NUM_CLASSES }),
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_check_out() {
        let g = build();
        assert_eq!(g.out_shape(), Shape::vec(10));
        // 5 compute layers
        assert_eq!(g.compute_layers().len(), 5);
    }

    #[test]
    fn parameter_count_reasonable() {
        let g = build();
        let p = g.total_params();
        assert!((10_000..30_000).contains(&p), "params {p}");
    }

    #[test]
    fn macs_per_inference() {
        let g = build();
        let m = g.total_macs();
        assert!((100_000..400_000).contains(&m), "macs {m}");
    }
}
