//! Tier-1 static verification: prove a compiled program is safe to
//! warm and replay **before** any DRAM write happens.
//!
//! Five PRs of growth pushed more and more load-bearing invariants into
//! address arithmetic — bump-allocated resident spans, shared scratch
//! sized to the widest layer, K-split shard slices that must tile K
//! exactly once on lane-aligned boundaries, a reduction-cost term the
//! bench gate ratchets on. Until now those invariants were enforced
//! only dynamically (differential tests catch a corruption *after* it
//! corrupted something). [`verify_program`] and [`verify_shard_plan`]
//! re-derive every one of them from the immutable
//! [`CompiledModel`] / [`ShardedModel`] alone and return a typed
//! [`VerifyError`] naming the first violated invariant, so the router
//! can reject an illegal program at registration time with zero side
//! effects on any replica.
//!
//! What is checked (mirroring, independently, what `warm_inner` /
//! `run` / `run_sharded` will do at runtime):
//!
//! * **Plan agreement** — one GEMM step per plan entry, in order, each
//!   step's engine mode equal to the plan's and a native hardware mode
//!   ([`PrecSel::for_precision`] round-trips), output precision equal
//!   to the plan's layer precision.
//! * **Resident layout** — the warm-time bump layout is simulated at
//!   base 0 (weight images in step order, then A-operand scratch, then
//!   result scratch, every span 64-aligned): spans must be disjoint,
//!   every runtime GEMM's operand/result must fit its scratch span
//!   (`m·k ≤ a_len`, `m·n ≤ c_len` — an undersized span means the job
//!   would write past its allocation into the next image), and the
//!   simulated total must equal [`CompiledModel::warm_footprint_bytes`]
//!   — the number the router's DRAM budget and the
//!   [`ResidencyManager`](super::residency::ResidencyManager) account.
//! * **Staging headroom** — the footprint must fit under the SoC's
//!   [`resident_limit`](crate::soc::Soc::resident_limit) (the top
//!   quarter of DRAM is the control FSM's packed-operand staging
//!   region; a program that could only warm by intruding into it is
//!   rejected here instead of failing mid-registration).
//! * **Gather/activation dataflow** — the activation chain is walked
//!   exactly as `run` walks it: every gather-map index must land inside
//!   the live extent of the ping-pong buffer (or be the zero-pad
//!   sentinel), every step's declared input length must equal the
//!   previous step's output, nothing may exceed `buf_len`, and the
//!   final extent must be the declared `output_len`.
//! * **Shard plans** — every shard must agree on identity (parent uid,
//!   shard count, one slice per parent GEMM), each layer's slices must
//!   share one kind, K-splits must tile `0..k` exactly once with every
//!   interior boundary on a [`SHARD_K_ALIGN`] multiple, N-splits must
//!   tile `0..n` exactly once, slice dims must match their weight
//!   slices, every N-slice must carry a shard-local fold tail
//!   ([`LocalTail`](super::compile::LocalTail)) agreeing bit-for-bit
//!   with the parent fold (sliced bias, frozen `s_b`) while K-slices
//!   must carry none (the fold runs once, centrally, after the quire
//!   merge), the cross-shard [`reduction_cost`] must match the
//!   documented formula, and each shard's own layout/footprint/staging
//!   obeys the same rules as a whole model.
//!
//! The checks are pure (no `Soc`, no allocation on any device), so the
//! router calls them on every `register`/`register_shards` path and
//! `replay` re-asserts them in debug builds on first warm.

use super::compile::{
    gather_cost, reduction_cost, CompiledModel, GatherMap, GemmStep, ShardSlice, ShardedModel,
    Step, SHARD_K_ALIGN,
};
use crate::arith::{Precision, QUIRE_SPILL_BYTES};
use crate::npe::PrecSel;
use crate::soc::AxiBus;
use std::borrow::Borrow;
use std::fmt;

/// Typed verification failures. Every variant names the model and the
/// first violated invariant with enough detail to locate the defect.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum VerifyError {
    /// GEMM step count/order disagrees with the precision plan.
    PlanShape { model: String, detail: String },
    /// A step's engine mode or output precision disagrees with the
    /// plan, or is not a native hardware mode.
    PrecSelMismatch { model: String, gemm_idx: usize, detail: String },
    /// A resident weight image's element count disagrees with its
    /// declared K×N dims.
    WeightShape { model: String, gemm_idx: usize, got: usize, want: usize },
    /// A runtime write would not fit inside its resident span — the
    /// job would bleed into the next image.
    SpanOverlap { model: String, what: &'static str, gemm_idx: usize, need: usize, have: usize },
    /// The simulated warm layout disagrees with the footprint the
    /// residency budget accounts.
    FootprintMismatch { model: String, simulated: u64, accounted: u64 },
    /// The warm footprint cannot fit under the FSM staging boundary.
    StagingIntrusion { model: String, footprint: u64, limit: u64 },
    /// A gather map's patch-matrix dims disagree with the GEMM's M×K.
    GatherShape { model: String, gemm_idx: usize, got: (usize, usize), want: (usize, usize) },
    /// A gather-map index reads past the live activation extent.
    GatherOutOfBounds { model: String, gemm_idx: usize, slot: usize, index: u32, extent: usize },
    /// An activation write would exceed the ping-pong buffer.
    ActivationOverrun { model: String, step_idx: usize, need: usize, have: usize },
    /// A step's declared input extent disagrees with the previous
    /// step's output (or the final extent with `output_len`).
    ChainMismatch { model: String, step_idx: usize, got: usize, want: usize },
    /// Shard-set identity defect: wrong count, order, parent uid, or
    /// per-shard step list.
    ShardSetShape { model: String, detail: String },
    /// An interior K-split boundary is not lane-aligned.
    KSplitMisaligned { model: String, gemm_idx: usize, shard_idx: usize, boundary: usize },
    /// K slices do not tile `0..k` exactly once (gap or overlap).
    KSplitCoverage { model: String, gemm_idx: usize, detail: String },
    /// N slices do not tile `0..n` exactly once.
    NSplitCoverage { model: String, gemm_idx: usize, detail: String },
    /// A shard slice's dims/weight disagree with its declared range.
    SliceShape { model: String, gemm_idx: usize, shard_idx: usize, detail: String },
    /// A shard-local fold tail is missing from an N-slice, grafted onto
    /// a K-slice, or disagrees with the parent layer's fold.
    TailMismatch { model: String, gemm_idx: usize, shard_idx: usize, detail: String },
    /// [`reduction_cost`] (K quire merge) or [`gather_cost`] (N f32
    /// column-block gather) drifted from its documented formula.
    ReductionCostMismatch { model: String, gemm_idx: usize, got: (u64, u64), want: (u64, u64) },
    /// A precision-ladder rung set is malformed: empty, mis-tagged rung
    /// indices, rungs lowering different models, or plan fidelity not
    /// non-increasing down the ladder.
    LadderShape { model: String, detail: String },
}

impl fmt::Display for VerifyError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            VerifyError::PlanShape { model, detail } => {
                write!(f, "`{model}`: program/plan shape mismatch: {detail}")
            }
            VerifyError::PrecSelMismatch { model, gemm_idx, detail } => {
                write!(f, "`{model}` gemm {gemm_idx}: precision-mode mismatch: {detail}")
            }
            VerifyError::WeightShape { model, gemm_idx, got, want } => write!(
                f,
                "`{model}` gemm {gemm_idx}: weight image has {got} elements, dims say {want}"
            ),
            VerifyError::SpanOverlap { model, what, gemm_idx, need, have } => write!(
                f,
                "`{model}` gemm {gemm_idx}: {what} needs {need} elements but the resident \
                 span holds {have} — the job would overwrite the next image"
            ),
            VerifyError::FootprintMismatch { model, simulated, accounted } => write!(
                f,
                "`{model}`: simulated warm layout is {simulated} B but the residency \
                 accounting says {accounted} B"
            ),
            VerifyError::StagingIntrusion { model, footprint, limit } => write!(
                f,
                "`{model}`: warm footprint {footprint} B exceeds the resident limit \
                 {limit} B (would intrude into the FSM staging quarter)"
            ),
            VerifyError::GatherShape { model, gemm_idx, got, want } => write!(
                f,
                "`{model}` gemm {gemm_idx}: gather map is {}x{}, GEMM wants {}x{}",
                got.0, got.1, want.0, want.1
            ),
            VerifyError::GatherOutOfBounds { model, gemm_idx, slot, index, extent } => write!(
                f,
                "`{model}` gemm {gemm_idx}: gather slot {slot} reads index {index} but \
                 only {extent} activation elements are live"
            ),
            VerifyError::ActivationOverrun { model, step_idx, need, have } => write!(
                f,
                "`{model}` step {step_idx}: writes {need} activation elements into a \
                 {have}-element ping-pong buffer"
            ),
            VerifyError::ChainMismatch { model, step_idx, got, want } => write!(
                f,
                "`{model}` step {step_idx}: expects {want} input elements but the \
                 previous step leaves {got}"
            ),
            VerifyError::ShardSetShape { model, detail } => {
                write!(f, "`{model}`: malformed shard set: {detail}")
            }
            VerifyError::KSplitMisaligned { model, gemm_idx, shard_idx, boundary } => write!(
                f,
                "`{model}` gemm {gemm_idx} shard {shard_idx}: K boundary {boundary} is \
                 not a multiple of {SHARD_K_ALIGN}"
            ),
            VerifyError::KSplitCoverage { model, gemm_idx, detail } => {
                write!(f, "`{model}` gemm {gemm_idx}: K slices do not tile K: {detail}")
            }
            VerifyError::NSplitCoverage { model, gemm_idx, detail } => {
                write!(f, "`{model}` gemm {gemm_idx}: N slices do not tile N: {detail}")
            }
            VerifyError::SliceShape { model, gemm_idx, shard_idx, detail } => {
                write!(f, "`{model}` gemm {gemm_idx} shard {shard_idx}: {detail}")
            }
            VerifyError::TailMismatch { model, gemm_idx, shard_idx, detail } => {
                write!(f, "`{model}` gemm {gemm_idx} shard {shard_idx}: fold-tail defect: {detail}")
            }
            VerifyError::ReductionCostMismatch { model, gemm_idx, got, want } => write!(
                f,
                "`{model}` gemm {gemm_idx}: reduction_cost returned {got:?}, documented \
                 formula says {want:?}"
            ),
            VerifyError::LadderShape { model, detail } => {
                write!(f, "`{model}`: malformed precision ladder: {detail}")
            }
        }
    }
}

impl std::error::Error for VerifyError {}

/// The positive result of verification: the statically derived facts a
/// caller may rely on (and that tests cross-check against the runtime).
#[derive(Debug, Clone)]
pub struct ProgramProof {
    /// Model (or shard parent) name.
    pub model: String,
    /// Uid whose warm state these spans describe.
    pub uid: u64,
    /// Simulated warm spans at base 0, in `warm_inner` order
    /// (`(start, end)` byte ranges, 64-aligned starts, disjoint).
    pub spans: Vec<(u64, u64)>,
    /// Total 64-aligned footprint — equal to `warm_footprint_bytes()`.
    pub footprint_bytes: u64,
    /// Widest live activation extent along the chain (elements).
    pub peak_activation: usize,
    /// Number of GEMM steps covered by the proof.
    pub n_gemm: usize,
}

/// Append one simulated span to a base-0 bump layout (the same
/// 64-alignment rule as [`crate::soc::Soc::alloc_resident`]).
fn bump(cursor: &mut u64, bytes: usize, spans: &mut Vec<(u64, u64)>) {
    let start = cursor.next_multiple_of(64);
    let end = start + bytes as u64;
    spans.push((start, end));
    *cursor = end;
}

/// Shared tail of whole-model and per-shard layout checks: spans are
/// already simulated; confirm the total agrees with the residency
/// accounting and fits under the staging boundary.
fn check_layout_totals(
    model: &str,
    cursor: u64,
    accounted: u64,
    resident_limit: u64,
) -> Result<u64, VerifyError> {
    let simulated = cursor.next_multiple_of(64);
    if simulated != accounted {
        return Err(VerifyError::FootprintMismatch {
            model: model.to_string(),
            simulated,
            accounted,
        });
    }
    if simulated > resident_limit {
        return Err(VerifyError::StagingIntrusion {
            model: model.to_string(),
            footprint: simulated,
            limit: resident_limit,
        });
    }
    Ok(simulated)
}

/// Statically verify a compiled program against every invariant its
/// warm/replay path relies on. `resident_limit` is the target fleet's
/// [`crate::soc::Soc::resident_limit`] (every replica of a fleet shares
/// one `SocConfig`, so one bound covers all).
pub fn verify_program(
    model: &CompiledModel,
    resident_limit: u64,
) -> Result<ProgramProof, VerifyError> {
    let gemms: Vec<&GemmStep> = model
        .steps
        .iter()
        .filter_map(|s| if let Step::Gemm(g) = s { Some(&**g) } else { None })
        .collect();

    // --- plan agreement -------------------------------------------------
    if gemms.len() != model.plan.per_layer.len() {
        return Err(VerifyError::PlanShape {
            model: model.name.clone(),
            detail: format!(
                "{} gemm steps, plan has {} layers",
                gemms.len(),
                model.plan.per_layer.len()
            ),
        });
    }
    for (i, g) in gemms.iter().enumerate() {
        if g.gemm_idx != i {
            return Err(VerifyError::PlanShape {
                model: model.name.clone(),
                detail: format!("step {i} carries gemm_idx {}", g.gemm_idx),
            });
        }
        let planned = model.plan.per_layer[i];
        if g.sel != planned {
            return Err(VerifyError::PrecSelMismatch {
                model: model.name.clone(),
                gemm_idx: i,
                detail: format!("step mode {:?}, plan says {:?}", g.sel, planned),
            });
        }
        // engine-mode legality: the mode must round-trip through the
        // native-precision table (guards enum drift), and the output
        // precision must be the plan's layer precision or raw f32
        if PrecSel::for_precision(g.sel.precision()) != Some(g.sel) {
            return Err(VerifyError::PrecSelMismatch {
                model: model.name.clone(),
                gemm_idx: i,
                detail: format!("{:?} is not a native engine mode", g.sel),
            });
        }
        let want_out = model.plan.layer_precision(i);
        if g.out_prec != want_out && g.out_prec != Precision::Fp32 {
            return Err(VerifyError::PrecSelMismatch {
                model: model.name.clone(),
                gemm_idx: i,
                detail: format!("output precision {:?}, plan says {:?}", g.out_prec, want_out),
            });
        }
    }

    // --- resident layout ------------------------------------------------
    let mut spans = Vec::with_capacity(gemms.len() + 2);
    let mut cursor = 0u64;
    for g in &gemms {
        let want = g.k * g.n;
        if g.weight.data.len() != want {
            return Err(VerifyError::WeightShape {
                model: model.name.clone(),
                gemm_idx: g.gemm_idx,
                got: g.weight.data.len(),
                want,
            });
        }
        bump(&mut cursor, want * 4, &mut spans);
    }
    for g in &gemms {
        if g.m * g.k > model.a_len {
            return Err(VerifyError::SpanOverlap {
                model: model.name.clone(),
                what: "A-operand scratch",
                gemm_idx: g.gemm_idx,
                need: g.m * g.k,
                have: model.a_len,
            });
        }
        if g.m * g.n > model.c_len {
            return Err(VerifyError::SpanOverlap {
                model: model.name.clone(),
                what: "result scratch",
                gemm_idx: g.gemm_idx,
                need: g.m * g.n,
                have: model.c_len,
            });
        }
    }
    bump(&mut cursor, model.a_len * 4, &mut spans);
    bump(&mut cursor, model.c_len * 4, &mut spans);
    let footprint = check_layout_totals(
        &model.name,
        cursor,
        model.warm_footprint_bytes() as u64,
        resident_limit,
    )?;

    // --- activation dataflow (the chain `run` will walk) ----------------
    let chain_err = |step_idx: usize, got: usize, want: usize| VerifyError::ChainMismatch {
        model: model.name.clone(),
        step_idx,
        got,
        want,
    };
    let overrun = |step_idx: usize, need: usize| VerifyError::ActivationOverrun {
        model: model.name.clone(),
        step_idx,
        need,
        have: model.buf_len,
    };
    let mut cur_len = model.input_len;
    if cur_len > model.buf_len {
        return Err(overrun(0, cur_len));
    }
    let mut peak = cur_len;
    for (si, step) in model.steps.iter().enumerate() {
        match step {
            Step::Gemm(g) => {
                match &g.gather {
                    Some(map) => {
                        if map.rows != g.m || map.cols != g.k {
                            return Err(VerifyError::GatherShape {
                                model: model.name.clone(),
                                gemm_idx: g.gemm_idx,
                                got: (map.rows, map.cols),
                                want: (g.m, g.k),
                            });
                        }
                        for (slot, &ix) in map.indices().iter().enumerate() {
                            if ix != GatherMap::PAD && ix as usize >= cur_len {
                                return Err(VerifyError::GatherOutOfBounds {
                                    model: model.name.clone(),
                                    gemm_idx: g.gemm_idx,
                                    slot,
                                    index: ix,
                                    extent: cur_len,
                                });
                            }
                        }
                    }
                    // fc: the live vector is the 1×K operand directly
                    None => {
                        if g.m != 1 || g.k != cur_len {
                            return Err(chain_err(si, cur_len, g.k));
                        }
                    }
                }
                let out_len = match g.conv_out {
                    Some(sh) => {
                        if g.m != sh.h * sh.w || g.n != sh.c {
                            return Err(chain_err(si, g.m * g.n, sh.numel()));
                        }
                        sh.numel()
                    }
                    None => g.n,
                };
                if out_len > model.buf_len {
                    return Err(overrun(si, out_len));
                }
                cur_len = out_len;
            }
            Step::Pool { in_shape, out_len, .. } => {
                if in_shape.numel() != cur_len {
                    return Err(chain_err(si, cur_len, in_shape.numel()));
                }
                if *out_len > model.buf_len {
                    return Err(overrun(si, *out_len));
                }
                cur_len = *out_len;
            }
            Step::Act { len, .. } => {
                if *len != cur_len {
                    return Err(chain_err(si, cur_len, *len));
                }
            }
            Step::ConcatAux { n } => {
                if cur_len + n > model.buf_len {
                    return Err(overrun(si, cur_len + n));
                }
                cur_len += n;
            }
        }
        peak = peak.max(cur_len);
    }
    if cur_len != model.output_len {
        return Err(chain_err(model.steps.len(), cur_len, model.output_len));
    }

    Ok(ProgramProof {
        model: model.name.clone(),
        uid: model.uid(),
        spans,
        footprint_bytes: footprint,
        peak_activation: peak,
        n_gemm: gemms.len(),
    })
}

/// Statically verify a shard plan against its parent program: identity,
/// slice coverage/alignment, reduction-cost agreement, and each shard's
/// own resident layout. Accepts both `&[ShardedModel]` and
/// `&[Arc<ShardedModel>]` (the router holds shards behind `Arc`).
pub fn verify_shard_plan<S: Borrow<ShardedModel>>(
    model: &CompiledModel,
    shards: &[S],
    resident_limit: u64,
) -> Result<Vec<ProgramProof>, VerifyError> {
    let set_err = |detail: String| VerifyError::ShardSetShape {
        model: model.name.clone(),
        detail,
    };
    if shards.is_empty() {
        return Err(set_err("zero shards".into()));
    }
    let gemms: Vec<&GemmStep> = model
        .steps
        .iter()
        .filter_map(|s| if let Step::Gemm(g) = s { Some(&**g) } else { None })
        .collect();
    for (si, sh) in shards.iter().enumerate() {
        let sh = sh.borrow();
        if sh.model_uid != model.uid() {
            return Err(set_err(format!(
                "shard {si} was planned from uid {}, model is uid {}",
                sh.model_uid,
                model.uid()
            )));
        }
        if sh.n_shards != shards.len() || sh.shard_idx != si {
            return Err(set_err(format!(
                "shard at position {si} says shard {}/{} (set has {})",
                sh.shard_idx,
                sh.n_shards,
                shards.len()
            )));
        }
        if sh.steps.len() != gemms.len() {
            return Err(set_err(format!(
                "shard {si} has {} slices, model has {} gemm steps",
                sh.steps.len(),
                gemms.len()
            )));
        }
        for (i, st) in sh.steps.iter().enumerate() {
            if st.gemm_idx != i {
                return Err(set_err(format!(
                    "shard {si} slice {i} carries gemm_idx {}",
                    st.gemm_idx
                )));
            }
        }
    }

    // --- per-layer slice coverage ---------------------------------------
    for (i, g) in gemms.iter().enumerate() {
        let slices: Vec<ShardSlice> =
            shards.iter().map(|sh| sh.borrow().steps[i].slice).collect();
        let all_k = slices.iter().all(|s| matches!(s, ShardSlice::K { .. }));
        let all_n = slices.iter().all(|s| matches!(s, ShardSlice::N { .. }));
        if !all_k && !all_n {
            return Err(set_err(format!("gemm {i} mixes K- and N-split slices")));
        }
        if all_k {
            // boundary legality first (a misaligned boundary is the root
            // defect even when it also breaks contiguity), then exact
            // single coverage of 0..k in ascending order
            let mut ranges: Vec<(usize, usize, usize)> = slices
                .iter()
                .enumerate()
                .map(|(si, s)| match *s {
                    ShardSlice::K { k0, k1 } => (k0, k1, si),
                    ShardSlice::N { .. } => (0, 0, si), // unreachable: all_k
                })
                .collect();
            ranges.sort_by_key(|&(k0, _, _)| k0);
            for &(k0, k1, si) in &ranges {
                for b in [k0, k1] {
                    if b != 0 && b != g.k && b % SHARD_K_ALIGN != 0 {
                        return Err(VerifyError::KSplitMisaligned {
                            model: model.name.clone(),
                            gemm_idx: i,
                            shard_idx: si,
                            boundary: b,
                        });
                    }
                }
                if k1 <= k0 || k1 > g.k {
                    return Err(VerifyError::KSplitCoverage {
                        model: model.name.clone(),
                        gemm_idx: i,
                        detail: format!("shard {si} holds degenerate range {k0}..{k1} of K={}", g.k),
                    });
                }
            }
            let cov_err = |detail: String| VerifyError::KSplitCoverage {
                model: model.name.clone(),
                gemm_idx: i,
                detail,
            };
            let mut expect = 0usize;
            for &(k0, k1, si) in &ranges {
                if k0 > expect {
                    return Err(cov_err(format!("gap {expect}..{k0} before shard {si}")));
                }
                if k0 < expect {
                    return Err(cov_err(format!(
                        "shard {si} range {k0}..{k1} overlaps {k0}..{expect}"
                    )));
                }
                expect = k1;
            }
            if expect != g.k {
                return Err(cov_err(format!("slices end at {expect}, K is {}", g.k)));
            }
        } else {
            let mut ranges: Vec<(usize, usize, usize)> = slices
                .iter()
                .enumerate()
                .map(|(si, s)| match *s {
                    ShardSlice::N { n0, n1 } => (n0, n1, si),
                    ShardSlice::K { .. } => (0, 0, si), // unreachable: all_n
                })
                .collect();
            ranges.sort_by_key(|&(n0, _, _)| n0);
            let cov_err = |detail: String| VerifyError::NSplitCoverage {
                model: model.name.clone(),
                gemm_idx: i,
                detail,
            };
            let mut expect = 0usize;
            for &(n0, n1, si) in &ranges {
                if n1 <= n0 || n1 > g.n {
                    return Err(cov_err(format!(
                        "shard {si} holds degenerate range {n0}..{n1} of N={}",
                        g.n
                    )));
                }
                if n0 != expect {
                    return Err(cov_err(format!(
                        "shard {si} starts at {n0}, coverage reached {expect}"
                    )));
                }
                expect = n1;
            }
            if expect != g.n {
                return Err(cov_err(format!("slices end at {expect}, N is {}", g.n)));
            }
        }

        // --- per-slice dims/weight --------------------------------------
        for (si, sh) in shards.iter().enumerate() {
            let st = &sh.borrow().steps[i];
            let slice_err = |detail: String| VerifyError::SliceShape {
                model: model.name.clone(),
                gemm_idx: i,
                shard_idx: si,
                detail,
            };
            if st.sel != g.sel {
                return Err(slice_err(format!(
                    "slice mode {:?}, parent gemm is {:?}",
                    st.sel, g.sel
                )));
            }
            if st.m != g.m {
                return Err(slice_err(format!("slice M {}, parent gemm M {}", st.m, g.m)));
            }
            let (want_k, want_n) = match st.slice {
                ShardSlice::K { k0, k1 } => (k1 - k0, g.n),
                ShardSlice::N { n0, n1 } => (g.k, n1 - n0),
            };
            if st.k != want_k || st.n != want_n {
                return Err(slice_err(format!(
                    "slice dims {}x{}, range implies {want_k}x{want_n}",
                    st.k, st.n
                )));
            }
            if st.weight.data.len() != st.k * st.n {
                return Err(slice_err(format!(
                    "weight slice has {} elements, dims say {}",
                    st.weight.data.len(),
                    st.k * st.n
                )));
            }
            // fold-tail double-entry: an N-slice rounds + folds on the
            // replica, so it must carry the parent bias columns and the
            // frozen weight scale bit-for-bit; a K-slice ships raw
            // quires and the fold runs once centrally after the merge —
            // a tail there would apply bias and `s_b` a second time
            let tail_err = |detail: String| VerifyError::TailMismatch {
                model: model.name.clone(),
                gemm_idx: i,
                shard_idx: si,
                detail,
            };
            match (st.slice, &st.tail) {
                (ShardSlice::K { .. }, None) => {}
                (ShardSlice::K { .. }, Some(_)) => {
                    return Err(tail_err(
                        "K-slice carries a fold tail — bias would be applied again \
                         after the central post-merge fold"
                            .into(),
                    ));
                }
                (ShardSlice::N { .. }, None) => {
                    return Err(tail_err(
                        "N-slice is missing its fold tail — the column block would \
                         ship unfolded"
                            .into(),
                    ));
                }
                (ShardSlice::N { n0, n1 }, Some(tail)) => {
                    if tail.s_b.to_bits() != g.s_b.to_bits() {
                        return Err(tail_err(format!(
                            "tail s_b {} disagrees with the parent's frozen scale {}",
                            tail.s_b, g.s_b
                        )));
                    }
                    if tail.bias[..] != g.bias[n0..n1] {
                        return Err(tail_err(format!(
                            "tail bias disagrees with parent bias[{n0}..{n1}]"
                        )));
                    }
                }
            }
        }

        // --- reduction-cost agreement -----------------------------------
        // recompute the documented formulas literally (double-entry).
        // K layers: every shard's full-width partial image moves
        // (n_shards·m·n quire spills) and (n_shards−1)·m·n exact adds
        // run 4 per cycle. N layers ship no quire image (the fold tail
        // keeps quires on the shards — enforced structurally by the
        // tail checks above) but each shard's rounded f32 column block
        // crosses the shared AXI read channel: re-derive the burst cost
        // from the bus parameters (`latency · bursts + beats`), not by
        // calling the same helper the runtime uses.
        if all_k {
            let outs = (g.m * g.n) as u64;
            let want = (
                (shards.len().saturating_sub(1) as u64 * outs).div_ceil(4),
                shards.len() as u64 * outs * QUIRE_SPILL_BYTES as u64,
            );
            let got = reduction_cost(shards.len(), g.m, g.n);
            if got != want {
                return Err(VerifyError::ReductionCostMismatch {
                    model: model.name.clone(),
                    gemm_idx: i,
                    got,
                    want,
                });
            }
        } else {
            let bus = AxiBus::default();
            let mut want = (0u64, 0u64);
            for s in &slices {
                let ShardSlice::N { n0, n1 } = *s else {
                    continue; // unreachable: all_n
                };
                let bytes = g.m * (n1 - n0) * 4;
                let beats = bytes.div_ceil(bus.data_bytes) as u64;
                let bursts = bytes.div_ceil(bus.data_bytes).div_ceil(bus.max_beats) as u64;
                want.0 += bus.read_latency * bursts + beats;
                want.1 += bytes as u64;
            }
            let got = gather_cost(&slices, g.m);
            if got != want {
                return Err(VerifyError::ReductionCostMismatch {
                    model: model.name.clone(),
                    gemm_idx: i,
                    got,
                    want,
                });
            }
        }
    }

    // --- per-shard resident layout --------------------------------------
    let mut proofs = Vec::with_capacity(shards.len());
    for (si, sh) in shards.iter().enumerate() {
        let sh = sh.borrow();
        let (a_len, q_len) = sh.scratch_lens();
        let mut spans = Vec::with_capacity(sh.steps.len() + 2);
        let mut cursor = 0u64;
        let mut peak = 0usize;
        for st in &sh.steps {
            if st.m * st.k > a_len {
                return Err(VerifyError::SpanOverlap {
                    model: model.name.clone(),
                    what: "shard A-slice scratch",
                    gemm_idx: st.gemm_idx,
                    need: st.m * st.k,
                    have: a_len,
                });
            }
            if st.m * st.n > q_len {
                return Err(VerifyError::SpanOverlap {
                    model: model.name.clone(),
                    what: "shard quire-spill scratch",
                    gemm_idx: st.gemm_idx,
                    need: st.m * st.n,
                    have: q_len,
                });
            }
            bump(&mut cursor, st.weight.data.len() * 4, &mut spans);
            peak = peak.max(st.m * st.k);
        }
        bump(&mut cursor, a_len * 4, &mut spans);
        bump(&mut cursor, q_len * QUIRE_SPILL_BYTES, &mut spans);
        let footprint = check_layout_totals(
            &model.name,
            cursor,
            sh.warm_footprint_bytes() as u64,
            resident_limit,
        )?;
        proofs.push(ProgramProof {
            model: format!("{}#{si}", model.name),
            uid: sh.uid(),
            spans,
            footprint_bytes: footprint,
            peak_activation: peak,
            n_gemm: sh.steps.len(),
        });
    }
    Ok(proofs)
}

/// Statically verify a precision-ladder rung set: every rung must
/// verify independently as a whole program ([`verify_program`]), all
/// rungs must lower the *same* model (name, IO extents, compute-layer
/// count), rung tags must be exactly `0..n` in order, and plan fidelity
/// (average bits per weight) must be non-increasing down the ladder —
/// rung 0 is the high-fidelity plan the fleet serves when idle. Returns
/// one [`ProgramProof`] per rung, in ladder order.
pub fn verify_ladder<M: Borrow<CompiledModel>>(
    rungs: &[M],
    resident_limit: u64,
) -> Result<Vec<ProgramProof>, VerifyError> {
    let first = match rungs.first() {
        Some(m) => m.borrow(),
        None => {
            return Err(VerifyError::LadderShape {
                model: String::new(),
                detail: "ladder has zero rungs".into(),
            })
        }
    };
    let mut proofs = Vec::with_capacity(rungs.len());
    let mut prev_bits = f64::INFINITY;
    for (i, m) in rungs.iter().enumerate() {
        let m = m.borrow();
        if m.rung as usize != i {
            return Err(VerifyError::LadderShape {
                model: m.name.clone(),
                detail: format!("rung {i} carries tag {}", m.rung),
            });
        }
        if m.name != first.name
            || m.input_len != first.input_len
            || m.output_len != first.output_len
            || m.plan.per_layer.len() != first.plan.per_layer.len()
        {
            return Err(VerifyError::LadderShape {
                model: first.name.clone(),
                detail: format!("rung {i} lowers a different model (`{}`)", m.name),
            });
        }
        let bits = m.plan.avg_bits();
        if bits > prev_bits + 1e-9 {
            return Err(VerifyError::LadderShape {
                model: m.name.clone(),
                detail: format!(
                    "rung {i} has {bits:.2} avg bits, above rung {} ({prev_bits:.2}) — \
                     the ladder must descend in fidelity",
                    i - 1
                ),
            });
        }
        prev_bits = bits;
        proofs.push(verify_program(m, resident_limit)?);
    }
    Ok(proofs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::graph::{ActKind, Layer, LayerKind, ModelGraph, Shape};
    use crate::models::{compile, effnet, gaze, random_weights, shard, ulvio, LocalTail};
    use crate::quant::PrecisionPlan;
    use crate::soc::{Soc, SocConfig};
    use crate::util::proptest::{self, Config, Draw};

    fn limit() -> u64 {
        Soc::new(SocConfig::default()).resident_limit()
    }

    fn compiled(g: &ModelGraph, seed: u64, plan: &PrecisionPlan) -> CompiledModel {
        compile(g, &random_weights(g, seed), plan).expect("compile")
    }

    fn mixed_plan(g: &ModelGraph) -> PrecisionPlan {
        let mut plan = PrecisionPlan::uniform(PrecSel::Fp4x4, &g.compute_layer_params());
        for (i, sel) in plan.per_layer.iter_mut().enumerate() {
            *sel = PrecSel::ALL[i % PrecSel::ALL.len()];
        }
        plan
    }

    fn first_gemm_mut(model: &mut CompiledModel) -> &mut GemmStep {
        model
            .steps
            .iter_mut()
            .find_map(|s| if let Step::Gemm(g) = s { Some(&mut **g) } else { None })
            .expect("model has a gemm step")
    }

    #[test]
    fn accepts_all_paper_workloads_all_modes() {
        for (g, base) in [(gaze::build(), 700u64), (ulvio::build(), 710), (effnet::build(), 720)]
        {
            for (i, sel) in PrecSel::ALL.into_iter().enumerate() {
                let plan = PrecisionPlan::uniform(sel, &g.compute_layer_params());
                let c = compiled(&g, base + i as u64, &plan);
                let proof = verify_program(&c, limit()).expect("verify");
                assert_eq!(proof.footprint_bytes, c.warm_footprint_bytes() as u64);
                assert_eq!(proof.n_gemm, c.n_gemm());
                assert!(proof.peak_activation <= c.buf_len);
            }
            let c = compiled(&g, base + 9, &mixed_plan(&g));
            verify_program(&c, limit()).expect("mixed plan verifies");
        }
    }

    #[test]
    fn proof_spans_are_disjoint_and_aligned() {
        let g = effnet::build();
        let c = compiled(&g, 730, &mixed_plan(&g));
        let proof = verify_program(&c, limit()).unwrap();
        let mut prev_end = 0u64;
        for &(s, e) in &proof.spans {
            assert_eq!(s % 64, 0, "span start {s} unaligned");
            assert!(s >= prev_end, "span at {s} overlaps previous end {prev_end}");
            assert!(e >= s);
            prev_end = e;
        }
        assert_eq!(proof.footprint_bytes, prev_end.next_multiple_of(64));
    }

    #[test]
    fn accepts_sharded_paper_workloads() {
        let g = ulvio::build();
        let c = compiled(&g, 740, &mixed_plan(&g));
        for n_shards in [1usize, 2, 3] {
            let shards = shard(&c, n_shards).expect("shard");
            let proofs = verify_shard_plan(&c, &shards, limit()).expect("verify shards");
            assert_eq!(proofs.len(), n_shards);
            for (sh, proof) in shards.iter().zip(&proofs) {
                assert_eq!(proof.footprint_bytes, sh.warm_footprint_bytes() as u64);
            }
        }
    }

    #[test]
    fn property_accepts_every_compile_output() {
        // randomized graphs (conv stacks and fc stacks) × randomized
        // per-layer plans: whatever compile() produces must verify, and
        // whatever shard() plans from it must verify too
        proptest::run(Config { cases: 24, seed: 0x5EED_6 }, |rng, case| {
            let g = if rng.coin(0.5) {
                let c = rng.usize_in(1, 2);
                let hw = rng.usize_in(5, 8);
                let out_c = rng.usize_in(2, 5);
                let k = if rng.coin(0.5) { 3 } else { 1 };
                let pad = if k == 3 { rng.usize_in(0, 1) } else { 0 };
                let flat = out_c * (hw + 2 * pad - k + 1).pow(2);
                ModelGraph {
                    name: format!("prop-conv-{case}"),
                    input: Shape { c, h: hw, w: hw },
                    layers: vec![
                        Layer {
                            name: "c1".into(),
                            kind: LayerKind::Conv2d { in_c: c, out_c, k, stride: 1, pad },
                        },
                        Layer { name: "a1".into(), kind: LayerKind::Act(ActKind::Relu) },
                        Layer { name: "fl".into(), kind: LayerKind::Flatten },
                        Layer {
                            name: "f1".into(),
                            kind: LayerKind::Fc { in_f: flat, out_f: rng.usize_in(2, 9) },
                        },
                    ],
                }
            } else {
                let mut layers = Vec::new();
                let mut width = rng.usize_in(6, 40);
                let input = Shape::vec(width);
                for li in 0..rng.usize_in(1, 3) {
                    let next = rng.usize_in(3, 32);
                    layers.push(Layer {
                        name: format!("f{li}"),
                        kind: LayerKind::Fc { in_f: width, out_f: next },
                    });
                    layers.push(Layer {
                        name: format!("a{li}"),
                        kind: LayerKind::Act(ActKind::Tanh),
                    });
                    width = next;
                }
                ModelGraph { name: format!("prop-fc-{case}"), input, layers }
            };
            let params = g.compute_layer_params();
            let mut plan = PrecisionPlan::uniform(PrecSel::Fp4x4, &params);
            for sel in plan.per_layer.iter_mut() {
                *sel = PrecSel::ALL[rng.usize_in(0, PrecSel::ALL.len() - 1)];
            }
            let c = compiled(&g, 7600 + case as u64, &plan);
            verify_program(&c, limit()).expect("compile output must verify");
            let n_shards = rng.usize_in(1, 3);
            if let Ok(shards) = shard(&c, n_shards) {
                verify_shard_plan(&c, &shards, limit()).expect("shard plan must verify");
            }
        });
    }

    // ------------------------- seeded corruption -------------------------

    #[test]
    fn rejects_undersized_scratch_span() {
        // corruption class 1: a-scratch span too small for a runtime
        // operand — the GEMM would write past its span into the next one
        let g = gaze::build();
        let mut c = compiled(&g, 750, &mixed_plan(&g));
        c.a_len = 1;
        match verify_program(&c, limit()) {
            Err(VerifyError::SpanOverlap { what: "A-operand scratch", need, have: 1, .. }) => {
                assert!(need > 1)
            }
            other => panic!("want SpanOverlap, got {other:?}"),
        }
    }

    #[test]
    fn rejects_out_of_bounds_gather_index() {
        // corruption class 2: a gather-map slot reading outside the live
        // activation extent
        let g = effnet::build();
        let mut c = compiled(&g, 751, &mixed_plan(&g));
        let gm = first_gemm_mut(&mut c);
        let (rows, cols, mut idx) = {
            let map = gm.gather.as_ref().expect("effnet leads with a conv");
            (map.rows, map.cols, map.indices().to_vec())
        };
        idx[0] = 0x7FFF_FFFF;
        gm.gather = Some(GatherMap::from_raw(rows, cols, idx));
        match verify_program(&c, limit()) {
            Err(VerifyError::GatherOutOfBounds { slot: 0, index: 0x7FFF_FFFF, .. }) => {}
            other => panic!("want GatherOutOfBounds, got {other:?}"),
        }
    }

    #[test]
    fn rejects_misaligned_k_split() {
        // corruption class 3: an interior K boundary off the lane grid
        let g = gaze::build();
        let c = compiled(&g, 752, &mixed_plan(&g));
        let mut shards = shard(&c, 2).expect("shard");
        let (gi, k1) = shards[0]
            .steps
            .iter()
            .find_map(|st| match st.slice {
                ShardSlice::K { k0: 0, k1 } if k1 >= SHARD_K_ALIGN * 2 => Some((st.gemm_idx, k1)),
                _ => None,
            })
            .expect("a K-split step");
        shards[0].steps[gi].slice = ShardSlice::K { k0: 0, k1: k1 - 1 };
        shards[1].steps[gi].slice = ShardSlice::K { k0: k1 - 1, k1: gemm_k(&c, gi) };
        match verify_shard_plan(&c, &shards, limit()) {
            Err(VerifyError::KSplitMisaligned { gemm_idx, boundary, .. }) => {
                assert_eq!(gemm_idx, gi);
                assert_eq!(boundary, k1 - 1);
            }
            other => panic!("want KSplitMisaligned, got {other:?}"),
        }
    }

    #[test]
    fn rejects_k_split_gap_and_overlap() {
        // corruption class 4: K slices leaving a gap / double-covering
        let g = gaze::build();
        let c = compiled(&g, 753, &mixed_plan(&g));
        for delta in [SHARD_K_ALIGN as isize, -(SHARD_K_ALIGN as isize)] {
            let mut shards = shard(&c, 2).expect("shard");
            let gi = shards[1]
                .steps
                .iter()
                .find_map(|st| match st.slice {
                    ShardSlice::K { k0, .. } if k0 >= 2 * SHARD_K_ALIGN => Some(st.gemm_idx),
                    _ => None,
                })
                .expect("a K-split step");
            let ShardSlice::K { k0, k1 } = shards[1].steps[gi].slice else { unreachable!() };
            let bad_k0 = (k0 as isize + delta) as usize;
            shards[1].steps[gi].slice = ShardSlice::K { k0: bad_k0, k1 };
            match verify_shard_plan(&c, &shards, limit()) {
                Err(VerifyError::KSplitCoverage { gemm_idx, detail, .. }) => {
                    assert_eq!(gemm_idx, gi);
                    let want = if delta > 0 { "gap" } else { "overlap" };
                    assert!(detail.contains(want), "delta {delta}: {detail}");
                }
                other => panic!("delta {delta}: want KSplitCoverage, got {other:?}"),
            }
        }
    }

    #[test]
    fn rejects_staging_intrusion() {
        // corruption class 5: a footprint that could only warm by
        // reaching into the FSM staging quarter
        let g = gaze::build();
        let c = compiled(&g, 754, &mixed_plan(&g));
        let tight = c.warm_footprint_bytes() as u64 - 64;
        match verify_program(&c, tight) {
            Err(VerifyError::StagingIntrusion { footprint, limit, .. }) => {
                assert!(footprint > limit);
            }
            other => panic!("want StagingIntrusion, got {other:?}"),
        }
        let shards = shard(&c, 2).expect("shard");
        assert!(matches!(
            verify_shard_plan(&c, &shards, 64),
            Err(VerifyError::StagingIntrusion { .. })
        ));
    }

    #[test]
    fn rejects_plan_drift() {
        // corruption class 6: the morph schedule disagrees with the plan
        let g = gaze::build();
        let mut c = compiled(&g, 755, &PrecisionPlan::uniform(PrecSel::Posit8x2, &g.compute_layer_params()));
        c.plan.per_layer[0] = PrecSel::Posit16x1;
        assert!(matches!(
            verify_program(&c, limit()),
            Err(VerifyError::PrecSelMismatch { gemm_idx: 0, .. })
        ));
    }

    #[test]
    fn rejects_truncated_chain() {
        // corruption class 7: a program whose final extent is not the
        // declared output length
        let g = gaze::build();
        let mut c = compiled(&g, 756, &mixed_plan(&g));
        c.steps.pop();
        assert!(matches!(
            verify_program(&c, limit()),
            Err(VerifyError::ChainMismatch { .. })
        ));
    }

    #[test]
    fn rejects_shuffled_shard_set() {
        // corruption class 8: shard set out of order / wrong cardinality
        let g = gaze::build();
        let c = compiled(&g, 757, &mixed_plan(&g));
        let mut shards = shard(&c, 2).expect("shard");
        shards.swap(0, 1);
        assert!(matches!(
            verify_shard_plan(&c, &shards, limit()),
            Err(VerifyError::ShardSetShape { .. })
        ));
        let shards = shard(&c, 3).expect("shard");
        assert!(matches!(
            verify_shard_plan(&c, &shards[..2], limit()),
            Err(VerifyError::ShardSetShape { .. })
        ));
    }

    #[test]
    fn rejects_footprint_drift() {
        // corruption class 9: scratch sized differently from what the
        // residency budget will account (no runtime write would trap
        // this — the span is too big, not too small)
        let g = gaze::build();
        let mut c = compiled(&g, 758, &mixed_plan(&g));
        c.c_len += 4096;
        // a *larger* c_len keeps every need<=have check green but moves
        // the simulated layout — which still matches warm_footprint_bytes
        // (both derive from c_len), so grow the declared buf instead via
        // a weight-shape corruption:
        assert!(verify_program(&c, limit()).is_ok(), "oversized scratch is consistent");
        let gm = first_gemm_mut(&mut c);
        gm.weight.data.push(0.0);
        assert!(matches!(
            verify_program(&c, limit()),
            Err(VerifyError::WeightShape { .. })
        ));
    }

    #[test]
    fn rejects_tail_defects() {
        // corruption class 10: the shard-local fold tail out of
        // double-entry with the parent layer — missing from an N-slice,
        // carrying the wrong scale or bias, or grafted onto a K-slice
        let g = ModelGraph {
            name: "tiny_fc".into(),
            input: Shape::vec(6),
            layers: vec![Layer {
                name: "fc".into(),
                kind: LayerKind::Fc { in_f: 6, out_f: 9 },
            }],
        };
        let plan = PrecisionPlan::uniform(PrecSel::Posit8x2, &g.compute_layer_params());
        let c = compiled(&g, 759, &plan);
        let mut shards = shard(&c, 3).expect("K=6 forces the N-split fallback");
        assert!(matches!(shards[0].steps[0].slice, ShardSlice::N { .. }));

        let saved = shards[0].steps[0].tail.take().expect("N-slice carries a tail");
        assert!(matches!(
            verify_shard_plan(&c, &shards, limit()),
            Err(VerifyError::TailMismatch { gemm_idx: 0, shard_idx: 0, .. })
        ));
        shards[0].steps[0].tail =
            Some(LocalTail { s_b: saved.s_b * 2.0, bias: saved.bias.clone() });
        assert!(matches!(
            verify_shard_plan(&c, &shards, limit()),
            Err(VerifyError::TailMismatch { .. })
        ));
        shards[0].steps[0].tail =
            Some(LocalTail { s_b: saved.s_b, bias: vec![1.0; saved.bias.len()] });
        assert!(matches!(
            verify_shard_plan(&c, &shards, limit()),
            Err(VerifyError::TailMismatch { .. })
        ));
        shards[0].steps[0].tail = Some(saved);
        verify_shard_plan(&c, &shards, limit()).expect("restored tail verifies");

        // the inverse defect: a fold tail on a K-slice would fold twice
        let g = gaze::build();
        let c = compiled(&g, 760, &mixed_plan(&g));
        let mut shards = shard(&c, 2).expect("shard");
        assert!(matches!(shards[1].steps[0].slice, ShardSlice::K { .. }));
        shards[1].steps[0].tail = Some(LocalTail { s_b: 1.0, bias: Vec::new() });
        assert!(matches!(
            verify_shard_plan(&c, &shards, limit()),
            Err(VerifyError::TailMismatch { gemm_idx: 0, shard_idx: 1, .. })
        ));
    }

    fn gemm_k(c: &CompiledModel, gemm_idx: usize) -> usize {
        c.steps
            .iter()
            .find_map(|s| match s {
                Step::Gemm(g) if g.gemm_idx == gemm_idx => Some(g.k),
                _ => None,
            })
            .expect("gemm_idx in range")
    }

    #[test]
    fn ladder_of_descending_plans_verifies() {
        let g = gaze::build();
        let params = g.compute_layer_params();
        let mut rungs = Vec::new();
        for (i, sel) in [PrecSel::Posit16x1, PrecSel::Posit8x2, PrecSel::Fp4x4]
            .into_iter()
            .enumerate()
        {
            let mut c = compiled(&g, 770, &PrecisionPlan::uniform(sel, &params));
            c.rung = i as u32;
            rungs.push(c);
        }
        let proofs = verify_ladder(&rungs, limit()).expect("descending ladder verifies");
        assert_eq!(proofs.len(), 3);
    }

    #[test]
    fn ladder_rejects_mistag_ascent_and_empty() {
        let g = gaze::build();
        let params = g.compute_layer_params();
        let hi = compiled(&g, 771, &PrecisionPlan::uniform(PrecSel::Posit16x1, &params));
        let mut lo = compiled(&g, 771, &PrecisionPlan::uniform(PrecSel::Fp4x4, &params));
        // mis-tagged: first rung carries tag 1
        lo.rung = 1;
        assert!(matches!(
            verify_ladder(std::slice::from_ref(&lo), limit()),
            Err(VerifyError::LadderShape { .. })
        ));
        // ascending fidelity: the FP4 plan ordered before the Posit16 one
        let mut hi2 = hi.clone();
        let mut lo2 = lo.clone();
        lo2.rung = 0;
        hi2.rung = 1;
        let err = verify_ladder(&[lo2, hi2], limit()).expect_err("ascending ladder");
        assert!(err.to_string().contains("descend"), "{err}");
        // zero rungs
        assert!(matches!(
            verify_ladder::<CompiledModel>(&[], limit()),
            Err(VerifyError::LadderShape { .. })
        ));
    }

    #[test]
    fn ladder_rejects_a_rung_of_a_different_model() {
        let g = gaze::build();
        let mut r0 = compiled(&g, 772, &PrecisionPlan::uniform(PrecSel::Posit16x1, &g.compute_layer_params()));
        r0.rung = 0;
        let e = effnet::build();
        let mut r1 = compiled(&e, 773, &PrecisionPlan::uniform(PrecSel::Fp4x4, &e.compute_layer_params()));
        r1.rung = 1;
        let err = verify_ladder(&[r0, r1], limit()).expect_err("foreign rung");
        assert!(err.to_string().contains("different model"), "{err}");
    }
}
