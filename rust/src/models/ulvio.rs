//! UL-VIO-lite — the visual-inertial odometry workload (paper Fig. 6,
//! Table III's VIO row), after UL-VIO [22] scaled to the synthetic
//! KITTI-like generator in [`crate::vio::kitti`].
//!
//! Input: two stacked feature frames (2 × 16 × 16 — current + previous
//! camera feature maps) plus a 6-D IMU vector (accel + gyro integrated
//! over the frame interval), concatenated after the conv encoder.
//! Output: 6-DoF relative pose (tx, ty, tz, roll, pitch, yaw).
//!
//! ```text
//! conv1 2→8  3×3 s2 p1 · PACT      (16×16 → 8×8)
//! conv2 8→16 3×3 s2 p1 · PACT      (8×8 → 4×4)
//! flatten (256) · concat IMU (6)
//! fc1 262→64 · PACT
//! fc2 64→6   (linear)
//! ```
//!
//! The output head (`fc2`) is the precision-critical layer — the
//! sensitivity analysis discovers this and the planner pins it high in
//! the MxP config, reproducing the paper's finding that MxP (Posit-8 /
//! FP4) trades best.

use super::graph::{ActKind, Layer, LayerKind, ModelGraph, Shape};

/// Camera input: 2 stacked 16×16 feature frames.
pub const INPUT: Shape = Shape { c: 2, h: 16, w: 16 };
/// IMU features concatenated after the encoder.
pub const IMU_DIM: usize = 6;
/// 6-DoF relative pose output.
pub const POSE_DIM: usize = 6;

/// Build the graph.
pub fn build() -> ModelGraph {
    let l = |name: &str, kind: LayerKind| Layer { name: name.into(), kind };
    ModelGraph {
        name: "ulvio_lite".into(),
        input: INPUT,
        layers: vec![
            l("conv1", LayerKind::Conv2d { in_c: 2, out_c: 8, k: 3, stride: 2, pad: 1 }),
            l("act1", LayerKind::Act(ActKind::Pact)),
            l("conv2", LayerKind::Conv2d { in_c: 8, out_c: 16, k: 3, stride: 2, pad: 1 }),
            l("act2", LayerKind::Act(ActKind::Pact)),
            l("flat", LayerKind::Flatten),
            l("imu", LayerKind::ConcatAux { n: IMU_DIM }),
            l("fc1", LayerKind::Fc { in_f: 16 * 4 * 4 + IMU_DIM, out_f: 64 }),
            l("act3", LayerKind::Act(ActKind::Pact)),
            l("fc2", LayerKind::Fc { in_f: 64, out_f: POSE_DIM }),
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes() {
        let g = build();
        assert_eq!(g.out_shape(), Shape::vec(POSE_DIM));
        assert_eq!(g.compute_layers().len(), 4);
    }

    #[test]
    fn stride2_convs_shrink() {
        let g = build();
        let shapes = g.shapes();
        assert_eq!(shapes[1], Shape { c: 8, h: 8, w: 8 });
        assert_eq!(shapes[3], Shape { c: 16, h: 4, w: 4 });
    }
}
