//! The quire: an exact fixed-point accumulator for sums of products.
//!
//! Fig. 3's "Quire scale-accumulate stage" performs the dot-product
//! accumulation *without intermediate rounding* — the defining numerical
//! property of posit MACs. We model it as a 128-bit two's-complement
//! fixed-point register with `FRAC = 56` fraction bits:
//!
//! * Posit(16,1) products have LSB weight ≥ 2^−56 (minpos² = 2^−56) and
//!   magnitude < 2^57, so every product of every native mode (FP4,
//!   Posit(4,1), Posit(8,0), Posit(16,1), and FP8 for baselines) is
//!   representable **exactly**.
//! * Headroom: 127 − (57 + 56) = 14 bits ⇒ ≥ 2^14 worst-case products can
//!   accumulate before saturation; real workloads are far below this, and
//!   overflow is detected and flagged, never silent.
//!
//! This matches the sizing rationale of the posit-standard quire
//! (16·n bits for n = 16).

use super::{Class, Decoded};

/// Fraction bits of the quire fixed-point representation.
pub const QUIRE_FRAC: u32 = 56;

/// Exact fixed-point accumulator.
#[derive(Debug, Clone, Copy)]
pub struct Quire {
    acc: i128,
    /// Saturation happened (would-be hardware sticky flag).
    pub overflow: bool,
    /// A value below quire resolution was rounded on insertion (only
    /// possible via [`Quire::add_value`] with sub-2^−56 inputs, which no
    /// native-mode product can produce).
    pub inexact: bool,
    /// NaR/NaN was accumulated; the result is NaR.
    pub nar: bool,
}

impl Default for Quire {
    fn default() -> Self {
        Self::new()
    }
}

impl Quire {
    pub fn new() -> Self {
        Quire { acc: 0, overflow: false, inexact: false, nar: false }
    }

    /// Accumulate the exact product `a · b`.
    ///
    /// Infinities are treated as NaR (the engine's posit-centric exception
    /// unit maps FP Inf into NaR on the accumulate path; see
    /// `npe::lane`). Zero products are skipped — this is exactly the
    /// power-gating condition the paper exploits.
    pub fn add_product(&mut self, a: Decoded, b: Decoded) {
        match (a.class, b.class) {
            (Class::Nan, _) | (_, Class::Nan) | (Class::Inf, _) | (_, Class::Inf) => {
                self.nar = true;
            }
            (Class::Zero, _) | (_, Class::Zero) => {}
            (Class::Normal, Class::Normal) => {
                let sig = a.sig as u128 * b.sig as u128;
                let e = (a.scale - a.frac_bits as i32) + (b.scale - b.frac_bits as i32);
                self.add_fixed(sig, e, a.sign ^ b.sign);
            }
        }
    }

    /// Accumulate a single value (bias add, residual add).
    pub fn add_value(&mut self, v: Decoded) {
        match v.class {
            Class::Nan | Class::Inf => self.nar = true,
            Class::Zero => {}
            Class::Normal => {
                self.add_fixed(v.sig as u128, v.scale - v.frac_bits as i32, v.sign)
            }
        }
    }

    /// Accumulate a raw significand product `±sig · 2^e` — the entry
    /// point the NPE multiplier datapath uses (`npe::lane`), keeping the
    /// RMMEC-computed integer product on the modeled path.
    pub fn add_sig_product(&mut self, sig: u128, e: i32, neg: bool) {
        if sig != 0 {
            self.add_fixed(sig, e, neg);
        }
    }

    /// Core: add `±sig · 2^e` into the accumulator.
    fn add_fixed(&mut self, sig: u128, e: i32, neg: bool) {
        let shift = e + QUIRE_FRAC as i32;
        let mag: i128 = if shift >= 0 {
            if shift >= 127 || (sig.leading_zeros() as i32) < shift + 2 {
                self.overflow = true;
                return;
            }
            (sig << shift) as i128
        } else {
            let s = (-shift) as u32;
            if s >= 128 {
                if sig != 0 {
                    self.inexact = true;
                }
                return;
            }
            let kept = sig >> s;
            if kept << s != sig {
                self.inexact = true; // bits below quire resolution dropped
            }
            kept as i128
        };
        let signed = if neg { -mag } else { mag };
        match self.acc.checked_add(signed) {
            Some(v) => self.acc = v,
            None => self.overflow = true,
        }
    }

    /// Exact value currently held (f64 rounds the 128-bit fixed point to
    /// nearest — the final output-processing round to the target format
    /// happens *after* this, matching the hardware's single-rounding
    /// behaviour for all practically-sized accumulations).
    pub fn to_f64(&self) -> f64 {
        if self.nar {
            return f64::NAN;
        }
        // i128 → f64 conversion rounds to nearest even.
        (self.acc as f64) * 2f64.powi(-(QUIRE_FRAC as i32))
    }

    /// True if the accumulated value is exactly zero.
    pub fn is_zero(&self) -> bool {
        !self.nar && self.acc == 0
    }

    /// Raw fixed-point accumulator (tests / debugging).
    pub fn raw(&self) -> i128 {
        self.acc
    }

    /// Merge another quire (adder-tree reduction of partial quires).
    pub fn merge(&mut self, other: &Quire) {
        self.nar |= other.nar;
        self.inexact |= other.inexact;
        match self.acc.checked_add(other.acc) {
            Some(v) => self.acc = v,
            None => self.overflow = true,
        }
        self.overflow |= other.overflow;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::Precision;

    fn dec(x: f64) -> Decoded {
        Decoded::from_f64(x)
    }

    #[test]
    fn exact_simple_dot() {
        let mut q = Quire::new();
        q.add_product(dec(1.5), dec(2.0));
        q.add_product(dec(-0.5), dec(3.0));
        assert_eq!(q.to_f64(), 1.5);
        assert!(!q.overflow && !q.inexact && !q.nar);
    }

    #[test]
    fn exact_minpos_squared_posit16() {
        // minpos² = 2^-56 = exactly one quire LSB
        let minpos = 2f64.powi(-28);
        let mut q = Quire::new();
        q.add_product(dec(minpos), dec(minpos));
        assert_eq!(q.raw(), 1);
        assert_eq!(q.to_f64(), 2f64.powi(-56));
        assert!(!q.inexact);
    }

    #[test]
    fn catastrophic_cancellation_is_exact() {
        // The reason the quire exists: (maxish · maxish) − (maxish · maxish)
        // + tiny must yield exactly tiny.
        let big = 2f64.powi(27);
        let tiny = 2f64.powi(-28);
        let mut q = Quire::new();
        q.add_product(dec(big), dec(big));
        q.add_product(dec(-big), dec(big));
        q.add_product(dec(tiny), dec(1.0));
        assert_eq!(q.to_f64(), tiny);
    }

    #[test]
    fn zero_products_skipped() {
        let mut q = Quire::new();
        q.add_product(Decoded::ZERO, dec(5.0));
        q.add_product(dec(5.0), Decoded::ZERO);
        assert!(q.is_zero());
    }

    #[test]
    fn nar_propagates() {
        let mut q = Quire::new();
        q.add_product(dec(1.0), dec(1.0));
        q.add_product(Decoded::NAN, dec(1.0));
        assert!(q.to_f64().is_nan());
        let mut q2 = Quire::new();
        q2.add_value(Decoded::inf(false));
        assert!(q2.to_f64().is_nan());
    }

    #[test]
    fn overflow_detected_not_silent() {
        let mut q = Quire::new();
        let big = dec(2f64.powi(28)); // posit16 maxpos
        for _ in 0..40_000 {
            q.add_product(big, big);
        }
        assert!(q.overflow);
    }

    #[test]
    fn all_hw_mode_products_exact() {
        // Every representable product of every native mode accumulates
        // exactly: check random pairs against rational arithmetic via f64
        // (all products fit f64's 52-bit mantissa exactly: ≤ 13+13 bits).
        let mut rng = crate::util::Rng::new(21);
        for p in Precision::HW_MODES {
            let mask = (1u64 << p.bits()) - 1;
            for _ in 0..2000 {
                let a = p.decode((rng.next_u64() & mask) as u32);
                let b = p.decode((rng.next_u64() & mask) as u32);
                if a.class != Class::Normal || b.class != Class::Normal {
                    continue;
                }
                let mut q = Quire::new();
                q.add_product(a, b);
                assert_eq!(q.to_f64(), a.to_f64() * b.to_f64(), "{p:?}");
                assert!(!q.inexact);
            }
        }
    }

    #[test]
    fn merge_equals_sequential() {
        let mut rng = crate::util::Rng::new(33);
        let xs: Vec<f64> = (0..64).map(|_| rng.normal()).collect();
        let ys: Vec<f64> = (0..64).map(|_| rng.normal()).collect();
        // quantize to posit8 so products are quire-exact
        let p = Precision::Posit8;
        let xs: Vec<f64> = xs.iter().map(|&x| p.quantize(x)).collect();
        let ys: Vec<f64> = ys.iter().map(|&y| p.quantize(y)).collect();
        let mut q_all = Quire::new();
        let mut q_a = Quire::new();
        let mut q_b = Quire::new();
        for i in 0..64 {
            q_all.add_product(dec(xs[i]), dec(ys[i]));
            if i % 2 == 0 {
                q_a.add_product(dec(xs[i]), dec(ys[i]));
            } else {
                q_b.add_product(dec(xs[i]), dec(ys[i]));
            }
        }
        q_a.merge(&q_b);
        assert_eq!(q_a.raw(), q_all.raw());
    }
}
