//! The quire: an exact fixed-point accumulator for sums of products.
//!
//! Fig. 3's "Quire scale-accumulate stage" performs the dot-product
//! accumulation *without intermediate rounding* — the defining numerical
//! property of posit MACs. We model it as a 128-bit two's-complement
//! fixed-point register with `FRAC = 56` fraction bits:
//!
//! * Posit(16,1) products have LSB weight ≥ 2^−56 (minpos² = 2^−56) and
//!   magnitude < 2^57, so every product of every native mode (FP4,
//!   Posit(4,1), Posit(8,0), Posit(16,1), and FP8 for baselines) is
//!   representable **exactly**.
//! * Headroom: 127 − (57 + 56) = 14 bits ⇒ ≥ 2^14 worst-case products can
//!   accumulate before saturation; real workloads are far below this, and
//!   overflow is detected and flagged, never silent.
//!
//! This matches the sizing rationale of the posit-standard quire
//! (16·n bits for n = 16).

use super::{tables, Class, Decoded, Precision};

/// Fraction bits of the quire fixed-point representation.
pub const QUIRE_FRAC: u32 = 56;

/// Bytes of one quire spilled to DRAM for cross-shard reduction: the
/// 128-bit accumulator little-endian plus one sticky-flag byte
/// (bit 0 = overflow, bit 1 = inexact, bit 2 = NaR).
pub const QUIRE_SPILL_BYTES: usize = 17;

/// Exact fixed-point accumulator.
#[derive(Debug, Clone, Copy)]
pub struct Quire {
    acc: i128,
    /// Saturation happened (would-be hardware sticky flag).
    pub overflow: bool,
    /// A value below quire resolution was rounded on insertion (only
    /// possible via [`Quire::add_value`] with sub-2^−56 inputs, which no
    /// native-mode product can produce).
    pub inexact: bool,
    /// NaR/NaN was accumulated; the result is NaR.
    pub nar: bool,
}

impl Default for Quire {
    fn default() -> Self {
        Self::new()
    }
}

impl Quire {
    pub fn new() -> Self {
        Quire { acc: 0, overflow: false, inexact: false, nar: false }
    }

    /// Accumulate the exact product `a · b`.
    ///
    /// Infinities are treated as NaR (the engine's posit-centric exception
    /// unit maps FP Inf into NaR on the accumulate path; see
    /// `npe::lane`). Zero products are skipped — this is exactly the
    /// power-gating condition the paper exploits.
    pub fn add_product(&mut self, a: Decoded, b: Decoded) {
        match (a.class, b.class) {
            (Class::Nan, _) | (_, Class::Nan) | (Class::Inf, _) | (_, Class::Inf) => {
                self.nar = true;
            }
            (Class::Zero, _) | (_, Class::Zero) => {}
            (Class::Normal, Class::Normal) => {
                let sig = a.sig as u128 * b.sig as u128;
                let e = (a.scale - a.frac_bits as i32) + (b.scale - b.frac_bits as i32);
                self.add_fixed(sig, e, a.sign ^ b.sign);
            }
        }
    }

    /// Accumulate a single value (bias add, residual add).
    pub fn add_value(&mut self, v: Decoded) {
        match v.class {
            Class::Nan | Class::Inf => self.nar = true,
            Class::Zero => {}
            Class::Normal => {
                self.add_fixed(v.sig as u128, v.scale - v.frac_bits as i32, v.sign)
            }
        }
    }

    /// Accumulate a raw significand product `±sig · 2^e` — the entry
    /// point the NPE multiplier datapath uses (`npe::lane`), keeping the
    /// RMMEC-computed integer product on the modeled path.
    pub fn add_sig_product(&mut self, sig: u128, e: i32, neg: bool) {
        if sig != 0 {
            self.add_fixed(sig, e, neg);
        }
    }

    /// Core: add `±sig · 2^e` into the accumulator.
    fn add_fixed(&mut self, sig: u128, e: i32, neg: bool) {
        let shift = e + QUIRE_FRAC as i32;
        let mag: i128 = if shift >= 0 {
            if shift >= 127 || (sig.leading_zeros() as i32) < shift + 2 {
                self.overflow = true;
                return;
            }
            (sig << shift) as i128
        } else {
            let s = (-shift) as u32;
            if s >= 128 {
                if sig != 0 {
                    self.inexact = true;
                }
                return;
            }
            let kept = sig >> s;
            if kept << s != sig {
                self.inexact = true; // bits below quire resolution dropped
            }
            kept as i128
        };
        let signed = if neg { -mag } else { mag };
        match self.acc.checked_add(signed) {
            Some(v) => self.acc = v,
            None => self.overflow = true,
        }
    }

    /// Exact value currently held (f64 rounds the 128-bit fixed point to
    /// nearest — the final output-processing round to the target format
    /// happens *after* this, matching the hardware's single-rounding
    /// behaviour for all practically-sized accumulations).
    pub fn to_f64(&self) -> f64 {
        if self.nar {
            return f64::NAN;
        }
        // i128 → f64 conversion rounds to nearest even.
        (self.acc as f64) * 2f64.powi(-(QUIRE_FRAC as i32))
    }

    /// True if the accumulated value is exactly zero.
    pub fn is_zero(&self) -> bool {
        !self.nar && self.acc == 0
    }

    /// Raw fixed-point accumulator (tests / debugging).
    pub fn raw(&self) -> i128 {
        self.acc
    }

    /// Merge another quire (adder-tree reduction of partial quires).
    ///
    /// The accumulator addition is plain i128 arithmetic, so merging
    /// shard-partial quires in any order reproduces the single-quire
    /// accumulation of the same products **bit-exactly** (integer
    /// addition is associative and commutative); the sticky flags OR.
    /// This is the exactness guarantee cross-replica sharded GEMM
    /// reduction rests on, property-tested below.
    pub fn merge(&mut self, other: &Quire) {
        self.nar |= other.nar;
        self.inexact |= other.inexact;
        match self.acc.checked_add(other.acc) {
            Some(v) => self.acc = v,
            None => self.overflow = true,
        }
        self.overflow |= other.overflow;
    }

    /// Rebuild a quire from its raw accumulator + sticky flags (the
    /// receive side of a cross-shard partial-quire transfer).
    pub fn from_raw(acc: i128, overflow: bool, inexact: bool, nar: bool) -> Quire {
        Quire { acc, overflow, inexact, nar }
    }

    /// Serialize for the DRAM spill the partial-GEMM writeback models
    /// ([`QUIRE_SPILL_BYTES`] bytes).
    pub fn to_spill_bytes(&self) -> [u8; QUIRE_SPILL_BYTES] {
        let mut out = [0u8; QUIRE_SPILL_BYTES];
        out[..16].copy_from_slice(&self.acc.to_le_bytes());
        out[16] = self.overflow as u8 | (self.inexact as u8) << 1 | (self.nar as u8) << 2;
        out
    }

    /// Inverse of [`Quire::to_spill_bytes`]. Panics on a short slice —
    /// the spill image is sized by the caller.
    pub fn from_spill_bytes(b: &[u8]) -> Quire {
        // xr_lint: allow(no-panic) -- documented contract: the caller sizes the spill image (QUIRE_SPILL_BYTES)
        let acc = i128::from_le_bytes(b[..16].try_into().expect("quire spill: short slice"));
        let f = b[16];
        Quire::from_raw(acc, f & 1 != 0, f & 2 != 0, f & 4 != 0)
    }

    /// Round to `prec` exactly as the engine's output-processing stage
    /// does (`Engine::read_lane` + table decode): encode the quire value
    /// once to the format, decode back to the f32 carrier. Sharded
    /// serving rounds the *merged* quire through this expression, so the
    /// result is bit-identical to the unsharded single-quire path.
    pub fn round_to(&self, prec: Precision) -> f32 {
        tables::decode_value(prec, prec.encode(self.to_f64())) as f32
    }
}

/// A rows×cols grid of partial quires — the payload of one sharded
/// GEMM's writeback, merged at the coordinator before the single final
/// rounding.
#[derive(Debug, Clone)]
pub struct QuireMatrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<Quire>,
}

impl QuireMatrix {
    /// All-zero quires (the merge identity).
    pub fn zeros(rows: usize, cols: usize) -> QuireMatrix {
        QuireMatrix { rows, cols, data: vec![Quire::new(); rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<Quire>) -> QuireMatrix {
        assert_eq!(data.len(), rows * cols);
        QuireMatrix { rows, cols, data }
    }

    /// Merge `other` into the column block starting at `c0` (rows must
    /// match). A K-split shard merges at `c0 = 0` over the full width; an
    /// N-split shard merges its disjoint column slice into zero quires.
    pub fn merge_block(&mut self, c0: usize, other: &QuireMatrix) {
        assert_eq!(self.rows, other.rows, "quire merge: row mismatch");
        assert!(c0 + other.cols <= self.cols, "quire merge: column block out of range");
        for r in 0..other.rows {
            for c in 0..other.cols {
                self.data[r * self.cols + c0 + c].merge(&other.data[r * other.cols + c]);
            }
        }
    }

    /// Round every quire once to `prec` (see [`Quire::round_to`]).
    pub fn round_to(&self, prec: Precision) -> Vec<f32> {
        self.data.iter().map(|q| q.round_to(prec)).collect()
    }

    /// Serialize row-major to the DRAM spill image.
    pub fn to_spill_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.data.len() * QUIRE_SPILL_BYTES);
        for q in &self.data {
            out.extend_from_slice(&q.to_spill_bytes());
        }
        out
    }

    /// Parse a spill image back into quires.
    pub fn from_spill_bytes(rows: usize, cols: usize, bytes: &[u8]) -> QuireMatrix {
        assert_eq!(bytes.len(), rows * cols * QUIRE_SPILL_BYTES, "quire spill: size mismatch");
        let data = bytes.chunks_exact(QUIRE_SPILL_BYTES).map(Quire::from_spill_bytes).collect();
        QuireMatrix { rows, cols, data }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::Precision;

    fn dec(x: f64) -> Decoded {
        Decoded::from_f64(x)
    }

    #[test]
    fn exact_simple_dot() {
        let mut q = Quire::new();
        q.add_product(dec(1.5), dec(2.0));
        q.add_product(dec(-0.5), dec(3.0));
        assert_eq!(q.to_f64(), 1.5);
        assert!(!q.overflow && !q.inexact && !q.nar);
    }

    #[test]
    fn exact_minpos_squared_posit16() {
        // minpos² = 2^-56 = exactly one quire LSB
        let minpos = 2f64.powi(-28);
        let mut q = Quire::new();
        q.add_product(dec(minpos), dec(minpos));
        assert_eq!(q.raw(), 1);
        assert_eq!(q.to_f64(), 2f64.powi(-56));
        assert!(!q.inexact);
    }

    #[test]
    fn catastrophic_cancellation_is_exact() {
        // The reason the quire exists: (maxish · maxish) − (maxish · maxish)
        // + tiny must yield exactly tiny.
        let big = 2f64.powi(27);
        let tiny = 2f64.powi(-28);
        let mut q = Quire::new();
        q.add_product(dec(big), dec(big));
        q.add_product(dec(-big), dec(big));
        q.add_product(dec(tiny), dec(1.0));
        assert_eq!(q.to_f64(), tiny);
    }

    #[test]
    fn zero_products_skipped() {
        let mut q = Quire::new();
        q.add_product(Decoded::ZERO, dec(5.0));
        q.add_product(dec(5.0), Decoded::ZERO);
        assert!(q.is_zero());
    }

    #[test]
    fn nar_propagates() {
        let mut q = Quire::new();
        q.add_product(dec(1.0), dec(1.0));
        q.add_product(Decoded::NAN, dec(1.0));
        assert!(q.to_f64().is_nan());
        let mut q2 = Quire::new();
        q2.add_value(Decoded::inf(false));
        assert!(q2.to_f64().is_nan());
    }

    #[test]
    fn overflow_detected_not_silent() {
        let mut q = Quire::new();
        let big = dec(2f64.powi(28)); // posit16 maxpos
        for _ in 0..40_000 {
            q.add_product(big, big);
        }
        assert!(q.overflow);
    }

    #[test]
    fn all_hw_mode_products_exact() {
        // Every representable product of every native mode accumulates
        // exactly: check random pairs against rational arithmetic via f64
        // (all products fit f64's 52-bit mantissa exactly: ≤ 13+13 bits).
        let mut rng = crate::util::Rng::new(21);
        for p in Precision::HW_MODES {
            let mask = (1u64 << p.bits()) - 1;
            for _ in 0..2000 {
                let a = p.decode((rng.next_u64() & mask) as u32);
                let b = p.decode((rng.next_u64() & mask) as u32);
                if a.class != Class::Normal || b.class != Class::Normal {
                    continue;
                }
                let mut q = Quire::new();
                q.add_product(a, b);
                assert_eq!(q.to_f64(), a.to_f64() * b.to_f64(), "{p:?}");
                assert!(!q.inexact);
            }
        }
    }

    #[test]
    fn merge_equals_sequential() {
        let mut rng = crate::util::Rng::new(33);
        let xs: Vec<f64> = (0..64).map(|_| rng.normal()).collect();
        let ys: Vec<f64> = (0..64).map(|_| rng.normal()).collect();
        // quantize to posit8 so products are quire-exact
        let p = Precision::Posit8;
        let xs: Vec<f64> = xs.iter().map(|&x| p.quantize(x)).collect();
        let ys: Vec<f64> = ys.iter().map(|&y| p.quantize(y)).collect();
        let mut q_all = Quire::new();
        let mut q_a = Quire::new();
        let mut q_b = Quire::new();
        for i in 0..64 {
            q_all.add_product(dec(xs[i]), dec(ys[i]));
            if i % 2 == 0 {
                q_a.add_product(dec(xs[i]), dec(ys[i]));
            } else {
                q_b.add_product(dec(xs[i]), dec(ys[i]));
            }
        }
        q_a.merge(&q_b);
        assert_eq!(q_a.raw(), q_all.raw());
    }

    /// Build a posit8-quantized product list plus its single-quire
    /// accumulation (the unsharded reference).
    fn random_products(rng: &mut crate::util::Rng, k: usize) -> (Vec<(f64, f64)>, Quire) {
        let p = Precision::Posit8;
        let prods: Vec<(f64, f64)> =
            (0..k).map(|_| (p.quantize(rng.normal()), p.quantize(rng.normal()))).collect();
        let mut whole = Quire::new();
        for &(x, y) in &prods {
            whole.add_product(dec(x), dec(y));
        }
        (prods, whole)
    }

    #[test]
    fn merge_matches_single_quire_over_random_partitions() {
        // The sharding invariant: partition the K dimension into any
        // number of contiguous shards, accumulate each shard in its own
        // quire, merge — the raw accumulator must equal the single-quire
        // accumulation bit for bit, for every partition.
        let mut rng = crate::util::Rng::new(41);
        for trial in 0..20 {
            let k = 1 + (rng.next_u64() % 96) as usize;
            let (prods, whole) = random_products(&mut rng, k);
            let n_shards = 1 + (rng.next_u64() % 5) as usize;
            // random cut points (may produce empty shards — merge of an
            // untouched quire is the identity, so they must be harmless)
            let mut cuts: Vec<usize> =
                (0..n_shards - 1).map(|_| (rng.next_u64() % (k as u64 + 1)) as usize).collect();
            cuts.sort_unstable();
            cuts.insert(0, 0);
            cuts.push(k);
            let mut merged = Quire::new();
            for w in cuts.windows(2) {
                let mut part = Quire::new();
                for &(x, y) in &prods[w[0]..w[1]] {
                    part.add_product(dec(x), dec(y));
                }
                merged.merge(&part);
            }
            assert_eq!(merged.raw(), whole.raw(), "trial {trial}: k={k} cuts={cuts:?}");
            assert_eq!(merged.to_f64(), whole.to_f64());
            assert_eq!(
                (merged.overflow, merged.inexact, merged.nar),
                (whole.overflow, whole.inexact, whole.nar)
            );
        }
    }

    #[test]
    fn merge_is_associative_and_commutative() {
        let mut rng = crate::util::Rng::new(43);
        for _ in 0..20 {
            let parts: Vec<Quire> = (0..3)
                .map(|_| {
                    let (_, q) = random_products(&mut rng, 1 + (rng.next_u64() % 32) as usize);
                    q
                })
                .collect();
            let [a, b, c] = [parts[0], parts[1], parts[2]];
            // (a ⊕ b) ⊕ c
            let mut ab = a;
            ab.merge(&b);
            ab.merge(&c);
            // a ⊕ (b ⊕ c)
            let mut bc = b;
            bc.merge(&c);
            let mut a_bc = a;
            a_bc.merge(&bc);
            assert_eq!(ab.raw(), a_bc.raw(), "merge must be associative");
            // c ⊕ b ⊕ a
            let mut rev = c;
            rev.merge(&b);
            rev.merge(&a);
            assert_eq!(ab.raw(), rev.raw(), "merge must be commutative");
        }
    }

    #[test]
    fn streamed_merge_is_arrival_order_independent() {
        // The streaming dataflow merges shard partials in completion-
        // arrival order, not shard order: under ANY permutation of the
        // partials the merged accumulator, sticky flags, spill image and
        // final rounding must be bit-identical to the in-order barrier
        // merge of the same set.
        let mut rng = crate::util::Rng::new(61);
        for trial in 0..20 {
            let n_shards = 2 + (rng.next_u64() % 5) as usize;
            let parts: Vec<Quire> = (0..n_shards)
                .map(|_| {
                    let (_, mut q) =
                        random_products(&mut rng, 1 + (rng.next_u64() % 48) as usize);
                    q.inexact = rng.coin(0.2);
                    q
                })
                .collect();
            let mut in_order = Quire::new();
            for p in &parts {
                in_order.merge(p);
            }
            // random arrival order (Fisher–Yates)
            let mut perm: Vec<usize> = (0..n_shards).collect();
            for i in (1..n_shards).rev() {
                let j = (rng.next_u64() % (i as u64 + 1)) as usize;
                perm.swap(i, j);
            }
            let mut streamed = Quire::new();
            for &i in &perm {
                streamed.merge(&parts[i]);
            }
            assert_eq!(streamed.raw(), in_order.raw(), "trial {trial} perm {perm:?}");
            assert_eq!(
                (streamed.overflow, streamed.inexact, streamed.nar),
                (in_order.overflow, in_order.inexact, in_order.nar)
            );
            assert_eq!(streamed.to_spill_bytes(), in_order.to_spill_bytes());
            assert_eq!(
                streamed.round_to(Precision::Posit8),
                in_order.round_to(Precision::Posit8)
            );
        }
    }

    #[test]
    fn quire_matrix_streamed_block_merge_order_independent() {
        // matrix-level version of the same invariant: K-split partial
        // images merged full-width in any completion-arrival order
        // produce the identical merged image and rounded output
        let mut rng = crate::util::Rng::new(67);
        let n_shards = 4usize;
        let images: Vec<QuireMatrix> = (0..n_shards)
            .map(|_| {
                let data: Vec<Quire> = (0..6).map(|_| random_products(&mut rng, 8).1).collect();
                QuireMatrix::from_vec(2, 3, data)
            })
            .collect();
        let mut in_order = QuireMatrix::zeros(2, 3);
        for im in &images {
            in_order.merge_block(0, im);
        }
        for seed in [71u64, 73, 79] {
            let mut rng2 = crate::util::Rng::new(seed);
            let mut perm: Vec<usize> = (0..n_shards).collect();
            for i in (1..n_shards).rev() {
                let j = (rng2.next_u64() % (i as u64 + 1)) as usize;
                perm.swap(i, j);
            }
            let mut streamed = QuireMatrix::zeros(2, 3);
            for &i in &perm {
                streamed.merge_block(0, &images[i]);
            }
            for (s, w) in streamed.data.iter().zip(&in_order.data) {
                assert_eq!(s.raw(), w.raw(), "perm {perm:?}");
            }
            assert_eq!(streamed.round_to(Precision::Fp32), in_order.round_to(Precision::Fp32));
        }
    }

    #[test]
    fn single_shard_merge_is_identity() {
        let mut rng = crate::util::Rng::new(47);
        let (_, whole) = random_products(&mut rng, 40);
        let mut acc = Quire::new();
        acc.merge(&whole);
        assert_eq!(acc.raw(), whole.raw());
        assert_eq!(acc.round_to(Precision::Fp32), whole.round_to(Precision::Fp32));
    }

    #[test]
    fn spill_bytes_round_trip() {
        let mut rng = crate::util::Rng::new(53);
        for _ in 0..50 {
            let (_, mut q) = random_products(&mut rng, 1 + (rng.next_u64() % 64) as usize);
            q.overflow = rng.coin(0.3);
            q.inexact = rng.coin(0.3);
            q.nar = rng.coin(0.2);
            let back = Quire::from_spill_bytes(&q.to_spill_bytes());
            assert_eq!(back.raw(), q.raw());
            assert_eq!(
                (back.overflow, back.inexact, back.nar),
                (q.overflow, q.inexact, q.nar)
            );
        }
        // negative accumulators survive the i128 round trip
        let mut q = Quire::new();
        q.add_product(dec(-3.0), dec(5.0));
        assert!(q.raw() < 0);
        assert_eq!(Quire::from_spill_bytes(&q.to_spill_bytes()).raw(), q.raw());
    }

    #[test]
    fn quire_matrix_merge_blocks_and_round() {
        // a 2×4 output reduced from one K-split shard pair (full-width
        // merges) plus an N-split pair (disjoint column blocks)
        let mut rng = crate::util::Rng::new(59);
        let mk = |rng: &mut crate::util::Rng| {
            let (_, q) = random_products(rng, 8);
            q
        };
        let parts: Vec<Quire> = (0..16).map(|_| mk(&mut rng)).collect();
        let a = QuireMatrix::from_vec(2, 4, parts[..8].to_vec());
        let b = QuireMatrix::from_vec(2, 4, parts[8..].to_vec());
        let mut k_merged = QuireMatrix::zeros(2, 4);
        k_merged.merge_block(0, &a);
        k_merged.merge_block(0, &b);
        for i in 0..8 {
            let mut want = parts[i];
            want.merge(&parts[8 + i]);
            assert_eq!(k_merged.data[i].raw(), want.raw());
        }
        // N-split: left/right column halves land disjoint
        let left = QuireMatrix::from_vec(2, 2, vec![parts[0], parts[1], parts[4], parts[5]]);
        let right = QuireMatrix::from_vec(2, 2, vec![parts[2], parts[3], parts[6], parts[7]]);
        let mut n_merged = QuireMatrix::zeros(2, 4);
        n_merged.merge_block(0, &left);
        n_merged.merge_block(2, &right);
        for i in 0..8 {
            assert_eq!(n_merged.data[i].raw(), parts[i].raw(), "slot {i}");
        }
        // spill round trip + single final rounding
        let back = QuireMatrix::from_spill_bytes(2, 4, &n_merged.to_spill_bytes());
        assert_eq!(back.round_to(Precision::Fp32), n_merged.round_to(Precision::Fp32));
    }
}
