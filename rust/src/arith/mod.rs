//! Bit-accurate scalar arithmetic for every number format XR-NPE touches.
//!
//! The engine (Fig. 3 of the paper) natively supports **HFP4 (E2M1)**,
//! **Posit(4,1)**, **Posit(8,0)** and **Posit(16,1)**, selected at run
//! time by `prec_sel`. For baselines and QAT analysis we additionally
//! model FP8 (E4M3 / E5M2), FP16, BF16, FP32, Posit(32,2) and the
//! fixed-point formats used by the FxP competitor designs.
//!
//! Everything decodes into a single exact intermediate, [`Decoded`]:
//! `value = (-1)^sign · sig · 2^(scale − frac_bits)` with
//! `2^frac_bits ≤ sig < 2^(frac_bits+1)` for normal values — i.e. the
//! classic `1.f × 2^scale` form the multiplier datapath consumes. All of
//! these formats are exactly representable in `f64`, so `f64` doubles as
//! a lossless carrier between the codecs and the rest of the simulator;
//! *accumulation* exactness is provided by [`quire::Quire`], never by
//! floating point.

pub mod fixed;
pub mod fp;
pub mod posit;
pub mod quire;
pub mod tables;

pub use quire::{Quire, QuireMatrix, QUIRE_SPILL_BYTES};

/// Classification of a decoded value.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Class {
    /// Exact zero.
    Zero,
    /// Finite non-zero (normal or subnormal — already normalized).
    Normal,
    /// IEEE infinity (FP16/BF16/FP32/E5M2 only; posits have none).
    Inf,
    /// IEEE NaN, or posit NaR (Not a Real).
    Nan,
}

/// Exact decoded number: `(-1)^sign · sig · 2^(scale − frac_bits)`.
///
/// For `class == Normal`, `sig` is normalized: bit `frac_bits` is the
/// (implicit/explicit) leading one, so `sig ∈ [2^frac_bits, 2^(frac_bits+1))`
/// and `scale = ⌊log2 |value|⌋`. For other classes the numeric fields are
/// zero and must be ignored.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Decoded {
    pub class: Class,
    pub sign: bool,
    pub scale: i32,
    pub sig: u64,
    pub frac_bits: u32,
}

impl Decoded {
    pub const ZERO: Decoded =
        Decoded { class: Class::Zero, sign: false, scale: 0, sig: 0, frac_bits: 0 };
    pub const NAN: Decoded =
        Decoded { class: Class::Nan, sign: false, scale: 0, sig: 0, frac_bits: 0 };

    pub fn inf(sign: bool) -> Decoded {
        Decoded { class: Class::Inf, sign, scale: 0, sig: 0, frac_bits: 0 }
    }

    /// Exact conversion to f64 (always exact for ≤32-bit formats).
    pub fn to_f64(self) -> f64 {
        match self.class {
            Class::Zero => 0.0,
            Class::Nan => f64::NAN,
            Class::Inf => {
                if self.sign {
                    f64::NEG_INFINITY
                } else {
                    f64::INFINITY
                }
            }
            Class::Normal => {
                let mag = self.sig as f64
                    * (self.scale - self.frac_bits as i32).exp2_i();
                if self.sign {
                    -mag
                } else {
                    mag
                }
            }
        }
    }

    /// Exact decomposition of a finite non-zero f64 (normalized form).
    ///
    /// Keeps all 52 fraction bits, so the decomposition is exact.
    pub fn from_f64(x: f64) -> Decoded {
        if x == 0.0 {
            return Decoded::ZERO;
        }
        if x.is_nan() {
            return Decoded::NAN;
        }
        if x.is_infinite() {
            return Decoded::inf(x < 0.0);
        }
        let sign = x < 0.0;
        let bits = x.abs().to_bits();
        let raw_exp = ((bits >> 52) & 0x7FF) as i32;
        let mant = bits & ((1u64 << 52) - 1);
        let (scale, sig, frac_bits) = if raw_exp == 0 {
            // f64 subnormal: value = mant · 2^-1074 with the leading one at
            // bit `lead`, so scale = lead − 1074 and frac_bits = lead.
            let lead = 63 - mant.leading_zeros();
            (lead as i32 - 1074, mant, lead)
        } else {
            (raw_exp - 1023, (1u64 << 52) | mant, 52)
        };
        Decoded { class: Class::Normal, sign, scale, sig, frac_bits }
    }
}

/// `2^i` as f64 for i in the range any of our formats use.
trait Exp2I {
    fn exp2_i(self) -> f64;
}
impl Exp2I for i32 {
    #[inline]
    fn exp2_i(self) -> f64 {
        if (-1022..=1023).contains(&self) {
            // exact normal-range fast path
            f64::from_bits(((1023 + self) as u64) << 52)
        } else if (-1074..-1022).contains(&self) {
            // exact f64 subnormal power of two (powi would round to 0)
            f64::from_bits(1u64 << (self + 1074))
        } else if self < -1074 {
            0.0
        } else {
            f64::INFINITY
        }
    }
}

/// Every precision the simulator can run. The first four are the modes
/// natively supported by the XR-NPE SIMD datapath (`prec_sel`); the rest
/// exist for baselines, QAT sweeps and SoTA comparisons.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Precision {
    /// HFP4: E2M1 minifloat (±{0, .5, 1, 1.5, 2, 3, 4, 6}), no Inf/NaN.
    Fp4,
    /// Posit(4,1).
    Posit4,
    /// Posit(8,0).
    Posit8,
    /// Posit(16,1).
    Posit16,
    /// Posit(32,2) — QAT analysis only, not a hardware mode.
    Posit32,
    /// FP8 E4M3 (OCP: single NaN encoding, no Inf, max 448).
    Fp8E4M3,
    /// FP8 E5M2 (IEEE-style Inf/NaN).
    Fp8E5M2,
    /// IEEE binary16.
    Fp16,
    /// bfloat16.
    Bf16,
    /// IEEE binary32 (identity quantization; the baseline).
    Fp32,
    /// Fixed-point Q1.2 (4-bit, 2 fraction bits) — FxP competitor mode.
    Fxp4,
    /// Fixed-point Q3.4 (8-bit, 4 fraction bits).
    Fxp8,
    /// Fixed-point Q7.8 (16-bit, 8 fraction bits).
    Fxp16,
}

impl Precision {
    /// All precisions, in sweep order used by figures.
    pub const ALL: [Precision; 13] = [
        Precision::Fp32,
        Precision::Bf16,
        Precision::Fp16,
        Precision::Fp8E4M3,
        Precision::Fp8E5M2,
        Precision::Fp4,
        Precision::Posit32,
        Precision::Posit16,
        Precision::Posit8,
        Precision::Posit4,
        Precision::Fxp16,
        Precision::Fxp8,
        Precision::Fxp4,
    ];

    /// The four modes the XR-NPE datapath supports natively.
    pub const HW_MODES: [Precision; 4] =
        [Precision::Fp4, Precision::Posit4, Precision::Posit8, Precision::Posit16];

    /// Storage width in bits.
    pub fn bits(self) -> u32 {
        match self {
            Precision::Fp4 | Precision::Posit4 | Precision::Fxp4 => 4,
            Precision::Posit8
            | Precision::Fp8E4M3
            | Precision::Fp8E5M2
            | Precision::Fxp8 => 8,
            Precision::Posit16 | Precision::Fp16 | Precision::Bf16 | Precision::Fxp16 => 16,
            Precision::Posit32 | Precision::Fp32 => 32,
        }
    }

    /// SIMD lanes packed into one 16-bit engine word (paper: 4× 4-bit,
    /// 2× 8-bit, 1× 16-bit). 32-bit formats occupy two words and are not
    /// hardware modes; they report 0 lanes.
    pub fn simd_lanes(self) -> u32 {
        match self.bits() {
            4 => 4,
            8 => 2,
            16 => 1,
            _ => 0,
        }
    }

    /// Width of the mantissa multiplication the RMMEC must perform in this
    /// mode (paper §II: 2-bit for Posit(4,1)/FP4, 6-bit for Posit(8,0),
    /// 12-bit for Posit(16,1)). This is `frac_bits + hidden bit` of the
    /// widest normal significand.
    pub fn mant_mult_bits(self) -> u32 {
        match self {
            Precision::Fp4 | Precision::Posit4 => 2,
            Precision::Posit8 | Precision::Fp8E4M3 => 6, // posit(8,0): 5 frac + hidden
            Precision::Fp8E5M2 => 3,
            Precision::Posit16 => 12, // 11 frac + hidden? regime ≥2 bits → ≤12 frac incl. hidden
            Precision::Fp16 => 11,
            Precision::Bf16 => 8,
            Precision::Posit32 => 28,
            Precision::Fp32 => 24,
            Precision::Fxp4 => 4,
            Precision::Fxp8 => 8,
            Precision::Fxp16 => 16,
        }
    }

    /// True if this is a posit format.
    pub fn is_posit(self) -> bool {
        matches!(
            self,
            Precision::Posit4 | Precision::Posit8 | Precision::Posit16 | Precision::Posit32
        )
    }

    /// True if this is one of the engine's native `prec_sel` modes.
    pub fn is_hw_mode(self) -> bool {
        Precision::HW_MODES.contains(&self)
    }

    /// (n, es) for posit formats.
    pub fn posit_spec(self) -> Option<(u32, u32)> {
        match self {
            Precision::Posit4 => Some((4, 1)),
            Precision::Posit8 => Some((8, 0)),
            Precision::Posit16 => Some((16, 1)),
            Precision::Posit32 => Some((32, 2)),
            _ => None,
        }
    }

    /// Short display name matching the paper's figures.
    pub fn name(self) -> &'static str {
        match self {
            Precision::Fp4 => "FP4",
            Precision::Posit4 => "Posit(4,1)",
            Precision::Posit8 => "Posit(8,0)",
            Precision::Posit16 => "Posit(16,1)",
            Precision::Posit32 => "Posit(32,2)",
            Precision::Fp8E4M3 => "FP8-E4M3",
            Precision::Fp8E5M2 => "FP8-E5M2",
            Precision::Fp16 => "FP16",
            Precision::Bf16 => "BF16",
            Precision::Fp32 => "FP32",
            Precision::Fxp4 => "FxP4",
            Precision::Fxp8 => "FxP8",
            Precision::Fxp16 => "FxP16",
        }
    }

    /// Decode a raw encoding (low `bits()` bits) to its exact value.
    pub fn decode(self, bits: u32) -> Decoded {
        match self {
            Precision::Fp4 => fp::MiniFloat::FP4.decode(bits),
            Precision::Fp8E4M3 => fp::MiniFloat::E4M3.decode(bits),
            Precision::Fp8E5M2 => fp::MiniFloat::E5M2.decode(bits),
            Precision::Fp16 => fp::MiniFloat::FP16.decode(bits),
            Precision::Bf16 => fp::MiniFloat::BF16.decode(bits),
            Precision::Fp32 => Decoded::from_f64(f32::from_bits(bits) as f64),
            Precision::Posit4 => posit::decode(bits, 4, 1),
            Precision::Posit8 => posit::decode(bits, 8, 0),
            Precision::Posit16 => posit::decode(bits, 16, 1),
            Precision::Posit32 => posit::decode(bits, 32, 2),
            Precision::Fxp4 => fixed::decode(bits, 4, 2),
            Precision::Fxp8 => fixed::decode(bits, 8, 4),
            Precision::Fxp16 => fixed::decode(bits, 16, 8),
        }
    }

    /// Encode an f64 to the nearest representable encoding (RNE in format
    /// space; posit clamping rules: never round a non-zero to zero/NaR).
    pub fn encode(self, x: f64) -> u32 {
        match self {
            Precision::Fp4 => fp::MiniFloat::FP4.encode(x),
            Precision::Fp8E4M3 => fp::MiniFloat::E4M3.encode(x),
            Precision::Fp8E5M2 => fp::MiniFloat::E5M2.encode(x),
            Precision::Fp16 => fp::MiniFloat::FP16.encode(x),
            Precision::Bf16 => fp::MiniFloat::BF16.encode(x),
            Precision::Fp32 => (x as f32).to_bits(),
            Precision::Posit4 => posit::encode(x, 4, 1),
            Precision::Posit8 => posit::encode(x, 8, 0),
            Precision::Posit16 => posit::encode(x, 16, 1),
            Precision::Posit32 => posit::encode(x, 32, 2),
            Precision::Fxp4 => fixed::encode(x, 4, 2),
            Precision::Fxp8 => fixed::encode(x, 8, 4),
            Precision::Fxp16 => fixed::encode(x, 16, 8),
        }
    }

    /// Round-trip quantization `decode(encode(x))` — the "fake quant"
    /// the QAT flow applies. NaN-safe.
    pub fn quantize(self, x: f64) -> f64 {
        if self == Precision::Fp32 {
            return x as f32 as f64;
        }
        self.decode(self.encode(x)).to_f64()
    }

    /// Largest finite representable magnitude.
    pub fn max_value(self) -> f64 {
        match self {
            Precision::Fp32 => f32::MAX as f64,
            Precision::Fp16 => 65504.0,
            Precision::Bf16 => f32::from_bits(0x7F7F_0000) as f64,
            _ => {
                // scan top encodings — formats are ≤16 bit except posit32
                if let Some((n, es)) = self.posit_spec() {
                    return posit::maxpos(n, es);
                }
                let mask = (1u64 << self.bits()) - 1;
                let mut best = 0.0f64;
                for b in 0..=mask {
                    let d = self.decode(b as u32);
                    if d.class == Class::Normal {
                        best = best.max(d.to_f64().abs());
                    }
                }
                best
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decoded_f64_roundtrip_exact() {
        for &x in &[1.0, -1.5, 0.375, 6.0, -448.0, 3.0e-5, 2.0f64.powi(-28)] {
            let d = Decoded::from_f64(x);
            assert_eq!(d.to_f64(), x, "roundtrip {x}");
            assert_eq!(d.class, Class::Normal);
            // normalized: leading bit at frac_bits
            assert_eq!(63 - d.sig.leading_zeros(), d.frac_bits);
        }
    }

    #[test]
    fn decoded_specials() {
        assert_eq!(Decoded::from_f64(0.0).class, Class::Zero);
        assert_eq!(Decoded::from_f64(f64::NAN).class, Class::Nan);
        assert_eq!(Decoded::from_f64(f64::INFINITY).class, Class::Inf);
        assert!(Decoded::from_f64(f64::NEG_INFINITY).sign);
    }

    #[test]
    fn decoded_subnormal_f64() {
        let x = f64::from_bits(1); // smallest subnormal
        let d = Decoded::from_f64(x);
        assert_eq!(d.to_f64(), x);
        assert_eq!(d.frac_bits, 0);
        assert_eq!(d.scale, -1074);
    }

    #[test]
    fn simd_lane_counts_match_paper() {
        assert_eq!(Precision::Fp4.simd_lanes(), 4);
        assert_eq!(Precision::Posit4.simd_lanes(), 4);
        assert_eq!(Precision::Posit8.simd_lanes(), 2);
        assert_eq!(Precision::Posit16.simd_lanes(), 1);
    }

    #[test]
    fn mant_mult_widths_match_paper() {
        // §II: "from 2-bit in Posit(4,1)/FP4 to 6-bit in Posit(8,0) and
        // 12-bit in Posit(16,1)".
        assert_eq!(Precision::Fp4.mant_mult_bits(), 2);
        assert_eq!(Precision::Posit4.mant_mult_bits(), 2);
        assert_eq!(Precision::Posit8.mant_mult_bits(), 6);
        assert_eq!(Precision::Posit16.mant_mult_bits(), 12);
    }

    #[test]
    fn quantize_identity_on_representables() {
        for p in Precision::HW_MODES {
            for b in 0..(1u32 << p.bits().min(8)) {
                let v = p.decode(b).to_f64();
                if v.is_finite() {
                    assert_eq!(p.quantize(v), v, "{p:?} bits {b:#x}");
                }
            }
        }
    }

    #[test]
    fn max_values_sane() {
        assert_eq!(Precision::Fp4.max_value(), 6.0);
        assert_eq!(Precision::Fp8E4M3.max_value(), 448.0);
        assert_eq!(Precision::Posit8.max_value(), 64.0); // 2^(8-2), es=0
        assert_eq!(Precision::Posit16.max_value(), 2.0f64.powi(28));
        assert_eq!(Precision::Posit4.max_value(), 16.0); // 2^((4-2)*2)
    }
}
