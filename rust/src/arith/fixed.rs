//! Two's-complement fixed-point formats (FxP4/8/16) — the baseline the
//! FxP competitor designs (Flex-PE [11] et al.) use in Fig. 5.
//!
//! `Q(n−1−frac).frac`: value = signed(bits) / 2^frac. Per-tensor scaling
//! is the quantizer's job (`quant::entropy`); the codec here is the raw
//! datapath format.

use super::Decoded;

/// Decode the low `n` bits as Q(n−1−frac).frac.
pub fn decode(bits: u32, n: u32, frac: u32) -> Decoded {
    assert!(n <= 32 && frac < n);
    let mask: u32 = if n == 32 { u32::MAX } else { (1 << n) - 1 };
    let v = bits & mask;
    // sign-extend
    let sign_bit = 1u32 << (n - 1);
    let sv: i64 = if v & sign_bit != 0 { (v as i64) - ((mask as i64) + 1) } else { v as i64 };
    Decoded::from_f64(sv as f64 * 2f64.powi(-(frac as i32)))
}

/// Encode `x` to Q(n−1−frac).frac with round-to-nearest-even and
/// saturation.
pub fn encode(x: f64, n: u32, frac: u32) -> u32 {
    assert!(n <= 32 && frac < n);
    let mask: u32 = if n == 32 { u32::MAX } else { (1 << n) - 1 };
    if x.is_nan() {
        return 0;
    }
    let scaled = x * 2f64.powi(frac as i32);
    let hi = (1i64 << (n - 1)) - 1;
    let lo = -(1i64 << (n - 1));
    let r = round_half_even(scaled).clamp(lo, hi);
    (r as u32) & mask
}

/// decode(encode(x)).
pub fn quantize(x: f64, n: u32, frac: u32) -> f64 {
    decode(encode(x, n, frac), n, frac).to_f64()
}

fn round_half_even(t: f64) -> i64 {
    if t.is_infinite() {
        return if t > 0.0 { i64::MAX } else { i64::MIN };
    }
    let fl = t.floor();
    let fr = t - fl;
    let base = fl as i64;
    if fr > 0.5 {
        base + 1
    } else if fr < 0.5 {
        base
    } else if base % 2 == 0 {
        base
    } else {
        base + 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fxp4_q12_values() {
        // Q1.2: step 0.25, range [-2, 1.75]
        assert_eq!(decode(0b0001, 4, 2).to_f64(), 0.25);
        assert_eq!(decode(0b0111, 4, 2).to_f64(), 1.75);
        assert_eq!(decode(0b1000, 4, 2).to_f64(), -2.0);
        assert_eq!(decode(0b1111, 4, 2).to_f64(), -0.25);
        assert_eq!(decode(0, 4, 2).to_f64(), 0.0);
    }

    #[test]
    fn saturation() {
        assert_eq!(quantize(100.0, 4, 2), 1.75);
        assert_eq!(quantize(-100.0, 4, 2), -2.0);
        assert_eq!(quantize(100.0, 8, 4), 127.0 / 16.0);
    }

    #[test]
    fn rne_ties() {
        // 0.125 is halfway between 0 and 0.25 in Q1.2 → ties to even (0)
        assert_eq!(quantize(0.125, 4, 2), 0.0);
        // 0.375 halfway between 0.25 and 0.5 → even is 0.5 (bits 0b10)
        assert_eq!(quantize(0.375, 4, 2), 0.5);
        assert_eq!(quantize(-0.125, 4, 2), 0.0);
    }

    #[test]
    fn exhaustive_roundtrip_all_widths() {
        for &(n, f) in &[(4u32, 2u32), (8, 4), (16, 8)] {
            for b in 0..(1u64 << n) {
                let v = decode(b as u32, n, f).to_f64();
                assert_eq!(encode(v, n, f), b as u32, "Q({n},{f}) bits {b:#x}");
            }
        }
    }

    #[test]
    fn nan_to_zero() {
        assert_eq!(encode(f64::NAN, 8, 4), 0);
    }
}
