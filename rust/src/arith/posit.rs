//! Generic Posit(n, es) codec, bit-accurate per the posit standard
//! (softposit-compatible bit-string rounding).
//!
//! Encoding layout (MSB→LSB): `sign | regime | exponent(es bits) | fraction`.
//! Negative values are the two's complement of the positive encoding.
//! Two reserved encodings: `0…0` = zero, `10…0` = NaR.
//!
//! Rounding: round-to-nearest-even on the (unbounded) bit string truncated
//! to `n` bits — the de-facto standard implementation. Per the standard,
//! non-zero reals never round to zero (they clamp to ±minpos) and finite
//! reals never round to NaR (they clamp to ±maxpos).

use super::{Class, Decoded};

/// Largest representable posit magnitude: `2^((n−2)·2^es)`.
pub fn maxpos(n: u32, es: u32) -> f64 {
    2f64.powi(((n - 2) << es) as i32)
}

/// Smallest non-zero posit magnitude: `2^−((n−2)·2^es)`.
pub fn minpos(n: u32, es: u32) -> f64 {
    2f64.powi(-(((n - 2) << es) as i32))
}

/// Decode an n-bit posit (low `n` bits of `bits`) into its exact value.
pub fn decode(bits: u32, n: u32, es: u32) -> Decoded {
    assert!((2..=32).contains(&n), "posit n out of range");
    assert!(es <= 3, "posit es out of range");
    let mask: u32 = if n == 32 { u32::MAX } else { (1 << n) - 1 };
    let bits = bits & mask;
    if bits == 0 {
        return Decoded::ZERO;
    }
    let nar = 1u32 << (n - 1);
    if bits == nar {
        return Decoded::NAN; // posit NaR
    }
    let sign = bits & nar != 0;
    // Two's complement magnitude for negative encodings.
    let v = if sign { bits.wrapping_neg() & mask } else { bits };

    // Regime: run of identical bits starting at bit n-2.
    let body_bits = n - 1; // bits below the sign
    let r0 = (v >> (n - 2)) & 1;
    let mut run = 0u32;
    while run < body_bits && ((v >> (n - 2 - run)) & 1) == r0 {
        run += 1;
        if run == body_bits {
            break;
        }
    }
    let k: i32 = if r0 == 1 { run as i32 - 1 } else { -(run as i32) };
    // Bits consumed: run + 1 terminating bit (if any remain).
    let consumed = (run + 1).min(body_bits);
    let rem = body_bits - consumed; // bits available for exponent+fraction

    // Exponent: next up-to-es bits; missing low bits are zero.
    let e_avail = rem.min(es);
    let e_bits = if e_avail > 0 {
        ((v >> (rem - e_avail)) & ((1 << e_avail) - 1)) << (es - e_avail)
    } else {
        0
    };
    let fb = rem - e_avail; // fraction bits present
    let frac = if fb > 0 { v & ((1 << fb) - 1) } else { 0 };

    let scale = (k << es) + e_bits as i32;
    let sig = (1u64 << fb) | frac as u64;
    Decoded { class: Class::Normal, sign, scale, sig, frac_bits: fb }
}

/// Encode `x` to the nearest n-bit posit (low `n` bits of the result).
pub fn encode(x: f64, n: u32, es: u32) -> u32 {
    assert!((2..=32).contains(&n), "posit n out of range");
    let mask: u32 = if n == 32 { u32::MAX } else { (1 << n) - 1 };
    if x == 0.0 {
        return 0;
    }
    if x.is_nan() || x.is_infinite() {
        return (1u32 << (n - 1)) & mask; // NaR
    }
    let sign = x < 0.0;
    let a = x.abs();

    // Clamp to the representable range first (standard posit saturation:
    // no rounding to zero / NaR).
    let top = maxpos(n, es);
    let bot = minpos(n, es);
    let body = if a >= top {
        (mask >> 1) as u128 // maxpos encoding: 0111…1
    } else if a <= bot {
        1u128 // minpos encoding
    } else {
        // Decompose a = 1.f × 2^scale exactly (normal f64 guaranteed here).
        let d = Decoded::from_f64(a);
        debug_assert_eq!(d.frac_bits, 52);
        let scale = d.scale;
        let frac52 = d.sig & ((1u64 << 52) - 1);

        // scale = k·2^es + e with 0 ≤ e < 2^es.
        let k = scale.div_euclid(1 << es);
        let e = scale.rem_euclid(1 << es) as u32;

        // Assemble the unbounded bit string (below the sign bit), MSB
        // first, into a u128: regime, exponent, fraction.
        let mut bs: u128 = 0;
        let mut len: u32 = 0;
        let push = |bs: &mut u128, len: &mut u32, bit: u32| {
            *bs = (*bs << 1) | bit as u128;
            *len += 1;
        };
        if k >= 0 {
            for _ in 0..(k + 1) {
                push(&mut bs, &mut len, 1);
            }
            push(&mut bs, &mut len, 0);
        } else {
            for _ in 0..(-k) {
                push(&mut bs, &mut len, 0);
            }
            push(&mut bs, &mut len, 1);
        }
        for i in (0..es).rev() {
            push(&mut bs, &mut len, (e >> i) & 1);
        }
        // 52 fraction bits; the clamp above bounds the regime length to
        // ≤ n ≤ 32 bits, so len ≤ 33 + es + 52 < 96 — fits u128.
        bs = (bs << 52) | frac52 as u128;
        len += 52;

        // Round-to-nearest-even at n−1 bits.
        let keep = n - 1;
        if len <= keep {
            bs << (keep - len)
        } else {
            let drop = len - keep;
            let topbits = bs >> drop;
            let guard = (bs >> (drop - 1)) & 1;
            let sticky = if drop > 1 { (bs & ((1u128 << (drop - 1)) - 1)) != 0 } else { false };
            let lsb = topbits & 1;
            let mut r = topbits;
            if guard == 1 && (sticky || lsb == 1) {
                r += 1;
            }
            // Carry out of n−1 bits ⇒ we rounded past maxpos; clamp.
            if r >> keep != 0 {
                (mask >> 1) as u128
            } else if r == 0 {
                1 // never round a non-zero to zero
            } else {
                r
            }
        }
    };

    let body = body as u32 & (mask >> 1);
    if sign {
        body.wrapping_neg() & mask
    } else {
        body
    }
}

/// Quantize: decode(encode(x)) as f64. NaR → NaN.
pub fn quantize(x: f64, n: u32, es: u32) -> f64 {
    decode(encode(x, n, es), n, es).to_f64()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_posit8_values() {
        // posit(8,0): 0x40 = 1.0, 0x20 = 0.5, 0x60 = 2.0, 0x01 = minpos = 2^-6
        assert_eq!(decode(0x40, 8, 0).to_f64(), 1.0);
        assert_eq!(decode(0x20, 8, 0).to_f64(), 0.5);
        assert_eq!(decode(0x60, 8, 0).to_f64(), 2.0);
        assert_eq!(decode(0x01, 8, 0).to_f64(), 2f64.powi(-6));
        assert_eq!(decode(0x7F, 8, 0).to_f64(), 64.0); // maxpos
        // negative: -1.0 is two's complement of 0x40 → 0xC0
        assert_eq!(decode(0xC0, 8, 0).to_f64(), -1.0);
    }

    #[test]
    fn known_posit16_values() {
        // posit(16,1): 0x4000 = 1.0, maxpos = 2^28, minpos = 2^-28
        assert_eq!(decode(0x4000, 16, 1).to_f64(), 1.0);
        assert_eq!(decode(0x7FFF, 16, 1).to_f64(), 2f64.powi(28));
        assert_eq!(decode(0x0001, 16, 1).to_f64(), 2f64.powi(-28));
        // 0x5000: sign 0, regime "10" (k=0), e=1 → 2^1, frac 0 → 2.0
        assert_eq!(decode(0x5000, 16, 1).to_f64(), 2.0);
    }

    #[test]
    fn known_posit4_values() {
        // posit(4,1): encodings 0..15 — the full value set.
        let expect = [
            0.0, 0.0625, 0.25, 0.5, 1.0, 2.0, 4.0, 16.0, // 0x0..=0x7
            f64::NAN, -16.0, -4.0, -2.0, -1.0, -0.5, -0.25, -0.0625,
        ];
        for b in 0..16u32 {
            let v = decode(b, 4, 1).to_f64();
            if expect[b as usize].is_nan() {
                assert!(v.is_nan(), "bits {b:#x}");
            } else {
                assert_eq!(v, expect[b as usize], "bits {b:#x}");
            }
        }
    }

    #[test]
    fn specials() {
        assert_eq!(decode(0, 16, 1).class, Class::Zero);
        assert_eq!(decode(0x8000, 16, 1).class, Class::Nan);
        assert_eq!(encode(0.0, 16, 1), 0);
        assert_eq!(encode(f64::NAN, 16, 1), 0x8000);
        assert_eq!(encode(f64::INFINITY, 16, 1), 0x8000);
    }

    #[test]
    fn saturation_rules() {
        // above maxpos clamps to maxpos, below minpos clamps to minpos
        assert_eq!(encode(1e30, 16, 1), 0x7FFF);
        assert_eq!(encode(1e-30, 16, 1), 0x0001);
        assert_eq!(encode(-1e30, 16, 1), 0x8001); // -maxpos
        assert_eq!(encode(-1e-30, 16, 1), 0xFFFF); // -minpos
    }

    fn exhaustive_roundtrip(n: u32, es: u32) {
        let count = 1u64 << n;
        for b in 0..count {
            let d = decode(b as u32, n, es);
            if d.class != Class::Normal {
                continue;
            }
            let v = d.to_f64();
            let back = encode(v, n, es);
            assert_eq!(back, b as u32, "posit({n},{es}) bits {b:#x} value {v}");
            // normalization invariant
            assert_eq!(63 - d.sig.leading_zeros(), d.frac_bits);
        }
    }

    #[test]
    fn roundtrip_posit4() {
        exhaustive_roundtrip(4, 1);
    }
    #[test]
    fn roundtrip_posit8() {
        exhaustive_roundtrip(8, 0);
    }
    #[test]
    fn roundtrip_posit16() {
        exhaustive_roundtrip(16, 1);
    }
    #[test]
    fn roundtrip_posit6_es2() {
        exhaustive_roundtrip(6, 2); // odd config to exercise generic paths
    }

    #[test]
    fn decode_monotonic_posit16() {
        // Positive encodings 1..=0x7FFF decode to strictly increasing values.
        let mut prev = f64::NEG_INFINITY;
        for b in 1u32..=0x7FFF {
            let v = decode(b, 16, 1).to_f64();
            assert!(v > prev, "bits {b:#x}: {v} !> {prev}");
            prev = v;
        }
    }

    #[test]
    fn encode_nearest_posit8() {
        // Midpoints and nearby values round correctly (spot checks).
        // Between 1.0 (0x40) and next 1.0625? posit(8,0): after 0x40 comes
        // 0x41 = 1 + 1/32 = 1.03125.
        assert_eq!(decode(0x41, 8, 0).to_f64(), 1.03125);
        assert_eq!(encode(1.01, 8, 0), 0x40);
        assert_eq!(encode(1.03, 8, 0), 0x41);
        // exact midpoint 1.015625 → ties to even → 0x40
        assert_eq!(encode(1.015625, 8, 0), 0x40);
        // midpoint between 0x41 and 0x42 (1.046875) → ties to even → 0x42
        assert_eq!(encode(1.046875, 8, 0), 0x42);
    }

    #[test]
    fn encode_nearest_is_truly_nearest_posit16() {
        // randomized nearest-value check against a scan of neighbours
        let mut rng = crate::util::Rng::new(99);
        for _ in 0..2000 {
            let x = rng.normal() * 4.0;
            let b = encode(x, 16, 1);
            let v = decode(b, 16, 1).to_f64();
            let err = (v - x).abs();
            // compare against both neighbours
            for nb in [b.wrapping_sub(1) & 0xFFFF, (b + 1) & 0xFFFF] {
                let d = decode(nb, 16, 1);
                if d.class == Class::Normal {
                    let e2 = (d.to_f64() - x).abs();
                    assert!(
                        err <= e2 + 1e-18,
                        "x={x}: chose {v} (err {err}) but neighbour {} has err {e2}",
                        d.to_f64()
                    );
                }
            }
        }
    }

    #[test]
    fn negation_symmetry() {
        for b in 1u32..256 {
            let v = decode(b, 8, 0);
            if v.class != Class::Normal {
                continue;
            }
            let neg = encode(-v.to_f64(), 8, 0);
            assert_eq!(neg, b.wrapping_neg() & 0xFF);
        }
    }
}
