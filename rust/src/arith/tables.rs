//! Precomputed decode/quantize tables — the simulator's hot-path lookup
//! structures (§Perf: replaces per-MAC bit-scanning with O(1) loads).
//!
//! The hardware decodes operands combinationally every cycle; the
//! simulator amortizes the same work into per-precision tables built once
//! per process (≤ 2^16 entries — at most 1 MiB of [`Decoded`] per 16-bit
//! format).
//!
//! **Quantization semantics.** `PrecTable::quantize` must agree *exactly*
//! with `Precision::quantize` (the codec), including posit bit-string
//! rounding — which is **not** value-nearest when the truncation point
//! falls inside the regime/exponent field (e.g. Posit(4,1) rounds 9.0 up
//! to 16, not down to 4, because the cut bit is the exponent bit). We
//! therefore precompute, by bisection over f64 bit space (monotone for
//! positive floats), the exact decision *thresholds* between adjacent
//! representable values, and look those up. Both the FP formats and
//! posits negate symmetrically, so thresholds are stored for the positive
//! half only.

use super::{Class, Decoded, Precision};
use std::collections::HashMap;
use std::sync::{Mutex, OnceLock};

/// Decode + quantize tables for one precision.
pub struct PrecTable {
    pub prec: Precision,
    /// `decoded[bits]` — exact decode of every encoding.
    pub decoded: Vec<Decoded>,
    /// `values[bits]` — f32 value of every encoding (NaN for NaR).
    pub values: Vec<f32>,
    /// Non-negative representable values, ascending, starting at 0 (or the
    /// smallest non-negative value if 0 is not representable — never the
    /// case for our formats).
    pos_vals: Vec<f64>,
    /// `thresholds[i]` = smallest positive f64 that the codec rounds to
    /// `pos_vals[i + 1]`. len = pos_vals.len() − 1.
    thresholds: Vec<f64>,
    /// Encoding of each `pos_vals` entry (for the fast encode path).
    pos_enc: Vec<u32>,
    /// How to negate a positive encoding (None ⇒ format is asymmetric,
    /// fall back to the codec — FxP two's complement min has no positive
    /// counterpart).
    neg: Option<NegRule>,
}

/// Sign-application rule for symmetric formats.
#[derive(Clone, Copy)]
enum NegRule {
    /// Two's complement in n bits (posits).
    TwosComplement(u32),
    /// OR the sign bit (sign-magnitude minifloats).
    SignBit(u32),
}

impl PrecTable {
    fn build(prec: Precision) -> PrecTable {
        assert!(prec.bits() <= 16, "PrecTable only for ≤16-bit formats");
        let n = 1usize << prec.bits();
        let mut decoded = Vec::with_capacity(n);
        let mut values = Vec::with_capacity(n);
        let mut pos_vals = vec![0.0f64];
        for b in 0..n as u32 {
            let d = prec.decode(b);
            decoded.push(d);
            let v = d.to_f64();
            values.push(v as f32);
            if d.class == Class::Normal && !d.sign {
                pos_vals.push(v);
            }
        }
        // total_cmp: the candidate values are finite by construction
        pos_vals.sort_by(|a, b| a.total_cmp(b));
        pos_vals.dedup();
        let pos_enc: Vec<u32> = pos_vals.iter().map(|&v| prec.encode(v)).collect();
        let neg = match prec {
            Precision::Fxp4 | Precision::Fxp8 | Precision::Fxp16 => None,
            p if p.is_posit() => Some(NegRule::TwosComplement(p.bits())),
            p => Some(NegRule::SignBit(1u32 << (p.bits() - 1))),
        };

        // Bisect each adjacent pair for the codec's decision threshold.
        let mut thresholds = Vec::with_capacity(pos_vals.len() - 1);
        for w in pos_vals.windows(2) {
            let (lo, hi) = (w[0], w[1]);
            debug_assert_eq!(prec.quantize(lo), lo);
            debug_assert_eq!(prec.quantize(hi), hi);
            // smallest positive-float bits whose quantization != lo
            let mut a = lo.to_bits(); // quantizes to lo
            let mut b = hi.to_bits(); // quantizes to hi (or beyond lo anyway)
            while b - a > 1 {
                let m = a + (b - a) / 2;
                if prec.quantize(f64::from_bits(m)) == lo {
                    a = m;
                } else {
                    b = m;
                }
            }
            thresholds.push(f64::from_bits(b));
        }
        PrecTable { prec, decoded, values, pos_vals, thresholds, pos_enc, neg }
    }

    /// Exact decode of an encoding.
    #[inline]
    pub fn decode(&self, bits: u32) -> Decoded {
        self.decoded[bits as usize & (self.decoded.len() - 1)]
    }

    /// f32 value of an encoding.
    #[inline]
    pub fn value(&self, bits: u32) -> f32 {
        self.values[bits as usize & (self.values.len() - 1)]
    }

    /// Nearest representable encoding. Fast path: threshold lookup +
    /// sign rule (§Perf — this is the array's input-processing stage,
    /// M·K + K·N calls per GEMM); asymmetric formats and specials fall
    /// back to the codec. Agrees with `Precision::encode` exactly
    /// (tested).
    pub fn encode(&self, x: f64) -> u32 {
        if x.is_nan() {
            return self.prec.encode(x);
        }
        let Some(neg) = self.neg else {
            return self.prec.encode(x);
        };
        let a = x.abs();
        let idx = self.thresholds.partition_point(|&t| t <= a);
        let enc = self.pos_enc[idx];
        if x.is_sign_negative() && enc != 0 {
            match neg {
                NegRule::TwosComplement(bits) => {
                    enc.wrapping_neg() & (((1u64 << bits) - 1) as u32)
                }
                NegRule::SignBit(bit) => enc | bit,
            }
        } else if x.is_sign_negative() {
            // −0 / underflow-to-zero: FP keeps a sign bit, posit has one 0
            match neg {
                NegRule::TwosComplement(_) => 0,
                NegRule::SignBit(bit) => enc | bit,
            }
        } else {
            enc
        }
    }

    /// Codec-exact fake quantization of a value (threshold lookup).
    pub fn quantize(&self, x: f64) -> f64 {
        if x.is_nan() {
            return self.prec.quantize(x); // format-specific NaN policy
        }
        let neg = x < 0.0;
        let a = x.abs();
        let idx = self.thresholds.partition_point(|&t| t <= a);
        let v = self.pos_vals[idx];
        if neg {
            -v
        } else {
            v
        }
    }

    /// Quantize a whole slice in place.
    pub fn quantize_slice(&self, xs: &mut [f32]) {
        for v in xs.iter_mut() {
            *v = self.quantize(*v as f64) as f32;
        }
    }

    /// All distinct non-negative representable values (ascending, from 0).
    pub fn positive_values(&self) -> &[f64] {
        &self.pos_vals
    }
}

/// Process-wide table cache.
static CACHE: OnceLock<Mutex<HashMap<Precision, &'static PrecTable>>> = OnceLock::new();

/// Get (building on first use) the table for `prec`. Tables are leaked
/// intentionally: one per precision per process, used for the entire run.
pub fn table(prec: Precision) -> &'static PrecTable {
    let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    // clear poisoning: the map only ever grows with leaked statics, so
    // it is consistent even if a panicking thread held the lock
    let mut map = match cache.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    };
    if let Some(t) = map.get(&prec) {
        return t;
    }
    let t: &'static PrecTable = Box::leak(Box::new(PrecTable::build(prec)));
    map.insert(prec, t);
    t
}

/// Quantize through the table cache (convenience; Fp32 is identity at f32
/// resolution, 32-bit formats bypass tables).
pub fn quantize(prec: Precision, x: f64) -> f64 {
    match prec {
        Precision::Fp32 => x as f32 as f64,
        Precision::Posit32 => prec.quantize(x),
        _ => table(prec).quantize(x),
    }
}

/// Decode an encoding to its value through the table cache (32-bit
/// formats go through the codec directly).
pub fn decode_value(prec: Precision, bits: u32) -> f64 {
    match prec {
        Precision::Fp32 | Precision::Posit32 => prec.decode(bits).to_f64(),
        _ => table(prec).value(bits) as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_matches_codec_decode() {
        for p in [Precision::Fp4, Precision::Posit4, Precision::Posit8, Precision::Fp8E4M3] {
            let t = table(p);
            for b in 0..(1u32 << p.bits()) {
                assert_eq!(t.decode(b), p.decode(b), "{p:?} {b:#x}");
            }
        }
    }

    #[test]
    fn table_quantize_matches_codec_quantize() {
        let mut rng = crate::util::Rng::new(17);
        for p in [
            Precision::Fp4,
            Precision::Posit4,
            Precision::Posit8,
            Precision::Posit16,
            Precision::Fp8E4M3,
            Precision::Bf16,
        ] {
            let t = table(p);
            for i in 0..20_000 {
                let x = match i % 4 {
                    0 => rng.normal() * 8.0,
                    1 => rng.normal() * 0.01,
                    2 => rng.normal() * 1e4,
                    _ => rng.range(-20.0, 20.0),
                };
                let a = t.quantize(x);
                let b = p.quantize(x);
                assert_eq!(a, b, "{p:?} at x={x}");
            }
        }
    }

    #[test]
    fn posit4_bitstring_rounding_threshold() {
        // Posit(4,1) has values … 4, 16(maxpos). The codec's bit-string
        // rounding cuts at the exponent bit → geometric-style threshold 8,
        // NOT the arithmetic midpoint 10. The table must reproduce this.
        let t = table(Precision::Posit4);
        assert_eq!(t.quantize(7.9), 4.0);
        assert_eq!(t.quantize(9.0), 16.0);
        assert_eq!(Precision::Posit4.quantize(9.0), 16.0); // codec agrees
    }

    #[test]
    fn quantize_saturates_at_extremes() {
        let t = table(Precision::Fp4);
        assert_eq!(t.quantize(1e9), 6.0);
        assert_eq!(t.quantize(-1e9), -6.0);
        // posit: huge values go to maxpos, tiny non-zero to minpos
        let tp = table(Precision::Posit8);
        assert_eq!(tp.quantize(1e20), 64.0);
        assert_eq!(tp.quantize(1e-20), 2f64.powi(-6));
    }

    #[test]
    fn posit16_table_size() {
        let t = table(Precision::Posit16);
        assert_eq!(t.decoded.len(), 65536);
        assert_eq!(t.value(0x4000), 1.0);
        // 0, then 2^15 - 1 positive values
        assert_eq!(t.positive_values().len(), 32768);
    }

    #[test]
    fn nan_handling_fp_vs_posit() {
        // FP4 squashes NaN to 0; posit quantize(NaN) = NaR -> NaN
        assert_eq!(quantize(Precision::Fp4, f64::NAN), 0.0);
        assert!(quantize(Precision::Posit8, f64::NAN).is_nan());
    }

    #[test]
    fn fast_encode_matches_codec() {
        let mut rng = crate::util::Rng::new(23);
        for p in [
            Precision::Fp4,
            Precision::Posit4,
            Precision::Posit8,
            Precision::Posit16,
            Precision::Fp8E4M3,
            Precision::Bf16,
            Precision::Fxp8,
        ] {
            let t = table(p);
            for i in 0..20_000 {
                let x = match i % 5 {
                    0 => rng.normal() * 4.0,
                    1 => rng.normal() * 1e-4,
                    2 => rng.normal() * 1e5,
                    3 => rng.range(-1.0, 1.0),
                    _ => -rng.normal().abs() * 8.0,
                };
                // encodings must produce the same decoded value (FP ±0
                // and redundant encodings may differ in bits, never value)
                let fast = t.encode(x);
                let codec = p.encode(x);
                let vf = p.decode(fast).to_f64();
                let vc = p.decode(codec).to_f64();
                assert!(
                    vf == vc || (vf == 0.0 && vc == 0.0),
                    "{p:?} x={x}: fast {fast:#x}->{vf} codec {codec:#x}->{vc}"
                );
            }
        }
    }

    #[test]
    fn exact_threshold_behaviour() {
        // At an exact threshold the table and codec must still agree
        // (thresholds are inclusive-up by construction).
        let t = table(Precision::Fp4);
        for &x in &[0.25, 0.75, 1.25, 1.75, 2.5, 3.5, 5.0] {
            assert_eq!(t.quantize(x), Precision::Fp4.quantize(x), "x={x}");
        }
    }
}
