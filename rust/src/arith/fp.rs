//! Generic minifloat codec: HFP4 (E2M1), FP8 (E4M3 / E5M2), FP16, BF16.
//!
//! One parameterized implementation covers every IEEE-style format in the
//! paper. Three "flavors" capture how the top exponent code is spent:
//!
//! * [`Flavor::Ieee`] — top exponent reserved for Inf/NaN (FP16, BF16,
//!   E5M2).
//! * [`Flavor::FiniteNan`] — OCP E4M3: only `S.1111.111` is NaN, the rest
//!   of the top exponent is numeric (max 448); no Inf, overflow saturates.
//! * [`Flavor::Finite`] — HFP4/MXFP4-style: no Inf/NaN at all; the whole
//!   code space is numeric (FP4 max = 6.0); NaN inputs quantize to 0,
//!   overflow saturates.
//!
//! Encoding is round-to-nearest-even with full subnormal support.

use super::{Class, Decoded};

/// How the format spends its top exponent code.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Flavor {
    Ieee,
    FiniteNan,
    Finite,
}

/// A sign + exponent + mantissa minifloat format.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MiniFloat {
    pub e_bits: u32,
    pub m_bits: u32,
    pub bias: i32,
    pub flavor: Flavor,
    pub name: &'static str,
}

impl MiniFloat {
    /// HFP4: E2M1, bias 1 — values ±{0, 0.5, 1, 1.5, 2, 3, 4, 6}.
    pub const FP4: MiniFloat =
        MiniFloat { e_bits: 2, m_bits: 1, bias: 1, flavor: Flavor::Finite, name: "FP4" };
    /// OCP FP8 E4M3.
    pub const E4M3: MiniFloat =
        MiniFloat { e_bits: 4, m_bits: 3, bias: 7, flavor: Flavor::FiniteNan, name: "E4M3" };
    /// OCP FP8 E5M2 (IEEE-style specials).
    pub const E5M2: MiniFloat =
        MiniFloat { e_bits: 5, m_bits: 2, bias: 15, flavor: Flavor::Ieee, name: "E5M2" };
    /// IEEE binary16.
    pub const FP16: MiniFloat =
        MiniFloat { e_bits: 5, m_bits: 10, bias: 15, flavor: Flavor::Ieee, name: "FP16" };
    /// bfloat16.
    pub const BF16: MiniFloat =
        MiniFloat { e_bits: 8, m_bits: 7, bias: 127, flavor: Flavor::Ieee, name: "BF16" };

    /// Total storage bits.
    pub fn bits(self) -> u32 {
        1 + self.e_bits + self.m_bits
    }

    fn exp_mask(self) -> u32 {
        (1 << self.e_bits) - 1
    }

    fn mant_mask(self) -> u32 {
        (1 << self.m_bits) - 1
    }

    /// Scale (unbiased exponent) of the smallest normal.
    fn min_normal_scale(self) -> i32 {
        1 - self.bias
    }

    /// Largest exponent *field* that holds numeric values.
    fn max_numeric_exp_field(self) -> u32 {
        match self.flavor {
            Flavor::Ieee => self.exp_mask() - 1,
            Flavor::FiniteNan | Flavor::Finite => self.exp_mask(),
        }
    }

    /// Largest finite value.
    pub fn max_value(self) -> f64 {
        let e = self.max_numeric_exp_field() as i32 - self.bias;
        let mut mant = self.mant_mask();
        if self.flavor == Flavor::FiniteNan {
            mant -= 1; // top mantissa in top exponent is NaN
        }
        (1.0 + mant as f64 / (1u64 << self.m_bits) as f64) * 2f64.powi(e)
    }

    /// Decode the low `bits()` bits.
    pub fn decode(self, raw: u32) -> Decoded {
        let raw = raw & ((1u32 << self.bits()) - 1);
        let sign = (raw >> (self.bits() - 1)) & 1 == 1;
        let exp = (raw >> self.m_bits) & self.exp_mask();
        let mant = raw & self.mant_mask();
        if exp == self.exp_mask() {
            match self.flavor {
                Flavor::Ieee => {
                    return if mant == 0 { Decoded::inf(sign) } else { Decoded::NAN };
                }
                Flavor::FiniteNan => {
                    if mant == self.mant_mask() {
                        return Decoded::NAN;
                    }
                    // else numeric — fall through
                }
                Flavor::Finite => {} // numeric
            }
        }
        if exp == 0 {
            if mant == 0 {
                return Decoded::ZERO;
            }
            // subnormal: value = mant · 2^(min_normal_scale − m_bits)
            let lead = 31 - mant.leading_zeros();
            return Decoded {
                class: Class::Normal,
                sign,
                scale: self.min_normal_scale() - self.m_bits as i32 + lead as i32,
                sig: mant as u64,
                frac_bits: lead,
            };
        }
        Decoded {
            class: Class::Normal,
            sign,
            scale: exp as i32 - self.bias,
            sig: ((1 << self.m_bits) | mant) as u64,
            frac_bits: self.m_bits,
        }
    }

    /// Encode `x` with round-to-nearest-even (subnormal-aware).
    pub fn encode(self, x: f64) -> u32 {
        let sign_bit = 1u32 << (self.bits() - 1);
        if x.is_nan() {
            return match self.flavor {
                Flavor::Ieee => (self.exp_mask() << self.m_bits) | 1, // a quiet NaN
                Flavor::FiniteNan => (self.exp_mask() << self.m_bits) | self.mant_mask(),
                Flavor::Finite => 0, // FP4: NaN squashes to 0 (documented)
            };
        }
        let sign = x.is_sign_negative();
        let s = if sign { sign_bit } else { 0 };
        if x == 0.0 {
            return s;
        }
        if x.is_infinite() {
            return match self.flavor {
                Flavor::Ieee => s | (self.exp_mask() << self.m_bits),
                _ => s | self.encode_max(),
            };
        }
        let a = x.abs();
        let d = Decoded::from_f64(a);

        if d.scale >= self.min_normal_scale() {
            // Candidate normal: round the 52-bit significand to m_bits.
            let shift = 52 - self.m_bits;
            let (mut sig, carry) = rne_shift(d.sig, shift);
            let mut scale = d.scale;
            if carry {
                sig = 1 << self.m_bits; // 10…0 — rounding overflowed 1.11…1
                scale += 1;
            }
            let exp_field = scale + self.bias;
            if exp_field > self.max_numeric_exp_field() as i32 {
                return self.overflow(s);
            }
            let mut mant = (sig as u32) & self.mant_mask();
            let mut exp_field = exp_field as u32;
            // FiniteNan: the all-ones (exp, mant) slot is NaN → clamp down.
            if self.flavor == Flavor::FiniteNan
                && exp_field == self.exp_mask()
                && mant == self.mant_mask()
            {
                // rounded into the NaN slot: saturate to max finite
                mant -= 1;
                // (exp stays)
                let _ = &mut exp_field;
            }
            s | (exp_field << self.m_bits) | mant
        } else {
            // Subnormal candidate: quantum = 2^(min_normal_scale − m_bits).
            let q = self.min_normal_scale() - self.m_bits as i32;
            // t = a / 2^q — exact scaling by a power of two.
            let t = a * 2f64.powi(-q);
            let r = round_half_even_f64(t);
            if r == 0 {
                return s; // underflow to (signed) zero
            }
            // r == 2^m_bits lands exactly on the smallest normal; the bit
            // pattern works out because r then occupies the exponent LSB.
            debug_assert!(r <= (1 << self.m_bits));
            s | r as u32
        }
    }

    fn encode_max(self) -> u32 {
        let mut mant = self.mant_mask();
        if self.flavor == Flavor::FiniteNan {
            mant -= 1;
        }
        (self.max_numeric_exp_field() << self.m_bits) | mant
    }

    fn overflow(self, s: u32) -> u32 {
        match self.flavor {
            Flavor::Ieee => s | (self.exp_mask() << self.m_bits), // Inf
            _ => s | self.encode_max(),                           // saturate
        }
    }

    /// decode(encode(x)) as f64.
    pub fn quantize(self, x: f64) -> f64 {
        self.decode(self.encode(x)).to_f64()
    }
}

/// Shift `sig` right by `shift` with round-to-nearest-even; returns
/// (rounded, carried_out_of_width) where width is the pre-shift leading-one
/// position minus shift.
fn rne_shift(sig: u64, shift: u32) -> (u64, bool) {
    if shift == 0 {
        return (sig, false);
    }
    let top = sig >> shift;
    let guard = (sig >> (shift - 1)) & 1;
    let sticky = if shift > 1 { sig & ((1u64 << (shift - 1)) - 1) != 0 } else { false };
    let lead = 63 - sig.leading_zeros();
    let width_after = lead - shift; // leading-one position after shift
    let mut r = top;
    if guard == 1 && (sticky || (top & 1) == 1) {
        r += 1;
    }
    let carry = (63 - r.leading_zeros()) > width_after;
    (r, carry)
}

/// Round f64 to nearest integer, ties to even, as u64 (input must be ≥ 0
/// and small).
fn round_half_even_f64(t: f64) -> u64 {
    let fl = t.floor();
    let fr = t - fl;
    let base = fl as u64;
    if fr > 0.5 {
        base + 1
    } else if fr < 0.5 {
        base
    } else if base % 2 == 0 {
        base
    } else {
        base + 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fp4_value_set() {
        // positive encodings 0..=7: 0, .5, 1, 1.5, 2, 3, 4, 6
        let expect = [0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0];
        for b in 0..8u32 {
            assert_eq!(MiniFloat::FP4.decode(b).to_f64(), expect[b as usize], "bits {b}");
        }
        // negatives mirror
        for b in 0..8u32 {
            let v = MiniFloat::FP4.decode(b | 8).to_f64();
            assert_eq!(v, -expect[b as usize], "bits {}", b | 8);
        }
    }

    #[test]
    fn fp4_encode_rounds_to_nearest_even() {
        let f = MiniFloat::FP4;
        assert_eq!(f.quantize(0.24), 0.0); // below 0.25 → 0
        assert_eq!(f.quantize(0.25), 0.0); // tie 0 vs 0.5 → even (0)
        assert_eq!(f.quantize(0.3), 0.5);
        assert_eq!(f.quantize(1.25), 1.0); // tie 1 vs 1.5 → even mant (1.0)
        assert_eq!(f.quantize(1.75), 2.0); // tie 1.5 vs 2 → even (2.0)
        assert_eq!(f.quantize(2.5), 2.0); // tie 2 vs 3 → even (2)
        assert_eq!(f.quantize(5.0), 4.0); // tie 4 vs 6 → even (4)
        assert_eq!(f.quantize(5.1), 6.0);
        assert_eq!(f.quantize(100.0), 6.0); // saturate
        assert_eq!(f.quantize(-100.0), -6.0);
        assert_eq!(f.quantize(f64::INFINITY), 6.0);
        assert_eq!(f.quantize(f64::NAN), 0.0);
    }

    #[test]
    fn e4m3_landmarks() {
        let f = MiniFloat::E4M3;
        assert_eq!(f.max_value(), 448.0);
        assert_eq!(f.quantize(448.0), 448.0);
        assert_eq!(f.quantize(1e6), 448.0); // saturating overflow
        assert_eq!(f.decode(0x7F).class, Class::Nan); // S.1111.111
        assert_eq!(f.decode(0x78).to_f64(), 256.0); // exp=15 numeric
        // smallest subnormal: 2^-9
        assert_eq!(f.decode(0x01).to_f64(), 2f64.powi(-9));
        assert_eq!(f.quantize(1.0), 1.0);
        assert!(f.quantize(f64::NAN).is_nan());
    }

    #[test]
    fn e5m2_ieee_specials() {
        let f = MiniFloat::E5M2;
        assert_eq!(f.decode(0x7C).class, Class::Inf);
        assert_eq!(f.decode(0x7D).class, Class::Nan);
        assert_eq!(f.max_value(), 57344.0);
        assert_eq!(f.quantize(1e9), f64::INFINITY); // IEEE overflow → Inf
    }

    #[test]
    fn fp16_matches_native_f32_path() {
        let f = MiniFloat::FP16;
        for &x in &[0.0, 1.0, -2.5, 65504.0, 6.1e-5, 5.96e-8, 0.1, 3.14159] {
            let q = f.quantize(x);
            // compare against decode of the canonical half-precision bits
            // computed by the generic algorithm itself (self-consistency)
            let q2 = f.quantize(q);
            assert_eq!(q, q2, "idempotent at {x}");
        }
        assert_eq!(f.quantize(65504.0), 65504.0);
        assert_eq!(f.quantize(1e6), f64::INFINITY);
        // known: 0.1 → 0x2E66 → 0.0999755859375
        assert!((f.quantize(0.1) - 0.0999755859375).abs() < 1e-12);
    }

    #[test]
    fn bf16_is_truncated_f32_rne() {
        let f = MiniFloat::BF16;
        for &x in &[1.0f32, -3.75, 0.1, 1234.5, 1e-30] {
            let expect = {
                // round f32 to bf16 via RNE on the upper 16 bits
                let b = x.to_bits();
                let lsb = (b >> 16) & 1;
                let rounded = (b + 0x7FFF + lsb) >> 16;
                f32::from_bits(rounded << 16) as f64
            };
            assert_eq!(f.quantize(x as f64), expect, "x={x}");
        }
    }

    fn exhaustive_roundtrip(f: MiniFloat) {
        for b in 0..(1u32 << f.bits()) {
            let d = f.decode(b);
            if d.class != Class::Normal {
                continue;
            }
            let v = d.to_f64();
            let back = f.encode(v);
            // -0 vs 0 aside, the encoding must round-trip
            assert_eq!(back, b, "{} bits {b:#x} value {v}", f.name);
        }
    }

    #[test]
    fn roundtrip_fp4() {
        exhaustive_roundtrip(MiniFloat::FP4);
    }
    #[test]
    fn roundtrip_e4m3() {
        exhaustive_roundtrip(MiniFloat::E4M3);
    }
    #[test]
    fn roundtrip_e5m2() {
        exhaustive_roundtrip(MiniFloat::E5M2);
    }
    #[test]
    fn roundtrip_fp16() {
        exhaustive_roundtrip(MiniFloat::FP16);
    }
    #[test]
    fn roundtrip_bf16() {
        exhaustive_roundtrip(MiniFloat::BF16);
    }

    #[test]
    fn nearest_value_property_e4m3() {
        // encode must pick the closest representable (scan neighbours)
        let f = MiniFloat::E4M3;
        let mut vals: Vec<f64> = (0..256u32)
            .map(|b| f.decode(b))
            .filter(|d| d.class == Class::Normal)
            .map(|d| d.to_f64())
            .collect();
        vals.push(0.0);
        let mut rng = crate::util::Rng::new(5);
        for _ in 0..3000 {
            let x = rng.normal() * 10.0;
            let q = f.quantize(x);
            let best = vals
                .iter()
                .map(|&v| (v - x).abs())
                .fold(f64::INFINITY, f64::min);
            assert!(
                ((q - x).abs() - best).abs() < 1e-12,
                "x={x} q={q} best-dist={best}"
            );
        }
    }

    #[test]
    fn subnormal_boundary_promotion() {
        // value rounding up from subnormal range into min normal
        let f = MiniFloat::E4M3;
        let min_normal = 2f64.powi(-6);
        let just_below = min_normal * (1.0 - 1e-9);
        assert_eq!(f.quantize(just_below), min_normal);
    }
}
