//! The async serving runtime — the layer between the coordinator and
//! the SoC replicas.
//!
//! PR 2's serving path was a synchronous fan-out: `Router::route_batch`
//! spawned scoped threads per batch and blocked until the slowest
//! replica drained, and the replica count was fixed at construction.
//! This subsystem replaces that with long-lived infrastructure:
//!
//! * [`queue`] — a bounded MPSC work queue (std `Mutex`/`Condvar`; the
//!   image is offline, so no channel crates). Bounded admission is the
//!   back-pressure mechanism.
//! * [`worker`] — one long-lived thread per replica draining its own
//!   queue; the replica's `Soc` lives behind an `Arc<Mutex<_>>` device
//!   lock so the coordinator can still warm/evict/inspect it directly.
//!   [`ServeRuntime`] owns the fleet and the shared [`RuntimeMetrics`].
//! * [`handle`] — one-shot [`Completion`] handles: submission returns
//!   immediately, the caller redeems the handle whenever it likes, so
//!   the batcher keeps admitting while replicas drain and consecutive
//!   requests pipeline gather → GEMM → postprocess across batches.
//! * [`autoscale`] — the policy that consumes queue-latency percentiles
//!   ([`crate::coordinator::LatencyStats`] p95 over a sliding window)
//!   and grows/parks the active replica set between a configurable
//!   floor and the fleet size.
//! * [`ladder`] — the precision-ladder sibling of the cycle autoscaler:
//!   the same simulated-cycle congestion signal, but instead of adding
//!   replicas it shifts dispatch between co-resident compiled precision
//!   plans (high-fidelity ↔ FP4-heavy), with dwell-tick hysteresis.
//!
//! [`crate::coordinator::Router`] builds its `submit`/`submit_batch`
//! entry points on this runtime; its `route`/`route_batch` are thin
//! blocking wrappers over them, differentially tested bit-identical
//! (values, cycles, `ExecReport`/`JobReport` stats) to the legacy
//! synchronous fan-out which survives as `route_batch_fanout`.

pub mod autoscale;
pub mod handle;
pub mod ladder;
pub mod queue;
pub mod worker;

pub use autoscale::{AutoscaleConfig, Autoscaler, CycleAutoscaleConfig, CycleAutoscaler};
pub use ladder::{LadderConfig, LadderPolicy};
pub use handle::{completion, Canceled, Completion, CompletionSender, CompletionSet};
pub use queue::{Closed, WorkQueue};
pub use worker::{
    device_lock, Job, JobPayload, ReplicaWorker, RuntimeMetrics, ServeRuntime, WindowedStats,
    WorkerPanic,
};

#[cfg(test)]
mod tests {
    use crate::coordinator::batcher::{Batch, Request};
    use crate::coordinator::{ModelInstance, Router, WorkloadKind};
    use crate::models::{gaze, random_weights};
    use crate::npe::PrecSel;
    use crate::soc::SocConfig;

    fn gaze_router(n_replicas: usize, sel: PrecSel, seed: u64) -> Router {
        let mut r = Router::new(n_replicas, SocConfig::default());
        let g = gaze::build();
        let w = random_weights(&g, seed);
        r.register(WorkloadKind::Gaze, ModelInstance::uniform(g, w, sel).unwrap()).unwrap();
        r
    }

    fn batch_of(n: usize, id0: u64) -> Batch {
        Batch {
            requests: (0..n)
                .map(|i| Request {
                    id: id0 + i as u64,
                    input: (0..16).map(|j| ((i * 16 + j) as f32 * 0.11).sin() * 0.4).collect(),
                    aux: vec![],
                    arrived: i as u64,
                })
                .collect(),
            released: n as u64,
        }
    }

    /// The acceptance-criteria differential: for every hardware mode,
    /// the async runtime path (`route_batch` = `submit_batch` + wait)
    /// must be bit-identical to the legacy synchronous scoped-thread
    /// fan-out — values, per-request `ExecReport`s (cycles + engine
    /// stats), replica assignment, and the per-replica lifetime
    /// `JobReport`s.
    #[test]
    fn async_runtime_bit_identical_to_sync_fanout_all_modes() {
        for (i, sel) in PrecSel::ALL.into_iter().enumerate() {
            let seed = 90 + i as u64;
            let mut sync = gaze_router(3, sel, seed);
            let mut async_ = gaze_router(3, sel, seed);
            for round in 0..3 {
                let batch = batch_of(7, round * 7);
                let want = sync.route_batch_fanout(WorkloadKind::Gaze, &batch).unwrap();
                let got = async_.route_batch(WorkloadKind::Gaze, &batch).unwrap();
                assert_eq!(want.len(), got.len());
                for (w, g) in want.iter().zip(&got) {
                    assert_eq!(w.output, g.output, "{sel:?} round {round}: values diverged");
                    assert_eq!(w.report, g.report, "{sel:?} round {round}: reports diverged");
                    assert_eq!(w.replica, g.replica, "{sel:?} round {round}: assignment diverged");
                }
            }
            for r in 0..3 {
                assert_eq!(
                    sync.replica_lifetime(r),
                    async_.replica_lifetime(r),
                    "{sel:?}: replica {r} lifetime stats diverged"
                );
            }
            assert_eq!(sync.total_served(), async_.total_served());
        }
    }

    /// Pipelining: several batches submitted before any completion is
    /// redeemed still produce exactly the serial-route results.
    #[test]
    fn pipelined_submit_batches_match_serial_route() {
        let mut serial = gaze_router(2, PrecSel::Posit8x2, 97);
        let mut pipelined = gaze_router(2, PrecSel::Posit8x2, 97);
        let batches: Vec<Batch> = (0..4).map(|b| batch_of(5, b * 5)).collect();
        let mut want = Vec::new();
        for batch in &batches {
            for req in &batch.requests {
                want.push(serial.route(WorkloadKind::Gaze, &req.input, &req.aux).unwrap().output);
            }
        }
        // submit everything first — the queues pipeline across batches —
        // then redeem the completions
        let handles: Vec<_> = batches
            .iter()
            .map(|b| pipelined.submit_batch(WorkloadKind::Gaze, b).unwrap())
            .collect();
        let mut got = Vec::new();
        for comps in handles {
            for c in comps {
                got.push(Router::resolve(c).unwrap().output);
            }
        }
        assert_eq!(got, want);
    }

    #[test]
    fn submit_single_request_roundtrips() {
        let mut r = gaze_router(1, PrecSel::Fp4x4, 98);
        let c = r.submit(WorkloadKind::Gaze, vec![0.1; 16], vec![]).unwrap();
        let res = Router::resolve(c).unwrap();
        assert_eq!(res.output.len(), 2);
        assert_eq!(res.replica, 0);
        assert_eq!(r.total_served(), 1);
    }
}
