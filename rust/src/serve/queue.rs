//! Bounded MPSC work queue for the per-replica serving workers.
//!
//! Built on `std::sync::{Mutex, Condvar}` only — the image is offline,
//! so no crossbeam/flume. One queue feeds one [`super::ReplicaWorker`];
//! any number of producers (the router thread, tests) may push.
//! `push` blocks when the queue is full (bounded admission is the
//! back-pressure mechanism: a saturated replica slows the dispatcher
//! instead of buffering unbounded work), `pop` blocks when it is empty,
//! and `close` wakes everyone: blocked producers get [`Closed`] back,
//! the consumer drains what is queued and then sees `None`.

use std::collections::VecDeque;
use std::fmt;
use std::sync::{Condvar, Mutex, MutexGuard};

/// Error returned by [`WorkQueue::push`] after [`WorkQueue::close`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Closed;

impl fmt::Display for Closed {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "work queue is closed")
    }
}

impl std::error::Error for Closed {}

struct State<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Lock the queue state, clearing poisoning: the state is kept
/// consistent at every unlock point, and one panicking producer must
/// not cascade a poisoned-lock panic into every other producer and the
/// consumer.
fn lock_state<T>(m: &Mutex<State<T>>) -> MutexGuard<'_, State<T>> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Condvar wait with the same poison-clearing policy as [`lock_state`].
fn wait_state<'a, T>(cv: &Condvar, g: MutexGuard<'a, State<T>>) -> MutexGuard<'a, State<T>> {
    match cv.wait(g) {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// A bounded multi-producer single-consumer queue. Share it via `Arc`.
pub struct WorkQueue<T> {
    cap: usize,
    state: Mutex<State<T>>,
    not_empty: Condvar,
    not_full: Condvar,
}

impl<T> WorkQueue<T> {
    /// Queue admitting at most `cap` items (cap >= 1).
    pub fn bounded(cap: usize) -> WorkQueue<T> {
        assert!(cap >= 1);
        WorkQueue {
            cap,
            state: Mutex::new(State { items: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
        }
    }

    /// Enqueue, blocking while the queue is full. Fails only after
    /// [`WorkQueue::close`].
    pub fn push(&self, item: T) -> Result<(), Closed> {
        let mut st = lock_state(&self.state);
        while st.items.len() >= self.cap && !st.closed {
            st = wait_state(&self.not_full, st);
        }
        if st.closed {
            return Err(Closed);
        }
        st.items.push_back(item);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Enqueue without blocking; hands the item back when full/closed.
    pub fn try_push(&self, item: T) -> Result<(), T> {
        let mut st = lock_state(&self.state);
        if st.closed || st.items.len() >= self.cap {
            return Err(item);
        }
        st.items.push_back(item);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Dequeue, blocking while empty. `None` means the queue is closed
    /// *and* fully drained — the worker's signal to exit.
    pub fn pop(&self) -> Option<T> {
        let mut st = lock_state(&self.state);
        loop {
            if let Some(item) = st.items.pop_front() {
                self.not_full.notify_one();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = wait_state(&self.not_empty, st);
        }
    }

    /// Close the queue: producers fail fast, the consumer drains and
    /// exits. Idempotent.
    pub fn close(&self) {
        let mut st = lock_state(&self.state);
        st.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Has [`WorkQueue::close`] been called?
    pub fn is_closed(&self) -> bool {
        lock_state(&self.state).closed
    }

    /// Items currently queued (racy by nature; for metrics/backlog
    /// inspection only).
    pub fn len(&self) -> usize {
        lock_state(&self.state).items.len()
    }

    /// True when nothing is queued (racy, like [`WorkQueue::len`]).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The bound passed at construction — pushes beyond it block.
    pub fn capacity(&self) -> usize {
        self.cap
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order_single_thread() {
        let q = WorkQueue::bounded(8);
        for i in 0..5 {
            q.push(i).unwrap();
        }
        assert_eq!(q.len(), 5);
        for i in 0..5 {
            assert_eq!(q.pop(), Some(i));
        }
        assert!(q.is_empty());
    }

    #[test]
    fn try_push_bounces_when_full() {
        let q = WorkQueue::bounded(2);
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert_eq!(q.try_push(3), Err(3));
        assert_eq!(q.pop(), Some(1));
        q.try_push(3).unwrap();
    }

    #[test]
    fn close_drains_then_none() {
        let q = WorkQueue::bounded(4);
        q.push(7).unwrap();
        q.close();
        assert_eq!(q.push(8), Err(Closed));
        assert_eq!(q.pop(), Some(7), "queued items drain after close");
        assert_eq!(q.pop(), None);
        assert_eq!(q.pop(), None, "idempotent");
    }

    #[test]
    fn blocked_push_unblocks_on_pop() {
        let q = Arc::new(WorkQueue::bounded(1));
        q.push(0u32).unwrap();
        let q2 = Arc::clone(&q);
        let producer = std::thread::spawn(move || q2.push(1).unwrap());
        // the producer is blocked on the full queue until we pop
        std::thread::sleep(std::time::Duration::from_millis(10));
        assert_eq!(q.pop(), Some(0));
        producer.join().unwrap();
        assert_eq!(q.pop(), Some(1));
    }

    #[test]
    fn blocked_pop_unblocks_on_close() {
        let q = Arc::new(WorkQueue::<u32>::bounded(1));
        let q2 = Arc::clone(&q);
        let consumer = std::thread::spawn(move || q2.pop());
        std::thread::sleep(std::time::Duration::from_millis(10));
        q.close();
        assert_eq!(consumer.join().unwrap(), None);
    }

    #[test]
    fn mpsc_conserves_items() {
        let q = Arc::new(WorkQueue::bounded(4));
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    for i in 0..25u32 {
                        q.push(p * 100 + i).unwrap();
                    }
                })
            })
            .collect();
        let qc = Arc::clone(&q);
        let consumer = std::thread::spawn(move || {
            let mut got = Vec::new();
            while let Some(x) = qc.pop() {
                got.push(x);
            }
            got
        });
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let mut got = consumer.join().unwrap();
        got.sort_unstable();
        let mut want: Vec<u32> = (0..4).flat_map(|p| (0..25).map(move |i| p * 100 + i)).collect();
        want.sort_unstable();
        assert_eq!(got, want);
    }
}
