//! The load-adaptive **precision ladder** policy — autoscale quality,
//! not just replicas.
//!
//! The fleet already scales the replica count on simulated-cycle
//! congestion ([`super::autoscale::CycleAutoscaler`]). This sibling
//! policy scales the *precision* axis instead: a model registered as a
//! ladder ([`crate::coordinator::Router::register_ladder`]) has several
//! co-resident compiled plans — rung 0 the high-fidelity plan, the last
//! rung the FP4-heavy congestion plan — and this policy decides which
//! rung dispatch uses:
//!
//! * sustained congestion (`queue depth × windowed mean service cycles`
//!   at/above [`LadderConfig::shift_down`]) moves dispatch one rung
//!   *down* the ladder (cheaper, lower fidelity);
//! * a relaxed fleet (congestion at/below [`LadderConfig::shift_up`])
//!   moves one rung back *up*;
//! * a truly idle fleet (no fresh samples, empty queues, nothing in
//!   flight for [`LadderConfig::idle_patience`] ticks) snaps straight
//!   back to rung 0.
//!
//! **Hysteresis**: after any switch the policy dwells for
//! [`LadderConfig::dwell_ticks`] ticks before it will switch again, so
//! congestion hovering around a threshold cannot thrash the ladder.
//! Every input is simulator output (service cycles, queue depth) — no
//! wall clock anywhere — so a seeded congestion trace replays to a
//! byte-identical switch sequence on any host (the repo's `xr_lint`
//! wall-clock rule applies here as everywhere).
//!
//! Like the autoscalers, this is pure policy: it never touches queues
//! or threads. [`crate::coordinator::Router::ladder_tick_cycles`] feeds
//! it live queue depth; [`crate::coordinator::Router::ladder_tick_with`]
//! feeds it a seeded depth trace for deterministic tests and benches.

use super::worker::WindowedStats;

/// Knobs for the precision-ladder policy. Thresholds are in units of
/// *congestion* = queued jobs × windowed mean service cycles, exactly
/// like [`super::autoscale::CycleAutoscaleConfig`].
#[derive(Debug, Clone, Copy)]
pub struct LadderConfig {
    /// Congestion at/above this shifts dispatch one rung **down** the
    /// ladder (toward the FP4-heavy plan).
    pub shift_down: u64,
    /// Congestion at/below this shifts one rung back **up** (toward the
    /// high-fidelity plan).
    pub shift_up: u64,
    /// Service-cycle sample window length.
    pub window: usize,
    /// Hysteresis: ticks the policy holds after any switch before it
    /// will switch again.
    pub dwell_ticks: u32,
    /// Truly-idle ticks (no fresh samples, nothing queued or in flight)
    /// before snapping back to rung 0.
    pub idle_patience: u32,
}

impl Default for LadderConfig {
    fn default() -> Self {
        LadderConfig {
            // one gaze-class inference is ~20-40k sim-cycles; several
            // requests' worth of queued work justifies spending fewer
            // bits per request
            shift_down: 150_000,
            shift_up: 15_000,
            window: 256,
            dwell_ticks: 2,
            idle_patience: 2,
        }
    }
}

/// The precision-ladder policy + its sliding service-cycle window.
#[derive(Debug)]
pub struct LadderPolicy {
    /// The policy knobs (public like the autoscalers' `cfg`).
    pub cfg: LadderConfig,
    service: WindowedStats,
    seen_at_last_decide: u64,
    idle_ticks: u32,
    dwell: u32,
    rung: usize,
}

impl LadderPolicy {
    /// Build a policy at rung 0 (high fidelity).
    pub fn new(cfg: LadderConfig) -> LadderPolicy {
        assert!(cfg.shift_down > cfg.shift_up, "ladder thresholds must leave a dead band");
        assert!(cfg.window >= 1);
        LadderPolicy {
            cfg,
            service: WindowedStats::with_window(cfg.window),
            seen_at_last_decide: 0,
            idle_ticks: 0,
            dwell: 0,
            rung: 0,
        }
    }

    /// Feed one completed job's simulated service cost.
    pub fn observe_service_cycles(&mut self, cycles: u64) {
        self.service.record(cycles);
    }

    /// Feed a batch of samples (the runtime's incremental tail).
    pub fn observe_samples(&mut self, samples: &[u64]) {
        for &s in samples {
            self.observe_service_cycles(s);
        }
    }

    /// The rung the last [`LadderPolicy::decide`] settled on (0 until
    /// the first tick).
    pub fn rung(&self) -> usize {
        self.rung
    }

    /// The congestion signal: `queue_depth ×` windowed mean service
    /// cycles — identical to the replica autoscaler's.
    pub fn congestion(&self, queue_depth: usize) -> u64 {
        (queue_depth as f64 * self.service.mean()) as u64
    }

    /// One policy tick: given the ladder length and the fleet's current
    /// load, return the rung dispatch should use (always
    /// `< n_rungs.max(1)`). Deep queues shift down even when nothing
    /// completed since the last tick (a backlogged fleet produces no
    /// fresh samples — exactly when shedding bits matters most);
    /// snapping back to rung 0 requires a truly idle runtime.
    pub fn decide(&mut self, n_rungs: usize, in_flight: usize, queue_depth: usize) -> usize {
        let top = n_rungs.saturating_sub(1);
        self.rung = self.rung.min(top);
        let fresh = self.service.recorded() > self.seen_at_last_decide;
        self.seen_at_last_decide = self.service.recorded();
        if !fresh && queue_depth == 0 {
            if in_flight > 0 {
                // backlogged, not idle: hold until completions report in
                self.idle_ticks = 0;
                return self.rung;
            }
            self.idle_ticks += 1;
            if self.idle_ticks >= self.cfg.idle_patience {
                self.rung = 0;
                self.dwell = 0;
            }
            return self.rung;
        }
        self.idle_ticks = 0;
        if self.service.count() == 0 {
            // queued work but no cost estimate yet: hold for a sample
            return self.rung;
        }
        if self.dwell > 0 {
            // hysteresis: a recent switch pins the rung for dwell_ticks
            self.dwell -= 1;
            return self.rung;
        }
        let congestion = self.congestion(queue_depth);
        if congestion >= self.cfg.shift_down && self.rung < top {
            self.rung += 1;
            self.dwell = self.cfg.dwell_ticks;
        } else if congestion <= self.cfg.shift_up && self.rung > 0 {
            self.rung -= 1;
            self.dwell = self.cfg.dwell_ticks;
        }
        self.rung
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> LadderConfig {
        LadderConfig {
            shift_down: 50_000,
            shift_up: 5_000,
            window: 16,
            dwell_ticks: 2,
            idle_patience: 2,
        }
    }

    #[test]
    fn congestion_shifts_down_and_idle_snaps_back_to_high_fidelity() {
        let mut p = LadderPolicy::new(cfg());
        p.observe_samples(&[20_000; 4]); // mean 20k cycles/request
        assert_eq!(p.decide(3, 3, 3), 1, "60k congestion >= 50k shifts down");
        // dwell holds through continued pressure...
        p.observe_samples(&[20_000; 2]);
        assert_eq!(p.decide(3, 3, 3), 1, "dwell tick 1 pins the rung");
        p.observe_samples(&[20_000; 2]);
        assert_eq!(p.decide(3, 3, 3), 1, "dwell tick 2 pins the rung");
        // ...then the still-deep queue shifts the rest of the way down
        p.observe_samples(&[20_000; 2]);
        assert_eq!(p.decide(3, 3, 3), 2, "sustained pressure reaches the FP4-heavy rung");
        // truly idle: patience, then snap to rung 0
        assert_eq!(p.decide(3, 0, 0), 2, "first idle tick within patience");
        assert_eq!(p.decide(3, 0, 0), 0, "second idle tick snaps to high fidelity");
    }

    #[test]
    fn dead_band_holds_the_current_rung() {
        let mut p = LadderPolicy::new(cfg());
        p.observe_samples(&[20_000; 4]);
        assert_eq!(p.decide(3, 3, 3), 1);
        // burn the dwell with mid-band congestion, then stay mid-band
        for _ in 0..4 {
            p.observe_samples(&[20_000; 1]);
            assert_eq!(p.decide(3, 1, 1), 1, "20k congestion sits in the dead band");
        }
    }

    #[test]
    fn relaxed_fresh_traffic_steps_back_up_one_rung_at_a_time() {
        let mut p = LadderPolicy::new(cfg());
        p.observe_samples(&[30_000; 8]);
        assert_eq!(p.decide(3, 4, 4), 1);
        for _ in 0..2 {
            p.observe_samples(&[30_000; 1]);
            p.decide(3, 4, 4); // burn dwell under pressure
        }
        p.observe_samples(&[30_000; 1]);
        assert_eq!(p.decide(3, 4, 4), 2, "still congested: bottom rung");
        // congestion collapses but traffic stays fresh: step up, not snap
        p.observe_samples(&[30_000; 1]);
        p.decide(3, 0, 0); // dwell tick (fresh sample, zero depth)
        p.observe_samples(&[30_000; 1]);
        p.decide(3, 0, 0); // dwell tick
        p.observe_samples(&[30_000; 1]);
        assert_eq!(p.decide(3, 0, 0), 1, "zero congestion steps up one rung");
        for _ in 0..2 {
            p.observe_samples(&[30_000; 1]);
            p.decide(3, 0, 0); // dwell
        }
        p.observe_samples(&[30_000; 1]);
        assert_eq!(p.decide(3, 0, 0), 0, "and the next eligible tick reaches rung 0");
    }

    #[test]
    fn backlog_without_completions_still_shifts_down() {
        // no fresh samples but a deep queue: exactly when shedding bits
        // matters — the policy must act on the last known mean cost
        let mut p = LadderPolicy::new(cfg());
        p.observe_samples(&[30_000; 4]);
        assert_eq!(p.decide(3, 4, 2), 1, "tick 1: 60k queued-cycles shifts down");
        assert_eq!(p.decide(3, 4, 2), 1, "dwell holds");
        assert_eq!(p.decide(3, 4, 2), 1, "dwell holds");
        assert_eq!(p.decide(3, 4, 2), 2, "tick 4: still backlogged, bottom rung");
    }

    #[test]
    fn in_flight_work_blocks_the_idle_snap_back() {
        let mut p = LadderPolicy::new(cfg());
        p.observe_samples(&[30_000; 4]);
        assert_eq!(p.decide(2, 4, 4), 1);
        // draining: nothing queued but jobs in flight → hold
        assert_eq!(p.decide(2, 2, 0), 1);
        assert_eq!(p.decide(2, 2, 0), 1, "in-flight work blocks the snap");
        // truly idle: patience, then rung 0
        assert_eq!(p.decide(2, 0, 0), 1);
        assert_eq!(p.decide(2, 0, 0), 0);
    }

    #[test]
    fn holds_until_first_cost_sample_and_clamps_to_ladder_length() {
        let mut p = LadderPolicy::new(cfg());
        assert_eq!(p.decide(3, 3, 3), 0, "no cost estimate yet: hold rung 0");
        p.observe_samples(&[1_000_000; 4]);
        assert_eq!(p.decide(1, 9, 9), 0, "a one-rung ladder never moves");
        assert_eq!(p.decide(0, 0, 0), 0, "an empty ladder is pinned to 0");
    }

    #[test]
    fn seeded_congestion_trace_replays_to_identical_switch_sequence() {
        // the acceptance-criteria property at policy level: the same
        // seeded (samples, depth) trace yields the same rung sequence
        let trace: Vec<(u64, usize, usize)> =
            vec![(20_000, 1, 1), (25_000, 6, 6), (25_000, 6, 6), (25_000, 5, 5), (0, 0, 0), (0, 0, 0), (0, 0, 0)];
        let run = || {
            let mut p = LadderPolicy::new(cfg());
            let mut seq = Vec::new();
            for &(cycles, inflight, depth) in &trace {
                if cycles > 0 {
                    p.observe_service_cycles(cycles);
                }
                seq.push(p.decide(3, inflight, depth));
            }
            seq
        };
        assert_eq!(run(), run());
    }
}
