//! Metrics-driven replica autoscaling policy.
//!
//! The policy consumes the serving runtime's **queue-latency** samples
//! (time a request sat in a replica's work queue before a worker picked
//! it up — the purest congestion signal: service latency reflects model
//! cost, queue latency reflects under-provisioning) and decides how many
//! replicas should actively receive dispatch:
//!
//! * sustained pressure — windowed p95 at or above
//!   [`AutoscaleConfig::scale_up_p95`] — grows the active set by
//!   [`AutoscaleConfig::step`], up to `max`;
//! * a relaxed queue — p95 at or below [`AutoscaleConfig::scale_down_p95`]
//!   — shrinks it by one, down to `floor`;
//! * an **idle** runtime (no new samples for
//!   [`AutoscaleConfig::idle_patience`] consecutive ticks *and* nothing
//!   in flight — samples only arrive at job completion, so a backlogged
//!   fleet is not idle) parks everything above the floor at once.
//!
//! The autoscaler is pure policy: it never touches threads or queues.
//! [`crate::coordinator::Router::autoscale_tick`] feeds it and applies
//! the decision to the dispatch set; parked replicas keep their threads
//! (blocked on an empty queue) and their warm state, so unparking is
//! free, and a replica activated for the first time warms on demand at
//! its first request ([`crate::models::CompiledModel::ensure_warm`]).

use super::worker::WindowedStats;

/// Policy knobs. Latency units are whatever the caller feeds
/// ([`crate::serve::RuntimeMetrics`] records host nanoseconds).
#[derive(Debug, Clone, Copy)]
pub struct AutoscaleConfig {
    /// Never park below this many active replicas.
    pub floor: usize,
    /// Never activate more than this many (callers clamp to the fleet).
    pub max: usize,
    /// Windowed queue-latency p95 at/above this scales up.
    pub scale_up_p95: u64,
    /// Windowed queue-latency p95 at/below this scales down by one.
    pub scale_down_p95: u64,
    /// Sliding-window length in samples.
    pub window: usize,
    /// Replicas added per scale-up decision.
    pub step: usize,
    /// Ticks with no fresh samples before parking to the floor.
    pub idle_patience: u32,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        AutoscaleConfig {
            floor: 1,
            max: usize::MAX,
            scale_up_p95: 200_000,   // 200 µs queued: dispatcher outruns the fleet
            scale_down_p95: 20_000,  // 20 µs: fleet is loafing
            window: 256,
            step: 1,
            idle_patience: 2,
        }
    }
}

/// The scaling policy + its sliding sample window (a
/// [`WindowedStats`], sized by [`AutoscaleConfig::window`]).
#[derive(Debug)]
pub struct Autoscaler {
    pub cfg: AutoscaleConfig,
    stats: WindowedStats,
    seen_at_last_decide: u64,
    idle_ticks: u32,
}

impl Autoscaler {
    pub fn new(cfg: AutoscaleConfig) -> Autoscaler {
        assert!(cfg.floor >= 1, "autoscale floor must be >= 1");
        assert!(cfg.max >= cfg.floor, "autoscale max must be >= floor");
        assert!(cfg.window >= 1 && cfg.step >= 1);
        Autoscaler {
            cfg,
            stats: WindowedStats::with_window(cfg.window),
            seen_at_last_decide: 0,
            idle_ticks: 0,
        }
    }

    /// Feed one queue-latency sample.
    pub fn observe(&mut self, queue_latency: u64) {
        self.stats.record(queue_latency);
    }

    /// Feed a batch of samples (e.g. the new tail of
    /// [`crate::coordinator::BatchMetrics`]'s `queue` distribution).
    pub fn observe_samples(&mut self, samples: &[u64]) {
        for &s in samples {
            self.observe(s);
        }
    }

    /// Windowed queue-latency percentile (nearest-rank, see
    /// [`WindowedStats::percentile`]).
    pub fn queue_percentile(&self, p: f64) -> u64 {
        self.stats.percentile(p)
    }

    /// Samples observed in total (fresh-traffic detector for idle ticks).
    pub fn observed(&self) -> u64 {
        self.stats.recorded()
    }

    /// One policy tick: given the current active-replica count and the
    /// runtime's current load (`in_flight` = jobs queued or executing),
    /// return the new target in `[floor, max]`.
    ///
    /// Queue-latency samples arrive only when jobs *complete*, so "no
    /// fresh samples" alone does not mean idle — a fleet backlogged
    /// with slow jobs completes nothing between ticks. Idle parking
    /// therefore requires both: no fresh samples **and** `in_flight`
    /// of zero.
    pub fn decide(&mut self, active: usize, in_flight: usize) -> usize {
        let active = active.clamp(self.cfg.floor, self.cfg.max);
        let fresh = self.stats.recorded() > self.seen_at_last_decide;
        self.seen_at_last_decide = self.stats.recorded();
        if !fresh {
            if in_flight > 0 {
                // backlogged, not idle: hold until completions report in
                self.idle_ticks = 0;
                return active;
            }
            self.idle_ticks += 1;
            if self.idle_ticks >= self.cfg.idle_patience {
                return self.cfg.floor;
            }
            return active;
        }
        self.idle_ticks = 0;
        let p95 = self.queue_percentile(95.0);
        if p95 >= self.cfg.scale_up_p95 {
            active.saturating_add(self.cfg.step).min(self.cfg.max)
        } else if p95 <= self.cfg.scale_down_p95 {
            active.saturating_sub(1).max(self.cfg.floor)
        } else {
            active
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AutoscaleConfig {
        AutoscaleConfig {
            floor: 1,
            max: 4,
            scale_up_p95: 1_000,
            scale_down_p95: 100,
            window: 16,
            step: 1,
            idle_patience: 2,
        }
    }

    #[test]
    fn sustained_pressure_scales_up_to_max() {
        let mut a = Autoscaler::new(cfg());
        let mut active = 1;
        for round in 0..5 {
            a.observe_samples(&[5_000; 8]);
            let next = a.decide(active, 0);
            assert!(
                next > active || next == a.cfg.max,
                "round {round}: active {active} -> {next} must rise toward max"
            );
            active = next;
        }
        assert_eq!(active, 4, "sustained queue pressure must reach max");
    }

    #[test]
    fn relaxed_queue_steps_down_and_idle_parks_to_floor() {
        let mut a = Autoscaler::new(cfg());
        // pressure up to max first
        let mut active = 1;
        for _ in 0..5 {
            a.observe_samples(&[5_000; 16]);
            active = a.decide(active, 0);
        }
        assert_eq!(active, 4);
        // fresh-but-relaxed traffic steps down one at a time
        a.observe_samples(&[10; 16]); // flushes the window of hot samples
        active = a.decide(active, 0);
        assert_eq!(active, 3, "relaxed p95 steps down by one");
        // idle: no fresh samples → after patience ticks, park to floor
        let after_one_idle = a.decide(active, 0);
        assert_eq!(after_one_idle, 3, "one idle tick is within patience");
        let after_two_idle = a.decide(after_one_idle, 0);
        assert_eq!(after_two_idle, 1, "sustained idle falls back to the floor");
        // floor holds while idle
        assert_eq!(a.decide(after_two_idle, 0), 1);
    }

    #[test]
    fn backlog_without_completions_is_not_idle() {
        // slow jobs: nothing completes between ticks, so no fresh
        // samples — but work is in flight, so the fleet must hold, not
        // park (parking here would funnel a deep backlog to one queue)
        let mut a = Autoscaler::new(cfg());
        assert_eq!(a.decide(3, 5), 3, "tick 1: backlogged fleet holds");
        assert_eq!(a.decide(3, 5), 3, "tick 2: still holds past idle_patience");
        assert_eq!(a.decide(3, 5), 3, "tick 3: holds as long as jobs are in flight");
        // backlog drains with no new traffic: now it really is idle
        assert_eq!(a.decide(3, 0), 3, "first truly idle tick is within patience");
        assert_eq!(a.decide(3, 0), 1, "second idle tick parks to the floor");
    }

    #[test]
    fn mid_band_pressure_holds_steady() {
        let mut a = Autoscaler::new(cfg());
        a.observe_samples(&[500; 16]); // between the two thresholds
        assert_eq!(a.decide(2, 0), 2);
    }

    #[test]
    fn decisions_respect_floor_and_max_bounds() {
        let mut a = Autoscaler::new(AutoscaleConfig { floor: 2, max: 3, ..cfg() });
        a.observe_samples(&[1_000_000; 4]);
        assert_eq!(a.decide(3, 0), 3, "never exceeds max");
        a.observe_samples(&[1; 16]);
        assert_eq!(a.decide(2, 0), 2, "never shrinks below floor");
    }

    #[test]
    fn window_is_sliding() {
        let mut a = Autoscaler::new(cfg());
        a.observe_samples(&[1_000_000; 16]);
        a.observe_samples(&[10; 16]); // fully displaces the hot samples
        assert!(a.queue_percentile(95.0) <= 10);
        assert_eq!(a.observed(), 32);
    }
}
