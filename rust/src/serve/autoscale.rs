//! Metrics-driven replica autoscaling policy.
//!
//! The policy consumes the serving runtime's **queue-latency** samples
//! (time a request sat in a replica's work queue before a worker picked
//! it up — the purest congestion signal: service latency reflects model
//! cost, queue latency reflects under-provisioning) and decides how many
//! replicas should actively receive dispatch:
//!
//! * sustained pressure — windowed p95 at or above
//!   [`AutoscaleConfig::scale_up_p95`] — grows the active set by
//!   [`AutoscaleConfig::step`], up to `max`;
//! * a relaxed queue — p95 at or below [`AutoscaleConfig::scale_down_p95`]
//!   — shrinks it by one, down to `floor`;
//! * an **idle** runtime (no new samples for
//!   [`AutoscaleConfig::idle_patience`] consecutive ticks *and* nothing
//!   in flight — samples only arrive at job completion, so a backlogged
//!   fleet is not idle) parks everything above the floor at once.
//!
//! The autoscaler is pure policy: it never touches threads or queues.
//! [`crate::coordinator::Router::autoscale_tick`] feeds it and applies
//! the decision to the dispatch set; parked replicas keep their threads
//! (blocked on an empty queue) and their warm state, so unparking is
//! free, and a replica activated for the first time warms on demand at
//! its first request ([`crate::models::CompiledModel::ensure_warm`]).

use super::worker::WindowedStats;

/// Policy knobs. Latency units are whatever the caller feeds
/// ([`crate::serve::RuntimeMetrics`] records host nanoseconds).
#[derive(Debug, Clone, Copy)]
pub struct AutoscaleConfig {
    /// Never park below this many active replicas.
    pub floor: usize,
    /// Never activate more than this many (callers clamp to the fleet).
    pub max: usize,
    /// Windowed queue-latency p95 at/above this scales up.
    pub scale_up_p95: u64,
    /// Windowed queue-latency p95 at/below this scales down by one.
    pub scale_down_p95: u64,
    /// Sliding-window length in samples.
    pub window: usize,
    /// Replicas added per scale-up decision.
    pub step: usize,
    /// Ticks with no fresh samples before parking to the floor.
    pub idle_patience: u32,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        AutoscaleConfig {
            floor: 1,
            max: usize::MAX,
            scale_up_p95: 200_000,   // 200 µs queued: dispatcher outruns the fleet
            scale_down_p95: 20_000,  // 20 µs: fleet is loafing
            window: 256,
            step: 1,
            idle_patience: 2,
        }
    }
}

/// The scaling policy + its sliding sample window (a
/// [`WindowedStats`], sized by [`AutoscaleConfig::window`]).
#[derive(Debug)]
pub struct Autoscaler {
    /// The thresholds and limits this policy decides with.
    pub cfg: AutoscaleConfig,
    stats: WindowedStats,
    seen_at_last_decide: u64,
    idle_ticks: u32,
}

impl Autoscaler {
    /// Build a policy from `cfg` (asserts the knobs are coherent:
    /// `floor >= 1`, `max >= floor`, non-zero window and step).
    pub fn new(cfg: AutoscaleConfig) -> Autoscaler {
        assert!(cfg.floor >= 1, "autoscale floor must be >= 1");
        assert!(cfg.max >= cfg.floor, "autoscale max must be >= floor");
        assert!(cfg.window >= 1 && cfg.step >= 1);
        Autoscaler {
            cfg,
            stats: WindowedStats::with_window(cfg.window),
            seen_at_last_decide: 0,
            idle_ticks: 0,
        }
    }

    /// Feed one queue-latency sample.
    pub fn observe(&mut self, queue_latency: u64) {
        self.stats.record(queue_latency);
    }

    /// Feed a batch of samples (e.g. the new tail of
    /// [`crate::coordinator::BatchMetrics`]'s `queue` distribution).
    pub fn observe_samples(&mut self, samples: &[u64]) {
        for &s in samples {
            self.observe(s);
        }
    }

    /// Windowed queue-latency percentile (nearest-rank, see
    /// [`WindowedStats::percentile`]).
    pub fn queue_percentile(&self, p: f64) -> u64 {
        self.stats.percentile(p)
    }

    /// Samples observed in total (fresh-traffic detector for idle ticks).
    pub fn observed(&self) -> u64 {
        self.stats.recorded()
    }

    /// One policy tick: given the current active-replica count and the
    /// runtime's current load (`in_flight` = jobs queued or executing),
    /// return the new target in `[floor, max]`.
    ///
    /// Queue-latency samples arrive only when jobs *complete*, so "no
    /// fresh samples" alone does not mean idle — a fleet backlogged
    /// with slow jobs completes nothing between ticks. Idle parking
    /// therefore requires both: no fresh samples **and** `in_flight`
    /// of zero.
    pub fn decide(&mut self, active: usize, in_flight: usize) -> usize {
        let active = active.clamp(self.cfg.floor, self.cfg.max);
        let fresh = self.stats.recorded() > self.seen_at_last_decide;
        self.seen_at_last_decide = self.stats.recorded();
        if !fresh {
            if in_flight > 0 {
                // backlogged, not idle: hold until completions report in
                self.idle_ticks = 0;
                return active;
            }
            self.idle_ticks += 1;
            if self.idle_ticks >= self.cfg.idle_patience {
                return self.cfg.floor;
            }
            return active;
        }
        self.idle_ticks = 0;
        let p95 = self.queue_percentile(95.0);
        if p95 >= self.cfg.scale_up_p95 {
            active.saturating_add(self.cfg.step).min(self.cfg.max)
        } else if p95 <= self.cfg.scale_down_p95 {
            active.saturating_sub(1).max(self.cfg.floor)
        } else {
            active
        }
    }
}

/// Knobs for the **wall-clock-free** scaling policy. Thresholds are in
/// units of *congestion* = queued jobs × windowed mean service cycles —
/// "how many simulated cycles of work are waiting", a number that
/// depends only on the workload and the engine model, never on host
/// speed. Tests against it reproduce exactly on any machine.
#[derive(Debug, Clone, Copy)]
pub struct CycleAutoscaleConfig {
    /// Never park below this many active replicas.
    pub floor: usize,
    /// Never activate more than this many.
    pub max: usize,
    /// Congestion at/above this scales up by `step`.
    pub scale_up: u64,
    /// Congestion at/below this scales down by one.
    pub scale_down: u64,
    /// Service-cycle sample window length.
    pub window: usize,
    /// Replicas added per scale-up decision.
    pub step: usize,
    /// Truly-idle ticks (no fresh samples, nothing queued or in flight)
    /// before parking to the floor.
    pub idle_patience: u32,
}

impl Default for CycleAutoscaleConfig {
    fn default() -> Self {
        CycleAutoscaleConfig {
            floor: 1,
            max: usize::MAX,
            // one gaze-class inference is ~20-40k sim-cycles; a few
            // requests' worth of queued work is congestion
            scale_up: 100_000,
            scale_down: 10_000,
            window: 256,
            step: 1,
            idle_patience: 2,
        }
    }
}

/// The simulated-cycle congestion policy (ROADMAP follow-up from the
/// async-serving PR): consumes **service cycles** from the runtime's
/// [`crate::serve::RuntimeMetrics::service_cycles`] window plus the
/// instantaneous queue depth, and scales on `depth × mean service
/// cycles`. Unlike [`Autoscaler`]'s nanosecond thresholds, every input
/// is simulator-deterministic, so scaling tests need no host-speed
/// tuning. Fed by [`crate::coordinator::Router::autoscale_tick_cycles`].
#[derive(Debug)]
pub struct CycleAutoscaler {
    /// The thresholds and limits this policy decides with.
    pub cfg: CycleAutoscaleConfig,
    service: WindowedStats,
    seen_at_last_decide: u64,
    idle_ticks: u32,
}

impl CycleAutoscaler {
    /// Build a policy from `cfg` (asserts the knobs are coherent:
    /// `floor >= 1`, `max >= floor`, non-zero window and step).
    pub fn new(cfg: CycleAutoscaleConfig) -> CycleAutoscaler {
        assert!(cfg.floor >= 1, "autoscale floor must be >= 1");
        assert!(cfg.max >= cfg.floor, "autoscale max must be >= floor");
        assert!(cfg.window >= 1 && cfg.step >= 1);
        CycleAutoscaler {
            cfg,
            service: WindowedStats::with_window(cfg.window),
            seen_at_last_decide: 0,
            idle_ticks: 0,
        }
    }

    /// Feed one completed job's simulated service cost.
    pub fn observe_service_cycles(&mut self, cycles: u64) {
        self.service.record(cycles);
    }

    /// Feed a batch of samples (the runtime's incremental tail).
    pub fn observe_samples(&mut self, samples: &[u64]) {
        for &s in samples {
            self.observe_service_cycles(s);
        }
    }

    /// The congestion signal: `queue_depth ×` windowed mean service
    /// cycles — the simulated work (in cycles) sitting in the queues.
    pub fn congestion(&self, queue_depth: usize) -> u64 {
        (queue_depth as f64 * self.service.mean()) as u64
    }

    /// One policy tick. `queue_depth` is the fleet-wide queued-job count
    /// at tick time; `in_flight` counts dispatched-but-unfulfilled jobs.
    /// Deep queues scale up even when nothing completed since the last
    /// tick (a fully backlogged fleet produces no fresh samples — that
    /// is exactly when scaling up matters most); parking requires a
    /// truly idle runtime: no fresh samples, empty queues, nothing in
    /// flight.
    pub fn decide(&mut self, active: usize, in_flight: usize, queue_depth: usize) -> usize {
        let active = active.clamp(self.cfg.floor, self.cfg.max);
        let fresh = self.service.recorded() > self.seen_at_last_decide;
        self.seen_at_last_decide = self.service.recorded();
        if !fresh && queue_depth == 0 {
            if in_flight > 0 {
                self.idle_ticks = 0;
                return active;
            }
            self.idle_ticks += 1;
            if self.idle_ticks >= self.cfg.idle_patience {
                return self.cfg.floor;
            }
            return active;
        }
        self.idle_ticks = 0;
        if self.service.count() == 0 {
            // queued work but no cost estimate yet (first requests still
            // executing): hold until a sample arrives
            return active;
        }
        let congestion = self.congestion(queue_depth);
        if congestion >= self.cfg.scale_up {
            active.saturating_add(self.cfg.step).min(self.cfg.max)
        } else if congestion <= self.cfg.scale_down {
            active.saturating_sub(1).max(self.cfg.floor)
        } else {
            active
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AutoscaleConfig {
        AutoscaleConfig {
            floor: 1,
            max: 4,
            scale_up_p95: 1_000,
            scale_down_p95: 100,
            window: 16,
            step: 1,
            idle_patience: 2,
        }
    }

    #[test]
    fn sustained_pressure_scales_up_to_max() {
        let mut a = Autoscaler::new(cfg());
        let mut active = 1;
        for round in 0..5 {
            a.observe_samples(&[5_000; 8]);
            let next = a.decide(active, 0);
            assert!(
                next > active || next == a.cfg.max,
                "round {round}: active {active} -> {next} must rise toward max"
            );
            active = next;
        }
        assert_eq!(active, 4, "sustained queue pressure must reach max");
    }

    #[test]
    fn relaxed_queue_steps_down_and_idle_parks_to_floor() {
        let mut a = Autoscaler::new(cfg());
        // pressure up to max first
        let mut active = 1;
        for _ in 0..5 {
            a.observe_samples(&[5_000; 16]);
            active = a.decide(active, 0);
        }
        assert_eq!(active, 4);
        // fresh-but-relaxed traffic steps down one at a time
        a.observe_samples(&[10; 16]); // flushes the window of hot samples
        active = a.decide(active, 0);
        assert_eq!(active, 3, "relaxed p95 steps down by one");
        // idle: no fresh samples → after patience ticks, park to floor
        let after_one_idle = a.decide(active, 0);
        assert_eq!(after_one_idle, 3, "one idle tick is within patience");
        let after_two_idle = a.decide(after_one_idle, 0);
        assert_eq!(after_two_idle, 1, "sustained idle falls back to the floor");
        // floor holds while idle
        assert_eq!(a.decide(after_two_idle, 0), 1);
    }

    #[test]
    fn backlog_without_completions_is_not_idle() {
        // slow jobs: nothing completes between ticks, so no fresh
        // samples — but work is in flight, so the fleet must hold, not
        // park (parking here would funnel a deep backlog to one queue)
        let mut a = Autoscaler::new(cfg());
        assert_eq!(a.decide(3, 5), 3, "tick 1: backlogged fleet holds");
        assert_eq!(a.decide(3, 5), 3, "tick 2: still holds past idle_patience");
        assert_eq!(a.decide(3, 5), 3, "tick 3: holds as long as jobs are in flight");
        // backlog drains with no new traffic: now it really is idle
        assert_eq!(a.decide(3, 0), 3, "first truly idle tick is within patience");
        assert_eq!(a.decide(3, 0), 1, "second idle tick parks to the floor");
    }

    #[test]
    fn mid_band_pressure_holds_steady() {
        let mut a = Autoscaler::new(cfg());
        a.observe_samples(&[500; 16]); // between the two thresholds
        assert_eq!(a.decide(2, 0), 2);
    }

    #[test]
    fn decisions_respect_floor_and_max_bounds() {
        let mut a = Autoscaler::new(AutoscaleConfig { floor: 2, max: 3, ..cfg() });
        a.observe_samples(&[1_000_000; 4]);
        assert_eq!(a.decide(3, 0), 3, "never exceeds max");
        a.observe_samples(&[1; 16]);
        assert_eq!(a.decide(2, 0), 2, "never shrinks below floor");
    }

    #[test]
    fn window_is_sliding() {
        let mut a = Autoscaler::new(cfg());
        a.observe_samples(&[1_000_000; 16]);
        a.observe_samples(&[10; 16]); // fully displaces the hot samples
        assert!(a.queue_percentile(95.0) <= 10);
        assert_eq!(a.observed(), 32);
    }

    fn sim_cfg() -> CycleAutoscaleConfig {
        CycleAutoscaleConfig {
            floor: 1,
            max: 4,
            scale_up: 50_000,
            scale_down: 5_000,
            window: 16,
            step: 1,
            idle_patience: 2,
        }
    }

    #[test]
    fn cycle_policy_is_reproducible_from_simulated_numbers_alone() {
        // the whole point of the satellite: every input is simulator
        // output (service cycles, queue depth), so this exact decision
        // sequence holds on any host at any load, no tuned thresholds
        let mut a = CycleAutoscaler::new(sim_cfg());
        a.observe_samples(&[20_000; 4]); // mean 20k cycles/request
        assert_eq!(a.congestion(3), 60_000);
        assert_eq!(a.decide(1, 3, 3), 2, "60k congestion >= 50k scales up");
        a.observe_samples(&[20_000; 2]);
        assert_eq!(a.decide(2, 0, 0), 1, "zero depth = zero congestion, steps down");
        // mid-band holds
        a.observe_samples(&[20_000; 2]);
        assert_eq!(a.decide(2, 1, 1), 2, "20k congestion holds steady");
    }

    #[test]
    fn cycle_policy_scales_up_on_deep_queue_without_fresh_samples() {
        // a fully backlogged fleet completes nothing between ticks — the
        // nanosecond policy holds (no samples), this one scales up from
        // the queue depth and the last known mean cost
        let mut a = CycleAutoscaler::new(sim_cfg());
        a.observe_samples(&[30_000; 4]);
        assert_eq!(a.decide(1, 4, 2), 2, "tick 1: 60k queued-cycles scales up");
        assert_eq!(a.decide(2, 4, 2), 3, "tick 2: no fresh samples, queue still deep");
    }

    #[test]
    fn cycle_policy_holds_until_first_cost_sample() {
        let mut a = CycleAutoscaler::new(sim_cfg());
        assert_eq!(a.decide(1, 3, 3), 1, "no cost estimate yet: hold");
    }

    #[test]
    fn cycle_policy_parks_only_when_truly_idle() {
        let mut a = CycleAutoscaler::new(sim_cfg());
        a.observe_samples(&[30_000; 8]);
        let up = a.decide(3, 8, 4);
        assert_eq!(up, 4);
        // draining: in flight but empty queues → hold, never park
        assert_eq!(a.decide(4, 2, 0), 4);
        assert_eq!(a.decide(4, 2, 0), 4, "in-flight work blocks idle parking");
        // truly idle: patience, then floor
        assert_eq!(a.decide(4, 0, 0), 4, "first idle tick within patience");
        assert_eq!(a.decide(4, 0, 0), 1, "second idle tick parks to the floor");
    }

    #[test]
    fn cycle_policy_respects_floor_and_max() {
        let mut a = CycleAutoscaler::new(CycleAutoscaleConfig { floor: 2, max: 3, ..sim_cfg() });
        a.observe_samples(&[1_000_000; 4]);
        assert_eq!(a.decide(3, 9, 9), 3, "never exceeds max");
        a.observe_samples(&[1; 4]);
        assert_eq!(a.decide(2, 1, 1), 2, "never shrinks below floor");
    }
}
