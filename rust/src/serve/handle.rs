//! Completion handles: the submission side of the async serving API.
//!
//! [`completion`] makes a one-shot channel out of a `Mutex` + `Condvar`
//! (std only — no futures executor in the offline image): the runtime
//! keeps the [`CompletionSender`] inside the queued job and the caller
//! keeps the [`Completion`]. The caller can poll ([`Completion::is_ready`])
//! or block ([`Completion::wait`]); if the job is dropped unfulfilled
//! (runtime shutdown, worker death) the waiter gets [`Canceled`] instead
//! of hanging.

use std::fmt;
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

/// The job backing this completion was dropped without producing a
/// value (runtime shut down before the job ran).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Canceled;

impl fmt::Display for Canceled {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "request canceled before completion")
    }
}

impl std::error::Error for Canceled {}

enum Slot<T> {
    Pending,
    Ready(T),
    Taken,
    Canceled,
}

struct Inner<T> {
    slot: Mutex<Slot<T>>,
    cv: Condvar,
}

/// Lock a completion slot, clearing poisoning: the slot is a single
/// enum replaced atomically under the lock, so it is consistent even
/// after a panicking holder — and a worker panic must surface as
/// [`Canceled`] to the waiter, not as a poisoned-lock panic cascade.
fn lock_slot<T>(m: &Mutex<Slot<T>>) -> MutexGuard<'_, Slot<T>> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Producer half: fulfilled exactly once by the worker that ran the job.
pub struct CompletionSender<T> {
    inner: Option<Arc<Inner<T>>>,
}

/// Consumer half: redeemed by the submitter.
pub struct Completion<T> {
    inner: Arc<Inner<T>>,
}

/// Create a linked sender/handle pair.
pub fn completion<T>() -> (CompletionSender<T>, Completion<T>) {
    let inner = Arc::new(Inner { slot: Mutex::new(Slot::Pending), cv: Condvar::new() });
    (CompletionSender { inner: Some(Arc::clone(&inner)) }, Completion { inner })
}

impl<T> CompletionSender<T> {
    /// Deliver the value and wake the waiter.
    pub fn fulfill(mut self, value: T) {
        if let Some(inner) = self.inner.take() {
            *lock_slot(&inner.slot) = Slot::Ready(value);
            inner.cv.notify_all();
        }
    }
}

impl<T> Drop for CompletionSender<T> {
    fn drop(&mut self) {
        if let Some(inner) = self.inner.take() {
            let mut slot = lock_slot(&inner.slot);
            if matches!(*slot, Slot::Pending) {
                *slot = Slot::Canceled;
                inner.cv.notify_all();
            }
        }
    }
}

impl<T> Completion<T> {
    /// Has the value (or a cancellation) arrived? Non-blocking.
    pub fn is_ready(&self) -> bool {
        !matches!(*lock_slot(&self.inner.slot), Slot::Pending)
    }

    /// Take the value if it already arrived; `Ok(None)` while pending
    /// — and also after the value was already taken, so a poll loop
    /// that revisits redeemed handles stays safe.
    pub fn try_take(&self) -> Result<Option<T>, Canceled> {
        let mut slot = lock_slot(&self.inner.slot);
        match std::mem::replace(&mut *slot, Slot::Taken) {
            Slot::Ready(v) => Ok(Some(v)),
            Slot::Pending => {
                *slot = Slot::Pending;
                Ok(None)
            }
            Slot::Canceled => {
                *slot = Slot::Canceled;
                Err(Canceled)
            }
            Slot::Taken => Ok(None),
        }
    }

    /// Block until the value arrives and take it. A handle whose value
    /// was already removed by [`Completion::try_take`] reports
    /// [`Canceled`] — the value is gone and will never arrive here.
    pub fn wait(self) -> Result<T, Canceled> {
        let mut slot = lock_slot(&self.inner.slot);
        loop {
            match std::mem::replace(&mut *slot, Slot::Taken) {
                Slot::Ready(v) => return Ok(v),
                Slot::Canceled | Slot::Taken => return Err(Canceled),
                Slot::Pending => {
                    *slot = Slot::Pending;
                    slot = match self.inner.cv.wait(slot) {
                        Ok(g) => g,
                        Err(poisoned) => poisoned.into_inner(),
                    };
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fulfill_then_wait() {
        let (tx, rx) = completion();
        tx.fulfill(41);
        assert!(rx.is_ready());
        assert_eq!(rx.wait(), Ok(41));
    }

    #[test]
    fn wait_blocks_until_fulfilled_cross_thread() {
        let (tx, rx) = completion();
        let t = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(10));
            tx.fulfill("done");
        });
        assert_eq!(rx.wait(), Ok("done"));
        t.join().unwrap();
    }

    #[test]
    fn dropped_sender_cancels() {
        let (tx, rx) = completion::<u32>();
        drop(tx);
        assert!(rx.is_ready());
        assert_eq!(rx.wait(), Err(Canceled));
    }

    #[test]
    fn try_take_polls_without_blocking() {
        let (tx, rx) = completion();
        assert_eq!(rx.try_take(), Ok(None));
        assert!(!rx.is_ready());
        tx.fulfill(7u8);
        assert_eq!(rx.try_take(), Ok(Some(7)));
        // re-polling a redeemed handle is safe, not a panic
        assert_eq!(rx.try_take(), Ok(None));
        assert_eq!(rx.wait(), Err(Canceled), "the value is gone for good");
    }
}
