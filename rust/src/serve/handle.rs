//! Completion handles: the submission side of the async serving API.
//!
//! [`completion`] makes a one-shot channel out of a `Mutex` + `Condvar`
//! (std only — no futures executor in the offline image): the runtime
//! keeps the [`CompletionSender`] inside the queued job and the caller
//! keeps the [`Completion`]. The caller can poll ([`Completion::is_ready`])
//! or block ([`Completion::wait`]); if the job is dropped unfulfilled
//! (runtime shutdown, worker death) the waiter gets [`Canceled`] instead
//! of hanging.
//!
//! [`CompletionSet`] groups many in-flight completions behind one shared
//! waker so a coordinator can block on **whichever finishes first**
//! ([`CompletionSet::wait_any`]) — the primitive the streaming sharded
//! pipeline uses to merge partial quires in completion-arrival order
//! instead of fixed shard order.

use std::fmt;
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

/// The job backing this completion was dropped without producing a
/// value (runtime shut down before the job ran).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Canceled;

impl fmt::Display for Canceled {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "request canceled before completion")
    }
}

impl std::error::Error for Canceled {}

enum Slot<T> {
    Pending,
    Ready(T),
    Taken,
    Canceled,
}

/// Shared wake channel of a [`CompletionSet`]: a generation counter
/// bumped on every member fulfill/cancel. Waiters snapshot the
/// generation, scan their members, and sleep only until the generation
/// moves past the snapshot — so a fulfill that lands between the scan
/// and the sleep can never be lost.
struct WakeSet {
    gen: Mutex<u64>,
    cv: Condvar,
}

/// Lock the generation counter, clearing poisoning (a plain `u64`
/// replaced under the lock is always consistent; see [`lock_slot`]).
fn lock_gen(m: &Mutex<u64>) -> MutexGuard<'_, u64> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

impl WakeSet {
    fn notify(&self) {
        let mut gen = lock_gen(&self.gen);
        *gen += 1;
        self.cv.notify_all();
    }

    fn generation(&self) -> u64 {
        *lock_gen(&self.gen)
    }

    fn wait_past(&self, seen: u64) {
        let mut gen = lock_gen(&self.gen);
        while *gen <= seen {
            gen = match self.cv.wait(gen) {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
        }
    }
}

struct Inner<T> {
    slot: Mutex<Slot<T>>,
    cv: Condvar,
    /// Set when this completion is a member of a [`CompletionSet`]:
    /// fulfill/cancel also bumps the set's shared wake channel (after
    /// releasing the slot lock — the two locks are never nested).
    wake: Option<Arc<WakeSet>>,
}

/// Lock a completion slot, clearing poisoning: the slot is a single
/// enum replaced atomically under the lock, so it is consistent even
/// after a panicking holder — and a worker panic must surface as
/// [`Canceled`] to the waiter, not as a poisoned-lock panic cascade.
fn lock_slot<T>(m: &Mutex<Slot<T>>) -> MutexGuard<'_, Slot<T>> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Producer half: fulfilled exactly once by the worker that ran the job.
pub struct CompletionSender<T> {
    inner: Option<Arc<Inner<T>>>,
}

/// Consumer half: redeemed by the submitter.
pub struct Completion<T> {
    inner: Arc<Inner<T>>,
}

/// Create a linked sender/handle pair.
pub fn completion<T>() -> (CompletionSender<T>, Completion<T>) {
    let inner =
        Arc::new(Inner { slot: Mutex::new(Slot::Pending), cv: Condvar::new(), wake: None });
    (CompletionSender { inner: Some(Arc::clone(&inner)) }, Completion { inner })
}

impl<T> CompletionSender<T> {
    /// Deliver the value and wake the waiter.
    pub fn fulfill(mut self, value: T) {
        if let Some(inner) = self.inner.take() {
            {
                *lock_slot(&inner.slot) = Slot::Ready(value);
                inner.cv.notify_all();
            }
            // slot lock released above: the set waker is bumped outside
            // it so the two locks never nest
            if let Some(w) = &inner.wake {
                w.notify();
            }
        }
    }
}

impl<T> Drop for CompletionSender<T> {
    fn drop(&mut self) {
        if let Some(inner) = self.inner.take() {
            let canceled = {
                let mut slot = lock_slot(&inner.slot);
                if matches!(*slot, Slot::Pending) {
                    *slot = Slot::Canceled;
                    inner.cv.notify_all();
                    true
                } else {
                    false
                }
            };
            if canceled {
                if let Some(w) = &inner.wake {
                    w.notify();
                }
            }
        }
    }
}

/// A group of in-flight completions sharing one waker, redeemed in
/// **completion order** rather than submission order.
///
/// [`CompletionSet::sender`] mints a sender whose completion joins the
/// set under a caller-chosen key; [`CompletionSet::wait_any`] blocks
/// until *any* member is fulfilled (or canceled), removes it, and
/// returns its key with the outcome. The streaming sharded coordinator
/// drives its incremental quire merge with this: partials are merged as
/// their shard replicas finish, so merge work overlaps the stragglers'
/// compute instead of waiting for the slowest shard.
pub struct CompletionSet<T> {
    wake: Arc<WakeSet>,
    pending: Vec<(usize, Completion<T>)>,
}

impl<T> Default for CompletionSet<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> CompletionSet<T> {
    /// An empty set with its own shared wake channel.
    pub fn new() -> CompletionSet<T> {
        CompletionSet {
            wake: Arc::new(WakeSet { gen: Mutex::new(0), cv: Condvar::new() }),
            pending: Vec::new(),
        }
    }

    /// Mint a sender whose completion is tracked by this set under
    /// `key` (keys need not be unique; each sender is its own member).
    pub fn sender(&mut self, key: usize) -> CompletionSender<T> {
        let inner = Arc::new(Inner {
            slot: Mutex::new(Slot::Pending),
            cv: Condvar::new(),
            wake: Some(Arc::clone(&self.wake)),
        });
        self.pending.push((key, Completion { inner: Arc::clone(&inner) }));
        CompletionSender { inner: Some(inner) }
    }

    /// Members still awaited.
    pub fn len(&self) -> usize {
        self.pending.len()
    }

    /// True when no members are awaited ([`CompletionSet::len`] == 0).
    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Block until any member completes; remove it and return its key
    /// with the outcome (`Err(Canceled)` if its sender was dropped
    /// unfulfilled). `None` when the set has no members left.
    pub fn wait_any(&mut self) -> Option<(usize, Result<T, Canceled>)> {
        loop {
            if self.pending.is_empty() {
                return None;
            }
            // snapshot BEFORE scanning: a fulfill landing mid-scan bumps
            // the generation past the snapshot, so the wait below
            // returns immediately instead of losing the wakeup
            let seen = self.wake.generation();
            let mut i = 0;
            while i < self.pending.len() {
                match self.pending[i].1.try_take() {
                    Ok(Some(v)) => {
                        let (key, _) = self.pending.swap_remove(i);
                        return Some((key, Ok(v)));
                    }
                    Err(Canceled) => {
                        let (key, _) = self.pending.swap_remove(i);
                        return Some((key, Err(Canceled)));
                    }
                    Ok(None) => i += 1,
                }
            }
            self.wake.wait_past(seen);
        }
    }
}

impl<T> Completion<T> {
    /// Has the value (or a cancellation) arrived? Non-blocking.
    pub fn is_ready(&self) -> bool {
        !matches!(*lock_slot(&self.inner.slot), Slot::Pending)
    }

    /// Take the value if it already arrived; `Ok(None)` while pending
    /// — and also after the value was already taken, so a poll loop
    /// that revisits redeemed handles stays safe.
    pub fn try_take(&self) -> Result<Option<T>, Canceled> {
        let mut slot = lock_slot(&self.inner.slot);
        match std::mem::replace(&mut *slot, Slot::Taken) {
            Slot::Ready(v) => Ok(Some(v)),
            Slot::Pending => {
                *slot = Slot::Pending;
                Ok(None)
            }
            Slot::Canceled => {
                *slot = Slot::Canceled;
                Err(Canceled)
            }
            Slot::Taken => Ok(None),
        }
    }

    /// Block until the value arrives and take it. A handle whose value
    /// was already removed by [`Completion::try_take`] reports
    /// [`Canceled`] — the value is gone and will never arrive here.
    pub fn wait(self) -> Result<T, Canceled> {
        let mut slot = lock_slot(&self.inner.slot);
        loop {
            match std::mem::replace(&mut *slot, Slot::Taken) {
                Slot::Ready(v) => return Ok(v),
                Slot::Canceled | Slot::Taken => return Err(Canceled),
                Slot::Pending => {
                    *slot = Slot::Pending;
                    slot = match self.inner.cv.wait(slot) {
                        Ok(g) => g,
                        Err(poisoned) => poisoned.into_inner(),
                    };
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fulfill_then_wait() {
        let (tx, rx) = completion();
        tx.fulfill(41);
        assert!(rx.is_ready());
        assert_eq!(rx.wait(), Ok(41));
    }

    #[test]
    fn wait_blocks_until_fulfilled_cross_thread() {
        let (tx, rx) = completion();
        let t = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(10));
            tx.fulfill("done");
        });
        assert_eq!(rx.wait(), Ok("done"));
        t.join().unwrap();
    }

    #[test]
    fn dropped_sender_cancels() {
        let (tx, rx) = completion::<u32>();
        drop(tx);
        assert!(rx.is_ready());
        assert_eq!(rx.wait(), Err(Canceled));
    }

    #[test]
    fn set_returns_members_already_ready() {
        let mut set = CompletionSet::new();
        let a = set.sender(7);
        let b = set.sender(9);
        b.fulfill("b");
        a.fulfill("a");
        // completion order, not insertion order: b finished first
        assert_eq!(set.len(), 2);
        let first = set.wait_any().unwrap();
        let second = set.wait_any().unwrap();
        let mut got = [first, second];
        got.sort_by_key(|(k, _)| *k);
        assert_eq!(got[0], (7, Ok("a")));
        assert_eq!(got[1], (9, Ok("b")));
        assert!(set.wait_any().is_none(), "drained set yields None");
    }

    #[test]
    fn set_wait_any_wakes_on_cross_thread_fulfill_in_any_order() {
        let mut set = CompletionSet::new();
        let senders: Vec<_> = (0..4).map(|k| set.sender(k)).collect();
        let t = std::thread::spawn(move || {
            // fulfill in scrambled order with small gaps so wait_any
            // really blocks between arrivals
            for (i, tx) in senders.into_iter().enumerate().rev() {
                std::thread::sleep(std::time::Duration::from_millis(5));
                tx.fulfill(i * 10);
            }
        });
        let mut got = Vec::new();
        while let Some((k, v)) = set.wait_any() {
            got.push((k, v.unwrap()));
        }
        t.join().unwrap();
        // arrival order is reversed insertion order
        assert_eq!(got, vec![(3, 30), (2, 20), (1, 10), (0, 0)]);
    }

    #[test]
    fn set_reports_canceled_member() {
        let mut set = CompletionSet::new();
        let a = set.sender(1);
        let b = set.sender(2);
        drop(b); // canceled
        a.fulfill(5u32);
        let mut got = vec![set.wait_any().unwrap(), set.wait_any().unwrap()];
        got.sort_by_key(|(k, _)| *k);
        assert_eq!(got[0], (1, Ok(5)));
        assert_eq!(got[1], (2, Err(Canceled)));
    }

    #[test]
    fn empty_set_yields_none_without_blocking() {
        let mut set: CompletionSet<()> = CompletionSet::new();
        assert!(set.is_empty());
        assert!(set.wait_any().is_none());
    }

    #[test]
    fn try_take_polls_without_blocking() {
        let (tx, rx) = completion();
        assert_eq!(rx.try_take(), Ok(None));
        assert!(!rx.is_ready());
        tx.fulfill(7u8);
        assert_eq!(rx.try_take(), Ok(Some(7)));
        // re-polling a redeemed handle is safe, not a panic
        assert_eq!(rx.try_take(), Ok(None));
        assert_eq!(rx.wait(), Err(Canceled), "the value is gone for good");
    }
}
