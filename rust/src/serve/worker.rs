//! Long-lived per-replica worker threads + the [`ServeRuntime`] that
//! owns them.
//!
//! Each worker owns the *serving loop* of one SoC replica: it drains a
//! bounded [`WorkQueue`] of [`Job`]s, runs each through the compiled
//! model's replay path while holding the replica lock, fulfills the
//! job's [`CompletionSender`], and stamps host queue/service latency
//! into the shared [`RuntimeMetrics`]. The replica's `Soc` lives in an
//! `Arc<Mutex<_>>` rather than inside the thread so the coordinator can
//! still reach it directly — registration warms models, eviction frees
//! resident DRAM, and stats readers snapshot lifetime counters — without
//! a control-message protocol; the per-replica mutex serializes those
//! against in-flight inference exactly like a device lock would.
//!
//! Jobs carry an `Arc<ModelInstance>` resolved at submission time, so a
//! worker needs no registry access, and a replica that was never warmed
//! eagerly warms **on demand** at its first job
//! ([`crate::models::CompiledModel::ensure_warm`] inside `replay`).

use super::handle::CompletionSender;
use super::queue::{Closed, WorkQueue};
use crate::coordinator::metrics::LatencyStats;
use crate::coordinator::router::{RoutedResult, WorkloadKind};
use crate::coordinator::scheduler::ModelInstance;
use crate::soc::{Soc, SocConfig};
use anyhow::Result;
use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// One unit of work for a replica worker.
pub struct Job {
    pub kind: WorkloadKind,
    pub inst: Arc<ModelInstance>,
    pub input: Vec<f32>,
    pub aux: Vec<f32>,
    /// Submission timestamp (host clock) — queue latency is measured
    /// from here to worker pickup.
    pub enqueued: Instant,
    /// Fulfilled with the inference result (or its error).
    pub done: CompletionSender<Result<RoutedResult>>,
}

/// Latency samples over a bounded sliding window. The serving runtime
/// is long-lived (continuous XR traffic), so an unbounded sample vector
/// would grow forever; the window keeps the last `cap` samples
/// ([`WindowedStats::DEFAULT_WINDOW`] by default) for percentiles while
/// a monotone `recorded` counter preserves "how many ever" for
/// incremental consumers (the autoscale tick). Also the sample window
/// behind [`crate::serve::Autoscaler`] — one copy of the window logic.
#[derive(Debug, Clone)]
pub struct WindowedStats {
    cap: usize,
    window: VecDeque<u64>,
    recorded: u64,
}

impl Default for WindowedStats {
    fn default() -> Self {
        WindowedStats::with_window(WindowedStats::DEFAULT_WINDOW)
    }
}

impl WindowedStats {
    /// Samples retained for percentile queries unless configured.
    pub const DEFAULT_WINDOW: usize = 4096;

    /// Stats retaining the last `cap` samples (cap >= 1).
    pub fn with_window(cap: usize) -> WindowedStats {
        assert!(cap >= 1);
        WindowedStats { cap, window: VecDeque::new(), recorded: 0 }
    }

    pub fn record(&mut self, v: u64) {
        if self.window.len() == self.cap {
            self.window.pop_front();
        }
        self.window.push_back(v);
        self.recorded += 1;
    }

    /// Samples currently in the window.
    pub fn count(&self) -> usize {
        self.window.len()
    }

    /// Samples ever recorded (monotone).
    pub fn recorded(&self) -> u64 {
        self.recorded
    }

    /// The newest `n` samples, oldest first (clamped to the window).
    pub fn tail(&self, n: usize) -> Vec<u64> {
        let skip = self.window.len().saturating_sub(n);
        self.window.iter().skip(skip).copied().collect()
    }

    /// Nearest-rank percentile over the window (see
    /// [`LatencyStats::percentile`]).
    pub fn percentile(&self, p: f64) -> u64 {
        let mut stats = LatencyStats::new();
        for &s in &self.window {
            stats.record(s);
        }
        stats.percentile(p)
    }

    pub fn p50(&self) -> u64 {
        self.percentile(50.0)
    }

    pub fn p95(&self) -> u64 {
        self.percentile(95.0)
    }

    pub fn p99(&self) -> u64 {
        self.percentile(99.0)
    }

    /// Window maximum.
    pub fn max(&self) -> u64 {
        self.window.iter().copied().max().unwrap_or(0)
    }

    /// Window mean.
    pub fn mean(&self) -> f64 {
        if self.window.is_empty() {
            return 0.0;
        }
        self.window.iter().sum::<u64>() as f64 / self.window.len() as f64
    }
}

/// Host-side latency accounting for the async serving path, in
/// **nanoseconds** (wall clock — this is the signal the autoscaler
/// reacts to; simulated-cycle latency lives in
/// [`crate::coordinator::BatchMetrics`]).
#[derive(Debug, Clone, Default)]
pub struct RuntimeMetrics {
    /// Time each job sat queued before a worker picked it up.
    pub queue: WindowedStats,
    /// Time each job spent executing (replica lock + replay).
    pub service: WindowedStats,
    /// Jobs completed (fulfilled, whether Ok or Err).
    pub completed: u64,
}

struct SharedState {
    metrics: RuntimeMetrics,
    /// Jobs dispatched but not yet fulfilled (queued + executing).
    busy: usize,
}

/// State shared between the dispatcher and every worker.
struct Shared {
    state: Mutex<SharedState>,
    idle: Condvar,
}

/// One spawned worker: its queue plus the thread draining it.
pub struct ReplicaWorker {
    pub id: usize,
    queue: Arc<WorkQueue<Job>>,
    handle: Option<JoinHandle<()>>,
}

impl ReplicaWorker {
    fn spawn(
        id: usize,
        soc: Arc<Mutex<Soc>>,
        shared: Arc<Shared>,
        queue_capacity: usize,
    ) -> ReplicaWorker {
        let queue = Arc::new(WorkQueue::bounded(queue_capacity));
        let q = Arc::clone(&queue);
        let handle = std::thread::Builder::new()
            .name(format!("xr-npe-replica-{id}"))
            .spawn(move || {
                while let Some(job) = q.pop() {
                    let waited = job.enqueued.elapsed().as_nanos() as u64;
                    let t0 = Instant::now();
                    let res = {
                        let mut soc = soc.lock().unwrap();
                        job.inst.infer(&mut soc, &job.input, &job.aux)
                    };
                    let service = t0.elapsed().as_nanos() as u64;
                    // account *before* fulfilling: a caller that redeems
                    // the completion is then guaranteed to observe this
                    // job in RuntimeMetrics and out of in_flight()
                    {
                        let mut st = shared.state.lock().unwrap();
                        st.metrics.queue.record(waited);
                        st.metrics.service.record(service);
                        st.metrics.completed += 1;
                        st.busy -= 1;
                        shared.idle.notify_all();
                    }
                    job.done.fulfill(res.map(|(output, report)| RoutedResult {
                        kind: job.kind,
                        output,
                        report,
                        replica: id,
                    }));
                }
            })
            .expect("spawn replica worker");
        ReplicaWorker { id, queue, handle: Some(handle) }
    }
}

/// The serving runtime: `n` replicas, each an `Arc<Mutex<Soc>>` drained
/// by its own worker thread through its own bounded queue. Dropping the
/// runtime closes every queue (pending jobs still drain) and joins the
/// workers.
pub struct ServeRuntime {
    socs: Vec<Arc<Mutex<Soc>>>,
    workers: Vec<ReplicaWorker>,
    shared: Arc<Shared>,
}

impl ServeRuntime {
    /// Spawn `n` replica workers over fresh SoCs.
    pub fn new(n: usize, cfg: SocConfig, queue_capacity: usize) -> ServeRuntime {
        assert!(n >= 1);
        let shared = Arc::new(Shared {
            state: Mutex::new(SharedState { metrics: RuntimeMetrics::default(), busy: 0 }),
            idle: Condvar::new(),
        });
        let socs: Vec<Arc<Mutex<Soc>>> =
            (0..n).map(|_| Arc::new(Mutex::new(Soc::new(cfg)))).collect();
        let workers = socs
            .iter()
            .enumerate()
            .map(|(i, soc)| {
                ReplicaWorker::spawn(i, Arc::clone(soc), Arc::clone(&shared), queue_capacity)
            })
            .collect();
        ServeRuntime { socs, workers, shared }
    }

    pub fn n_replicas(&self) -> usize {
        self.socs.len()
    }

    /// Direct handle to replica `i`'s SoC (registration, stats). Lock
    /// order: never hold two replica locks at once.
    pub fn soc(&self, i: usize) -> &Arc<Mutex<Soc>> {
        &self.socs[i]
    }

    /// Enqueue a job on replica `replica`'s queue, blocking if that
    /// queue is full (bounded admission = back-pressure).
    pub fn dispatch(&self, replica: usize, job: Job) -> Result<(), Closed> {
        self.shared.state.lock().unwrap().busy += 1;
        match self.workers[replica].queue.push(job) {
            Ok(()) => Ok(()),
            Err(e) => {
                let mut st = self.shared.state.lock().unwrap();
                st.busy -= 1;
                self.shared.idle.notify_all();
                Err(e)
            }
        }
    }

    /// Jobs queued (not yet picked up) on replica `i`.
    pub fn queue_len(&self, i: usize) -> usize {
        self.workers[i].queue.len()
    }

    /// Jobs dispatched but not yet fulfilled, runtime-wide.
    pub fn in_flight(&self) -> usize {
        self.shared.state.lock().unwrap().busy
    }

    /// Block until every dispatched job has finished executing and been
    /// accounted (its completion may be a fulfillment away — `wait` on
    /// the handle still blocks until it lands). Used by registration to
    /// let in-flight requests against a replaced model drain off the
    /// hardware before its warm state is evicted.
    pub fn quiesce(&self) {
        let mut st = self.shared.state.lock().unwrap();
        while st.busy > 0 {
            st = self.shared.idle.wait(st).unwrap();
        }
    }

    /// Snapshot of the host-side latency metrics.
    pub fn metrics(&self) -> RuntimeMetrics {
        self.shared.state.lock().unwrap().metrics.clone()
    }

    /// Queue-latency samples recorded after the caller's last
    /// checkpoint (the autoscale tick's incremental feed). `seen` is
    /// the total returned by the previous call (0 initially); returns
    /// the new samples still retained in the window (oldest first) and
    /// the new checkpoint.
    pub fn queue_samples_since(&self, seen: u64) -> (Vec<u64>, u64) {
        let st = self.shared.state.lock().unwrap();
        let q = &st.metrics.queue;
        let total = q.recorded();
        let missed = total.saturating_sub(seen) as usize;
        (q.tail(missed), total)
    }
}

impl Drop for ServeRuntime {
    fn drop(&mut self) {
        for w in &self.workers {
            w.queue.close();
        }
        for w in &mut self.workers {
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::random_weights;
    use crate::models::{effnet, gaze};
    use crate::npe::PrecSel;
    use crate::serve::handle::completion;

    fn gaze_inst(seed: u64) -> Arc<ModelInstance> {
        let g = gaze::build();
        let w = random_weights(&g, seed);
        Arc::new(ModelInstance::uniform(g, w, PrecSel::Posit8x2).unwrap())
    }

    fn job(
        inst: &Arc<ModelInstance>,
        input: Vec<f32>,
    ) -> (Job, crate::serve::handle::Completion<Result<RoutedResult>>) {
        let (tx, rx) = completion();
        (
            Job {
                kind: WorkloadKind::Gaze,
                inst: Arc::clone(inst),
                input,
                aux: vec![],
                enqueued: Instant::now(),
                done: tx,
            },
            rx,
        )
    }

    #[test]
    fn worker_serves_jobs_and_records_metrics() {
        let rt = ServeRuntime::new(2, SocConfig::default(), 8);
        let inst = gaze_inst(1);
        let mut handles = Vec::new();
        for i in 0..6 {
            let (j, rx) = job(&inst, vec![0.01 * i as f32; 16]);
            rt.dispatch(i % 2, j).unwrap();
            handles.push(rx);
        }
        for (i, rx) in handles.into_iter().enumerate() {
            let res = rx.wait().unwrap().unwrap();
            assert_eq!(res.output.len(), 2, "job {i}");
            assert_eq!(res.replica, i % 2);
        }
        rt.quiesce();
        let m = rt.metrics();
        assert_eq!(m.completed, 6);
        assert_eq!(m.queue.count(), 6);
        assert_eq!(m.service.count(), 6);
        assert!(m.service.max() > 0, "service time must be recorded");
        assert_eq!(rt.in_flight(), 0);
    }

    #[test]
    fn worker_warms_replica_on_demand() {
        let rt = ServeRuntime::new(1, SocConfig::default(), 4);
        let inst = gaze_inst(2);
        let n_gemm = inst.compiled.n_gemm() as u64;
        // nothing warmed the replica — the first job does it in-loop
        assert_eq!(rt.soc(0).lock().unwrap().enc_cache.preloads, 0);
        let (j, rx) = job(&inst, vec![0.1; 16]);
        rt.dispatch(0, j).unwrap();
        rx.wait().unwrap().unwrap();
        assert_eq!(rt.soc(0).lock().unwrap().enc_cache.preloads, n_gemm);
    }

    #[test]
    fn same_replica_jobs_serialize_in_fifo_order() {
        // two models' jobs interleaved on one replica stay coherent and
        // the lifetime stats accumulate every job
        let rt = ServeRuntime::new(1, SocConfig::default(), 16);
        let gi = gaze_inst(3);
        let ge = effnet::build();
        let we = random_weights(&ge, 4);
        let ei = Arc::new(ModelInstance::uniform(ge, we, PrecSel::Fp4x4).unwrap());
        let mut rxs = Vec::new();
        for i in 0..4 {
            let (j, rx) = job(&gi, vec![0.02 * i as f32; 16]);
            rt.dispatch(0, j).unwrap();
            rxs.push(rx.wait().unwrap().unwrap().output);
            let (tx, rx) = completion();
            rt.dispatch(
                0,
                Job {
                    kind: WorkloadKind::Classify,
                    inst: Arc::clone(&ei),
                    input: vec![0.1; 256],
                    aux: vec![],
                    enqueued: Instant::now(),
                    done: tx,
                },
            )
            .unwrap();
            assert_eq!(rx.wait().unwrap().unwrap().output.len(), 10);
        }
        // identical inputs replayed later give identical outputs (no
        // cross-model clobbering of warm state)
        let (j, rx) = job(&gi, vec![0.0; 16]);
        rt.dispatch(0, j).unwrap();
        let again = rx.wait().unwrap().unwrap().output;
        assert_eq!(again, rxs[0]);
        rt.quiesce();
        assert_eq!(rt.metrics().completed, 9);
    }

    #[test]
    fn windowed_stats_bound_retention_but_count_everything() {
        let mut s = WindowedStats::default();
        for v in 0..(WindowedStats::DEFAULT_WINDOW as u64 + 100) {
            s.record(v);
        }
        assert_eq!(s.count(), WindowedStats::DEFAULT_WINDOW, "window must stay bounded");
        assert_eq!(s.recorded(), WindowedStats::DEFAULT_WINDOW as u64 + 100, "recorded is monotone");
        // the oldest 100 samples were displaced
        assert_eq!(s.percentile(0.0), 100);
        assert_eq!(s.max(), WindowedStats::DEFAULT_WINDOW as u64 + 99);
        assert_eq!(s.tail(3), vec![
            WindowedStats::DEFAULT_WINDOW as u64 + 97,
            WindowedStats::DEFAULT_WINDOW as u64 + 98,
            WindowedStats::DEFAULT_WINDOW as u64 + 99,
        ]);
        assert_eq!(s.tail(usize::MAX).len(), WindowedStats::DEFAULT_WINDOW, "tail clamps to the window");
    }

    #[test]
    fn infer_error_comes_back_through_the_completion() {
        let rt = ServeRuntime::new(1, SocConfig::default(), 4);
        let inst = gaze_inst(5);
        let (j, rx) = job(&inst, vec![0.1; 3]); // wrong input length
        rt.dispatch(0, j).unwrap();
        assert!(rx.wait().unwrap().is_err());
        rt.quiesce();
        assert_eq!(rt.metrics().completed, 1, "errors still complete and count");
    }

    #[test]
    fn drop_drains_pending_jobs() {
        let rt = ServeRuntime::new(1, SocConfig::default(), 8);
        let inst = gaze_inst(6);
        let mut rxs = Vec::new();
        for i in 0..5 {
            let (j, rx) = job(&inst, vec![0.03 * i as f32; 16]);
            rt.dispatch(0, j).unwrap();
            rxs.push(rx);
        }
        drop(rt); // closes the queue; the worker drains before exiting
        for rx in rxs {
            assert!(rx.wait().unwrap().is_ok(), "queued jobs complete during shutdown");
        }
    }
}
