//! Long-lived per-replica worker threads + the [`ServeRuntime`] that
//! owns them.
//!
//! Each worker owns the *serving loop* of one SoC replica: it drains a
//! bounded [`WorkQueue`] of [`Job`]s, runs each through the compiled
//! model's replay path while holding the replica lock, fulfills the
//! job's [`CompletionSender`], and stamps host queue/service latency
//! into the shared [`RuntimeMetrics`]. The replica's `Soc` lives in an
//! `Arc<Mutex<_>>` rather than inside the thread so the coordinator can
//! still reach it directly — registration warms models, eviction frees
//! resident DRAM, and stats readers snapshot lifetime counters — without
//! a control-message protocol; the per-replica mutex serializes those
//! against in-flight inference exactly like a device lock would.
//!
//! Jobs carry an `Arc<ModelInstance>` resolved at submission time, so a
//! worker needs no registry access, and a replica that was never warmed
//! eagerly warms **on demand** at its first job
//! ([`crate::models::CompiledModel::ensure_warm`] inside `replay`).

use super::handle::CompletionSender;
use super::queue::{Closed, WorkQueue};
use crate::coordinator::metrics::LatencyStats;
use crate::coordinator::router::{RoutedResult, WorkloadKind};
use crate::coordinator::scheduler::ModelInstance;
use crate::models::residency::{residency_lock, ResidencyManager, ResidentImage};
use crate::models::{PartialOut, ShardedModel};
use crate::obs::{TraceCtx, TraceEvent};
use crate::soc::{JobReport, Soc, SocConfig};
use crate::util::hosttime::{host_now, HostInstant};
use crate::util::lockdep::{lock_tracked, LockClass, Tracked};
use crate::util::Matrix;
use anyhow::Result;
use std::collections::VecDeque;
use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;

/// One unit of work for a replica worker.
pub struct Job {
    /// Submission timestamp (host clock, via the quarantined
    /// [`crate::util::hosttime`] boundary) — queue latency is measured
    /// from here to worker pickup.
    pub enqueued: HostInstant,
    /// The request's tracing handle, when the fleet has a trace sink
    /// enabled. `None` (the default for direct runtime users) means no
    /// emission code runs at all — tracing is provably zero-overhead
    /// when off.
    pub trace: Option<TraceCtx>,
    /// What to run (see [`JobPayload`]).
    pub payload: JobPayload,
}

/// What the worker runs while holding the replica device lock.
pub enum JobPayload {
    /// A whole-model inference (the resident fast path).
    Infer {
        kind: WorkloadKind,
        inst: Arc<ModelInstance>,
        input: Vec<f32>,
        aux: Vec<f32>,
        /// The replica's DRAM-budget catalog, when the dispatcher runs
        /// one (the router always does): the worker **admits** the
        /// model before inferring — a cold model triggers policy-driven
        /// evict → warm under the device lock, and the dispatch pin the
        /// router took is released after the job. `None` = unmanaged
        /// legacy path (direct runtime users, tests): the model warms
        /// on demand with no budget accounting.
        residency: Option<Arc<Mutex<ResidencyManager>>>,
        /// Gateway-predicted **warm-ahead** target
        /// ([`crate::coordinator::RuntimeConfig::warm_ahead`]): after
        /// this job completes, the worker streams the predicted-next
        /// cold model into the catalog through the same budgeted
        /// admission — its weight upload is charged to the AXI
        /// **management** initiator while the replica is between
        /// requests, so the next dispatch finds the model already
        /// warm. Best effort: an over-budget or failed admission just
        /// leaves the model cold. `None` = prediction off (the
        /// default) — the serving path is untouched.
        warm_ahead: Option<Arc<ModelInstance>>,
        /// Fulfilled with the inference result (or its error).
        done: CompletionSender<Result<RoutedResult>>,
    },
    /// One **partial GEMM** of a sharded layer: the coordinator-scaled
    /// A slice runs against this replica's resident weight shard. A
    /// K-split slice sends raw partial quires back for cross-shard
    /// reduction; an N-split slice runs its shard-local tail here and
    /// sends back a rounded f32 column block (`s_a` is the layer's
    /// dynamic activation scale the tail folds).
    Partial {
        shard: Arc<ShardedModel>,
        gemm_idx: usize,
        a: Matrix,
        s_a: f64,
        done: CompletionSender<Result<(PartialOut, JobReport)>>,
    },
    /// Diagnostic escape hatch: run an arbitrary closure on the replica
    /// (device checks, and the panic-containment regression tests).
    Probe {
        run: Box<dyn FnOnce(&mut Soc) -> Result<Vec<f32>> + Send>,
        done: CompletionSender<Result<Vec<f32>>>,
    },
}

/// Typed error a waiter receives when the replica worker **panicked**
/// while executing its job: the panic is contained, the completion
/// fails with this instead of a hang or an opaque cancellation, and the
/// worker keeps draining its queue.
#[derive(Debug, Clone)]
pub struct WorkerPanic {
    /// Replica whose worker panicked.
    pub replica: usize,
    /// The panic payload, when it was a string.
    pub message: String,
}

impl WorkerPanic {
    /// Build from a [`catch_unwind`] payload (also used by the router's
    /// sharded-coordinator fence — same containment, same typed error).
    pub(crate) fn new(replica: usize, payload: Box<dyn std::any::Any + Send>) -> WorkerPanic {
        let message = payload
            .downcast_ref::<&str>()
            .map(|s| s.to_string())
            .or_else(|| payload.downcast_ref::<String>().cloned())
            .unwrap_or_else(|| "non-string panic payload".into());
        WorkerPanic { replica, message }
    }
}

impl fmt::Display for WorkerPanic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "replica {} worker panicked: {}", self.replica, self.message)
    }
}

impl std::error::Error for WorkerPanic {}

/// Take a replica device lock, clearing poisoning: a contained worker
/// panic poisons the mutex on unwind, but every job is fenced by
/// [`catch_unwind`] and the SoC's warm-state handoff is per-request
/// (worst case a later request re-warms), so the device stays usable —
/// a poisoned-lock panic cascade would turn one bad request into a dead
/// replica. Order-tracked in debug builds ([`LockClass::Device`] is the
/// outermost rank — never acquire it while holding a residency or
/// shared lock on the same thread).
pub fn device_lock(soc: &Mutex<Soc>) -> Tracked<MutexGuard<'_, Soc>> {
    lock_tracked(soc, LockClass::Device)
}

/// Latency samples over a bounded sliding window. The serving runtime
/// is long-lived (continuous XR traffic), so an unbounded sample vector
/// would grow forever; the window keeps the last `cap` samples
/// ([`WindowedStats::DEFAULT_WINDOW`] by default) for percentiles while
/// a monotone `recorded` counter preserves "how many ever" for
/// incremental consumers (the autoscale tick). Also the sample window
/// behind [`crate::serve::Autoscaler`] — one copy of the window logic.
#[derive(Debug, Clone)]
pub struct WindowedStats {
    cap: usize,
    window: VecDeque<u64>,
    recorded: u64,
}

impl Default for WindowedStats {
    fn default() -> Self {
        WindowedStats::with_window(WindowedStats::DEFAULT_WINDOW)
    }
}

impl WindowedStats {
    /// Samples retained for percentile queries unless configured.
    pub const DEFAULT_WINDOW: usize = 4096;

    /// Stats retaining the last `cap` samples (cap >= 1).
    pub fn with_window(cap: usize) -> WindowedStats {
        assert!(cap >= 1);
        WindowedStats { cap, window: VecDeque::new(), recorded: 0 }
    }

    /// Append one sample, dropping the oldest past the window cap.
    pub fn record(&mut self, v: u64) {
        if self.window.len() == self.cap {
            self.window.pop_front();
        }
        self.window.push_back(v);
        self.recorded += 1;
    }

    /// Samples currently in the window.
    pub fn count(&self) -> usize {
        self.window.len()
    }

    /// Samples ever recorded (monotone).
    pub fn recorded(&self) -> u64 {
        self.recorded
    }

    /// The newest `n` samples, oldest first (clamped to the window).
    pub fn tail(&self, n: usize) -> Vec<u64> {
        let skip = self.window.len().saturating_sub(n);
        self.window.iter().skip(skip).copied().collect()
    }

    /// Nearest-rank percentile over the window (see
    /// [`LatencyStats::percentile`]).
    pub fn percentile(&self, p: f64) -> u64 {
        let mut stats = LatencyStats::new();
        for &s in &self.window {
            stats.record(s);
        }
        stats.percentile(p)
    }

    /// Window median ([`WindowedStats::percentile`] at 50).
    pub fn p50(&self) -> u64 {
        self.percentile(50.0)
    }

    /// Window 95th percentile — the autoscalers' pressure signal.
    pub fn p95(&self) -> u64 {
        self.percentile(95.0)
    }

    /// Window 99th percentile (tail latency).
    pub fn p99(&self) -> u64 {
        self.percentile(99.0)
    }

    /// Window maximum.
    pub fn max(&self) -> u64 {
        self.window.iter().copied().max().unwrap_or(0)
    }

    /// Window mean.
    pub fn mean(&self) -> f64 {
        if self.window.is_empty() {
            return 0.0;
        }
        self.window.iter().sum::<u64>() as f64 / self.window.len() as f64
    }
}

/// Host-side latency accounting for the async serving path, in
/// **nanoseconds** (wall clock — this is the signal the autoscaler
/// reacts to; simulated-cycle latency lives in
/// [`crate::coordinator::BatchMetrics`]).
#[derive(Debug, Clone, Default)]
pub struct RuntimeMetrics {
    /// Time each job sat queued before a worker picked it up.
    pub queue: WindowedStats,
    /// Time each job spent executing (replica lock + replay).
    pub service: WindowedStats,
    /// **Simulated** service cost of each successful job in engine
    /// cycles (`ExecReport`/`JobReport` totals) — the wall-clock-free
    /// congestion signal [`super::CycleAutoscaler`] consumes, so scaling
    /// decisions reproduce exactly regardless of host speed.
    pub service_cycles: WindowedStats,
    /// Jobs completed (fulfilled, whether Ok or Err).
    pub completed: u64,
    /// Jobs whose execution panicked (contained; the waiter got a typed
    /// [`WorkerPanic`] error).
    pub worker_panics: u64,
    /// Times a worker's drain loop itself died and was respawned by the
    /// supervisor.
    pub worker_respawns: u64,
    /// Models evicted by the DRAM-budget residency managers (filled in
    /// by the router from the per-replica
    /// [`crate::models::residency::ResidencyStats`]; zero on a bare
    /// [`ServeRuntime`]).
    pub evictions: u64,
    /// Live compactions performed by the residency managers.
    pub compactions: u64,
    /// Cold models made warm by an admission (registration floor warms
    /// and dispatch-triggered warms alike).
    pub cold_warms: u64,
    /// Highest per-replica budgeted warm-set footprint ever reached,
    /// bytes (max across replicas).
    pub resident_high_water: u64,
}

struct SharedState {
    metrics: RuntimeMetrics,
    /// Jobs dispatched but not yet fulfilled (queued + executing).
    busy: usize,
}

/// State shared between the dispatcher and every worker.
struct Shared {
    state: Mutex<SharedState>,
    idle: Condvar,
}

/// One spawned worker: its queue plus the thread draining it.
pub struct ReplicaWorker {
    /// The replica index this worker drains (fleet-wide, 0-based).
    pub id: usize,
    queue: Arc<WorkQueue<Job>>,
    handle: Option<JoinHandle<()>>,
}

/// Take the shared-state lock, clearing poisoning (see [`device_lock`]).
/// [`LockClass::Shared`] is the leaf rank: this lock is never held
/// across a device or residency acquisition.
fn shared_lock(shared: &Shared) -> Tracked<MutexGuard<'_, SharedState>> {
    lock_tracked(&shared.state, LockClass::Shared)
}

/// Account one finished job *before* its completion is fulfilled: a
/// caller that redeems the handle is then guaranteed to observe the job
/// in [`RuntimeMetrics`] and out of `in_flight()`. Runs for panicked
/// jobs too — a panic must never strand `busy` (quiesce would hang).
fn account(shared: &Shared, waited: u64, service: u64, sim_cycles: Option<u64>, panicked: bool) {
    let mut st = shared_lock(shared);
    st.metrics.queue.record(waited);
    st.metrics.service.record(service);
    if let Some(c) = sim_cycles {
        st.metrics.service_cycles.record(c);
    }
    st.metrics.completed += 1;
    if panicked {
        st.metrics.worker_panics += 1;
    }
    st.busy -= 1;
    shared.idle.notify_all();
}

impl ReplicaWorker {
    fn spawn(
        id: usize,
        soc: Arc<Mutex<Soc>>,
        shared: Arc<Shared>,
        queue_capacity: usize,
    ) -> ReplicaWorker {
        let queue = Arc::new(WorkQueue::bounded(queue_capacity));
        let q = Arc::clone(&queue);
        let handle = std::thread::Builder::new()
            .name(format!("xr-npe-replica-{id}"))
            .spawn(move || {
                // Respawn-on-panic supervisor: each job is individually
                // fenced below, so a drain-loop death means something
                // outside a job fence panicked — restart the loop
                // instead of stranding the queue (pending jobs would
                // otherwise hang until shutdown).
                loop {
                    let run =
                        catch_unwind(AssertUnwindSafe(|| Self::drain(id, &q, &soc, &shared)));
                    match run {
                        Ok(()) => break, // queue closed and drained
                        Err(_) => shared_lock(&shared).metrics.worker_respawns += 1,
                    }
                }
            })
            // xr_lint: allow(no-panic) -- thread-spawn failure at runtime construction is unrecoverable by design
            .expect("spawn replica worker");
        ReplicaWorker { id, queue, handle: Some(handle) }
    }

    /// The drain loop: pop → execute under the device lock (panic-
    /// fenced) → account → fulfill. A job that panics fails its
    /// completion with a typed [`WorkerPanic`] and the loop continues —
    /// one poisoned request cannot strand the queued requests behind it.
    fn drain(id: usize, q: &WorkQueue<Job>, soc: &Arc<Mutex<Soc>>, shared: &Shared) {
        while let Some(job) = q.pop() {
            let waited = job.enqueued.elapsed_nanos();
            let t0 = host_now();
            let trace = job.trace;
            if let Some(tr) = &trace {
                tr.emit(id, 0, 0, TraceEvent::Dispatch);
            }
            match job.payload {
                JobPayload::Infer { kind, inst, input, aux, residency, warm_ahead, done } => {
                    let mut admitted = None;
                    let res = catch_unwind(AssertUnwindSafe(
                        || -> Result<(Vec<f32>, crate::models::ExecReport)> {
                        let mut dev = device_lock(soc);
                        if let Some(mgr) = &residency {
                            // budget admission: a cold model evicts
                            // policy-chosen victims (compacting a
                            // fragmented free list) before warming —
                            // all under the device lock, so a relocated
                            // arena is never observed mid-move
                            let image: Arc<dyn ResidentImage> = Arc::clone(&inst.compiled);
                            admitted = Some(residency_lock(mgr).admit_outcome(&mut dev, &image)?);
                        }
                        inst.infer(&mut dev, &input, &aux)
                    },
                    ));
                    let service = t0.elapsed_nanos();
                    let cycles = match &res {
                        Ok(Ok((_, rep))) => Some(rep.total_cycles()),
                        _ => None,
                    };
                    // release the dispatch pin before accounting: once
                    // quiesce observes the job done, nothing can still
                    // hold its eviction protection
                    if let Some(mgr) = &residency {
                        residency_lock(mgr).unpin(inst.compiled.uid());
                    }
                    // gateway-predicted warm-ahead: stream the
                    // predicted-next cold model into the catalog after
                    // this job's compute, before the next dispatch can
                    // land — the upload rides the AXI management
                    // budget. Panic-fenced and best effort; runs
                    // before the job is accounted so completion
                    // implies the warm-ahead landed (deterministic for
                    // tests).
                    let mut warm_ahead_cycles = 0u64;
                    if let (Some(mgr), Some(next)) = (&residency, &warm_ahead) {
                        let image: Arc<dyn ResidentImage> = Arc::clone(&next.compiled);
                        let warmed = catch_unwind(AssertUnwindSafe(|| {
                            let mut dev = device_lock(soc);
                            let before = dev.management_traffic().cycles;
                            let ok = residency_lock(mgr).admit_outcome(&mut dev, &image).is_ok();
                            (ok, dev.management_traffic().cycles.saturating_sub(before))
                        }));
                        if let Ok((true, spent)) = warmed {
                            warm_ahead_cycles = spent;
                        }
                    }
                    // trace spans are derived from report values that
                    // are already computed — emission cannot perturb
                    // the simulated accounting
                    if let Some(tr) = &trace {
                        if let Some(o) = &admitted {
                            if o.evictions > 0 {
                                tr.emit(id, 0, 0, TraceEvent::Evict { count: o.evictions });
                            }
                            if o.compactions > 0 {
                                tr.emit(id, 0, 0, TraceEvent::Compact { count: o.compactions });
                            }
                            if o.cold_warms > 0 {
                                tr.emit(id, 0, 0, TraceEvent::ColdWarm { count: o.cold_warms });
                            }
                        }
                        match &res {
                            Ok(Ok((_, rep))) => {
                                let mut at = 0u64;
                                for &(layer, c) in &rep.per_layer_cycles {
                                    tr.emit(id, at, c, TraceEvent::GemmJob { layer });
                                    at += c;
                                }
                                tr.emit(id, at, rep.vector_cycles, TraceEvent::Requantize);
                                if warm_ahead_cycles > 0 {
                                    tr.emit(
                                        id,
                                        rep.total_cycles(),
                                        warm_ahead_cycles,
                                        TraceEvent::Prefetch,
                                    );
                                }
                                // per-request plan stamp: which ladder
                                // rung (0 for single-plan models)
                                // produced this report
                                tr.emit(
                                    id,
                                    rep.total_cycles(),
                                    0,
                                    TraceEvent::PlanStamp { rung: rep.rung },
                                );
                                tr.emit(id, rep.total_cycles(), 0, TraceEvent::Complete);
                            }
                            Ok(Err(_)) => {}
                            Err(_) => tr.emit(id, 0, 0, TraceEvent::WorkerPanic),
                        }
                    }
                    account(shared, waited, service, cycles, res.is_err());
                    match res {
                        Ok(r) => done.fulfill(r.map(|(output, report)| RoutedResult {
                            kind,
                            output,
                            report,
                            replica: id,
                        })),
                        Err(p) => done.fulfill(Err(WorkerPanic::new(id, p).into())),
                    }
                }
                JobPayload::Partial { shard, gemm_idx, a, s_a, done } => {
                    let res = catch_unwind(AssertUnwindSafe(|| {
                        let mut dev = device_lock(soc);
                        shard.run_gemm(&mut dev, gemm_idx, &a, s_a)
                    }));
                    let service = t0.elapsed_nanos();
                    let cycles = match &res {
                        Ok(Ok((_, rep))) => Some(rep.total_cycles),
                        _ => None,
                    };
                    // partial spans themselves are stamped by the
                    // coordinator's shard channel (which owns the lane
                    // cursors); the worker only flags contained panics
                    if let (Some(tr), Err(_)) = (&trace, &res) {
                        tr.emit(id, 0, 0, TraceEvent::WorkerPanic);
                    }
                    account(shared, waited, service, cycles, res.is_err());
                    match res {
                        Ok(r) => done.fulfill(r),
                        Err(p) => done.fulfill(Err(WorkerPanic::new(id, p).into())),
                    }
                }
                JobPayload::Probe { run, done } => {
                    let res = catch_unwind(AssertUnwindSafe(|| {
                        let mut dev = device_lock(soc);
                        run(&mut dev)
                    }));
                    let service = t0.elapsed_nanos();
                    if let (Some(tr), Err(_)) = (&trace, &res) {
                        tr.emit(id, 0, 0, TraceEvent::WorkerPanic);
                    }
                    account(shared, waited, service, None, res.is_err());
                    match res {
                        Ok(r) => done.fulfill(r),
                        Err(p) => done.fulfill(Err(WorkerPanic::new(id, p).into())),
                    }
                }
            }
        }
    }
}

/// The serving runtime: `n` replicas, each an `Arc<Mutex<Soc>>` drained
/// by its own worker thread through its own bounded queue. Dropping the
/// runtime closes every queue (pending jobs still drain) and joins the
/// workers.
pub struct ServeRuntime {
    socs: Vec<Arc<Mutex<Soc>>>,
    workers: Vec<ReplicaWorker>,
    shared: Arc<Shared>,
}

impl ServeRuntime {
    /// Spawn `n` replica workers over fresh SoCs.
    pub fn new(n: usize, cfg: SocConfig, queue_capacity: usize) -> ServeRuntime {
        assert!(n >= 1);
        let shared = Arc::new(Shared {
            state: Mutex::new(SharedState { metrics: RuntimeMetrics::default(), busy: 0 }),
            idle: Condvar::new(),
        });
        let socs: Vec<Arc<Mutex<Soc>>> =
            (0..n).map(|_| Arc::new(Mutex::new(Soc::new(cfg)))).collect();
        let workers = socs
            .iter()
            .enumerate()
            .map(|(i, soc)| {
                ReplicaWorker::spawn(i, Arc::clone(soc), Arc::clone(&shared), queue_capacity)
            })
            .collect();
        ServeRuntime { socs, workers, shared }
    }

    /// Number of replica workers (and SoCs) this runtime drives.
    pub fn n_replicas(&self) -> usize {
        self.socs.len()
    }

    /// Direct handle to replica `i`'s SoC (registration, stats). Lock
    /// order: never hold two replica locks at once.
    pub fn soc(&self, i: usize) -> &Arc<Mutex<Soc>> {
        &self.socs[i]
    }

    /// Enqueue a job on replica `replica`'s queue, blocking if that
    /// queue is full (bounded admission = back-pressure).
    pub fn dispatch(&self, replica: usize, job: Job) -> Result<(), Closed> {
        shared_lock(&self.shared).busy += 1;
        match self.workers[replica].queue.push(job) {
            Ok(()) => Ok(()),
            Err(e) => {
                let mut st = shared_lock(&self.shared);
                st.busy -= 1;
                self.shared.idle.notify_all();
                Err(e)
            }
        }
    }

    /// Jobs queued (not yet picked up) on replica `i`.
    pub fn queue_len(&self, i: usize) -> usize {
        self.workers[i].queue.len()
    }

    /// Jobs dispatched but not yet fulfilled, runtime-wide.
    pub fn in_flight(&self) -> usize {
        shared_lock(&self.shared).busy
    }

    /// Block until every dispatched job has finished executing and been
    /// accounted (its completion may be a fulfillment away — `wait` on
    /// the handle still blocks until it lands). Used by registration to
    /// let in-flight requests against a replaced model drain off the
    /// hardware before its warm state is evicted.
    pub fn quiesce(&self) {
        let mut st = shared_lock(&self.shared);
        while st.busy > 0 {
            st = st.wait(&self.shared.idle);
        }
    }

    /// Snapshot of the host-side latency metrics.
    pub fn metrics(&self) -> RuntimeMetrics {
        shared_lock(&self.shared).metrics.clone()
    }

    /// Queue-latency samples recorded after the caller's last
    /// checkpoint (the autoscale tick's incremental feed). `seen` is
    /// the total returned by the previous call (0 initially); returns
    /// the new samples still retained in the window (oldest first) and
    /// the new checkpoint.
    pub fn queue_samples_since(&self, seen: u64) -> (Vec<u64>, u64) {
        let st = shared_lock(&self.shared);
        let q = &st.metrics.queue;
        let total = q.recorded();
        let missed = total.saturating_sub(seen) as usize;
        (q.tail(missed), total)
    }

    /// Simulated service-cycle samples recorded after the caller's last
    /// checkpoint — the [`super::CycleAutoscaler`]'s incremental feed
    /// (mirror of [`ServeRuntime::queue_samples_since`]).
    pub fn service_cycle_samples_since(&self, seen: u64) -> (Vec<u64>, u64) {
        let st = shared_lock(&self.shared);
        let s = &st.metrics.service_cycles;
        let total = s.recorded();
        let missed = total.saturating_sub(seen) as usize;
        (s.tail(missed), total)
    }
}

impl Drop for ServeRuntime {
    fn drop(&mut self) {
        for w in &self.workers {
            w.queue.close();
        }
        for w in &mut self.workers {
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::random_weights;
    use crate::models::{effnet, gaze};
    use crate::npe::PrecSel;
    use crate::serve::handle::completion;

    fn gaze_inst(seed: u64) -> Arc<ModelInstance> {
        let g = gaze::build();
        let w = random_weights(&g, seed);
        Arc::new(ModelInstance::uniform(g, w, PrecSel::Posit8x2).unwrap())
    }

    fn job(
        inst: &Arc<ModelInstance>,
        input: Vec<f32>,
    ) -> (Job, crate::serve::handle::Completion<Result<RoutedResult>>) {
        let (tx, rx) = completion();
        (
            Job {
                enqueued: host_now(),
                trace: None,
                payload: JobPayload::Infer {
                    kind: WorkloadKind::Gaze,
                    inst: Arc::clone(inst),
                    input,
                    aux: vec![],
                    residency: None,
                    warm_ahead: None,
                    done: tx,
                },
            },
            rx,
        )
    }

    #[test]
    fn worker_serves_jobs_and_records_metrics() {
        let rt = ServeRuntime::new(2, SocConfig::default(), 8);
        let inst = gaze_inst(1);
        let mut handles = Vec::new();
        for i in 0..6 {
            let (j, rx) = job(&inst, vec![0.01 * i as f32; 16]);
            rt.dispatch(i % 2, j).unwrap();
            handles.push(rx);
        }
        for (i, rx) in handles.into_iter().enumerate() {
            let res = rx.wait().unwrap().unwrap();
            assert_eq!(res.output.len(), 2, "job {i}");
            assert_eq!(res.replica, i % 2);
        }
        rt.quiesce();
        let m = rt.metrics();
        assert_eq!(m.completed, 6);
        assert_eq!(m.queue.count(), 6);
        assert_eq!(m.service.count(), 6);
        assert!(m.service.max() > 0, "service time must be recorded");
        assert_eq!(rt.in_flight(), 0);
    }

    #[test]
    fn worker_warms_replica_on_demand() {
        let rt = ServeRuntime::new(1, SocConfig::default(), 4);
        let inst = gaze_inst(2);
        let n_gemm = inst.compiled.n_gemm() as u64;
        // nothing warmed the replica — the first job does it in-loop
        assert_eq!(rt.soc(0).lock().unwrap().enc_cache.preloads, 0);
        let (j, rx) = job(&inst, vec![0.1; 16]);
        rt.dispatch(0, j).unwrap();
        rx.wait().unwrap().unwrap();
        assert_eq!(rt.soc(0).lock().unwrap().enc_cache.preloads, n_gemm);
    }

    #[test]
    fn same_replica_jobs_serialize_in_fifo_order() {
        // two models' jobs interleaved on one replica stay coherent and
        // the lifetime stats accumulate every job
        let rt = ServeRuntime::new(1, SocConfig::default(), 16);
        let gi = gaze_inst(3);
        let ge = effnet::build();
        let we = random_weights(&ge, 4);
        let ei = Arc::new(ModelInstance::uniform(ge, we, PrecSel::Fp4x4).unwrap());
        let mut rxs = Vec::new();
        for i in 0..4 {
            let (j, rx) = job(&gi, vec![0.02 * i as f32; 16]);
            rt.dispatch(0, j).unwrap();
            rxs.push(rx.wait().unwrap().unwrap().output);
            let (tx, rx) = completion();
            rt.dispatch(
                0,
                Job {
                    enqueued: host_now(),
                    trace: None,
                    payload: JobPayload::Infer {
                        kind: WorkloadKind::Classify,
                        inst: Arc::clone(&ei),
                        input: vec![0.1; 256],
                        aux: vec![],
                        residency: None,
                        warm_ahead: None,
                        done: tx,
                    },
                },
            )
            .unwrap();
            assert_eq!(rx.wait().unwrap().unwrap().output.len(), 10);
        }
        // identical inputs replayed later give identical outputs (no
        // cross-model clobbering of warm state)
        let (j, rx) = job(&gi, vec![0.0; 16]);
        rt.dispatch(0, j).unwrap();
        let again = rx.wait().unwrap().unwrap().output;
        assert_eq!(again, rxs[0]);
        rt.quiesce();
        assert_eq!(rt.metrics().completed, 9);
    }

    #[test]
    fn windowed_stats_bound_retention_but_count_everything() {
        let mut s = WindowedStats::default();
        for v in 0..(WindowedStats::DEFAULT_WINDOW as u64 + 100) {
            s.record(v);
        }
        assert_eq!(s.count(), WindowedStats::DEFAULT_WINDOW, "window must stay bounded");
        assert_eq!(s.recorded(), WindowedStats::DEFAULT_WINDOW as u64 + 100, "recorded is monotone");
        // the oldest 100 samples were displaced
        assert_eq!(s.percentile(0.0), 100);
        assert_eq!(s.max(), WindowedStats::DEFAULT_WINDOW as u64 + 99);
        assert_eq!(s.tail(3), vec![
            WindowedStats::DEFAULT_WINDOW as u64 + 97,
            WindowedStats::DEFAULT_WINDOW as u64 + 98,
            WindowedStats::DEFAULT_WINDOW as u64 + 99,
        ]);
        assert_eq!(s.tail(usize::MAX).len(), WindowedStats::DEFAULT_WINDOW, "tail clamps to the window");
    }

    #[test]
    fn windowed_stats_empty_window_is_all_zeros() {
        let s = WindowedStats::default();
        assert_eq!(s.count(), 0);
        assert_eq!(s.recorded(), 0);
        assert_eq!(s.max(), 0);
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.p50(), 0);
        assert_eq!(s.p99(), 0);
        assert!(s.tail(5).is_empty());
    }

    #[test]
    fn windowed_stats_repeated_wraparound_stays_exact() {
        // wrap a tiny window many times over: retention stays bounded,
        // `recorded` stays monotone-exact, and every percentile is a
        // function of the *live* window only — displaced samples can
        // never resurface
        let mut s = WindowedStats::with_window(4);
        for round in 0u64..10 {
            for v in 0..4 {
                s.record(round * 1000 + v);
            }
            assert_eq!(s.count(), 4);
            assert_eq!(s.recorded(), (round + 1) * 4);
            // after each full round the window holds exactly that
            // round's four samples
            assert_eq!(s.percentile(0.0), round * 1000);
            assert_eq!(s.max(), round * 1000 + 3);
            assert_eq!(s.p50(), round * 1000 + 1);
            assert_eq!(s.mean(), round as f64 * 1000.0 + 1.5);
        }
        // a partial extra wrap displaces only the oldest samples
        s.record(99_999);
        assert_eq!(s.count(), 4);
        assert_eq!(s.recorded(), 41);
        assert_eq!(s.percentile(0.0), 9001, "oldest live sample after displacement");
        assert_eq!(s.max(), 99_999);
    }

    fn probe_job(
        f: impl FnOnce(&mut crate::soc::Soc) -> Result<Vec<f32>> + Send + 'static,
    ) -> (Job, crate::serve::handle::Completion<Result<Vec<f32>>>) {
        let (tx, rx) = completion();
        (
            Job {
                enqueued: host_now(),
                trace: None,
                payload: JobPayload::Probe { run: Box::new(f), done: tx },
            },
            rx,
        )
    }

    #[test]
    fn panicking_job_fails_typed_and_queue_keeps_draining() {
        // the panic-containment regression: a deliberately panicking
        // job must fail its own completion with a typed WorkerPanic —
        // and the jobs queued behind it must still serve
        let rt = ServeRuntime::new(1, SocConfig::default(), 8);
        let inst = gaze_inst(7);
        let (bomb, bomb_rx) = probe_job(|_| panic!("injected test panic"));
        let (after, after_rx) = job(&inst, vec![0.1; 16]);
        rt.dispatch(0, bomb).unwrap();
        rt.dispatch(0, after).unwrap();
        let err = bomb_rx.wait().unwrap().unwrap_err();
        let wp = err.downcast_ref::<WorkerPanic>().expect("typed WorkerPanic");
        assert_eq!(wp.replica, 0);
        assert!(wp.message.contains("injected test panic"), "{}", wp.message);
        // the queue behind the panicking job is NOT stranded
        assert_eq!(after_rx.wait().unwrap().unwrap().output.len(), 2);
        rt.quiesce();
        let m = rt.metrics();
        assert_eq!(m.completed, 2, "panicked jobs still complete and count");
        assert_eq!(m.worker_panics, 1);
        assert_eq!(rt.in_flight(), 0, "a panic must not strand busy accounting");
    }

    #[test]
    fn replica_survives_repeated_panics_between_real_work() {
        let rt = ServeRuntime::new(1, SocConfig::default(), 8);
        let inst = gaze_inst(8);
        let (j0, rx0) = job(&inst, vec![0.2; 16]);
        rt.dispatch(0, j0).unwrap();
        let first = rx0.wait().unwrap().unwrap().output;
        for round in 0..3 {
            let (bomb, bomb_rx) = probe_job(move |_| panic!("boom {round}"));
            rt.dispatch(0, bomb).unwrap();
            assert!(bomb_rx.wait().unwrap().is_err());
            // identical input after each panic: identical output — the
            // device lock recovered and warm state still serves
            let (j, rx) = job(&inst, vec![0.2; 16]);
            rt.dispatch(0, j).unwrap();
            assert_eq!(rx.wait().unwrap().unwrap().output, first, "round {round}");
        }
        rt.quiesce();
        assert_eq!(rt.metrics().worker_panics, 3);
    }

    #[test]
    fn service_cycles_metric_records_simulated_cost() {
        let rt = ServeRuntime::new(1, SocConfig::default(), 8);
        let inst = gaze_inst(9);
        let mut want = Vec::new();
        for i in 0..4 {
            let (j, rx) = job(&inst, vec![0.01 * i as f32; 16]);
            rt.dispatch(0, j).unwrap();
            want.push(rx.wait().unwrap().unwrap().report.total_cycles());
        }
        rt.quiesce();
        let m = rt.metrics();
        assert_eq!(m.service_cycles.count(), 4);
        // incremental feed returns exactly the recorded sim-cycle totals
        let (samples, total) = rt.service_cycle_samples_since(0);
        assert_eq!(total, 4);
        assert_eq!(samples, want, "sim-cycle samples must match the job reports exactly");
        let (fresh, _) = rt.service_cycle_samples_since(total);
        assert!(fresh.is_empty());
    }

    #[test]
    fn managed_jobs_admit_through_the_residency_manager() {
        // jobs carrying a residency manager rotate two models through a
        // budget that holds only one of them — evictions and cold warms
        // are counted, and every job still serves correct outputs
        let rt = ServeRuntime::new(1, SocConfig::default(), 8);
        // budget = one gaze model (+ slack), far below the real limit
        let budget = {
            let gi = gaze_inst(20);
            gi.compiled.warm_footprint_bytes() as u64 + 1024
        };
        let mgr = Arc::new(Mutex::new(ResidencyManager::lru(budget)));
        let a = gaze_inst(21);
        let b = gaze_inst(22);
        let managed = |inst: &Arc<ModelInstance>, x: f32| {
            let (tx, rx) = completion();
            (
                Job {
                    enqueued: host_now(),
                    trace: None,
                    payload: JobPayload::Infer {
                        kind: WorkloadKind::Gaze,
                        inst: Arc::clone(inst),
                        input: vec![x; 16],
                        aux: vec![],
                        residency: Some(Arc::clone(&mgr)),
                        warm_ahead: None,
                        done: tx,
                    },
                },
                rx,
            )
        };
        let mut first = Vec::new();
        for round in 0..3 {
            for inst in [&a, &b] {
                let (j, rx) = managed(inst, 0.1);
                rt.dispatch(0, j).unwrap();
                let out = rx.wait().unwrap().unwrap().output;
                if round == 0 {
                    first.push(out);
                } else {
                    // re-warmed model serves bit-identically
                    let want = &first[if Arc::ptr_eq(inst, &a) { 0 } else { 1 }];
                    assert_eq!(&out, want, "round {round}");
                }
            }
        }
        rt.quiesce();
        let s = residency_lock(&mgr).stats();
        assert_eq!(s.cold_warms, 6, "every dispatch found its model cold");
        assert_eq!(s.evictions, 5, "each admit after the first evicts the other model");
        assert!(s.resident_high_water <= budget);
    }

    #[test]
    fn warm_ahead_streams_the_predicted_model_on_the_management_budget() {
        // a job carrying a warm-ahead prediction leaves the predicted
        // model warm by the time its completion is observable, with
        // the cold-model upload charged to the AXI management
        // initiator — the gateway-predicted analogue of the streaming
        // flow's double-buffered weight prefetch
        let rt = ServeRuntime::new(1, SocConfig::default(), 8);
        let a = gaze_inst(30);
        let b = gaze_inst(31);
        let budget = a.compiled.warm_footprint_bytes() as u64
            + b.compiled.warm_footprint_bytes() as u64
            + 1024;
        let mgr = Arc::new(Mutex::new(ResidencyManager::lru(budget)));
        let (tx, rx) = completion();
        rt.dispatch(
            0,
            Job {
                enqueued: host_now(),
                trace: None,
                payload: JobPayload::Infer {
                    kind: WorkloadKind::Gaze,
                    inst: Arc::clone(&a),
                    input: vec![0.1; 16],
                    aux: vec![],
                    residency: Some(Arc::clone(&mgr)),
                    warm_ahead: Some(Arc::clone(&b)),
                    done: tx,
                },
            },
        )
        .unwrap();
        rx.wait().unwrap().unwrap();
        assert!(
            residency_lock(&mgr).warm_hint(b.compiled.uid()),
            "completion implies the warm-ahead admission landed"
        );
        let mgmt = rt.soc(0).lock().unwrap().management_traffic();
        assert!(
            mgmt.bytes_written >= b.compiled.warm_footprint_bytes() as u64,
            "the warm-ahead upload must ride the management budget: {mgmt:?}"
        );
        assert!(mgmt.cycles > 0);
    }

    #[test]
    fn infer_error_comes_back_through_the_completion() {
        let rt = ServeRuntime::new(1, SocConfig::default(), 4);
        let inst = gaze_inst(5);
        let (j, rx) = job(&inst, vec![0.1; 3]); // wrong input length
        rt.dispatch(0, j).unwrap();
        assert!(rx.wait().unwrap().is_err());
        rt.quiesce();
        assert_eq!(rt.metrics().completed, 1, "errors still complete and count");
    }

    #[test]
    fn drop_drains_pending_jobs() {
        let rt = ServeRuntime::new(1, SocConfig::default(), 8);
        let inst = gaze_inst(6);
        let mut rxs = Vec::new();
        for i in 0..5 {
            let (j, rx) = job(&inst, vec![0.03 * i as f32; 16]);
            rt.dispatch(0, j).unwrap();
            rxs.push(rx);
        }
        drop(rt); // closes the queue; the worker drains before exiting
        for rx in rxs {
            assert!(rx.wait().unwrap().is_ok(), "queued jobs complete during shutdown");
        }
    }
}
