//! Dataflow schedule models: output-stationary (the paper's choice,
//! implemented functionally in [`super::morphable`]) vs weight-stationary
//! — the ablation that justifies the design (bench `ablations`).
//!
//! Both models price the same GEMM on the same R×C array; they differ in
//! *what stays put* and therefore in operand-fetch traffic and cycle
//! overheads:
//!
//! * **Output-stationary (OS)**: each PE owns one output element for a
//!   whole K sweep; A rows and B columns stream. One quire write-back per
//!   output; operands are fetched per tile.
//! * **Weight-stationary (WS)**: a K×C slab of B is pinned in the PEs;
//!   A streams through, partial sums spill/reload when K exceeds the
//!   resident slab (the classic partial-sum traffic penalty — and with a
//!   quire, spilling means *rounding* partial sums, which also costs
//!   accuracy; see `quire_spill_rounds`).

use super::morphable::PIPE_STAGES;
use super::tiling::TilePlan;
use crate::npe::PrecSel;

/// Which schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dataflow {
    OutputStationary,
    WeightStationary,
}

/// Cost estimate for one GEMM under a schedule.
#[derive(Debug, Clone, Copy)]
pub struct DataflowCost {
    /// Array compute cycles.
    pub cycles: u64,
    /// Operand words fetched from SPM into the array.
    pub operand_words: u64,
    /// Partial-sum words spilled + reloaded (WS only).
    pub psum_words: u64,
    /// Quire drain/restore events that force intermediate rounding
    /// (WS only — the numerical argument for OS with a quire).
    pub quire_spill_rounds: u64,
}

/// Price a GEMM (m×k×n) on an r×c array in the given mode.
pub fn cost(
    flow: Dataflow,
    m: usize,
    k: usize,
    n: usize,
    r: usize,
    c: usize,
    sel: PrecSel,
) -> DataflowCost {
    let lanes = sel.lanes();
    let k_words = k.div_ceil(lanes) as u64;
    let plan = TilePlan::new(m, k, n, r, c);
    let fill = (r as u64 - 1) + (c as u64 - 1) + PIPE_STAGES;
    match flow {
        Dataflow::OutputStationary => {
            let mut cycles = 0u64;
            let mut words = 0u64;
            let mut prev_row = usize::MAX;
            for t in &plan.tiles {
                cycles += fill + k_words + r as u64;
                // B cols per tile; A rows once per tile row
                words += t.nt as u64 * k_words;
                if t.m0 != prev_row {
                    words += t.mt as u64 * k_words;
                    prev_row = t.m0;
                }
            }
            DataflowCost { cycles, operand_words: words, psum_words: 0, quire_spill_rounds: 0 }
        }
        Dataflow::WeightStationary => {
            // B slab resident: r rows of K are pinned per pass, i.e. the
            // array holds an (k_res × c) weight block with k_res = r·lanes
            // elements of K; the K loop outside that spills partial sums.
            let k_res = (r * lanes).max(1);
            let k_passes = k.div_ceil(k_res) as u64;
            let m_tiles = m.div_ceil(r) as u64; // A streams in r-row groups
            let n_tiles = n.div_ceil(c) as u64;
            let mut cycles = 0u64;
            let mut words = 0u64;
            let mut psum = 0u64;
            for _ in 0..n_tiles {
                for _ in 0..k_passes {
                    // load the weight slab once per (n-tile, k-pass)
                    words += (k_res.min(k) as u64).div_ceil(lanes as u64) * c as u64;
                    cycles += fill;
                    for _ in 0..m_tiles {
                        // stream A rows; each produces c partials
                        words += (r as u64) * (k_res as u64).div_ceil(lanes as u64);
                        cycles += (k_res as u64).div_ceil(lanes as u64) + r as u64;
                        if k_passes > 1 {
                            psum += (r * c) as u64; // spill + reload
                        }
                    }
                }
            }
            let spill_rounds = if k_passes > 1 {
                (k_passes - 1) * m_tiles * n_tiles * (r * c) as u64
            } else {
                0
            };
            DataflowCost {
                cycles,
                operand_words: words,
                psum_words: psum * 2, // out and back
                quire_spill_rounds: spill_rounds,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn os_matches_morphable_cycle_model() {
        // the OS cost here must equal the executed array's cycle count
        use crate::array::{ArrayMorph, MatrixArray};
        use crate::util::{Matrix, Rng};
        let mut rng = Rng::new(5);
        let a = Matrix::random(16, 64, 1.0, &mut rng);
        let b = Matrix::random(64, 16, 1.0, &mut rng);
        let mut arr = MatrixArray::new(ArrayMorph::M8x8, PrecSel::Posit8x2);
        let (_, rep) = arr.gemm(&a, &b, PrecSel::Posit8x2.precision());
        let c = cost(Dataflow::OutputStationary, 16, 64, 16, 8, 8, PrecSel::Posit8x2);
        assert_eq!(c.cycles, rep.cycles);
    }

    #[test]
    fn ws_pays_partial_sum_traffic_on_deep_k() {
        // deep K (≫ resident slab): WS spills partial sums, OS doesn't
        let os = cost(Dataflow::OutputStationary, 32, 1024, 32, 8, 8, PrecSel::Posit16x1);
        let ws = cost(Dataflow::WeightStationary, 32, 1024, 32, 8, 8, PrecSel::Posit16x1);
        assert_eq!(os.psum_words, 0);
        assert!(ws.psum_words > 0);
        assert!(ws.quire_spill_rounds > 0, "WS must round partial sums");
    }

    #[test]
    fn ws_competitive_on_shallow_k_wide_n() {
        // WS's sweet spot: K fits the resident slab (FP4: 8 PEs x 4
        // lanes = 32 >= K), weights reused across many A rows
        let os = cost(Dataflow::OutputStationary, 512, 16, 8, 8, 8, PrecSel::Fp4x4);
        let ws = cost(Dataflow::WeightStationary, 512, 16, 8, 8, 8, PrecSel::Fp4x4);
        assert_eq!(ws.quire_spill_rounds, 0);
        assert!(ws.operand_words < 2 * os.operand_words);
    }

    #[test]
    fn lanes_reduce_kwords_for_both() {
        for flow in [Dataflow::OutputStationary, Dataflow::WeightStationary] {
            let p16 = cost(flow, 64, 256, 64, 8, 8, PrecSel::Posit16x1);
            let fp4 = cost(flow, 64, 256, 64, 8, 8, PrecSel::Fp4x4);
            assert!(fp4.cycles < p16.cycles, "{flow:?}");
        }
    }
}
